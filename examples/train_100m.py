"""End-to-end training driver: ~100M-param LM, a few hundred steps.

Uses the full substrate stack (data pipeline w/ prefetch, AdamW, remat,
checkpoint/restart driver).  Loss must decrease on the structured
synthetic stream.

    PYTHONPATH=src python examples/train_100m.py --steps 300
"""
import argparse
from dataclasses import replace

import jax

from repro.configs import get_config
from repro.launch.train import build
from repro.checkpoint import CheckpointStore
from repro.runtime import FaultTolerantDriver


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    args = ap.parse_args()

    # ~100M params: gemma3 family, scaled down
    cfg = replace(get_config("gemma3-12b"),
                  n_layers=10, d_model=640, n_heads=10, n_kv_heads=5,
                  head_dim=64, d_ff=2560, vocab=32768, window=32,
                  global_every=6, dtype="float32")
    print(f"model: {cfg.n_params / 1e6:.1f}M params")

    state, step, data = build(cfg, args.steps, lr=3e-3,
                              seq_len=args.seq_len, global_batch=args.batch)
    store = CheckpointStore("artifacts/ckpt/train100m", keep=2)
    driver = FaultTolerantDriver(step, store, data, ckpt_every=100)
    state, res = driver.run(state, args.steps)
    import numpy as np
    first, last = np.mean(res.losses[:10]), np.mean(res.losses[-10:])
    print(f"steps={res.steps_done} loss {first:.3f} -> {last:.3f}")
    assert last < first, "loss did not decrease!"
    print("OK: loss decreased")


if __name__ == "__main__":
    main()
