"""Quickstart — the paper's Fig. 1 flow on its own case study, end to end.

An *unmodified* Harris corner-detection app is traced while it runs
(Frontend, Steps 1-3), the call graph incl. I/O data is rendered (Fig. 4),
the Backend looks up Pallas "hardware modules" in the database and the
Pipeline Generator builds a balanced mixed sw/hw pipeline (Step 8), which
the Function Off-loader deploys as a drop-in replacement (Step 9).

    PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax
import numpy as np

from repro.core import courier_offload
from repro.core.tracer import Library
from repro.models.harris import corner_harris_demo, make_harris_db


def main():
    # The "running binary": user code over a library namespace, never edited.
    db = make_harris_db(with_hw=True)
    lib = Library(db)
    app = corner_harris_demo(lib)

    frames = [jax.random.uniform(jax.random.PRNGKey(i), (270, 480, 3)) * 255
              for i in range(8)]

    # Steps 1-9 in one call: trace -> DB lookup -> balanced partition ->
    # token pipeline -> deployable wrapper.
    off = courier_offload(app, frames[0], db=db, n_threads=3)

    print("=== Fig.4: traced call graph (I/O data + profile) ===")
    print(off.ir.render())
    print("\n=== Step 8: generated pipeline ===")
    print(off.describe())

    # Deployed run: same semantics, pipelined execution.
    ref = app(frames[0])
    got = off(frames[0])
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-3, atol=1e-3)
    print("\nsemantics preserved: pipeline(f) == original(f)")

    for name, fn in [("original (unmodified app)",
                      lambda: [jax.block_until_ready(app(f)) for f in frames]),
                     ("Courier pipeline (token stream)",
                      lambda: jax.block_until_ready(off.map(frames)))]:
        fn()                      # warmup
        t0 = time.perf_counter()
        fn()
        print(f"{name:34s}: {(time.perf_counter() - t0) * 1e3 / len(frames):7.2f} ms/frame")


if __name__ == "__main__":
    main()
