"""Batched serving examples.

LM mode (default): prefill + KV-cache decode on a reduced config::

    PYTHONPATH=src python examples/serve_batched.py --arch gemma3-12b

Pipeline mode: dynamic-batching request-queue server over the Courier
Harris pipeline (bounded-token-pool backpressure, per-request latency
stats)::

    PYTHONPATH=src python examples/serve_batched.py --mode pipeline \\
        --requests 64 --max-batch 8
"""
import sys

from repro.launch import serve

if __name__ == "__main__":
    sys.argv = [sys.argv[0]] + (sys.argv[1:] or
                                ["--arch", "gemma3-12b", "--reduced"])
    serve.main()
