"""Batched serving example: prefill + KV-cache decode on a reduced config.

    PYTHONPATH=src python examples/serve_batched.py --arch gemma3-12b
"""
import sys

from repro.launch import serve

if __name__ == "__main__":
    sys.argv = [sys.argv[0]] + (sys.argv[1:] or
                                ["--arch", "gemma3-12b", "--reduced"])
    serve.main()
