"""Courier at pod scale: balanced pipeline parallelism via shard_map.

The paper's Pipeline Generator decides *stage boundaries* from per-stage
costs; here those boundaries place transformer layers onto a 4-stage mesh
axis and a microbatch token pipeline (ppermute hand-offs) executes them —
TBB tokens become microbatches.  Layers are deliberately heterogeneous in
cost, so the Courier balanced partition differs from naive equal-count
splitting, and the example quantifies the predicted bottleneck gain.

Runs on 8 virtual host devices (set before jax import).
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (CourierIR, Node, linear_ir, partition_optimal,
                        partition_paper, pipeline_microbatches)

try:                                    # AxisType only exists on jax>=0.5
    from jax.sharding import AxisType
    _mesh = lambda shape, axes: jax.make_mesh(
        shape, axes, axis_types=(AxisType.Auto,) * len(axes))
except ImportError:
    _mesh = lambda shape, axes: jax.make_mesh(shape, axes)


def main():
    mesh = _mesh((4,), ("stage",))

    # A 12-layer stack whose second half is 4x wider (cost-heterogeneous,
    # like a vlm's cross-attn tail) — naive equal-count splitting is
    # unbalanced here, the Courier partition is not.
    L, d = 12, 32
    widths = [4 * d if i >= 6 else d for i in range(L)]
    key = jax.random.PRNGKey(0)
    Win = jnp.stack([jnp.pad(jax.random.normal(key, (d, w)) * 0.2,
                             ((0, 0), (0, 4 * d - w))) for w in widths])
    Wout = jnp.stack([jnp.pad(jax.random.normal(key, (w, d)) * 0.2,
                              ((0, 4 * d - w), (0, 0))) for w in widths])
    params = {"win": Win, "wout": Wout}

    def block(p, x):
        return x + jnp.tanh(x @ p["win"]) @ p["wout"]

    # Courier: per-layer cost model → balanced boundaries
    cost = [2.0 * d * w * 2 for w in widths]          # matmul flops per layer
    ir = linear_ir("layers", [f"L{i}" for i in range(L)], cost)
    paper_plan = partition_paper(ir, n_threads=3)
    opt_plan = partition_optimal(ir, max_stages=4)
    naive_bottleneck = max(sum(cost[i:i + 3]) for i in range(0, L, 3))
    print("naive equal-count bottleneck :", naive_bottleneck)
    print("paper-policy bottleneck      :", paper_plan.bottleneck_ms)
    print("optimal-DP bottleneck        :", opt_plan.bottleneck_ms)

    boundaries, i = [], 0
    for s in opt_plan.stages:
        boundaries.append(i)
        i += len(s.node_names)
    while len(boundaries) < 4:                        # pad to mesh stages
        boundaries.append(L - 1)
    print("stage boundaries (layer idx) :", boundaries)

    # run the token pipeline and check semantics vs sequential
    M, mb = 6, 4
    xs = jax.random.normal(jax.random.PRNGKey(1), (M, mb, d))
    out = pipeline_microbatches(mesh, block, params, boundaries, xs)

    h = xs
    for i in range(L):
        h = block({"win": Win[i], "wout": Wout[i]}, h)
    np.testing.assert_allclose(np.asarray(out), np.asarray(h),
                               rtol=2e-4, atol=2e-4)
    print("pipeline output == sequential stack: OK")

    # elasticity: a stage group is lost -> re-plan for 3 stages (Courier
    # re-balance), not job abort
    from repro.runtime import ElasticPlanner
    b3 = ElasticPlanner(ir).boundaries(3)
    mesh3 = _mesh((3,), ("stage",))
    out3 = pipeline_microbatches(mesh3, block, params, b3, xs)
    np.testing.assert_allclose(np.asarray(out3), np.asarray(h),
                               rtol=2e-4, atol=2e-4)
    print(f"elastic re-plan to 3 stages {b3}: OK")


if __name__ == "__main__":
    main()
