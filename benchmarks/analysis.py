"""Static-analysis overhead benchmark — the gate must be ~free.

The plan verifier (:mod:`repro.analysis.verify`) runs on every
``generate()``, every re-plan candidate, and every hot-swap.  That is only
acceptable if verification costs a small fraction of building the plan it
checks, so this benchmark times both over the same IR and reports the
ratio.  Smoke mode *asserts* the ratio stays under 5% — the number CI
holds the gate to (see EXPERIMENTS.md, "Static analysis").

Also reports the lint wall-clock over ``src/repro`` (full mode only):
informational, since lint runs once per ``make ci``, not per plan.
"""
from __future__ import annotations

import os
import time

N_NODES = 48
REPS = 20


def _best_ms(fn, reps: int) -> float:
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, (time.perf_counter() - t0) * 1e3)
    return best


def verify_overhead(n_nodes: int = N_NODES, reps: int = REPS) -> dict:
    """min-of-reps plan-build ms vs verify ms over an n-node chain."""
    from repro.analysis import verify_plan
    from repro.core import (DeviceInventory, assign_replicas, linear_ir,
                            partition_optimal)

    ir = linear_ir("bench", [f"f{i}" for i in range(n_nodes)],
                   [1.0 + (i % 5) for i in range(n_nodes)],
                   io_shape=(64, 96))
    inv = DeviceInventory.host(8)

    def build():
        plan = partition_optimal(ir, max_stages=8)
        assign_replicas(plan, ir, worker_budget=8, inventory=inv)
        return plan

    plan = build()
    assert verify_plan(ir, plan, inventory=inv) == []
    build_ms = _best_ms(build, reps)
    verify_ms = _best_ms(lambda: verify_plan(ir, plan, inventory=inv), reps)
    return {"n_nodes": n_nodes, "build_ms": round(build_ms, 4),
            "verify_ms": round(verify_ms, 4),
            "ratio": round(verify_ms / max(build_ms, 1e-9), 4)}


def lint_wall_ms() -> dict:
    from repro.analysis import lint_paths
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src", "repro")
    t0 = time.perf_counter()
    findings = lint_paths([src])
    return {"ms": round((time.perf_counter() - t0) * 1e3, 1),
            "findings": len(findings)}


_payload_cache: dict = {}


def payload(smoke: bool = False) -> dict:
    if smoke not in _payload_cache:
        out = {"verify": verify_overhead(reps=8 if smoke else REPS)}
        if not smoke:
            out["lint"] = lint_wall_ms()
        else:
            # the CI bar: verifying a committed plan must cost under 5% of
            # building it, or the per-replan/per-swap gates are too hot
            assert out["verify"]["ratio"] < 0.05, \
                f"verifier overhead {out['verify']['ratio']:.1%} >= 5%"
        _payload_cache[smoke] = out
    return _payload_cache[smoke]


def run() -> list:
    p = payload()
    v = p["verify"]
    return [
        ("analysis.verify.build_ms", v["build_ms"],
         f"partition_optimal+assign_replicas over {v['n_nodes']} nodes"),
        ("analysis.verify.verify_ms", v["verify_ms"],
         f"all {v['n_nodes']}-node rules, pinned plan + inventory"),
        ("analysis.verify.overhead", v["ratio"],
         "verify_ms / build_ms; CI smoke bar is 0.05"),
        ("analysis.lint.wall_ms", p["lint"]["ms"],
         f"{p['lint']['findings']} findings over src/repro"),
    ]
