"""Paper Table I — processing-time comparison (sequential vs Courier pipeline).

Two parts:
1. *Reproduction*: feed the paper's own measured/estimated per-function
   times (Zynq) to our Pipeline Generator and verify it reproduces the
   4-stage plan and the ≈15x speedup the paper measured.
2. *This system*: trace the actual jnp Harris app on this host, build the
   mixed pipeline (Pallas "hw" modules + jnp "sw" normalize) and measure
   sequential vs token-pipelined wall time over a frame stream.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs.harris import config as HARRIS
from repro.core import (courier_offload, linear_ir, partition_optimal,
                        partition_paper)
from repro.models.harris import corner_harris_demo, make_harris_db
from repro.core.tracer import Library

PAPER_FNS = ["cvtColor", "cornerHarris", "normalize", "convertScaleAbs"]


def paper_replay() -> list[tuple[str, float, str]]:
    rows = []
    offl = [HARRIS.paper_times_offl[f] for f in PAPER_FNS]
    ir = linear_ir("harris-paper", PAPER_FNS, offl)
    plan = partition_paper(ir, n_threads=3)
    pred_period = plan.bottleneck_ms
    pred_speedup = HARRIS.paper_total_orig_ms / pred_period
    rows.append(("table1.paper.n_stages", plan.n_stages,
                 "paper built 4"))
    rows.append(("table1.paper.pipeline_period_ms", pred_period,
                 f"paper measured {HARRIS.paper_total_offl_ms}"))
    rows.append(("table1.paper.predicted_speedup", round(pred_speedup, 2),
                 f"paper measured {HARRIS.paper_speedup}x"))
    opt = partition_optimal(ir)
    rows.append(("table1.optimal_dp.bottleneck_ms", opt.bottleneck_ms,
                 f"{opt.n_stages} stages (beyond-paper)"))
    return rows


def measured_run(n_frames: int = 12, hw: bool = True,
                 size: tuple[int, int] = (270, 480)) -> list[tuple[str, float, str]]:
    """Trace + offload + run the real app; wall-clock seq vs pipelined."""
    db = make_harris_db(with_hw=hw)
    lib = Library(db)
    app = corner_harris_demo(lib)
    H, W = size
    key = jax.random.PRNGKey(0)
    frames = [jax.random.uniform(jax.random.PRNGKey(i), (H, W, 3)) * 255
              for i in range(n_frames)]
    off = courier_offload(app, frames[0], db=db, prefer_hw=False)

    # warmup both paths
    jax.block_until_ready(off.pipeline(frames[0]))
    jax.block_until_ready(app(frames[0]))

    t0 = time.perf_counter()
    for f in frames:
        jax.block_until_ready(app(f))
    t_seq = (time.perf_counter() - t0) * 1e3

    # same compiled stages, no token overlap (isolates the pipelining gain
    # from the stage-compilation gain, like paper Table I's two columns)
    t0 = time.perf_counter()
    for f in frames:
        jax.block_until_ready(off.pipeline(f))
    t_seqjit = (time.perf_counter() - t0) * 1e3

    t0 = time.perf_counter()
    outs = off.map(frames)
    jax.block_until_ready(outs)
    t_pipe = (time.perf_counter() - t0) * 1e3

    return [
        ("table1.this_host.sequential_ms_per_frame", t_seq / n_frames,
         f"{H}x{W}, {n_frames} frames, unmodified eager app"),
        ("table1.this_host.staged_nopipe_ms_per_frame", t_seqjit / n_frames,
         "compiled stages, no token overlap"),
        ("table1.this_host.pipelined_ms_per_frame", t_pipe / n_frames,
         f"{off.pipeline.plan.n_stages} stages"),
        ("table1.this_host.speedup_total", round(t_seq / max(t_pipe, 1e-9), 3),
         "vs unmodified app (paper's headline comparison)"),
        ("table1.this_host.speedup_pipelining", round(t_seqjit / max(t_pipe, 1e-9), 3),
         "token overlap only; 1-core container limits true parallelism"),
    ]


def run() -> list[tuple[str, float, str]]:
    return paper_replay() + measured_run()


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
