"""Paper Table I — processing-time comparison (sequential vs Courier pipeline).

Three parts:
1. *Reproduction*: feed the paper's own measured/estimated per-function
   times (Zynq) to our Pipeline Generator and verify it reproduces the
   4-stage plan and the ≈15x speedup the paper measured.
2. *This system*: trace the actual jnp Harris app on this host, build the
   mixed pipeline (Pallas "hw" modules + jnp "sw" normalize) and measure
   sequential vs synchronous-wavefront vs async-executor wall time over a
   multi-frame token stream (with and without per-stage micro-batching).
3. *Serving*: run the same pipeline behind the dynamic-batching
   request-queue server and report per-request latency percentiles.
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp

from repro.configs.harris import config as HARRIS
from repro.core import (courier_offload, linear_ir, partition_optimal,
                        partition_paper)
from repro.models.harris import corner_harris_demo, make_harris_db
from repro.core.tracer import Library

PAPER_FNS = ["cvtColor", "cornerHarris", "normalize", "convertScaleAbs"]


def paper_replay() -> list[tuple[str, float, str]]:
    rows = []
    offl = [HARRIS.paper_times_offl[f] for f in PAPER_FNS]
    ir = linear_ir("harris-paper", PAPER_FNS, offl)
    plan = partition_paper(ir, n_threads=3)
    pred_period = plan.bottleneck_ms
    pred_speedup = HARRIS.paper_total_orig_ms / pred_period
    rows.append(("table1.paper.n_stages", plan.n_stages,
                 "paper built 4"))
    rows.append(("table1.paper.pipeline_period_ms", pred_period,
                 f"paper measured {HARRIS.paper_total_offl_ms}"))
    rows.append(("table1.paper.predicted_speedup", round(pred_speedup, 2),
                 f"paper measured {HARRIS.paper_speedup}x"))
    opt = partition_optimal(ir)
    rows.append(("table1.optimal_dp.bottleneck_ms", opt.bottleneck_ms,
                 f"{opt.n_stages} stages (beyond-paper)"))
    return rows


def measured_run(n_frames: int = 12, hw: bool = True,
                 size: tuple[int, int] = (270, 480)) -> list[tuple[str, float, str]]:
    """Trace + offload + run the real app; wall-clock seq vs pipelined."""
    m = measured_numbers(n_frames=n_frames, hw=hw, size=size)
    H, W = size
    return [
        ("table1.this_host.sequential_ms_per_frame", m["sequential_ms"],
         f"{H}x{W}, {n_frames} frames, unmodified eager app"),
        ("table1.this_host.staged_nopipe_ms_per_frame", m["staged_ms"],
         "compiled stages, no token overlap"),
        ("table1.this_host.pipelined_ms_per_frame", m["wavefront_ms"],
         f"{m['n_stages']} stages, synchronous wavefront run()"),
        ("table1.this_host.async_ms_per_frame", m["async_ms"],
         f"PipelineExecutor, mean occupancy {m['occupancy']:.1f} tokens"),
        ("table1.this_host.async_microbatch_ms_per_frame", m["microbatch_ms"],
         f"PipelineExecutor, microbatch={m['microbatch']}"),
        ("table1.this_host.async_throughput_fps", m["async_tps"],
         "async executor frames/s"),
        ("table1.this_host.speedup_total",
         round(m["sequential_ms"] / max(m["wavefront_ms"], 1e-9), 3),
         "vs unmodified app (paper's headline comparison)"),
        ("table1.this_host.speedup_pipelining",
         round(m["staged_ms"] / max(m["wavefront_ms"], 1e-9), 3),
         "token overlap only; 1-core container limits true parallelism"),
        ("table1.this_host.speedup_async_vs_wavefront",
         round(m["wavefront_ms"] / max(m["async_ms"], 1e-9), 3),
         "async executor vs synchronous wavefront run()"),
        ("table1.this_host.speedup_async_vs_sequential",
         round(m["sequential_ms"] / max(m["async_ms"], 1e-9), 3),
         "async executor vs unmodified sequential app"),
    ]


_numbers_cache: dict = {}


def measured_numbers(n_frames: int = 12, hw: bool = True,
                     size: tuple[int, int] = (270, 480)) -> dict:
    """Machine-readable core of the Table-1 measurement (per-frame ms and
    tokens/s for every execution mode); consumed by ``bench_payload``.
    Memoized per (n_frames, hw, size) so the CSV rows and the JSON artifact
    share one measurement instead of running the benchmark twice."""
    cache_key = (n_frames, hw, tuple(size))
    if cache_key in _numbers_cache:
        return _numbers_cache[cache_key]
    db = make_harris_db(with_hw=hw)
    lib = Library(db)
    app = corner_harris_demo(lib)
    H, W = size
    key = jax.random.PRNGKey(0)
    frames = [jax.random.uniform(jax.random.PRNGKey(i), (H, W, 3)) * 255
              for i in range(n_frames)]
    off = courier_offload(app, frames[0], db=db, prefer_hw=False)

    # warmup both paths
    jax.block_until_ready(off.pipeline(frames[0]))
    jax.block_until_ready(app(frames[0]))

    def best_ms(f, reps: int = 3) -> float:
        """min-of-reps wall time (single-shot timings are noisy on a
        shared 1-2 core container)."""
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            jax.block_until_ready(f())
            best = min(best, (time.perf_counter() - t0) * 1e3)
        return best

    def run_eager():
        return [app(f) for f in frames]

    def run_staged():
        # same compiled stages, no token overlap (isolates the pipelining
        # gain from the stage-compilation gain, like Table I's two columns)
        return [off.pipeline(f) for f in frames]

    t_seq = best_ms(run_eager)
    t_seqjit = best_ms(run_staged)

    # async executor (eager issue, bounded pool).  The pool is sized like
    # the wavefront's (~2x stages), NOT to the whole frame stream: on a
    # small host the live working set (pool x frame + intermediates) is
    # what dominates per-frame wall time, and an n_frames pool measurably
    # loses to the wavefront on big frames purely through allocator/cache
    # pressure.  Interleave the wavefront/async reps so both sample the
    # same background noise (shared-container swings dominate single runs).
    S = off.pipeline.plan.n_stages
    ex = off.pipeline.executor(max_in_flight=2 * S + 1)
    jax.block_until_ready(ex.run(frames[:2]))
    ex.reset_stats()
    t_pipe = t_async = float("inf")
    for _ in range(5):
        t_pipe = min(t_pipe, best_ms(lambda: off.map(frames), reps=1))
        t_async = min(t_async, best_ms(lambda: ex.run(frames), reps=1))
    occ = ex.stats().mean_occupancy

    # async executor + per-stage micro-batching (stacked token groups)
    mb = 4
    exb = off.pipeline.executor(max_in_flight=max(2 * S + 1, 2 * mb),
                                microbatch=mb)
    jax.block_until_ready(exb.run(frames[:mb]))
    t_batched = best_ms(lambda: exb.run(frames))

    _numbers_cache[cache_key] = {
        "shape": [H, W], "n_frames": n_frames,
        "sequential_ms": t_seq / n_frames,
        "staged_ms": t_seqjit / n_frames,
        "wavefront_ms": t_pipe / n_frames,
        "async_ms": t_async / n_frames,
        "microbatch_ms": t_batched / n_frames,
        "microbatch": mb,
        "occupancy": occ,
        "n_stages": off.pipeline.plan.n_stages,
        "bottleneck_ms": off.pipeline.plan.bottleneck_ms,
        "sequential_tps": round(n_frames / max(t_seq / 1e3, 1e-9), 2),
        "wavefront_tps": round(n_frames / max(t_pipe / 1e3, 1e-9), 2),
        "async_tps": round(n_frames / max(t_async / 1e3, 1e-9), 2),
        "compile_count": off.pipeline.compile_count(),
    }
    return _numbers_cache[cache_key]


# --------------------------------------------------------------------------- #
# Machine-readable benchmark artifact (BENCH_pipeline.json)
# --------------------------------------------------------------------------- #
def bench_payload(smoke: bool = False) -> dict:
    """sequential / wavefront / async / fused tokens-per-sec + bottleneck ms,
    plus the fusion, adaptive-replan, and stage-replication benchmarks —
    the perf trajectory tracked across PRs."""
    from benchmarks import (decode, devices, faults, fusion, overload,
                            replan, replicate, trace_pipeline)

    n_frames = 2 if smoke else 12
    size = (64, 96) if smoke else (270, 480)
    # fusion comparison first: it is the finest-grained measurement and the
    # most sensitive to allocator/background state left by the big-frame
    # run; the replan/replicate benchmarks LAST — their thread pools and
    # serving loops are the noisiest neighbors of all
    fus = fusion.payload(smoke=smoke)
    m = measured_numbers(n_frames=n_frames, hw=True, size=size)
    trc = trace_pipeline.payload(smoke=smoke)
    rep = replan.payload(smoke=smoke)
    wide = replicate.payload(smoke=smoke)
    dev = devices.payload(smoke=smoke)
    flt = faults.payload(smoke=smoke)    # fault churn + serving loops
    ovl = overload.payload(smoke=smoke)  # open-loop load saturation
    dec = decode.payload(smoke=smoke)    # last: open-loop decode sessions
    return {
        "bench": "table1_pipeline", "smoke": bool(smoke),
        "shape": m["shape"], "n_frames": m["n_frames"],
        "tokens_per_sec": {
            "sequential": m["sequential_tps"],
            "wavefront": m["wavefront_tps"],
            "async": m["async_tps"],
            "fused": fus["pipeline"]["fused"]["tokens_per_sec"],
        },
        "bottleneck_ms": {
            "pipeline": round(m["bottleneck_ms"], 6),
            "fused_pipeline": fus["pipeline"]["fused"]["bottleneck_ms"],
            "unfused_pipeline": fus["pipeline"]["unfused"]["bottleneck_ms"],
        },
        "per_frame_ms": {k: round(m[k], 4) for k in
                         ("sequential_ms", "staged_ms", "wavefront_ms",
                          "async_ms", "microbatch_ms")},
        "compile_count_steady": m["compile_count"],
        "fusion": fus,
        "trace": trc,
        "replan": rep,
        "replicate": wide,
        "devices": dev,
        "faults": flt,
        "overload": ovl,
        "decode": dec,
    }


def write_bench_json(path: str | None = None, smoke: bool = False) -> str:
    path = path or os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "BENCH_pipeline.json")
    with open(path, "w") as f:
        json.dump(bench_payload(smoke=smoke), f, indent=1, sort_keys=True)
        f.write("\n")
    return path


def serving_run(n_requests: int = 24, max_batch: int = 4) -> list[tuple[str, float, str]]:
    """Dynamic-batching serving loop over the Harris pipeline (latency)."""
    from repro.launch.serve import serve_pipeline_demo

    stats = serve_pipeline_demo(n_requests=n_requests, max_batch=max_batch,
                                max_wait_ms=4.0, size=(64, 96))
    lat = stats["latency_ms"]
    return [
        ("table1.serving.requests", stats["requests_served"],
         f"{stats['batches']} dynamic batches, "
         f"mean size {stats['mean_batch_size']:.1f}"),
        ("table1.serving.latency_p50_ms", round(lat["p50"], 2),
         "per-request (queue + execute)"),
        ("table1.serving.latency_p95_ms", round(lat["p95"], 2),
         "per-request (queue + execute)"),
        ("table1.serving.throughput_rps", round(stats["throughput_rps"], 2),
         "requests/s, first submit → last completion"),
    ]


def run() -> list[tuple[str, float, str]]:
    return paper_replay() + measured_run() + serving_run()


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
    print("wrote", write_bench_json())
