"""Paper Fig. 4 — the traced function call graph including input/output data.

Runs the Frontend on the unmodified Harris app and prints the chronological
call graph with I/O shapes ("height x width x bit-depth"), per-function
times and placements — the same artifact the paper renders as Fig. 4.
"""
from __future__ import annotations

import jax

from repro.core import Frontend, PipelineGenerator
from repro.core.tracer import Library
from repro.models.harris import corner_harris_demo, make_harris_db


def run(height: int = 270, width: int = 480) -> list[tuple[str, float, str]]:
    db = make_harris_db(with_hw=True)
    lib = Library(db)
    app = corner_harris_demo(lib)
    img = jax.random.uniform(jax.random.PRNGKey(0), (height, width, 3)) * 255
    ir, _ = Frontend(db).trace(app, img)
    print(ir.render())
    pipe = PipelineGenerator(db).generate(ir, n_threads=3, prefer_hw=True)
    print(pipe.describe())
    rows = [("fig4.n_nodes", len(ir.nodes), "traced function calls"),
            ("fig4.total_ms", round(ir.total_time_ms(), 2),
             f"{height}x{width} frame on this host"),
            ("fig4.n_stages", pipe.plan.n_stages, "generated pipeline")]
    for n in pipe.ir.nodes:
        rows.append((f"fig4.node.{n.name}", round(n.time_ms or 0, 3),
                     f"{n.placement}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
