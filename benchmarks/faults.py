"""Fault-tolerance benchmark — device loss and transient bursts, zero drops.

Courier-FPGA accelerates a *running* binary, so the built pipeline must
survive its runtime: a hardware module dropping out mid-stream has to
degrade the pipeline, not kill it.  Three scenarios exercise the whole
fault path (injector -> executor retry/quarantine -> inventory diff ->
survivors re-plan -> zero-drop hot-swap):

1. **device_loss** — a sleep-backed chain widened onto a 4-device
   inventory serves through :class:`RequestQueueServer`; mid-run a
   scripted :class:`DeviceLostError` pulls one of the wide stage's
   devices.  The executor quarantines the replica pinned there (sibling
   replicas absorb its sequence numbers), ``DeviceInventory.refresh``
   diffs the surviving devices, ``replan_on_inventory_change`` re-widens
   onto them, and ``swap_executor`` deploys.  Acceptance: **0 dropped
   requests, 0 out-of-order retirements, post-recovery throughput >=
   0.8x the survivors-only optimum** (a fresh plan built directly on the
   surviving devices).
2. **transient** — a scripted burst of transient stage faults on the
   widened stage (spaced so retried calls land on unscripted invocation
   counts); bounded per-group retries absorb the burst with no
   quarantine.  Acceptance: 0 dropped, 0 out-of-order, throughput >=
   0.8x the fault-free run of the same chain.
3. **harris_transient** — the real jitted Harris pipeline, replicated,
   with transient faults mid-stream: results must be IDENTICAL to a
   fault-free run (retries re-execute the stage body, injection fires
   before it, so no half-donated buffers).  Correctness only — wall
   clock on the jitted path is CI noise.

Feeds the ``faults`` section of ``BENCH_pipeline.json``.
"""
from __future__ import annotations

import sys
import time

import numpy as np

from benchmarks.simchain import make_planner, tps as _tps

LOSS_STAGE_MS = [2.0, 8.0]            # dominant 2nd stage gets the widening
BURST_STAGE_MS = [1.0, 4.0, 1.0]
RECOVERY_FLOOR = 0.8                  # acceptance: tps_after/tps_survivor


def _serve_phase(srv, toks) -> tuple[float, int, int]:
    """Push one request wave through the server; (wall_s, served, dropped)."""
    t0 = time.perf_counter()
    reqs = [srv.submit(t) for t in toks]
    served = dropped = 0
    for r in reqs:
        try:
            r.wait(timeout=120.0)
            served += 1
        except Exception:
            dropped += 1
    return time.perf_counter() - t0, served, dropped


def device_loss(n_per_phase: int = 24, smoke: bool = False) -> dict:
    """Mid-run device loss: quarantine -> refresh -> re-plan -> hot-swap."""
    from repro.core import DeviceInventory, StageProfiler
    from repro.launch.serve import RequestQueueServer
    from repro.runtime.faults import FaultInjector

    if smoke:
        n_per_phase = 12
    n_stages = len(LOSS_STAGE_MS)
    inv = DeviceInventory.host(4)
    inj = FaultInjector()             # scripted live, mid-run
    planner = make_planner("faults-loss", LOSS_STAGE_MS, inventory=inv,
                           fault_injector=inj, quarantine_after=1)
    prof = StageProfiler(n_stages, min_samples=2)
    ex, _ = planner.executor_for(n_stages, jit=False, profiler=prof)
    replicas_before = list(ex.replicas)
    wide_si = max(range(n_stages), key=lambda s: ex.replicas[s])
    target = ex.devices[wide_si][0]
    toks = [np.full((8,), float(i)) for i in range(n_per_phase)]

    served = dropped = 0
    with RequestQueueServer(ex, max_batch=4, max_wait_ms=1.0) as srv:
        # phase 1: healthy serving (also fills the profile)
        dt, s, d = _serve_phase(srv, toks)
        tps_before = n_per_phase / max(dt, 1e-9)
        served += s
        dropped += d
        # phase 2: pull a device serving the wide stage; the replica
        # pinned there is quarantined, siblings absorb its seqs
        inj.lose_device(target)
        _dt, s, d = _serve_phase(srv, toks)
        served += s
        dropped += d
        stats = ex.stats()
        # phase 3: elastic recovery — diff the surviving inventory,
        # re-widen onto it, hot-swap with zero drops
        diff = inv.refresh(probe=lambda: inj.surviving(inv))
        decision = planner.replan_on_inventory_change(
            diff, profiler=prof, stats=stats, jit=False)
        old = srv.swap_executor(decision.executor,
                                warm_args=(toks[0],))
        dt, s, d = _serve_phase(srv, toks)
        tps_after = n_per_phase / max(dt, 1e-9)
        served += s
        dropped += d
    ooo = (old.stats().out_of_order_retired
           + decision.executor.stats().out_of_order_retired)
    old.close()
    decision.executor.close()

    # survivors-only optimum: a fresh plan built directly on the
    # remaining devices — the bar the recovered pipeline must clear
    sur_planner = make_planner("faults-loss-sur", LOSS_STAGE_MS,
                               inventory=inv.drop([target]))
    sur_ex, _ = sur_planner.executor_for(n_stages, jit=False)
    with RequestQueueServer(sur_ex, max_batch=4, max_wait_ms=1.0) as ssrv:
        dt, _s, _d = _serve_phase(ssrv, toks)
    tps_survivor = n_per_phase / max(dt, 1e-9)
    sur_ex.close()

    recovery = tps_after / max(tps_survivor, 1e-9)
    out = {
        "stage_ms": list(LOSS_STAGE_MS), "requests": 3 * n_per_phase,
        "served": served, "dropped": dropped, "out_of_order": int(ooo),
        "retries": int(stats.retries), "quarantined": int(stats.quarantined),
        "lost_device": int(target),
        "replicas_before": replicas_before,
        "replicas_after": list(decision.replicas or []),
        "tps_before": round(tps_before, 2),
        "tps_after": round(tps_after, 2),
        "tps_survivor": round(tps_survivor, 2),
        "recovery": round(recovery, 3),
        "swaps": srv.swaps, "replanned": bool(decision.replanned),
    }
    assert out["dropped"] == 0, f"device loss dropped {out['dropped']} requests"
    assert out["out_of_order"] == 0, "out-of-order retirement under loss"
    assert out["quarantined"] >= 1, "device loss never quarantined a replica"
    assert out["replanned"], "inventory change did not trigger a re-plan"
    assert recovery >= RECOVERY_FLOOR, \
        f"post-recovery throughput {recovery:.2f}x survivors-only optimum " \
        f"(floor {RECOVERY_FLOOR}x)"
    return out


def transient(n_tokens: int = 32, smoke: bool = False) -> dict:
    """Transient-error burst on the widened stage: retries, no quarantine."""
    from repro.runtime.faults import FaultPlan

    if smoke:
        n_tokens = 16
    n_stages = len(BURST_STAGE_MS)
    toks = [np.full((8,), float(i)) for i in range(n_tokens)]

    clean_planner = make_planner("faults-clean", BURST_STAGE_MS)
    clean_ex, _ = clean_planner.executor_for(n_stages, worker_budget=6,
                                             jit=False)
    wide_si = max(range(n_stages), key=lambda s: clean_ex.replicas[s])
    tps_clean = _tps(clean_ex, toks)
    expect = clean_ex.run(toks)
    clean_ex.close()

    # burst on the wide stage, SPACED every 3rd call: a retried call is a
    # new invocation count, so each faulted group recovers on its first
    # retry instead of walking the rest of the scripted burst
    burst = list(range(4, min(n_tokens, 20), 3))
    plan = FaultPlan().transient(wide_si, at_calls=burst)
    planner = make_planner("faults-burst", BURST_STAGE_MS,
                           fault_injector=plan.build(),
                           quarantine_after=len(burst) + 1)
    ex, _ = planner.executor_for(n_stages, worker_budget=6, jit=False)
    t0 = time.perf_counter()
    handles = ex.submit_many([(t,) for t in toks])
    served = dropped = 0
    results = []
    for h in handles:
        try:
            results.append(h.result())
            served += 1
        except Exception:
            results.append(None)
            dropped += 1
    tps_faulty = n_tokens / max(time.perf_counter() - t0, 1e-9)
    stats = ex.stats()
    ex.close()

    match = served == n_tokens and all(
        np.allclose(r, e) for r, e in zip(results, expect))
    recovery = tps_faulty / max(tps_clean, 1e-9)
    out = {
        "stage_ms": list(BURST_STAGE_MS), "requests": n_tokens,
        "served": served, "dropped": dropped,
        "out_of_order": int(stats.out_of_order_retired),
        "retries": int(stats.retries), "quarantined": int(stats.quarantined),
        "errors_injected": len(burst),
        "tps_clean": round(tps_clean, 2),
        "tps_faulty": round(tps_faulty, 2),
        "recovery": round(recovery, 3),
        "results_match": bool(match),
    }
    assert out["dropped"] == 0, f"burst dropped {out['dropped']} requests"
    assert out["out_of_order"] == 0, "out-of-order retirement under burst"
    assert out["retries"] >= len(burst), "burst faults were not retried"
    assert out["results_match"], "retried results diverge from fault-free run"
    assert recovery >= RECOVERY_FLOOR, \
        f"throughput under burst {recovery:.2f}x fault-free " \
        f"(floor {RECOVERY_FLOOR}x)"
    return out


def harris_transient(n_requests: int = 16, size: tuple[int, int] = (64, 96),
                     smoke: bool = False) -> dict:
    """Transient faults on the replicated jitted Harris pipeline:
    results must be bit-identical to the fault-free run."""
    import jax

    from repro.core import assign_replicas, courier_offload
    from repro.core.tracer import Library
    from repro.models.harris import corner_harris_demo, make_harris_db
    from repro.runtime.faults import FaultPlan

    if smoke:
        n_requests = 8
    db = make_harris_db(with_hw=False)
    lib = Library(db)
    app = corner_harris_demo(lib)
    H, W = size
    frames = [jax.random.uniform(jax.random.PRNGKey(i), (H, W, 3)) * 255
              for i in range(n_requests)]
    off = courier_offload(app, frames[0], db=db, prefer_hw=False)
    pipe = off.pipeline
    plan = assign_replicas(pipe.plan, pipe.ir, worker_budget=8)
    wide_si = max(range(plan.n_stages), key=lambda s: plan.replicas[s])

    ex_clean = pipe.executor(replicas=plan.replicas)
    ex_clean.warmup(frames[0])
    expect = ex_clean.run(frames)
    ex_clean.close()

    burst = [2, 5] if n_requests >= 8 else [2]
    inj = FaultPlan().build()            # empty: warmup must run fault-free
    ex = pipe.executor(replicas=plan.replicas, fault_injector=inj,
                       quarantine_after=len(burst) + 1)
    ex.warmup(frames[0])
    # injector counters include the warmup calls; script relative to them
    # so the faults land mid-stream
    base = inj.stage_calls(wide_si)
    inj.plan.transient(wide_si, at_calls=[base + c for c in burst])
    handles = ex.submit_many([(f,) for f in frames])
    served = dropped = 0
    results = []
    for h in handles:
        try:
            results.append(h.result())
            served += 1
        except Exception:
            results.append(None)
            dropped += 1
    stats = ex.stats()
    ex.close()

    match = served == n_requests and all(
        np.allclose(np.asarray(r), np.asarray(e))
        for r, e in zip(results, expect))
    out = {
        "requests": n_requests, "served": served, "dropped": dropped,
        "out_of_order": int(stats.out_of_order_retired),
        "retries": int(stats.retries),
        "errors_injected": int(inj.injected),
        "replicas": list(plan.replicas),
        "results_match": bool(match),
        "shape": [H, W],
    }
    assert out["dropped"] == 0, \
        f"harris burst dropped {out['dropped']} requests"
    assert out["results_match"], \
        "harris results diverge from the fault-free run"
    return out


_payload_cache: dict = {}


def payload(smoke: bool = False) -> dict:
    key = bool(smoke)
    if key not in _payload_cache:
        _payload_cache[key] = {
            "device_loss": device_loss(smoke=smoke),
            "transient": transient(smoke=smoke),
            "harris_transient": harris_transient(smoke=smoke),
        }
    return _payload_cache[key]


def run(smoke: bool = False) -> list:
    p = payload(smoke=smoke)
    dl, tr, ht = p["device_loss"], p["transient"], p["harris_transient"]
    return [
        ("faults.device_loss.dropped", dl["dropped"],
         f"{dl['served']}/{dl['requests']} served across loss of device "
         f"{dl['lost_device']}; {dl['quarantined']} quarantined"),
        ("faults.device_loss.recovery", dl["recovery"],
         f"post-recovery {dl['tps_after']} tps vs survivors-only "
         f"{dl['tps_survivor']} tps (floor {RECOVERY_FLOOR})"),
        ("faults.device_loss.replicas", str(dl["replicas_after"]).replace(
            ",", ";"),
         f"re-widened from {dl['replicas_before']} after the loss"),
        ("faults.transient.dropped", tr["dropped"],
         f"{tr['served']}/{tr['requests']} served under "
         f"{tr['errors_injected']} injected faults; {tr['retries']} retries"),
        ("faults.transient.recovery", tr["recovery"],
         f"{tr['tps_faulty']} tps under burst vs {tr['tps_clean']} tps clean"),
        ("faults.harris.results_match", int(ht["results_match"]),
         f"{ht['served']}/{ht['requests']} served; {ht['retries']} retries "
         f"on the replicated jitted pipeline"),
    ]


if __name__ == "__main__":
    for r in run(smoke="--smoke" in sys.argv[1:]):
        print(",".join(str(x) for x in r))
