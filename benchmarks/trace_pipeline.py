"""Trace-to-pipeline benchmark — a transformer served from its own trace.

The generality claim of this repo: any workload written against
``Library`` calls — weights closed over, no model-code edits — traces
into a causal graph that lowers through partition → fusion → replication
→ verify and serves behind the request queue.  This benchmark measures
that path end-to-end on the model-zoo transformer and asserts the two
acceptance bars in smoke mode:

* the async traced pipeline sustains >= 1.5x the sequential (eager,
  untraced) tokens/s, and
* the pipeline's results match the untraced model bit-exactly
  (``jax.jit`` of the very same user function — XLA's cross-op fusion
  makes *eager* float32 the wrong bit-parity anchor, see EXPERIMENTS.md).

Also traces the recurrent (RWKV-shift + SSM-scan) zoo block to show the
trace path is not transformer-shaped, and runs the dynamic-batching
serving loop over the traced pipeline.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

REPS = 5


def _best_s(fn, reps: int) -> float:
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def transformer_numbers(smoke: bool = False) -> dict:
    """Trace the zoo transformer, lower it, and race the async pipeline
    against the unmodified eager app over a token stream."""
    from repro.core import PipelineGenerator
    from repro.core.tracer import Frontend, Library
    from repro.models.zoo import (init_transformer_params, make_zoo_db,
                                  transformer_demo)

    seq_len, d, ff, vocab = (16, 32, 64, 64) if smoke else (64, 128, 256, 512)
    n_tokens = 8 if smoke else 24
    reps = 3 if smoke else REPS

    db = make_zoo_db()
    app = transformer_demo(Library(db), init_transformer_params(
        jax.random.PRNGKey(0), n_layers=2, d=d, ff=ff, n_heads=2 if smoke
        else 4, vocab=vocab))
    toks = [jax.random.normal(jax.random.PRNGKey(10 + i), (seq_len, d),
                              jnp.float32) for i in range(n_tokens)]

    ir, _ = Frontend(db).trace(app, toks[0])
    pipe = PipelineGenerator(db).generate(ir, policy="optimal", fuse=True,
                                          max_stages=4)
    S = pipe.plan.n_stages
    ex = pipe.executor(max_in_flight=2 * S + 1)
    ex.warmup(toks[0])
    jax.block_until_ready(app(toks[0]))

    # interleave the reps so both paths sample the same background noise
    t_seq = t_async = float("inf")
    for _ in range(reps):
        t_seq = min(t_seq, _best_s(lambda: [app(t) for t in toks], 1))
        t_async = min(t_async, _best_s(lambda: ex.run(toks), 1))

    ref = jax.jit(app)
    match = all(bool(jnp.array_equal(y, ref(t)))
                for y, t in zip(ex.run(toks), toks))
    return {
        "seq_len": seq_len, "d_model": d, "n_tokens": n_tokens,
        "n_nodes": len(pipe.ir.nodes), "n_stages": S,
        "fused_nodes": [n.name for n in pipe.ir.nodes if n.fused_from],
        "captured_inputs": len(pipe.captured),
        "token_inputs": len(pipe.graph_inputs),
        "tps_sequential": round(n_tokens / max(t_seq, 1e-9), 2),
        "tps_async": round(n_tokens / max(t_async, 1e-9), 2),
        "speedup": round(t_seq / max(t_async, 1e-9), 3),
        "results_match": match,
    }


def recurrent_numbers(smoke: bool = False) -> dict:
    """The same trace path over the RWKV/SSM block — different op mix,
    same bit-parity bar vs ``jax.jit`` of the untraced function."""
    from repro.core import PipelineGenerator
    from repro.core.tracer import Frontend, Library
    from repro.models.zoo import (init_recurrent_params, make_zoo_db,
                                  recurrent_demo)

    seq_len, d = (16, 32) if smoke else (64, 64)
    db = make_zoo_db()
    app = recurrent_demo(Library(db),
                         init_recurrent_params(jax.random.PRNGKey(1), d=d))
    x = jax.random.normal(jax.random.PRNGKey(2), (seq_len, d), jnp.float32)
    ir, _ = Frontend(db).trace(app, x)
    pipe = PipelineGenerator(db).generate(ir, policy="optimal", fuse=True,
                                          max_stages=2)
    match = bool(jnp.array_equal(pipe(x), jax.jit(app)(x)))
    return {"n_nodes": len(pipe.ir.nodes), "n_stages": pipe.plan.n_stages,
            "captured_inputs": len(pipe.captured), "results_match": match}


def serving_numbers(smoke: bool = False) -> dict:
    """Dynamic-batching request queue over the traced transformer."""
    from repro.launch.serve import serve_traced_transformer_demo

    kw = (dict(n_requests=8, seq_len=16, d=32, ff=64, n_heads=2, vocab=64)
          if smoke else dict(n_requests=24, seq_len=32, d=64, ff=128,
                             n_heads=4, vocab=128))
    s = serve_traced_transformer_demo(max_batch=4, max_wait_ms=4.0, **kw)
    return {
        "requests": int(s["requests_served"]),
        "mean_batch_size": round(float(s["mean_batch_size"]), 2),
        "latency_p95_ms": round(float(s["latency_ms"]["p95"]), 2),
        "results_match": bool(s["results_match"]),
        "fused_nodes": list(s["fused_nodes"]),
        "captured_inputs": int(s["captured_inputs"]),
        "replicas": s["replicas"],
    }


_payload_cache: dict = {}


def payload(smoke: bool = False) -> dict:
    if smoke not in _payload_cache:
        out = {"transformer": transformer_numbers(smoke=smoke),
               "recurrent": recurrent_numbers(smoke=smoke),
               "serving": serving_numbers(smoke=smoke)}
        if smoke:
            # the CI bars (ISSUE 8 acceptance): async traced pipeline beats
            # the unmodified eager app >= 1.5x, results bit-match the
            # untraced model, and the registered mega-kernel actually fired
            # on the traced graph
            t = out["transformer"]
            assert t["speedup"] >= 1.5, \
                f"traced pipeline speedup {t['speedup']} < 1.5x"
            assert t["results_match"], "traced pipeline != jit(untraced app)"
            assert t["fused_nodes"], "mega-kernel did not fire on the trace"
            assert t["captured_inputs"] > 0 and t["token_inputs"] == 1
            assert out["recurrent"]["results_match"]
            assert out["serving"]["results_match"]
        _payload_cache[smoke] = out
    return _payload_cache[smoke]


def run() -> list:
    p = payload()
    t, r, s = p["transformer"], p["recurrent"], p["serving"]
    fused = ";".join(t["fused_nodes"]) or "none"
    return [
        ("trace.transformer.n_nodes", t["n_nodes"],
         f"{t['n_stages']} stages; fused {fused}"),
        ("trace.transformer.captured_inputs", t["captured_inputs"],
         f"closure weights promoted to graph inputs; "
         f"{t['token_inputs']} per-token input"),
        ("trace.transformer.tps_sequential", t["tps_sequential"],
         f"eager untraced app, {t['n_tokens']} x [{t['seq_len']},"
         f"{t['d_model']}] tokens"),
        ("trace.transformer.tps_async", t["tps_async"],
         "async executor over the traced+fused pipeline"),
        ("trace.transformer.speedup", t["speedup"],
         "async traced pipeline vs eager untraced; CI bar is 1.5"),
        ("trace.transformer.results_match", int(t["results_match"]),
         "bit-exact vs jax.jit of the untraced model"),
        ("trace.recurrent.results_match", int(r["results_match"]),
         f"RWKV-shift+SSM-scan block, {r['n_nodes']} nodes"),
        ("trace.serving.requests", s["requests"],
         f"mean batch {s['mean_batch_size']}; replicas {s['replicas']}"),
        ("trace.serving.latency_p95_ms", s["latency_p95_ms"],
         "per-request (queue + execute)"),
        ("trace.serving.results_match", int(s["results_match"]),
         "served results vs jit(untraced app)"),
    ]


if __name__ == "__main__":
    import sys
    smoke = "--smoke" in sys.argv[1:]
    if smoke:
        p = payload(smoke=True)
        t = p["transformer"]
        print(f"smoke.trace.speedup,{t['speedup']},"
              f"async {t['tps_async']} tps vs sequential "
              f"{t['tps_sequential']} tps")
        print(f"smoke.trace.results_match,{int(t['results_match'])},"
              f"recurrent {int(p['recurrent']['results_match'])}; "
              f"serving {int(p['serving']['results_match'])}")
    else:
        for row in run():
            print(",".join(str(x) for x in row))
