"""Paper Table III — resource utilization of generated modules.

Zynq resources (BRAM/DSP/FF/LUT) map to the TPU kernel budget: VMEM bytes
per program block, fraction of the ~128 MiB VMEM, grid size, and MXU-tile
alignment of the contracting dims.  Derived from each kernel's BlockSpecs.
"""
from __future__ import annotations

from repro.configs.harris import config as HARRIS
from repro.core.costmodel import LANE, MXU_TILE, SUBLANE, VMEM_BYTES
from repro.kernels.harris import ROW_BLOCK


def _row(name: str, vmem_bytes: int, grid: int, note: str):
    return (f"table3.{name}.vmem_block_bytes", vmem_bytes,
            f"{100 * vmem_bytes / VMEM_BYTES:.2f}% of VMEM; grid={grid}; {note}")


def run() -> list[tuple[str, float, str]]:
    H, W = HARRIS.height, HARRIS.width
    rb = ROW_BLOCK
    rows = []
    # cvtColor: in block [rb, W, 3] u8→f32 + out [rb, W] f32
    rows.append(_row("cvtColor", rb * W * 3 * 4 + rb * W * 4, H // rb,
                     f"VPU elementwise, {W}-lane rows"))
    # cornerHarris: halo rows + 3 sobel products + 3 sums + out (f32)
    halo = 2
    work = (rb + 2 * halo) * (W + 2 * halo) * 4 * 3 + rb * W * 4 * 4
    rows.append(_row("cornerHarris", work, H // rb,
                     "stencil halo-blocks (line-buffer analog)"))
    rows.append(("table3.cornerHarris.paper_luts", 17494,
                 "paper: 32% LUT, 23% BRAM for hls::cornerHarris"))
    # convertScaleAbs
    rows.append(_row("convertScaleAbs", rb * W * 4 * 2, H // rb,
                     "VPU elementwise"))
    # flash attention: q block + k/v stream + f32 acc + score block
    bq, bk, hd, M = 512, 512, 128, 32768
    fa = bq * hd * 2 + 2 * M * hd * 2 + bq * hd * 4 + bq * bk * 4
    rows.append(_row("flash_attention", fa, f"BHxT/{bq}",
                     f"MXU {MXU_TILE[0]}x{MXU_TILE[1]}-aligned (hd={hd}, "
                     f"bq%{SUBLANE}==0, bk%{LANE}==0)"))
    # rmsnorm
    rows.append(_row("rmsnorm", 256 * 4096 * 4 * 2, "N/256",
                     "row-tiled, f32 accumulation"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
