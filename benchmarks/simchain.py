"""Sleep-backed simulated pipeline chains (shared benchmark scaffolding).

Both the adaptive-replan and the stage-replication benchmarks drive the
planner with the same device-free fixture: a linear chain of library
functions whose per-call processing time is a host ``time.sleep`` read
from a mutable knob at CALL time, so drift can be injected (or a stage
can simply dominate) without any retrace/recompile.  The registered
impls carry ``__name__ = key`` because the planner's database lookups
key on the function name — keep that invariant here, in one place.
"""
from __future__ import annotations

import time

import numpy as np

# per-function processing-time knob, read at call time (the drift injector);
# each benchmark resets it via make_planner, and benchmarks run sequentially
DELAYS_MS: dict[str, float] = {}


def make_impl(key: str):
    def sw(x):
        time.sleep(DELAYS_MS[key] / 1e3)
        return np.asarray(x) + 1.0
    sw.__name__ = key
    return sw


def make_planner(name: str, times_ms, io_shape=(8,), inventory=None,
                 **planner_kwargs):
    """ElasticPlanner over a sleep-backed chain; one node per entry of
    ``times_ms``, keys ``f0..fN-1``, knobs initialized to those times.
    ``inventory`` and extra keyword arguments (fault_injector,
    quarantine_after, ...) are forwarded to the planner."""
    from repro.core import ModuleDatabase, linear_ir
    from repro.runtime import ElasticPlanner

    keys = [f"f{i}" for i in range(len(times_ms))]
    DELAYS_MS.clear()
    DELAYS_MS.update(dict(zip(keys, (float(t) for t in times_ms))))
    db = ModuleDatabase(name)
    for k in keys:
        db.register(k, software=make_impl(k))
    ir = linear_ir(name, keys, [float(t) for t in times_ms],
                   io_shape=io_shape)
    return ElasticPlanner(ir, db=db, inventory=inventory, **planner_kwargs)


def tps(executor, tokens) -> float:
    """Blocking tokens-per-second of one run over ``tokens``."""
    t0 = time.perf_counter()
    executor.run(tokens)
    return len(tokens) / max(time.perf_counter() - t0, 1e-9)
