"""Parallel-filter stage replication benchmark — widen the bottleneck.

PR 3's adaptive re-planner could only *move* work between stages, so a
pipeline with one dominant host-bound function was stuck at that
function's service time no matter where the boundaries sat (recovery
topped out well below the hardware).  TBB's answer — and Courier-FPGA's,
whose generated pipelines use TBB *parallel* filters for the replicable
middle stages — is to run the bottleneck filter N-wide.  This benchmark
exercises the whole widened path:

1. **Simulation** — a 4-function chain with ONE dominant sleep-backed
   stage (the shape re-balancing cannot fix: boundaries can't split a
   node).  A serial stage-worker executor is profiled while serving;
   ``replan_from_profile(worker_budget=...)`` then picks "widen" over
   "re-balance" from the measured costs and the replicated executor is
   measured against the serial one.  Acceptance: **>= 1.5x tokens/s**,
   zero out-of-order retirements.
2. **Hot-swap** — the real jitted Harris pipeline behind
   :class:`RequestQueueServer` is swapped serial -> replicated
   mid-stream: zero dropped requests, zero post-warmup recompiles (the
   replicated executor reuses every compiled StageFn — widening never
   moves boundaries), and in-order retirement throughout.

Feeds the ``replicate`` section of ``BENCH_pipeline.json``.
"""
from __future__ import annotations

import sys

import numpy as np

from benchmarks.simchain import make_planner, tps as _tps

N_NODES = 4
STAGE_MS = [0.5, 6.0, 0.5, 0.5]          # one dominant host-bound stage
WORKER_BUDGET = 8


def simulate(n_tokens: int = 32, smoke: bool = False) -> dict:
    """Serial stage-worker vs planner-widened replicated executor."""
    from repro.core import StageProfiler

    if smoke:
        n_tokens = 16
    planner = make_planner("replicate-sim", STAGE_MS)
    prof = StageProfiler(N_NODES, min_samples=4)
    ex, _ = planner.executor_for(N_NODES, max_in_flight=2 * N_NODES + 2,
                                 jit=False, profiler=prof, stage_workers=True)
    plan0 = planner.current_plan
    toks = [np.full((8,), float(i)) for i in range(n_tokens)]

    tps_serial = _tps(ex, toks)          # profiles WHILE serving serially

    decision = planner.replan_from_profile(
        prof, worker_budget=WORKER_BUDGET,
        max_in_flight=2 * WORKER_BUDGET + 2, jit=False)
    if decision.executor is not None:
        tps_replicated = _tps(decision.executor, toks)
        ooo = decision.executor.stats().out_of_order_retired
        decision.executor.close()
    else:                                # no widen — report serial as-is
        tps_replicated, ooo = tps_serial, 0
    ex.close()
    return {
        "n_nodes": N_NODES, "stage_ms": list(STAGE_MS),
        "worker_budget": WORKER_BUDGET, "n_tokens": n_tokens,
        "n_stages": (decision.plan.n_stages if decision.plan is not None
                     else plan0.n_stages),
        "tps_serial": round(tps_serial, 2),
        "tps_replicated": round(tps_replicated, 2),
        "speedup": round(tps_replicated / max(tps_serial, 1e-9), 3),
        "widened": bool(decision.widened),
        "replicas": list(decision.replicas or plan0.replicas),
        "predicted_gain": round(decision.gain, 3),
        "out_of_order": int(ooo),
    }


def hot_swap(n_requests: int = 32, size: tuple[int, int] = (64, 96),
             smoke: bool = False) -> dict:
    """Serial -> replicated executor hot-swap over the jitted Harris app."""
    import jax

    from repro.core import assign_replicas, courier_offload
    from repro.core.tracer import Library
    from repro.launch.serve import RequestQueueServer
    from repro.models.harris import corner_harris_demo, make_harris_db

    if smoke:
        n_requests = 16
    db = make_harris_db(with_hw=False)
    lib = Library(db)
    app = corner_harris_demo(lib)
    H, W = size
    frames = [jax.random.uniform(jax.random.PRNGKey(i), (H, W, 3)) * 255
              for i in range(n_requests)]
    off = courier_offload(app, frames[0], db=db, prefer_hw=False)
    pipe = off.pipeline
    plan = assign_replicas(pipe.plan, pipe.ir, worker_budget=WORKER_BUDGET)
    mb = 4
    ex_serial = pipe.executor(microbatch=mb, pad_microbatches=True)
    ex_serial.warmup(frames[0])
    compiles_warm = pipe.compile_count()

    with RequestQueueServer(ex_serial, max_batch=mb, max_wait_ms=3.0) as srv:
        reqs = [srv.submit(f) for f in frames[: n_requests // 2]]
        # replicated executor over the SAME compiled stages: widening never
        # moves boundaries, so the swap pays zero recompiles
        ex_rep = pipe.executor(microbatch=mb, pad_microbatches=True,
                               replicas=plan.replicas)
        srv.swap_executor(ex_rep, warm_args=(frames[0],))
        reqs += [srv.submit(f) for f in frames[n_requests // 2:]]
        served = dropped = 0
        for r in reqs:
            try:
                r.wait(timeout=120.0)
                served += 1
            except Exception:
                dropped += 1
    ooo = (ex_serial.stats().out_of_order_retired
           + ex_rep.stats().out_of_order_retired)
    ex_rep.close()
    return {
        "requests": n_requests, "served": served, "dropped": dropped,
        "swaps": srv.swaps, "replicas": list(plan.replicas),
        "recompiles_after_warmup": pipe.compile_count() - compiles_warm,
        "out_of_order": int(ooo),
        "shape": [H, W],
    }


_payload_cache: dict = {}


def payload(smoke: bool = False) -> dict:
    key = bool(smoke)
    if key not in _payload_cache:
        _payload_cache[key] = {"sim": simulate(smoke=smoke),
                               "hot_swap": hot_swap(smoke=smoke)}
    return _payload_cache[key]


def run(smoke: bool = False) -> list:
    p = payload(smoke=smoke)
    sim, hs = p["sim"], p["hot_swap"]
    return [
        ("replicate.sim.tps_serial", sim["tps_serial"],
         f"{sim['n_nodes']} nodes; dominant stage {max(sim['stage_ms'])} ms; "
         "serial stage workers"),
        ("replicate.sim.tps_replicated", sim["tps_replicated"],
         f"worker budget {sim['worker_budget']} -> replicas "
         f"{sim['replicas']}"),
        ("replicate.sim.speedup", sim["speedup"],
         "replicated vs serial tokens/s (acceptance >= 1.5)"),
        ("replicate.sim.out_of_order", sim["out_of_order"],
         "retirements out of submission order (acceptance 0)"),
        ("replicate.hot_swap.dropped", hs["dropped"],
         f"{hs['served']}/{hs['requests']} served across "
         f"{hs['swaps']} serial->replicated swap(s)"),
        ("replicate.hot_swap.recompiles_after_warmup",
         hs["recompiles_after_warmup"],
         "compile_count delta across the serial->replicated hot-swap"),
    ]


if __name__ == "__main__":
    for r in run(smoke="--smoke" in sys.argv[1:]):
        print(",".join(str(x) for x in r))
