"""Adaptive re-planning benchmark — profile drift → re-plan → hot-swap.

The scenario Courier-FPGA motivates but never closes the loop on: the
pipeline was balanced from one cost table, then reality drifts (a library
function slows down — cache pollution, thermal throttling, a noisy
neighbor).  The static plan keeps its old boundaries and the slowed stage
becomes the token period; the adaptive path profiles the running pipeline,
re-balances the boundaries from *measured* costs, and hot-swaps the rebuilt
executor with zero dropped requests.

Two parts:

1. **Simulation** — a 6-function chain whose per-function processing time
   is a host-side sleep read from a mutable knob at *call* time, so a mid-
   run slowdown needs no retrace/recompile.  Stages run on the executor's
   threaded stage workers (the TBB execution model), so wall-clock
   tokens/s genuinely tracks the bottleneck stage.  A 3x slowdown is
   injected into one stage; we measure tokens/s for the static plan vs the
   profile-guided re-plan (acceptance: >= 1.3x recovery).
2. **Hot-swap on the real pipeline** — the jitted Harris pipeline behind
   :class:`RequestQueueServer`; an executor rebuilt over the same compiled
   stages is swapped mid-stream.  Asserts zero dropped requests and zero
   post-warmup recompiles (the StageFn/vmapped executables are reused).

Feeds the ``replan`` section of ``BENCH_pipeline.json``.
"""
from __future__ import annotations

import numpy as np

from benchmarks.simchain import DELAYS_MS as _DELAYS_MS
from benchmarks.simchain import make_planner, tps as _tps

# --------------------------------------------------------------------------- #
# 1. simulated drift: sleep-backed stages with a runtime knob
# --------------------------------------------------------------------------- #
N_NODES = 6
BASE_MS = 2.0
SLOWDOWN = 3.0
SLOWED_STAGE = 1            # middle stage of the initial 3-stage plan


def simulate(n_tokens: int = 24, smoke: bool = False) -> dict:
    """Static vs adaptive tokens/s across an injected 3x stage slowdown."""
    from repro.core import StageProfiler

    if smoke:
        n_tokens = 12
    planner = make_planner("replan-sim", [BASE_MS] * N_NODES)
    prof = StageProfiler(3, min_samples=4)
    ex, _ = planner.executor_for(3, max_in_flight=2 * 3 + 2, jit=False,
                                 profiler=prof, stage_workers=True)
    plan0 = planner.current_plan
    toks = [np.full((8,), float(i)) for i in range(n_tokens)]

    tps_before = _tps(ex, toks)

    # inject: every function of the slowed stage drifts 3x (mid-run knob —
    # no retrace; the same executor keeps serving, now off-balance)
    slowed = list(plan0.stages[SLOWED_STAGE].node_names)
    for nn in slowed:
        _DELAYS_MS[planner.layer_ir.node(nn).fn_key] *= SLOWDOWN
    prof.reset()
    tps_static = _tps(ex, toks)          # profiles WHILE serving the slow plan

    decision = planner.replan_from_profile(
        prof, max_stages=N_NODES, max_in_flight=2 * 6 + 2, jit=False,
        stage_workers=True)
    if decision.executor is not None:
        tps_adaptive = _tps(decision.executor, toks)
        decision.executor.close()
    else:                                # no replan — report static as-is
        tps_adaptive = tps_static
    ex.close()
    return {
        "n_nodes": N_NODES, "base_ms": BASE_MS, "slowdown": SLOWDOWN,
        "slowed_stage": SLOWED_STAGE, "n_tokens": n_tokens,
        "tps_before_slowdown": round(tps_before, 2),
        "tps_static": round(tps_static, 2),
        "tps_adaptive": round(tps_adaptive, 2),
        "recovery": round(tps_adaptive / max(tps_static, 1e-9), 3),
        "replanned": decision.replanned,
        "replan_gain_predicted": round(decision.gain, 3),
        "measured_bottleneck_ms": round(decision.old_bottleneck_ms, 3),
        "replanned_bottleneck_ms": round(decision.new_bottleneck_ms, 3),
        "n_stages": (decision.plan.n_stages if decision.plan is not None
                     else plan0.n_stages),
    }


# --------------------------------------------------------------------------- #
# 2. zero-downtime hot-swap over the real (jitted) Harris pipeline
# --------------------------------------------------------------------------- #
def hot_swap(n_requests: int = 32, size: tuple[int, int] = (64, 96),
             smoke: bool = False) -> dict:
    import jax

    from repro.core import courier_offload
    from repro.core.tracer import Library
    from repro.launch.serve import RequestQueueServer
    from repro.models.harris import corner_harris_demo, make_harris_db

    if smoke:
        n_requests = 16
    db = make_harris_db(with_hw=False)
    lib = Library(db)
    app = corner_harris_demo(lib)
    H, W = size
    frames = [jax.random.uniform(jax.random.PRNGKey(i), (H, W, 3)) * 255
              for i in range(n_requests)]
    off = courier_offload(app, frames[0], db=db, prefer_hw=False)
    pipe = off.pipeline
    mb = 4
    ex_a = pipe.executor(microbatch=mb, pad_microbatches=True)
    ex_a.warmup(frames[0])
    compiles_warm = pipe.compile_count()

    with RequestQueueServer(ex_a, max_batch=mb, max_wait_ms=3.0) as srv:
        reqs = [srv.submit(f) for f in frames[: n_requests // 2]]
        # rebuilt executor over the SAME compiled stages (what the planner
        # hands the server after a re-plan that kept these boundaries)
        ex_b = pipe.executor(microbatch=mb, pad_microbatches=True)
        srv.swap_executor(ex_b, warm_args=(frames[0],))
        reqs += [srv.submit(f) for f in frames[n_requests // 2:]]
        served = dropped = 0
        for r in reqs:
            try:
                r.wait(timeout=120.0)
                served += 1
            except Exception:
                dropped += 1
    return {
        "requests": n_requests, "served": served, "dropped": dropped,
        "swaps": srv.swaps,
        "recompiles_after_warmup": pipe.compile_count() - compiles_warm,
        "shape": [H, W],
    }


_payload_cache: dict = {}


def payload(smoke: bool = False) -> dict:
    key = bool(smoke)
    if key not in _payload_cache:
        _payload_cache[key] = {"sim": simulate(smoke=smoke),
                               "hot_swap": hot_swap(smoke=smoke)}
    return _payload_cache[key]


def run() -> list:
    p = payload()
    sim, hs = p["sim"], p["hot_swap"]
    return [
        ("replan.sim.tps_before_slowdown", sim["tps_before_slowdown"],
         f"{sim['n_nodes']} nodes x {sim['base_ms']} ms, 3-stage plan"),
        ("replan.sim.tps_static", sim["tps_static"],
         f"{sim['slowdown']}x slowdown on stage {sim['slowed_stage']}, "
         "old boundaries"),
        ("replan.sim.tps_adaptive", sim["tps_adaptive"],
         f"profile-guided re-plan -> {sim['n_stages']} stages"),
        ("replan.sim.recovery", sim["recovery"],
         "adaptive vs static tokens/s (acceptance >= 1.3)"),
        ("replan.hot_swap.dropped", hs["dropped"],
         f"{hs['served']}/{hs['requests']} served across "
         f"{hs['swaps']} swap(s)"),
        ("replan.hot_swap.recompiles_after_warmup",
         hs["recompiles_after_warmup"],
         "compile_count delta across warm executor hot-swap"),
    ]


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
