"""Overload benchmark — open-loop Poisson load against the serving layer.

Closed-loop load generators (submit, wait, submit) hide queueing collapse:
the generator slows down with the server, so offered load silently tracks
capacity and the queue never grows.  This benchmark is **open-loop**: a
submitter thread fires requests on a pre-drawn Poisson schedule regardless
of completions (the admission controller guarantees ``submit`` never
blocks), which is the only load shape that exposes what a serving system
does when offered load exceeds capacity.

Two scenarios feed the ``overload`` section of ``BENCH_pipeline.json``:

1. **sweep** — a sleep-backed chain served at 0.7x / 1.0x / 2.0x of its
   measured closed-loop capacity with a 30/30/40 interactive/batch/
   best-effort mix.  Interactive and batch carry deadlines (tight and
   loose); best-effort carries none and is the degradation ladder's first
   casualty.  Acceptance at 2.0x: interactive goodput >= 0.9x its offered
   load (shedding lands on best-effort/batch), interactive p99 within its
   deadline SLO, and the accounting invariant — submitted == served +
   shed + expired + failed, every request resolved (nothing blocked
   forever).
2. **chaos** — 2.0x overload composed with the fault harness: seeded
   random transients on the widened stage (post-warmup via
   ``random_transients(from_call=)``), a live mid-run device loss
   (quarantine -> inventory refresh -> survivors re-plan -> zero-downtime
   ``swap_executor``), still under admission control.  Acceptance: zero
   unaccounted requests and zero out-of-order retirements through all of
   it.

``poisson_schedule`` is a pure function of its seed (bulk draws from
``np.random.default_rng``), so the offered traffic reproduces bit-exactly
— the determinism test in ``tests/test_overload.py`` relies on this.
"""
from __future__ import annotations

import sys
import threading
import time

import numpy as np

from benchmarks.simchain import make_planner, tps as _tps

STAGE_MS = [1.0, 3.0, 1.0]            # serial sweep chain
CHAOS_STAGE_MS = [2.0, 8.0]           # dominant 2nd stage gets the widening
RATES = (0.7, 1.0, 2.0)               # offered load as a fraction of capacity
MIX = (0.3, 0.3, 0.4)                 # interactive / batch / best-effort
INTERACTIVE_DEADLINE_MS = 100.0
BATCH_DEADLINE_MS = 450.0
DEADLINES = (INTERACTIVE_DEADLINE_MS, BATCH_DEADLINE_MS, None)
GOODPUT_FLOOR = 0.9                   # interactive served/offered at 2.0x


def poisson_schedule(rate_rps: float, duration_s: float, seed: int,
                     mix=MIX) -> tuple[np.ndarray, np.ndarray]:
    """Seeded open-loop schedule: (arrival times s, priority classes).

    Pure function of ``(rate_rps, duration_s, seed, mix)`` — exponential
    interarrivals and class draws come from one ``default_rng(seed)``
    stream in a fixed order, so the same seed reproduces the same traffic
    bit-exactly on any machine.
    """
    if rate_rps <= 0 or duration_s <= 0:
        raise ValueError("rate_rps and duration_s must be > 0")
    rng = np.random.default_rng(seed)
    times: list[float] = []
    t = 0.0
    while True:
        # bulk draws keep the rng call sequence deterministic AND fast
        chunk = rng.exponential(1.0 / rate_rps, size=256)
        for dt in chunk:
            t += dt
            if t >= duration_s:
                break
            times.append(t)
        if t >= duration_s:
            break
    arrivals = np.asarray(times, dtype=np.float64)
    edges = np.cumsum(np.asarray(mix, dtype=np.float64))
    classes = np.searchsorted(edges, rng.random(len(arrivals)),
                              side="right").astype(np.int64)
    classes = np.minimum(classes, len(mix) - 1)
    return arrivals, classes


def _measure_capacity(ex, n_tokens: int = 48) -> float:
    """Closed-loop requests-per-second of the executor (the 1.0x anchor)."""
    toks = [np.full((8,), float(i)) for i in range(n_tokens)]
    return _tps(ex, toks)


def _drive_open_loop(srv, arrivals: np.ndarray, classes: np.ndarray,
                     deadlines=DEADLINES) -> list:
    """Submit on the absolute-time schedule; returns the Request list.

    Runs on the caller's thread; with an admission controller attached
    ``submit`` never blocks, so the schedule is honored even when the
    server is drowning (the definition of open-loop).
    """
    tok = np.full((8,), 1.0)
    t0 = time.perf_counter()
    reqs = []
    for t_rel, cls in zip(arrivals, classes):
        delay = t0 + float(t_rel) - time.perf_counter()
        if delay > 0:
            time.sleep(delay)
        reqs.append(srv.submit(tok, deadline_ms=deadlines[int(cls)],
                               priority=int(cls)))
    return reqs


def _settle(reqs, timeout_s: float = 60.0) -> int:
    """Wait for every request to resolve; count the unresolved stragglers
    (must be zero: 'no request blocked forever' is the invariant)."""
    from repro.launch.serve import WaitTimeout

    deadline = time.perf_counter() + timeout_s
    unresolved = 0
    for r in reqs:
        try:
            r.wait(timeout=max(deadline - time.perf_counter(), 0.001))
        except WaitTimeout:
            unresolved += 1
        except Exception:
            pass                      # shed/expired/failed: resolved
    return unresolved


def _class_summary(stats: dict) -> dict:
    out = {}
    for name, entry in stats["classes"].items():
        sub = entry["submitted"]
        lat = entry["latency_ms"]
        out[name] = {
            "submitted": int(sub),
            "served": int(entry["served"]),
            "shed": int(entry["shed"]),
            "expired": int(entry["expired"]),
            "failed": int(entry["failed"]),
            "goodput": round(entry["served"] / sub, 4) if sub else 1.0,
            "p50_ms": round(lat["p50"], 3),
            "p99_ms": round(lat["p99"], 3),
            "p999_ms": round(lat["p999"], 3),
        }
    return out


def _accounted(stats: dict) -> bool:
    total = (stats["requests_served"] + stats["shed"] + stats["expired"]
             + stats["failed"])
    return total == stats["submitted"]


def sweep(smoke: bool = False, seed: int = 7) -> dict:
    """Serve the same pipeline at 0.7x/1.0x/2.0x measured capacity."""
    from repro.core import StageProfiler
    from repro.launch.serve import AdmissionController, RequestQueueServer

    duration_s = 0.8 if smoke else 2.5
    n_stages = len(STAGE_MS)
    planner = make_planner("overload-sweep", STAGE_MS)
    prof = StageProfiler(n_stages, min_samples=2)
    ex, _ = planner.executor_for(n_stages, jit=False, profiler=prof)
    plan = planner.current_plan
    capacity_rps = _measure_capacity(ex)

    out: dict = {
        "capacity_rps": round(capacity_rps, 2),
        "period_ms": round(float(plan.effective_bottleneck_ms), 3),
        "duration_s": duration_s,
        "mix": list(MIX),
        "deadline_ms": {"interactive": INTERACTIVE_DEADLINE_MS,
                        "batch": BATCH_DEADLINE_MS},
        "sweep": {},
    }
    for i, rate in enumerate(RATES):
        offered = rate * capacity_rps
        arrivals, classes = poisson_schedule(offered, duration_s, seed + i)
        # batch_hint=1: this executor serves microbatch=1, so the pipeline
        # retires ONE token per effective period — a dispatch group is a
        # single token for admission's wait prediction
        adm = AdmissionController.from_plan(
            plan, max_batch=1, slo_ref_ms=BATCH_DEADLINE_MS)
        # max_batch=4 on a pool-4 executor: one flush of a lower class
        # never occupies more than ~4 service periods before interactive
        # work can preempt again
        with RequestQueueServer(ex, max_batch=4, max_wait_ms=2.0,
                                queue_depth=256, admission=adm) as srv:
            reqs = _drive_open_loop(srv, arrivals, classes)
            unresolved = _settle(reqs)
        stats = srv.stats()
        by_class = _class_summary(stats)
        entry = {
            "offered_rps": round(offered, 2),
            "submitted": int(stats["submitted"]),
            "served": int(stats["requests_served"]),
            "shed": int(stats["shed"]),
            "expired": int(stats["expired"]),
            "failed": int(stats["failed"]),
            "unresolved": int(unresolved),
            "accounted": bool(_accounted(stats) and unresolved == 0),
            "slo_violation_rate": round(stats["slo_violation_rate"], 4),
            "interactive": by_class["interactive"],
            "batch": by_class["batch"],
            "best_effort": by_class["best_effort"],
        }
        out["sweep"][f"{rate:g}x"] = entry
        assert entry["accounted"], \
            f"{rate:g}x: {entry['submitted']} submitted != " \
            f"{entry['served']} served + {entry['shed']} shed + " \
            f"{entry['expired']} expired + {entry['failed']} failed " \
            f"({entry['unresolved']} unresolved)"
    ex.close()

    hot = out["sweep"]["2x"]
    ia = hot["interactive"]
    assert ia["goodput"] >= GOODPUT_FLOOR, \
        f"2x overload: interactive goodput {ia['goodput']:.3f} below " \
        f"{GOODPUT_FLOOR} ({ia['served']}/{ia['submitted']})"
    assert ia["p99_ms"] <= INTERACTIVE_DEADLINE_MS, \
        f"2x overload: interactive p99 {ia['p99_ms']:.1f} ms breaks the " \
        f"{INTERACTIVE_DEADLINE_MS:g} ms deadline SLO"
    # shedding must land on the no-deadline class first, not interactive
    assert hot["best_effort"]["shed"] >= ia["shed"], \
        "2x overload shed more interactive than best-effort traffic"
    return out


def chaos(smoke: bool = False, seed: int = 11) -> dict:
    """2.0x overload + random transients + a live device loss, end to end."""
    from repro.core import DeviceInventory, StageProfiler
    from repro.launch.serve import AdmissionController, RequestQueueServer
    from repro.runtime.faults import FaultInjector

    duration_s = 1.5 if smoke else 4.0
    n_stages = len(CHAOS_STAGE_MS)
    inv = DeviceInventory.host(4)
    inj = FaultInjector()             # faults scripted live, post-warmup
    planner = make_planner("overload-chaos", CHAOS_STAGE_MS, inventory=inv,
                           fault_injector=inj, quarantine_after=3)
    prof = StageProfiler(n_stages, min_samples=2)
    ex, _ = planner.executor_for(n_stages, jit=False, profiler=prof)
    plan = planner.current_plan
    wide_si = max(range(n_stages), key=lambda s: ex.replicas[s])
    target = ex.devices[wide_si][0]
    capacity_rps = _measure_capacity(ex)

    # transients start AFTER the capacity run's calls: the calibration
    # anchor stays fault-free, the serving phase gets the full rate
    inj.plan.random_transients(0.02, seed=seed, stages=[wide_si],
                               from_call=inj.stage_calls(wide_si))

    offered = 2.0 * capacity_rps
    arrivals, classes = poisson_schedule(offered, duration_s, seed)
    adm = AdmissionController.from_plan(
        plan, max_batch=1, slo_ref_ms=BATCH_DEADLINE_MS)
    old_ex = None
    decision = None
    with RequestQueueServer(ex, max_batch=4, max_wait_ms=2.0,
                            queue_depth=256, admission=adm) as srv:
        box: dict = {}

        def _driver():
            box["reqs"] = _drive_open_loop(srv, arrivals, classes)

        sub = threading.Thread(target=_driver, daemon=True)
        sub.start()
        # mid-run: pull one of the wide stage's devices out from under the
        # serving loop, then recover elastically while overloaded
        time.sleep(0.35 * duration_s)
        inj.lose_device(target)
        time.sleep(0.25 * duration_s)
        diff = inv.refresh(probe=lambda: inj.surviving(inv))
        decision = planner.replan_on_inventory_change(
            diff, profiler=prof, stats=ex.stats(), jit=False)
        if decision.replanned and decision.executor is not None:
            old_ex = srv.swap_executor(decision.executor,
                                       warm_args=(np.full((8,), 1.0),))
        sub.join()
        unresolved = _settle(box["reqs"])
    stats = srv.stats()
    exec_stats = [ex.stats()] + ([decision.executor.stats()]
                                 if old_ex is not None else [])
    ooo = sum(s.out_of_order_retired for s in exec_stats)
    retries = sum(s.retries for s in exec_stats)
    quarantined = sum(s.quarantined for s in exec_stats)
    ex.close()
    if old_ex is not None:
        decision.executor.close()

    out = {
        "offered_rps": round(offered, 2),
        "capacity_rps": round(capacity_rps, 2),
        "duration_s": duration_s,
        "submitted": int(stats["submitted"]),
        "served": int(stats["requests_served"]),
        "shed": int(stats["shed"]),
        "expired": int(stats["expired"]),
        "failed": int(stats["failed"]),
        "unresolved": int(unresolved),
        "accounted": bool(_accounted(stats) and unresolved == 0),
        "out_of_order": int(ooo),
        "retries": int(retries),
        "quarantined": int(quarantined),
        "errors_injected": int(inj.injected),
        "lost_device": int(target),
        "replanned": bool(decision is not None and decision.replanned),
        "swaps": int(srv.swaps),
        "interactive_goodput": round(
            stats["classes"]["interactive"]["served"]
            / max(stats["classes"]["interactive"]["submitted"], 1), 4),
    }
    assert out["accounted"], \
        f"chaos: {out['submitted']} submitted != {out['served']} served + " \
        f"{out['shed']} shed + {out['expired']} expired + " \
        f"{out['failed']} failed ({out['unresolved']} unresolved)"
    assert out["out_of_order"] == 0, \
        f"chaos: {out['out_of_order']} out-of-order retirements"
    assert out["errors_injected"] >= 1, "chaos injected no faults"
    assert out["replanned"], "device loss did not trigger a re-plan"
    return out


_payload_cache: dict = {}


def payload(smoke: bool = False) -> dict:
    key = bool(smoke)
    if key not in _payload_cache:
        s = sweep(smoke=smoke)
        s["chaos"] = chaos(smoke=smoke)
        _payload_cache[key] = s
    return _payload_cache[key]


def run(smoke: bool = False) -> list:
    p = payload(smoke=smoke)
    hot, ch = p["sweep"]["2x"], p["chaos"]
    rows = []
    for rate, entry in p["sweep"].items():
        ia = entry["interactive"]
        rows.append((
            f"overload.{rate}.interactive_goodput", ia["goodput"],
            f"{ia['served']}/{ia['submitted']} served; p99 "
            f"{ia['p99_ms']} ms vs {INTERACTIVE_DEADLINE_MS:g} ms deadline"))
        rows.append((
            f"overload.{rate}.shed", entry["shed"],
            f"{entry['submitted']} submitted at {entry['offered_rps']} rps; "
            f"{entry['expired']} expired; accounted {entry['accounted']}"))
    rows.append((
        "overload.chaos.unaccounted",
        ch["submitted"] - ch["served"] - ch["shed"] - ch["expired"]
        - ch["failed"],
        f"{ch['errors_injected']} faults injected; device {ch['lost_device']}"
        f" lost; {ch['retries']} retries; {ch['quarantined']} quarantined; "
        f"{ch['out_of_order']} out-of-order"))
    assert hot["interactive"]["goodput"] >= GOODPUT_FLOOR
    return rows


if __name__ == "__main__":
    for r in run(smoke="--smoke" in sys.argv[1:]):
        print(",".join(str(x) for x in r))
