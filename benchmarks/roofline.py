"""Roofline analysis (deliverable g) — three terms per (arch × shape × mesh).

Reads artifacts/dryrun/*.json (written by repro.launch.dryrun) and derives

    compute term    = HLO_FLOPs / peak_FLOP/s          (per chip)
    memory term     = HLO_bytes / HBM_bw               (per chip)
    collective term = collective_bytes / link_bw       (per chip)

Sources: probe-extrapolated cost_analysis (XLA counts while-loop bodies
once, so the dry-run compiles 1- and 2-layer *unrolled* probes on the same
mesh/shardings and extrapolates linearly in L — see launch/dryrun.py).
Time-recurrence inner scans (rwkv/hymba SSM) stay under-counted even in the
probes; an analytic correction (documented below) is added for those archs.

Also reports MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE) and the
useful-compute ratio MODEL_FLOPS / (chips · HLO_FLOPs).
"""
from __future__ import annotations

import glob
import json
import os

from repro.core.costmodel import HBM_BW, ICI_BW_PER_LINK, PEAK_FLOPS_BF16

ICI_LINKS = 4          # v5e: 4 ICI links per chip usable for the 2D mesh


def _recurrence_correction(rec: dict) -> tuple[float, float]:
    """Analytic (flops, bytes) PER DEVICE for scan-based recurrences.

    rwkv time-mix step: S[d,64] update+readout ≈ 6 flops/elem; 2 f32 R/W.
    hymba ssm step:     h[d,16] update+readout ≈ 9 flops/elem; 2 f32 R/W.
    Train multiplies by 4 (fwd + remat-fwd + ~2x bwd); decode/prefill by 1.
    """
    arch = rec["arch"]
    if "rwkv" in arch:
        d, st, L = 2048, 64, 24
        f_per = 6 * d * st
        b_per = 2 * d * st * 4
    elif "hymba" in arch:
        d, st, L = 1600, 16, 32
        f_per = 9 * d * st
        b_per = 2 * d * st * 4
    else:
        return 0.0, 0.0
    chips = rec.get("chips", 256)
    batch_shards = chips // 16          # data(+pod) axes of the mesh
    B, S = rec["global_batch"], rec["seq_len"]
    if rec["kind"] == "train":
        toks = max(B // batch_shards, 1) * S
        mult = 4.0
    elif rec["kind"] == "prefill":
        toks = max(B // batch_shards, 1) * S
        mult = 1.0
    else:
        toks = max(B // batch_shards, 1)
        mult = 1.0
    return mult * f_per * toks * L, mult * b_per * toks * L


def _model_flops(rec: dict) -> float:
    n = rec["n_params_active"]
    B, S = rec["global_batch"], rec["seq_len"]
    if rec["kind"] == "train":
        return 6.0 * n * B * S
    if rec["kind"] == "prefill":
        return 2.0 * n * B * S
    return 2.0 * n * B                 # decode: one token per sequence


def analyze(rec: dict) -> dict | None:
    if rec.get("status") != "ok":
        return None
    probe = rec.get("probe", {})
    ext = probe.get("extrapolated")
    if ext:
        flops, byts = ext["flops"], ext["bytes"]
        coll = sum(v for k, v in ext["collectives"].items()
                   if not k.endswith("_count"))
        source = "probe-extrapolated"
    else:
        flops = rec["cost"].get("flops", 0.0)
        byts = rec["cost"].get("bytes accessed", 0.0)
        coll = sum(v for k, v in rec.get("collectives", {}).items()
                   if not k.endswith("_count"))
        source = "raw (loop bodies counted once — underestimate)"
    cf, cb = _recurrence_correction(rec)
    flops += cf
    byts += cb
    t_c = flops / PEAK_FLOPS_BF16
    t_m = byts / HBM_BW
    t_x = coll / (ICI_LINKS * ICI_BW_PER_LINK)
    dom = ("compute", "memory", "collective")[
        [t_c, t_m, t_x].index(max(t_c, t_m, t_x))]
    mf = _model_flops(rec)
    chips = rec.get("chips", 256)
    ratio = mf / max(chips * flops, 1.0)
    step = max(t_c, t_m) + t_x
    mfu = mf / (chips * PEAK_FLOPS_BF16 * step) if step > 0 else 0.0
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "t_compute_s": t_c, "t_memory_s": t_m, "t_collective_s": t_x,
        "dominant": dom, "model_flops": mf, "hlo_flops_per_chip": flops,
        "useful_ratio": ratio, "roofline_frac": min(mfu, 1.0),
        "source": source,
        "recurrence_corrected": cf > 0,
    }


SUGGEST = {
    "compute": "reduce recompute (remat policy) / push MXU-aligned fusion",
    "memory": "cut HBM traffic: fuse elementwise chains, windowed KV, "
              "keep recurrence state in VMEM (chunked kernel)",
    "collective": "reshard to cut per-layer gathers; overlap collectives "
                  "with compute; larger per-device batch",
}


def run(art_dir: str = "artifacts/dryrun") -> list[tuple[str, float, str]]:
    rows = []
    table = []
    for f in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        rec = json.load(open(f))
        if rec.get("status") == "skip":
            rows.append((f"roofline.{rec['arch']}.{rec['shape']}.{rec['mesh']}",
                         -1, f"SKIP: {rec['reason'][:60]}"))
            continue
        a = analyze(rec)
        if a is None:
            rows.append((f"roofline.{rec['arch']}.{rec['shape']}.{rec['mesh']}",
                         -2, f"ERROR: {rec.get('error', '?')[:60]}"))
            continue
        table.append(a)
        key = f"roofline.{a['arch']}.{a['shape']}.{a['mesh']}"
        rows.append((key + ".roofline_frac", round(a["roofline_frac"], 4),
                     f"dom={a['dominant']}; "
                     f"tC={a['t_compute_s']:.3e}s tM={a['t_memory_s']:.3e}s "
                     f"tX={a['t_collective_s']:.3e}s; "
                     f"useful={a['useful_ratio']:.2f}; → "
                     f"{SUGGEST[a['dominant']][:48]}"))
    if table:
        os.makedirs("artifacts", exist_ok=True)
        with open("artifacts/roofline.json", "w") as f:
            json.dump(table, f, indent=1)
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
