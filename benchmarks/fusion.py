"""Fusion benchmark — fused Pallas mega-kernels vs unfused chains.

Three views, all feeding ``BENCH_pipeline.json``:

1. *Kernel*: the single-pass fused Harris mega-kernel (cvtColor →
   cornerHarris → convertScaleAbs in one ``pallas_call``) against the
   unfused 3-kernel chain, wall-clocked (interpret-mode kernels on CPU
   containers; native on TPU).
2. *Roofline*: the cost model's side of the story — HBM bytes for the
   unfused chain vs the fused kernel (intermediates VMEM-resident), i.e.
   the traffic reduction that makes fusion win on TPU where the paper's
   FPGA synthesis report made it lose.
3. *Pipeline*: tokens/s of the generated mixed pipeline with the fusion
   compiler off vs on (cost-model-driven ``fuse=True``), plus the fused
   rmsnorm+matmul epilogue micro-benchmark.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import courier_offload
from repro.core.tracer import Library
from repro.models.harris import (_c_csa, _c_cvt, _c_fused_mega, _c_harris,
                                 corner_harris_demo, make_harris_db)

SIZE = (64, 96)


def _interleaved_best_ms(fns: dict, reps: int = 10) -> dict:
    """min-of-reps wall ms per callable, reps interleaved across variants.

    On a shared container the background load swings throughput by 2-4x
    between seconds; measuring variant A's reps back-to-back before variant
    B's makes the comparison meaningless.  Interleaving gives every variant
    the same noise distribution and min-of-reps picks each one's clean run.
    """
    import time

    for f in fns.values():                       # warmup/compile
        jax.block_until_ready(f())
    best = {k: float("inf") for k in fns}
    for _ in range(reps):
        for k, f in fns.items():
            t0 = time.perf_counter()
            jax.block_until_ready(f())
            best[k] = min(best[k], (time.perf_counter() - t0) * 1e3)
    return best


# --------------------------------------------------------------------------- #
# 1. kernel-level: fused mega-kernel vs 3-kernel chain
# --------------------------------------------------------------------------- #
def kernel_compare(size: tuple[int, int] = SIZE, reps: int = 10) -> dict:
    from repro.kernels.harris import (convert_scale_abs, corner_harris,
                                      cvt_color, harris_fused)

    H, W = size
    img = jax.random.uniform(jax.random.PRNGKey(0), (H, W, 3)) * 255

    @jax.jit
    def chain(img):
        return convert_scale_abs(corner_harris(cvt_color(img)))

    @jax.jit
    def fused(img):
        return harris_fused(img)

    best = _interleaved_best_ms({"chain": lambda: chain(img),
                                 "fused": lambda: fused(img)}, reps=reps)
    return {"shape": [H, W], "chain_ms": round(best["chain"], 4),
            "fused_ms": round(best["fused"], 4),
            "speedup": round(best["chain"] / max(best["fused"], 1e-9), 3)}


# --------------------------------------------------------------------------- #
# 2. roofline: HBM traffic with/without VMEM-resident intermediates
# --------------------------------------------------------------------------- #
def roofline_report(size: tuple[int, int] = SIZE) -> dict:
    H, W = size
    shapes = [(H, W, 3)]
    parts = [_c_cvt(shapes, None, None), _c_harris([(H, W)], None, None),
             _c_csa([(H, W)], None, None)]
    unfused_bytes = sum(p.bytes_rw for p in parts)
    fused = _c_fused_mega(shapes, None, None)
    return {
        "shape": [H, W],
        "hbm_bytes_unfused": int(unfused_bytes),
        "hbm_bytes_fused": int(fused.bytes_rw),
        "hbm_bytes_saved": int(unfused_bytes - fused.bytes_rw),
        "traffic_reduction": round(unfused_bytes / max(fused.bytes_rw, 1), 3),
        "est_unfused_ms": round(sum(p.time_ms() for p in parts), 6),
        "est_fused_ms": round(fused.time_ms(), 6),
    }


# --------------------------------------------------------------------------- #
# 3. pipeline-level: fusion compiler off vs on (same hw modules)
# --------------------------------------------------------------------------- #
def pipeline_compare(n_frames: int = 8,
                     size: tuple[int, int] = SIZE) -> dict:
    H, W = size
    frames = [jax.random.uniform(jax.random.PRNGKey(i), (H, W, 3)) * 255
              for i in range(n_frames)]

    def build(fuse: bool):
        db = make_harris_db(with_hw=True)
        app = corner_harris_demo(Library(db))
        return courier_offload(app, frames[0], db=db, prefer_hw=True,
                               fuse=fuse)

    offs = {"unfused": build(False), "fused": build(True)}
    execs, best = {}, {}
    for label, off in offs.items():
        execs[label] = off.pipeline.executor(max_in_flight=n_frames)
        execs[label].warmup(frames[0])
        best[label] = float("inf")
    # interleave the reps so both variants sample the same background noise
    # (shared-container throughput swings dominate back-to-back runs)
    for _ in range(10):
        for label, ex in execs.items():
            ex.reset_stats()
            ex.run(frames)
            best[label] = min(best[label], ex.stats().wall_ms)
    out = {}
    for label, off in offs.items():
        out[label] = {
            "tokens_per_sec": round(n_frames / (best[label] / 1e3), 2),
            "bottleneck_ms": round(off.pipeline.plan.bottleneck_ms, 6),
            "n_stages": off.pipeline.plan.n_stages,
            "compile_count": off.pipeline.compile_count(),
            "fused_nodes": [n.fn_key for n in off.pipeline.ir.nodes
                            if n.fused_from],
        }
    out["speedup_fused_vs_unfused"] = round(
        out["fused"]["tokens_per_sec"]
        / max(out["unfused"]["tokens_per_sec"], 1e-9), 3)
    return out


def rmsnorm_matmul_compare(N: int = 256, d: int = 512,
                           dout: int = 512) -> dict:
    from repro.kernels import ref
    from repro.kernels.rmsnorm import rmsnorm, rmsnorm_matmul

    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    x = jax.random.normal(ks[0], (N, d))
    s = jax.random.normal(ks[1], (d,)) * 0.1
    w = jax.random.normal(ks[2], (d, dout))

    @jax.jit
    def unfused(x, s, w):
        return jnp.dot(rmsnorm(x, s).astype(jnp.float32), w)

    @jax.jit
    def fused(x, s, w):
        return rmsnorm_matmul(x, s, w)

    best = _interleaved_best_ms({"unfused": lambda: unfused(x, s, w),
                                 "fused": lambda: fused(x, s, w)})
    return {"shape": [N, d, dout], "unfused_ms": round(best["unfused"], 4),
            "fused_ms": round(best["fused"], 4),
            "speedup": round(best["unfused"] / max(best["fused"], 1e-9), 3)}


_payload_cache: dict = {}


def payload(smoke: bool = False) -> dict:
    """The fusion half of ``BENCH_pipeline.json``.  Memoized per ``smoke``
    flag so CSV emission and the JSON artifact share one measurement."""
    if smoke not in _payload_cache:
        n_frames = 2 if smoke else 8
        _payload_cache[smoke] = {
            "harris_kernel": kernel_compare(),
            "roofline": roofline_report(),
            "pipeline": pipeline_compare(n_frames=n_frames),
            "rmsnorm_matmul": rmsnorm_matmul_compare(
                *((64, 128, 128) if smoke else (256, 512, 512))),
        }
    return _payload_cache[smoke]


def run() -> list[tuple[str, float, str]]:
    p = payload()
    rows = [
        ("fusion.kernel.chain_ms", p["harris_kernel"]["chain_ms"],
         "3 pallas_calls; gray/response bounce through HBM"),
        ("fusion.kernel.fused_ms", p["harris_kernel"]["fused_ms"],
         "one pallas_call; intermediates stay in VMEM scratch"),
        ("fusion.kernel.speedup", p["harris_kernel"]["speedup"],
         "fused mega-kernel vs unfused 3-kernel chain"),
        ("fusion.roofline.traffic_reduction",
         p["roofline"]["traffic_reduction"],
         f"{p['roofline']['hbm_bytes_saved']} HBM bytes saved/frame"),
        ("fusion.pipeline.unfused_tps",
         p["pipeline"]["unfused"]["tokens_per_sec"],
         f"{p['pipeline']['unfused']['n_stages']} stages"),
        ("fusion.pipeline.fused_tps",
         p["pipeline"]["fused"]["tokens_per_sec"],
         f"fused nodes: {p['pipeline']['fused']['fused_nodes']}"),
        ("fusion.pipeline.speedup", p["pipeline"]["speedup_fused_vs_unfused"],
         "cost-model fusion on vs off, same Pallas modules"),
        ("fusion.rmsnorm_matmul.speedup", p["rmsnorm_matmul"]["speedup"],
         "fused epilogue vs rmsnorm-then-matmul"),
    ]
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
