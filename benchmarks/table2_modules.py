"""Paper Table II — evaluation of individual generated modules.

The paper reports per-HLS-module frequency/latency/processing time.  The
TPU analog: per Pallas module, the analytic roofline time on one v5e chip
(the synthesis-report stand-in the Pipeline Generator actually uses) next
to the measured software (jnp/XLA-CPU) time on this host.
"""
from __future__ import annotations

import jax

from repro.configs.harris import config as HARRIS
from repro.core.costmodel import measure_ms
from repro.models.harris import make_harris_db


def run() -> list[tuple[str, float, str]]:
    db = make_harris_db(with_hw=True)
    H, W = HARRIS.height, HARRIS.width
    img = jax.random.uniform(jax.random.PRNGKey(0), (H, W, 3)) * 255
    gray = db.entries["cvtColor"].software(img)

    args = {"cvtColor": (img,), "cornerHarris": (gray,),
            "normalize": (gray,), "convertScaleAbs": (gray,)}
    rows = []
    for name, a in args.items():
        e = db.entries[name]
        shapes = [tuple(x.shape) for x in a]
        dtypes = [str(x.dtype) for x in a]
        sw_ms = measure_ms(jax.jit(e.software), *a)
        rows.append((f"table2.{name}.sw_cpu_ms", round(sw_ms, 3),
                     f"paper Zynq-SW {HARRIS.paper_times_orig[name]} ms"))
        if e.cost_hw is not None and e.accelerated is not None:
            c = e.cost_hw(shapes, dtypes, {})
            rows.append((f"table2.{name}.hw_tpu_roofline_ms",
                         round(c.time_ms(), 4),
                         f"paper HLS {HARRIS.paper_times_offl[name]} ms; "
                         f"AI={c.arithmetic_intensity:.2f} flop/B "
                         f"({c.dominant()}-bound)"))
        else:
            rows.append((f"table2.{name}.hw_tpu_roofline_ms", -1,
                         "no hw module in DB (paper: normalize stayed SW)"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
