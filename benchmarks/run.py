"""Benchmark harness — one function per paper table/figure.

Prints ``name,value,derived`` CSV.  Tables map to the paper:
  table1 — processing-time comparison (sequential vs Courier pipeline)
  table2 — per-module evaluation (HLS report → TPU roofline estimate)
  table3 — resource utilization (BRAM/DSP/LUT → VMEM/MXU budget)
  fig4   — traced function call graph incl. I/O data
  roofline — deliverable (g), from the dry-run artifacts when present
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (fig4_callgraph, roofline, table1_pipeline,
                            table2_modules, table3_resources)
    print("name,value,derived")
    for mod in (table1_pipeline, table2_modules, table3_resources,
                fig4_callgraph, roofline):
        try:
            for name, value, derived in mod.run():
                print(f"{name},{value},{str(derived).replace(',', ';')}")
        except Exception as e:
            print(f"{mod.__name__}.ERROR,-1,{type(e).__name__}: "
                  f"{str(e)[:120]}".replace(",", ";"))
            traceback.print_exc(file=sys.stderr)


if __name__ == "__main__":
    main()
