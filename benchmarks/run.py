"""Benchmark harness — one function per paper table/figure.

Prints ``name,value,derived`` CSV.  Tables map to the paper:
  table1 — processing-time comparison (sequential vs Courier pipeline)
  table2 — per-module evaluation (HLS report → TPU roofline estimate)
  table3 — resource utilization (BRAM/DSP/LUT → VMEM/MXU budget)
  fig4   — traced function call graph incl. I/O data
  fusion — fused mega-kernels vs unfused chains (beyond-paper)
  roofline — deliverable (g), from the dry-run artifacts when present

Also writes ``BENCH_pipeline.json`` (machine-readable tokens/s +
bottleneck ms incl. the fused path) so the perf trajectory is tracked
across PRs.

``--smoke``: the fast CI entry point — a 2-token pipeline benchmark plus
the fusion smoke comparison only (pair with ``pytest -m "not slow"``, see
``make bench-smoke``).
"""
from __future__ import annotations

import sys
import traceback


def _emit(mod) -> None:
    try:
        for name, value, derived in mod.run():
            print(f"{name},{value},{str(derived).replace(',', ';')}")
    except Exception as e:
        print(f"{mod.__name__}.ERROR,-1,{type(e).__name__}: "
              f"{str(e)[:120]}".replace(",", ";"))
        traceback.print_exc(file=sys.stderr)


def main() -> None:
    from benchmarks import (analysis, decode, devices, faults,
                            fig4_callgraph, fusion, overload, replan,
                            replicate, roofline, table1_pipeline,
                            table2_modules, table3_resources,
                            trace_pipeline)

    smoke = "--smoke" in sys.argv[1:]
    print("name,value,derived")
    if smoke:
        # 2-token pipeline benchmark + fusion comparison + adaptive-replan
        # smoke, small frames; one measurement feeds both the CSV rows and
        # BENCH_pipeline.json (measured_numbers / *.payload are memoized)
        try:
            m = table1_pipeline.measured_numbers(n_frames=2, size=(64, 96))
            for key in ("sequential_ms", "wavefront_ms", "async_ms"):
                print(f"smoke.{key},{round(m[key], 3)},2-token 64x96 stream")
            f = fusion.payload(smoke=True)["harris_kernel"]
            print(f"smoke.fusion.speedup,{f['speedup']},"
                  f"fused {f['fused_ms']} ms vs chain {f['chain_ms']} ms")
            rep = replan.payload(smoke=True)
            print(f"smoke.replan.recovery,{rep['sim']['recovery']},"
                  f"adaptive {rep['sim']['tps_adaptive']} tps vs static "
                  f"{rep['sim']['tps_static']} tps")
            print(f"smoke.replan.dropped,{rep['hot_swap']['dropped']},"
                  f"{rep['hot_swap']['served']} served; "
                  f"{rep['hot_swap']['recompiles_after_warmup']} recompiles")
            wide = replicate.payload(smoke=True)
            reps = str(wide['sim']['replicas']).replace(",", ";")
            print(f"smoke.replicate.speedup,{wide['sim']['speedup']},"
                  f"replicated {wide['sim']['tps_replicated']} tps vs serial "
                  f"{wide['sim']['tps_serial']} tps; replicas {reps}")
            print(f"smoke.replicate.dropped,{wide['hot_swap']['dropped']},"
                  f"{wide['hot_swap']['served']} served; "
                  f"{wide['hot_swap']['recompiles_after_warmup']} recompiles; "
                  f"{wide['sim']['out_of_order']} out-of-order")
            dev = devices.payload(smoke=True)
            dv = str(dev['sim']['bottleneck_devices']).replace(",", ";")
            print(f"smoke.devices.speedup,{dev['sim']['speedup']},"
                  f"multi-device {dev['sim']['tps_replicated']} tps vs serial "
                  f"{dev['sim']['tps_serial']} tps; devices {dv}")
            print(f"smoke.devices.pinned,{dev['sim']['distinct_devices']},"
                  f"{dev['pinning']['distinct']} distinct committed devices; "
                  f"{dev['hot_swap']['dropped']} dropped across swap")
            flt = faults.payload(smoke=True)   # asserts 0 dropped, >= 0.8x
            print(f"smoke.faults.device_loss,{flt['device_loss']['dropped']},"
                  f"{flt['device_loss']['served']} served; "
                  f"{flt['device_loss']['quarantined']} quarantined; "
                  f"{flt['device_loss']['out_of_order']} out-of-order")
            print(f"smoke.faults.recovery,{flt['device_loss']['recovery']},"
                  f"post-loss {flt['device_loss']['tps_after']} tps vs "
                  f"survivors-only {flt['device_loss']['tps_survivor']} tps")
            print(f"smoke.faults.transient,{flt['transient']['dropped']},"
                  f"{flt['transient']['retries']} retries absorbed "
                  f"{flt['transient']['errors_injected']} injected faults")
            ver = analysis.payload(smoke=True)["verify"]   # asserts < 5%
            print(f"smoke.verify.overhead,{ver['ratio']},"
                  f"verify {ver['verify_ms']} ms vs build {ver['build_ms']} "
                  f"ms over {ver['n_nodes']} nodes")
            trc = trace_pipeline.payload(smoke=True)  # asserts >= 1.5x + parity
            t = trc["transformer"]
            fused = ";".join(t["fused_nodes"]) or "none"
            print(f"smoke.trace.speedup,{t['speedup']},"
                  f"traced transformer async {t['tps_async']} tps vs "
                  f"sequential {t['tps_sequential']} tps; fused {fused}")
            print(f"smoke.trace.results_match,{int(t['results_match'])},"
                  f"{t['captured_inputs']} captured weights; recurrent "
                  f"{int(trc['recurrent']['results_match'])}; serving "
                  f"{int(trc['serving']['results_match'])}")
            ovl = overload.payload(smoke=True)  # asserts goodput + accounting
            hot, ch = ovl["sweep"]["2x"], ovl["chaos"]
            print(f"smoke.overload.goodput,"
                  f"{hot['interactive']['goodput']},"
                  f"interactive {hot['interactive']['served']}/"
                  f"{hot['interactive']['submitted']} at 2x capacity; p99 "
                  f"{hot['interactive']['p99_ms']} ms vs "
                  f"{ovl['deadline_ms']['interactive']} ms deadline")
            print(f"smoke.overload.chaos,"
                  f"{int(not ch['accounted'])},"
                  f"{ch['served']} served; {ch['shed']} shed; "
                  f"{ch['expired']} expired; {ch['failed']} failed of "
                  f"{ch['submitted']}; {ch['out_of_order']} out-of-order; "
                  f"{ch['errors_injected']} faults")
            dec = decode.payload(smoke=True)  # asserts >= 1.5x TTFT + parity
            db, dc = dec["boundary"], dec["continuous"]
            print(f"smoke.decode.ttft,{dec['p50_ttft_improvement']},"
                  f"continuous {dc['p50_ttft_ms']} ms vs boundary "
                  f"{db['p50_ttft_ms']} ms p50 at {dec['load']}x capacity; "
                  f"{dc['seam_joins']} seam joins")
            print(f"smoke.decode.dropped,{db['dropped'] + dc['dropped']},"
                  f"results_match {int(dec['results_match'])}; "
                  f"{db['out_of_order'] + dc['out_of_order']} out-of-order; "
                  f"{db['recompiles_steady'] + dc['recompiles_steady']} "
                  f"recompiles")
            path = table1_pipeline.write_bench_json(smoke=True)
            print(f"smoke.bench_json,0,{path}")
        except Exception as e:
            print(f"smoke.ERROR,-1,{type(e).__name__}: "
                  f"{str(e)[:120]}".replace(",", ";"))
            traceback.print_exc(file=sys.stderr)
            sys.exit(1)
        return
    # replan/replicate/devices/faults/overload last: their thread pools,
    # serving loops, and open-loop load generators are the noisiest
    # neighbors for the wall-clock benchmarks that precede them
    for mod in (table1_pipeline, table2_modules, table3_resources,
                fig4_callgraph, fusion, roofline, analysis, trace_pipeline,
                replan, replicate, devices, faults, overload, decode):
        _emit(mod)
    try:
        path = table1_pipeline.write_bench_json()
        print(f"bench_json,0,{path}")
    except Exception as e:
        print(f"bench_json.ERROR,-1,{type(e).__name__}: "
              f"{str(e)[:120]}".replace(",", ";"))


if __name__ == "__main__":
    main()
