"""Continuous batching on the decode hot path — TTFT under open-loop load.

The serving workload continuous batching exists for: decode-style
sessions where every request is a short stateful step (read the KV
prefix, append one row, emit one token) and new sessions arrive
mid-flight.  The baseline is *request-level* (batch-boundary)
admission — the defect ISSUE 10 names: a cohort of up to ``MAX_BATCH``
sessions decodes all its steps to completion while newly-arrived
sessions queue at the boundary, so a long-running batch makes every
arrival's first token wait out the whole cohort drain.  The continuous
server admits a session the moment it arrives: its step is offered to
the seam of an in-flight group (``executor.try_join``) or dispatched
immediately as a padded open group that later arrivals join — same
executor, same stage fns, only the admission policy differs.

Workload
--------
Sessions arrive open-loop (Poisson, seeded).  Each session decodes
``L_STEPS`` tokens *sequentially* — step ``t+1`` is submitted only after
step ``t`` returned — through a 3-stage host pipeline whose middle stage
is stateful: it reads the session's :class:`KVSlotPool` prefix and
appends one row, so outputs depend on per-session history and any slot
misrouting / double-write / out-of-order retirement shows up as a bitwise
output mismatch between the two modes.  Step 0 is submitted as the
``interactive`` class (TTFT is user-facing), continuation steps as the
``batch`` class — the standard decode-serving split PR 9's priority
queues exist for.  TTFT is measured from the session's scheduled
*arrival*, so the boundary mode's cohort-gate wait is part of it.

Both modes run the *same* shape-polymorphic host stage fns (no jit, so
``compile_count`` is structurally 0 and the zero-steady-state-recompile
gate is a real invariant, not vacuous: joins reuse the admitted group's
padded buffers).  Capacity is anchored closed-loop: the measured serial
(singleton-group) step throughput of the same executor plan; the open
loop then offers ``LOAD * capacity`` steps/s.

Acceptance (asserted here and in ``test_bench_schema.py``):
  * p50 TTFT improves >= 1.5x (continuous vs batch-boundary) at 0.8x
    capacity,
  * zero drops (every submitted step served),
  * zero out-of-order retirements,
  * zero steady-state recompiles,
  * outputs bitwise identical between the two modes,
  * the seam was actually exercised (>= 1 in-flight join),
  * the slot arena ends the run leak-free (``check_no_leaks``).
"""
from __future__ import annotations

import threading
import time
from collections import deque

import numpy as np

from repro.core.executor import PipelineExecutor
from repro.launch.serve import RequestQueueServer
from repro.runtime.kvstate import KVSlotPool

IO = 8             # per-step token width
L_STEPS = 4        # decode steps per session (step 0 == first token)
STAGE_MS = 2.0     # per-group service time of each of the 3 stages
MAX_BATCH = 4      # cohort width == batcher width == microbatch bucket
MAX_WAIT_MS = 8.0  # dynamic-batching window (~ one pipeline service time)
LOAD = 0.8         # offered steps/s as a fraction of measured capacity


# --------------------------------------------------------------------------- #
# The decode pipeline: 3 host stages, stateful KV middle
# --------------------------------------------------------------------------- #
def make_stage_fns(pool: KVSlotPool) -> list:
    """Env-dict stage fns, shape-polymorphic over ``[IO]`` and ``[B, IO]``
    so the same callables serve singleton and stacked groups (they are
    passed as both ``stage_fns`` and ``batched_fns`` — nothing jits, so
    the sleep that models the stage's service time is never traced away
    and the recompile gate measures the real serving path)."""

    def pre(env):
        time.sleep(STAGE_MS / 1e3)
        x = np.asarray(env["x"], dtype=np.float32)
        return {"x": x + 1.0, "slot": env["slot"]}

    def kv(env):
        # stateful: read the session prefix, append this step's row.
        # Per-row math so stacked [B, IO] and singleton [IO] groups are
        # bitwise identical; slot -1 (padding / dead seat) reads empty
        # and appends nowhere, so padded groups never touch live state.
        time.sleep(STAGE_MS / 1e3)
        x = np.asarray(env["x"], dtype=np.float32)
        x2 = x if x.ndim == 2 else x[None]
        slots = np.atleast_1d(np.asarray(env["slot"])).astype(np.int64)
        y = np.empty_like(x2)
        for i in range(x2.shape[0]):
            sid = int(slots[i])
            hist = pool.read(sid)["k"]            # [t, IO] prefix so far
            pool.append(sid, k=x2[i])
            y[i] = x2[i] + hist.sum(axis=0, dtype=np.float32)
        return {"x": y if x.ndim == 2 else y[0]}

    def post(env):
        time.sleep(STAGE_MS / 1e3)
        x = np.asarray(env["x"], dtype=np.float32)
        return {"y": x * 0.5}

    pre.__name__, kv.__name__, post.__name__ = "pre", "kv", "post"
    return [pre, kv, post]


def make_executor(pool: KVSlotPool, *, open_groups: bool,
                  microbatch: int = MAX_BATCH) -> PipelineExecutor:
    fns = make_stage_fns(pool)
    kw: dict = {}
    if microbatch > 1:
        kw.update(microbatch=microbatch, pad_microbatches=True,
                  buckets=(microbatch,), batched_fns=fns,
                  pad_token=(np.zeros(IO, np.float32), -1))
    # a deep token pool: submit_many must never block the batcher during
    # an arrival burst — a stalled batcher cannot offer seam joins, which
    # is exactly when the seam matters most
    return PipelineExecutor(
        fns, ["x", "slot"], ["y"], max_in_flight=64,
        replicas=[1, 1, 1], open_groups=open_groups, **kw)


def _measure_capacity(n_tokens: int = 48) -> float:
    """Closed-loop serial capacity anchor: steps/s of the same 3-stage
    plan run as singleton groups (dead slot -1, so no state touched) —
    the pipeline's bottleneck-bound decode rate without batching."""
    pool = KVSlotPool(1, L_STEPS, {"k": (IO,)})
    ex = make_executor(pool, open_groups=False, microbatch=1)
    tok = (np.zeros(IO, np.float32), -1)
    ex.warmup(*tok)
    t0 = time.perf_counter()
    ex.run([tok] * n_tokens)
    dt = time.perf_counter() - t0
    ex.close()
    return n_tokens / dt


# --------------------------------------------------------------------------- #
# Open-loop session driver
# --------------------------------------------------------------------------- #
def poisson_arrivals(rate_per_s: float, n: int, seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return np.cumsum(rng.exponential(1.0 / rate_per_s, size=n))


def session_inputs(n_sessions: int, seed: int) -> np.ndarray:
    """[n_sessions, L_STEPS, IO] float32 per-step inputs, shared by both
    modes so outputs are comparable bitwise."""
    rng = np.random.default_rng(seed)
    return rng.standard_normal((n_sessions, L_STEPS, IO)).astype(np.float32)


def _drive_sessions(srv: RequestQueueServer, pool: KVSlotPool,
                    arrivals: np.ndarray, xs: np.ndarray,
                    cohort: int | None = None) -> dict:
    """Run sessions against the server; within a session, step t+1 goes
    in only after step t resolved (decode is sequential).  Step 0 is
    ``interactive`` (TTFT), later steps ``batch``.  The last step
    releases the session's KV slot through the server's ``on_finish``
    hook — the documented place per-request state is returned on every
    terminal outcome.

    ``cohort=None`` is continuous admission: a session's first step is
    submitted the moment it arrives.  ``cohort=k`` is request-level
    (batch-boundary) admission: up to ``k`` sessions decode together to
    completion while later arrivals queue at the boundary — the next
    cohort is admitted only once the running one fully drained.  TTFT is
    ``t_done - scheduled arrival`` either way, so the gate wait counts.
    """
    n = len(arrivals)
    ttft: list = [None] * n
    outs: list = [[None] * L_STEPS for _ in range(n)]
    slots: list = [None] * n
    step = [0] * n
    errors: list = []
    active: dict = {}
    waiting: deque = deque()
    in_cohort: set = set()
    rel_lock = threading.Lock()

    def _release(sess: int) -> None:
        with rel_lock:
            s, slots[sess] = slots[sess], None
        if s is not None:
            pool.free(s)

    def _submit(sess: int) -> None:
        t = step[sess]
        last = t == L_STEPS - 1
        active[sess] = srv.submit(
            xs[sess, t], slots[sess],
            priority="interactive" if t == 0 else "batch",
            on_finish=(lambda _r, s=sess: _release(s)) if last else None)

    def _admit(sess: int) -> None:
        slots[sess] = pool.alloc()
        _submit(sess)

    t0 = time.perf_counter()
    nxt = 0
    while nxt < n or active or waiting:
        now = time.perf_counter() - t0
        while nxt < n and arrivals[nxt] <= now:
            if cohort is None:
                _admit(nxt)
            else:
                waiting.append(nxt)
            nxt += 1
        if cohort is not None and not in_cohort and waiting:
            # batch boundary: the previous cohort fully drained
            while waiting and len(in_cohort) < cohort:
                s = waiting.popleft()
                in_cohort.add(s)
                _admit(s)
        progressed = False
        for sess, r in list(active.items()):
            if not r._event.is_set():     # resolved-yet poll (non-blocking)
                continue
            progressed = True
            del active[sess]
            t = step[sess]
            try:
                y = r.wait(0)
            except BaseException as e:    # recorded; asserted empty below
                errors.append((sess, t, repr(e)))
                _release(sess)
                in_cohort.discard(sess)
                continue
            outs[sess][t] = np.asarray(y)
            if t == 0:
                ttft[sess] = (r.t_done - (t0 + arrivals[sess])) * 1e3
            step[sess] += 1
            if step[sess] < L_STEPS:
                _submit(sess)
            else:
                in_cohort.discard(sess)
        if not progressed:
            time.sleep(0.0003)
    return {"ttft_ms": ttft, "outs": outs, "errors": errors}


def _run_mode(continuous: bool, arrivals: np.ndarray,
              xs: np.ndarray, n_slots: int) -> dict:
    pool = KVSlotPool(n_slots, L_STEPS, {"k": (IO,)})
    ex = make_executor(pool, open_groups=continuous)
    ex.warmup(np.zeros(IO, np.float32), -1)
    compiles_warm = ex.compile_count()
    srv = RequestQueueServer(ex, max_batch=MAX_BATCH,
                             max_wait_ms=MAX_WAIT_MS, queue_depth=512,
                             continuous=continuous)
    with srv:
        drv = _drive_sessions(srv, pool, arrivals, xs,
                              cohort=None if continuous else MAX_BATCH)
    st = srv.stats()
    xst = ex.stats()
    compiles_run = ex.compile_count() - compiles_warm
    ex.close()
    pool.check_no_leaks()                 # every session freed its slot
    ttft = [t for t in drv["ttft_ms"] if t is not None]
    return {
        "p50_ttft_ms": round(float(np.percentile(ttft, 50)), 3),
        "p95_ttft_ms": round(float(np.percentile(ttft, 95)), 3),
        "outs": drv["outs"],
        "errors": drv["errors"],
        "submitted": st["submitted"],
        "served": st["requests_served"],
        "dropped": st["shed"] + st["expired"] + st["failed"],
        "seam_joins": st["seam_joins"],
        "release_errors": st["release_errors"],
        "out_of_order": xst.out_of_order_retired,
        "recompiles_steady": compiles_run,
        "slot_stats": pool.stats(),
    }


# --------------------------------------------------------------------------- #
# Benchmark entry points
# --------------------------------------------------------------------------- #
_payload_cache: dict = {}


def payload(smoke: bool = False) -> dict:
    key = bool(smoke)
    if key in _payload_cache:
        return _payload_cache[key]
    n_sessions = 48 if smoke else 160
    capacity = _measure_capacity(24 if smoke else 48)
    step_rate = LOAD * capacity           # offered decode steps/s
    session_rate = step_rate / L_STEPS
    arrivals = poisson_arrivals(session_rate, n_sessions, seed=7)
    xs = session_inputs(n_sessions, seed=11)

    boundary = _run_mode(False, arrivals, xs, n_slots=64)
    continuous = _run_mode(True, arrivals, xs, n_slots=64)

    match = all(
        a is not None and b is not None and np.array_equal(a, b)
        for sa, sb in zip(boundary.pop("outs"), continuous.pop("outs"))
        for a, b in zip(sa, sb))
    improvement = round(
        boundary["p50_ttft_ms"] / max(continuous["p50_ttft_ms"], 1e-9), 3)
    out = {
        "bench": "decode", "smoke": key,
        "n_sessions": n_sessions, "steps_per_session": L_STEPS,
        "capacity_steps_per_s": round(capacity, 2),
        "offered_steps_per_s": round(step_rate, 2),
        "load": LOAD,
        "p50_ttft_improvement": improvement,
        "results_match": match,
        "boundary": boundary,
        "continuous": continuous,
    }
    total = n_sessions * L_STEPS
    for name, m in (("boundary", boundary), ("continuous", continuous)):
        assert not m["errors"], f"{name}: request errors {m['errors'][:3]}"
        assert m["submitted"] == total and m["served"] == total, \
            f"{name}: served {m['served']}/{m['submitted']} of {total}"
        assert m["dropped"] == 0, f"{name}: dropped {m['dropped']}"
        assert m["out_of_order"] == 0, \
            f"{name}: {m['out_of_order']} out-of-order retirements"
        assert m["recompiles_steady"] == 0, \
            f"{name}: {m['recompiles_steady']} steady-state recompiles"
        assert m["release_errors"] == 0, \
            f"{name}: {m['release_errors']} on_finish hook errors"
        m.pop("errors")
    assert continuous["seam_joins"] > 0, \
        "continuous mode never exercised the join seam"
    assert match, "decode outputs differ between boundary and continuous"
    assert improvement >= 1.5, (
        f"p50 TTFT improvement {improvement}x < 1.5x "
        f"(boundary {boundary['p50_ttft_ms']} ms vs "
        f"continuous {continuous['p50_ttft_ms']} ms)")
    _payload_cache[key] = out
    return out


def run(smoke: bool = False) -> list[tuple[str, float, str]]:
    p = payload(smoke=smoke)
    b, c = p["boundary"], p["continuous"]
    return [
        ("decode.p50_ttft_improvement", p["p50_ttft_improvement"],
         f"boundary {b['p50_ttft_ms']} ms vs continuous "
         f"{c['p50_ttft_ms']} ms at {p['load']}x capacity "
         f"({p['offered_steps_per_s']} steps/s offered)"),
        ("decode.continuous.p50_ttft_ms", c["p50_ttft_ms"],
         f"p95 {c['p95_ttft_ms']} ms; {c['seam_joins']} seam joins"),
        ("decode.boundary.p50_ttft_ms", b["p50_ttft_ms"],
         f"p95 {b['p95_ttft_ms']} ms; cohort width {MAX_BATCH}"),
        ("decode.results_match", int(p["results_match"]),
         f"{p['n_sessions']} sessions x {p['steps_per_session']} steps "
         "bitwise identical across modes"),
        ("decode.dropped", b["dropped"] + c["dropped"],
         f"{b['served']}+{c['served']} served; "
         f"{b['out_of_order']}+{c['out_of_order']} out-of-order; "
         f"{b['recompiles_steady']}+{c['recompiles_steady']} recompiles"),
    ]


if __name__ == "__main__":
    import sys
    for name, value, derived in run(smoke="--smoke" in sys.argv[1:]):
        print(f"{name},{value},{str(derived).replace(',', ';')}")
