"""Multi-device stage replication benchmark — N replicas on N devices.

PR 4 widened a bottleneck stage across *host threads*; the structured
placement layer maps those replicas onto genuine device parallelism: the
planner consumes a :class:`~repro.core.placement.DeviceInventory`, pins
each replica of a widened stage to its own chip/core, and the executor
``jax.device_put``\\ s every replica's token groups onto its device.  This
benchmark exercises the whole device-pinned path on a **forced 4-host-
device** jax (``XLA_FLAGS=--xla_force_host_platform_device_count=4``,
``JAX_PLATFORMS=cpu``) in a subprocess, since the parent process's jax is
already initialized single-device:

1. **Pinning** — a stage replicated 4-wide over devices ``[0,1,2,3]``:
   token ``i`` is served by replica ``i % 4``, so the committed result
   arrays' ``.devices()`` must cycle through all four devices (the
   acceptance audit: each replica on a *distinct* device).
2. **Simulation** — a 3-function chain with ONE dominant stage (a fixed
   per-call latency around real jnp device work — the accelerator-module
   stand-in).  The serial plan is measured against the inventory-widened
   plan (dominant stage 4-wide on 4 devices).  Acceptance: **>= 1.5x
   tokens/s**, zero out-of-order retirements, cross-device stage
   boundaries charged their transfer cost.
3. **Hot-swap** — mid-stream serial → multi-device executor swap behind
   :class:`~repro.launch.serve.RequestQueueServer`: zero dropped requests.

Feeds the ``devices`` section of ``BENCH_pipeline.json``; the slow split
of ``tests/test_devices.py`` asserts the same payload.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
N_DEVICES = 4
# One dominant device-backed stage.  The dominant latency is deliberately
# large relative to jax's per-op dispatch overhead on committed (non-
# default-device) arrays — on a small shared host that slow-path dispatch
# costs ~1-5 ms per op under thread contention, which the serial baseline
# (default device, fast path) never pays; a 60 ms module keeps the
# comparison about device parallelism, not dispatch-path asymmetry.
STAGE_MS = [2.0, 60.0, 2.0]
WORKER_BUDGET = 6                        # -> replicas [1, 4, 1]
IO_SHAPE = (64,)                         # small tokens: staging off the path
MARKER = "DEVICES-JSON:"


# --------------------------------------------------------------------------- #
# Child (runs under the forced multi-device jax)
# --------------------------------------------------------------------------- #
def _make_db_and_ir():
    import time

    import jax.numpy as jnp

    from repro.core import ModuleDatabase, linear_ir

    keys = [f"f{i}" for i in range(len(STAGE_MS))]
    delays = dict(zip(keys, STAGE_MS))
    db = ModuleDatabase("devices")
    for k in keys:
        def impl(x, _k=k):
            # fixed per-call latency (the predefined accelerator module's
            # service time) around real jnp work committed to whatever
            # device the executor staged ``x`` onto
            time.sleep(delays[_k] / 1e3)
            return jnp.asarray(x) + 1.0
        impl.__name__ = k
        db.register(k, software=impl)
    ir = linear_ir("devices", keys, list(STAGE_MS), io_shape=IO_SHAPE)
    return db, ir


def _tps(executor, tokens) -> float:
    import time

    t0 = time.perf_counter()
    executor.run(tokens)
    return len(tokens) / max(time.perf_counter() - t0, 1e-9)


def _pinning_check() -> dict:
    """Replicated stage over explicit devices: results commit per replica."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import DeviceInventory
    from repro.core.executor import PipelineExecutor

    inv = DeviceInventory.detect()
    n = len(inv)
    ex = PipelineExecutor([lambda env: {"y": env["x"] * 2.0}], ["x"], ["y"],
                          replicas=[n], devices=[list(range(n))],
                          inventory=inv, max_in_flight=2 * n)
    handles = ex.submit_many([(jnp.full((8,), float(i)),)
                              for i in range(2 * n)])
    seen: list[int] = []
    for i, h in enumerate(handles):
        out = h.result()
        np.testing.assert_allclose(np.asarray(out), float(i) * 2.0)
        (dev,) = out.devices()               # committed, exactly one device
        assert dev is inv.jax_device(i % n), \
            f"token {i} retired on {dev}, expected replica {i % n}'s device"
        seen.append(int(dev.id))
    ooo = ex.stats().out_of_order_retired
    ex.close()
    return {"result_devices": seen, "distinct": len(set(seen)),
            "out_of_order": int(ooo)}


def _simulate(n_tokens: int) -> dict:
    import numpy as np

    from repro.core import DeviceInventory, StageProfiler, transfer_ms
    from repro.runtime import ElasticPlanner

    db, ir = _make_db_and_ir()
    inv = DeviceInventory.detect()
    planner = ElasticPlanner(ir, db=db, inventory=inv)
    n = len(STAGE_MS)
    toks = [np.full(IO_SHAPE, float(i), np.float32) for i in range(n_tokens)]

    # serial baseline: worker_budget == n_stages -> no widening
    ex_serial, _ = planner.executor_for(n, jit=False, stage_workers=True,
                                        worker_budget=n,
                                        max_in_flight=2 * n + 2)
    tps_serial = _tps(ex_serial, toks)
    ex_serial.close()

    prof = StageProfiler(n, min_samples=1)
    ex_rep, rebuilt = planner.executor_for(
        n, jit=False, worker_budget=WORKER_BUDGET, profiler=prof,
        max_in_flight=2 * WORKER_BUDGET + 2)
    assert rebuilt
    plan = planner.current_plan
    wide = max(plan.stages, key=lambda s: s.est_time_ms)
    tps_rep = _tps(ex_rep, toks)
    st = ex_rep.stats()
    snap = prof.snapshot()
    wide_idx = plan.stages.index(wide)
    devices_profiled = len(snap["per_stage"][wide_idx].get("devices", {}))
    ex_rep.close()

    # cross-device boundary transfer accounting: every stage whose device
    # set differs from its predecessor's is charged its comm bytes
    xfer_ok = True
    for a, b in zip(plan.stages[:-1], plan.stages[1:]):
        if set(a.devices) != set(b.devices) and b.comm_in_bytes > 0:
            want = transfer_ms(b.comm_in_bytes,
                               inv.device_class(0).xfer_bw)
            xfer_ok &= abs(b.xfer_in_ms - want) < 1e-9
        else:
            xfer_ok &= b.xfer_in_ms == 0.0
    return {
        "n_devices": len(inv), "stage_ms": list(STAGE_MS),
        "worker_budget": WORKER_BUDGET, "n_tokens": n_tokens,
        "tps_serial": round(tps_serial, 2),
        "tps_replicated": round(tps_rep, 2),
        "speedup": round(tps_rep / max(tps_serial, 1e-9), 3),
        "replicas": list(plan.replicas),
        "bottleneck_devices": list(wide.devices),
        "distinct_devices": len(set(wide.devices)),
        "devices_profiled": int(devices_profiled),
        "xfer_accounted": bool(xfer_ok),
        "out_of_order": int(st.out_of_order_retired),
    }


def _hot_swap(n_requests: int) -> dict:
    import numpy as np

    from repro.core import DeviceInventory
    from repro.launch.serve import RequestQueueServer
    from repro.runtime import ElasticPlanner

    db, ir = _make_db_and_ir()
    inv = DeviceInventory.detect()
    planner = ElasticPlanner(ir, db=db, inventory=inv)
    n = len(STAGE_MS)
    frames = [np.full(IO_SHAPE, float(i), np.float32)
              for i in range(n_requests)]
    ex_serial, _ = planner.executor_for(n, jit=False, stage_workers=True,
                                        worker_budget=n,
                                        max_in_flight=2 * n + 2)
    with RequestQueueServer(ex_serial, max_batch=1, max_wait_ms=1.0) as srv:
        reqs = [srv.submit(f) for f in frames[: n_requests // 2]]
        ex_rep, _ = planner.executor_for(
            n, jit=False, worker_budget=WORKER_BUDGET,
            max_in_flight=2 * WORKER_BUDGET + 2)
        srv.swap_executor(ex_rep)
        reqs += [srv.submit(f) for f in frames[n_requests // 2:]]
        served = dropped = 0
        for i, r in enumerate(reqs):
            try:
                out = r.wait(timeout=300.0)
                np.testing.assert_allclose(np.asarray(out).ravel()[0],
                                           float(i) + n)
                served += 1
            except Exception:
                dropped += 1
    ooo = (ex_serial.stats().out_of_order_retired
           + ex_rep.stats().out_of_order_retired)
    ex_rep.close()
    ex_serial.close()
    return {"requests": n_requests, "served": served, "dropped": dropped,
            "swaps": srv.swaps, "out_of_order": int(ooo)}


def _child_main(smoke: bool) -> None:
    import jax

    assert len(jax.devices()) == N_DEVICES, \
        f"forced host device count not applied: {jax.devices()}"
    result = {
        "pinning": _pinning_check(),
        "sim": _simulate(n_tokens=16 if smoke else 32),
        "hot_swap": _hot_swap(n_requests=12 if smoke else 24),
    }
    print(MARKER + json.dumps(result))


# --------------------------------------------------------------------------- #
# Parent (spawns the forced multi-device child)
# --------------------------------------------------------------------------- #
def _spawn(smoke: bool) -> dict:
    env = dict(os.environ)
    env["JAX_PLATFORMS"] = "cpu"
    flags = env.get("XLA_FLAGS", "")
    env["XLA_FLAGS"] = (flags + " " if flags else "") + \
        f"--xla_force_host_platform_device_count={N_DEVICES}"
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(ROOT, "src"), ROOT]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    cmd = [sys.executable, "-m", "benchmarks.devices", "--child"]
    if smoke:
        cmd.append("--smoke")
    r = subprocess.run(cmd, capture_output=True, text=True, timeout=600,
                       env=env, cwd=ROOT)
    for line in r.stdout.splitlines():
        if line.startswith(MARKER):
            return json.loads(line[len(MARKER):])
    raise RuntimeError(
        f"multi-device child emitted no payload (exit {r.returncode}):\n"
        f"{r.stdout[-1000:]}\n{r.stderr[-2000:]}")


_payload_cache: dict = {}


def payload(smoke: bool = False) -> dict:
    key = bool(smoke)
    if key not in _payload_cache:
        _payload_cache[key] = _spawn(smoke)
    return _payload_cache[key]


def run(smoke: bool = False) -> list:
    p = payload(smoke=smoke)
    sim, pin, hs = p["sim"], p["pinning"], p["hot_swap"]
    return [
        ("devices.pinning.distinct", pin["distinct"],
         f"result arrays committed across {pin['distinct']} devices "
         f"(acceptance {N_DEVICES})"),
        ("devices.sim.tps_serial", sim["tps_serial"],
         f"{len(sim['stage_ms'])} stages; dominant "
         f"{max(sim['stage_ms'])} ms; serial on 1 device"),
        ("devices.sim.tps_replicated", sim["tps_replicated"],
         f"replicas {sim['replicas']} on devices "
         f"{sim['bottleneck_devices']}"),
        ("devices.sim.speedup", sim["speedup"],
         "multi-device vs serial tokens/s (acceptance >= 1.5)"),
        ("devices.sim.distinct_devices", sim["distinct_devices"],
         "distinct devices pinned under the bottleneck stage"),
        ("devices.sim.out_of_order", sim["out_of_order"],
         "retirements out of submission order (acceptance 0)"),
        ("devices.hot_swap.dropped", hs["dropped"],
         f"{hs['served']}/{hs['requests']} served across "
         f"{hs['swaps']} serial->multi-device swap(s)"),
    ]


def main() -> None:
    argv = sys.argv[1:]
    if "--child" in argv:
        _child_main(smoke="--smoke" in argv)
        return
    for row in run(smoke="--smoke" in argv):
        print(",".join(str(x) for x in row))


if __name__ == "__main__":
    main()
