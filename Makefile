# Courier-TPU — common entry points.
# PYTHONPATH covers src/ (the package) and . (the benchmarks package).
PY      ?= python
PYPATH  := src:.

.PHONY: test test-fast bench bench-smoke lint ci clean-autotune

test:            ## full tier-1 suite (incl. slow markers)
	PYTHONPATH=$(PYPATH) $(PY) -m pytest -x -q

test-fast:       ## fast split (excludes @slow: subprocess/multi-device/soak tests)
	PYTHONPATH=$(PYPATH) $(PY) -m pytest -q -m "not slow"

bench:           ## all paper tables + fusion + replan + replicate + faults benchmarks; writes BENCH_pipeline.json
	PYTHONPATH=$(PYPATH) $(PY) benchmarks/run.py

bench-smoke:     ## 2-token pipeline + fusion + replan + replicate + devices + faults (device-loss recovery) smoke benchmark
	PYTHONPATH=$(PYPATH) $(PY) benchmarks/run.py --smoke

lint:            ## concurrency/style lint over the package (repro.analysis.lint)
	PYTHONPATH=$(PYPATH) $(PY) -m repro.analysis lint src/repro

ci: test-fast bench-smoke lint  ## single CI entry point: fast tests, smoke benchmark, lint

clean-autotune:  ## drop the persistent block-size autotune cache
	PYTHONPATH=$(PYPATH) $(PY) -c "from repro.kernels.autotune import \
	default_cache; default_cache.clear(); print('cleared', default_cache.path)"
