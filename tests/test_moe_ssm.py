"""MoE routing invariants + recurrence-core equivalences (hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.models.moe import moe_apply, moe_init
from repro.models.rwkv import rwkv_init, rwkv_init_state, time_mix
from repro.models.ssm import ssm_apply, ssm_init, ssm_init_state

KEY = jax.random.PRNGKey(3)


# --------------------------------------------------------------------------- #
# MoE
# --------------------------------------------------------------------------- #
@settings(max_examples=15, deadline=None)
@given(st.integers(min_value=1, max_value=3),
       st.integers(min_value=2, max_value=8),
       st.sampled_from([4, 8]))
def test_moe_router_invariants(B, T, E):
    d, ff, k = 16, 32, 2
    p = moe_init(KEY, d, ff, E, jnp.float32)
    x = jax.random.normal(KEY, (B, T, d))
    y, aux = moe_apply(p, x, top_k=k, capacity_factor=8.0)   # no drops
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()
    assert float(aux["dropped_frac"]) == pytest.approx(0.0, abs=1e-6)
    # E·Σ me·ce ≈ 1 at balance; small samples fluctuate slightly below
    assert float(aux["load_balance_loss"]) >= 0.85


def test_moe_capacity_drops_tokens():
    d, ff, E, k = 8, 16, 4, 2
    p = moe_init(KEY, d, ff, E, jnp.float32)
    x = jax.random.normal(KEY, (2, 32, d))
    _, aux = moe_apply(p, x, top_k=k, capacity_factor=0.25)
    assert float(aux["dropped_frac"]) > 0.0


def test_moe_single_expert_equals_dense_ffn():
    """E=1, top-1, generous capacity → exactly the expert's SwiGLU."""
    d, ff = 8, 16
    p = moe_init(KEY, d, ff, 1, jnp.float32)
    x = jax.random.normal(KEY, (2, 8, d))
    y, _ = moe_apply(p, x, top_k=1, capacity_factor=4.0)
    gu = jnp.einsum("btd,dkf->btkf", x, p["wi"][0])
    want = jnp.einsum("btf,fd->btd",
                      jax.nn.silu(gu[:, :, 0]) * gu[:, :, 1], p["wo"][0])
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


def test_moe_grouping_preserves_semantics():
    """G groups == 1 group when capacity is generous (same expert math)."""
    d, ff, E, k = 8, 16, 4, 2
    p = moe_init(KEY, d, ff, E, jnp.float32)
    x = jax.random.normal(KEY, (4, 8, d))
    y1, _ = moe_apply(p, x, top_k=k, capacity_factor=8.0, n_groups=1)
    y2, _ = moe_apply(p, x, top_k=k, capacity_factor=8.0, n_groups=4)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-5)


def test_moe_einsum_dispatch_equals_sort_dispatch():
    """The GShard-style all-einsum path (EXPERIMENTS §Perf A1) is exact:
    same outputs, same drops, same gradients as the sort-based path."""
    d, ff, E, k = 8, 16, 4, 2
    p = moe_init(KEY, d, ff, E, jnp.float32)
    x = jax.random.normal(KEY, (2, 64, d))
    # no-drop: identical outputs
    y1, a1 = moe_apply(p, x, top_k=k, capacity_factor=8.0,
                       n_groups=2, mode="sort")
    y2, a2 = moe_apply(p, x, top_k=k, capacity_factor=8.0,
                       n_groups=2, mode="einsum")
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-4, atol=2e-5)
    # heavy-drop: capacity per expert is identical, so the dropped token
    # FRACTION must match even though the two paths break ties differently
    # (sort: token-priority; einsum: GShard k-slot priority)
    _, a1 = moe_apply(p, x, top_k=k, capacity_factor=0.5,
                      n_groups=2, mode="sort")
    _, a2 = moe_apply(p, x, top_k=k, capacity_factor=0.5,
                      n_groups=2, mode="einsum")
    assert float(a1["dropped_frac"]) == pytest.approx(
        float(a2["dropped_frac"]), abs=1e-6)

    g1 = jax.grad(lambda p: jnp.sum(
        moe_apply(p, x, k, 8.0, 2, "sort")[0] ** 2))(p)
    g2 = jax.grad(lambda p: jnp.sum(
        moe_apply(p, x, k, 8.0, 2, "einsum")[0] ** 2))(p)
    for l1, l2 in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                                   rtol=1e-3, atol=1e-4)


# --------------------------------------------------------------------------- #
# SSM: full-sequence scan == step-by-step decode
# --------------------------------------------------------------------------- #
def test_ssm_prefill_equals_stepwise():
    d, N, K = 16, 4, 4
    p = ssm_init(KEY, d, N, K, jnp.float32)
    x = jax.random.normal(KEY, (2, 6, d)) * 0.3
    y_full, st_full = ssm_apply(p, x)

    st = ssm_init_state(2, d, N, K, jnp.float32)
    ys = []
    for t in range(6):
        y_t, st = ssm_apply(p, x[:, t:t + 1], state=st)
        ys.append(y_t)
    y_step = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_step),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st_full["h"]), np.asarray(st["h"]),
                               rtol=2e-4, atol=2e-4)


# --------------------------------------------------------------------------- #
# RWKV: chunked-remat scan == plain recurrence; decode == prefill
# --------------------------------------------------------------------------- #
def test_rwkv_time_mix_stepwise_equivalence():
    d = 128                       # 2 heads of 64
    p = rwkv_init(KEY, d, 4 * d, jnp.float32)
    x = jax.random.normal(KEY, (1, 5, d)) * 0.2
    S0 = jnp.zeros((1, d // 64, 64, 64), jnp.float32)
    y_full, S_full = time_mix(p, x, S0, None)

    S = S0
    last = jnp.zeros((1, d), jnp.float32)
    ys = []
    for t in range(5):
        y_t, S = time_mix(p, x[:, t:t + 1], S, last)
        last = x[:, t]
        ys.append(y_t)
    y_step = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_step),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(S_full), np.asarray(S),
                               rtol=2e-4, atol=2e-4)


def test_chunked_scan_matches_plain():
    from repro.models.scan_utils import chunked_scan

    def body(c, x):
        c = c * 0.9 + x
        return c, c

    xs = jax.random.normal(KEY, (512, 8))
    c1, y1 = jax.lax.scan(body, jnp.zeros(8), xs)
    c2, y2 = chunked_scan(body, jnp.zeros(8), xs, chunk=256)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(c1), np.asarray(c2), rtol=1e-6)

    # grads flow identically through the chunked remat
    f1 = lambda x: jnp.sum(jax.lax.scan(body, jnp.zeros(8), x)[1] ** 2)
    f2 = lambda x: jnp.sum(chunked_scan(body, jnp.zeros(8), x, chunk=128)[1] ** 2)
    np.testing.assert_allclose(np.asarray(jax.grad(f1)(xs)),
                               np.asarray(jax.grad(f2)(xs)), rtol=1e-5)
