"""Test bootstrap: src/ on sys.path + a deterministic `hypothesis` shim.

The tier-1 suite must collect and run on machines without the `hypothesis`
package (the container image does not ship it).  Rather than skipping the
property tests wholesale, this conftest installs a tiny deterministic
stand-in module into ``sys.modules`` *before* the test modules import it:

* ``@given(*strategies)`` re-runs the test body over a fixed-seed sample of
  each strategy (default 8 examples, override with
  ``HYPOTHESIS_SHIM_MAX_EXAMPLES``),
* ``@settings(max_examples=..., deadline=...)`` caps the example count,
* ``strategies.integers/floats/lists/sampled_from/booleans/just/tuples``
  cover everything the suite uses.

When the real `hypothesis` is installed it is used untouched.
"""
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Keep the block-size autotuner's persistent cache out of the user's home
# directory during test runs (tests that care pass their own tmp cache).
os.environ.setdefault("REPRO_AUTOTUNE_CACHE",
                      tempfile.mkdtemp(prefix="repro-autotune-test-"))


def _install_hypothesis_shim() -> None:
    import functools
    import inspect
    import random
    import types

    SEED = 0xC0FFEE
    CAP = int(os.environ.get("HYPOTHESIS_SHIM_MAX_EXAMPLES", "8"))

    class _Strategy:
        def __init__(self, sample):
            self._sample = sample

        def example(self, rng):
            return self._sample(rng)

    def integers(min_value=0, max_value=2**16):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    def floats(min_value=0.0, max_value=1.0, allow_nan=False,
               allow_infinity=False, **_):
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    def booleans():
        return _Strategy(lambda rng: bool(rng.getrandbits(1)))

    def just(value):
        return _Strategy(lambda rng: value)

    def sampled_from(elements):
        elements = list(elements)
        return _Strategy(lambda rng: rng.choice(elements))

    def lists(elements, min_size=0, max_size=10, **_):
        def sample(rng):
            n = rng.randint(min_size, max_size)
            return [elements.example(rng) for _ in range(n)]
        return _Strategy(sample)

    def tuples(*strategies):
        return _Strategy(lambda rng: tuple(s.example(rng) for s in strategies))

    def given(*arg_strategies, **kw_strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = min(getattr(wrapper, "_shim_max_examples", CAP), CAP)
                rng = random.Random(SEED)
                for _ in range(max(1, n)):
                    extra = [s.example(rng) for s in arg_strategies]
                    kws = {k: s.example(rng) for k, s in kw_strategies.items()}
                    fn(*args, *extra, **kwargs, **kws)
            # pytest must NOT see the wrapped fn's params as fixtures: the
            # strategies fill them all, so expose a parameterless signature.
            del wrapper.__wrapped__
            wrapper.__signature__ = inspect.Signature()
            wrapper._shim_max_examples = CAP
            return wrapper
        return deco

    def settings(max_examples=CAP, deadline=None, **_):
        def deco(fn):
            fn._shim_max_examples = max_examples
            return fn
        return deco

    st_mod = types.ModuleType("hypothesis.strategies")
    for f in (integers, floats, booleans, just, sampled_from, lists, tuples):
        setattr(st_mod, f.__name__, f)

    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    mod.strategies = st_mod
    mod.__shim__ = True
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st_mod


try:
    import hypothesis  # noqa: F401  (real package wins when present)
except ModuleNotFoundError:
    _install_hypothesis_shim()
