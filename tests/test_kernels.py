"""Per-kernel shape/dtype sweeps vs the ref.py oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.harris import convert_scale_abs, corner_harris, cvt_color
from repro.kernels.rmsnorm import rmsnorm
from repro.models import harris as mh

KEY = jax.random.PRNGKey(7)


@pytest.mark.parametrize("B,T,H,hd,M", [
    (1, 128, 1, 64, 128),
    (2, 256, 4, 64, 256),
    (1, 512, 2, 128, 512),
    (2, 128, 4, 32, 384),         # cross-attn style T != M
])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 64), (False, 0)])
@pytest.mark.slow
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, T, H, hd, M, causal, window, dtype):
    if not causal and T != M:
        pass        # valid: cross attention
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, T, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, M, H, hd), dtype)
    v = jax.random.normal(ks[2], (B, M, H, hd), dtype)
    o = flash_attention(q, k, v, causal, window, 128, 128, True)
    r = ref.reference_attention(q, k, v, causal, window)
    tol = 2.5e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32), atol=tol, rtol=tol)


def test_flash_attention_grads():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, 256, 2, 64))
    k = jax.random.normal(ks[1], (2, 256, 2, 64))
    v = jax.random.normal(ks[2], (2, 256, 2, 64))

    def f(fn):
        return lambda q, k, v: jnp.sum(jnp.square(fn(q, k, v)))

    g1 = jax.grad(f(lambda *a: flash_attention(*a, True, 0, 128, 128, True)),
                  argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f(lambda *a: ref.reference_attention(*a, True, 0)),
                  argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-4, rtol=2e-4)


def test_flash_attention_windowed_grads():
    ks = jax.random.split(KEY, 3)
    q, k, v = (jax.random.normal(kk, (1, 256, 2, 64)) for kk in ks)
    f1 = lambda q, k, v: jnp.sum(flash_attention(q, k, v, True, 128, 128, 128, True) ** 2)
    f2 = lambda q, k, v: jnp.sum(ref.reference_attention(q, k, v, True, 128) ** 2)
    g1 = jax.grad(f1, (0, 1, 2))(q, k, v)
    g2 = jax.grad(f2, (0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-4, rtol=2e-4)


@pytest.mark.parametrize("H,W", [(8, 128), (64, 256), (33, 130)])
def test_cvt_color_sweep(H, W):
    img = jax.random.uniform(KEY, (H, W, 3)) * 255
    np.testing.assert_allclose(np.asarray(cvt_color(img)),
                               np.asarray(mh.cvt_color(img)),
                               rtol=1e-5, atol=1e-3)


@pytest.mark.parametrize("H,W", [(16, 128), (64, 256), (40, 136)])
@pytest.mark.parametrize("block_size", [2, 3])
def test_corner_harris_sweep(H, W, block_size):
    gray = mh.cvt_color(jax.random.uniform(KEY, (H, W, 3)) * 255)
    got = corner_harris(gray, block_size)
    want = mh.corner_harris(gray, block_size)
    scale = float(jnp.max(jnp.abs(want))) + 1e-9
    np.testing.assert_allclose(np.asarray(got) / scale,
                               np.asarray(want) / scale, atol=1e-5)


@pytest.mark.parametrize("alpha,beta", [(1.0, 0.0), (0.01, 5.0), (-2.0, 100.0)])
def test_convert_scale_abs_sweep(alpha, beta):
    x = jax.random.normal(KEY, (32, 128)) * 300
    np.testing.assert_allclose(np.asarray(convert_scale_abs(x, alpha, beta)),
                               np.asarray(mh.convert_scale_abs(x, alpha, beta)),
                               rtol=1e-5, atol=1e-3)


@pytest.mark.parametrize("N,d", [(256, 128), (512, 384), (100, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_sweep(N, d, dtype):
    x = jax.random.normal(KEY, (N, d), dtype)
    s = (jax.random.normal(KEY, (d,)) * 0.2).astype(dtype)
    got = rmsnorm(x, s)
    want = ref.reference_rmsnorm(x, s)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


def test_vmem_working_set_documented():
    """The fwd kernel's per-program VMEM footprint stays under budget."""
    from repro.core.costmodel import VMEM_BYTES
    bq, bk, hd, M = 512, 512, 128, 32768
    # q block + k/v full-seq refs + f32 acc + score block
    working = (bq * hd * 2 + 2 * M * hd * 2 + bq * hd * 4 + bq * bk * 4)
    assert working < VMEM_BYTES


def test_kernel_switch_and_fused_harris_response():
    """The ops-layer dispatch switch: ``use_kernels`` flips what
    ``kernels_enabled`` reports, and the single-call ``harris_response``
    matches the three-step reference chain on the default (sw) path."""
    from repro.kernels.ops import (harris_response, kernels_enabled,
                                   use_kernels)
    assert not kernels_enabled()           # CPU container default: refs
    use_kernels(True)
    try:
        assert kernels_enabled()
    finally:
        use_kernels(False)
    assert not kernels_enabled()

    img = jax.random.uniform(KEY, (32, 48, 3)) * 255.0
    got = harris_response(img)
    want = ref.reference_convert_scale_abs(
        ref.reference_corner_harris(ref.reference_cvt_color(img), 2, 0.04),
        1.0, 0.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4, rtol=1e-4)
