"""The fusion compiler layer: fused kernels, cost model, autotuner, and the
zero-recompile steady state.  (ISSUE 2 acceptance tests.)"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CourierIR, ModuleDatabase, Node, NodeCost,
                        courier_offload, fuse_adjacent_hw, fused_cost,
                        linear_ir, make_model_fused_cost)
from repro.core.costmodel import VMEM_BYTES
from repro.core.tracer import Library
from repro.kernels import ref
from repro.kernels.autotune import AutotuneCache, autotune
from repro.kernels.harris import fused_row_block, harris_fused, harris_fused_pair
from repro.kernels.rmsnorm import rmsnorm_matmul
from repro.models import harris as mh
from repro.models.harris import corner_harris_demo, make_harris_db

KEY = jax.random.PRNGKey(11)


def _close(got, want, tol=1e-5):
    scale = float(jnp.max(jnp.abs(want))) + 1e-9
    np.testing.assert_allclose(np.asarray(got) / scale,
                               np.asarray(want) / scale, atol=tol)


# --------------------------------------------------------------------------- #
# fused Harris mega-kernel vs the ref composition (halo correctness)
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("H,W", [(16, 128), (40, 136), (33, 130), (8, 64)])
@pytest.mark.parametrize("block_size", [2, 3])
def test_fused_harris_matches_ref_composition(H, W, block_size):
    img = jax.random.uniform(KEY, (H, W, 3)) * 255
    want = mh.convert_scale_abs(
        mh.corner_harris(mh.cvt_color(img), block_size, 0.04))
    got = harris_fused(img, block_size, 0.04, row_block=8)
    _close(got, want)


@pytest.mark.parametrize("row_block", [8, 16])
def test_fused_harris_halo_at_row_block_boundaries(row_block):
    """Multi-block grids must agree with the single-block (rb=H) kernel —
    any halo-exchange bug shows up exactly at block boundaries."""
    H, W = 48, 96
    img = jax.random.uniform(KEY, (H, W, 3)) * 255
    one_block = harris_fused(img, row_block=H)
    multi = harris_fused(img, row_block=row_block)
    _close(multi, one_block, tol=1e-6)


def test_fused_harris_pair_matches_chain():
    img = jax.random.uniform(KEY, (24, 80, 3)) * 255
    _close(harris_fused_pair(img, 2, 0.04, row_block=8),
           mh.corner_harris(mh.cvt_color(img), 2, 0.04))


@pytest.mark.parametrize("N,d,dout", [(64, 128, 96), (100, 64, 64)])
def test_rmsnorm_matmul_fused_matches_ref(N, d, dout):
    ks = jax.random.split(KEY, 3)
    x = jax.random.normal(ks[0], (N, d))
    s = jax.random.normal(ks[1], (d,)) * 0.2
    w = jax.random.normal(ks[2], (d, dout))
    got = rmsnorm_matmul(x, s, w, row_block=32)
    want = ref.reference_rmsnorm_matmul(x, s, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4, rtol=1e-4)


# --------------------------------------------------------------------------- #
# cost model: fused_cost and VMEM gating
# --------------------------------------------------------------------------- #
def test_fused_cost_removes_intermediate_traffic():
    a = NodeCost(flops=1e6, bytes_rw=8e6)
    b = NodeCost(flops=1e6, bytes_rw=8e6)
    fe = fused_cost([a, b], intermediate_bytes=2e6, vmem_required=1024)
    assert fe.cost.flops == 2e6                      # arithmetic conserved
    assert fe.cost.bytes_rw == 16e6 - 4e6            # write+read removed
    assert fe.hbm_bytes_saved == 4e6
    assert fe.fits_vmem and fe.wins
    assert fe.fused_ms < fe.unfused_ms


def test_fused_cost_vmem_overflow_rejected():
    a = NodeCost(flops=1e6, bytes_rw=8e6)
    fe = fused_cost([a, a], intermediate_bytes=2e6,
                    vmem_required=VMEM_BYTES + 1)
    assert not fe.fits_vmem
    assert fe.fused_ms == float("inf")
    assert not fe.wins


def test_nodecost_add_mixed_measured_and_estimated():
    measured = NodeCost(measured_ms=2.0)
    estimated = NodeCost(flops=0.0, bytes_rw=819e9)  # exactly 1000 ms roofline
    s = measured + estimated
    assert s.measured_ms == pytest.approx(2.0 + 1000.0)
    assert s.time_ms() == pytest.approx(1002.0)
    # pure-estimate sums still have no bogus "measured" time
    assert (estimated + estimated).measured_ms is None


# --------------------------------------------------------------------------- #
# model-driven fusion pass
# --------------------------------------------------------------------------- #
def _db_two_hw():
    db = ModuleDatabase("t")
    for f in ("a", "b"):
        db.register(f, software=lambda x: x, accelerated=lambda x: x)
    return db


def _annotated_ir(shape, inter_bytes_per_el=4):
    """a -> b chain over `shape` arrays, annotated as memory-bound."""
    ir = linear_ir("t", ["a", "b"], [1.0, 1.0], io_shape=shape)
    nbytes = int(np.prod(shape)) * 4
    for n in ir.nodes:
        n.flops = 10.0
        n.bytes_rw = 2.0 * nbytes
    return ir


def test_model_fusion_accepts_memory_bound_chain():
    ir = _annotated_ir((128, 128))
    fused = fuse_adjacent_hw(ir, _db_two_hw(), fused_cost_ms="model")
    assert [n.fn_key for n in fused.nodes] == ["a+b"]
    node = fused.nodes[0]
    # the fused node carries the reduced HBM traffic for the partitioners
    assert node.bytes_rw < ir.nodes[0].bytes_rw + ir.nodes[1].bytes_rw


def test_model_fusion_rejects_vmem_spill():
    # rows so wide that even an 8-row tile of the intermediates spills VMEM
    ir = _annotated_ir((8, 50_000_000))
    est = make_model_fused_cost(ir)(list(ir.nodes))
    assert est.fused_ms == float("inf") and not est.fits_vmem
    kept = fuse_adjacent_hw(ir, _db_two_hw(), fused_cost_ms="model")
    assert [n.fn_key for n in kept.nodes] == ["a", "b"]


def test_model_fusion_conservative_without_annotations():
    ir = linear_ir("t", ["a", "b"], [1.0, 1.0], io_shape=(4, 4))
    kept = fuse_adjacent_hw(ir, _db_two_hw(), fused_cost_ms="model")
    assert [n.fn_key for n in kept.nodes] == ["a", "b"]


def test_fusion_collects_external_inputs_of_later_parts():
    """A later part's side operand (matmul's weight) must become a fused-
    node input, and the composed impl must route it correctly."""
    from repro.kernels.ops import register_rmsnorm_matmul_modules

    db = ModuleDatabase("t")
    register_rmsnorm_matmul_modules(db)
    lib = Library(db)

    def app(x, s, w):
        return lib.matmul(lib.rmsnorm(x, s), w)

    ks = jax.random.split(KEY, 3)
    x = jax.random.normal(ks[0], (64, 128))
    s = jax.random.normal(ks[1], (128,)) * 0.1
    w = jax.random.normal(ks[2], (128, 96))
    off = courier_offload(app, x, s, w, db=db, prefer_hw=True, fuse=True)
    fused_nodes = [n for n in off.pipeline.ir.nodes if n.fused_from]
    assert len(fused_nodes) == 1
    assert len(fused_nodes[0].inputs) == 3           # x, scale AND w
    got = off.pipeline(x, s, w)
    want = ref.reference_rmsnorm_matmul(x, s, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4, rtol=1e-4)


def test_fused_harris_pipeline_end_to_end():
    """Full toolchain with fusion on: demo output unchanged, the pair run
    is fused, and the dedicated mega-kernel module resolves for it."""
    db = make_harris_db(with_hw=True)
    app = corner_harris_demo(Library(db))
    frame = jax.random.uniform(KEY, (32, 64, 3)) * 255
    off = courier_offload(app, frame, db=db, prefer_hw=True, fuse=True)
    fused_keys = [n.fn_key for n in off.pipeline.ir.nodes if n.fused_from]
    assert fused_keys == ["cvtColor+cornerHarris"]
    assert db.lookup("cvtColor+cornerHarris").has_hw((32, 64, 3))
    _close(off.pipeline(frame), app(frame), tol=1e-4)


# --------------------------------------------------------------------------- #
# autotuner cache behavior
# --------------------------------------------------------------------------- #
def test_autotune_cache_hit_miss_and_persistence(tmp_path):
    cache = AutotuneCache(str(tmp_path))
    calls = []

    def score(c):
        calls.append(c)
        return float(c)                              # smallest candidate wins

    r1 = autotune("k", (64, 128), [8, 4, 16], score, cache=cache)
    assert r1.best == 4 and r1.source == "tuned"
    assert sorted(calls) == [4, 8, 16]

    calls.clear()
    r2 = autotune("k", (64, 128), [8, 4, 16], score, cache=cache)
    assert r2.best == 4 and r2.source == "cache"
    assert calls == []                               # memoized: no re-scoring

    # different key → miss; persistence → a fresh cache instance still hits
    r3 = autotune("k", (64, 256), [8, 4], score, cache=cache)
    assert r3.source == "tuned"
    fresh = AutotuneCache(str(tmp_path))
    assert autotune("k", (64, 128), [8, 4, 16], score,
                    cache=fresh).source == "cache"
    assert fresh.info()["hits"] == 1

    cache.clear()
    calls.clear()
    r4 = autotune("k", (64, 128), [8, 4, 16], score, cache=cache)
    assert r4.source == "tuned" and calls != []


def test_autotune_all_infeasible_falls_back_to_first(tmp_path):
    cache = AutotuneCache(str(tmp_path))
    r = autotune("k", ("x",), [8, 16], lambda c: float("inf"), cache=cache)
    assert r.best == 8


def test_fused_row_block_divides_height(tmp_path):
    cache = AutotuneCache(str(tmp_path))
    for H in (16, 33, 40, 256):
        rb = fused_row_block(H, 128, cache=cache)
        assert H % rb == 0


# --------------------------------------------------------------------------- #
# zero-recompile steady state
# --------------------------------------------------------------------------- #
def test_zero_recompiles_across_token_waves():
    db = make_harris_db(with_hw=False)
    app = corner_harris_demo(Library(db))
    frames = [jax.random.uniform(jax.random.PRNGKey(i), (16, 32, 3)) * 255
              for i in range(6)]
    off = courier_offload(app, frames[0], db=db, prefer_hw=False)
    ex = off.pipeline.executor(max_in_flight=6, microbatch=4,
                               pad_microbatches=True, buckets=(1, 2, 4))
    ex.warmup(frames[0])
    c0 = ex.compile_count()
    assert c0 > 0
    for _ in range(3):                    # >= 3 identical-shape token waves
        out = ex.run([(f,) for f in frames[:5]])     # ragged: groups 4 + 1
        assert len(out) == 5
        assert ex.compile_count() == c0, "steady state recompiled!"
    # ragged group sizes bucket to warmed executables, not the compile path
    ex.run([(f,) for f in frames[:3]])
    ex.run([(f,) for f in frames[:2]])
    assert ex.compile_count() == c0
    # a rebuilt executor over the same pipeline shares the compiled stages
    # (same microbatch config; a smaller pool would clamp microbatch and
    # legitimately introduce a new group size)
    ex2 = off.pipeline.executor(max_in_flight=6, microbatch=4,
                                pad_microbatches=True, buckets=(1, 2, 4))
    ex2.run([(f,) for f in frames[:5]])
    assert off.pipeline.compile_count() == c0


def test_microbatch_bucketing_pads_to_bucket_not_max():
    from repro.core.executor import PipelineExecutor

    def stage(env):
        return {"y": env["x"] * 2.0}

    ex = PipelineExecutor([stage], ["x"], ["y"], max_in_flight=8,
                          microbatch=8, pad_microbatches=True,
                          buckets=(2, 4))
    assert ex._pad_for(3) == 1            # → bucket 4, not microbatch 8
    assert ex._pad_for(2) == 0
    assert ex._pad_for(5) == 3            # no bucket fits → pad to 8... via
    # buckets (2,4): 5 > 4 → falls through to microbatch
    assert ex._pad_for(8) == 0
    out = ex.run([(jnp.ones(3) * i,) for i in range(3)])
    np.testing.assert_allclose(np.asarray(out[2]), np.asarray(jnp.ones(3) * 4))


def test_donated_stages_keep_results_correct():
    """Stage-env donation must not change results when callers re-use the
    same token arrays across waves (graph inputs are never donated)."""
    db = make_harris_db(with_hw=False)
    app = corner_harris_demo(Library(db))
    frame = jax.random.uniform(KEY, (16, 32, 3)) * 255
    off = courier_offload(app, frame, db=db, prefer_hw=False)
    first = off.pipeline(frame)
    for _ in range(3):
        _close(off.pipeline(frame), first, tol=1e-7)


# --------------------------------------------------------------------------- #
# fusion-pass generality: kw-bound runs, in-run branches, stateful guards
# (ISSUE 10 satellite regressions for the MoE-shaped exemplars)
# --------------------------------------------------------------------------- #
def _kw_fused_offload():
    """x -> kscale(x, s=...) -> kshift: the middle operand is keyword-only,
    so fusion must record and replay the binding (fused_part_kw)."""
    from repro.core import courier_offload

    db = ModuleDatabase("t")

    def impl_scale(x, *, s):
        return x * s

    def impl_shift(x, b):
        return x + b

    db.register("kscale", software=impl_scale, accelerated=impl_scale)
    db.register("kshift", software=impl_shift, accelerated=impl_shift)
    lib = Library(db)

    def app(x, s, b):
        return lib.kshift(lib.kscale(x, s=s), b)

    ks = jax.random.split(KEY, 3)
    x = jax.random.normal(ks[0], (16, 8))
    s = jax.random.normal(ks[1], (16, 8)) * 0.5
    b = jax.random.normal(ks[2], (16, 8))
    off = courier_offload(app, x, s, b, db=db, prefer_hw=True, fuse=True,
                          fused_cost_ms=lambda run: 0.0)
    return off, app, (x, s, b)


def test_kw_bound_run_fuses_and_replays_bindings():
    off, app, args = _kw_fused_offload()
    fused = [n for n in off.pipeline.ir.nodes if n.fused_from]
    assert len(fused) == 1 and fused[0].fn_key == "kscale+kshift"
    # the keyword binding of the first part is part of the routing metadata
    assert fused[0].fused_part_kw[0] == [None, "s"]
    np.testing.assert_allclose(np.asarray(off.pipeline(*args)),
                               np.asarray(app(*args)), atol=1e-6)


def test_split_fused_node_roundtrips_input_kw():
    from repro.core import split_fused_node

    off, _, _ = _kw_fused_offload()
    ir = off.pipeline.ir
    fused = next(n for n in ir.nodes if n.fused_from)
    back = split_fused_node(ir, fused.name)
    keys = [n.fn_key for n in back.nodes]
    assert keys == ["kscale", "kshift"]
    scale = next(n for n in back.nodes if n.fn_key == "kscale")
    assert scale.input_kw == [None, "s"]             # binding survives the undo
    assert scale.outputs == fused.fused_part_outputs[0]
    back.validate()


def test_multi_consumer_intermediate_fuses_when_run_closed():
    """The MoE diamond: gate feeds BOTH dispatch and combine, all inside
    one hw run — a branch that stays inside the run must still fuse."""
    from repro.core import courier_offload

    db = ModuleDatabase("t")
    for name, fn in (("gate", lambda x: x * 2.0),
                     ("dispatch", lambda g: g + 1.0),
                     ("combine", lambda h, g: h * g)):
        db.register(name, software=fn, accelerated=fn)
    lib = Library(db)

    def app(x):
        g = lib.gate(x)
        return lib.combine(lib.dispatch(g), g)

    x = jax.random.normal(KEY, (8, 8))
    off = courier_offload(app, x, db=db, prefer_hw=True, fuse=True,
                          fused_cost_ms=lambda run: 0.0)
    fused = [n for n in off.pipeline.ir.nodes if n.fused_from]
    assert len(fused) == 1 and len(fused[0].fused_from) == 3
    np.testing.assert_allclose(np.asarray(off.pipeline(x)),
                               np.asarray(app(x)), atol=1e-6)


def test_escaping_consumer_keeps_run_unfused():
    """gate's output is also consumed OUTSIDE the hw run (a sw-only tail):
    fusing would hide a value another node still needs."""
    from repro.core import courier_offload

    db = ModuleDatabase("t")
    for name, fn in (("gate", lambda x: x * 2.0),
                     ("dispatch", lambda g: g + 1.0)):
        db.register(name, software=fn, accelerated=fn)
    db.register("swtail", software=lambda g, h: g - h)   # no hw impl
    lib = Library(db)

    def app(x):
        g = lib.gate(x)
        return lib.swtail(g, lib.dispatch(g))

    x = jax.random.normal(KEY, (8, 8))
    off = courier_offload(app, x, db=db, prefer_hw=True, fuse=True,
                          fused_cost_ms=lambda run: 0.0)
    assert not [n for n in off.pipeline.ir.nodes if n.fused_from]
    np.testing.assert_allclose(np.asarray(off.pipeline(x)),
                               np.asarray(app(x)), atol=1e-6)


def test_graph_output_intermediate_keeps_run_unfused():
    ir = _annotated_ir((64, 64))
    ir.graph_outputs = list(ir.nodes[0].outputs) + list(ir.graph_outputs)
    kept = fuse_adjacent_hw(ir, _db_two_hw(), fused_cost_ms=lambda run: 0.0)
    assert [n.fn_key for n in kept.nodes] == ["a", "b"]


def test_stateful_node_never_fuses():
    ir = _annotated_ir((64, 64))
    ir.nodes[0].state = "kv"                         # host-side slot writes
    kept = fuse_adjacent_hw(ir, _db_two_hw(), fused_cost_ms=lambda run: 0.0)
    assert [n.fn_key for n in kept.nodes] == ["a", "b"]
