"""Fault-injection harness, executor retry/quarantine, elastic inventory
recovery, serve-layer shutdown/deadline semantics — and the chaos soak.

The executor-level tests use numpy stage fns (no jit: injection + retries
are scheduler behavior, not compilation behavior); values encode the token
index so any seq/slot mix-up shows up as a wrong result, not just a
counter."""
import threading
import time

import numpy as np
import pytest

from repro.core import DeviceInventory, StageProfiler
from repro.core.executor import ExecutorClosed, PipelineExecutor
from repro.core.executor import _SeqRing
from repro.launch.serve import DeadlineExceeded, RequestQueueServer
from repro.runtime.faults import (DeviceLostError, FaultInjector, FaultPlan,
                                  InjectedFault, _hash_draw, as_injector)


# --------------------------------------------------------------------------- #
# FaultPlan / FaultInjector: deterministic scripting
# --------------------------------------------------------------------------- #
def test_transient_fires_on_scripted_counts_only():
    plan = FaultPlan().transient(0, at_calls=[1, 3])
    inj = plan.build()
    fired = []
    for call in range(5):
        try:
            inj.on_stage_call(0)
        except InjectedFault:
            fired.append(call)
    assert fired == [1, 3]
    assert inj.injected == 2
    assert inj.stage_calls(0) == 5
    inj.on_stage_call(1)                       # other stages unaffected
    # a fresh build of the same plan replays the same schedule
    fired2 = []
    inj2 = plan.build()
    for call in range(5):
        try:
            inj2.on_stage_call(0)
        except InjectedFault:
            fired2.append(call)
    assert fired2 == fired


def test_random_transients_reproducible_and_validated():
    assert 0.0 <= _hash_draw(7, 0, 0) < 1.0
    with pytest.raises(ValueError, match="rate"):
        FaultPlan().random_transients(1.5, seed=1)

    def schedule(inj, n=200):
        out = []
        for call in range(n):
            try:
                inj.on_stage_call(0)
            except InjectedFault:
                out.append(call)
        return out

    plan = FaultPlan().random_transients(0.1, seed=42)
    a = schedule(plan.build())
    b = schedule(plan.build())
    assert a == b and 5 <= len(a) <= 40        # ~10% of 200, seeded
    # stage filter: faults only land on listed stages
    inj = FaultPlan().random_transients(0.5, seed=1, stages=[3]).build()
    for _ in range(50):
        inj.on_stage_call(0)
    assert inj.injected == 0


def test_slowdown_window_sleeps_without_raising():
    inj = FaultPlan().slowdown(0, 5.0, from_call=1, to_call=3).build()
    t0 = time.perf_counter()
    for _ in range(4):
        inj.on_stage_call(0)
    assert (time.perf_counter() - t0) * 1e3 >= 8.0   # calls 1 and 2 slept
    assert inj.slowed == 2
    with pytest.raises(ValueError, match="extra_ms"):
        FaultPlan().slowdown(0, -1.0)


def test_device_loss_triggers_and_derives_survivors():
    inj = FaultPlan().lose_device(2).build()
    inj.on_stage_call(0, device=0)             # other ordinals unaffected
    with pytest.raises(DeviceLostError) as ei:
        inj.on_stage_call(0, replica=1, device=2)
    assert ei.value.ordinal == 2
    with pytest.raises(DeviceLostError):       # permanent, not transient
        inj.on_stage_call(1, device=2)
    assert inj.lost_ordinals() == frozenset({2})
    assert inj.device_faults == 2
    inv = DeviceInventory.host(4)
    assert len(inj.surviving(inv)) == 3
    assert inj.stats()["lost_ordinals"] == [2]


def test_scripted_but_unhit_loss_is_not_observable():
    # like a real chip that died while idle: until a call lands on it,
    # nothing has observed the failure
    inj = FaultPlan().lose_device(1).build()
    inj.on_stage_call(0, device=0)
    assert inj.lost_ordinals() == frozenset()
    inv = DeviceInventory.host(2)
    assert inj.surviving(inv) is inv


def test_live_lose_device_counts_from_now():
    inj = FaultInjector()
    for _ in range(3):
        inj.on_stage_call(0, device=1)
    inj.lose_device(1, after_calls=1)          # one more call survives
    inj.on_stage_call(0, device=1)
    with pytest.raises(DeviceLostError):
        inj.on_stage_call(0, device=1)


def test_remap_devices_follows_survivors():
    inj = FaultPlan().lose_device(1).lose_device(3).build()
    with pytest.raises(DeviceLostError):
        inj.on_stage_call(0, device=1)
    # inventory re-densified after dropping ordinal 1: old->new mapping
    inj.remap_devices({0: 0, 2: 1, 3: 2})
    assert inj.lost_ordinals() == frozenset()  # loss now lives in inventory
    assert inj.plan.device_losses == {2: 0}    # old 3 follows to new 2
    with pytest.raises(DeviceLostError) as ei:
        inj.on_stage_call(0, device=2)
    assert ei.value.ordinal == 2


def test_fail_step_fires_once_and_as_injector_normalizes():
    inj = FaultPlan().fail_step([3]).build()
    inj.on_step(2)
    with pytest.raises(InjectedFault):
        inj.on_step(3)
    inj.on_step(3)                             # replay after restart succeeds
    assert as_injector(None) is None
    assert as_injector(inj) is inj
    assert isinstance(as_injector(FaultPlan()), FaultInjector)
    with pytest.raises(TypeError, match="FaultPlan or FaultInjector"):
        as_injector(lambda s: None)


# --------------------------------------------------------------------------- #
# _SeqRing: residue ownership, adopt/retire hand-off
# --------------------------------------------------------------------------- #
def test_seqring_owns_residue_and_consumes_in_order():
    ring = _SeqRing(stride=2, first_seq=0)
    assert ring.put(2, "g2") and ring.put(0, "g0")   # out-of-order arrival
    assert ring.pop() == (0, "g0")
    assert ring.pop() == (2, "g2")
    ring.close()
    assert ring.pop() is None
    assert ring.put(4, "g4") is False          # closed: caller must fail it


def test_seqring_adopt_resumes_siblings_watermark():
    victim = _SeqRing(stride=2, first_seq=1)
    victim.put(1, "g1")
    assert victim.pop() == (1, "g1")           # watermark advances to 3
    victim.put(3, "g3")
    slots, nxt = victim.retire()
    assert slots == {3: "g3"} and nxt == {1: 3}
    assert victim.put(5, "g5") is False        # retired == closed

    survivor = _SeqRing(stride=2, first_seq=0)
    survivor.adopt(1, nxt[1])
    for s, g in slots.items():
        assert survivor.put(s, g)
    survivor.put(0, "g0")
    assert survivor.pop() == (0, "g0")         # own residue still served
    assert survivor.pop() == (3, "g3")         # adopted residue resumes at 3


# --------------------------------------------------------------------------- #
# executor: retry, quarantine, bounded budgets
# --------------------------------------------------------------------------- #
def _fns():
    def s0(env):
        time.sleep(0.001)
        return {"x": np.asarray(env["x"]) * 2.0}

    def s1(env):
        time.sleep(0.001)
        return {"y": np.asarray(env["x"]) + 1.0}
    return [s0, s1]


def _expect(i):
    return float(i) * 2.0 + 1.0


def test_transient_retries_on_sibling_no_quarantine():
    inj = FaultPlan().transient(0, at_calls=[2]).build()
    ex = PipelineExecutor(_fns(), ["x"], ["y"], replicas=[2, 1],
                          fault_injector=inj, quarantine_after=3)
    got = ex.run([(np.full((2,), float(i)),) for i in range(8)])
    st = ex.stats()
    ex.close()
    for i, g in enumerate(got):
        np.testing.assert_allclose(np.asarray(g), _expect(i))
    assert st.retries == 1 and st.quarantined == 0
    assert st.out_of_order_retired == 0
    assert st.tokens_retired == 8
    assert st.per_stage[0].errors == 1


def test_repeated_errors_quarantine_the_replica():
    # every call placed on replica residue 0 of stage 0 faults until the
    # eviction: quarantine_after=1 evicts on the first error
    inj = FaultPlan().transient(0, at_calls=[0]).build()
    ex = PipelineExecutor(_fns(), ["x"], ["y"], replicas=[3, 1],
                          fault_injector=inj, quarantine_after=1)
    got = ex.run([(np.full((2,), float(i)),) for i in range(9)])
    st = ex.stats()
    healthy = ex.healthy_replicas()
    ex.close()
    for i, g in enumerate(got):
        np.testing.assert_allclose(np.asarray(g), _expect(i))
    assert st.quarantined == 1
    assert st.quarantined_replicas and st.quarantined_replicas[0][0] == 0
    assert healthy[0] == 2 and healthy[1] == 1
    assert st.out_of_order_retired == 0 and st.tokens_retired == 9


def test_unreplicated_stage_error_fails_the_group():
    # stage 1 has no sibling: the injected fault errors that group only,
    # in order, and the pool is not leaked
    inj = FaultPlan().transient(1, at_calls=[2]).build()
    ex = PipelineExecutor(_fns(), ["x"], ["y"], replicas=[2, 1],
                          fault_injector=inj, quarantine_after=3)
    handles = ex.submit_many([(np.full((2,), float(i)),) for i in range(6)])
    ok, failed = [], []
    for i, h in enumerate(handles):
        try:
            h.result()
            ok.append(i)
        except InjectedFault:
            failed.append(i)
    st = ex.stats()
    ex.close()
    assert len(failed) == 1 and len(ok) == 5
    assert st.retries == 0 and st.quarantined == 0
    assert st.tokens_admitted == st.tokens_retired == 6
    assert st.out_of_order_retired == 0


def test_max_group_retries_bounds_the_retry_loop():
    # every stage-0 invocation faults; the group burns its retry budget
    # and then fails instead of spinning forever
    inj = FaultPlan().transient(0, at_calls=range(1000)).build()
    ex = PipelineExecutor(_fns(), ["x"], ["y"], replicas=[2, 1],
                          fault_injector=inj, quarantine_after=10_000,
                          max_group_retries=3)
    h = ex.submit(np.full((2,), 1.0))
    with pytest.raises(InjectedFault):
        h.result()
    st = ex.stats()
    ex.close()
    assert st.retries == 3                     # bounded, then failed
    assert st.tokens_retired == 1


def test_retry_budget_ms_zero_disables_retries():
    inj = FaultPlan().transient(0, at_calls=[0]).build()
    ex = PipelineExecutor(_fns(), ["x"], ["y"], replicas=[2, 1],
                          fault_injector=inj, quarantine_after=10_000,
                          retry_budget_ms=0.0)
    h = ex.submit(np.full((2,), 1.0))
    with pytest.raises(InjectedFault):
        h.result()
    st = ex.stats()
    ex.close()
    assert st.retries == 0


def test_device_loss_attributes_errors_to_configured_ordinal():
    inj = FaultPlan().lose_device(1).build()
    ex = PipelineExecutor(_fns(), ["x"], ["y"], replicas=[2, 1],
                          devices=[[0, 1], [2]],
                          inventory=DeviceInventory.host(3),
                          fault_injector=inj, quarantine_after=1)
    got = ex.run([(np.full((2,), float(i)),) for i in range(6)])
    st = ex.stats()
    ex.close()
    for i, g in enumerate(got):
        np.testing.assert_allclose(np.asarray(g), _expect(i))
    assert st.quarantined == 1
    assert st.device_errors.get(1, 0) >= 1     # keyed by CONFIGURED ordinal
    assert st.out_of_order_retired == 0


# --------------------------------------------------------------------------- #
# inventory: structured refresh diff
# --------------------------------------------------------------------------- #
def test_inventory_refresh_diffs_by_identity():
    inv = DeviceInventory.host(4)
    diff = inv.refresh(probe=lambda: inv.drop([0]))
    assert diff.changed
    assert diff.lost == (0,) and diff.gained == ()
    assert diff.survivors == {1: 0, 2: 1, 3: 2}   # identity survives re-dense
    assert "lost" in diff.describe()
    same = inv.refresh(probe=lambda: inv)
    assert not same.changed and same.survivors == {0: 0, 1: 1, 2: 2, 3: 3}


def test_inventory_drop_and_reweighted():
    inv = DeviceInventory.host(3)
    smaller = inv.drop({1})
    assert len(smaller) == 2
    assert [s.ordinal for s in smaller.specs] == [0, 1]     # re-densified
    with pytest.raises(ValueError):
        inv.drop({0, 1, 2})
    slow = inv.reweighted({1: 0.25})
    assert slow.spec(1).speed == pytest.approx(inv.spec(1).speed * 0.25)
    assert slow.spec(0).speed == inv.spec(0).speed


# --------------------------------------------------------------------------- #
# elastic recovery: loss -> quarantine -> refresh -> survivors re-plan
# --------------------------------------------------------------------------- #
DELAYS: dict[str, float] = {}


def _impl(key):
    def sw(x):
        time.sleep(DELAYS[key] / 1e3)
        return np.asarray(x) + 1.0
    sw.__name__ = key
    return sw


def _chain_planner(times=(1.0, 4.0), inventory=None, **kw):
    from repro.core import ModuleDatabase, linear_ir
    from repro.runtime import ElasticPlanner

    keys = [f"f{i}" for i in range(len(times))]
    DELAYS.clear()
    DELAYS.update(dict(zip(keys, times)))
    db = ModuleDatabase("faults-chain")
    for k in keys:
        db.register(k, software=_impl(k))
    ir = linear_ir("faults-chain", keys, list(times), io_shape=(4,))
    return ElasticPlanner(ir, db=db, inventory=inventory, **kw)


def test_replan_on_inventory_change_sheds_lost_device():
    inj = FaultInjector()
    inv = DeviceInventory.host(4)
    planner = _chain_planner(inventory=inv, fault_injector=inj,
                             quarantine_after=1)
    prof = StageProfiler(2, min_samples=2)
    ex, _ = planner.executor_for(2, jit=False, profiler=prof)
    assert max(ex.replicas) > 1                # inventory widened the chain
    wide_si = max(range(2), key=lambda s: ex.replicas[s])
    target = ex.devices[wide_si][0]
    toks = [np.full((4,), float(i)) for i in range(8)]
    ex.run(toks)

    inj.lose_device(target)
    got = ex.run(toks)                         # quarantine absorbs the loss
    for i, g in enumerate(got):
        np.testing.assert_allclose(np.asarray(g), float(i) + 2.0)
    st = ex.stats()
    assert st.quarantined == 1 and st.out_of_order_retired == 0

    diff = inv.refresh(probe=lambda: inj.surviving(inv))
    assert diff.lost == (target,)
    d = planner.replan_on_inventory_change(diff, profiler=prof, stats=st,
                                           jit=False)
    assert d.replanned and d.widened
    assert "lost" in d.reason
    assert sum(d.replicas) <= 3                # only 3 survivors remain
    if d.executor.devices is not None:
        assert all(o < 3 for row in d.executor.devices for o in row)
    got2 = d.executor.run(toks)
    for i, g in enumerate(got2):
        np.testing.assert_allclose(np.asarray(g), float(i) + 2.0)
    st2 = d.executor.stats()
    assert st2.retries == 0 and st2.quarantined == 0   # clean on survivors
    d.executor.close()
    ex.close()


def test_replan_on_inventory_change_keeps_when_unchanged():
    planner = _chain_planner(inventory=DeviceInventory.host(4))
    planner.executor_for(2, jit=False)
    inv = planner.inventory
    diff = inv.refresh(probe=lambda: inv)
    d = planner.replan_on_inventory_change(diff, jit=False)
    assert not d.replanned and d.reason == "inventory unchanged"


# --------------------------------------------------------------------------- #
# serve layer: stop() rejects pending, deadlines bound queue time
# --------------------------------------------------------------------------- #
def _slow_executor(ms=30.0, max_in_flight=2):
    def slow(env):
        time.sleep(ms / 1e3)
        return {"y": np.asarray(env["x"]) * 2.0}
    return PipelineExecutor([slow], ["x"], ["y"], stage_workers=True,
                            max_in_flight=max_in_flight)


def test_server_stop_fails_pending_requests_with_executor_closed():
    ex = _slow_executor()
    srv = RequestQueueServer(ex, max_batch=1, max_wait_ms=0.5).start()
    reqs = [srv.submit(np.full((2,), float(i))) for i in range(4)]
    srv.stop()
    served = rejected = 0
    for r in reqs:
        try:
            r.wait(timeout=10.0)
            served += 1
        except ExecutorClosed:
            rejected += 1
    assert served + rejected == 4              # nobody left hanging
    st = srv.stats()
    assert st["rejected"] == rejected
    assert st["queue_depth"] == 0
    # post-stop submissions are rejected immediately, not queued forever
    late = srv.submit(np.zeros(2))
    with pytest.raises(ExecutorClosed):
        late.wait(timeout=1.0)
    ex.close()


def test_deadline_ms_fails_queued_requests_instead_of_serving_late():
    ex = _slow_executor(ms=50.0, max_in_flight=1)
    with RequestQueueServer(ex, max_batch=1, max_wait_ms=0.5,
                            queue_depth=16) as srv:
        head = srv.submit(np.zeros(2))         # occupies the executor
        doomed = [srv.submit(np.zeros(2), deadline_ms=1.0)
                  for _ in range(3)]
        ok = srv.submit(np.zeros(2))           # no deadline: served
        head.wait(timeout=10.0)
        expired = served_late = 0
        for r in doomed:
            try:
                r.wait(timeout=10.0)
                served_late += 1
            except DeadlineExceeded:
                expired += 1
        ok.wait(timeout=10.0)
        # the batcher may have collected the first doomed request before
        # its deadline; everything still queued when it expired must fail
        assert expired >= 2 and expired + served_late == 3
        assert srv.stats()["rejected"] >= expired
    ex.close()


# --------------------------------------------------------------------------- #
# training driver: faults= harness, legacy hook, loss accounting
# --------------------------------------------------------------------------- #
def test_driver_faults_and_fail_hook_are_exclusive(tmp_path):
    from repro.checkpoint import CheckpointStore
    from repro.runtime import FaultTolerantDriver

    store = CheckpointStore(str(tmp_path))
    with pytest.raises(ValueError, match="not both"):
        FaultTolerantDriver(lambda s, b: (s, {"loss": 0.0}), store, None,
                            faults=FaultPlan(), fail_hook=lambda s: None)


def test_driver_replay_does_not_double_count_losses(tmp_path):
    import jax.numpy as jnp

    from repro.checkpoint import CheckpointStore
    from repro.runtime import FaultTolerantDriver

    class Data:
        def batch(self, step):
            return float(step)

    def step_fn(state, batch):
        w = state["w"] - 0.1
        return {"w": w}, {"loss": jnp.sum(w * w)}

    store = CheckpointStore(str(tmp_path))
    drv = FaultTolerantDriver(step_fn, store, Data(), ckpt_every=4,
                              async_ckpt=False,
                              faults=FaultPlan().fail_step([6]))
    state, res = drv.run({"w": jnp.ones(3)}, n_steps=10)
    assert res.restarts == 1 and res.steps_done == 10
    # steps 4 and 5 were replayed after the restart; keyed-by-step
    # accounting keeps exactly one loss per step
    assert len(res.losses) == 10
    np.testing.assert_allclose(np.asarray(state["w"]), np.ones(3) - 1.0,
                               atol=1e-6)


def test_driver_legacy_fail_hook_still_supported(tmp_path):
    import jax.numpy as jnp

    from repro.checkpoint import CheckpointStore
    from repro.runtime import FaultTolerantDriver

    class Data:
        def batch(self, step):
            return float(step)

    def step_fn(state, batch):
        return {"w": state["w"] - 0.1}, {"loss": jnp.zeros(())}

    armed = {"on": True}

    def hook(step):
        if step == 3 and armed["on"]:
            armed["on"] = False
            raise RuntimeError("legacy injected failure")

    store = CheckpointStore(str(tmp_path))
    drv = FaultTolerantDriver(step_fn, store, Data(), ckpt_every=2,
                              async_ckpt=False, fail_hook=hook)
    _, res = drv.run({"w": jnp.ones(2)}, n_steps=6)
    assert res.restarts == 1 and res.steps_done == 6


# --------------------------------------------------------------------------- #
# lint: swallowed-exception rule
# --------------------------------------------------------------------------- #
def _lint_src(tmp_path, src):
    from repro.analysis.lint import lint_paths

    f = tmp_path / "mod.py"
    f.write_text(src)
    return [d for d in lint_paths([str(f)])
            if d.rule == "swallowed-exception"]


def test_lint_flags_swallowed_broad_handlers(tmp_path):
    findings = _lint_src(tmp_path, (
        "def f():\n"
        "    try:\n"
        "        g()\n"
        "    except Exception:\n"
        "        pass\n"
        "def h():\n"
        "    try:\n"
        "        g()\n"
        "    except:\n"
        "        x = 1\n"
    ))
    assert len(findings) == 2
    assert "neither re-raises nor records" in findings[0].message


def test_lint_accepts_reraise_recorded_or_annotated(tmp_path):
    findings = _lint_src(tmp_path, (
        "def a():\n"
        "    try:\n"
        "        g()\n"
        "    except Exception:\n"
        "        raise\n"
        "def b(log):\n"
        "    try:\n"
        "        g()\n"
        "    except Exception as e:\n"
        "        log.append(e)\n"
        "def c():\n"
        "    try:\n"
        "        g()\n"
        "    except Exception:  # lint: allow-swallow(best-effort probe)\n"
        "        pass\n"
        "def d():\n"
        "    try:\n"
        "        g()\n"
        "    except ValueError:\n"        # narrow handlers are not its job
        "        pass\n"
    ))
    assert findings == []


# --------------------------------------------------------------------------- #
# chaos soak: randomized transients under concurrent submitters
# --------------------------------------------------------------------------- #
@pytest.mark.slow
def test_chaos_soak_randomized_transients_zero_drops():
    """8 threads x 250 requests against a replicated pipeline under seeded
    random transients: every request retires, in order per thread, with
    results identical to the fault-free pipeline."""
    def s0(env):
        return {"x": np.asarray(env["x"]) * 2.0}

    def s1(env):
        return {"x": np.asarray(env["x"]) + 1.0}

    def s2(env):
        return {"y": np.asarray(env["x"]) * 3.0}

    n_threads, per_thread = 8, 250
    inj = FaultPlan().random_transients(0.02, seed=1234).build()
    ex = PipelineExecutor([s0, s1, s2], ["x"], ["y"], replicas=[2, 3, 2],
                          max_in_flight=16, fault_injector=inj,
                          quarantine_after=10**9)   # pure retries, no evict
    errors: list = []
    results: dict[int, list] = {}

    def worker(tid):
        try:
            hs = ex.submit_many([(np.full((2,), tid * 1000.0 + i),)
                                 for i in range(per_thread)])
            results[tid] = [float(np.asarray(h.result())[0]) for h in hs]
        except BaseException as e:     # pragma: no cover - fail the test
            errors.append(e)

    threads = [threading.Thread(target=worker, args=(t,))
               for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    st = ex.stats()
    ex.close()
    assert not errors, errors
    assert st.tokens_admitted == st.tokens_retired == n_threads * per_thread
    assert st.out_of_order_retired == 0
    assert st.retries > 0                      # the soak actually injected
    assert st.quarantined == 0
    for tid in range(n_threads):
        want = [(tid * 1000.0 + i) * 2.0 * 3.0 + 3.0
                for i in range(per_thread)]
        assert results[tid] == want, f"thread {tid} results diverged"
