"""Static analysis gates: plan verifier corruption classes, gated call
sites (generate / replan / hot-swap), the ExecutorClosed race fix, and
unit tests for every lint rule (repro.analysis.lint)."""
import copy
import textwrap
import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import (PlanVerificationError, check_plan, verify_plan,
                            VERIFY_RULES)
from repro.analysis.diagnostics import ERROR, WARNING
from repro.analysis.lint import (FILE_RULES, LINT_RULES, PROJECT_RULES,
                                 LintContext, lint_file)
from repro.core import (DeviceInventory, ExecutorClosed, Frontend, Library,
                        ModuleDatabase, PipelineGenerator, Placement,
                        StageProfiler, assign_replicas, linear_ir,
                        partition_optimal)
from repro.core.executor import SubmitError
from repro.core.ir import CourierIR, Node
from repro.core.partition import PipelinePlan
from repro.launch.serve import RequestQueueServer
from repro.runtime import ElasticPlanner

IO = (64, 96)


# --------------------------------------------------------------------------- #
# fixtures
# --------------------------------------------------------------------------- #
def _linear():
    """Known-good 4-node chain and its 3-stage optimal plan."""
    ir = linear_ir("t", ["a", "b", "c", "d"], [1.0, 4.0, 2.0, 1.0],
                   io_shape=IO)
    plan = partition_optimal(ir, max_stages=3)
    assert [s.node_names for s in plan.stages] == \
        [["a_0"], ["b_1"], ["c_2", "d_3"]]
    return ir, plan


def _sw_db():
    db = ModuleDatabase("t")
    for k in ("a", "b", "c", "d"):
        db.register(k, software=lambda x: x)
    return db


def _pinned():
    """The linear plan widened + pinned onto a 4-device host inventory."""
    ir, plan = _linear()
    inv = DeviceInventory.host(4)
    assign_replicas(plan, ir, worker_budget=4, inventory=inv)
    assert plan.replicas == [1, 2, 1]           # stage #1 is the widened one
    return ir, plan, inv


def _fused(rows=64, cols=96):
    """Hand-built IR holding one fused hw node a_0+b_1 (d0 -> d1 -> d2)."""
    ir = CourierIR("fz")
    for v in ("d0", "d1", "d2"):
        ir.add_value(v, (rows, cols), "float32")
    ir.add_node(Node(
        name="a_0+b_1", fn_key="a+b", inputs=["d0"], outputs=["d2"],
        time_ms=1.0, placement=Placement.hw(),
        fused_from=["a_0", "b_1"],
        fused_input_shapes=[[(rows, cols)], [(rows, cols)]],
        fused_params=[{}, {}],
        fused_part_inputs=[["d0"], ["d1"]],
        fused_part_outputs=[["d1"], ["d2"]]))
    ir.graph_inputs = ["d0"]
    ir.graph_outputs = ["d2"]
    plan = partition_optimal(ir, max_stages=1)
    return ir, plan


def _jit_pipe():
    """A tiny traced+generated pipeline (mul2 -> add1 -> sq)."""
    db = ModuleDatabase("t")
    db.register("mul2", software=lambda x: x * 2.0)
    db.register("add1", software=lambda x: x + 1.0)
    db.register("sq", software=lambda x: x * x)
    lib = Library(db)

    def app(x):
        return lib.sq(lib.add1(lib.mul2(x)))
    ir, _ = Frontend(db).trace(app, jnp.arange(4.0), profile=False)
    for n in ir.nodes:
        n.time_ms = 1.0
    return db, ir


def _feed(prof, stage_times, n=8):
    for _ in range(n):
        for k, t in enumerate(stage_times):
            prof.record(k, t)


# --------------------------------------------------------------------------- #
# clean plans verify clean (incl. the deliberately-legal replan patterns)
# --------------------------------------------------------------------------- #
def test_clean_serial_plan_has_no_findings():
    ir, plan = _linear()
    assert verify_plan(ir, plan, db=_sw_db()) == []


def test_clean_pinned_plan_and_replan_candidate_pattern_are_legal():
    ir, plan, inv = _pinned()
    db = _sw_db()
    assert verify_plan(ir, plan, db=db, inventory=inv) == []
    # the replanner's pinned-candidate normalization: keep devices, drop
    # speeds and transfer charges — must stay legal, not a replica-vector
    for s in plan.stages:
        s.xfer_in_ms = 0.0
        s.device_speeds = []
    assert verify_plan(ir, plan, db=db, inventory=inv) == []


def test_clean_fused_plan_has_no_findings():
    ir, plan = _fused()
    assert verify_plan(ir, plan, db=_sw_db()) == []


def test_plan_json_round_trip_verifies_clean():
    ir, plan, inv = _pinned()
    plan2 = PipelinePlan.from_json(plan.to_json())
    assert [s.node_names for s in plan2.stages] == \
        [s.node_names for s in plan.stages]
    assert plan2.replicas == plan.replicas
    assert verify_plan(ir, plan2, db=_sw_db(), inventory=inv) == []


# --------------------------------------------------------------------------- #
# corruption classes -> rule ids (the acceptance matrix)
# --------------------------------------------------------------------------- #
def _mut_drop_producer(ir, plan):
    ir.nodes = [n for n in ir.nodes if n.name != "b_1"]
    for s in plan.stages:
        s.node_names = [nn for nn in s.node_names if nn != "b_1"]


def _mut_reverse_stages(ir, plan):
    plan.stages = list(reversed(plan.stages))


def _mut_duplicate_node(ir, plan):
    plan.stages[-1].node_names.append("a_0")


def _mut_phantom_node(ir, plan):
    plan.stages[0].node_names.append("ghost_9")


def _mut_missing_output(ir, plan):
    ir.graph_outputs = ["never_made"]


def _mut_phantom_xfer(ir, plan):
    plan.stages[0].xfer_in_ms = 1.5


def _mut_zero_replicas(ir, plan):
    plan.stages[1].replicas = 0


LINEAR_CORRUPTIONS = [
    ("drop-producer", "produced-once", _mut_drop_producer),
    ("reverse-stages", "stage-order", _mut_reverse_stages),
    ("duplicate-node", "stage-coverage", _mut_duplicate_node),
    ("phantom-node", "stage-coverage", _mut_phantom_node),
    ("missing-output", "output-missing", _mut_missing_output),
    ("phantom-xfer", "phantom-xfer", _mut_phantom_xfer),
    ("zero-replicas", "replica-vector", _mut_zero_replicas),
]


@pytest.mark.parametrize("rule,mutate",
                         [(r, m) for _id, r, m in LINEAR_CORRUPTIONS],
                         ids=[c[0] for c in LINEAR_CORRUPTIONS])
def test_linear_corruption_flags_rule(rule, mutate):
    ir, plan = _linear()
    mutate(ir, plan)
    diags = verify_plan(ir, plan)
    assert rule in {d.rule for d in diags}, \
        "\n".join(d.format() for d in diags)
    assert all(d.severity == ERROR for d in diags if d.rule == rule)


def _mut_serial_widened(ir, plan):
    ir.node("b_1").serial_only = True


def _mut_truncate_speeds(ir, plan):
    plan.stages[1].device_speeds = [1.0]        # widened stage: 2 replicas


def _mut_bad_ordinal(ir, plan):
    plan.stages[0].devices = [99]


PINNED_CORRUPTIONS = [
    ("serial-only-widened", "serial-only-widened", _mut_serial_widened),
    ("truncate-speeds", "replica-vector", _mut_truncate_speeds),
    ("bad-ordinal", "device-ordinal", _mut_bad_ordinal),
]


@pytest.mark.parametrize("rule,mutate",
                         [(r, m) for _id, r, m in PINNED_CORRUPTIONS],
                         ids=[c[0] for c in PINNED_CORRUPTIONS])
def test_pinned_corruption_flags_rule(rule, mutate):
    ir, plan, inv = _pinned()
    mutate(ir, plan)
    diags = verify_plan(ir, plan, inventory=inv)
    assert rule in {d.rule for d in diags}, \
        "\n".join(d.format() for d in diags)


def test_hw_placement_without_accelerated_module_flags():
    ir, plan = _linear()
    node = ir.node("c_2")
    node.placement = Placement.hw()
    for s in plan.stages:
        if "c_2" in s.node_names and s.placements:
            s.placements[s.node_names.index("c_2")] = Placement.hw()
    rules = {d.rule for d in verify_plan(ir, plan, db=_sw_db())}
    assert "hw-unresolvable" in rules


def test_fused_routing_truncation_flags():
    ir, plan = _fused()
    ir.nodes[0].fused_part_inputs = ir.nodes[0].fused_part_inputs[:1]
    rules = {d.rule for d in verify_plan(ir, plan)}
    assert "fused-routing" in rules


def test_fused_shape_drift_flags():
    ir, plan = _fused()
    ir.nodes[0].fused_input_shapes = [[(8, 8)], [(64, 96)]]
    rules = {d.rule for d in verify_plan(ir, plan)}
    assert "shape-mismatch" in rules


def test_fused_vmem_spill_flags():
    ir, plan = _fused(rows=4096, cols=4_000_000)     # tiles alone spill VMEM
    rules = {d.rule for d in verify_plan(ir, plan)}
    assert "vmem-spill" in rules


def test_nonfinite_stage_time_is_warning_not_error():
    ir, plan = _linear()
    plan.stages[0].est_time_ms = float("nan")
    diags = verify_plan(ir, plan)
    assert {d.rule for d in diags} == {"stage-time"}
    assert all(d.severity == WARNING for d in diags)
    # check_plan passes warnings through without raising
    assert [d.rule for d in check_plan(ir, plan)] == ["stage-time"]


def test_rule_catalog_is_complete():
    expected = {"stage-coverage", "stage-order", "produced-once",
                "output-missing", "fused-routing", "shape-mismatch",
                "hw-unresolvable", "replica-vector", "device-ordinal",
                "serial-only-widened", "phantom-xfer", "vmem-spill",
                "stage-time"}
    assert expected <= set(VERIFY_RULES)
    assert {"placement-literal", "lock-discipline", "blocking-in-lock",
            "frozen-dataclass", "acquire-without-finally",
            "dead-export"} <= set(LINT_RULES)


# --------------------------------------------------------------------------- #
# check_plan: raise semantics + REPRO_VERIFY escape hatch
# --------------------------------------------------------------------------- #
def test_check_plan_raises_with_where_and_rules(monkeypatch):
    ir, plan = _linear()
    plan.stages = list(reversed(plan.stages))
    with pytest.raises(PlanVerificationError) as ei:
        check_plan(ir, plan, where="unit-test")
    e = ei.value
    assert e.where == "unit-test" and "unit-test" in str(e)
    assert "stage-order" in e.rules and e.diagnostics
    monkeypatch.setenv("REPRO_VERIFY", "off")
    assert check_plan(ir, plan, where="unit-test") == []


# --------------------------------------------------------------------------- #
# gate 1: PipelineGenerator.generate
# --------------------------------------------------------------------------- #
def _corrupting_partition(module, name, corrupt):
    real = getattr(module, name)

    def wrapper(ir, **kw):
        plan = real(ir, **kw)
        corrupt(plan)
        return plan
    return wrapper


def test_generate_gate_rejects_corrupt_partition(monkeypatch):
    import repro.core.pipeline as pl
    db, ir = _jit_pipe()
    monkeypatch.setattr(pl, "partition_paper", _corrupting_partition(
        pl, "partition_paper",
        lambda plan: setattr(plan.stages[0], "xfer_in_ms", 5.0)))
    with pytest.raises(PlanVerificationError) as ei:
        PipelineGenerator(db).generate(ir, n_threads=2)
    assert "phantom-xfer" in ei.value.rules
    assert "generate" in ei.value.where


def test_generate_gate_env_off_builds_and_computes(monkeypatch):
    import repro.core.pipeline as pl
    db, ir = _jit_pipe()
    monkeypatch.setattr(pl, "partition_paper", _corrupting_partition(
        pl, "partition_paper",
        lambda plan: setattr(plan.stages[0], "xfer_in_ms", 5.0)))
    monkeypatch.setenv("REPRO_VERIFY", "off")
    pipe = PipelineGenerator(db).generate(ir, n_threads=2)
    x = jnp.arange(4.0)
    np.testing.assert_allclose(np.asarray(pipe(x)),
                               np.asarray((x * 2.0 + 1.0) ** 2), rtol=1e-6)


# --------------------------------------------------------------------------- #
# gate 2: ElasticPlanner.replan_from_profile discards failing candidates
# --------------------------------------------------------------------------- #
def _sim_db(keys):
    db = ModuleDatabase("sim")
    for k in keys:
        def impl(x, _k=k):
            return np.asarray(x) + 1.0
        impl.__name__ = k
        db.register(k, software=impl)
    return db


def test_replan_gate_discards_corrupt_candidate(monkeypatch):
    import repro.runtime.driver as drv
    keys = [f"f{i}" for i in range(6)]
    ir = linear_ir("sim", keys, [2.0] * 6, io_shape=(4,))
    planner = ElasticPlanner(ir, db=_sim_db(keys))
    planner.executor_for(3, jit=False)
    before = [list(s.node_names) for s in planner.current_plan.stages]

    monkeypatch.setattr(drv, "partition_optimal", _corrupting_partition(
        drv, "partition_optimal",
        lambda plan: plan.stages.reverse()))
    prof = StageProfiler(3, min_samples=4)
    _feed(prof, [4.0, 12.0, 4.0])          # would normally trigger a replan
    d = planner.replan_from_profile(prof, max_stages=6, jit=False)
    assert not d.replanned
    assert "failed verification" in d.reason
    assert "stage-order" in d.reason or "produced-once" in d.reason
    assert planner.replans == 0
    assert [list(s.node_names) for s in planner.current_plan.stages] == before


def test_replan_gate_mid_stream_serves_every_request(monkeypatch):
    """A corrupted candidate rejected mid-stream: the old executor keeps
    serving and not a single request is dropped."""
    import repro.runtime.driver as drv
    keys = [f"g{i}" for i in range(4)]
    ir = linear_ir("sim2", keys, [2.0] * 4, io_shape=(4,))
    planner = ElasticPlanner(ir, db=_sim_db(keys))
    ex, _ = planner.executor_for(2, jit=False, max_in_flight=4)
    monkeypatch.setattr(drv, "partition_optimal", _corrupting_partition(
        drv, "partition_optimal",
        lambda plan: plan.stages.reverse()))

    toks = [np.full((4,), float(i)) for i in range(12)]
    with RequestQueueServer(ex, max_batch=2, max_wait_ms=2.0) as srv:
        reqs = [srv.submit(t) for t in toks[:6]]
        prof = StageProfiler(2, min_samples=4)
        _feed(prof, [8.0, 24.0])
        d = planner.replan_from_profile(prof, max_stages=4, jit=False)
        assert not d.replanned and "failed verification" in d.reason
        reqs += [srv.submit(t) for t in toks[6:]]
        got = [r.wait(timeout=60.0) for r in reqs]      # zero drops
    for i, g in enumerate(got):
        np.testing.assert_allclose(np.asarray(g),
                                   np.full((4,), float(i)) + 4.0)
    st = srv.stats()
    assert st["requests_served"] == 12 and st["swaps"] == 0


# --------------------------------------------------------------------------- #
# gate 3: RequestQueueServer.swap_executor refuses a corrupted plan
# --------------------------------------------------------------------------- #
def test_swap_gate_refuses_corrupt_plan_then_accepts_valid_one():
    db, ir = _jit_pipe()
    pipe = PipelineGenerator(db).generate(ir, n_threads=2)
    toks = [jnp.full((4,), float(i + 1)) for i in range(8)]
    want = pipe.run_sequential(toks)
    ex_a = pipe.executor(max_in_flight=4)
    ex_b = pipe.executor(max_in_flight=4)
    bad = copy.deepcopy(pipe.plan)
    bad.stages = list(reversed(bad.stages))

    with RequestQueueServer(ex_a, max_batch=2, max_wait_ms=2.0) as srv:
        reqs = [srv.submit(t) for t in toks[:4]]
        with pytest.raises(PlanVerificationError) as ei:
            srv.swap_executor(ex_b, plan=bad, ir=pipe.ir, db=db)
        assert "swap_executor" in ei.value.where
        assert srv.executor is ex_a and srv.swaps == 0   # swap refused
        # the same swap with the real plan passes the gate
        old = srv.swap_executor(ex_b, plan=pipe.plan, ir=pipe.ir, db=db,
                                warm_args=(toks[0],))
        assert old is ex_a and srv.executor is ex_b and srv.swaps == 1
        reqs += [srv.submit(t) for t in toks[4:]]
        got = [r.wait(timeout=60.0) for r in reqs]       # zero drops
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-6)
    assert srv.stats()["requests_served"] == 8


# --------------------------------------------------------------------------- #
# ExecutorClosed: the close/submit race ends in an exception, not a hang
# --------------------------------------------------------------------------- #
def test_submit_after_close_raises_executor_closed():
    db, ir = _jit_pipe()
    pipe = PipelineGenerator(db).generate(ir, n_threads=2)
    ex = pipe.executor(max_in_flight=4)
    x = jnp.arange(4.0)
    ex.run([x])
    ex.close()
    with pytest.raises(ExecutorClosed):
        ex.submit_many([x])
    ex.close()                                  # idempotent


def test_concurrent_close_and_submit_does_not_hang():
    db, ir = _jit_pipe()
    pipe = PipelineGenerator(db).generate(ir, n_threads=2)
    ex = pipe.executor(max_in_flight=2)
    x = jnp.arange(4.0)
    ex.run([x])                                 # compile before racing
    errs, served = [], [0]

    def feeder():
        try:
            for _ in range(500):
                for h in ex.submit_many([x]):
                    h.result()
                served[0] += 1
        except (ExecutorClosed, SubmitError) as e:
            errs.append(e)

    t = threading.Thread(target=feeder)
    t.start()
    time.sleep(0.05)
    ex.close()
    t.join(timeout=30.0)
    assert not t.is_alive(), "submit hung against close()"
    assert errs or served[0] == 500             # race lost -> clean error
    st = ex.stats()
    assert st.tokens_admitted == st.tokens_retired   # nothing leaked


# --------------------------------------------------------------------------- #
# lint rules (file rules via lint_file over synthetic modules)
# --------------------------------------------------------------------------- #
def _findings(rule, path, src):
    ctx = LintContext(path, textwrap.dedent(src))
    return [d for d in lint_file(ctx) if d.rule == rule]


def test_lint_placement_literal():
    src = 'MODE = "hw"\n'
    assert len(_findings("placement-literal",
                         "src/repro/core/pipeline.py", src)) == 1
    # the parser module itself is the one place allowed to spell them
    assert _findings("placement-literal",
                     "src/repro/core/placement.py", src) == []
    # docstrings are exempt; suppression comment works
    assert _findings("placement-literal", "src/repro/core/x.py",
                     'def f():\n    "hw"\n') == []
    assert _findings("placement-literal", "src/repro/core/x.py",
                     'M = "hw"  # lint: ignore[placement-literal]\n') == []


LOCKED_CLASS = """
import threading

class Box:
    def __init__(self):
        self._lock = threading.Lock()
        self.n = 0

    def bump(self):
        with self._lock:
            self.n += 1

    def sneak(self):
        self.n = 5{owner}
"""


def test_lint_lock_discipline():
    bad = LOCKED_CLASS.format(owner="")
    finds = _findings("lock-discipline", "m.py", bad)
    assert len(finds) == 1 and "n" in finds[0].message
    ok = LOCKED_CLASS.format(owner="  # owner: stats thread")
    assert _findings("lock-discipline", "m.py", ok) == []


def test_lint_blocking_in_lock():
    bad = """
    class Q:
        def take(self, fut):
            with self._lock:
                return fut.result()
    """
    assert len(_findings("blocking-in-lock", "m.py", bad)) == 1
    bounded = """
    class Q:
        def take(self, fut):
            with self._lock:
                return fut.result(timeout=1.0)
    """
    assert _findings("blocking-in-lock", "m.py", bounded) == []
    sleepy = """
    import time

    class Q:
        def nap(self):
            with self._lock:
                time.sleep(1.0)
    """
    assert len(_findings("blocking-in-lock", "m.py", sleepy)) == 1


DATACLASS_SRC = """
from dataclasses import dataclass

@dataclass{frozen}
class P:{pragma}
    x: int = 0
"""


def test_lint_frozen_dataclass():
    bad = DATACLASS_SRC.format(frozen="", pragma="")
    assert len(_findings("frozen-dataclass",
                         "src/repro/analysis/synth.py", bad)) == 1
    # out of scope -> no finding even when mutable
    assert _findings("frozen-dataclass",
                     "src/repro/core/executor.py", bad) == []
    frozen = DATACLASS_SRC.format(frozen="(frozen=True)", pragma="")
    assert _findings("frozen-dataclass",
                     "src/repro/analysis/synth.py", frozen) == []
    allowed = DATACLASS_SRC.format(
        frozen="", pragma="  # lint: allow-mutable(test double)")
    assert _findings("frozen-dataclass",
                     "src/repro/analysis/synth.py", allowed) == []


def test_lint_acquire_without_finally():
    bad = """
    def f(lock):
        lock.acquire()
        work()
        lock.release()
    """
    finds = _findings("acquire-without-finally", "m.py", bad)
    assert len(finds) == 1 and "lock.acquire()" in finds[0].message
    good = """
    def f(lock):
        lock.acquire()
        try:
            work()
        finally:
            lock.release()
    """
    assert _findings("acquire-without-finally", "m.py", good) == []


def test_lint_dead_export():
    rule = PROJECT_RULES["dead-export"]
    mod_a = LintContext("src/pkg/a.py", textwrap.dedent("""
        def used():
            return 1

        def dead():
            return 2

        def kept():  # lint: allow-dead(public API)
            return 3

        def helper():
            return 4

        def recursive():
            return recursive()

        _x = helper()
    """))
    mod_b = LintContext("src/pkg/b.py", "from pkg.a import used\n")
    init = LintContext("src/pkg/__init__.py", "from .a import dead\n")
    finds = list(rule([mod_a], [mod_a, mod_b, init]))
    flagged = {d.message.split("'")[1] for d in finds}
    # 'dead' is only re-exported by the facade (doesn't count); 'recursive'
    # only references itself; 'helper' is genuinely used in-module; 'kept'
    # carries the pragma
    assert flagged == {"dead", "recursive"}


def test_lint_file_runs_all_file_rules():
    assert set(FILE_RULES) <= set(LINT_RULES)
    assert lint_file(LintContext("clean.py", "X = 1\n")) == []
