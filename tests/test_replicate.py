"""Parallel-filter stage replication: planner pass, executor dataflow,
ordered retirement, and the widen-vs-rebalance replan decision."""
import random
import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ModuleDatabase, Node, PipelinePlan, StagePlan,
                        StageProfiler, assign_replicas, linear_ir,
                        partition_optimal, replicated_bottleneck_ms)
from repro.core.executor import PipelineExecutor
from repro.runtime import ElasticPlanner


def _plan(times, **kw):
    return PipelinePlan(stages=[StagePlan(node_names=[f"n{i}"],
                                          est_time_ms=float(t), **kw)
                                for i, t in enumerate(times)])


# --------------------------------------------------------------------------- #
# cost model: replication-aware bottleneck
# --------------------------------------------------------------------------- #
def test_replicated_bottleneck_ms():
    assert replicated_bottleneck_ms([2.0, 6.0, 2.0], [1, 1, 1]) == 6.0
    assert replicated_bottleneck_ms([2.0, 6.0, 2.0], [1, 3, 1]) == 2.0
    # widening one stage exposes the next bottleneck
    assert replicated_bottleneck_ms([3.0, 6.0], [1, 4]) == 3.0
    assert replicated_bottleneck_ms([], []) == 0.0
    with pytest.raises(ValueError):
        replicated_bottleneck_ms([1.0], [1, 2])


def test_plan_effective_bottleneck_and_workers():
    p = _plan([2.0, 6.0, 2.0])
    assert p.effective_bottleneck_ms == p.bottleneck_ms == 6.0
    p.stages[1].replicas = 3
    assert p.bottleneck_ms == 6.0                 # service time unchanged
    assert p.effective_bottleneck_ms == 2.0       # throughput widened
    assert p.replicas == [1, 3, 1] and p.total_workers == 5
    assert "x3" in p.describe()


# --------------------------------------------------------------------------- #
# planner pass: assign_replicas
# --------------------------------------------------------------------------- #
def test_assign_replicas_widens_dominant_stage():
    p = _plan([0.5, 6.0, 0.5, 0.5])
    assign_replicas(p, worker_budget=8)
    assert p.total_workers <= 8
    assert p.stages[1].replicas == max(p.replicas)    # bottleneck widest
    assert p.effective_bottleneck_ms <= 6.0 / (p.stages[1].replicas - 1)
    # derived target beat the serial plan substantially
    assert p.effective_bottleneck_ms < 2.0


def test_assign_replicas_explicit_target_is_ceil_rule():
    p = _plan([2.0, 6.0, 3.0])
    assign_replicas(p, worker_budget=16, target_ms=1.0)
    assert p.replicas == [2, 6, 3]                    # ceil(t / target)
    p2 = _plan([2.0, 6.0, 3.0])
    assign_replicas(p2, worker_budget=6, target_ms=1.0)
    assert p2.total_workers <= 6                      # budget clamps the rule
    assert p2.stages[1].replicas >= p2.stages[0].replicas


def test_assign_replicas_budget_floor_and_max_replicas():
    p = _plan([1.0, 1.0])
    with pytest.raises(ValueError, match="worker_budget"):
        assign_replicas(p, worker_budget=1)
    p = _plan([0.5, 8.0])
    assign_replicas(p, worker_budget=12, max_replicas=2)
    assert max(p.replicas) <= 2


def test_assign_replicas_respects_serial_only_nodes():
    ir = linear_ir("s", ["a", "b", "c"], [1.0, 9.0, 1.0])
    ir.nodes[1].serial_only = True                    # the bottleneck is I/O
    plan = partition_optimal(ir, max_stages=3)
    assign_replicas(plan, ir, worker_budget=9)
    k = next(i for i, s in enumerate(plan.stages)
             if "b_1" in s.node_names)
    assert plan.stages[k].replicas == 1               # never widened
    # and the derived target respects the serial floor: no other stage is
    # widened past the point of helping (9 ms stays the period)
    assert plan.effective_bottleneck_ms == pytest.approx(9.0)


def test_serial_only_survives_fuse_and_split():
    from repro.core import fuse_adjacent_hw, split_fused_node

    db = ModuleDatabase("t")
    db.register("f", software=lambda x: x + 1.0, accelerated=lambda x: x + 1.0)
    db.register("g", software=lambda x: x * 2.0, accelerated=lambda x: x * 2.0)
    ir = linear_ir("x", ["f", "g"], [1.0, 1.0], io_shape=(4,))
    ir.nodes[0].serial_only = True
    fused = fuse_adjacent_hw(ir, db, fused_cost_ms=lambda run: 0.5)
    fnode = next(n for n in fused.nodes if n.fused_from)
    assert fnode.serial_only                          # any part marks the fuse
    back = split_fused_node(fused, fnode.name)
    assert all(n.serial_only for n in back.nodes)     # conservative split


# --------------------------------------------------------------------------- #
# executor: replicated dataflow correctness
# --------------------------------------------------------------------------- #
def _jnp_fns():
    def s0(env):
        return {"x": env["x"] + 1.0}

    def s1(env):
        return {"x": jnp.tanh(env["x"]) * 2.0}

    def s2(env):
        return {"y": env["x"] - 3.0}
    return [s0, s1, s2]


def test_replicated_results_match_serial_and_retire_in_order():
    toks = [(jnp.full((4,), float(i)),) for i in range(20)]
    ser = PipelineExecutor(_jnp_fns(), ["x"], ["y"], stage_workers=True)
    want = ser.run(toks)
    ser.close()
    rep = PipelineExecutor(_jnp_fns(), ["x"], ["y"], replicas=[2, 3, 2])
    got = rep.run(toks)
    st = rep.stats()
    rep.close()
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-6)
    assert st.out_of_order_retired == 0
    assert st.tokens_admitted == st.tokens_retired == 20
    assert [c.replicas for c in st.per_stage] == [2, 3, 2]


def test_replicated_with_microbatch_groups():
    toks = [(jnp.full((4,), float(i)),) for i in range(13)]   # ragged tail
    ser = PipelineExecutor(_jnp_fns(), ["x"], ["y"])
    want = ser.run(toks)
    rep = PipelineExecutor(_jnp_fns(), ["x"], ["y"], replicas=[1, 3, 1],
                           microbatch=4, max_in_flight=12)
    got = rep.run(toks)
    assert rep.stats().out_of_order_retired == 0
    rep.close()
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-6)


def test_replicated_validation_and_pool_default():
    fns = _jnp_fns()
    with pytest.raises(ValueError, match="every stage"):
        PipelineExecutor(fns, ["x"], ["y"], replicas=[2, 2])
    with pytest.raises(ValueError, match=">= 1"):
        PipelineExecutor(fns, ["x"], ["y"], replicas=[1, 0, 1])
    ex = PipelineExecutor(fns, ["x"], ["y"], replicas=[1, 3, 1])
    assert ex.pool == 5 + 1            # sum(replicas) + 1 default
    ex.close()
    with pytest.raises(RuntimeError, match="closed"):
        ex.submit(jnp.zeros(4))


def test_replicated_stage_error_propagates_and_frees_pool():
    def boom(env):
        if float(env["x"][0]) == 3.0:
            raise RuntimeError("kaboom")
        return {"x": env["x"]}

    def out(env):
        return {"y": env["x"] * 2.0}

    ex = PipelineExecutor([boom, out], ["x"], ["y"], replicas=[2, 1],
                          max_in_flight=4)
    handles = ex.submit_many([(np.full((2,), float(i)),) for i in range(6)])
    ok, failed = 0, 0
    for h in handles:
        try:
            h.result()
            ok += 1
        except RuntimeError:
            failed += 1
    assert (ok, failed) == (5, 1)
    st = ex.stats()
    assert st.tokens_admitted == st.tokens_retired == 6   # pool not leaked
    assert st.out_of_order_retired == 0                   # errors retire in order
    ex.close()


# --------------------------------------------------------------------------- #
# ordered-retirement determinism stress (randomized per-replica jitter)
# --------------------------------------------------------------------------- #
def test_ordered_retirement_under_random_replica_jitter():
    """Replicated stages with randomized per-call sleeps must retire every
    token in submission order with results identical to the serial
    executor — the reorder buffer, not luck, provides the ordering."""
    rng = random.Random(1234)

    def jittery(env):
        time.sleep(rng.uniform(0.0, 0.004))       # per-replica jitter
        return {"x": np.asarray(env["x"]) * 2.0 + 1.0}

    def tail(env):
        time.sleep(rng.uniform(0.0, 0.002))
        return {"y": np.asarray(env["x"]) - 5.0}

    toks = [(np.full((3,), float(i)),) for i in range(40)]
    ser = PipelineExecutor([jittery, tail], ["x"], ["y"], stage_workers=True)
    want = ser.run(toks)
    ser.close()
    rep = PipelineExecutor([jittery, tail], ["x"], ["y"], replicas=[4, 3],
                           max_in_flight=10)
    got = rep.run(toks)
    st = rep.stats()
    rep.close()
    assert st.out_of_order_retired == 0
    assert st.tokens_retired == 40
    for i, (g, w) in enumerate(zip(got, want)):
        # value encodes the token index: any slot/seq mix-up shows here
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   err_msg=f"token {i}")
        np.testing.assert_allclose(np.asarray(g), float(i) * 2.0 - 4.0)


def test_replicated_concurrent_submitters_stay_ordered_per_thread():
    def double(env):
        time.sleep(0.001)
        return {"y": np.asarray(env["x"]) * 2.0}

    ex = PipelineExecutor([double], ["x"], ["y"], replicas=[4],
                          max_in_flight=8)
    results: dict[int, list] = {}

    def worker(tid):
        hs = ex.submit_many([(np.full((2,), tid * 100.0 + i),)
                             for i in range(10)])
        results[tid] = [float(np.asarray(h.result())[0]) for h in hs]

    threads = [threading.Thread(target=worker, args=(t,)) for t in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    ex.close()
    assert ex.stats().out_of_order_retired == 0
    for tid, vals in results.items():
        assert vals == [(tid * 100.0 + i) * 2.0 for i in range(10)]


# --------------------------------------------------------------------------- #
# profiler: per-replica attribution
# --------------------------------------------------------------------------- #
def test_profiler_per_replica_attribution():
    p = StageProfiler(2, min_samples=1)
    for _ in range(4):
        p.record(0, 10.0, replica=0)
        p.record(0, 30.0, replica=1)
    p.record(1, 5.0)                        # serial stage: no replica index
    assert p.measured_ms(0) == pytest.approx(20.0)   # aggregate = service
    reps = p.replica_ms(0)
    assert set(reps) == {0, 1}
    assert reps[0] == pytest.approx(10.0) and reps[1] == pytest.approx(30.0)
    assert p.replica_ms(1) == {}
    snap = p.snapshot()
    assert snap["per_stage"][0]["replicas"]["1"]["samples"] == 4
    assert "replicas" not in snap["per_stage"][1]
    p.reset()
    assert p.replica_ms(0) == {}


def test_replicated_executor_feeds_replica_profile():
    def slow(env):
        time.sleep(0.002)
        return {"y": np.asarray(env["x"]) + 1.0}

    prof = StageProfiler(1, min_samples=2)
    ex = PipelineExecutor([slow], ["x"], ["y"], replicas=[3],
                          max_in_flight=6, profiler=prof)
    ex.run([(np.zeros(2),) for _ in range(12)])
    ex.close()
    assert prof.samples(0) == 12
    reps = prof.replica_ms(0)
    assert set(reps) <= {0, 1, 2} and len(reps) == 3   # all replicas worked
    assert all(v >= 1.0 for v in reps.values())


# --------------------------------------------------------------------------- #
# replan integration: widen beats rebalance on a one-dominant-node chain
# --------------------------------------------------------------------------- #
DELAYS: dict[str, float] = {}


def _impl(key):
    def sw(x):
        time.sleep(DELAYS[key] / 1e3)
        return np.asarray(x) + 1.0
    sw.__name__ = key
    return sw


def _dominant_planner(times=(0.5, 6.0, 0.5, 0.5)):
    keys = [f"f{i}" for i in range(len(times))]
    DELAYS.clear()
    DELAYS.update(dict(zip(keys, times)))
    db = ModuleDatabase("wide")
    for k in keys:
        db.register(k, software=_impl(k))
    ir = linear_ir("wide", keys, list(times), io_shape=(4,))
    return ElasticPlanner(ir, db=db)


def test_replan_widen_beats_rebalance_on_dominant_stage():
    planner = _dominant_planner()
    prof = StageProfiler(4, min_samples=4)
    ex, _ = planner.executor_for(4, max_in_flight=10, jit=False,
                                 profiler=prof, stage_workers=True)
    boundaries0 = [list(s.node_names) for s in planner.current_plan.stages]
    toks = [np.full((4,), float(i)) for i in range(12)]
    ex.run(toks)
    d = planner.replan_from_profile(prof, worker_budget=8, jit=False)
    assert d.replanned and d.widened, d.describe()
    assert d.replicas is not None and max(d.replicas) > 1
    # boundaries did NOT move: widening reuses every stage identity
    assert [list(s.node_names) for s in d.plan.stages] == boundaries0
    assert d.new_bottleneck_ms < d.old_bottleneck_ms / 1.5
    out = d.executor.run(toks)
    np.testing.assert_allclose(np.asarray(out[0]),
                               np.full((4,), 4.0))     # 4 increments
    assert d.executor.stats().out_of_order_retired == 0
    d.executor.close()
    ex.close()


def test_replan_widened_plan_is_stable_not_flapping():
    planner = _dominant_planner()
    prof = StageProfiler(4, min_samples=4)
    ex, _ = planner.executor_for(4, max_in_flight=10, jit=False,
                                 profiler=prof, stage_workers=True)
    ex.run([np.full((4,), float(i)) for i in range(12)])
    d = planner.replan_from_profile(prof, worker_budget=8, jit=False)
    assert d.replanned and d.widened
    ex.close()
    # steady state: same measured service times -> same widen decision ->
    # "plan unchanged", call after call
    for trial in range(3):
        prof2 = StageProfiler(d.plan.n_stages, min_samples=4)
        rng = np.random.default_rng(trial)
        for _ in range(8):
            for k, s in enumerate(d.plan.stages):
                prof2.record(k, s.est_time_ms * (1 + 0.1 * rng.uniform(-1, 1)))
        d2 = planner.replan_from_profile(prof2, worker_budget=8, jit=False)
        assert not d2.replanned, f"flapped on trial {trial}: {d2.reason}"
    assert planner.replans == 1
    d.executor.close()


def test_replan_without_budget_keeps_legacy_rebalance_path():
    planner = _dominant_planner((2.0, 2.0, 2.0, 2.0, 2.0, 2.0))
    prof = StageProfiler(3, min_samples=4)
    planner.executor_for(3, jit=False)
    for _ in range(6):
        for k, t in enumerate([4.0, 12.0, 4.0]):
            prof.record(k, t)
    d = planner.replan_from_profile(prof, max_stages=6, jit=False)
    assert d.replanned and not d.widened and d.plan.replicas == \
        [1] * d.plan.n_stages


def test_executor_for_worker_budget_builds_replicated_executor():
    planner = _dominant_planner()
    ex, rebuilt = planner.executor_for(4, jit=False, worker_budget=8,
                                       max_in_flight=10)
    assert rebuilt
    assert any(r > 1 for r in planner.current_plan.replicas)
    assert ex.replicas == planner.current_plan.replicas
    out = ex.run([np.full((4,), 0.0)])
    np.testing.assert_allclose(np.asarray(out[0]), np.full((4,), 4.0))
    # same request again: cached, not rebuilt
    ex2, rebuilt2 = planner.executor_for(4, jit=False, worker_budget=8,
                                         max_in_flight=10)
    assert ex2 is ex and not rebuilt2
    ex.close()


# --------------------------------------------------------------------------- #
# _pad_for: microbatch is the explicit final bucket; no silent new sizes
# --------------------------------------------------------------------------- #
def test_pad_for_buckets_include_microbatch_and_guard():
    def stage(env):
        return {"y": env["x"] * 2.0}

    ex = PipelineExecutor([stage], ["x"], ["y"], max_in_flight=8,
                          microbatch=8, pad_microbatches=True,
                          buckets=(2, 4))
    assert ex.buckets == (2, 4, 8)        # microbatch appended explicitly
    assert ex._pad_for(5) == 3            # -> final bucket 8, a warmed size
    with pytest.raises(RuntimeError, match="exceeds every pad bucket"):
        # only reachable by bypassing the microbatch grouping cap
        ex.buckets = (2, 4)
        ex._pad_for(5)


def test_pad_for_all_buckets_filtered_still_explicit():
    def stage(env):
        return {"y": env["x"]}

    ex = PipelineExecutor([stage], ["x"], ["y"], max_in_flight=8,
                          microbatch=4, pad_microbatches=True,
                          buckets=(16, 32))          # all above microbatch
    assert ex.buckets == (4,)
    assert ex._pad_for(3) == 1
