"""Optimizer / data / checkpoint / runtime substrates."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.checkpoint import CheckpointStore
from repro.core import linear_ir
from repro.data import PrefetchIterator, SyntheticLMData
from repro.optim import adamw_init, adamw_update, clip_by_global_norm, \
    cosine_schedule, global_norm
from repro.runtime import (ElasticPlanner, FaultTolerantDriver,
                           StragglerMonitor)


# --------------------------------------------------------------------------- #
# optimizer
# --------------------------------------------------------------------------- #
def test_adamw_minimizes_quadratic():
    params = {"w": jnp.array([5.0, -3.0])}
    opt = adamw_init(params)
    target = jnp.array([1.0, 2.0])
    for _ in range(300):
        g = jax.grad(lambda p: jnp.sum((p["w"] - target) ** 2))(params)
        params, opt, _ = adamw_update(g, opt, params, lr=5e-2,
                                      weight_decay=0.0)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=1e-2)


@settings(max_examples=30, deadline=None)
@given(st.floats(min_value=0.01, max_value=10.0))
def test_clip_by_global_norm_property(max_norm):
    g = {"a": jnp.arange(6.0).reshape(2, 3), "b": jnp.ones(4) * 7}
    clipped, norm = clip_by_global_norm(g, max_norm)
    post = float(global_norm(clipped))
    assert post <= max_norm * (1 + 1e-5) or post <= float(norm) + 1e-5


def test_cosine_schedule_shape():
    lr = cosine_schedule(1e-3, warmup=10, total=100)
    assert float(lr(0)) == pytest.approx(0.0)
    assert float(lr(10)) == pytest.approx(1e-3, rel=1e-3)
    assert float(lr(100)) < 1e-5


# --------------------------------------------------------------------------- #
# data
# --------------------------------------------------------------------------- #
def test_data_is_deterministic_per_step():
    d = SyntheticLMData(vocab=97, seq_len=32, global_batch=4, seed=3)
    b1, b2 = d.batch(7), d.batch(7)
    np.testing.assert_array_equal(b1.ids, b2.ids)
    assert not np.array_equal(d.batch(8).ids, b1.ids)
    # next-token alignment
    np.testing.assert_array_equal(b1.ids[:, 1:], b1.labels[:, :-1])


def test_prefetch_iterator_preserves_order():
    d = SyntheticLMData(vocab=17, seq_len=8, global_batch=2)
    it = iter(d)
    pre = PrefetchIterator((d.batch(i) for i in range(5)), depth=2)
    got = [b.ids for b in pre]
    want = [d.batch(i).ids for i in range(5)]
    for g, w in zip(got, want):
        np.testing.assert_array_equal(g, w)


# --------------------------------------------------------------------------- #
# checkpoint
# --------------------------------------------------------------------------- #
def test_checkpoint_roundtrip(tmp_path):
    store = CheckpointStore(str(tmp_path), keep=2)
    tree = {"w": jnp.arange(12.0).reshape(3, 4),
            "nested": {"b": jnp.ones(5, jnp.bfloat16)}}
    store.save(10, tree, {"next_step": 10})
    got, extra = store.restore(None, like=tree)
    assert extra["next_step"] == 10
    np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(tree["w"]))
    assert got["nested"]["b"].dtype == jnp.bfloat16


def test_checkpoint_keep_last_k(tmp_path):
    store = CheckpointStore(str(tmp_path), keep=2)
    tree = {"w": jnp.zeros(3)}
    for s in (1, 2, 3, 4):
        store.save(s, tree)
    assert store.steps() == [3, 4]


def test_checkpoint_detects_corruption(tmp_path):
    store = CheckpointStore(str(tmp_path))
    tree = {"w": jnp.arange(4.0)}
    path = store.save(1, tree)
    # flip bytes in the array file
    f = os.path.join(path, "arrays.npz")
    data = bytearray(open(f, "rb").read())
    data[-20] ^= 0xFF
    open(f, "wb").write(bytes(data))
    with pytest.raises(Exception):
        store.restore(1, like=tree)


def test_checkpoint_async_save(tmp_path):
    store = CheckpointStore(str(tmp_path))
    tree = {"w": jnp.arange(4.0)}
    store.save_async(5, tree, {"next_step": 5})
    store.wait()
    got, extra = store.restore(None, like=tree)
    np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(tree["w"]))


# --------------------------------------------------------------------------- #
# fault-tolerant driver
# --------------------------------------------------------------------------- #
class _ToyData:
    def __init__(self):
        self.d = SyntheticLMData(vocab=11, seq_len=4, global_batch=2, seed=0)

    def batch(self, step):
        return self.d.batch(step)


def _toy_step(state, batch):
    w = state["w"] - 0.1
    return {"w": w}, {"loss": jnp.sum(w * w)}


def test_driver_restarts_from_checkpoint(tmp_path):
    from repro.runtime.faults import FaultPlan

    store = CheckpointStore(str(tmp_path))
    # a scripted step fault fires ONCE, so the restart's replay of step 7
    # succeeds (the legacy fail_hook= path is covered in test_faults.py)
    drv = FaultTolerantDriver(_toy_step, store, _ToyData(), ckpt_every=5,
                              async_ckpt=False,
                              faults=FaultPlan().fail_step([7]))
    state, res = drv.run({"w": jnp.ones(3)}, n_steps=12)
    assert res.restarts == 1
    assert res.steps_done == 12
    # resumed from step 5: total applied updates == 12 (deterministic replay)
    np.testing.assert_allclose(np.asarray(state["w"]),
                               np.ones(3) - 0.1 * 12, rtol=1e-5)


def test_driver_resume_across_runs(tmp_path):
    store = CheckpointStore(str(tmp_path))
    drv = FaultTolerantDriver(_toy_step, store, _ToyData(), ckpt_every=5,
                              async_ckpt=False)
    _, res1 = drv.run({"w": jnp.ones(3)}, n_steps=5)
    # brand-new driver (fresh process restart) picks up at step 5
    drv2 = FaultTolerantDriver(_toy_step, store, _ToyData(), ckpt_every=5,
                               async_ckpt=False)
    state, res2 = drv2.run({"w": jnp.ones(3)}, n_steps=10)
    assert res2.steps_done == 10
    np.testing.assert_allclose(np.asarray(state["w"]),
                               np.ones(3) - 0.1 * 10, atol=1e-6)


def test_straggler_monitor_flags_outliers():
    mon = StragglerMonitor(threshold=3.0)
    for i in range(10):
        assert not mon.record(i, 1.0)
    assert mon.record(10, 10.0)          # 10x median
    assert mon.flagged and mon.flagged[0][0] == 10


def test_elastic_planner_rebalances():
    """Device loss → re-run the Courier partitioner for fewer stages."""
    ir = linear_ir("layers", [f"L{i}" for i in range(12)],
                   [1, 1, 1, 5, 1, 1, 1, 5, 1, 1, 1, 5])
    planner = ElasticPlanner(ir)
    b4 = planner.boundaries(4)
    b3 = planner.boundaries(3)           # one stage group lost
    assert len(b4) == 4 and len(b3) == 3
    assert b4[0] == b3[0] == 0
    assert planner.plan(3).bottleneck_ms >= planner.plan(4).bottleneck_ms
