"""Multi-device placement: per-replica device pinning, cross-device
transfer accounting, ordered retirement across devices, and the serial →
multi-device hot-swap — all under a forced 4-host-device jax
(``JAX_PLATFORMS=cpu`` + ``XLA_FLAGS=--xla_force_host_platform_device_
count=4``), run in subprocesses because the parent's jax is already
initialized single-device."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_forced(script: str, n_devices: int = 4,
                timeout: float = 600.0) -> subprocess.CompletedProcess:
    env = dict(os.environ)
    env["JAX_PLATFORMS"] = "cpu"
    flags = env.get("XLA_FLAGS", "")
    env["XLA_FLAGS"] = (flags + " " if flags else "") + \
        f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(ROOT, "src"), ROOT]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    return subprocess.run([sys.executable, "-c", script],
                          capture_output=True, text=True, timeout=timeout,
                          env=env, cwd=ROOT)


PLACEMENT_SCRIPT = textwrap.dedent("""
    import random, threading, time
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.core import (DeviceInventory, StageProfiler, transfer_ms,
                            linear_ir, partition_optimal, assign_replicas)
    from repro.core.executor import PipelineExecutor

    inv = DeviceInventory.detect()
    assert len(inv) == 4, jax.devices()
    assert inv.jax_device(2) is jax.devices()[2]

    # --- per-replica device pinning: committed results cycle the devices ---
    ex = PipelineExecutor([lambda env: {"y": env["x"] * 2.0}], ["x"], ["y"],
                          replicas=[4], devices=[[0, 1, 2, 3]],
                          inventory=inv, max_in_flight=8)
    hs = ex.submit_many([(jnp.full((8,), float(i)),) for i in range(8)])
    for i, h in enumerate(hs):
        out = h.result()
        np.testing.assert_allclose(np.asarray(out), float(i) * 2.0)
        (dev,) = out.devices()
        assert dev is inv.jax_device(i % 4), (i, dev)
    assert ex.stats().out_of_order_retired == 0
    # per-stage counters carry the pinning
    assert ex.stats().per_stage[0].devices == [0, 1, 2, 3]
    assert ex.stats().per_stage[0].xfer_ms > 0.0
    ex.close()

    # warmup on a pinned executor submits one group per replica ring, so
    # every device builds its executable before traffic (seq coverage)
    exw = PipelineExecutor([lambda env: {"y": env["x"] * 2.0}], ["x"], ["y"],
                           replicas=[4], devices=[[0, 1, 2, 3]],
                           inventory=inv, max_in_flight=8)
    exw.warmup(jnp.zeros((8,)))
    assert exw._seq == 4, exw._seq
    exw.close()

    # --- ordered retirement across devices under randomized jitter ---
    rng = random.Random(7)
    def jittery(env):
        time.sleep(rng.uniform(0.0, 0.004))
        return {"x": env["x"] * 2.0 + 1.0}
    def tail(env):
        time.sleep(rng.uniform(0.0, 0.002))
        return {"y": env["x"] - 5.0}
    prof = StageProfiler(2, min_samples=1)
    rep = PipelineExecutor([jittery, tail], ["x"], ["y"],
                           replicas=[4, 2], devices=[[0, 1, 2, 3], [0, 1]],
                           inventory=inv, max_in_flight=10, profiler=prof)
    toks = [(jnp.full((4,), float(i)),) for i in range(32)]
    got = rep.run(toks)
    st = rep.stats()
    rep.close()
    assert st.out_of_order_retired == 0
    assert st.tokens_retired == 32
    for i, g in enumerate(got):
        np.testing.assert_allclose(np.asarray(g), float(i) * 2.0 - 4.0)
    # per-device attribution landed in the profiler snapshot
    snap = prof.snapshot()
    assert len(snap["per_stage"][0]["devices"]) == 4, snap["per_stage"][0]
    assert set(prof.device_ms(1)) <= {0, 1} and len(prof.device_ms(1)) == 2

    # --- cross-device boundary transfer accounting on a real inventory ---
    ir = linear_ir("x", ["f0", "f1"], [2.0, 2.0], io_shape=(512, 512))
    plan = partition_optimal(ir, max_stages=2)
    assign_replicas(plan, ir, worker_budget=4, inventory=inv)
    nbytes = plan.stages[1].comm_in_bytes
    assert nbytes == 512 * 512 * 4
    if set(plan.stages[0].devices) != set(plan.stages[1].devices):
        want = transfer_ms(nbytes, inv.device_class(0).xfer_bw)
        assert abs(plan.stages[1].xfer_in_ms - want) < 1e-9
        assert plan.stages[1].xfer_in_ms > 0.0
    # multi-device plan + known ir: stage 0 is charged the graph inputs'
    # host-side staging (every admitted group is device_put)
    if len({d for s in plan.stages for d in s.devices}) > 1:
        in_bytes = sum(ir.values[v].nbytes for v in ir.graph_inputs)
        want0 = transfer_ms(in_bytes, inv.device_class(0).xfer_bw)
        assert abs(plan.stages[0].xfer_in_ms - want0) < 1e-9
    print("PLACEMENT-OK")
""")


@pytest.mark.slow
def test_multidevice_pinning_ordering_and_transfer_accounting():
    """Per-replica device pinning (committed ``.devices()`` audit), ordered
    retirement across devices, per-device profiler attribution, and
    cross-device boundary transfer accounting on 4 forced host devices."""
    r = _run_forced(PLACEMENT_SCRIPT)
    assert "PLACEMENT-OK" in r.stdout, r.stderr[-3000:]


@pytest.mark.slow
def test_devices_benchmark_meets_acceptance():
    """The committed acceptance numbers, measured live: a replicated hw
    stage pins each replica to a distinct device, delivers >= 1.5x
    tokens/s over the serial plan, and a mid-stream serial → multi-device
    hot-swap completes with zero dropped requests."""
    sys.path.insert(0, ROOT)
    from benchmarks import devices

    p = devices.payload(smoke=True)
    sim, pin, hs = p["sim"], p["pinning"], p["hot_swap"]
    assert pin["distinct"] == devices.N_DEVICES
    assert pin["out_of_order"] == 0
    assert sim["distinct_devices"] == max(sim["replicas"])
    assert sim["speedup"] >= 1.5, sim
    assert sim["out_of_order"] == 0
    assert sim["xfer_accounted"] is True
    assert sim["devices_profiled"] == sim["distinct_devices"]
    assert hs["dropped"] == 0 and hs["served"] == hs["requests"]
    assert hs["swaps"] == 1 and hs["out_of_order"] == 0


SERVE_SCRIPT = textwrap.dedent("""
    from repro.launch.serve import serve_pipeline_demo

    stats = serve_pipeline_demo(n_requests=12, max_batch=2, max_wait_ms=2.0,
                                worker_budget="auto", devices=4,
                                size=(48, 64))
    assert stats["requests_served"] == 12, stats
    assert stats["executor"]["out_of_order_retired"] == 0
    print("SERVE-OK", stats["requests_served"])
""")


@pytest.mark.slow
def test_serve_demo_with_devices_and_auto_budget():
    """`--devices`/`--worker-budget auto` path: the serving demo plans
    against the detected inventory and serves every request."""
    r = _run_forced(SERVE_SCRIPT)
    assert "SERVE-OK 12" in r.stdout, r.stderr[-3000:]
