"""Schema guard for the committed machine-readable benchmark artifact.

``BENCH_pipeline.json`` is the perf trajectory tracked across PRs; if its
keys or types drift silently, cross-PR comparisons quietly break.  The fast
test validates the committed file against an explicit schema; the slow test
runs the actual smoke benchmark (the same code path as ``benchmarks/run.py
--smoke``) and asserts it emits a key-superset of the committed file.
"""
import json
import numbers
import os

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BENCH_PATH = os.path.join(ROOT, "BENCH_pipeline.json")

# key -> type (dict = nested schema validated recursively; extra keys in the
# file are allowed so ADDING metrics never breaks the guard, but the keys
# below must exist with these types)
NUM = numbers.Real
# per-priority-class outcome block in the overload sweep (one dict object
# reused for all classes/rates — the validator only reads it)
_OVL_CLASS = {
    "submitted": int, "served": int, "shed": int, "expired": int,
    "failed": int, "goodput": NUM, "p50_ms": NUM, "p99_ms": NUM,
    "p999_ms": NUM,
}
_OVL_RATE = {
    "offered_rps": NUM, "submitted": int, "served": int, "shed": int,
    "expired": int, "failed": int, "unresolved": int, "accounted": bool,
    "slo_violation_rate": NUM, "interactive": _OVL_CLASS,
    "batch": _OVL_CLASS, "best_effort": _OVL_CLASS,
}
# one admission mode of the continuous-batching decode benchmark (the
# boundary and continuous entries share this shape)
_DEC_MODE = {
    "p50_ttft_ms": NUM, "p95_ttft_ms": NUM, "submitted": int,
    "served": int, "dropped": int, "seam_joins": int,
    "release_errors": int, "out_of_order": int, "recompiles_steady": int,
    "slot_stats": {"n_slots": int, "live": int, "allocs": int,
                   "frees": int, "high_water": int},
}
SCHEMA = {
    "bench": str,
    "smoke": bool,
    "shape": list,
    "n_frames": int,
    "tokens_per_sec": {
        "sequential": NUM, "wavefront": NUM, "async": NUM, "fused": NUM,
    },
    "bottleneck_ms": {
        "pipeline": NUM, "fused_pipeline": NUM, "unfused_pipeline": NUM,
    },
    "per_frame_ms": {
        "sequential_ms": NUM, "staged_ms": NUM, "wavefront_ms": NUM,
        "async_ms": NUM, "microbatch_ms": NUM,
    },
    "compile_count_steady": int,
    "fusion": {
        "harris_kernel": {"chain_ms": NUM, "fused_ms": NUM, "speedup": NUM},
        "pipeline": {
            "fused": {"bottleneck_ms": NUM, "tokens_per_sec": NUM,
                      "n_stages": int, "compile_count": int},
            "unfused": {"bottleneck_ms": NUM, "tokens_per_sec": NUM},
            "speedup_fused_vs_unfused": NUM,
        },
        "roofline": {"traffic_reduction": NUM, "hbm_bytes_saved": NUM},
    },
    "trace": {
        "transformer": {
            "n_nodes": int, "n_stages": int, "fused_nodes": list,
            "captured_inputs": int, "token_inputs": int,
            "tps_sequential": NUM, "tps_async": NUM, "speedup": NUM,
            "results_match": bool,
        },
        "recurrent": {"n_nodes": int, "results_match": bool},
        "serving": {
            "requests": int, "latency_p95_ms": NUM, "results_match": bool,
            "fused_nodes": list, "captured_inputs": int,
        },
    },
    "replan": {
        "sim": {
            "tps_before_slowdown": NUM, "tps_static": NUM,
            "tps_adaptive": NUM, "recovery": NUM, "replanned": bool,
            "slowdown": NUM, "n_stages": int,
        },
        "hot_swap": {
            "requests": int, "served": int, "dropped": int, "swaps": int,
            "recompiles_after_warmup": int,
        },
    },
    "replicate": {
        "sim": {
            "tps_serial": NUM, "tps_replicated": NUM, "speedup": NUM,
            "widened": bool, "replicas": list, "worker_budget": int,
            "out_of_order": int,
        },
        "hot_swap": {
            "requests": int, "served": int, "dropped": int, "swaps": int,
            "recompiles_after_warmup": int, "replicas": list,
            "out_of_order": int,
        },
    },
    "devices": {
        "pinning": {"result_devices": list, "distinct": int,
                    "out_of_order": int},
        "sim": {
            "n_devices": int, "tps_serial": NUM, "tps_replicated": NUM,
            "speedup": NUM, "replicas": list, "bottleneck_devices": list,
            "distinct_devices": int, "devices_profiled": int,
            "xfer_accounted": bool, "out_of_order": int,
            "worker_budget": int,
        },
        "hot_swap": {
            "requests": int, "served": int, "dropped": int, "swaps": int,
            "out_of_order": int,
        },
    },
    "faults": {
        "device_loss": {
            "requests": int, "served": int, "dropped": int,
            "out_of_order": int, "retries": int, "quarantined": int,
            "lost_device": int, "replicas_before": list,
            "replicas_after": list, "tps_before": NUM, "tps_after": NUM,
            "tps_survivor": NUM, "recovery": NUM, "swaps": int,
            "replanned": bool,
        },
        "transient": {
            "requests": int, "served": int, "dropped": int,
            "out_of_order": int, "retries": int, "quarantined": int,
            "errors_injected": int, "tps_clean": NUM, "tps_faulty": NUM,
            "recovery": NUM, "results_match": bool,
        },
        "harris_transient": {
            "requests": int, "served": int, "dropped": int,
            "out_of_order": int, "retries": int, "errors_injected": int,
            "replicas": list, "results_match": bool,
        },
    },
    "overload": {
        "capacity_rps": NUM, "period_ms": NUM, "duration_s": NUM,
        "mix": list,
        "deadline_ms": {"interactive": NUM, "batch": NUM},
        "sweep": {"0.7x": _OVL_RATE, "1x": _OVL_RATE, "2x": _OVL_RATE},
        "chaos": {
            "offered_rps": NUM, "capacity_rps": NUM, "submitted": int,
            "served": int, "shed": int, "expired": int, "failed": int,
            "unresolved": int, "accounted": bool, "out_of_order": int,
            "retries": int, "quarantined": int, "errors_injected": int,
            "lost_device": int, "replanned": bool, "swaps": int,
            "interactive_goodput": NUM,
        },
    },
    "decode": {
        "n_sessions": int, "steps_per_session": int,
        "capacity_steps_per_s": NUM, "offered_steps_per_s": NUM,
        "load": NUM, "p50_ttft_improvement": NUM, "results_match": bool,
        "boundary": _DEC_MODE, "continuous": _DEC_MODE,
    },
}


def _validate(obj, schema, path="$"):
    problems = []
    if not isinstance(obj, dict):
        return [f"{path}: expected object, got {type(obj).__name__}"]
    for key, want in schema.items():
        if key not in obj:
            problems.append(f"{path}.{key}: missing")
            continue
        val = obj[key]
        if isinstance(want, dict):
            problems.extend(_validate(val, want, f"{path}.{key}"))
        elif want is NUM:
            # bool is a Real subclass in Python; a bool here is a type drift
            if isinstance(val, bool) or not isinstance(val, numbers.Real):
                problems.append(f"{path}.{key}: expected number, "
                                f"got {type(val).__name__}")
        elif not isinstance(val, want):
            problems.append(f"{path}.{key}: expected {want.__name__}, "
                            f"got {type(val).__name__}")
    return problems


def _key_paths(obj, prefix="$"):
    """All dict key paths in a nested JSON object (leaves and interior)."""
    paths = set()
    if isinstance(obj, dict):
        for k, v in obj.items():
            p = f"{prefix}.{k}"
            paths.add(p)
            paths.update(_key_paths(v, p))
    return paths


def test_committed_bench_json_matches_schema():
    assert os.path.exists(BENCH_PATH), "BENCH_pipeline.json not committed"
    with open(BENCH_PATH) as f:
        data = json.load(f)
    problems = _validate(data, SCHEMA)
    assert not problems, "BENCH_pipeline.json drifted:\n  " + \
        "\n  ".join(problems)
    # sanity on the acceptance-critical numbers, not just their types
    assert data["replan"]["sim"]["recovery"] >= 1.3
    assert data["replan"]["hot_swap"]["dropped"] == 0
    assert data["replan"]["hot_swap"]["recompiles_after_warmup"] == 0
    assert data["replicate"]["sim"]["speedup"] >= 1.5
    assert data["replicate"]["sim"]["out_of_order"] == 0
    assert data["replicate"]["hot_swap"]["dropped"] == 0
    assert data["replicate"]["hot_swap"]["out_of_order"] == 0
    assert data["replicate"]["hot_swap"]["recompiles_after_warmup"] == 0
    assert data["tokens_per_sec"]["sequential"] > 0
    # trace-to-pipeline acceptance (ISSUE 8): the async traced pipeline
    # >= 1.5x sequential tokens/s, bit-exact vs the untraced model, the
    # registered mega-kernel fired on the traced graph, and closure
    # weights were captured (one per-token input remains)
    trc = data["trace"]
    assert trc["transformer"]["speedup"] >= 1.5
    assert trc["transformer"]["results_match"] is True
    assert trc["transformer"]["fused_nodes"]
    assert trc["transformer"]["captured_inputs"] >= 1
    assert trc["transformer"]["token_inputs"] == 1
    assert trc["recurrent"]["results_match"] is True
    assert trc["serving"]["results_match"] is True
    # multi-device placement acceptance: each replica of the widened stage
    # on its own device, >= 1.5x over serial, zero drops across the swap
    dev = data["devices"]
    assert dev["sim"]["speedup"] >= 1.5
    assert dev["sim"]["distinct_devices"] == max(dev["sim"]["replicas"])
    assert dev["sim"]["distinct_devices"] == dev["sim"]["n_devices"]
    assert dev["sim"]["xfer_accounted"] is True
    assert dev["sim"]["out_of_order"] == 0
    assert dev["pinning"]["distinct"] == dev["sim"]["n_devices"]
    assert dev["pinning"]["out_of_order"] == 0
    assert dev["hot_swap"]["dropped"] == 0
    assert dev["hot_swap"]["out_of_order"] == 0
    # fault-tolerance acceptance: zero drops through a mid-run device loss
    # AND a transient burst, in-order retirement throughout, post-recovery
    # throughput within 0.8x of the survivors-only optimum, and retried
    # results identical to the fault-free run
    flt = data["faults"]
    assert flt["device_loss"]["dropped"] == 0
    assert flt["device_loss"]["out_of_order"] == 0
    assert flt["device_loss"]["quarantined"] >= 1
    assert flt["device_loss"]["replanned"] is True
    assert flt["device_loss"]["recovery"] >= 0.8
    assert flt["transient"]["dropped"] == 0
    assert flt["transient"]["out_of_order"] == 0
    assert flt["transient"]["recovery"] >= 0.8
    assert flt["transient"]["results_match"] is True
    assert flt["harris_transient"]["dropped"] == 0
    assert flt["harris_transient"]["results_match"] is True
    # overload acceptance (ISSUE 9): under 2x sustained overload the
    # interactive class keeps its SLO (p99 within deadline, goodput >=
    # 0.9x offered — shedding lands on best-effort), every request is
    # accounted for (submitted == served + shed + expired + failed,
    # nothing blocked forever), and the chaos variant (2x overload +
    # transients + device loss) retires in order with zero unaccounted
    ovl = data["overload"]
    for rate, entry in ovl["sweep"].items():
        assert entry["accounted"] is True, f"{rate} lost requests"
        assert entry["unresolved"] == 0, f"{rate} left requests blocked"
    hot = ovl["sweep"]["2x"]
    assert hot["interactive"]["goodput"] >= 0.9
    assert hot["interactive"]["p99_ms"] <= ovl["deadline_ms"]["interactive"]
    assert hot["best_effort"]["shed"] >= hot["interactive"]["shed"]
    assert ovl["chaos"]["accounted"] is True
    assert ovl["chaos"]["unresolved"] == 0
    assert ovl["chaos"]["out_of_order"] == 0
    assert ovl["chaos"]["errors_injected"] >= 1
    assert ovl["chaos"]["replanned"] is True
    # continuous-batching decode acceptance (ISSUE 10): continuous
    # admission improves p50 TTFT >= 1.5x over batch-boundary (cohort)
    # admission at 0.8x capacity, with zero drops, in-order retirement,
    # no steady-state recompiles, bitwise-identical outputs, a live join
    # seam, and a leak-free slot arena on both paths
    dec = data["decode"]
    assert dec["p50_ttft_improvement"] >= 1.5
    assert dec["results_match"] is True
    assert dec["continuous"]["seam_joins"] >= 1
    for mode in ("boundary", "continuous"):
        m = dec[mode]
        assert m["served"] == m["submitted"], f"decode.{mode} lost requests"
        assert m["dropped"] == 0
        assert m["out_of_order"] == 0
        assert m["recompiles_steady"] == 0
        assert m["release_errors"] == 0
        assert m["slot_stats"]["live"] == 0
        assert m["slot_stats"]["allocs"] == m["slot_stats"]["frees"]


@pytest.mark.slow
def test_smoke_benchmark_emits_superset_of_committed_keys(tmp_path):
    """`benchmarks/run.py --smoke` writes a key-superset of the committed
    artifact, so the smoke CI path exercises every committed metric."""
    import sys
    sys.path.insert(0, ROOT)              # benchmarks/ is a root package
    from benchmarks.table1_pipeline import write_bench_json

    with open(BENCH_PATH) as f:
        committed = json.load(f)
    out = write_bench_json(path=str(tmp_path / "bench.json"), smoke=True)
    with open(out) as f:
        smoke = json.load(f)
    missing = _key_paths(committed) - _key_paths(smoke)
    assert not missing, f"smoke payload lost keys: {sorted(missing)}"
    assert not _validate(smoke, SCHEMA)
