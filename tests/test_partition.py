"""Property tests for the Pipeline Generator's partitioners (hypothesis)."""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (fuse_adjacent_hw, linear_ir, ModuleDatabase,
                        partition_optimal, partition_paper)

times_strategy = st.lists(
    st.floats(min_value=0.1, max_value=1000.0, allow_nan=False,
              allow_infinity=False),
    min_size=1, max_size=24)


def _brute_force_bottleneck(times, k):
    """Optimal contiguous-partition bottleneck by exhaustive search."""
    n = len(times)
    best = float("inf")

    def rec(i, parts_left, cur_best_max):
        nonlocal best
        if parts_left == 1:
            best = min(best, max(cur_best_max, sum(times[i:])))
            return
        for j in range(i + 1, n - parts_left + 2):
            rec(j, parts_left - 1, max(cur_best_max, sum(times[i:j])))
    rec(0, k, 0.0)
    return best


@settings(max_examples=60, deadline=None)
@given(times_strategy)
def test_paper_policy_invariants(times):
    ir = linear_ir("t", [f"f{i}" for i in range(len(times))], times)
    plan = partition_paper(ir, n_threads=2)
    # contiguous cover: every node in exactly one stage, original order
    names = [n for s in plan.stages for n in s.node_names]
    assert names == [n.name for n in ir.nodes]
    # stage times = sum of member times
    for s in plan.stages:
        want = sum(ir.node(n).time_ms for n in s.node_names)
        assert s.est_time_ms == pytest.approx(want)
    # pipelining never loses throughput vs sequential
    assert plan.bottleneck_ms <= sum(times) + 1e-9
    assert plan.predicted_speedup() >= 1.0 - 1e-9


@settings(max_examples=60, deadline=None)
@given(times_strategy)
def test_optimal_dp_beats_or_ties_paper_policy(times):
    ir = linear_ir("t", [f"f{i}" for i in range(len(times))], times)
    paper = partition_paper(ir, n_threads=2)
    opt = partition_optimal(ir)
    assert opt.bottleneck_ms <= paper.bottleneck_ms + 1e-9


@settings(max_examples=40, deadline=None)
@given(st.lists(st.floats(min_value=0.1, max_value=100.0), min_size=1,
                max_size=9),
       st.integers(min_value=1, max_value=4))
def test_optimal_dp_matches_brute_force(times, k):
    k = min(k, len(times))
    ir = linear_ir("t", [f"f{i}" for i in range(len(times))], times)
    opt = partition_optimal(ir, max_stages=k)
    want = min(_brute_force_bottleneck(times, kk) for kk in range(1, k + 1))
    assert opt.bottleneck_ms == pytest.approx(want, rel=1e-9)


def test_fusion_accepts_fast_rejects_slow():
    db = ModuleDatabase("t")
    for f in ("a", "b", "c"):
        db.register(f, software=lambda x: x, accelerated=lambda x: x)
    db.register("d", software=lambda x: x)        # sw-only breaks the run
    ir = linear_ir("t", ["a", "b", "d", "c"], [10.0, 20.0, 5.0, 7.0])

    # estimator says fused(a,b) runs at max(10,20) → accept
    fused = fuse_adjacent_hw(ir, db, fused_cost_ms=lambda run: 20.0)
    assert [n.fn_key for n in fused.nodes] == ["a+b", "d", "c"]
    assert fused.nodes[0].time_ms == pytest.approx(20.0)
    fused.validate()

    # estimator says fused module is too slow → reject (paper's observed case)
    kept = fuse_adjacent_hw(ir, db, fused_cost_ms=lambda run: 100.0)
    assert [n.fn_key for n in kept.nodes] == ["a", "b", "d", "c"]


def test_fusion_never_crosses_sw_nodes():
    db = ModuleDatabase("t")
    for f in ("a", "b"):
        db.register(f, software=lambda x: x, accelerated=lambda x: x)
    db.register("s", software=lambda x: x)
    ir = linear_ir("t", ["a", "s", "b"], [1.0, 1.0, 1.0])
    fused = fuse_adjacent_hw(ir, db, fused_cost_ms=lambda run: 0.1)
    assert [n.fn_key for n in fused.nodes] == ["a", "s", "b"]
