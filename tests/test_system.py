"""End-to-end behaviour of the Courier toolchain (paper Steps 1-9)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CourierIR, Frontend, Library, ModuleDatabase,
                        OffloadPlan, PipelineGenerator, courier_offload,
                        deploy, linear_ir, partition_paper)
from repro.models.harris import corner_harris_demo, make_harris_db


def _demo_db():
    db = ModuleDatabase("t")
    db.register("f1", software=lambda x: x * 2.0, accelerated=lambda x: x * 2.0)
    db.register("f2", software=lambda x: x + 1.0)                  # sw-only
    db.register("f3", software=lambda x: x * x, accelerated=lambda x: x * x)
    return db


def _app(db):
    lib = Library(db)

    def app(x):
        return lib.f3(lib.f2(lib.f1(x)))
    return app


def test_trace_builds_causal_graph():
    db = _demo_db()
    app = _app(db)
    ir, out = Frontend(db).trace(app, jnp.arange(4.0))
    assert [n.fn_key for n in ir.nodes] == ["f1", "f2", "f3"]
    assert ir.is_linear_chain()
    assert ir.graph_inputs == ["d0"]
    assert len(ir.graph_outputs) == 1
    ir.validate()
    # profile log captured
    assert all(n.time_ms is not None and n.time_ms >= 0 for n in ir.nodes)
    # I/O metadata (the paper's "bit-depth")
    assert ir.values["d0"].shape == (4,)
    assert ir.values["d0"].bit_depth == 32


def test_offloaded_function_matches_original():
    db = _demo_db()
    app = _app(db)
    x = jnp.arange(8.0)
    off = courier_offload(app, x, db=db)
    np.testing.assert_allclose(off(x), app(x))
    # db hit → hw, miss → sw (paper's placement rule); the structured
    # Placement carries the backend kind
    placements = {n.fn_key: n.placement.kind for n in off.ir.nodes}
    assert placements == {"f1": "hw", "f2": "sw", "f3": "hw"}
    assert off.ir.nodes[0].placement.is_hw


def test_token_pipeline_equals_sequential():
    db = _demo_db()
    app = _app(db)
    off = courier_offload(app, jnp.arange(8.0), db=db)
    toks = [jnp.full((8,), float(i)) for i in range(7)]
    got = off.map(toks)
    want = [app(t) for t in toks]
    for g, w in zip(got, want):
        np.testing.assert_allclose(g, w)


def test_offload_switcher_falls_back_on_failure():
    db = ModuleDatabase("t")

    def boom(x):
        raise RuntimeError("hw module died")
    db.register("f", software=lambda x: x + 1.0, accelerated=boom)
    lib = Library(db)
    plan = OffloadPlan(decisions={"f": "hw"})
    with deploy(plan):
        out = lib.f(jnp.zeros(3))            # must not raise
    np.testing.assert_allclose(out, np.ones(3))
    assert plan.fallback_log and "hw module died" in plan.fallback_log[0]


def test_switch_to_original_path():
    db = _demo_db()
    app = _app(db)
    off = courier_offload(app, jnp.arange(4.0), db=db)
    off.switch("original")
    np.testing.assert_allclose(off(jnp.arange(4.0)), app(jnp.arange(4.0)))


def test_user_ir_edit_hook():
    """Paper Steps 6-7: the user may pin a node to software."""
    db = _demo_db()
    app = _app(db)

    def edit(ir: CourierIR) -> CourierIR:
        ir.node("f1_0").placement = "sw"
        return ir

    off = courier_offload(app, jnp.arange(4.0), db=db, edit_ir=edit,
                          prefer_hw=False)
    np.testing.assert_allclose(off(jnp.arange(4.0)), app(jnp.arange(4.0)))


# --------------------------------------------------------------------------- #
# Paper reproduction anchors (Table I)
# --------------------------------------------------------------------------- #
PAPER_FNS = ["cvtColor", "cornerHarris", "normalize", "convertScaleAbs"]
PAPER_OFFL = [39.8, 13.6, 80.2, 13.2]       # post-offload stage times [ms]
PAPER_TOTAL_ORIG = 1371.1
PAPER_MEASURED_SPEEDUP = 15.36


def test_paper_policy_reproduces_four_stage_plan():
    ir = linear_ir("harris", PAPER_FNS, PAPER_OFFL)
    plan = partition_paper(ir, n_threads=3)
    assert plan.n_stages == 4                      # paper built 4 stages
    assert plan.bottleneck_ms == pytest.approx(80.2)
    # predicted speedup vs the original binary ≈ paper's measured 15.36x
    pred = PAPER_TOTAL_ORIG / plan.bottleneck_ms
    assert pred == pytest.approx(17.1, abs=0.1)
    assert pred >= PAPER_MEASURED_SPEEDUP          # measured includes overhead
    # stage kinds: serial_in_order endpoints, parallel middle (TBB filters)
    kinds = [s.kind for s in plan.stages]
    assert kinds[0] == kinds[-1] == "serial_in_order"
    assert all(k == "parallel" for k in kinds[1:-1])


def test_harris_app_end_to_end():
    """The paper's own case study through the whole toolchain."""
    db = make_harris_db(with_hw=True)
    lib = Library(db)
    app = corner_harris_demo(lib)
    img = jax.random.uniform(jax.random.PRNGKey(0), (32, 64, 3)) * 255
    off = courier_offload(app, img, db=db, prefer_hw=False)
    np.testing.assert_allclose(off(img), app(img), rtol=1e-5, atol=1e-4)
    # normalize must remain a software function (no hw module, paper Table I)
    placements = {n.fn_key: n.placement for n in off.ir.nodes}
    assert placements["normalize"].is_sw


def test_harris_app_with_hw_kernels():
    db = make_harris_db(with_hw=True)
    lib = Library(db)
    app = corner_harris_demo(lib)
    img = jax.random.uniform(jax.random.PRNGKey(1), (32, 64, 3)) * 255
    off = courier_offload(app, img, db=db, prefer_hw=True)
    hw = {n.fn_key for n in off.ir.nodes if n.placement.is_hw}
    assert hw == {"cvtColor", "cornerHarris", "convertScaleAbs"}
    ref = app(img)
    got = off(img)
    scale = float(jnp.max(jnp.abs(ref)))
    np.testing.assert_allclose(np.asarray(got) / scale,
                               np.asarray(ref) / scale, atol=1e-4)
