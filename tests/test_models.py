"""Per-arch smoke tests: reduced config, one fwd + one train step on CPU."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import LM

KEY = jax.random.PRNGKey(0)
B, S = 2, 16


def _inputs(c):
    ids = jax.random.randint(KEY, (B, S), 0, c.vocab)
    kw = {}
    if c.embeds_in:
        kw["embeds"] = jax.random.normal(KEY, (B, S, c.d_model), jnp.float32)
    if c.cross_attn_every:
        kw["img_embeds"] = jax.random.normal(
            KEY, (B, c.n_img_tokens, c.d_model), jnp.float32)
    return ids, kw


@pytest.mark.slow
@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke(arch):
    c = get_config(arch).reduced()
    m = LM(c)
    params = m.init(KEY)
    ids, kw = _inputs(c)

    # forward: shape + finiteness
    h, aux = m.apply(params, None if c.embeds_in else ids, **kw)
    assert h.shape == (B, S, c.d_model)
    assert np.isfinite(np.asarray(h, np.float32)).all()

    # one train step: loss finite, grads finite and nonzero
    def loss_fn(p):
        hh, aux = m.apply(p, None if c.embeds_in else ids, **kw)
        l = m.loss(p, hh, ids, chunk=8)
        if c.n_experts:
            l = l + 1e-2 * aux["load_balance_loss"]
        return l

    l, g = jax.value_and_grad(loss_fn)(params)
    assert np.isfinite(float(l))
    gsum = sum(float(jnp.sum(jnp.abs(x.astype(jnp.float32))))
               for x in jax.tree.leaves(g))
    assert np.isfinite(gsum) and gsum > 0

    # optimizer application keeps params finite
    from repro.optim import adamw_init, adamw_update
    opt = adamw_init(params)
    p2, opt2, metrics = adamw_update(g, opt, params, lr=1e-3)
    assert np.isfinite(float(metrics["grad_norm"]))
    assert all(np.isfinite(np.asarray(x, np.float32)).all()
               for x in jax.tree.leaves(p2))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_prefill_decode_consistency(arch):
    """prefill hidden == full-forward hidden; decode step runs and is finite."""
    c = get_config(arch).reduced()
    m = LM(c)
    params = m.init(KEY)
    ids, kw = _inputs(c)
    h, _ = m.apply(params, None if c.embeds_in else ids, remat=False, **kw)
    cache = m.init_cache(B, S + 4)
    hp, cache = m.prefill(params, None if c.embeds_in else ids, cache, **kw)
    lf = m.logits(params, h)[:, -1]
    lp = m.logits(params, hp[:, -1:])[:, 0]
    np.testing.assert_allclose(np.asarray(lf), np.asarray(lp),
                               rtol=1e-4, atol=1e-4)
    dkw = {"embeds": kw["embeds"][:, :1]} if c.embeds_in else {}
    lg, cache = m.decode_step(params, None if c.embeds_in else ids[:, :1],
                              cache, S, **dkw)
    assert lg.shape == (B, 1, c.vocab)
    assert np.isfinite(np.asarray(lg, np.float32)).all()


def test_incremental_decode_matches_full_forward():
    """Greedy decode token-by-token == slicing a longer full forward (dense)."""
    c = get_config("gemma3-12b").reduced()
    m = LM(c)
    params = m.init(KEY)
    ids = jax.random.randint(KEY, (1, 12), 0, c.vocab)
    h_full, _ = m.apply(params, ids, remat=False)
    logits_full = m.logits(params, h_full)

    cache = m.init_cache(1, 16)
    hp, cache = m.prefill(params, ids[:, :8], cache)
    logits = [m.logits(params, hp[:, -1:])[:, 0]]
    for t in range(8, 12):
        lg, cache = m.decode_step(params, ids[:, t:t + 1], cache, t)
        if t < 11:
            logits.append(lg[:, 0])
    got = jnp.stack(logits, axis=1)          # positions 7..10
    want = logits_full[:, 7:11]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_scan_chunks_equivalence():
    """Nested-remat scan must not change the forward function."""
    c = get_config("deepseek-67b").reduced(n_layers=4)
    m = LM(c)
    params = m.init(KEY)
    ids = jax.random.randint(KEY, (B, S), 0, c.vocab)
    h1, _ = m.apply(params, ids, scan_chunks=0)
    h2, _ = m.apply(params, ids, scan_chunks=2)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                               rtol=1e-5, atol=1e-5)


def test_chunked_attention_equals_unchunked():
    """The q-blocked softmax path == the direct path (same model, long seq)."""
    import repro.models.layers as L
    c = get_config("mistral-large-123b").reduced(n_layers=2)
    m = LM(c)
    params = m.init(KEY)
    ids = jax.random.randint(KEY, (1, 4 * L.Q_CHUNK), 0, c.vocab)
    h1, _ = m.apply(params, ids, remat=False)      # chunked path (T >= 2*Q_CHUNK)
    old = L.Q_CHUNK
    try:
        L.Q_CHUNK = 10 ** 9                        # force direct path
        h2, _ = m.apply(params, ids, remat=False)
    finally:
        L.Q_CHUNK = old
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                               rtol=2e-4, atol=2e-4)
