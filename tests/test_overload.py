"""Overload-protection tests: priority classes, EDF + starvation credit,
admission control, degradation ladder, end-to-end deadlines, exactly-once
accounting under racy interleavings, and the Poisson generator's
seeded determinism.

The serving layer's core claim is an invariant, not a number: every
submitted request resolves **exactly once** into served / shed / expired /
failed, no matter how submit, stop, deadlines, and the batcher interleave.
The property-style test here drives randomized interleavings against that
claim; the unit tests pin the individual mechanisms the invariant is built
from.
"""
import os
import sys
import threading
import time

import numpy as np
import pytest

from repro.core import ModuleDatabase, StageProfiler, linear_ir
from repro.core.executor import ExecutorClosed
from repro.launch.serve import (BATCH, BEST_EFFORT, INTERACTIVE,
                                PRIORITY_CLASSES, AdmissionController,
                                DeadlineExceeded, Overloaded, Request,
                                RequestQueueServer, WaitTimeout,
                                _ClassedQueue, _percentile, priority_of)
from repro.runtime import ElasticPlanner, ReplanDecision

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

DELAYS: dict = {}


def _impl(key):
    def sw(x):
        time.sleep(DELAYS[key] / 1e3)
        return np.asarray(x) + 1.0
    sw.__name__ = key
    return sw


def _chain_planner(times=(1.0, 2.0), **kw):
    keys = [f"f{i}" for i in range(len(times))]
    DELAYS.clear()
    DELAYS.update(dict(zip(keys, times)))
    db = ModuleDatabase("overload-chain")
    for k in keys:
        db.register(k, software=_impl(k))
    ir = linear_ir("overload-chain", keys, list(times), io_shape=(4,))
    return ElasticPlanner(ir, db=db, **kw)


def _executor(times=(1.0, 2.0), **kw):
    planner = _chain_planner(times)
    ex, _ = planner.executor_for(len(times), jit=False, **kw)
    return ex, planner


# --------------------------------------------------------------------------- #
# _percentile: exact linear interpolation + p999 (satellite 3)
# --------------------------------------------------------------------------- #
def test_percentile_matches_numpy_linear_interpolation():
    rng = np.random.default_rng(0)
    for n in (1, 2, 3, 7, 100, 999):
        xs = list(rng.uniform(0, 50, size=n))
        for q in (0, 50, 95, 99, 99.9, 100):
            assert _percentile(xs, q) == pytest.approx(
                float(np.percentile(np.asarray(xs), q)), rel=1e-12)


def test_percentile_filters_nonfinite_and_none():
    xs = [1.0, None, float("nan"), 3.0, float("inf"), 2.0]
    assert _percentile(xs, 50) == 2.0
    assert _percentile([], 99) == 0.0
    assert _percentile([None, float("nan")], 50) == 0.0


def test_latency_summary_has_tail_quantiles():
    ex, _ = _executor()
    with RequestQueueServer(ex, max_batch=2, max_wait_ms=1.0) as srv:
        for _ in range(4):
            srv.submit(np.ones(4)).wait(timeout=30.0)
    lat = srv.stats()["latency_ms"]
    for k in ("mean", "p50", "p95", "p99", "p999", "max"):
        assert k in lat and lat[k] > 0
    assert lat["p50"] <= lat["p99"] <= lat["p999"] <= lat["max"]
    ex.close()


# --------------------------------------------------------------------------- #
# Request.wait timeout distinguishability (satellite 1)
# --------------------------------------------------------------------------- #
def test_wait_timeout_raises_distinct_timeout_error():
    r = Request(args=(1,), t_submit=time.perf_counter())
    with pytest.raises(WaitTimeout):
        r.wait(timeout=0.01)
    # WaitTimeout (my wait gave up) and DeadlineExceeded (the server
    # failed the request) are both TimeoutError but distinguishable
    assert issubclass(WaitTimeout, TimeoutError)
    assert issubclass(DeadlineExceeded, TimeoutError)
    assert not issubclass(WaitTimeout, DeadlineExceeded)
    assert not issubclass(DeadlineExceeded, WaitTimeout)
    # a later wait still observes a late resolution (nothing was consumed)
    r.result = 42
    r._event.set()
    assert r.wait(timeout=0.01) == 42


def test_priority_of_accepts_names_and_indices():
    assert priority_of("interactive") == INTERACTIVE
    assert priority_of("best-effort") == BEST_EFFORT
    assert priority_of(BATCH) == BATCH
    with pytest.raises(ValueError):
        priority_of("platinum")
    with pytest.raises(ValueError):
        priority_of(3)


# --------------------------------------------------------------------------- #
# _ClassedQueue: EDF within class, strict priority across, starvation credit
# --------------------------------------------------------------------------- #
def _req(priority=INTERACTIVE, deadline_ms=None):
    return Request(args=(), t_submit=time.perf_counter(),
                   deadline_ms=deadline_ms, priority=priority)


def test_classed_queue_edf_within_class():
    q = _ClassedQueue(16)
    late = _req(deadline_ms=500.0)
    soon = _req(deadline_ms=10.0)
    never = _req()                      # no deadline: after every deadlined
    for r in (late, never, soon):
        assert q.put(r) == "ok"
    order = [q.get_first(lambda: False)[0] for _ in range(3)]
    assert order == [soon, late, never]


def test_classed_queue_strict_priority_across_classes():
    q = _ClassedQueue(16)
    be = _req(priority=BEST_EFFORT)
    ia = _req(priority=INTERACTIVE)
    ba = _req(priority=BATCH)
    for r in (be, ba, ia):
        q.put(r)
    got = [q.get_first(lambda: False)[0] for _ in range(3)]
    assert got == [ia, ba, be]


def test_classed_queue_starvation_credit_grants_trickle():
    credit = 3
    q = _ClassedQueue(64, credit=credit)
    q.put(_req(priority=BATCH))
    picks = []
    for _ in range(credit + 1):
        q.put(_req(priority=INTERACTIVE))
        r, override = q.get_first(lambda: False)
        picks.append((r.priority, override))
    # the batch request was passed over `credit` times, then granted a
    # trickle batch (override flag True) ahead of waiting interactive work
    assert picks[:credit] == [(INTERACTIVE, False)] * credit
    assert picks[credit] == (BATCH, True)
    # the interactive request enqueued in the last round is still there
    r, override = q.get_first(lambda: False)
    assert (r.priority, override) == (INTERACTIVE, False)


def test_classed_queue_put_full_and_closed():
    q = _ClassedQueue(1)
    assert q.put(_req()) == "ok"
    assert q.put(_req(), block=False) == "full"
    q.close()
    assert q.put(_req(), block=False) == "closed"
    assert q.put(_req(), block=True) == "closed"   # close unblocks producers


def test_classed_queue_depth_upto_counts_higher_classes():
    q = _ClassedQueue(16)
    q.put(_req(priority=INTERACTIVE))
    q.put(_req(priority=BATCH))
    q.put(_req(priority=BEST_EFFORT))
    assert q.depth_upto(INTERACTIVE) == 1
    assert q.depth_upto(BATCH) == 2
    assert q.depth_upto(BEST_EFFORT) == 3
    assert q.depths() == [1, 1, 1]


# --------------------------------------------------------------------------- #
# AdmissionController
# --------------------------------------------------------------------------- #
def test_admission_predicted_wait_and_deadline_shed():
    adm = AdmissionController(period_ms=10.0, batch_hint=1)
    assert adm.predicted_wait_ms(0) == 0.0
    assert adm.predicted_wait_ms(5) == 50.0
    # infeasible deadline at submit time -> shed with a reason
    reason = adm.admit(priority=INTERACTIVE, deadline_ms=30.0,
                       depth_ahead=5, depth_total=5)
    assert reason is not None and "deadline" in reason
    assert adm.shed[INTERACTIVE] == 1
    assert adm.shed_reasons["deadline"] == 1
    # feasible deadline -> admitted
    assert adm.admit(priority=INTERACTIVE, deadline_ms=80.0,
                     depth_ahead=5, depth_total=5) is None
    assert adm.admitted[INTERACTIVE] == 1


def test_admission_batch_hint_groups_the_wait():
    adm = AdmissionController(period_ms=10.0, batch_hint=4)
    assert adm.predicted_wait_ms(4) == 10.0     # one dispatch group
    assert adm.predicted_wait_ms(5) == 20.0     # spills into a second


def test_admission_ladder_sheds_best_effort_then_degrades_wait():
    adm = AdmissionController(period_ms=10.0, slo_ref_ms=100.0,
                              shed_at=0.5, degrade_at=1.0,
                              degraded_wait_scale=0.5)
    # level 0: everything admitted
    assert adm.admit(priority=BEST_EFFORT, deadline_ms=None,
                     depth_ahead=0, depth_total=4) is None
    assert adm.max_wait_scale() == 1.0
    # level 1 (backlog > 50 ms): best-effort shed, batch still admitted
    reason = adm.admit(priority=BEST_EFFORT, deadline_ms=None,
                       depth_ahead=0, depth_total=6)
    assert reason is not None and "ladder" in reason
    assert adm.admit(priority=BATCH, deadline_ms=None,
                     depth_ahead=0, depth_total=6) is None
    assert adm.max_wait_scale() == 1.0
    # level 2 (backlog > 100 ms): also shrink the batcher's max wait
    assert adm.admit(priority=BEST_EFFORT, deadline_ms=None,
                     depth_ahead=0, depth_total=11) is not None
    assert adm.max_wait_scale() == 0.5
    snap = adm.snapshot()
    assert snap["level"] == 2
    assert snap["shed"]["best_effort"] == 2
    assert snap["shed_reasons"]["ladder"] == 2


def test_admission_from_plan_and_update_period():
    planner = _chain_planner((1.0, 2.0))
    planner.executor_for(2, jit=False)[0].close()
    plan = planner.current_plan
    adm = AdmissionController.from_plan(plan, max_batch=4)
    assert adm.period_ms == pytest.approx(plan.effective_bottleneck_ms)
    assert adm.batch_hint == 4
    adm.update_period(7.5)
    assert adm.period_ms == 7.5
    adm.update_period(0.0)                     # ignored: not a valid period
    assert adm.period_ms == 7.5


def test_profiler_effective_period_feeds_admission():
    prof = StageProfiler(2, min_samples=2)
    assert prof.effective_period_ms() is None  # no samples yet
    for _ in range(3):
        prof.record(0, 2.0)
        prof.record(1, 8.0)
    assert prof.effective_period_ms() == pytest.approx(8.0)
    # replication-aware: the widened bottleneck drains r-wide
    assert prof.effective_period_ms([1, 4]) == pytest.approx(2.0)
    assert prof.effective_period_ms([1, 2, 3]) is None   # wrong shape


# --------------------------------------------------------------------------- #
# Server integration: shedding, priorities, end-to-end deadlines
# --------------------------------------------------------------------------- #
def test_server_sheds_instead_of_blocking_with_admission():
    ex, _ = _executor((1.0, 5.0))
    adm = AdmissionController(period_ms=5.0, batch_hint=1)
    with RequestQueueServer(ex, max_batch=2, max_wait_ms=1.0,
                            admission=adm) as srv:
        r = srv.submit(np.ones(4), deadline_ms=2.0)   # infeasible: depth>0
        ok = srv.submit(np.ones(4))                   # no deadline: admitted
        # the first submit lands before any dispatch: in_flight 0, queue 0
        # -> admitted; pile on until prediction crosses the deadline
        sheds = [srv.submit(np.ones(4), deadline_ms=1.0) for _ in range(8)]
        shed_errors = 0
        for s in sheds:
            try:
                s.wait(timeout=30.0)
            except Overloaded:
                shed_errors += 1
            except DeadlineExceeded:
                pass
        ok.wait(timeout=30.0)
        try:
            r.wait(timeout=30.0)
        except (Overloaded, DeadlineExceeded):
            pass
    st = srv.stats()
    assert shed_errors >= 1                    # fast-fails, not queue waits
    assert st["admission"]["shed_reasons"]["deadline"] >= 1
    assert st["submitted"] == st["requests_served"] + st["shed"] \
        + st["expired"] + st["failed"]
    ex.close()


def test_end_to_end_deadline_fails_at_retirement_not_late():
    ex, _ = _executor((1.0, 30.0))             # slow stage: ~31 ms service
    with RequestQueueServer(ex, max_batch=1, max_wait_ms=0.5) as srv:
        r = srv.submit(np.ones(4), deadline_ms=5.0)   # dispatches, too slow
        with pytest.raises(DeadlineExceeded):
            r.wait(timeout=30.0)
    st = srv.stats()
    assert st["expired"] == 1
    assert st["classes"]["interactive"]["expired"] == 1
    assert st["slo_violation_rate"] == 1.0
    ex.close()


def test_interactive_served_before_batch_backlog():
    ex, _ = _executor((1.0, 4.0))
    with RequestQueueServer(ex, max_batch=2, max_wait_ms=0.5,
                            queue_depth=64) as srv:
        batch = [srv.submit(np.ones(4), priority=BATCH) for _ in range(10)]
        ia = srv.submit(np.ones(4), priority="interactive")
        ia.wait(timeout=30.0)
        done_batch = sum(1 for b in batch if b.t_done is not None)
        # the interactive request overtook most of the earlier batch backlog
        assert done_batch < len(batch)
        for b in batch:
            b.wait(timeout=30.0)
    st = srv.stats()
    assert st["classes"]["interactive"]["served"] == 1
    assert st["classes"]["batch"]["served"] == 10
    ex.close()


def test_stats_backcompat_keys_and_rejected():
    ex, _ = _executor()
    srv = RequestQueueServer(ex, max_batch=2, max_wait_ms=1.0).start()
    srv.submit(np.ones(4)).wait(timeout=30.0)
    srv.stop()
    st = srv.stats()
    for k in ("requests_served", "batches", "mean_batch_size",
              "throughput_rps", "latency_ms", "queue_ms_mean", "queue_depth",
              "rejected", "swaps", "executor", "profile"):
        assert k in st
    assert st["requests_served"] == 1 and st["rejected"] == 0
    assert st["executor"]["tokens_failed"] == 0
    r = srv.submit(np.ones(4))                 # post-stop: shed
    with pytest.raises(ExecutorClosed):
        r.wait(timeout=5.0)
    assert srv.stats()["rejected"] == 1
    ex.close()


# --------------------------------------------------------------------------- #
# Property-style: every request resolves exactly once (satellite 4)
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_every_request_resolves_exactly_once_under_races(seed):
    rng = np.random.default_rng(seed)
    ex, _ = _executor((0.5, 1.0))
    adm = AdmissionController(period_ms=1.5, batch_hint=1,
                              slo_ref_ms=60.0) \
        if seed % 2 == 0 else None
    srv = RequestQueueServer(ex, max_batch=3, max_wait_ms=1.0,
                             queue_depth=8, admission=adm).start()
    reqs: list = []
    lock = threading.Lock()

    def submitter(tseed):
        trng = np.random.default_rng(tseed)
        for _ in range(20):
            pri = int(trng.integers(0, 3))
            dl = float(trng.uniform(1.0, 40.0)) \
                if trng.random() < 0.5 else None
            r = srv.submit(np.ones(4), deadline_ms=dl, priority=pri)
            with lock:
                reqs.append(r)
            time.sleep(float(trng.uniform(0, 0.003)))

    threads = [threading.Thread(target=submitter, args=(seed * 10 + i,))
               for i in range(4)]
    for t in threads:
        t.start()
    # stop races the submitters mid-stream on odd seeds
    if seed % 2 == 1:
        time.sleep(float(rng.uniform(0.01, 0.05)))
        srv.stop()
    for t in threads:
        t.join()
    if seed % 2 == 0:
        srv.stop()

    st = srv.stats()
    # exactly-once: the per-class counters account for every submission...
    assert st["submitted"] == len(reqs) == 80
    assert st["submitted"] == st["requests_served"] + st["shed"] \
        + st["expired"] + st["failed"]
    per_class = [st["classes"][name] for name in PRIORITY_CLASSES]
    for c in per_class:
        assert c["submitted"] == c["served"] + c["shed"] + c["expired"] \
            + c["failed"]
    # ...and every request object resolved (event set, outcome visible):
    # nothing is left blocked in wait() forever
    for r in reqs:
        try:
            r.wait(timeout=10.0)
            assert r.error is None and r.t_done is not None
        except WaitTimeout:
            pytest.fail("request never resolved (blocked forever)")
        except (Overloaded, DeadlineExceeded, ExecutorClosed):
            pass
    ex.close()


def test_stop_wakes_idle_batcher_promptly():
    """Satellite 2: no 0.02 s poll — an idle server stops in well under
    one legacy poll interval."""
    ex, _ = _executor()
    srv = RequestQueueServer(ex, max_batch=4, max_wait_ms=50.0).start()
    time.sleep(0.05)                  # batcher parks on the empty queue
    t0 = time.perf_counter()
    srv.stop()
    assert time.perf_counter() - t0 < 0.5
    ex.close()


def test_swap_executor_wakes_idle_batcher():
    ex, planner = _executor()
    ex2, _ = planner.executor_for(2, jit=False, max_in_flight=5)
    with RequestQueueServer(ex, max_batch=2, max_wait_ms=1.0) as srv:
        srv.submit(np.ones(4)).wait(timeout=30.0)
        time.sleep(0.02)              # batcher idle-blocked on the queue
        old = srv.swap_executor(ex2, timeout=10.0)
        assert old is ex and srv.executor is ex2
        srv.submit(np.ones(4)).wait(timeout=30.0)
    assert srv.stats()["swaps"] == 1
    ex.close()
    ex2.close()


# --------------------------------------------------------------------------- #
# SLO feedback into the replanner
# --------------------------------------------------------------------------- #
def test_slo_violation_rate_waives_replan_hysteresis():
    planner = _chain_planner((4.0, 4.0))
    prof = StageProfiler(2, min_samples=2)
    ex, _ = planner.executor_for(2, jit=False, profiler=prof)
    # measured 4.0/4.4 with a 3-worker budget: widening the slow stage to
    # 2 replicas predicts effective max(4.0, 4.4/2) = 4.0 ms — a 1.1x
    # win, below the default 1.15x hysteresis gate
    for _ in range(6):
        prof.record(0, 4.0)
        prof.record(1, 4.4)
    d_calm = planner.replan_from_profile(prof, worker_budget=3,
                                         slo_violation_rate=0.0)
    assert not d_calm.replanned and "hysteresis" in d_calm.reason
    # the same profile under SLO pressure: any predicted win justifies
    # the (zero-drop) swap, so hysteresis is waived
    d_hot = planner.replan_from_profile(prof, worker_budget=3,
                                        slo_violation_rate=0.2)
    assert d_hot.replanned
    assert "SLO pressure" in d_hot.reason
    ex.close()
    if d_hot.executor is not None:
        d_hot.executor.close()


# --------------------------------------------------------------------------- #
# Poisson load generator: seeded determinism (satellite 4)
# --------------------------------------------------------------------------- #
def test_poisson_schedule_deterministic_per_seed():
    sys.path.insert(0, ROOT)          # benchmarks/ is a root package
    from benchmarks.overload import poisson_schedule

    a1, c1 = poisson_schedule(200.0, 2.0, seed=42)
    a2, c2 = poisson_schedule(200.0, 2.0, seed=42)
    np.testing.assert_array_equal(a1, a2)
    np.testing.assert_array_equal(c1, c2)
    a3, _ = poisson_schedule(200.0, 2.0, seed=43)
    assert len(a1) != len(a3) or not np.array_equal(a1, a3)
    # sanity: roughly the offered rate, classes within range, sorted times
    assert len(a1) == pytest.approx(400, rel=0.25)
    assert np.all(np.diff(a1) >= 0) and a1[-1] < 2.0
    assert set(np.unique(c1)) <= {0, 1, 2}


def test_random_transients_from_call_exempts_warmup():
    from repro.runtime.faults import FaultPlan, InjectedFault

    plan = FaultPlan().random_transients(0.9, seed=3, stages=[0],
                                         from_call=50)
    inj = plan.build()
    for _ in range(50):               # warmup window: never faults
        inj.on_stage_call(0)
    assert inj.injected == 0
    with pytest.raises(InjectedFault):
        for _ in range(40):           # post-warmup: rate 0.9 fires fast
            inj.on_stage_call(0)
    assert inj.injected >= 1


# --------------------------------------------------------------------------- #
# sustained-overload autoscale: ladder level-2 streak -> widening replan
# (ISSUE 10 satellite: capacity response instead of shedding forever)
# --------------------------------------------------------------------------- #
def _level2_window(adm):
    """One observation window whose worst admission-time level reached 2
    (backlog 11 x 10 ms > degrade_at x slo_ref_ms)."""
    adm.admit(priority=BEST_EFFORT, deadline_ms=None,
              depth_ahead=0, depth_total=11)
    adm.end_window()


def test_level2_streak_counts_consecutive_windows_only():
    adm = AdmissionController(period_ms=10.0, slo_ref_ms=100.0,
                              shed_at=0.5, degrade_at=1.0)
    _level2_window(adm)
    _level2_window(adm)
    assert adm.level2_streak == 2
    # a milder window (level 0) breaks the streak
    adm.admit(priority=BATCH, deadline_ms=None, depth_ahead=0, depth_total=1)
    adm.end_window()
    assert adm.level2_streak == 0
    _level2_window(adm)
    assert adm.level2_streak == 1
    assert adm.snapshot()["level2_streak"] == 1
    adm.reset_streak()
    assert adm.level2_streak == 0


def test_autoscale_from_ladder_widens_after_sustained_streak():
    planner = _chain_planner((1.0, 4.0))          # f1 is the 4 ms bottleneck
    planner.executor_for(2, jit=False)[0].close()
    prof = StageProfiler(2, min_samples=4)
    for _ in range(6):
        prof.record(0, 1.0)
        prof.record(1, 4.0)
    adm = AdmissionController(period_ms=10.0, slo_ref_ms=100.0,
                              shed_at=0.5, degrade_at=1.0)

    # below the trigger: no replan attempt, the streak keeps accumulating
    for want in (1, 2):
        _level2_window(adm)
        d = planner.autoscale_from_ladder(adm, prof, worker_budget=4,
                                          streak=3, jit=False)
        assert d is None and adm.level2_streak == want
    assert planner.replan_checks == 0             # never reached the planner

    # third consecutive level-2 window trips the widen
    _level2_window(adm)
    d = planner.autoscale_from_ladder(adm, prof, worker_budget=4,
                                      streak=3, jit=False)
    assert isinstance(d, ReplanDecision) and d.replanned
    assert d.plan.replicas is not None and max(d.plan.replicas) > 1
    assert d.new_bottleneck_ms < d.old_bottleneck_ms
    assert adm.level2_streak == 0                 # one burst, one attempt
    if d.executor is not None:
        d.executor.close()

    with pytest.raises(ValueError, match="streak"):
        planner.autoscale_from_ladder(adm, prof, worker_budget=4, streak=0)
