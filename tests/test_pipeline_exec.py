"""Pipeline executors: host token pipeline ≡ sequential; SPMD pipeline ≡ stack."""
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (Frontend, Library, ModuleDatabase, PipelineGenerator)

OPS = {
    "mul2": lambda x: x * 2.0,
    "add1": lambda x: x + 1.0,
    "neg": lambda x: -x,
    "sq": lambda x: x * x,
    "tanh": jnp.tanh,
}


@settings(max_examples=25, deadline=None)
@given(st.lists(st.sampled_from(sorted(OPS)), min_size=1, max_size=8),
       st.integers(min_value=1, max_value=6),
       st.integers(min_value=1, max_value=3),
       st.integers(min_value=2, max_value=8))
def test_pipeline_semantics_random_chains(chain, n_tokens, n_threads, pool):
    db = ModuleDatabase("t")
    for name, fn in OPS.items():
        db.register(name, software=fn,
                    accelerated=fn if name != "add1" else None)
    lib = Library(db)

    def app(x):
        for f in chain:
            x = getattr(lib, f)(x)
        return x

    ir, _ = Frontend(db).trace(app, jnp.arange(4.0), profile=False)
    for n in ir.nodes:                    # synthetic profile (no wall clock)
        n.time_ms = 1.0 + (hash(n.name) % 7)
    pipe = PipelineGenerator(db).generate(ir, n_threads=n_threads)
    pipe.max_in_flight = pool
    toks = [jnp.full((4,), float(i + 1)) for i in range(n_tokens)]
    got = pipe.run(toks)
    want = [app(t) for t in toks]
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-6)


def test_pipeline_nonlinear_graph_liveness():
    """A value consumed across a stage boundary must stay live."""
    db = ModuleDatabase("t")
    db.register("a", software=lambda x: x + 1.0)
    db.register("b", software=lambda x: x * 2.0)
    db.register("c", software=lambda x, y: x + y)   # consumes BOTH a and b
    lib = Library(db)

    def app(x):
        u = lib.a(x)
        v = lib.b(u)
        return lib.c(u, v)

    ir, _ = Frontend(db).trace(app, jnp.arange(3.0), profile=False)
    for n in ir.nodes:
        n.time_ms = 1.0
    pipe = PipelineGenerator(db).generate(ir, n_threads=3)
    x = jnp.arange(3.0)
    np.testing.assert_allclose(pipe(x), app(x))


SPMD_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import pipeline_microbatches

    try:                                   # AxisType only exists on jax>=0.5
        from jax.sharding import AxisType
        mesh = jax.make_mesh((4,), ("stage",), axis_types=(AxisType.Auto,))
    except ImportError:
        mesh = jax.make_mesh((4,), ("stage",))
    L, d, M, mb = 9, 8, 5, 2
    W = jax.random.normal(jax.random.PRNGKey(0), (L, d, d)) * 0.3
    xs = jax.random.normal(jax.random.PRNGKey(1), (M, mb, d))
    block = lambda p, x: jnp.tanh(x @ p["w"])

    def ref(xs):
        h = xs
        for i in range(L):
            h = jnp.tanh(h @ W[i])
        return h

    # unequal, cost-balanced boundaries (Courier partition output shape)
    out = pipeline_microbatches(mesh, block, {"w": W}, [0, 2, 5, 7], xs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref(xs)),
                               rtol=2e-5, atol=2e-5)

    # differentiability: same grads as the stacked reference
    loss = lambda p: jnp.mean(
        pipeline_microbatches(mesh, block, p, [0, 2, 5, 7], xs) ** 2)
    def loss_ref(p):
        h = xs
        for i in range(L):
            h = jnp.tanh(h @ p["w"][i])
        return jnp.mean(h ** 2)
    g = jax.grad(loss)({"w": W})["w"]
    gr = jax.grad(loss_ref)({"w": W})["w"]
    np.testing.assert_allclose(np.asarray(g), np.asarray(gr),
                               rtol=1e-4, atol=1e-5)
    print("SPMD-OK")
""")


@pytest.mark.slow
def test_spmd_pipeline_multidevice_subprocess():
    """Runs the shard_map/ppermute token pipeline on 8 host devices."""
    # inherit the parent env (esp. JAX_PLATFORMS=cpu — without it jax may
    # probe for accelerator backends at import and hang) and force src/ on
    # the child's path
    import os
    env = dict(os.environ)
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", SPMD_SCRIPT],
                       capture_output=True, text=True, timeout=300,
                       env=env)
    assert "SPMD-OK" in r.stdout, r.stderr[-2000:]
