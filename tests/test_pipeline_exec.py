"""Pipeline executors: host token pipeline ≡ sequential; SPMD pipeline ≡ stack."""
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (Frontend, Library, ModuleDatabase, PipelineGenerator)

OPS = {
    "mul2": lambda x: x * 2.0,
    "add1": lambda x: x + 1.0,
    "neg": lambda x: -x,
    "sq": lambda x: x * x,
    "tanh": jnp.tanh,
}


@settings(max_examples=25, deadline=None)
@given(st.lists(st.sampled_from(sorted(OPS)), min_size=1, max_size=8),
       st.integers(min_value=1, max_value=6),
       st.integers(min_value=1, max_value=3),
       st.integers(min_value=2, max_value=8))
def test_pipeline_semantics_random_chains(chain, n_tokens, n_threads, pool):
    db = ModuleDatabase("t")
    for name, fn in OPS.items():
        db.register(name, software=fn,
                    accelerated=fn if name != "add1" else None)
    lib = Library(db)

    def app(x):
        for f in chain:
            x = getattr(lib, f)(x)
        return x

    ir, _ = Frontend(db).trace(app, jnp.arange(4.0), profile=False)
    for n in ir.nodes:                    # synthetic profile (no wall clock)
        n.time_ms = 1.0 + (hash(n.name) % 7)
    pipe = PipelineGenerator(db).generate(ir, n_threads=n_threads)
    pipe.max_in_flight = pool
    toks = [jnp.full((4,), float(i + 1)) for i in range(n_tokens)]
    got = pipe.run(toks)
    want = [app(t) for t in toks]
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-6)


def test_pipeline_nonlinear_graph_liveness():
    """A value consumed across a stage boundary must stay live."""
    db = ModuleDatabase("t")
    db.register("a", software=lambda x: x + 1.0)
    db.register("b", software=lambda x: x * 2.0)
    db.register("c", software=lambda x, y: x + y)   # consumes BOTH a and b
    lib = Library(db)

    def app(x):
        u = lib.a(x)
        v = lib.b(u)
        return lib.c(u, v)

    ir, _ = Frontend(db).trace(app, jnp.arange(3.0), profile=False)
    for n in ir.nodes:
        n.time_ms = 1.0
    pipe = PipelineGenerator(db).generate(ir, n_threads=3)
    x = jnp.arange(3.0)
    np.testing.assert_allclose(pipe(x), app(x))


SPMD_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import pipeline_microbatches

    try:                                   # AxisType only exists on jax>=0.5
        from jax.sharding import AxisType
        mesh = jax.make_mesh((4,), ("stage",), axis_types=(AxisType.Auto,))
    except ImportError:
        mesh = jax.make_mesh((4,), ("stage",))
    L, d, M, mb = 9, 8, 5, 2
    W = jax.random.normal(jax.random.PRNGKey(0), (L, d, d)) * 0.3
    xs = jax.random.normal(jax.random.PRNGKey(1), (M, mb, d))
    block = lambda p, x: jnp.tanh(x @ p["w"])

    def ref(xs):
        h = xs
        for i in range(L):
            h = jnp.tanh(h @ W[i])
        return h

    # unequal, cost-balanced boundaries (Courier partition output shape)
    out = pipeline_microbatches(mesh, block, {"w": W}, [0, 2, 5, 7], xs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref(xs)),
                               rtol=2e-5, atol=2e-5)

    # differentiability: same grads as the stacked reference
    loss = lambda p: jnp.mean(
        pipeline_microbatches(mesh, block, p, [0, 2, 5, 7], xs) ** 2)
    def loss_ref(p):
        h = xs
        for i in range(L):
            h = jnp.tanh(h @ p["w"][i])
        return jnp.mean(h ** 2)
    g = jax.grad(loss)({"w": W})["w"]
    gr = jax.grad(loss_ref)({"w": W})["w"]
    np.testing.assert_allclose(np.asarray(g), np.asarray(gr),
                               rtol=1e-4, atol=1e-5)
    print("SPMD-OK")
""")


@pytest.mark.slow
def test_spmd_pipeline_multidevice_subprocess():
    """Runs the shard_map/ppermute token pipeline on 8 host devices."""
    # inherit the parent env (esp. JAX_PLATFORMS=cpu — without it jax may
    # probe for accelerator backends at import and hang) and force src/ on
    # the child's path
    import os
    env = dict(os.environ)
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", SPMD_SCRIPT],
                       capture_output=True, text=True, timeout=300,
                       env=env)
    assert "SPMD-OK" in r.stdout, r.stderr[-2000:]


# --------------------------------------------------------------------------- #
# SPMD building blocks, tested directly (not just via pipeline_microbatches)
# --------------------------------------------------------------------------- #
def test_stack_stage_params_pads_and_counts():
    from repro.core import stack_stage_params
    L = 5
    params = {"w": jnp.arange(float(L)).reshape(L, 1)}
    staged, lengths = stack_stage_params(params, [0, 3])
    assert staged["w"].shape == (2, 3, 1)          # padded to Lmax=3
    np.testing.assert_array_equal(np.asarray(lengths), [3, 2])
    np.testing.assert_allclose(np.asarray(staged["w"][1, :, 0]),
                               [3.0, 4.0, 0.0])    # zero-padded tail
    with pytest.raises(ValueError, match="start at 0"):
        stack_stage_params(params, [1, 3])
    with pytest.raises(ValueError, match="empty stage"):
        stack_stage_params(params, [0, 5])


def test_stage_apply_masks_padding_layers():
    from repro.core import stage_apply

    def block(p, h):
        return h + p["b"]
    stage_params = {"b": jnp.array([1.0, 10.0, 100.0])}
    assert float(stage_apply(block, stage_params, jnp.int32(3),
                             jnp.zeros(()))) == 111.0
    # the masked tail layer (the 100.0) must not run
    assert float(stage_apply(block, stage_params, jnp.int32(2),
                             jnp.zeros(()))) == 11.0


def test_spmd_pipeline_fn_matches_sequential_under_vmap():
    """Drive the shard_map-interior function with vmap's named axis (one
    stage: the ICI hand-off is skipped, which is exactly what vmap's
    ppermute rule requires): every microbatch retires with all L layers
    applied in order."""
    from repro.core import spmd_pipeline_fn, stack_stage_params
    L, M = 4, 3
    params = {"b": jnp.arange(1.0, L + 1.0)}       # layer i adds i+1
    staged, lengths = stack_stage_params(params, [0])

    def block(p, h):
        return h + p["b"]
    fn = spmd_pipeline_fn(block, 1)
    xs = jnp.arange(float(M * 2)).reshape(M, 2)
    per_dev = jax.tree.map(lambda a: a[:, None], staged)   # [S, 1, Lmax, ...]
    out = jax.vmap(fn, in_axes=(0, None, None),
                   axis_name="stage")(per_dev, lengths, xs)
    assert out.shape == (1, M, 2)
    np.testing.assert_allclose(np.asarray(out[-1]),
                               np.asarray(xs + jnp.sum(params["b"])),
                               rtol=1e-6)
