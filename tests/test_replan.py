"""Online profiling, profile-guided re-planning, and executor hot-swap."""
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (Frontend, Library, ModuleDatabase, PipelineGenerator,
                        StageProfiler, fuse_adjacent_hw, linear_ir,
                        measured_contradicts, split_fused_node)
from repro.launch.serve import RequestQueueServer, _percentile
from repro.runtime import ElasticPlanner, ReplanDecision


# --------------------------------------------------------------------------- #
# fixtures: a sleep-backed simulated pipeline (runtime-injectable drift)
# --------------------------------------------------------------------------- #
DELAYS_MS: dict[str, float] = {}


def _impl(key):
    def sw(x):
        time.sleep(DELAYS_MS[key] / 1e3)
        return np.asarray(x) + 1.0
    sw.__name__ = key
    return sw


def _sim_planner(n_nodes=6, base_ms=2.0, **kw):
    keys = [f"f{i}" for i in range(n_nodes)]
    DELAYS_MS.clear()
    DELAYS_MS.update({k: base_ms for k in keys})
    db = ModuleDatabase("sim")
    for k in keys:
        db.register(k, software=_impl(k))
    ir = linear_ir("sim", keys, [base_ms] * n_nodes, io_shape=(4,))
    return ElasticPlanner(ir, db=db, **kw)


def _jit_pipe():
    db = ModuleDatabase("t")
    db.register("mul2", software=lambda x: x * 2.0)
    db.register("add1", software=lambda x: x + 1.0)
    db.register("sq", software=lambda x: x * x)
    db.register("tanh", software=jnp.tanh)
    lib = Library(db)

    def app(x):
        return lib.tanh(lib.sq(lib.add1(lib.mul2(x))))
    ir, _ = Frontend(db).trace(app, jnp.arange(4.0), profile=False)
    for n in ir.nodes:
        n.time_ms = 1.0
    return PipelineGenerator(db).generate(ir, n_threads=3)


# --------------------------------------------------------------------------- #
# StageProfiler: mechanics + accuracy
# --------------------------------------------------------------------------- #
def test_profiler_ema_window_percentiles():
    p = StageProfiler(2, alpha=0.5, window=4, min_samples=2)
    assert p.measured_ms(0) is None and p.ema_ms(0) is None
    for ms in (10.0, 20.0, 30.0, 40.0, 50.0):
        p.record(0, ms)
    # window keeps the last 4 samples; median over [20, 30, 40, 50]
    assert p.percentile_ms(0, 50.0) == pytest.approx(35.0)
    assert p.samples(0) == 5
    assert p.ema_ms(0) == pytest.approx(
        0.5 * 50 + 0.5 * (0.5 * 40 + 0.5 * (0.5 * 30 + 0.5 * (
            0.5 * 20 + 0.5 * 10))))
    assert not p.ready                       # stage 1 has no samples
    p.record(1, 1.0)
    p.record(1, 2.0)
    assert p.ready
    snap = p.snapshot()
    assert snap["per_stage"][0]["samples"] == 5
    assert snap["per_stage"][1]["p50_ms"] == pytest.approx(1.5)
    p.reset()
    assert p.samples(0) == 0 and p.measured_ms(0) is None
    with pytest.raises(IndexError):
        p.record(7, 1.0)
    with pytest.raises(ValueError):
        StageProfiler(0)


def test_profiler_sampling_tick():
    p = StageProfiler(1, sample_every=4)
    ticks = [p.tick() for _ in range(8)]
    assert ticks == [True, False, False, False, True, False, False, False]


def test_profiler_converges_on_injected_stage_times():
    """Measured medians track the injected sleeps (threaded stage workers)."""
    planner = _sim_planner(n_nodes=6, base_ms=2.0)
    prof = StageProfiler(3, min_samples=4)
    ex, _ = planner.executor_for(3, max_in_flight=8, jit=False,
                                 profiler=prof, stage_workers=True)
    toks = [np.full((4,), float(i)) for i in range(12)]
    ex.run(toks)
    for k in range(3):
        m = prof.measured_ms(k)
        # each stage = two 2 ms sleeps; sleep overshoot and scheduler noise
        # only ever push the measurement UP
        assert m is not None and 4.0 <= m <= 12.0, f"stage {k}: {m}"
    # drift one stage 3x and verify the profile follows it
    for nn in planner.current_plan.stages[1].node_names:
        DELAYS_MS[planner.layer_ir.node(nn).fn_key] *= 3.0
    prof.reset()
    ex.run(toks)
    slow, fast = prof.measured_ms(1), prof.measured_ms(0)
    assert slow >= 2.0 * fast, f"slowdown not observed: {slow} vs {fast}"
    ex.close()


def test_profiler_apply_to_ir_writes_measured_costs():
    ir = linear_ir("x", ["a", "b", "c", "d"], [1.0, 3.0, 1.0, 1.0])
    from repro.core import partition_optimal
    plan = partition_optimal(ir, max_stages=2)      # [a b] [c d] or similar
    prof = StageProfiler(plan.n_stages, min_samples=1)
    for k in range(plan.n_stages):
        for _ in range(4):
            prof.record(k, 8.0)
    replaced = prof.apply_to_ir(ir, plan)
    assert replaced                              # something was superseded
    for s in plan.stages:
        nodes = [ir.node(nn) for nn in s.node_names]
        # stage total equals the measurement; split proportional to priors
        assert sum(n.time_ms for n in nodes) == pytest.approx(8.0)
        assert all(n.time_source == "profile" for n in nodes)
    # proportionality: b had 3x a's prior -> keeps 3x after write-back
    sa, sb = ir.node("a_0").time_ms, ir.node("b_1").time_ms
    if "b_1" in [n.name for s in plan.stages for n in
                 [ir.node(nn) for nn in s.node_names]
                 if "a_0" in s.node_names]:
        assert sb == pytest.approx(3.0 * sa)


def test_measured_supersedes_roofline_in_assign_placements():
    """assign_placements must not overwrite a profiled time with cost_hw."""
    from repro.core import NodeCost, assign_placements

    db = ModuleDatabase("t")
    db.register("f", software=lambda x: x, accelerated=lambda x: x,
                cost_hw=lambda shapes, dtypes, params: NodeCost(
                    flops=1e9, bytes_rw=1e9))
    ir = linear_ir("x", ["f"], [123.0], io_shape=(4,))
    ir.nodes[0].time_source = "profile"
    assign_placements(ir, db)
    assert ir.nodes[0].time_ms == pytest.approx(123.0)   # kept the profile
    ir.nodes[0].time_source = "estimate"
    assign_placements(ir, db)
    assert ir.nodes[0].time_ms != pytest.approx(123.0)   # estimate replaced


def test_measured_contradicts_margins():
    assert measured_contradicts(2.0, 6.0, margin=1.5)
    assert measured_contradicts(6.0, 2.0, margin=1.5)    # both directions
    assert not measured_contradicts(2.0, 2.5, margin=1.5)
    assert not measured_contradicts(None, 5.0)
    assert not measured_contradicts(5.0, None)
    assert measured_contradicts(0.0, 1.0)
    with pytest.raises(ValueError):
        measured_contradicts(1.0, 2.0, margin=0.5)


def test_costmodel_observe_supersedes_annotation():
    from repro.core import CostModel, NodeCost

    cm = CostModel()
    cm.register("a", lambda shapes, dtypes, params: NodeCost(flops=1.0,
                                                             bytes_rw=1.0))
    ir = linear_ir("x", ["a"], [1.0], io_shape=(4,))
    ir.nodes[0].time_ms = None
    cm.observe("a", 10.0)
    cm.observe("a", 20.0)                    # EMA: 10 + 0.25 * 10 = 12.5
    cm.annotate(ir)
    assert ir.nodes[0].time_ms == pytest.approx(12.5)
    assert ir.nodes[0].time_source == "profile"


# --------------------------------------------------------------------------- #
# replan trigger: decision rule + hysteresis (no flapping)
# --------------------------------------------------------------------------- #
def _feed(prof, stage_times, n=8, noise=0.0, seed=0):
    rng = np.random.default_rng(seed)
    for _ in range(n):
        for k, t in enumerate(stage_times):
            prof.record(k, t * (1.0 + noise * rng.uniform(-1.0, 1.0)))


def test_replan_requires_profile_and_prior_plan():
    planner = _sim_planner()
    prof = StageProfiler(3, min_samples=4)
    with pytest.raises(ValueError, match="executor_for"):
        planner.replan_from_profile(prof)
    ex, _ = planner.executor_for(3, jit=False)
    d = planner.replan_from_profile(prof)
    assert not d.replanned and "insufficient" in d.reason
    assert planner.replans == 0 and planner.replan_checks == 1


def test_replan_triggers_on_contradicting_profile_then_stays_stable():
    planner = _sim_planner(n_nodes=6, base_ms=2.0)
    ex, _ = planner.executor_for(3, jit=False)
    assert [len(s.node_names) for s in planner.current_plan.stages] == [2, 2, 2]

    # measured: stage 1 is 3x slower than planned -> re-balance
    prof = StageProfiler(3, min_samples=4)
    _feed(prof, [4.0, 12.0, 4.0])
    d = planner.replan_from_profile(prof, max_stages=6, jit=False)
    assert isinstance(d, ReplanDecision) and d.replanned
    assert d.gain >= 1.5 and d.executor is not None
    assert d.new_bottleneck_ms < d.old_bottleneck_ms
    assert planner.replans == 1
    # measured node times were written back and marked profiled
    assert all(n.time_source == "profile" for n in planner.layer_ir.nodes)

    # steady state: noisy timings around the NEW plan's real stage costs
    # must not flap the plan, call after call
    n_stages = d.plan.n_stages
    stage_ms = [s.est_time_ms for s in d.plan.stages]
    for trial in range(5):
        prof2 = StageProfiler(n_stages, min_samples=4)
        _feed(prof2, stage_ms, noise=0.2, seed=trial)
        d2 = planner.replan_from_profile(prof2, max_stages=6, jit=False)
        assert not d2.replanned, f"flapped on trial {trial}: {d2.reason}"
    assert planner.replans == 1


def test_replan_hysteresis_blocks_marginal_gains():
    planner = _sim_planner(n_nodes=6, base_ms=2.0, min_gain=1.5)
    planner.executor_for(3, jit=False)
    # stage 0 measured mildly slower: best re-balance would win < min_gain
    prof = StageProfiler(3, min_samples=4)
    _feed(prof, [5.2, 4.0, 4.0])
    d = planner.replan_from_profile(prof, max_stages=3, jit=False)
    assert not d.replanned
    assert planner.replans == 0


def test_replan_reuses_stagefns_for_unchanged_boundaries():
    """Bounded recompiles: stages whose boundaries didn't move keep their
    compiled StageFn object across a re-plan."""
    db = ModuleDatabase("t")
    for k, f in (("a", lambda x: x + 1.0), ("b", lambda x: x * 2.0),
                 ("c", lambda x: x - 3.0), ("d", jnp.tanh)):
        db.register(k, software=f)
    ir = linear_ir("x", ["a", "b", "c", "d"], [1.0, 1.0, 1.0, 5.0],
                   io_shape=(4,))
    planner = ElasticPlanner(ir, db=db)
    ex1, _ = planner.executor_for(2)
    assert [s.node_names for s in planner.current_plan.stages] == \
        [["a_0", "b_1", "c_2"], ["d_3"]]
    fns_before = {tuple(s.node_names): f for s, f in
                  zip(planner.current_plan.stages, ex1.stage_fns)}
    x = jnp.arange(4.0)
    ex1.run([x])                                  # compile stage executables

    prof = StageProfiler(2, min_samples=4)
    _feed(prof, [9.0, 5.0])                       # stage 0 is 3x its plan
    d = planner.replan_from_profile(prof, max_stages=3)
    assert d.replanned
    new_stages = [tuple(s.node_names) for s in d.plan.stages]
    assert ("d_3",) in new_stages                 # the [d] stage survived
    reused = d.executor.stage_fns[new_stages.index(("d_3",))]
    assert reused is fns_before[("d_3",)]         # same compiled StageFn
    assert reused.compiles == 1                   # still warm, no recompile
    # and the replanned executor computes the same function
    want = np.asarray(jnp.tanh((x + 1.0) * 2.0 - 3.0))
    np.testing.assert_allclose(np.asarray(d.executor.run([x])[0]), want,
                               rtol=1e-6)


def test_replan_defuses_contradicted_fused_node():
    """A fused node whose measured time breaks the model is split apart."""
    db = ModuleDatabase("t")
    db.register("f", software=lambda x: x + 1.0, accelerated=lambda x: x + 1.0)
    db.register("g", software=lambda x: x * 2.0, accelerated=lambda x: x * 2.0)
    db.register("h", software=lambda x: x - 3.0)
    ir = linear_ir("x", ["f", "g", "h"], [2.0, 2.0, 4.0], io_shape=(4,))
    fused = fuse_adjacent_hw(ir, db, fused_cost_ms=lambda run: 1.0)
    fnode = next(n for n in fused.nodes if n.fused_from)

    planner = ElasticPlanner(fused, db=db)
    planner.executor_for(2)
    plan = planner.current_plan
    # the fused node's stage measured 12 ms against a ~1 ms model ->
    # contradiction -> defuse -> parts can split across stages
    prof = StageProfiler(plan.n_stages, min_samples=4)
    stage_of_fused = next(i for i, s in enumerate(plan.stages)
                          if fnode.name in s.node_names)
    _feed(prof, [12.0 if i == stage_of_fused else 4.0
                 for i in range(plan.n_stages)])
    d = planner.replan_from_profile(prof, max_stages=3)
    assert d.defused == [fnode.name]
    assert all(not n.fused_from for n in planner.layer_ir.nodes)
    names = [n.name for n in planner.layer_ir.nodes]
    assert "f_0" in names and "g_1" in names
    # and the defused pipeline still computes f->g->h
    x = jnp.arange(4.0)
    want = np.asarray((x + 1.0) * 2.0 - 3.0)
    np.testing.assert_allclose(np.asarray(d.executor.run([x])[0]), want,
                               rtol=1e-6)


def test_replan_keep_path_never_commits_a_defuse():
    """A contradicted fused node with a below-threshold gain must NOT
    mutate the planner's IR (the current plan still references it)."""
    db = ModuleDatabase("t")
    db.register("f", software=lambda x: x + 1.0, accelerated=lambda x: x + 1.0)
    db.register("g", software=lambda x: x * 2.0, accelerated=lambda x: x * 2.0)
    ir = linear_ir("x", ["f", "g"], [2.0, 2.0], io_shape=(4,))
    fused = fuse_adjacent_hw(ir, db, fused_cost_ms=lambda run: 1.0)
    fnode = next(n for n in fused.nodes if n.fused_from)
    planner = ElasticPlanner(fused, db=db, min_gain=1e9)   # nothing passes
    planner.executor_for(1)
    prof = StageProfiler(1, min_samples=4)
    _feed(prof, [12.0])                   # contradicts the 1 ms fused model
    d = planner.replan_from_profile(prof, max_stages=2)
    assert not d.replanned
    # the defuse was staged, not committed: the fused node is still there
    assert any(n.name == fnode.name for n in planner.layer_ir.nodes)
    # and a second check against the same plan must not crash on a stale
    # node name (regression: KeyError from apply_to_ir on a defused IR)
    d2 = planner.replan_from_profile(prof, max_stages=2)
    assert not d2.replanned


def test_replan_detects_gradual_drift_against_model_baseline():
    """The contradiction check compares against the MODEL, not against the
    previous measurement — gradual drift can't creep under the margin."""
    db = ModuleDatabase("t")
    db.register("f", software=lambda x: x + 1.0, accelerated=lambda x: x + 1.0)
    db.register("g", software=lambda x: x * 2.0, accelerated=lambda x: x * 2.0)
    db.register("h", software=lambda x: x - 3.0)
    ir = linear_ir("x", ["f", "g", "h"], [2.0, 2.0, 4.0], io_shape=(4,))
    fused = fuse_adjacent_hw(ir, db, fused_cost_ms=lambda run: 1.0)
    fname = next(n for n in fused.nodes if n.fused_from).name
    planner = ElasticPlanner(fused, db=db)
    planner.executor_for(2)
    stage_of_fused = next(i for i, s in enumerate(planner.current_plan.stages)
                          if fname in s.node_names)

    def stage_times(fused_ms):
        return [fused_ms if i == stage_of_fused else 4.0
                for i in range(planner.current_plan.n_stages)]

    # drift step 1: 1.4x the 1.0 ms model — below the 1.5x margin, no defuse
    prof = StageProfiler(planner.current_plan.n_stages, min_samples=4)
    _feed(prof, stage_times(1.4))
    d1 = planner.replan_from_profile(prof, max_stages=3)
    assert not d1.defused
    # drift step 2: 1.9 ms — only 1.36x the PREVIOUS measurement, but 1.9x
    # the model: the contradiction must fire
    prof2 = StageProfiler(planner.current_plan.n_stages, min_samples=4)
    _feed(prof2, stage_times(1.9))
    d2 = planner.replan_from_profile(prof2, max_stages=3)
    assert fname in d2.defused, d2.describe()


def test_executor_for_never_serves_a_closed_executor():
    planner = _sim_planner(n_nodes=4, base_ms=1.0)
    ex, rebuilt = planner.executor_for(2, jit=False, stage_workers=True)
    assert rebuilt
    ex.run([np.zeros(4)])
    ex.close()
    ex2, rebuilt = planner.executor_for(2, jit=False, stage_workers=True)
    assert rebuilt and ex2 is not ex          # closed executor not cached out
    out = ex2.run([np.zeros(4)])              # and the fresh one works
    np.testing.assert_allclose(np.asarray(out[0]), np.full(4, 4.0))
    ex2.close()


def test_replan_min_samples_override_can_lower_profiler_floor():
    planner = _sim_planner(n_nodes=6, base_ms=2.0)
    planner.executor_for(3, jit=False)
    prof = StageProfiler(3, min_samples=8)     # profiler's own floor: 8
    _feed(prof, [4.0, 12.0, 4.0], n=3)         # only 3 samples per stage
    assert prof.measured_ms(0) is None         # below the profiler's floor
    d = planner.replan_from_profile(prof, max_stages=6, jit=False,
                                    min_samples=3)
    assert d.replanned                         # caller's floor of 3 decides


def test_split_fused_node_roundtrip():
    db = ModuleDatabase("t")
    db.register("f", software=lambda x: x + 1.0, accelerated=lambda x: x + 1.0)
    db.register("g", software=lambda x: x * 2.0, accelerated=lambda x: x * 2.0)
    ir = linear_ir("x", ["f", "g"], [1.0, 1.0], io_shape=(4,))
    fused = fuse_adjacent_hw(ir, db, fused_cost_ms=lambda run: 0.5)
    fnode = next(n for n in fused.nodes if n.fused_from)
    back = split_fused_node(fused, fnode.name, part_times_ms=[3.0, 5.0])
    assert [n.name for n in back.nodes] == ["f_0", "g_1"]
    assert [n.time_ms for n in back.nodes] == [3.0, 5.0]
    back.validate()
    pipe = PipelineGenerator(db).generate(back, n_threads=1)
    x = jnp.arange(4.0)
    np.testing.assert_allclose(np.asarray(pipe(x)),
                               np.asarray((x + 1.0) * 2.0), rtol=1e-6)
    with pytest.raises(ValueError, match="not a fused node"):
        split_fused_node(back, "f_0")


# --------------------------------------------------------------------------- #
# hot-swap correctness: zero drops, identical results, bounded compiles
# --------------------------------------------------------------------------- #
def test_hot_swap_zero_drops_identical_results_bounded_compiles():
    pipe = _jit_pipe()
    toks = [jnp.full((4,), float(i + 1)) for i in range(24)]
    want = pipe.run_sequential(toks)

    ex_a = pipe.executor(max_in_flight=6, microbatch=2, pad_microbatches=True)
    ex_a.warmup(toks[0])
    compiles_warm = pipe.compile_count()

    with RequestQueueServer(ex_a, max_batch=2, max_wait_ms=2.0) as srv:
        reqs = [srv.submit(t) for t in toks[:12]]
        ex_b = pipe.executor(max_in_flight=4, microbatch=2,
                             pad_microbatches=True)
        old = srv.swap_executor(ex_b, warm_args=(toks[0],))
        assert old is ex_a and srv.executor is ex_b and srv.swaps == 1
        reqs += [srv.submit(t) for t in toks[12:]]
        got = [r.wait(timeout=60.0) for r in reqs]     # zero drops

    for g, w in zip(got, want):                         # identical results
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-6)
    st = srv.stats()
    assert st["requests_served"] == 24 and st["swaps"] == 1
    # both executors' tokens are accounted; nothing lost at the boundary
    assert (ex_a.stats().tokens_retired + ex_b.stats().tokens_retired) == 24
    assert ex_a.stats().tokens_admitted == ex_a.stats().tokens_retired
    assert ex_b.stats().tokens_admitted == ex_b.stats().tokens_retired
    # bounded recompiles: shared compiled stages -> ZERO new executables
    assert pipe.compile_count() == compiles_warm


def test_hot_swap_serial_to_replicated_mid_stream():
    """Swap a serial executor for a REPLICATED one mid-stream: zero drops,
    identical in-order results, zero new compiles (widening keeps every
    stage boundary, so every StageFn and vmapped executable is reused)."""
    pipe = _jit_pipe()
    toks = [jnp.full((4,), float(i + 1)) for i in range(24)]
    want = pipe.run_sequential(toks)

    ex_serial = pipe.executor(max_in_flight=6, microbatch=2,
                              pad_microbatches=True)
    ex_serial.warmup(toks[0])
    compiles_warm = pipe.compile_count()

    with RequestQueueServer(ex_serial, max_batch=2, max_wait_ms=2.0) as srv:
        reqs = [srv.submit(t) for t in toks[:12]]
        ex_rep = pipe.executor(microbatch=2, pad_microbatches=True,
                               replicas=[1, 3, 1, 1][: len(pipe.stage_fns)])
        old = srv.swap_executor(ex_rep, warm_args=(toks[0],))
        assert old is ex_serial and srv.executor is ex_rep
        reqs += [srv.submit(t) for t in toks[12:]]
        got = [r.wait(timeout=60.0) for r in reqs]      # zero drops

    for g, w in zip(got, want):                          # identical results
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-6)
    assert (ex_serial.stats().tokens_retired
            + ex_rep.stats().tokens_retired) == 24
    assert ex_rep.stats().out_of_order_retired == 0      # in-order retirement
    assert pipe.compile_count() == compiles_warm         # zero new executables
    ex_rep.close()


def test_hot_swap_outside_serving_loop_is_immediate():
    pipe = _jit_pipe()
    ex_a = pipe.executor()
    srv = RequestQueueServer(ex_a)        # never started
    ex_b = pipe.executor()
    old = srv.swap_executor(ex_b)
    assert old is ex_a and srv.executor is ex_b and srv.swaps == 1


def test_percentile_is_nan_free_on_tiny_and_dirty_windows():
    assert _percentile([], 95) == 0.0
    assert _percentile([3.0], 95) == pytest.approx(3.0)
    assert _percentile([1.0, float("nan"), 3.0], 50) == pytest.approx(2.0)
    assert _percentile([float("nan")], 50) == 0.0
    assert _percentile([None, 2.0], 50) == pytest.approx(2.0)
    assert np.isfinite(_percentile([float("inf"), 1.0], 50))
