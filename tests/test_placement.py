"""Structured placement layer: Placement parsing, the device inventory,
the budget governor, device-aware replica assignment + transfer accounting,
replication-aware batching — and the lint gate (repro.analysis.lint) that
keeps raw "hw"/"sw" string literals out of every module except the
back-compat parser."""
import os

import numpy as np
import pytest

from repro.core import (DeviceInventory, DeviceSpec, ModuleDatabase, Node,
                        Placement, PipelinePlan, StagePlan, assign_replicas,
                        assign_stage_devices, default_worker_budget,
                        device_class, is_hw, is_sw, linear_ir,
                        partition_optimal, placement_kind,
                        replicated_bottleneck_ms, resolve_worker_budget,
                        transfer_ms)
from repro.core.ir import CourierIR
from repro.core.placement import AUTO_BUDGET, RESERVED_CORES_ENV

SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                   "src", "repro")


# --------------------------------------------------------------------------- #
# Placement: parsing, back-compat, identity
# --------------------------------------------------------------------------- #
def test_placement_parse_backcompat_strings():
    p = Placement.parse("hw")
    assert p.is_hw and not p.is_sw and p.is_assigned
    assert p.device is None and p.replica == 0
    assert Placement.parse("sw").is_sw
    u = Placement.parse("unassigned")
    assert not u.is_assigned and not u.is_hw and not u.is_sw
    assert Placement.parse(None) == Placement.unassigned()
    assert Placement.parse(p) is p                    # idempotent
    with pytest.raises(ValueError, match="unknown placement kind"):
        Placement.parse("fpga")
    with pytest.raises(TypeError):
        Placement.parse(42)


def test_placement_structured_fields_and_rendering():
    p = Placement.hw(device=2, replica=1, mesh_coord=(0, 1))
    assert p.device == 2 and p.replica == 1 and p.mesh_coord == (0, 1)
    assert p.short() == "hw@2.1"
    assert Placement.hw(device=3).short() == "hw@3"
    assert Placement.sw().short() == "sw"
    # with_kind preserves the pinning; on() preserves the kind
    assert p.with_kind("sw").device == 2 and p.with_kind("sw").is_sw
    q = Placement.sw().on(1, replica=2)
    assert q.is_sw and q.device == 1 and q.replica == 2
    # hashable identity for StageFn cache keys
    assert p.key == ("hw", 2, 1)
    assert len({Placement.hw(), Placement.hw(), Placement.sw()}) == 2


def test_placement_helpers_tolerate_legacy_values():
    assert is_hw("hw") and not is_hw("sw") and not is_hw(None)
    assert is_sw("sw") and not is_sw("unassigned")
    assert is_hw(Placement.hw(device=1))
    assert placement_kind("hw") == placement_kind(Placement.hw())


def test_node_placement_parses_strings_and_json_roundtrips():
    n = Node(name="f_0", fn_key="f", placement="hw")
    assert isinstance(n.placement, Placement) and n.placement.is_hw
    ir = linear_ir("t", ["a", "b"], [1.0, 2.0], io_shape=(4,))
    ir.nodes[0].placement = Placement.hw(device=3, replica=2,
                                         mesh_coord=(1, 0))
    ir2 = CourierIR.from_json(ir.to_json())
    p = ir2.nodes[0].placement
    assert isinstance(p, Placement)
    assert (p.kind, p.device, p.replica, p.mesh_coord) == ("hw", 3, 2, (1, 0))
    assert "hw@3.2" in ir2.render()


# --------------------------------------------------------------------------- #
# Lint gate: the AST grep-guard now lives in repro.analysis.lint as the
# `placement-literal` rule (plus the concurrency/style rules); this test
# just asserts the linter reports zero findings over src/.
# --------------------------------------------------------------------------- #
def test_lint_clean_over_src():
    """Every "hw"/"sw" comparison must go through repro.core.placement —
    a raw string literal elsewhere is a refactor leak (docstrings exempt).
    That rule, and the rest of the lint catalog (lock-discipline,
    blocking-in-lock, frozen-dataclass, acquire-without-finally,
    dead-export), must hold across the whole tree."""
    from repro.analysis.lint import lint_paths
    findings = lint_paths([SRC])
    assert not findings, "lint findings over src/:\n  " + \
        "\n  ".join(d.format() for d in findings)


# --------------------------------------------------------------------------- #
# DeviceInventory + budget governor
# --------------------------------------------------------------------------- #
def test_device_inventory_synthetic_and_validation():
    inv = DeviceInventory.host(4)
    assert len(inv) == 4 and inv.homogeneous
    assert inv.spec(2).ordinal == 2 and inv.spec(2).platform == "cpu"
    assert inv.jax_device(1) is None              # planning-only inventory
    assert inv.device_class(0) is device_class("cpu")
    assert "4 devices" in inv.describe()
    with pytest.raises(ValueError, match="at least one"):
        DeviceInventory([])
    with pytest.raises(ValueError, match="dense"):
        DeviceInventory([DeviceSpec(ordinal=1)])
    with pytest.raises(ValueError, match="speed"):
        DeviceSpec(ordinal=0, speed=0.0)


def test_device_inventory_detect_matches_jax_devices():
    import jax

    inv = DeviceInventory.detect()
    assert len(inv) == len(jax.devices())
    assert inv.jax_device(0) is jax.devices()[0]
    assert inv.spec(0).platform == jax.devices()[0].platform
    with pytest.raises(ValueError, match="limit"):
        DeviceInventory.detect(limit=0)


def test_default_worker_budget_governor(monkeypatch):
    monkeypatch.setattr(os, "cpu_count", lambda: 8)
    monkeypatch.delenv(RESERVED_CORES_ENV, raising=False)
    assert default_worker_budget(3) == 7            # 8 cores - 1 reserved
    assert default_worker_budget(3, reserved_cores=4) == 4
    # saturated host: collapses to the one-worker-per-stage floor
    assert default_worker_budget(3, reserved_cores=8) == 3
    monkeypatch.setenv(RESERVED_CORES_ENV, "6")
    assert default_worker_budget(1) == 2            # knob read from the env
    with pytest.raises(ValueError):
        default_worker_budget(0)
    with pytest.raises(ValueError):
        default_worker_budget(1, reserved_cores=-1)


def test_resolve_worker_budget_modes(monkeypatch):
    monkeypatch.setattr(os, "cpu_count", lambda: 8)
    monkeypatch.delenv(RESERVED_CORES_ENV, raising=False)
    inv = DeviceInventory.host(4)
    assert resolve_worker_budget(5, 2) == 5                  # explicit wins
    assert resolve_worker_budget(None, 2) is None            # legacy: no widen
    assert resolve_worker_budget(None, 2, inv) == inv.worker_budget(2)
    assert resolve_worker_budget(AUTO_BUDGET, 2) == 7        # the governor
    assert resolve_worker_budget(AUTO_BUDGET, 2, inv) >= 4   # >= one/device
    # a 16-device inventory must be widenable even on a small host
    assert DeviceInventory.host(16).worker_budget(2) >= 16


# --------------------------------------------------------------------------- #
# Device-aware replica assignment + cross-device transfer accounting
# --------------------------------------------------------------------------- #
def _chain_ir(times, io_shape=(256, 256)):
    keys = [f"f{i}" for i in range(len(times))]
    return linear_ir("chain", keys, list(times), io_shape=io_shape)


def test_assign_replicas_pins_each_replica_to_distinct_device():
    ir = _chain_ir([0.5, 6.0, 0.5])
    plan = partition_optimal(ir, max_stages=3)
    inv = DeviceInventory.host(4)
    assign_replicas(plan, ir, worker_budget=6, inventory=inv)
    k = max(range(3), key=lambda i: plan.stages[i].est_time_ms)
    wide = plan.stages[k]
    assert wide.replicas == 4
    assert len(set(wide.devices)) == wide.replicas     # distinct devices
    assert wide.device_speeds == [1.0] * 4
    # every stage got a full per-replica assignment
    for s in plan.stages:
        assert len(s.devices) == s.replicas
    assert plan.stage_devices == [s.devices for s in plan.stages]


def test_assign_replicas_rerun_without_inventory_clears_stale_devices():
    """The mutate-and-rerun API: a later run without an inventory must not
    leave a previous run's per-replica pinnings behind (their lengths
    would no longer match the new replica counts)."""
    ir = _chain_ir([0.5, 6.0, 0.5])
    plan = partition_optimal(ir, max_stages=3)
    assign_replicas(plan, ir, worker_budget=6, inventory=DeviceInventory.host(4))
    assert any(s.devices for s in plan.stages)
    assign_replicas(plan, ir, worker_budget=4)          # no inventory
    assert all(s.devices == [] and s.device_speeds == []
               and s.xfer_in_ms == 0.0 for s in plan.stages)
    assert plan.stage_devices is None
    plan.effective_bottleneck_ms                        # must not raise


def test_assign_stage_devices_picks_earliest_completion_on_heterogeneous():
    """Least-loaded = earliest completion time, not busy-time re-divided
    by speed: a fast-but-busier device must lose to an idle slow one when
    the idle one finishes the share sooner."""
    inv = DeviceInventory([DeviceSpec(ordinal=0, speed=2.0),
                           DeviceSpec(ordinal=1, speed=1.0)])
    # one 60ms stage 1-wide then one 20ms stage 1-wide: the heavy stage
    # takes the fast device (completion 30 < 60); the light stage must
    # take the idle slow device (20 < 30 + 10)
    p = PipelinePlan(stages=[
        StagePlan(node_names=["a"], est_time_ms=60.0),
        StagePlan(node_names=["b"], est_time_ms=20.0)])
    assign_stage_devices(p, inv)
    assert p.stages[0].devices == [0]
    assert p.stages[1].devices == [1]
    assert p.stages[0].device_speeds == [2.0]


def test_assign_replicas_inventory_derives_budget():
    ir = _chain_ir([0.5, 6.0, 0.5])
    plan = partition_optimal(ir, max_stages=3)
    # no worker_budget: the inventory's governor supplies it
    assign_replicas(plan, ir, inventory=DeviceInventory.host(6))
    assert max(plan.replicas) > 1
    with pytest.raises(ValueError, match="worker_budget"):
        assign_replicas(partition_optimal(ir, max_stages=3), ir)


def test_cross_device_boundary_transfer_accounting():
    ir = _chain_ir([2.0, 2.0], io_shape=(512, 512))   # 1 MiB boundaries
    plan = partition_optimal(ir, max_stages=2)
    nbytes = plan.stages[1].comm_in_bytes
    assert nbytes == 512 * 512 * 4
    inv = DeviceInventory.host(2)
    assign_stage_devices(plan, inv)
    if set(plan.stages[0].devices) == set(plan.stages[1].devices):
        assert plan.stages[1].xfer_in_ms == 0.0
    else:
        want = transfer_ms(nbytes, inv.device_class(0).xfer_bw)
        assert plan.stages[1].xfer_in_ms == pytest.approx(want)
        assert want > 0
    # without an ir the graph-input bytes are unknown: stage 0 uncharged
    assert plan.stages[0].xfer_in_ms == 0.0
    # with the ir, a multi-device plan charges stage 0 the graph inputs'
    # host-side staging (the executor device_puts every admitted group)
    plan_ir = partition_optimal(ir, max_stages=2)
    assign_stage_devices(plan_ir, inv, ir=ir)
    if len({d for s in plan_ir.stages for d in s.devices}) > 1:
        in_bytes = sum(ir.values[v].nbytes for v in ir.graph_inputs)
        want0 = transfer_ms(in_bytes, inv.device_class(0).xfer_bw)
        assert plan_ir.stages[0].xfer_in_ms == pytest.approx(want0)
        assert want0 > 0
    # single-device inventory: no transfer anywhere, all ordinals 0 (the
    # executor degrades and pays no staging at all)
    plan1 = partition_optimal(ir, max_stages=2)
    assign_stage_devices(plan1, DeviceInventory.host(1), ir=ir)
    assert all(set(s.devices) == {0} for s in plan1.stages)
    assert all(s.xfer_in_ms == 0.0 for s in plan1.stages)


def test_widen_without_replication_deploys_unpinned_plan():
    """A planner holding an inventory whose widening pass yields no
    replicated stage must deploy a plan with NO device pinnings — the
    executor runs unpinned, so keeping pinnings would charge transfer
    costs never paid and skew later replan gain comparisons."""
    from repro.core import ModuleDatabase
    from repro.runtime import ElasticPlanner

    keys = ["g0", "g1", "g2"]
    db = ModuleDatabase("flat")
    for k in keys:
        def impl(x):
            return x
        impl.__name__ = k
        db.register(k, software=impl)
    ir = linear_ir("flat", keys, [2.0, 2.0, 2.0], io_shape=(512, 512))
    planner = ElasticPlanner(ir, db=db, inventory=DeviceInventory.host(4))
    # budget at the floor: no stage widens
    ex, _ = planner.executor_for(3, jit=False, worker_budget=3)
    plan = planner.current_plan
    assert all(r == 1 for r in plan.replicas)
    assert plan.stage_devices is None
    assert all(s.xfer_in_ms == 0.0 for s in plan.stages)
    assert plan.effective_bottleneck_ms == pytest.approx(plan.bottleneck_ms)
    assert ex.devices is None
    ex.close()


def test_widen_for_deployment_shared_rule():
    """The one deploy-or-degrade helper every site uses: widened plans
    return (replicas, devices); non-widened plans come back unpinned."""
    from repro.core import widen_for_deployment

    ir = _chain_ir([0.5, 6.0, 0.5])
    inv = DeviceInventory.host(4)
    plan = partition_optimal(ir, max_stages=3)
    reps, devs = widen_for_deployment(plan, ir, worker_budget=6,
                                      inventory=inv)
    assert reps == plan.replicas and max(reps) == 4
    assert devs == plan.stage_devices and devs is not None
    # degrade: budget at the floor -> unpinned plan, no stale charges
    plan2 = partition_optimal(ir, max_stages=3)
    reps2, devs2 = widen_for_deployment(plan2, ir, worker_budget=3,
                                        inventory=inv)
    assert reps2 is None and devs2 is None
    assert plan2.stage_devices is None
    assert all(s.xfer_in_ms == 0.0 and s.device_speeds == []
               for s in plan2.stages)
    # no budget, no inventory: legacy no-widen
    plan3 = partition_optimal(ir, max_stages=3)
    assert widen_for_deployment(plan3, ir) == (None, None)
    # the no-budget early return must ALSO clear a previously pinned plan
    plan4 = partition_optimal(ir, max_stages=3)
    assign_replicas(plan4, ir, worker_budget=6, inventory=inv)
    assert plan4.stage_devices is not None
    assert widen_for_deployment(plan4, ir) == (None, None)
    assert plan4.stage_devices is None
    assert all(s.device_speeds == [] and s.xfer_in_ms == 0.0
               for s in plan4.stages)


def test_replan_on_pinned_deployment_does_not_double_charge_xfer():
    """Measured stage times from a device-pinned executor already include
    the staging hop; the replan candidates must not re-add the modeled
    transfer on top."""
    from repro.core import ModuleDatabase, StageProfiler
    from repro.runtime import ElasticPlanner

    keys = ["h0", "h1", "h2"]
    db = ModuleDatabase("pinned")
    for k in keys:
        def impl(x):
            return x
        impl.__name__ = k
        db.register(k, software=impl)
    ir = linear_ir("pinned", keys, [0.5, 6.0, 0.5], io_shape=(512, 512))
    planner = ElasticPlanner(ir, db=db, inventory=DeviceInventory.host(4))
    ex, _ = planner.executor_for(3, jit=False, worker_budget=6)
    assert planner.current_plan.stage_devices is not None  # pinned deploy
    prof = StageProfiler(3, min_samples=1)
    for _ in range(6):
        # the dominant stage drifted 2x: forces a wider replan candidate
        for k, t in enumerate([0.5, 12.0, 0.5]):
            prof.record(k, t)
    d = planner.replan_from_profile(prof, worker_budget=8, jit=False)
    assert d.replanned and d.plan is not None, d.describe()
    # measured-on-device times already reflect staging AND device speed:
    # neither may be re-applied to the candidate's predicted period
    assert all(s.xfer_in_ms == 0.0 and s.device_speeds == []
               for s in d.plan.stages)
    ex.close()
    if d.executor is not None:
        d.executor.close()


def test_warmup_rounds_cover_every_replica_only_when_pinned():
    """A device-pinned executor warms one group per replica ring (groups
    route to replica seq % r, each pinned device building its own
    executable); degraded/unpinned executors keep the single-group
    warmup."""
    from repro.core.executor import PipelineExecutor

    fns = [lambda env: {"y": env["x"] + 1.0}]
    # planning-only inventory -> degraded: one warm group
    ex = PipelineExecutor(fns, ["x"], ["y"], replicas=[3],
                          devices=[[0, 1, 2]],
                          inventory=DeviceInventory.host(3),
                          max_in_flight=6)
    ex.warmup(np.zeros(2))
    assert ex._seq == 1
    ex.close()
    # thread-widened (no devices): also one warm group
    ex2 = PipelineExecutor(fns, ["x"], ["y"], replicas=[3], max_in_flight=6)
    ex2.warmup(np.zeros(2))
    assert ex2._seq == 1
    ex2.close()


def test_device_inventory_rejects_out_of_range_ordinals():
    from repro.core.executor import PipelineExecutor

    inv = DeviceInventory.host(2)
    with pytest.raises(IndexError, match="out of range"):
        inv.spec(-1)
    with pytest.raises(IndexError, match="out of range"):
        inv.jax_device(2)
    with pytest.raises(IndexError, match="out of range"):
        inv.device_class(-1)
    # the executor surfaces a bad devices matrix at construction
    with pytest.raises(IndexError, match="out of range"):
        PipelineExecutor([lambda env: env], ["x"], ["x"], replicas=[1],
                         devices=[[-1]], inventory=inv)


def test_serve_worker_budget_arg_parses_int_auto_and_rejects_garbage():
    import argparse

    from repro.launch.serve import _budget_arg

    assert _budget_arg("8") == 8
    assert _budget_arg("auto") == "auto"
    with pytest.raises(argparse.ArgumentTypeError, match="expected an int"):
        _budget_arg("fast")


def test_effective_bottleneck_includes_xfer_and_speeds():
    p = PipelinePlan(stages=[
        StagePlan(node_names=["a"], est_time_ms=4.0, replicas=2,
                  devices=[0, 1], device_speeds=[1.0, 1.0]),
        StagePlan(node_names=["b"], est_time_ms=1.0, xfer_in_ms=1.5),
    ])
    # stage 0: 4/2 = 2.0; stage 1: 1.0 + 1.5 xfer = 2.5 → bottleneck
    assert p.effective_bottleneck_ms == pytest.approx(2.5)
    # a faster device raises the widened stage's aggregate rate
    p.stages[0].device_speeds = [1.0, 3.0]
    assert replicated_bottleneck_ms([4.0], [2], [[1.0, 3.0]]) == \
        pytest.approx(1.0)
    with pytest.raises(ValueError, match="replica speeds"):
        replicated_bottleneck_ms([4.0], [2], [[1.0]])
    with pytest.raises(ValueError, match="> 0"):
        replicated_bottleneck_ms([4.0], [2], [[1.0, 0.0]])
    with pytest.raises(ValueError, match="speed vectors"):
        replicated_bottleneck_ms([4.0, 1.0], [2, 1], [[1.0, 1.0]])


def test_per_device_class_roofline_costing():
    from repro.core import NodeCost

    c = NodeCost(flops=1e9, bytes_rw=1e6)
    t_tpu = c.time_ms(device=device_class("tpu"))
    t_cpu = c.time_ms(device=device_class("cpu"))
    assert t_cpu > t_tpu                      # same op, slower device class
    assert c.time_ms() == pytest.approx(t_tpu)   # default = TPU table
    assert device_class("nonsense") is device_class("tpu")
    # measured times win regardless of device class
    m = NodeCost(flops=1e9, bytes_rw=1e6, measured_ms=7.0)
    assert m.time_ms(device=device_class("cpu")) == 7.0
    assert transfer_ms(0) == 0.0
    assert transfer_ms(16e9) == pytest.approx(1000.0)   # 16 GB @ 16 GB/s
    with pytest.raises(ValueError):
        transfer_ms(1.0, 0.0)


# --------------------------------------------------------------------------- #
# Replication-aware batching (serving satellite)
# --------------------------------------------------------------------------- #
def test_replication_aware_batching_scales_by_effective_period():
    from repro.launch.serve import replication_aware_batching

    serial = PipelinePlan(stages=[
        StagePlan(node_names=["a"], est_time_ms=6.0),
        StagePlan(node_names=["b"], est_time_ms=1.0)])
    assert replication_aware_batching(serial, max_batch=4, max_wait_ms=4.0) \
        == (4, 4.0)                                   # ratio 1: unchanged
    widened = PipelinePlan(stages=[
        StagePlan(node_names=["a"], est_time_ms=6.0, replicas=3),
        StagePlan(node_names=["b"], est_time_ms=1.0)])
    mb, wait = replication_aware_batching(widened, max_batch=4,
                                          max_wait_ms=4.0)
    assert mb == 12 and wait == pytest.approx(4.0 / 3.0)   # ratio 3
    # growth clamp + wait floor
    huge = PipelinePlan(stages=[
        StagePlan(node_names=["a"], est_time_ms=64.0, replicas=64),
        StagePlan(node_names=["b"], est_time_ms=1.0)])
    mb, wait = replication_aware_batching(huge, max_batch=4, max_wait_ms=4.0)
    assert mb == 16 and wait == pytest.approx(1.0)         # clamped at 4x
    mb, wait = replication_aware_batching(widened, max_batch=1,
                                          max_wait_ms=0.3)
    assert mb >= 1 and wait >= 0.25
    with pytest.raises(ValueError):
        replication_aware_batching(serial, max_batch=0, max_wait_ms=1.0)


def test_request_queue_server_applies_plan_sizing():
    from repro.core.executor import PipelineExecutor
    from repro.launch.serve import RequestQueueServer

    ex = PipelineExecutor([lambda env: {"y": env["x"]}], ["x"], ["y"])
    plan = PipelinePlan(stages=[
        StagePlan(node_names=["a"], est_time_ms=8.0, replicas=4)])
    srv = RequestQueueServer(ex, max_batch=2, max_wait_ms=4.0, plan=plan)
    assert srv.max_batch == 8 and srv.max_wait_ms == pytest.approx(1.0)
    srv2 = RequestQueueServer(ex, max_batch=2, max_wait_ms=4.0)
    assert srv2.max_batch == 2 and srv2.max_wait_ms == 4.0


# --------------------------------------------------------------------------- #
# Executor device plumbing (single-real-device paths)
# --------------------------------------------------------------------------- #
def test_executor_devices_validation_and_single_device_degrade():
    from repro.core.executor import PipelineExecutor

    fns = [lambda env: {"y": env["x"] + 1.0}]
    with pytest.raises(ValueError, match="requires replicas"):
        PipelineExecutor(fns, ["x"], ["y"], devices=[[0]])
    with pytest.raises(ValueError, match="per replica"):
        PipelineExecutor(fns, ["x"], ["y"], replicas=[2], devices=[[0]])
    # planning-only inventory (no jax devices): degrade, no staging hop
    from repro.core import StageProfiler

    inv = DeviceInventory.host(4)
    prof = StageProfiler(1, min_samples=1)
    ex = PipelineExecutor(fns, ["x"], ["y"], replicas=[2],
                          devices=[[0, 1]], inventory=inv, profiler=prof)
    assert ex._replica_devs is None                  # degraded to threads
    assert ex.stats().per_stage[0].devices == [0, 1]   # config echo only
    out = ex.run([(np.zeros(2),), (np.ones(2),)])
    np.testing.assert_allclose(np.asarray(out[1]), 2.0)
    ex.close()
    # degraded pinning is NOT in effect: samples must not be attributed
    # to device ordinals nothing was staged onto
    assert prof.device_ms(0) == {}
    assert prof.samples(0) == 2 and prof.replica_ms(0) != {}
    # stats dict carries the new per-stage fields
    d = ex.stats().as_dict()["per_stage"][0]
    assert d["devices"] == [0, 1] and "xfer_ms" in d


def test_profiler_per_device_attribution():
    from repro.core import StageProfiler

    p = StageProfiler(2, min_samples=1)
    for _ in range(3):
        p.record(0, 10.0, replica=0, device=2)
        p.record(0, 20.0, replica=1, device=3)
    p.record(1, 5.0)
    assert set(p.device_ms(0)) == {2, 3}
    assert p.device_ms(0)[2] == pytest.approx(10.0)
    assert p.device_ms(1) == {}
    snap = p.snapshot()
    assert snap["per_stage"][0]["devices"]["3"]["samples"] == 3
    assert "devices" not in snap["per_stage"][1]
    p.reset()
    assert p.device_ms(0) == {}
