"""KV slot pool + incremental decode attention (ISSUE 10 state layer).

Covers the three contracts the continuous-batching path leans on:

1. :class:`KVSlotPool` lifecycle guards — every illegal transition
   (exhaustion, double free, use-after-free, partial append, overflow)
   raises :class:`SlotError` instead of corrupting another request's
   cache, and the dead-row id ``-1`` is a uniform no-op.
2. :class:`DecodeSession` — the slot is returned on every exit path,
   including exceptions (the runtime counterpart of the
   ``state-slot-leak`` lint rule).
3. ``attention_decode`` parity — the O(prefix) incremental step is
   bit-identical to re-running :func:`sw_attention` over the accumulated
   prefix, and the database/tracer thread its ``state=`` marker onto the
   traced node as ``serial_only``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Frontend, Library, ModuleDatabase
from repro.models.zoo import register_decode_modules, sw_attention
from repro.runtime.kvstate import DecodeSession, KVSlotPool, SlotError


def _pool(n_slots: int = 2, max_seq: int = 4) -> KVSlotPool:
    return KVSlotPool(n_slots, max_seq, {"k": (3,), "v": (3,)})


# --------------------------------------------------------------------------- #
# 1. Pool lifecycle guards
# --------------------------------------------------------------------------- #
def test_alloc_unique_and_exhaustion():
    p = _pool(n_slots=3)
    slots = [p.alloc() for _ in range(3)]
    assert len(set(slots)) == 3
    assert p.live_count() == 3
    with pytest.raises(SlotError, match="exhausted"):
        p.alloc()
    # freeing one slot makes exactly one admission possible again
    p.free(slots[1])
    s = p.alloc()
    assert s == slots[1]
    assert p.stats()["high_water"] == 3


def test_double_free_raises_and_dead_row_free_is_noop():
    p = _pool()
    s = p.alloc()
    p.free(s)
    with pytest.raises(SlotError, match="non-live"):
        p.free(s)
    p.free(-1)  # dead row: no-op, not an error
    assert p.frees == 1


def test_append_read_length_roundtrip():
    p = _pool()
    s = p.alloc()
    rows = [np.arange(3, dtype=np.float32) + 10 * t for t in range(3)]
    for t, r in enumerate(rows):
        assert p.length(s) == t
        assert p.append(s, k=r, v=-r) == t
    got = p.read(s)
    np.testing.assert_array_equal(got["k"], np.stack(rows))
    np.testing.assert_array_equal(got["v"], -np.stack(rows))
    # read returns copies: mutating the result must not reach the arena
    got["k"][:] = 99.0
    np.testing.assert_array_equal(p.read(s)["k"], np.stack(rows))
    p.free(s)


def test_append_must_write_every_buffer():
    p = _pool()
    s = p.alloc()
    with pytest.raises(SlotError, match="every buffer"):
        p.append(s, k=np.zeros(3, np.float32))          # missing "v"
    with pytest.raises(SlotError, match="every buffer"):
        p.append(s, k=np.zeros(3, np.float32),
                 v=np.zeros(3, np.float32), extra=np.zeros(3))
    assert p.length(s) == 0                              # nothing advanced
    p.free(s)


def test_slot_full_raises():
    p = _pool(max_seq=2)
    s = p.alloc()
    row = np.zeros(3, np.float32)
    p.append(s, k=row, v=row)
    p.append(s, k=row, v=row)
    with pytest.raises(SlotError, match="full"):
        p.append(s, k=row, v=row)
    p.free(s)


def test_use_after_free_raises_everywhere():
    p = _pool()
    s = p.alloc()
    p.free(s)
    row = np.zeros(3, np.float32)
    with pytest.raises(SlotError):
        p.append(s, k=row, v=row)
    with pytest.raises(SlotError):
        p.read(s)
    with pytest.raises(SlotError):
        p.length(s)


def test_dead_row_is_uniform_noop():
    p = _pool()
    row = np.ones(3, np.float32)
    assert p.append(-1, k=row, v=row) == -1
    assert p.length(-1) == 0
    empty = p.read(-1)
    assert empty["k"].shape == (0, 3) and empty["v"].shape == (0, 3)
    assert p.allocs == 0 and p.live_count() == 0


def test_realloc_resets_length_no_stale_rows():
    p = _pool(n_slots=1)
    s = p.alloc()
    p.append(s, k=np.ones(3, np.float32), v=np.ones(3, np.float32))
    p.free(s)
    s2 = p.alloc()
    assert s2 == s and p.length(s2) == 0
    assert p.read(s2)["k"].shape == (0, 3)
    p.free(s2)


def test_check_no_leaks_audit():
    p = _pool()
    s = p.alloc()
    with pytest.raises(SlotError, match="leak audit"):
        p.check_no_leaks()
    p.check_no_leaks(expected_live=[s])
    p.free(s)
    p.check_no_leaks()


# --------------------------------------------------------------------------- #
# 2. DecodeSession — slot returned on every exit path
# --------------------------------------------------------------------------- #
def test_decode_session_frees_on_normal_exit():
    p = _pool()
    with DecodeSession(p) as ses:
        assert ses.slot is not None and p.live_count() == 1
        p.append(ses.slot, k=np.zeros(3, np.float32),
                 v=np.zeros(3, np.float32))
    assert ses.slot is None
    p.check_no_leaks()


def test_decode_session_frees_on_exception():
    p = _pool()
    with pytest.raises(RuntimeError, match="driver died"):
        with DecodeSession(p):
            raise RuntimeError("driver died mid-request")
    p.check_no_leaks()
    assert p.allocs == 1 and p.frees == 1


# --------------------------------------------------------------------------- #
# 3. Incremental decode attention — parity + stateful registration
# --------------------------------------------------------------------------- #
D, HEADS, HD, T = 8, 2, 4, 5


def _decode_db() -> tuple[ModuleDatabase, KVSlotPool]:
    pool = KVSlotPool(2, T + 1, {"k": (HEADS, HD), "v": (HEADS, HD)})
    db = ModuleDatabase()
    register_decode_modules(db, pool, n_heads=HEADS)
    return db, pool


def _weights(seed: int = 0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    return tuple(jax.random.normal(k, (D, D), jnp.float32) * 0.3 for k in ks)


def test_decode_attention_matches_full_prefix_rerun():
    db, pool = _decode_db()
    attn = db.lookup("attention_decode").software
    wq, wk, wv, wo = _weights()
    x = jax.random.normal(jax.random.PRNGKey(9), (T, D), jnp.float32)
    with DecodeSession(pool) as ses:
        for t in range(T):
            inc = attn(x[t:t + 1], ses.slot, wq, wk, wv, wo)
            ref = sw_attention(x[:t + 1], wq, wk, wv, wo, n_heads=HEADS)
            # bit-identical: _rope_at reuses _rope's fp32 angle math and
            # the structural causal mask matches the -1e30 masked softmax
            np.testing.assert_array_equal(np.asarray(inc[0]),
                                          np.asarray(ref[-1]))
            assert pool.length(ses.slot) == t + 1
    pool.check_no_leaks()


def test_decode_attention_dead_row_touches_nothing():
    db, pool = _decode_db()
    attn = db.lookup("attention_decode").software
    wq, wk, wv, wo = _weights(1)
    x = jax.random.normal(jax.random.PRNGKey(2), (1, D), jnp.float32)
    y = attn(x, -1, wq, wk, wv, wo)
    # a dead row attends over only itself == single-token full attention
    ref = sw_attention(x, wq, wk, wv, wo, n_heads=HEADS)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(ref))
    assert pool.allocs == 0 and pool.live_count() == 0


def test_stateful_registration_and_accelerated_rejection():
    db, _ = _decode_db()
    entry = db.lookup("attention_decode")
    assert entry.state == "kv" and entry.accelerated is None
    with pytest.raises(ValueError, match="stateful"):
        db.register("bad_stateful", software=lambda x: x,
                    accelerated=lambda x: x, state="kv")


def test_trace_threads_state_onto_serial_only_node():
    db, pool = _decode_db()
    lib = Library(db)
    wq, wk, wv, wo = _weights(3)

    def app(x, slot):
        return lib.attention_decode(x, slot, wq, wk, wv, wo)

    x = jax.random.normal(jax.random.PRNGKey(4), (1, D), jnp.float32)
    # trace with the dead row so trace-time execution mutates no state
    ir, _out = Frontend(db).trace(app, x, np.asarray(-1, dtype=np.int64))
    nodes = [n for n in ir.nodes if n.fn_key == "attention_decode"]
    assert len(nodes) == 1
    assert nodes[0].state == "kv" and nodes[0].serial_only
    assert pool.allocs == 0 and pool.live_count() == 0
