"""Async PipelineExecutor + serving loop + pipeline bugfix regressions."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (Frontend, Library, ModuleDatabase, Node,
                        PipelineExecutor, PipelineGenerator, fuse_adjacent_hw,
                        linear_ir)
from repro.core.pipeline import _liveness, make_stage_fns
from repro.launch.serve import RequestQueueServer
from repro.runtime import ElasticPlanner


# --------------------------------------------------------------------------- #
# graph fixtures
# --------------------------------------------------------------------------- #
def _linear_db():
    db = ModuleDatabase("t")
    db.register("mul2", software=lambda x: x * 2.0)
    db.register("add1", software=lambda x: x + 1.0)
    db.register("sq", software=lambda x: x * x)
    db.register("tanh", software=jnp.tanh)
    return db


def _linear_app(lib):
    def app(x):
        return lib.tanh(lib.sq(lib.add1(lib.mul2(x))))
    return app


def _branch_db():
    db = ModuleDatabase("t")
    db.register("a", software=lambda x: x + 1.0)
    db.register("b", software=lambda x: x * 2.0)
    db.register("c", software=lambda x, y: x + y)    # consumes BOTH a and b
    db.register("d", software=lambda x: x - 0.5)
    return db


def _branch_app(lib):
    def app(x):
        u = lib.a(x)
        v = lib.b(u)
        return lib.d(lib.c(u, v))
    return app


def _pipe(db, app, n_threads=3, x=None):
    x = jnp.arange(4.0) if x is None else x
    ir, _ = Frontend(db).trace(app, x, profile=False)
    for n in ir.nodes:
        n.time_ms = 1.0
    return PipelineGenerator(db).generate(ir, n_threads=n_threads)


# --------------------------------------------------------------------------- #
# async run ≡ run_sequential (linear + branching), in order
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("mkdb,mkapp", [(_linear_db, _linear_app),
                                        (_branch_db, _branch_app)])
@pytest.mark.parametrize("pool", [1, 2, 5])
def test_async_run_matches_sequential(mkdb, mkapp, pool):
    db = mkdb()
    app = mkapp(Library(db))
    pipe = _pipe(db, app)
    toks = [jnp.full((4,), float(i + 1)) for i in range(7)]
    want = pipe.run_sequential(toks)
    got = pipe.run_async(toks, max_in_flight=pool)
    assert len(got) == len(want)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-6)


def test_async_matches_sync_wavefront_run():
    db = _branch_db()
    app = _branch_app(Library(db))
    pipe = _pipe(db, app)
    toks = [jnp.full((4,), float(i)) for i in range(5)]
    for g, w in zip(pipe.run_async(toks), pipe.run(toks)):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-6)


# --------------------------------------------------------------------------- #
# bounded token pool
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("pool", [1, 2, 3])
def test_bounded_pool_never_exceeded(pool):
    db = _linear_db()
    app = _linear_app(Library(db))
    pipe = _pipe(db, app)
    ex = pipe.executor(max_in_flight=pool)
    ex.run([jnp.full((4,), float(i)) for i in range(9)])
    s = ex.stats()
    assert s.tokens_retired == 9
    assert 1 <= s.max_in_flight_seen <= pool
    assert ex.in_flight == 0


def test_max_in_flight_zero_rejected_everywhere():
    db = _linear_db()
    app = _linear_app(Library(db))
    pipe = _pipe(db, app)
    pipe.max_in_flight = 0
    with pytest.raises(ValueError, match="max_in_flight"):
        pipe.run([jnp.ones(4)])
    with pytest.raises(ValueError, match="max_in_flight"):
        pipe.executor()
    with pytest.raises(ValueError, match="max_in_flight"):
        PipelineExecutor(pipe.stage_fns, pipe.graph_inputs,
                         pipe.graph_outputs, max_in_flight=0)
    with pytest.raises(ValueError, match="max_in_flight"):
        pipe.run_async([jnp.ones(4)], max_in_flight=-2)
    pipe.max_in_flight = None                    # None = default, still fine
    assert len(pipe.run([jnp.ones(4)])) == 1


# --------------------------------------------------------------------------- #
# micro-batching
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("mkdb,mkapp", [(_linear_db, _linear_app),
                                        (_branch_db, _branch_app)])
def test_microbatch_path_equivalence(mkdb, mkapp):
    db = mkdb()
    app = mkapp(Library(db))
    pipe = _pipe(db, app)
    toks = [jnp.full((4,), float(i + 1)) for i in range(10)]
    want = pipe.run_sequential(toks)
    ex = pipe.executor(max_in_flight=8, microbatch=4)
    got = ex.run(toks)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-6)
    s = ex.stats()
    assert s.groups_admitted < s.tokens_admitted      # stacking happened
    assert s.max_in_flight_seen <= 8


def test_microbatch_splits_on_shape_mismatch():
    db = _linear_db()
    app = _linear_app(Library(db))
    pipe = _pipe(db, app)
    # shape change mid-stream: groups must split rather than stack
    toks = [jnp.ones(4), jnp.ones(4), jnp.ones(3), jnp.ones(3), jnp.ones(4)]
    ex = pipe.executor(max_in_flight=8, microbatch=4)
    got = ex.run(toks)
    want = pipe.run_sequential(toks)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-6)
    assert ex.stats().groups_admitted == 3            # [4,4], [3,3], [4]


def test_padded_microbatch_equivalence_and_no_ragged_groups():
    db = _branch_db()
    app = _branch_app(Library(db))
    pipe = _pipe(db, app)
    toks = [jnp.full((4,), float(i + 1)) for i in range(7)]   # 7 % 3 != 0
    want = pipe.run_sequential(toks)
    ex = pipe.executor(max_in_flight=6, microbatch=3, pad_microbatches=True)
    got = ex.run(toks)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-6)
    s = ex.stats()
    # padding rows never count as tokens
    assert s.tokens_admitted == s.tokens_retired == 7
    # [3], [3], [1] — the singleton tail is never padded (the per-token
    # executables are always warm, so padding would only waste compute)
    assert s.groups_admitted == 3


def test_submit_many_rejects_bad_arity_before_admitting():
    db = _linear_db()
    app = _linear_app(Library(db))
    pipe = _pipe(db, app)
    ex = pipe.executor(max_in_flight=4)
    with pytest.raises(ValueError, match="token 1"):
        ex.submit_many([(jnp.ones(4),), (jnp.ones(4), jnp.ones(4))])
    # all-or-nothing: the valid token 0 must NOT have been issued
    assert ex.stats().tokens_admitted == 0
    assert ex.in_flight == 0


def test_submit_error_keeps_admitted_prefix():
    from repro.core.executor import SubmitError

    db = ModuleDatabase("t")
    db.register("dot4", software=lambda x: x @ jnp.ones(4))   # needs len-4 axis
    db.register("add1", software=lambda x: x + 1.0)
    lib = Library(db)

    def app(x):
        return lib.add1(lib.dot4(x))
    pipe = _pipe(db, app, n_threads=2)
    ex = pipe.executor(max_in_flight=4)
    ok = jnp.ones(4)
    bad = jnp.ones(3)                  # same arity, dim breaks the matmul
    with pytest.raises(SubmitError) as ei:
        ex.submit_many([ok, bad])
    # token 0 stayed admitted, its handle is usable, nothing was re-issued
    assert len(ei.value.handles) == 1
    want = pipe.run_sequential([ok])[0]
    np.testing.assert_allclose(np.asarray(ei.value.handles[0].result()),
                               np.asarray(want), rtol=1e-6)
    # the failed group unwound its pool reservation
    assert ex.in_flight == 0
    assert ex.stats().tokens_admitted == 1


# --------------------------------------------------------------------------- #
# liveness: stage-boundary envs carry exactly the live set
# --------------------------------------------------------------------------- #
def test_stage_boundaries_carry_exact_live_set():
    db = _branch_db()
    lib = Library(db)
    app = _branch_app(lib)
    ir, _ = Frontend(db).trace(app, jnp.arange(3.0), profile=False)
    for n in ir.nodes:
        n.time_ms = 1.0
    pipe = PipelineGenerator(db).generate(ir, n_threads=4)
    bounds = _liveness(ir, pipe.plan)
    assert len(bounds) == pipe.plan.n_stages + 1
    assert bounds[0] == list(ir.graph_inputs)
    # independently recompute the live set at each boundary
    name_to_stage = {nn: si for si, s in enumerate(pipe.plan.stages)
                     for nn in s.node_names}
    produced = set(ir.graph_inputs)
    for k in range(1, pipe.plan.n_stages + 1):
        for nn in pipe.plan.stages[k - 1].node_names:
            produced.update(ir.node(nn).outputs)
        expect = sorted(
            v for v in produced
            if v in ir.graph_outputs
            or any(name_to_stage.get(c, -1) >= k
                   for c in ir.values[v].consumers))
        assert bounds[k] == expect, f"boundary {k}"
    # final boundary is exactly the graph outputs (nothing dead kept alive)
    assert set(bounds[-1]) == set(ir.graph_outputs)
    # and running the pipeline agrees with the reference app
    x = jnp.arange(3.0)
    np.testing.assert_allclose(np.asarray(pipe(x)), np.asarray(app(x)),
                               rtol=1e-6)


# --------------------------------------------------------------------------- #
# fused-node resolution respects shape-gated hw applicability
# --------------------------------------------------------------------------- #
def test_fused_resolution_threads_part_shapes():
    db = ModuleDatabase("t")
    # hw impls are deliberately WRONG (x1000) so a mis-resolution is visible;
    # "g"'s hw module only supports 2-D inputs, and its traced input is 1-D.
    db.register("f", software=lambda x: x + 1.0,
                accelerated=lambda x: x + 1.0)
    db.register("g", software=lambda x: x * 2.0,
                accelerated=lambda x: x * 1000.0,
                applicable=lambda s: len(s) == 2)
    ir = linear_ir("fused", ["f", "g"], [1.0, 1.0], io_shape=(4,))
    fused_ir = fuse_adjacent_hw(ir, db, fused_cost_ms=lambda run: 0.5)
    # g is shape-gated out for 1-D → no fusable hw run → nothing fused,
    # and the traced shapes were recorded for any fusion that does happen
    assert all(not n.fused_from for n in fused_ir.nodes)

    # now a genuinely fused run whose parts recorded their shapes
    db2 = ModuleDatabase("t2")
    db2.register("f", software=lambda x: x + 1.0,
                 accelerated=lambda x: x + 1.0)
    db2.register("g", software=lambda x: x * 2.0,
                 accelerated=lambda x: x * 2.0,
                 applicable=lambda s: len(s) == 1)
    ir2 = linear_ir("fused2", ["f", "g"], [1.0, 1.0], io_shape=(4,))
    fused2 = fuse_adjacent_hw(ir2, db2, fused_cost_ms=lambda run: 0.5)
    fnode = next(n for n in fused2.nodes if n.fused_from)
    assert fnode.fused_input_shapes == [[(4,)], [(4,)]]

    # hand-built fused node whose part "g" sees a gated-out (1-D) shape:
    # resolution must fall back to g's SOFTWARE impl (x2, not x1000)
    ir3 = linear_ir("fused3", ["f", "g"], [1.0, 1.0], io_shape=(4,))
    merged = Node(name="f_0+g_0", fn_key="f+g", inputs=["d0"], outputs=["d2"],
                  time_ms=0.5, placement="hw", fused_from=["f_0", "g_0"],
                  fused_input_shapes=[[(4,)], [(4,)]])
    ir3.nodes = [merged]
    for v in ir3.values.values():
        v.consumers, v.producer = [], None
    ir3.values["d2"].producer = merged.name
    ir3.values["d0"].consumers = [merged.name]
    pipe = PipelineGenerator(db).generate(ir3, n_threads=1)
    x = jnp.arange(4.0)
    np.testing.assert_allclose(np.asarray(pipe(x)),
                               np.asarray((x + 1.0) * 2.0), rtol=1e-6)


# --------------------------------------------------------------------------- #
# serving loop
# --------------------------------------------------------------------------- #
def test_request_queue_server_smoke():
    db = _linear_db()
    app = _linear_app(Library(db))
    pipe = _pipe(db, app)
    ex = pipe.executor(max_in_flight=6, microbatch=3)
    toks = [jnp.full((4,), float(i + 1)) for i in range(11)]
    want = pipe.run_sequential(toks)
    with RequestQueueServer(ex, max_batch=3, max_wait_ms=3.0) as srv:
        reqs = [srv.submit(t) for t in toks]
        got = [r.wait(timeout=60.0) for r in reqs]
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-6)
    stats = srv.stats()
    assert stats["requests_served"] == 11
    assert stats["batches"] >= 1
    assert stats["latency_ms"]["p50"] > 0.0
    assert stats["latency_ms"]["p95"] >= stats["latency_ms"]["p50"]
    assert stats["executor"]["tokens_retired"] == 11
    # every request has a full latency timeline
    for r in reqs:
        assert r.latency_ms is not None and r.queue_ms is not None
        assert r.latency_ms >= r.queue_ms >= 0.0


def test_request_queue_server_propagates_errors():
    db = ModuleDatabase("t")
    db.register("f", software=lambda x: x + 1.0)
    lib = Library(db)

    def app(x):
        return lib.f(x)
    pipe = _pipe(db, app, n_threads=1)
    ex = pipe.executor()
    with RequestQueueServer(ex, max_batch=2, max_wait_ms=1.0) as srv:
        ok = srv.submit(jnp.ones(4))
        bad = srv.submit(jnp.ones(4), jnp.ones(4))      # wrong arity
        np.testing.assert_allclose(np.asarray(ok.wait(timeout=30.0)),
                                   np.full(4, 2.0))
        with pytest.raises((ValueError, TypeError)):
            bad.wait(timeout=30.0)


# --------------------------------------------------------------------------- #
# elastic re-planning rebuilds the executor only when the plan changes
# --------------------------------------------------------------------------- #
def test_elastic_planner_rebuilds_executor_on_plan_change():
    db = _linear_db()
    app = _linear_app(Library(db))
    ir, _ = Frontend(db).trace(app, jnp.arange(4.0), profile=False)
    for i, n in enumerate(ir.nodes):
        n.time_ms = float(i + 1)
    planner = ElasticPlanner(ir, db=db)

    ex2, rebuilt = planner.executor_for(2)
    assert rebuilt and planner.rebuilds == 1
    # same stage count → same boundaries → cached executor, no rebuild
    ex2b, rebuilt = planner.executor_for(2)
    assert ex2b is ex2 and not rebuilt and planner.rebuilds == 1
    # resource change → different boundaries → fresh executor
    ex4, rebuilt = planner.executor_for(4)
    assert rebuilt and ex4 is not ex2 and planner.rebuilds == 2

    toks = [jnp.full((4,), float(i)) for i in range(5)]
    want = [app(t) for t in toks]
    for ex in (ex2, ex4):
        for g, w in zip(ex.run(toks), want):
            np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                       rtol=1e-6)


def test_elastic_planner_without_db_still_plans():
    ir = linear_ir("x", ["a", "b", "c"], [1.0, 2.0, 3.0])
    planner = ElasticPlanner(ir)
    assert planner.boundaries(2) == [0, 2]
    with pytest.raises(ValueError, match="ModuleDatabase"):
        planner.executor_for(2)
