"""Continuous batching — the in-flight join seam (ISSUE 10 tentpole).

Executor level: ``try_join`` fills an unsealed group's padding seat (and
only that), ``try_evict`` turns a seat back into a dead row before the
seal, and ``seam_capacity`` reports exactly the free seats.  The tests
pin the seam open deterministically by blocking the single stage-0
worker inside an older group's stage body — everything behind it in the
ring stays unsealed.

Serving level: randomized join/leave stress through the continuous
:class:`RequestQueueServer` over a stateful KV pipeline, checked against
analytically computed outputs (any slot aliasing, double-append, or
out-of-order retirement shows up as a bitwise mismatch), plus the same
stress under injected transient faults, and the exactly-once
``on_finish`` release hook on shed/expired terminal paths.
"""
from __future__ import annotations

import threading
import time

import numpy as np
import pytest

from repro.core.executor import PipelineExecutor
from repro.launch.serve import (DeadlineExceeded, ExecutorClosed,
                                RequestQueueServer)
from repro.runtime.faults import FaultPlan
from repro.runtime.kvstate import KVSlotPool

IO = 8
MB = 4


def _stage_fns(pool: KVSlotPool, *, stage_ms: float = 1.0,
               gate: threading.Event | None = None,
               entered: threading.Event | None = None) -> list:
    """3-stage decode-shaped host pipeline (pre / stateful kv / post),
    shape-polymorphic over ``[IO]`` and ``[B, IO]``.  When ``gate`` is
    given, the FIRST ``pre`` call signals ``entered`` and blocks on the
    gate — the stage-0 worker is now parked inside a sealed group, so
    every group submitted after it stays unsealed (a deterministic seam).
    """
    first = [True]

    def pre(env):
        if gate is not None and first[0]:
            first[0] = False
            entered.set()
            assert gate.wait(timeout=10.0)
        time.sleep(stage_ms / 1e3)
        x = np.asarray(env["x"], dtype=np.float32)
        return {"x": x + 1.0, "slot": env["slot"]}

    def kv(env):
        x = np.asarray(env["x"], dtype=np.float32)
        x2 = x if x.ndim == 2 else x[None]
        slots = np.atleast_1d(np.asarray(env["slot"])).astype(np.int64)
        y = np.empty_like(x2)
        for i in range(x2.shape[0]):
            sid = int(slots[i])
            hist = pool.read(sid)["k"]
            pool.append(sid, k=x2[i])
            y[i] = x2[i] + hist.sum(axis=0, dtype=np.float32)
        return {"x": y if x.ndim == 2 else y[0]}

    def post(env):
        x = np.asarray(env["x"], dtype=np.float32)
        return {"y": x * 0.5}

    return [pre, kv, post]


def _executor(fns, *, open_groups: bool = True,
              replicas=(1, 1, 1), **kw) -> PipelineExecutor:
    return PipelineExecutor(
        fns, ["x", "slot"], ["y"], max_in_flight=64,
        replicas=list(replicas), microbatch=MB, pad_microbatches=True,
        buckets=(MB,), batched_fns=fns, open_groups=open_groups,
        pad_token=(np.zeros(IO, np.float32), -1), **kw)


def _expected_step(pool_rows: list[np.ndarray], x: np.ndarray) -> np.ndarray:
    """What one decode step must return given the rows already in the
    slot — same float32 ops/order as the kv stage, so bitwise-comparable."""
    row = np.asarray(x, np.float32) + 1.0
    hist = (np.stack(pool_rows) if pool_rows
            else np.zeros((0, IO), np.float32))
    return (row + hist.sum(axis=0, dtype=np.float32)) * 0.5


# --------------------------------------------------------------------------- #
# Executor seam: join / evict / capacity
# --------------------------------------------------------------------------- #
def test_try_join_fills_open_seats_then_refuses():
    pool = KVSlotPool(8, 4, {"k": (IO,)})
    gate, entered = threading.Event(), threading.Event()
    ex = _executor(_stage_fns(pool, gate=gate, entered=entered))
    try:
        blocker = ex.submit(np.zeros(IO, np.float32), -1)
        assert entered.wait(5.0)          # stage-0 worker parked: seam open
        slots = [pool.alloc() for _ in range(4)]
        xs = np.arange(4 * IO, dtype=np.float32).reshape(4, IO)
        hB = ex.submit(xs[0], slots[0])   # 1 real token, 3 padding seats
        assert ex.seam_capacity() == MB - 1
        # signature mismatch never claims a seat
        assert ex.try_join((np.zeros(IO + 1, np.float32), slots[1])) is None
        joins = [ex.try_join((xs[i], slots[i])) for i in (1, 2, 3)]
        assert all(j is not None for j in joins)
        assert ex.seam_capacity() == 0    # group full: seam exhausted
        assert ex.try_join((xs[1], slots[1])) is None
        gate.set()
        np.testing.assert_array_equal(
            np.asarray(blocker.result()), (np.zeros(IO, np.float32) + 1) * 0.5)
        for h, i in zip([hB] + joins, range(4)):
            np.testing.assert_array_equal(np.asarray(h.result()),
                                          _expected_step([], xs[i]))
        st = ex.stats()
        assert st.seam_joins == 3
        assert st.tokens_retired == 5 and st.out_of_order_retired == 0
        # every live row appended exactly once; padding touched nothing
        assert [pool.length(s) for s in slots] == [1, 1, 1, 1]
    finally:
        gate.set()
        ex.close()
    for s in slots:
        pool.free(s)
    pool.check_no_leaks()


def test_try_evict_unsealed_seat_is_dead_row():
    pool = KVSlotPool(4, 4, {"k": (IO,)})
    gate, entered = threading.Event(), threading.Event()
    ex = _executor(_stage_fns(pool, gate=gate, entered=entered))
    try:
        blocker = ex.submit(np.zeros(IO, np.float32), -1)
        assert entered.wait(5.0)
        s_live, s_gone = pool.alloc(), pool.alloc()
        x = np.ones((2, IO), np.float32)
        hB = ex.submit(x[0], s_live)
        hJ = ex.try_join((x[1], s_gone))
        assert hJ is not None
        boom = RuntimeError("client went away")
        assert ex.try_evict(hJ, boom) is True
        assert ex.try_evict(hJ, boom) is True      # idempotent
        gate.set()
        np.testing.assert_array_equal(np.asarray(hB.result()),
                                      _expected_step([], x[0]))
        with pytest.raises(RuntimeError, match="client went away"):
            hJ.result()
        blocker.result()
        # the evicted seat ran as the dead row: its slot was never touched
        assert pool.length(s_gone) == 0 and pool.length(s_live) == 1
        assert ex.stats().seam_evictions == 1
        # once the group sealed and retired, eviction is too late
        assert ex.try_evict(hB) is False
    finally:
        gate.set()
        ex.close()
    pool.free(s_live)
    pool.free(s_gone)
    pool.check_no_leaks()


def test_seam_closed_without_open_groups():
    pool = KVSlotPool(2, 4, {"k": (IO,)})
    ex = _executor(_stage_fns(pool), open_groups=False)
    try:
        assert ex.seam_capacity() == 0
        assert ex.try_join((np.zeros(IO, np.float32), -1)) is None
    finally:
        ex.close()


# --------------------------------------------------------------------------- #
# Serving stress: randomized join/leave, analytic ground truth
# --------------------------------------------------------------------------- #
def _drive_continuous(srv: RequestQueueServer, pool: KVSlotPool,
                      arrivals: np.ndarray, xs: np.ndarray,
                      lengths: np.ndarray) -> list:
    """Sessions of randomized length decode sequentially; the last step
    frees the slot through ``on_finish``.  Returns per-session output
    lists (None entries on error)."""
    n = len(arrivals)
    outs: list = [[None] * int(lengths[i]) for i in range(n)]
    slots: list = [None] * n
    step = [0] * n
    active: dict = {}
    lock = threading.Lock()

    def _release(sess):
        with lock:
            s, slots[sess] = slots[sess], None
        if s is not None:
            pool.free(s)

    def _submit(sess):
        t = step[sess]
        last = t == lengths[sess] - 1
        active[sess] = srv.submit(
            xs[sess, t], slots[sess],
            priority="interactive" if t == 0 else "batch",
            on_finish=(lambda _r, s=sess: _release(s)) if last else None)

    t0 = time.perf_counter()
    nxt = 0
    while nxt < n or active:
        now = time.perf_counter() - t0
        while nxt < n and arrivals[nxt] <= now:
            slots[nxt] = pool.alloc()
            _submit(nxt)
            nxt += 1
        progressed = False
        for sess, r in list(active.items()):
            if not r._event.is_set():
                continue
            progressed = True
            del active[sess]
            outs[sess][step[sess]] = np.asarray(r.wait(0))
            step[sess] += 1
            if step[sess] < lengths[sess]:
                _submit(sess)
        if not progressed:
            time.sleep(0.0002)
    return outs


def _stress(fault_injector=None, replicas=(1, 1, 1)) -> None:
    rng = np.random.default_rng(5)
    n = 20
    lengths = rng.integers(1, 5, size=n)          # join/leave at random times
    arrivals = np.cumsum(rng.exponential(1 / 300.0, size=n))  # bursty overlap
    xs = rng.standard_normal((n, 4, IO)).astype(np.float32)
    pool = KVSlotPool(12, 4, {"k": (IO,)})
    kw = {} if fault_injector is None else {
        "fault_injector": fault_injector, "quarantine_after": 2}
    ex = _executor(_stage_fns(pool), replicas=replicas, **kw)
    srv = RequestQueueServer(ex, max_batch=MB, max_wait_ms=2.0,
                             queue_depth=256, continuous=True)
    with srv:
        outs = _drive_continuous(srv, pool, arrivals, xs, lengths)
    st, xst = srv.stats(), ex.stats()
    ex.close()
    pool.check_no_leaks()                          # every leave freed its slot
    for sess in range(n):
        rows: list = []
        for t in range(int(lengths[sess])):
            y = outs[sess][t]
            assert y is not None, f"session {sess} step {t} never resolved"
            np.testing.assert_array_equal(y, _expected_step(rows, xs[sess, t]))
            rows.append(np.asarray(xs[sess, t], np.float32) + 1.0)
    total = int(lengths.sum())
    assert st["submitted"] == total and st["requests_served"] == total
    assert st["shed"] + st["expired"] + st["failed"] == 0
    assert st["release_errors"] == 0
    assert xst.out_of_order_retired == 0
    ps = pool.stats()
    assert ps["allocs"] == n and ps["frees"] == n  # never aliased, never leaked
    assert ps["high_water"] <= pool.n_slots
    return st, xst


def test_randomized_continuous_stress_bitwise_ground_truth():
    _stress()


def test_continuous_stress_survives_transient_faults():
    # transients on the replicated pure front stage retry on the sibling
    # (one quarantine allowed); the serial stateful stage is never faulted,
    # so retries must not double-append and outputs stay bit-exact
    inj = FaultPlan().transient(0, at_calls=[1, 4, 9]).build()
    st, xst = _stress(fault_injector=inj, replicas=(2, 1, 1))
    assert xst.retries + xst.quarantined >= 1      # the chaos actually landed


# --------------------------------------------------------------------------- #
# on_finish: exactly once, on every terminal path
# --------------------------------------------------------------------------- #
def test_on_finish_exactly_once_on_shed_and_expired():
    pool = KVSlotPool(4, 4, {"k": (IO,)})
    ex = _executor(_stage_fns(pool, stage_ms=2.0))
    calls: list = []
    srv = RequestQueueServer(ex, max_batch=MB, max_wait_ms=2.0,
                             queue_depth=64, continuous=True)
    with srv:
        s1 = pool.alloc()
        r1 = srv.submit(np.zeros(IO, np.float32), s1, deadline_ms=0.001,
                        on_finish=lambda r: (calls.append(("r1", r)),
                                             pool.free(s1)))
        with pytest.raises(DeadlineExceeded):
            r1.wait(5.0)
    # stopped server: the shed path still runs the release hook
    s2 = pool.alloc()
    r2 = srv.submit(np.zeros(IO, np.float32), s2,
                    on_finish=lambda r: (calls.append(("r2", r)),
                                         pool.free(s2)))
    with pytest.raises(ExecutorClosed):
        r2.wait(1.0)
    ex.close()
    assert [c[0] for c in calls] == ["r1", "r2"]   # exactly once each
    assert calls[0][1].error is not None and calls[1][1].error is not None
    pool.check_no_leaks()                           # both slots returned
    st = srv.stats()
    assert st["expired"] == 1 and st["shed"] == 1
    assert st["release_errors"] == 0
