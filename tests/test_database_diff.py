"""Differential sweep: EVERY ModuleDatabase entry, hw vs the jnp reference.

The Off-load Switcher's safety story rests on "the accelerated module
computes the same function as the software fallback".  This harness
enumerates *all* entries of every database the repo builds — including the
``register_fused`` mega-kernels — and asserts hw/sw agreement over a
shape/dtype grid that includes odd sizes and non-multiple-of-block rows.

It is also a registration gate: an entry whose name has no input factory
below FAILS the suite, so a future kernel cannot be registered without
adding its differential coverage here.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.database import ModuleDatabase
from repro.kernels.ops import register_rmsnorm_matmul_modules
from repro.models.harris import make_harris_db


# --------------------------------------------------------------------------- #
# every database the repo constructs
# --------------------------------------------------------------------------- #
def _databases() -> dict[str, ModuleDatabase]:
    rms = ModuleDatabase("rmsnorm-matmul")
    register_rmsnorm_matmul_modules(rms)
    return {"harris": make_harris_db(with_hw=True), "rmsnorm": rms}


# image-plane grid: odd sizes and rows that are NOT multiples of the
# kernels' row blocks (harris ROW_BLOCK=8, rmsnorm ROW_BLOCK=256)
IMG_SHAPES = [(16, 32), (17, 23), (13, 40)]
ROW_SHAPES = [(8, 32), (7, 16), (5, 130)]       # (rows, d) for rmsnorm/matmul
DTYPES = [jnp.float32]
ROW_DTYPES = [jnp.float32, jnp.bfloat16]


def _key(i: int) -> jax.Array:
    return jax.random.PRNGKey(1234 + i)


# entry name -> list of input tuples covering the grid
def _img(i, h, w, c=None, dtype=jnp.float32):
    shape = (h, w) if c is None else (h, w, c)
    return (jax.random.uniform(_key(i), shape, dtype) * 255.0).astype(dtype)


def _inputs_for(name: str) -> list[tuple]:
    cases: list[tuple] = []
    if name in ("cvtColor", "cvtColor+cornerHarris",
                "cvtColor+cornerHarris+convertScaleAbs"):
        for i, (h, w) in enumerate(IMG_SHAPES):
            for dt in DTYPES:
                cases.append((_img(i, h, w, 3, dt),))
    elif name in ("cornerHarris", "normalize", "convertScaleAbs"):
        for i, (h, w) in enumerate(IMG_SHAPES):
            for dt in DTYPES:
                cases.append((_img(i, h, w, None, dt),))
    elif name == "rmsnorm":
        for i, (n, d) in enumerate(ROW_SHAPES):
            for dt in ROW_DTYPES:
                x = jax.random.normal(_key(i), (n, d), jnp.float32).astype(dt)
                s = jax.random.normal(_key(i + 50), (d,),
                                      jnp.float32).astype(dt) * 0.1
                cases.append((x, s))
    elif name == "matmul":
        for i, (n, d) in enumerate(ROW_SHAPES):
            for dt in ROW_DTYPES:
                x = jax.random.normal(_key(i), (n, d), jnp.float32).astype(dt)
                w = jax.random.normal(_key(i + 60), (d, 24),
                                      jnp.float32).astype(dt)
                cases.append((x, w))
    elif name == "rmsnorm+matmul":
        for i, (n, d) in enumerate(ROW_SHAPES):
            for dt in ROW_DTYPES:
                x = jax.random.normal(_key(i), (n, d), jnp.float32).astype(dt)
                s = jax.random.normal(_key(i + 50), (d,),
                                      jnp.float32).astype(dt) * 0.1
                w = jax.random.normal(_key(i + 60), (d, 24),
                                      jnp.float32).astype(dt)
                cases.append((x, s, w))
    return cases


# entries that legitimately have NO accelerated module (paper Table I:
# normalize never got an HLS module); they are still enumerated so a future
# hw registration immediately enters the differential sweep
SW_ONLY_OK = {"normalize"}

_ALL = [(db_name, entry_name)
        for db_name, db in _databases().items()
        for entry_name in db.names()]


def _assert_close(name: str, got, want, dtype) -> None:
    g = np.asarray(got, np.float64)
    w = np.asarray(want, np.float64)
    assert g.shape == w.shape, f"{name}: shape {g.shape} != {w.shape}"
    # normalize by the reference's magnitude: Harris responses are O(1e9+)
    # for uint8-range inputs, rmsnorm outputs O(1); one tolerance serves both
    scale = max(1.0, float(np.max(np.abs(w))))
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(g / scale, w / scale, atol=tol, rtol=tol,
                               err_msg=f"{name}: hw diverged from reference")


def test_every_entry_has_differential_coverage():
    """Registration gate: a database entry without an input factory fails."""
    for db_name, db in _databases().items():
        for name in db.names():
            assert _inputs_for(name), (
                f"database {db_name!r} entry {name!r} has no differential "
                "input factory — add one to tests/test_database_diff.py "
                "before registering the kernel")


@pytest.mark.parametrize("db_name,entry_name", _ALL)
def test_hw_matches_reference_over_grid(db_name, entry_name):
    db = _databases()[db_name]
    e = db.lookup(entry_name)
    assert e is not None
    if e.accelerated is None:
        assert entry_name in SW_ONLY_OK, (
            f"{entry_name!r} has no accelerated impl and is not on the "
            "known software-only list")
        pytest.skip(f"{entry_name} is software-only (as in the paper)")
    cases = _inputs_for(entry_name)
    assert cases
    checked = 0
    for inputs in cases:
        shapes = [jnp.shape(a) for a in inputs]
        if not e.has_hw(*shapes):        # shape-gated: sw path serves these
            continue
        got = e.accelerated(*inputs)
        want = e.software(*inputs)
        _assert_close(f"{db_name}.{entry_name}{shapes}", got, want,
                      inputs[0].dtype)
        checked += 1
    assert checked > 0, (f"{entry_name!r}: applicability gated out every "
                         "grid point — widen the grid")


def test_fused_entries_are_covered():
    """The mega-kernels registered via register_fused are in the sweep."""
    fused = [n for _, n in _ALL if "+" in n]
    assert "cvtColor+cornerHarris" in fused
    assert "cvtColor+cornerHarris+convertScaleAbs" in fused
    assert "rmsnorm+matmul" in fused
