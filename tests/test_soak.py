"""Serving soak: concurrent submitters, small token pool, consistent stats."""
import threading

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Frontend, Library, ModuleDatabase, PipelineGenerator
from repro.launch.serve import RequestQueueServer

N_THREADS = 8
N_PER_THREAD = 250            # 8 x 250 = 2000 requests


def _pipe():
    db = ModuleDatabase("t")
    db.register("mul2", software=lambda x: x * 2.0)
    db.register("add1", software=lambda x: x + 1.0)
    db.register("tanh", software=jnp.tanh)
    lib = Library(db)

    def app(x):
        return lib.tanh(lib.add1(lib.mul2(x)))
    ir, _ = Frontend(db).trace(app, jnp.arange(4.0), profile=False)
    for n in ir.nodes:
        n.time_ms = 1.0
    return PipelineGenerator(db).generate(ir, n_threads=2)


@pytest.mark.slow
def test_soak_concurrent_submit_under_backpressure():
    pipe = _pipe()
    total = N_THREADS * N_PER_THREAD
    # deliberately tiny token pool: the executor's backpressure (admission
    # blocks on the oldest group) and the bounded request queue are BOTH
    # continuously exercised
    ex = pipe.executor(max_in_flight=2, microbatch=2, pad_microbatches=True)
    # warm with a REPRESENTATIVE token: jnp.full(shape, <python float>) is
    # weakly typed, and a strong-f32 warmup (jnp.zeros) would compile a
    # different signature than the traffic below
    ex.warmup(jnp.full((4,), 0.0))
    compiles_warm = pipe.compile_count()

    results: list[list] = [[] for _ in range(N_THREADS)]
    errors: list[BaseException] = []

    with RequestQueueServer(ex, max_batch=2, max_wait_ms=0.5,
                            queue_depth=4) as srv:
        def client(tid: int) -> None:
            try:
                for i in range(N_PER_THREAD):
                    v = float(tid * N_PER_THREAD + i)
                    r = srv.submit(jnp.full((4,), v))
                    results[tid].append((v, r))
            except BaseException as e:           # pragma: no cover
                errors.append(e)

        threads = [threading.Thread(target=client, args=(t,))
                   for t in range(N_THREADS)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        # every request resolves (zero drops under sustained backpressure)
        for tid in range(N_THREADS):
            for v, r in results[tid]:
                out = np.asarray(r.wait(timeout=300.0))
                np.testing.assert_allclose(
                    out, np.tanh(np.full(4, v) * 2.0 + 1.0), rtol=1e-6)

    assert not errors
    st = srv.stats()
    es = st["executor"]
    # counter consistency: everything admitted retired, nothing duplicated
    assert st["requests_served"] == total
    assert es["tokens_admitted"] == es["tokens_retired"] == total
    assert ex.in_flight == 0
    # per-stage counters agree with the token flow
    for s in es["per_stage"]:
        assert s["tokens"] == total
    # latency stats over the full window AND tiny slices are NaN-free
    lat = st["latency_ms"]
    for k in ("mean", "p50", "p95", "max"):
        assert np.isfinite(lat[k]) and lat[k] >= 0.0, f"latency {k}={lat[k]}"
    assert lat["p95"] >= lat["p50"] > 0.0
    assert np.isfinite(st["queue_ms_mean"])
    assert np.isfinite(st["throughput_rps"]) and st["throughput_rps"] > 0
    # steady state: the soak compiled nothing beyond warmup
    assert pipe.compile_count() == compiles_warm
