"""Regression tests for the Frontend's causal-graph reconstruction.

Each bug class below shipped in the pre-fix tracer (ISSUE 8) and broke
trace-to-pipeline for real models:

* constant / never-recorded outputs silently dropped from graph_outputs,
* array kwargs losing their keyword name (misbound at stage replay),
* closure-captured weights producing dangling producer-less values that
  failed ``validate()`` instead of becoming captured graph inputs,
* aliasing (a fn returning an operand unchanged) making one value both a
  node's input and its output.

Plus round-trip property tests (trace → pipeline == eager app) over
nested pytrees, kwargs, repeated calls, and passthrough outputs, and a
verify-rule test for the IR-level dangling-value gate.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
import hypothesis.strategies as st

from repro.analysis import verify_plan
from repro.analysis.diagnostics import ERROR
from repro.core import (CourierIR, Frontend, Library, ModuleDatabase,
                        PipelineGenerator, partition_optimal)
from repro.core.ir import Node
from repro.core.tracer import TraceBindingError


# --------------------------------------------------------------------------- #
# fixtures
# --------------------------------------------------------------------------- #
def _db() -> ModuleDatabase:
    db = ModuleDatabase("t")
    db.register("mul2", software=lambda x: x * 2.0)
    db.register("add", software=lambda x, y: x + y)
    db.register("ident", software=lambda x: x)

    def scale(x, *, w):                     # array only reachable by keyword
        return x * w
    db.register("scale", software=scale)

    def shift(x, k, y):                     # non-array between two arrays
        return x * k + y
    db.register("shift", software=shift)

    def cat(*xs):                           # uninspectable positions
        return jnp.concatenate([jnp.atleast_1d(jnp.asarray(x)) for x in xs])
    db.register("cat", software=cat)
    return db


def _trace_pipe(app, *args, max_stages=2):
    db = app.__self_db__
    ir, out = Frontend(db).trace(app, *args)
    pipe = PipelineGenerator(db).generate(ir, policy="optimal",
                                          max_stages=max_stages)
    return ir, out, pipe


def _app(fn):
    """Bind a user fn to a fresh db + Library, keeping the db reachable."""
    db = _db()
    lib = Library(db)

    def app(*args):
        return fn(lib, *args)
    app.__name__ = getattr(fn, "__name__", "app")
    app.__self_db__ = db
    return app


X = jnp.arange(6.0).reshape(2, 3)
Y = jnp.ones((2, 3), jnp.float32) * 0.5


# --------------------------------------------------------------------------- #
# bug (a): outputs whose id() was never recorded were silently dropped
# --------------------------------------------------------------------------- #
def test_constant_output_is_registered_not_dropped():
    const = jnp.full((2, 3), 7.0)

    def f(lib, x):
        return lib.mul2(x), const            # second output: no call saw it

    ir, out, pipe = _trace_pipe(_app(f), X)
    # pre-fix: graph_outputs had 1 entry and the constant vanished
    assert len(ir.graph_outputs) == 2
    cn = ir.graph_outputs[1]
    assert cn in ir.captured and cn in ir.graph_inputs
    y, c = pipe(X)
    assert jnp.array_equal(y, X * 2.0)
    assert jnp.array_equal(c, const)


def test_passthrough_input_output_round_trips():
    def f(lib, x):
        return lib.mul2(x), x                # plain passthrough of an input

    ir, out, pipe = _trace_pipe(_app(f), X)
    assert len(ir.graph_outputs) == 2
    assert ir.graph_outputs[1] in ir.graph_inputs
    y, x2 = pipe(X)
    assert jnp.array_equal(y, X * 2.0)
    assert jnp.array_equal(x2, X)


# --------------------------------------------------------------------------- #
# bug (b): array kwargs lost their keyword name
# --------------------------------------------------------------------------- #
def test_kwarg_array_keeps_its_keyword():
    def f(lib, x, w):
        return lib.scale(x, w=w)             # software impl is kw-only in w

    ir, out, pipe = _trace_pipe(_app(f), X, Y)
    (node,) = ir.nodes
    assert node.input_kw == [None, "w"]
    # pre-fix: replay appended w positionally -> TypeError in the stage fn
    assert jnp.array_equal(pipe(X, Y), X * Y)


def test_shifted_positionals_rebind_by_name():
    def f(lib, x, y):
        return lib.shift(x, 3.0, y)          # 3.0 folds into params["k"]

    ir, out, pipe = _trace_pipe(_app(f), X, Y)
    (node,) = ir.nodes
    assert node.params == {"k": 3.0}
    # y sat AFTER the folded positional: it must be rebound by name, not
    # replayed at a position that no longer exists
    assert node.input_kw == [None, "y"]
    assert jnp.array_equal(pipe(X, Y), X * 3.0 + Y)


def test_unbindable_positional_raises_trace_binding_error():
    def f(lib, x, y):
        return lib.cat(x, 2.0, y)            # *args: position 2 is unnameable

    app = _app(f)
    with pytest.raises(TraceBindingError):
        Frontend(app.__self_db__).trace(app, X, Y)


# --------------------------------------------------------------------------- #
# bug (c): closure-captured weights -> dangling producer-less values
# --------------------------------------------------------------------------- #
def test_closure_weights_become_captured_graph_inputs():
    w = jnp.linspace(0.1, 1.0, 6).reshape(2, 3)

    def f(lib, x):
        return lib.add(lib.scale(x, w=w), w)     # w first seen mid-trace

    app = _app(f)
    # pre-fix: ir.validate() raised (w's value had no producer and was not
    # a graph input); post-fix the trace succeeds and w is captured
    ir, out, pipe = _trace_pipe(app, X)
    cap_names = [vn for vn in ir.graph_inputs if vn in ir.captured]
    assert len(cap_names) == 1
    assert jnp.array_equal(ir.captured[cap_names[0]], w)
    # captured weights are baked into stages, not per-token traffic
    assert pipe.graph_inputs == [ir.graph_inputs[0]]
    assert jnp.array_equal(pipe(X), X * w + w)


def test_dangling_value_verify_rule():
    ir = CourierIR("dangle")
    ir.add_value("d0", (2, 3), "float32")
    ir.add_value("d1", (2, 3), "float32")                 # no producer
    ir.add_value("d2", (2, 3), "float32", producer="add_0")
    ir.add_node(Node(name="add_0", fn_key="add", inputs=["d0", "d1"],
                     outputs=["d2"], time_ms=1.0))
    ir.graph_inputs = ["d0"]                              # d1 missing
    ir.graph_outputs = ["d2"]
    plan = partition_optimal(ir, max_stages=1)
    diags = [d for d in verify_plan(ir, plan) if d.rule == "dangling-value"]
    assert diags and all(d.severity == ERROR for d in diags)
    # registering d1 as a graph input clears the finding
    ir.graph_inputs = ["d0", "d1"]
    assert not [d for d in verify_plan(ir, plan)
                if d.rule == "dangling-value"]


# --------------------------------------------------------------------------- #
# bug (d): aliasing — fn returns an operand unchanged
# --------------------------------------------------------------------------- #
def test_alias_gets_fresh_value_and_identity_edge():
    def f(lib, x):
        return lib.mul2(lib.ident(x))        # ident aliases its input

    ir, out, pipe = _trace_pipe(_app(f), X)
    for n in ir.nodes:
        assert not set(n.inputs) & set(n.outputs), \
            f"{n.name} consumes and produces the same value"
    gi = ir.graph_inputs[0]
    assert ir.values[gi].producer is None     # input's producer not stomped
    ident = ir.nodes[0]
    assert ident.inputs == [gi] and ident.outputs != [gi]
    assert ir.values[ident.outputs[0]].producer == ident.name
    assert jnp.array_equal(pipe(X), X * 2.0)


def test_pure_identity_app():
    def f(lib, x):
        return lib.ident(x)

    ir, out, pipe = _trace_pipe(_app(f), X)
    assert ir.graph_outputs != ir.graph_inputs     # alias, not the input
    assert jnp.array_equal(pipe(X), X)


def test_repeated_alias_chain():
    def f(lib, x):
        y = lib.ident(x)
        z = lib.ident(y)                      # alias of an alias
        return lib.add(z, x)

    ir, out, pipe = _trace_pipe(_app(f), X)
    names = [v for n in ir.nodes for v in n.outputs]
    assert len(names) == len(set(names))      # every output distinct
    assert jnp.array_equal(pipe(X), X + X)


# --------------------------------------------------------------------------- #
# round-trip property tests: trace -> pipeline == eager app
# --------------------------------------------------------------------------- #
@settings(max_examples=8, deadline=None)
@given(st.integers(min_value=1, max_value=4), st.booleans(), st.booleans())
def test_roundtrip_chain(n_calls, use_kw, passthrough):
    def f(lib, x, w):
        y = x
        for _ in range(n_calls):              # repeated calls to the same fn
            y = lib.scale(y, w=w) if use_kw else lib.mul2(y)
        return (y, x) if passthrough else y

    app = _app(f)
    ir, out, pipe = _trace_pipe(app, X, Y)
    got, want = pipe(X, Y), app(X, Y)
    for g, w_ in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_allclose(g, w_, rtol=1e-6)
    # structural to_json round-trip survives kw bindings and aliases
    ir2 = CourierIR.from_json(ir.to_json())
    assert [n.name for n in ir2.nodes] == [n.name for n in ir.nodes]
    assert [n.input_kw for n in ir2.nodes] == [n.input_kw for n in ir.nodes]
    assert ir2.graph_inputs == ir.graph_inputs
    assert ir2.graph_outputs == ir.graph_outputs


@settings(max_examples=6, deadline=None)
@given(st.integers(min_value=0, max_value=3))
def test_roundtrip_nested_pytree_inputs(depth):
    def f(lib, tree):
        a, (b, c) = tree["a"], tree["bc"]
        h = lib.add(a, b)
        for _ in range(depth):
            h = lib.mul2(h)
        return {"out": lib.add(h, c), "keep": a}

    db = _db()
    lib = Library(db)

    def app(tree):
        return f(lib, tree)
    tree = {"a": X, "bc": (Y, X + 1.0)}
    ir, out = Frontend(db).trace(app, tree)
    # all three leaves are per-token graph inputs, none captured
    assert len(ir.graph_inputs) == 3 and not ir.captured
    pipe = PipelineGenerator(db).generate(ir, policy="optimal", max_stages=2)
    got = pipe(*jax.tree.leaves(tree))
    want = app(tree)
    # graph_outputs follow jax.tree.leaves order over the output dict:
    # sorted keys -> ("keep", "out")
    keep, out_arr = got
    assert jnp.array_equal(keep, want["keep"])
    np.testing.assert_allclose(out_arr, want["out"], rtol=1e-6)


def test_traced_zoo_transformer_matches_jit_of_untraced():
    """The acceptance parity claim: traced+fused pipeline vs jax.jit(app)."""
    from repro.models.zoo import (init_transformer_params, make_zoo_db,
                                  transformer_demo)

    db = make_zoo_db()
    app = transformer_demo(Library(db), init_transformer_params(
        jax.random.PRNGKey(0), n_layers=1, d=16, ff=32, n_heads=2, vocab=32))
    x = jax.random.normal(jax.random.PRNGKey(3), (8, 16), jnp.float32)
    ir, _ = Frontend(db).trace(app, x)
    pipe = PipelineGenerator(db).generate(ir, policy="optimal", fuse=True,
                                          max_stages=3)
    assert any(n.fused_from for n in pipe.ir.nodes)   # mega-kernel fired
    assert pipe.captured and pipe.graph_inputs == [ir.graph_inputs[0]]
    assert jnp.array_equal(pipe(x), jax.jit(app)(x))
