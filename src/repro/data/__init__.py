from .pipeline import Batch, PrefetchIterator, SyntheticLMData

__all__ = ["Batch", "PrefetchIterator", "SyntheticLMData"]
