"""Data pipeline — deterministic synthetic LM stream + host-side prefetch.

Determinism is the fault-tolerance contract: ``batch(step)`` is a pure
function of (seed, step), so a restarted job resumes mid-epoch with the
exact same token stream, and every data-parallel host slices the same
global batch by ``process_index`` without coordination.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Iterator

import jax
import numpy as np


@dataclass
class Batch:
    ids: np.ndarray          # [B, S] int32
    labels: np.ndarray       # [B, S] int32 (next-token targets)
    mask: np.ndarray         # [B, S] float32


class SyntheticLMData:
    """Structured synthetic tokens (repeating n-gram motifs + noise).

    Motif structure gives a learnable signal so the end-to-end example can
    show a *decreasing* loss, unlike iid-uniform tokens.
    """

    def __init__(self, vocab: int, seq_len: int, global_batch: int,
                 seed: int = 0, motif_len: int = 8, n_motifs: int = 64,
                 noise: float = 0.05):
        self.vocab, self.seq_len, self.global_batch = vocab, seq_len, global_batch
        self.seed, self.noise = seed, noise
        rng = np.random.default_rng(seed)
        self.motifs = rng.integers(0, vocab, (n_motifs, motif_len), dtype=np.int32)

    # -- multi-host slicing -------------------------------------------------- #
    def local_slice(self) -> tuple[int, int]:
        n, i = jax.process_count(), jax.process_index()
        per = self.global_batch // n
        return i * per, per

    def batch(self, step: int, local_only: bool = False) -> Batch:
        rng = np.random.default_rng((self.seed, step))
        start, per = self.local_slice() if local_only else (0, self.global_batch)
        m_len = self.motifs.shape[1]
        reps = self.seq_len // m_len + 2
        idx = rng.integers(0, len(self.motifs), (per, reps))
        toks = self.motifs[idx].reshape(per, -1)[:, :self.seq_len + 1]
        flip = rng.random(toks.shape) < self.noise
        toks = np.where(flip, rng.integers(0, self.vocab, toks.shape), toks)
        toks = toks.astype(np.int32)
        return Batch(ids=toks[:, :-1], labels=toks[:, 1:],
                     mask=np.ones((per, self.seq_len), np.float32))

    def __iter__(self) -> Iterator[Batch]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


class PrefetchIterator:
    """Background-thread prefetch (depth-bounded), overlapping host data
    generation with device compute — the data-pipeline half of the paper's
    token pipeline."""

    def __init__(self, it: Iterator, depth: int = 2):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._it = it
        self._done = object()
        self._thread = threading.Thread(target=self._fill, daemon=True)
        self._thread.start()

    def _fill(self):
        try:
            for x in self._it:
                self._q.put(x)
        finally:
            self._q.put(self._done)

    def __iter__(self):
        return self

    def __next__(self):
        x = self._q.get()
        if x is self._done:
            raise StopIteration
        return x
