"""Flash attention — Pallas TPU kernel (the DB's "hardware module" for attention).

Design (TPU-native, not a CUDA port):
  * grid = (batch·heads, T/BQ): one program owns a [BQ, hd] query block in
    VMEM and streams K/V blocks of [BK, hd] from the full-sequence refs,
    maintaining the online-softmax running (max, sum, accumulator) in f32
    registers — the HBM→VMEM→VREG hierarchy replaces the CUDA shared-memory
    staging of the original algorithm.
  * block sizes are MXU-aligned (multiples of 128 on the contracting dim,
    8×128 vector lanes); BQ/BK default 512/512 → VMEM working set
    ≈ BQ·hd + 2·BK·hd + BQ·BK f32 ≈ 1.4 MiB at hd=128, far under ~128 MiB.
  * causal + sliding-window masking are fused into the score block; fully
    masked K/V blocks are skipped via the loop bounds (window/causal prune).

Backward uses the standard recompute strategy via ``jax.custom_vjp``:
residuals are (q, k, v, o, lse); dq/dk/dv kernels re-stream blocks and
rebuild probabilities from the saved logsumexp — no [T, M] tensor is ever
materialized in either pass.

Validated against ``ref.reference_attention`` in interpret mode (CPU).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

DEFAULT_BQ = 512
DEFAULT_BK = 512
NEG_INF = -1e30


# --------------------------------------------------------------------------- #
# forward kernel
# --------------------------------------------------------------------------- #
def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, *,
                bq: int, bk: int, causal: bool, window: int, scale: float):
    qi = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32) * scale            # [bq, hd]
    M = k_ref.shape[1]
    nk = M // bk
    hd = q.shape[-1]

    q_pos = qi * bq + jax.lax.iota(jnp.int32, bq)

    def body(j, carry):
        acc, m_i, l_i = carry
        k = pl.load(k_ref, (pl.ds(0, 1), pl.ds(j * bk, bk), slice(None))
                    )[0].astype(jnp.float32)            # [bk, hd]
        v = pl.load(v_ref, (pl.ds(0, 1), pl.ds(j * bk, bk), slice(None))
                    )[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))   # [bq, bk]
        k_pos = j * bk + jax.lax.iota(jnp.int32, bk)
        d = q_pos[:, None] - k_pos[None, :]
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask &= d >= 0
        if window > 0:
            mask &= d < window
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m_i, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_i - m_new)
        l_new = alpha * l_i + jnp.sum(p, axis=-1)
        acc = acc * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        return acc, m_new, l_new

    # causal prune: query block qi only sees k blocks j with
    # j*bk <= qi*bq + bq - 1 (fully-masked trailing blocks are skipped)
    j_hi = (qi * bq + bq - 1) // bk + 1 if causal else nk
    acc0 = (jnp.zeros((bq, hd), jnp.float32),
            jnp.full((bq,), NEG_INF, jnp.float32),
            jnp.zeros((bq,), jnp.float32))
    acc, m_i, l_i = jax.lax.fori_loop(0, j_hi, body, acc0)
    out = acc / jnp.maximum(l_i, 1e-30)[:, None]
    o_ref[0] = out.astype(o_ref.dtype)
    lse_ref[0] = m_i + jnp.log(jnp.maximum(l_i, 1e-30))


def _fwd(q, k, v, *, causal, window, bq, bk, interpret):
    """q: [BH, T, hd], k/v: [BH, M, hd] → (o [BH, T, hd], lse [BH, T])."""
    BH, T, hd = q.shape
    M = k.shape[1]
    bq = min(bq, T)
    bk = min(bk, M)
    assert T % bq == 0 and M % bk == 0, (T, bq, M, bk)
    scale = 1.0 / np.sqrt(hd)
    grid = (BH, T // bq)
    kernel = functools.partial(_fwd_kernel, bq=bq, bk=bk, causal=causal,
                               window=window, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, M, hd), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, M, hd), lambda b, i: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, bq), lambda b, i: (b, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, T, hd), q.dtype),
            jax.ShapeDtypeStruct((BH, T), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)


# --------------------------------------------------------------------------- #
# backward kernels (recompute from lse)
# --------------------------------------------------------------------------- #
def _bwd_dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref, *,
                   bq: int, bk: int, causal: bool, window: int, scale: float):
    qi = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32) * scale
    do = do_ref[0].astype(jnp.float32)                   # [bq, hd]
    lse = lse_ref[0]                                     # [bq]
    delta = delta_ref[0]                                 # [bq]
    M = k_ref.shape[1]
    nk = M // bk
    q_pos = qi * bq + jax.lax.iota(jnp.int32, bq)

    def body(j, dq):
        k = pl.load(k_ref, (pl.ds(0, 1), pl.ds(j * bk, bk),
                            slice(None)))[0].astype(jnp.float32)
        v = pl.load(v_ref, (pl.ds(0, 1), pl.ds(j * bk, bk),
                            slice(None)))[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))
        k_pos = j * bk + jax.lax.iota(jnp.int32, bk)
        d = q_pos[:, None] - k_pos[None, :]
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask &= d >= 0
        if window > 0:
            mask &= d < window
        s = jnp.where(mask, s, NEG_INF)
        p = jnp.exp(s - lse[:, None])                     # [bq, bk]
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())))
        ds = p * (dp - delta[:, None])
        return dq + jax.lax.dot_general(ds, k, (((1,), (0,)), ((), ())))

    j_hi = (qi * bq + bq - 1) // bk + 1 if causal else nk
    dq = jax.lax.fori_loop(0, j_hi, body,
                           jnp.zeros_like(q))
    dq_ref[0] = (dq * scale).astype(dq_ref.dtype)


def _bwd_dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                    dk_ref, dv_ref, *,
                    bq: int, bk: int, causal: bool, window: int, scale: float):
    ki = pl.program_id(1)
    k = k_ref[0].astype(jnp.float32)                      # [bk, hd]
    v = v_ref[0].astype(jnp.float32)
    T = q_ref.shape[1]
    nq = T // bq
    k_pos = ki * bk + jax.lax.iota(jnp.int32, bk)

    def body(i, carry):
        dk, dv = carry
        q = pl.load(q_ref, (pl.ds(0, 1), pl.ds(i * bq, bq), slice(None))
                    )[0].astype(jnp.float32) * scale
        do = pl.load(do_ref, (pl.ds(0, 1), pl.ds(i * bq, bq), slice(None))
                     )[0].astype(jnp.float32)
        lse = pl.load(lse_ref, (pl.ds(0, 1), pl.ds(i * bq, bq)))[0]
        delta = pl.load(delta_ref, (pl.ds(0, 1), pl.ds(i * bq, bq)))[0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # [bq, bk]
        q_pos = i * bq + jax.lax.iota(jnp.int32, bq)
        d = q_pos[:, None] - k_pos[None, :]
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask &= d >= 0
        if window > 0:
            mask &= d < window
        s = jnp.where(mask, s, NEG_INF)
        p = jnp.exp(s - lse[:, None])
        dv = dv + jax.lax.dot_general(p, do, (((0,), (0,)), ((), ())))
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())))
        ds = p * (dp - delta[:, None])
        dk = dk + jax.lax.dot_general(ds, q, (((0,), (0,)), ((), ())))
        return dk, dv

    i_lo = (ki * bk) // bq if causal else 0
    dk0 = jnp.zeros_like(k)
    dv0 = jnp.zeros_like(v)
    # q was pre-scaled in the loop body, so dk already carries the 1/sqrt(hd)
    dk, dv = jax.lax.fori_loop(i_lo, nq, body, (dk0, dv0))
    dk_ref[0] = dk.astype(dk_ref.dtype)
    dv_ref[0] = dv.astype(dv_ref.dtype)


def _bwd(q, k, v, o, lse, do, *, causal, window, bq, bk, interpret):
    BH, T, hd = q.shape
    M = k.shape[1]
    bq = min(bq, T)
    bk = min(bk, M)
    scale = 1.0 / np.sqrt(hd)
    delta = jnp.sum(o.astype(jnp.float32) * do.astype(jnp.float32), axis=-1)

    dq = pl.pallas_call(
        functools.partial(_bwd_dq_kernel, bq=bq, bk=bk, causal=causal,
                          window=window, scale=scale),
        grid=(BH, T // bq),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, M, hd), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, M, hd), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, bq, hd), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, bq), lambda b, i: (b, i)),
            pl.BlockSpec((1, bq), lambda b, i: (b, i)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, T, hd), q.dtype),
        interpret=interpret,
    )(q, k, v, do, lse, delta)

    dk, dv = pl.pallas_call(
        functools.partial(_bwd_dkv_kernel, bq=bq, bk=bk, causal=causal,
                          window=window, scale=scale),
        grid=(BH, M // bk),
        in_specs=[
            pl.BlockSpec((1, T, hd), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, T, hd), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, T), lambda b, j: (b, 0)),
            pl.BlockSpec((1, T), lambda b, j: (b, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bk, hd), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, j: (b, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, M, hd), k.dtype),
            jax.ShapeDtypeStruct((BH, M, hd), v.dtype),
        ],
        interpret=interpret,
    )(q, k, v, do, lse, delta)
    return dq, dk, dv


# --------------------------------------------------------------------------- #
# public entry: [B, T, H, hd] GQA attention with custom VJP
# --------------------------------------------------------------------------- #
@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, window: int = 0,
                    bq: int = DEFAULT_BQ, bk: int = DEFAULT_BK,
                    interpret: bool = True) -> jax.Array:
    """q: [B, T, H, hd]; k/v: [B, M, H, hd] (kv pre-expanded) → [B, T, H, hd]."""
    o, _ = _flash_fwd(q, k, v, causal, window, bq, bk, interpret)
    return o


def _flash_fwd(q, k, v, causal, window, bq, bk, interpret):
    B, T, H, hd = q.shape
    M = k.shape[1]
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, T, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * H, M, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * H, M, hd)
    o, lse = _fwd(qf, kf, vf, causal=causal, window=window, bq=bq, bk=bk,
                  interpret=interpret)
    out = o.reshape(B, H, T, hd).transpose(0, 2, 1, 3)
    return out, (q, k, v, out, lse)


def _flash_bwd(causal, window, bq, bk, interpret, res, g):
    q, k, v, o, lse = res
    B, T, H, hd = q.shape
    M = k.shape[1]
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, T, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * H, M, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * H, M, hd)
    of = o.transpose(0, 2, 1, 3).reshape(B * H, T, hd)
    gf = g.transpose(0, 2, 1, 3).reshape(B * H, T, hd)
    dq, dk, dv = _bwd(qf, kf, vf, of, lse, gf, causal=causal, window=window,
                      bq=bq, bk=bk, interpret=interpret)
    un = lambda x, L: x.reshape(B, H, L, hd).transpose(0, 2, 1, 3)
    return un(dq, T), un(dk, M), un(dv, M)


flash_attention.defvjp(
    lambda q, k, v, causal, window, bq, bk, interpret:
        _flash_fwd(q, k, v, causal, window, bq, bk, interpret),
    _flash_bwd)
