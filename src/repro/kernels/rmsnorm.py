"""Fused RMSNorm — Pallas kernel (row-tiled, f32 accumulation in VMEM)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROW_BLOCK = 256
INTERPRET = True


def _rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)                 # [rb, d]
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * (1.0 + s_ref[...].astype(jnp.float32))
                  ).astype(o_ref.dtype)


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6, *,
            row_block: int = ROW_BLOCK,
            interpret: bool | None = None) -> jax.Array:
    """x: [N, d] (flatten leading dims first), scale: [d]."""
    N, d = x.shape
    rb = row_block if N % row_block == 0 else N
    return pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(N // rb,),
        in_specs=[pl.BlockSpec((rb, d), lambda i: (i, 0)),
                  pl.BlockSpec((d,), lambda i: (0,))],
        out_specs=pl.BlockSpec((rb, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N, d), x.dtype),
        interpret=INTERPRET if interpret is None else interpret,
    )(x, scale)
