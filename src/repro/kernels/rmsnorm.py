"""Fused RMSNorm — Pallas kernel (row-tiled, f32 accumulation in VMEM)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROW_BLOCK = 256
INTERPRET = True


def _rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)                 # [rb, d]
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * (1.0 + s_ref[...].astype(jnp.float32))
                  ).astype(o_ref.dtype)


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6, *,
            row_block: int = ROW_BLOCK,
            interpret: bool | None = None) -> jax.Array:
    """x: [N, d] (flatten leading dims first), scale: [d]."""
    N, d = x.shape
    rb = row_block if N % row_block == 0 else N
    return pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(N // rb,),
        in_specs=[pl.BlockSpec((rb, d), lambda i: (i, 0)),
                  pl.BlockSpec((d,), lambda i: (0,))],
        out_specs=pl.BlockSpec((rb, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N, d), x.dtype),
        interpret=INTERPRET if interpret is None else interpret,
    )(x, scale)


# --------------------------------------------------------------------------- #
# Fused rmsnorm + matmul epilogue (normalized rows never round-trip to HBM)
# --------------------------------------------------------------------------- #
def _rmsnorm_matmul_kernel(x_ref, s_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)                 # [rb, d]
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = (x * jax.lax.rsqrt(var + eps)
         * (1.0 + s_ref[...].astype(jnp.float32)))
    o_ref[...] = jnp.dot(y, w_ref[...].astype(jnp.float32),
                         preferred_element_type=jnp.float32
                         ).astype(o_ref.dtype)


def rmsnorm_matmul(x: jax.Array, scale: jax.Array, w: jax.Array,
                   eps: float = 1e-6, *, row_block: int = ROW_BLOCK,
                   interpret: bool | None = None) -> jax.Array:
    """Fused ``rmsnorm(x, scale) @ w``; x: [N, d], scale: [d], w: [d, out].

    The normalized activations are produced and consumed inside one
    ``pallas_call`` per row block — unfused, the [N, d] normalized tensor is
    written to and re-read from HBM between the two ops, which the roofline
    cost model charges as the dominant term for memory-bound d.
    """
    N, d = x.shape
    d2, dout = w.shape
    if d2 != d:
        raise ValueError(f"rmsnorm_matmul: x has d={d} but w has d={d2}")
    rb = row_block if N % row_block == 0 else N
    return pl.pallas_call(
        functools.partial(_rmsnorm_matmul_kernel, eps=eps),
        grid=(N // rb,),
        in_specs=[pl.BlockSpec((rb, d), lambda i: (i, 0)),
                  pl.BlockSpec((d,), lambda i: (0,)),
                  pl.BlockSpec((d, dout), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((rb, dout), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N, dout), x.dtype),
        interpret=INTERPRET if interpret is None else interpret,
    )(x, scale, w)
