"""jit'd public wrappers around the Pallas kernels.

The dispatch switch (`use_kernels`) is the kernels' Off-load Switcher: on
TPU the Pallas modules run natively; on CPU they run in interpret mode for
validation, and the default execution path falls back to the jnp
references — mirroring the paper's hw-if-available / sw-fallback rule.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .flash_attention import flash_attention
from .harris import convert_scale_abs as _csa_kernel
from .harris import corner_harris as _harris_kernel
from .harris import cvt_color as _cvt_kernel
from .rmsnorm import rmsnorm as _rmsnorm_kernel

_USE_KERNELS = False      # CPU container default: jnp refs; TPU: flip on


def use_kernels(on: bool = True) -> None:
    global _USE_KERNELS
    _USE_KERNELS = on


def kernels_enabled() -> bool:
    return _USE_KERNELS


@functools.partial(jax.jit, static_argnames=("causal", "window"))
def attention(q, k, v, causal: bool = True, window: int = 0):
    """[B, T, H, hd] × [B, M, H, hd] (kv pre-expanded) → [B, T, H, hd]."""
    if _USE_KERNELS:
        return flash_attention(q, k, v, causal, window)
    return ref.reference_attention(q, k, v, causal, window)


@jax.jit
def rmsnorm(x, scale):
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    if _USE_KERNELS:
        return _rmsnorm_kernel(x2, scale).reshape(shape)
    return ref.reference_rmsnorm(x2, scale).reshape(shape)


@jax.jit
def cvt_color(img):
    if _USE_KERNELS:
        return _cvt_kernel(img)
    return ref.reference_cvt_color(img)


@functools.partial(jax.jit, static_argnames=("block_size", "k"))
def corner_harris(gray, block_size: int = 2, k: float = 0.04):
    if _USE_KERNELS:
        return _harris_kernel(gray, block_size, k)
    return ref.reference_corner_harris(gray, block_size, k)


@functools.partial(jax.jit, static_argnames=("alpha", "beta"))
def convert_scale_abs(x, alpha: float = 1.0, beta: float = 0.0):
    if _USE_KERNELS:
        return _csa_kernel(x, alpha, beta)
    return ref.reference_convert_scale_abs(x, alpha, beta)
