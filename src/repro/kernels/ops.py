"""jit'd public wrappers around the Pallas kernels.

The dispatch switch (`use_kernels`) is the kernels' Off-load Switcher: on
TPU the Pallas modules run natively; on CPU they run in interpret mode for
validation, and the default execution path falls back to the jnp
references — mirroring the paper's hw-if-available / sw-fallback rule.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .flash_attention import flash_attention
from .harris import convert_scale_abs as _csa_kernel
from .harris import corner_harris as _harris_kernel
from .harris import cvt_color as _cvt_kernel
from .harris import harris_fused as _harris_fused_kernel
from .rmsnorm import rmsnorm as _rmsnorm_kernel
from .rmsnorm import rmsnorm_matmul as _rmsnorm_matmul_kernel

_USE_KERNELS = False      # CPU container default: jnp refs; TPU: flip on


def use_kernels(on: bool = True) -> None:
    global _USE_KERNELS
    _USE_KERNELS = on


def kernels_enabled() -> bool:
    return _USE_KERNELS


@functools.partial(jax.jit, static_argnames=("causal", "window"))
def attention(q, k, v, causal: bool = True, window: int = 0):
    """[B, T, H, hd] × [B, M, H, hd] (kv pre-expanded) → [B, T, H, hd]."""
    if _USE_KERNELS:
        return flash_attention(q, k, v, causal, window)
    return ref.reference_attention(q, k, v, causal, window)


@jax.jit
def rmsnorm(x, scale):
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    if _USE_KERNELS:
        return _rmsnorm_kernel(x2, scale).reshape(shape)
    return ref.reference_rmsnorm(x2, scale).reshape(shape)


@jax.jit
def cvt_color(img):
    if _USE_KERNELS:
        return _cvt_kernel(img)
    return ref.reference_cvt_color(img)


@functools.partial(jax.jit, static_argnames=("block_size", "k"))
def corner_harris(gray, block_size: int = 2, k: float = 0.04):
    if _USE_KERNELS:
        return _harris_kernel(gray, block_size, k)
    return ref.reference_corner_harris(gray, block_size, k)


@functools.partial(jax.jit, static_argnames=("alpha", "beta"))
def convert_scale_abs(x, alpha: float = 1.0, beta: float = 0.0):
    if _USE_KERNELS:
        return _csa_kernel(x, alpha, beta)
    return ref.reference_convert_scale_abs(x, alpha, beta)


@jax.jit
def rmsnorm_matmul(x, scale, w):
    """Fused rmsnorm + matmul epilogue; x: [..., d], w: [d, out]."""
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    if _USE_KERNELS:
        out = _rmsnorm_matmul_kernel(x2, scale, w)
    else:
        out = ref.reference_rmsnorm_matmul(x2, scale, w)
    return out.reshape(*shape[:-1], w.shape[-1])


@functools.partial(jax.jit, static_argnames=("block_size", "k", "alpha",
                                             "beta"))
def harris_response(img, block_size: int = 2, k: float = 0.04,
                    alpha: float = 1.0, beta: float = 0.0):
    """Single-call fused Harris chain (cvt → harris → csa)."""
    if _USE_KERNELS:
        return _harris_fused_kernel(img, block_size, k, alpha, beta,
                                    row_block=8)
    gray = ref.reference_cvt_color(img)
    resp = ref.reference_corner_harris(gray, block_size, k)
    return ref.reference_convert_scale_abs(resp, alpha, beta)


# --------------------------------------------------------------------------- #
# Database registration — the rmsnorm/matmul module family.  Mirrors the
# Harris registrations in repro.models.harris but for the transformer-side
# epilogue, so the fusion compiler generalizes beyond the paper's demo: the
# fused "rmsnorm+matmul" hw module is a first-class database row the
# backend resolves when the cost model accepts the fusion.
# --------------------------------------------------------------------------- #
def register_rmsnorm_matmul_modules(db) -> None:
    """Register rmsnorm / matmul (+ fused pair) into a ModuleDatabase."""
    from repro.core.costmodel import (NodeCost, elementwise_cost, fused_cost,
                                      matmul_cost)

    def _c_rms(shapes, dtypes, params) -> NodeCost:
        n, d = shapes[0]
        return elementwise_cost(n * d, flops_per_el=4, bytes_per_el=4,
                                n_operands=2)

    def _c_mm(shapes, dtypes, params) -> NodeCost:
        (n, d), (_, dout) = shapes[0], shapes[1]
        return matmul_cost(n, dout, d, bytes_per_el=4)

    def _c_fused(shapes, dtypes, params) -> NodeCost:
        n, d = shapes[0]
        dout = shapes[2][1] if len(shapes) > 2 else d
        inter = 4 * n * d                 # the normalized [n, d] intermediate
        fe = fused_cost([_c_rms([(n, d)], None, None),
                         _c_mm([(n, d), (d, dout)], None, None)],
                        intermediate_bytes=inter,
                        vmem_required=4 * (8 * d + d + d * dout + 8 * dout))
        return fe.cost

    def _sw_rms(x, scale):
        return ref.reference_rmsnorm(x, scale)

    def _sw_mm(x, w):
        import jax.numpy as jnp
        return jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32),
                       preferred_element_type=jnp.float32).astype(x.dtype)

    db.register("rmsnorm", software=_sw_rms,
                accelerated=lambda x, scale: _rmsnorm_kernel(x, scale),
                applicable=lambda *s: len(s[0]) == 2,
                cost_hw=_c_rms, cost_sw=_c_rms)
    db.register("matmul", software=_sw_mm,
                accelerated=_sw_mm,        # XLA's MXU matmul IS the hw module
                cost_hw=_c_mm, cost_sw=_c_mm)
    db.register_fused(
        ("rmsnorm", "matmul"),
        accelerated=lambda x, scale, w: _rmsnorm_matmul_kernel(x, scale, w),
        applicable=lambda *s: len(s[0]) == 2,
        cost_hw=_c_fused)
