"""Pallas TPU kernels — the module database's "hardware modules".

Each kernel ships three layers (per task spec):
  <name>.py  — pl.pallas_call + explicit BlockSpec VMEM tiling
  ops.py     — jit'd public wrappers with the hw/sw dispatch switch
  ref.py     — pure-jnp oracles (assert_allclose targets)
"""
from . import ops, ref
from .flash_attention import flash_attention
from .harris import convert_scale_abs, corner_harris, cvt_color
from .rmsnorm import rmsnorm

__all__ = ["ops", "ref", "flash_attention", "convert_scale_abs",
           "corner_harris", "cvt_color", "rmsnorm"]
