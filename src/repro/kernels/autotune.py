"""Block-size autotuner with a persistent on-disk cache.

The fused Pallas kernels are parameterized by a row-block size; the best
value depends on (kernel, shape, dtype) and on which target executes it.
Rather than hardcoding one constant, the kernels ask :func:`autotune` to

* sweep a candidate list with a scoring function — either an analytical
  roofline score (cheap, deterministic, the default) or wall-clock timing
  of the actual kernel (``measure`` candidates built by the caller), and
* memoize the winner in a **persistent on-disk cache** keyed by
  ``(kernel, shape, dtype, ...)`` so later processes (and the serving
  steady state) skip the sweep entirely.

Cache location: ``$REPRO_AUTOTUNE_CACHE`` if set, else
``~/.cache/repro-autotune``.  One JSON file, written atomically; safe to
delete at any time (``AutotuneCache.clear`` or ``rm -rf``) — the next run
re-tunes and re-populates it.
"""
from __future__ import annotations

import json
import os
import threading
from dataclasses import dataclass, field
from typing import Any, Callable, Sequence

__all__ = ["AutotuneCache", "TuneResult", "autotune", "default_cache",
           "cache_dir"]


def cache_dir() -> str:
    return os.environ.get(
        "REPRO_AUTOTUNE_CACHE",
        os.path.join(os.path.expanduser("~"), ".cache", "repro-autotune"))


class AutotuneCache:
    """Tiny persistent key → winner store (one JSON file, write-through).

    ``hits``/``misses`` count :meth:`get` outcomes since construction, so
    tests (and ``cache_info`` callers) can observe memoization behavior.
    """

    def __init__(self, path: str | None = None):
        self._path = path
        self._mem: dict[str, Any] | None = None
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0

    @property
    def path(self) -> str:
        return self._path or cache_dir()

    @property
    def file(self) -> str:
        return os.path.join(self.path, "autotune.json")

    def _load(self) -> dict[str, Any]:
        # every caller (get/put/clear/info) already holds self._lock
        if self._mem is None:
            try:
                with open(self.file) as f:
                    self._mem = json.load(f)  # owner: lock holder
            except (OSError, ValueError):
                self._mem = {}  # owner: lock holder
        return self._mem

    def get(self, key: str) -> Any | None:
        with self._lock:
            val = self._load().get(key)
            if val is None:
                self.misses += 1
            else:
                self.hits += 1
            return val

    def put(self, key: str, value: Any) -> None:
        with self._lock:
            mem = self._load()
            mem[key] = value
            try:
                os.makedirs(self.path, exist_ok=True)
                tmp = self.file + ".tmp"
                with open(tmp, "w") as f:
                    json.dump(mem, f, indent=1, sort_keys=True)
                os.replace(tmp, self.file)       # atomic on POSIX
            except OSError:
                pass                             # cache is best-effort only

    def clear(self) -> None:
        with self._lock:
            self._mem = {}
            try:
                os.remove(self.file)
            except OSError:
                pass

    def info(self) -> dict[str, Any]:
        with self._lock:
            return {"path": self.file, "entries": len(self._load()),
                    "hits": self.hits, "misses": self.misses}


default_cache = AutotuneCache()


@dataclass
class TuneResult:
    """Outcome of one autotune query."""

    best: Any                                   # winning candidate
    source: str                                 # "cache" | "tuned"
    scores: dict[str, float] = field(default_factory=dict)


def make_key(kernel: str, key_parts: Sequence[Any]) -> str:
    return kernel + "::" + ",".join(str(p) for p in key_parts)


def autotune(kernel: str, key_parts: Sequence[Any],
             candidates: Sequence[Any],
             score: Callable[[Any], float], *,
             cache: AutotuneCache | None = None) -> TuneResult:
    """Pick the candidate with the lowest score, memoized on disk.

    ``key_parts`` must capture everything the winner depends on (shape,
    dtype, static kernel params, scoring mode); ``score`` returns a
    lower-is-better figure (analytic cost or measured ms; ``inf`` marks an
    infeasible candidate, e.g. a block that would spill VMEM).  All-infeasible
    sweeps fall back to the first candidate rather than failing, so callers
    always get something runnable.
    """
    if not candidates:
        raise ValueError(f"autotune({kernel!r}): empty candidate list")
    cache = cache if cache is not None else default_cache
    key = make_key(kernel, key_parts)
    hit = cache.get(key)
    if hit is not None and hit.get("best") in list(candidates):
        return TuneResult(best=hit["best"], source="cache",
                          scores=hit.get("scores", {}))
    scores = {str(c): float(score(c)) for c in candidates}
    best = min(candidates, key=lambda c: scores[str(c)])
    if scores[str(best)] == float("inf"):
        best = candidates[0]
    cache.put(key, {"best": best, "scores": scores})
    return TuneResult(best=best, source="tuned", scores=scores)
