"""Pure-jnp oracles for every Pallas kernel (the "software functions")."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def reference_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                        causal: bool = True, window: int = 0) -> jax.Array:
    """q: [B, T, H, hd]; k/v: [B, M, H, hd] → [B, T, H, hd], exact softmax."""
    B, T, H, hd = q.shape
    M = k.shape[1]
    s = jnp.einsum("bthd,bmhd->bhtm", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / np.sqrt(hd)
    d = jnp.arange(T)[:, None] - jnp.arange(M)[None, :]
    mask = jnp.ones((T, M), bool)
    if causal:
        mask &= d >= 0
    if window > 0:
        mask &= d < window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhtm,bmhd->bthd", p,
                      v.astype(jnp.float32)).astype(q.dtype)


def reference_rmsnorm(x: jax.Array, scale: jax.Array,
                      eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)
            * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def reference_rmsnorm_matmul(x: jax.Array, scale: jax.Array, w: jax.Array,
                             eps: float = 1e-6) -> jax.Array:
    """Unfused composition oracle: rmsnorm then matmul, f32 accumulation."""
    y = reference_rmsnorm(x, scale, eps).astype(jnp.float32)
    return jnp.dot(y, w.astype(jnp.float32),
                   preferred_element_type=jnp.float32).astype(x.dtype)


# Harris oracles live with the model (repro.models.harris) — re-exported here
# so every kernel has its ref in one namespace.
from repro.models.harris import (convert_scale_abs as reference_convert_scale_abs,
                                 corner_harris as reference_corner_harris,
                                 cvt_color as reference_cvt_color)

__all__ = ["reference_attention", "reference_rmsnorm",
           "reference_rmsnorm_matmul",
           "reference_convert_scale_abs", "reference_corner_harris",
           "reference_cvt_color"]
