"""Harris case-study kernels — the predefined "hardware modules" (paper §IV).

Three Pallas TPU kernels mirror the three HLS modules the paper's database
held (``hls::cvtColor``, ``hls::cornerHarris``, ``hls::convertScaleAbs``);
``normalize`` deliberately has none, exactly like the paper's Table I.

TPU adaptation of the paper's streaming AXI modules:
  * the paper streams pixels over AXI with per-pixel pipelining; here each
    grid program owns a row-block in VMEM and the 8×128 VPU vectorizes
    across the row — block height plays the role of the AXI burst length.
  * cornerHarris needs a 2-row halo (3×3 Sobel then box filter); the host
    wrapper edge-pads the image and each program loads its rows + halo from
    the padded HBM ref with ``pl.load`` (manual DMA), writing only its own
    rows — the BlockSpec analog of the paper's line-buffer BRAMs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .autotune import AutotuneCache, autotune

ROW_BLOCK = 8          # rows per program (8 sublanes × 128-lane rows)
INTERPRET = True       # container is CPU; TPU target flips this off


# --------------------------------------------------------------------------- #
# cvtColor: RGB → gray (elementwise, tiled rows)
# --------------------------------------------------------------------------- #
def _cvt_kernel(img_ref, o_ref):
    img = img_ref[...].astype(jnp.float32)
    o_ref[...] = (0.299 * img[..., 0] + 0.587 * img[..., 1]
                  + 0.114 * img[..., 2])


def cvt_color(img: jax.Array, *, row_block: int = ROW_BLOCK,
              interpret: bool | None = None) -> jax.Array:
    H, W, C = img.shape
    rb = row_block if H % row_block == 0 else H
    return pl.pallas_call(
        _cvt_kernel,
        grid=(H // rb,),
        in_specs=[pl.BlockSpec((rb, W, C), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((rb, W), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((H, W), jnp.float32),
        interpret=INTERPRET if interpret is None else interpret,
    )(img)


# --------------------------------------------------------------------------- #
# cornerHarris: Sobel + box-filtered second moments + response
# --------------------------------------------------------------------------- #
def _harris_kernel(g_ref, o_ref, *, rb: int, W: int, block_size: int,
                   k: float, halo: int):
    i = pl.program_id(0)
    rows = pl.load(g_ref, (pl.ds(i * rb, rb + 2 * halo), slice(None))
                   ).astype(jnp.float32)            # [rb+2h, W+2h]

    def sh(a, dy, dx, h, w):                        # shifted window helper
        return jax.lax.dynamic_slice(a, (dy, dx), (h, w))

    h1, w1 = rb + 2 * halo - 2, W + 2 * halo - 2    # after 3x3 sobel
    dx = (sh(rows, 0, 2, h1, w1) + 2 * sh(rows, 1, 2, h1, w1)
          + sh(rows, 2, 2, h1, w1)
          - sh(rows, 0, 0, h1, w1) - 2 * sh(rows, 1, 0, h1, w1)
          - sh(rows, 2, 0, h1, w1))
    dy = (sh(rows, 2, 0, h1, w1) + 2 * sh(rows, 2, 1, h1, w1)
          + sh(rows, 2, 2, h1, w1)
          - sh(rows, 0, 0, h1, w1) - 2 * sh(rows, 0, 1, h1, w1)
          - sh(rows, 0, 2, h1, w1))
    ixx, iyy, ixy = dx * dx, dy * dy, dx * dy

    def box(a):
        out = jnp.zeros((rb, W), jnp.float32)
        for by in range(block_size):
            for bx in range(block_size):
                out = out + sh(a, by, bx, rb, W)
        return out

    sxx, syy, sxy = box(ixx), box(iyy), box(ixy)
    det = sxx * syy - sxy * sxy
    tr = sxx + syy
    o_ref[...] = det - k * tr * tr


def corner_harris(gray: jax.Array, block_size: int = 2, k: float = 0.04, *,
                  row_block: int = ROW_BLOCK,
                  interpret: bool | None = None) -> jax.Array:
    H, W = gray.shape
    rb = row_block if H % row_block == 0 else H
    halo = 1 + block_size // 2          # sobel (1) + box reach
    # edge-pad on the host (the paper's modules see replicated borders too)
    pad = jnp.pad(gray, ((halo, halo + block_size - 1),
                         (halo, halo + block_size - 1)), mode="edge")
    kernel = functools.partial(_harris_kernel, rb=rb, W=W,
                               block_size=block_size, k=k, halo=halo)
    return pl.pallas_call(
        kernel,
        grid=(H // rb,),
        in_specs=[pl.BlockSpec(memory_space=pl.ANY)],
        out_specs=pl.BlockSpec((rb, W), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((H, W), jnp.float32),
        interpret=INTERPRET if interpret is None else interpret,
    )(pad)


# --------------------------------------------------------------------------- #
# convertScaleAbs: |αx + β| saturated (elementwise, tiled rows)
# --------------------------------------------------------------------------- #
def _csa_kernel(x_ref, o_ref, *, alpha: float, beta: float):
    x = x_ref[...].astype(jnp.float32)
    o_ref[...] = jnp.clip(jnp.abs(x * alpha + beta), 0.0, 255.0)


def convert_scale_abs(x: jax.Array, alpha: float = 1.0, beta: float = 0.0, *,
                      row_block: int = ROW_BLOCK,
                      interpret: bool | None = None) -> jax.Array:
    H, W = x.shape
    rb = row_block if H % row_block == 0 else H
    return pl.pallas_call(
        functools.partial(_csa_kernel, alpha=alpha, beta=beta),
        grid=(H // rb,),
        in_specs=[pl.BlockSpec((rb, W), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((rb, W), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((H, W), jnp.float32),
        interpret=INTERPRET if interpret is None else interpret,
    )(x)


# --------------------------------------------------------------------------- #
# Fused mega-kernel: cvtColor → cornerHarris [→ convertScaleAbs] in ONE pass
# --------------------------------------------------------------------------- #
# The unfused chain bounces gray/response through HBM between pallas_calls
# (the paper's "intermediate data ... stored in the external memory").  Here
# each program converts its padded RGB row-block to gray in a VMEM scratch
# tile, runs Sobel + box + response on it, and (optionally) the
# convertScaleAbs epilogue — the gray and response tiles never leave VMEM.
# On the paper's FPGA the fused cvtColor+cornerHarris module was "too slow
# to use"; on TPU the cost model accepts it because the eliminated HBM
# round-trips dominate (see repro.core.costmodel.fused_cost).

_F32 = 4                                        # intermediate element bytes
_VMEM_BUDGET = 96 * 1024 * 1024                 # leave headroom of 128M VMEM


def _fused_harris_kernel(img_ref, o_ref, gray_ref, *, rb: int, W: int,
                         block_size: int, k: float, halo: int,
                         with_csa: bool, alpha: float, beta: float):
    i = pl.program_id(0)
    rgb = pl.load(img_ref, (pl.ds(i * rb, rb + 2 * halo), slice(None),
                            slice(None))).astype(jnp.float32)
    # cvtColor on the padded block; the gray tile lives in VMEM scratch and
    # is consumed in-place by the stencil below — no HBM round-trip.
    gray_ref[...] = (0.299 * rgb[..., 0] + 0.587 * rgb[..., 1]
                     + 0.114 * rgb[..., 2])
    rows = gray_ref[...]                        # [rb+2h, W+2h+bs-1]

    def sh(a, dy, dx, h, w):
        return jax.lax.dynamic_slice(a, (dy, dx), (h, w))

    h1, w1 = rb + 2 * halo - 2, W + 2 * halo - 2
    dx = (sh(rows, 0, 2, h1, w1) + 2 * sh(rows, 1, 2, h1, w1)
          + sh(rows, 2, 2, h1, w1)
          - sh(rows, 0, 0, h1, w1) - 2 * sh(rows, 1, 0, h1, w1)
          - sh(rows, 2, 0, h1, w1))
    dy = (sh(rows, 2, 0, h1, w1) + 2 * sh(rows, 2, 1, h1, w1)
          + sh(rows, 2, 2, h1, w1)
          - sh(rows, 0, 0, h1, w1) - 2 * sh(rows, 0, 1, h1, w1)
          - sh(rows, 0, 2, h1, w1))
    ixx, iyy, ixy = dx * dx, dy * dy, dx * dy

    def box(a):
        out = jnp.zeros((rb, W), jnp.float32)
        for by in range(block_size):
            for bx in range(block_size):
                out = out + sh(a, by, bx, rb, W)
        return out

    sxx, syy, sxy = box(ixx), box(iyy), box(ixy)
    det = sxx * syy - sxy * sxy
    tr = sxx + syy
    resp = det - k * tr * tr
    if with_csa:                                # fused epilogue, still VMEM
        resp = jnp.clip(jnp.abs(resp * alpha + beta), 0.0, 255.0)
    o_ref[...] = resp


def _roofline_rb_score(rb: int, H: int, Wp: int, halo: int) -> float:
    """Lower-is-better analytic score for a fused-kernel row block.

    HBM read amplification from the halo is ``(rb + 2*halo) / rb``; a small
    per-program launch term rewards larger blocks; blocks whose resident
    tiles (RGB load + gray scratch + ~6 stencil temporaries) would overflow
    VMEM are infeasible.
    """
    tile_rows = rb + 2 * halo
    resident = tile_rows * Wp * _F32 * (3 + 1 + 6)
    if resident > _VMEM_BUDGET:
        return float("inf")
    return (tile_rows / rb) + 0.25 * (H / rb) / max(H, 1)


def fused_row_block(H: int, W: int, block_size: int = 2, *,
                    cache: AutotuneCache | None = None) -> int:
    """Autotuned row-block for :func:`harris_fused` (memoized on disk)."""
    halo = 1 + block_size // 2
    Wp = W + 2 * halo + block_size - 1
    cands = [rb for rb in (8, 16, 32, 64, 128, 256) if H % rb == 0]
    if not cands:
        return H
    res = autotune("harris_fused", (H, W, "float32", block_size), cands,
                   lambda rb: _roofline_rb_score(rb, H, Wp, halo),
                   cache=cache)
    return int(res.best)


def harris_fused(img: jax.Array, block_size: int = 2, k: float = 0.04,
                 alpha: float = 1.0, beta: float = 0.0, *,
                 with_csa: bool = True, row_block: int | None = None,
                 interpret: bool | None = None,
                 cache: AutotuneCache | None = None) -> jax.Array:
    """Single-pass fused Harris: cvtColor → cornerHarris [→ convertScaleAbs].

    One ``pallas_call`` over row blocks; gray and response tiles stay in
    scratch VMEM, with the stencil halo re-loaded from the edge-padded HBM
    input at row-block boundaries (2-row overlap between programs — the
    halo-exchange analog of the paper's line-buffer BRAMs).
    ``row_block=None`` asks the autotuner (persistent cache) for the block.
    """
    H, W, _C = img.shape
    halo = 1 + block_size // 2
    if row_block is None:
        rb = fused_row_block(H, W, block_size, cache=cache)
    else:
        rb = row_block
    rb = rb if H % rb == 0 else H
    pad = jnp.pad(img, ((halo, halo + block_size - 1),
                        (halo, halo + block_size - 1), (0, 0)), mode="edge")
    Wp = W + 2 * halo + block_size - 1
    kernel = functools.partial(_fused_harris_kernel, rb=rb, W=W,
                               block_size=block_size, k=k, halo=halo,
                               with_csa=with_csa, alpha=alpha, beta=beta)
    return pl.pallas_call(
        kernel,
        grid=(H // rb,),
        in_specs=[pl.BlockSpec(memory_space=pl.ANY)],
        out_specs=pl.BlockSpec((rb, W), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((H, W), jnp.float32),
        scratch_shapes=[pltpu.VMEM((rb + 2 * halo, Wp), jnp.float32)],
        interpret=INTERPRET if interpret is None else interpret,
    )(pad)


def harris_fused_pair(img: jax.Array, block_size: int = 2, k: float = 0.04,
                      **kwargs) -> jax.Array:
    """cvtColor+cornerHarris fused module (no epilogue) — the DB entry for
    the demo chain, where ``normalize`` separates cornerHarris from
    convertScaleAbs and limits the fusable run to two functions."""
    kwargs.pop("alpha", None)
    kwargs.pop("beta", None)
    return harris_fused(img, block_size, k, with_csa=False, **kwargs)
