"""CLI for the static-analysis subsystem.

Usage::

    python -m repro.analysis lint [PATH ...]
    python -m repro.analysis verify IR.json [--plan PLAN.json]
                                            [--policy paper|optimal]

``lint`` runs the AST rule set (default target: ``src/repro``) and exits 1
on any finding.  ``verify`` loads a ``CourierIR`` JSON (and optionally a
``PipelinePlan`` JSON; otherwise it partitions the IR itself) and runs the
plan verifier, printing every diagnostic; exits 1 on errors.
"""
from __future__ import annotations

import argparse
import sys


def _cmd_lint(args: argparse.Namespace) -> int:
    from .lint import lint_paths
    paths = args.paths or ["src/repro"]
    findings = lint_paths(paths)
    for d in findings:
        print(d.format())
    print(f"{len(findings)} finding(s) in {', '.join(paths)}")
    return 1 if findings else 0


def _cmd_verify(args: argparse.Namespace) -> int:
    from repro.core.ir import CourierIR
    from repro.core.partition import (PipelinePlan, partition_optimal,
                                      partition_paper)

    from .diagnostics import ERROR
    from .verify import verify_plan

    with open(args.ir, encoding="utf-8") as f:
        ir = CourierIR.from_json(f.read())
    if args.plan:
        with open(args.plan, encoding="utf-8") as f:
            plan = PipelinePlan.from_json(f.read())
    elif args.policy == "paper":
        plan = partition_paper(ir)
    else:
        plan = partition_optimal(ir)
    diags = verify_plan(ir, plan)
    for d in diags:
        print(d.format())
    errors = sum(d.severity == ERROR for d in diags)
    print(f"{len(diags)} finding(s) ({errors} error(s)) for "
          f"{ir.name!r} / {plan.policy!r}")
    return 1 if errors else 0


def main(argv: "list[str] | None" = None) -> int:
    p = argparse.ArgumentParser(prog="python -m repro.analysis",
                                description=__doc__)
    sub = p.add_subparsers(dest="cmd", required=True)
    pl = sub.add_parser("lint", help="lint a source tree")
    pl.add_argument("paths", nargs="*", help="files/dirs (default src/repro)")
    pl.set_defaults(fn=_cmd_lint)
    pv = sub.add_parser("verify", help="verify an IR/plan JSON")
    pv.add_argument("ir", help="CourierIR JSON file")
    pv.add_argument("--plan", help="PipelinePlan JSON file (default: "
                                   "partition the IR)")
    pv.add_argument("--policy", choices=("paper", "optimal"),
                    default="optimal", help="partitioner when no --plan")
    pv.set_defaults(fn=_cmd_verify)
    args = p.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
