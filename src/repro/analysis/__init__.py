"""Static analysis — plan/IR verifier + AST concurrency lint (ISSUE 6).

Two halves, one Diagnostic vocabulary:

* :mod:`repro.analysis.verify` — a pass pipeline over ``CourierIR`` +
  ``PipelinePlan`` that statically checks dataflow well-formedness,
  shape/dtype routing through fused nodes, placement legality, and fusion
  (VMEM) legality *before* a plan is committed to traffic.  Wired as a
  mandatory gate in ``PipelineGenerator.generate``, ``ElasticPlanner.
  replan_from_profile`` and ``RequestQueueServer.swap_executor`` —
  ``REPRO_VERIFY=off`` is the escape hatch.
* :mod:`repro.analysis.lint` — an AST-based concurrency/style linter over
  ``src/repro`` with a registered-rule framework (lock discipline,
  blocking-calls-in-critical-sections, frozen dataclasses, placement
  literals, acquire-without-finally, dead exports).

CLI: ``python -m repro.analysis lint src/repro`` /
``python -m repro.analysis verify ir.json [--plan plan.json]``.
"""
from .diagnostics import Diagnostic, PlanVerificationError, Severity
from .lint import LINT_RULES, lint_paths
from .verify import (VERIFY_ENV, VERIFY_RULES, check_plan, verify_enabled,
                     verify_plan)

__all__ = [
    "Diagnostic", "Severity", "PlanVerificationError",
    "verify_plan", "check_plan", "verify_enabled",
    "VERIFY_ENV", "VERIFY_RULES",
    "lint_paths", "LINT_RULES",
]
