"""Static plan/IR verifier — the legality gate before a plan meets traffic.

A pass pipeline over :class:`~repro.core.ir.CourierIR` +
:class:`~repro.core.partition.PipelinePlan` that re-checks, on the
*committed* artifact, every invariant the planning passes are supposed to
establish: dataflow well-formedness, fused-node routing/shape consistency,
placement legality against the kernel database and device inventory,
replica-vector consistency, and the VMEM spill gate.  The compiler-side
analogy (GCC accelerator plugins, Halide schedule legality) is deliberate —
a plan is a schedule, and a schedule gets verified before it runs.

Rules are registered with :func:`verify_rule` and each returns
:class:`~repro.analysis.diagnostics.Diagnostic` records.  ``verify_plan``
runs every applicable rule; ``check_plan`` raises
:class:`PlanVerificationError` on error-severity findings unless the
``REPRO_VERIFY=off`` escape hatch is set.

Gated call sites: ``PipelineGenerator.generate`` (a fresh build),
``ElasticPlanner.replan_from_profile`` (a failing candidate is discarded and
the old plan keeps serving), ``RequestQueueServer.swap_executor`` (a failing
swap is refused — zero dropped requests).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable, Iterable, Optional

from repro.core.costmodel import VMEM_BYTES
from repro.core.database import ModuleDatabase
from repro.core.ir import CourierIR, Node
from repro.core.partition import PipelinePlan, working_set_bytes
from repro.core.placement import DeviceInventory, Placement

from .diagnostics import (ERROR, WARNING, VERIFY_ENV, Diagnostic,
                          PlanVerificationError, verify_enabled)

__all__ = [
    "verify_plan", "check_plan", "verify_rule", "VERIFY_RULES",
    "VERIFY_ENV", "verify_enabled", "PlanVerificationError", "Diagnostic",
]


@dataclass(frozen=True)
class VerifyContext:
    """Everything a verify rule may look at.  ``db``/``inventory`` are
    optional — rules that need them no-op when absent (a planning-only
    caller can still verify dataflow without a kernel database)."""

    ir: CourierIR
    plan: PipelinePlan
    db: Optional[ModuleDatabase] = None
    inventory: Optional[DeviceInventory] = None
    vmem_bytes: int = VMEM_BYTES

    def node(self, name: str) -> Optional[Node]:
        # lazy name index — rules look nodes up per stage entry, and the
        # per-replan/per-swap gates need that to stay O(1)
        index = self.__dict__.get("_index")
        if index is None:
            index = {n.name: n for n in self.ir.nodes}
            object.__setattr__(self, "_index", index)
        return index.get(name)


Rule = Callable[[VerifyContext], Iterable[Diagnostic]]

#: rule id -> rule fn, in registration (= execution) order
VERIFY_RULES: dict[str, Rule] = {}


def verify_rule(rule_id: str) -> Callable[[Rule], Rule]:
    """Register a verify pass under ``rule_id`` (its Diagnostic.rule)."""
    def deco(fn: Rule) -> Rule:
        VERIFY_RULES[rule_id] = fn
        return fn
    return deco


def _plan_nodes(ctx: VerifyContext):
    """(stage_index, stage, node_name, Node|None) over the plan's order.

    Cached on the context: seven rules walk this and the result must not
    be re-resolved per rule — the gate runs on every replan candidate."""
    cached = ctx.__dict__.get("_plan_nodes")
    if cached is None:
        cached = [(si, s, nn, ctx.node(nn))
                  for si, s in enumerate(ctx.plan.stages)
                  for nn in s.node_names]
        object.__setattr__(ctx, "_plan_nodes", cached)
    return cached


def _stage_label(si: int) -> str:
    return f"#{si}"


# --------------------------------------------------------------------------- #
# dataflow well-formedness
# --------------------------------------------------------------------------- #
@verify_rule("stage-coverage")
def _rule_stage_coverage(ctx: VerifyContext) -> Iterable[Diagnostic]:
    """Every IR node appears in exactly one stage; no phantom names."""
    out: list[Diagnostic] = []
    counts: dict[str, int] = {}
    for si, _s, nn, node in _plan_nodes(ctx):
        counts[nn] = counts.get(nn, 0) + 1
        if node is None:
            out.append(Diagnostic(
                rule="stage-coverage", stage=_stage_label(si), node=nn,
                message=f"stage names node {nn!r} which is not in the IR",
                hint="the plan was built against a different IR revision"))
    for nn, c in counts.items():
        if c > 1:
            out.append(Diagnostic(
                rule="stage-coverage", node=nn,
                message=f"node {nn!r} appears in {c} stages",
                hint="stage boundaries must partition the node list"))
    for n in ctx.ir.nodes:
        if n.name not in counts:
            out.append(Diagnostic(
                rule="stage-coverage", node=n.name,
                message=f"IR node {n.name!r} is not covered by any stage",
                hint="re-run the partitioner against this IR"))
    return out


@verify_rule("stage-order")
def _rule_stage_order(ctx: VerifyContext) -> Iterable[Diagnostic]:
    """Stage concat must equal the IR's chronological (traced) order —
    stages are contiguous runs of it, so any permutation breaks the
    executor's token routing."""
    plan_order = [nn for _si, _s, nn, _n in _plan_nodes(ctx)]
    ir_order = [n.name for n in ctx.ir.nodes]
    if sorted(plan_order) != sorted(ir_order):
        return []                  # coverage rule already owns this case
    if plan_order != ir_order:
        first = next(i for i, (a, b) in enumerate(zip(plan_order, ir_order))
                     if a != b)
        return [Diagnostic(
            rule="stage-order", node=plan_order[first],
            message=(f"stage concatenation diverges from traced order at "
                     f"position {first}: plan has {plan_order[first]!r}, "
                     f"IR has {ir_order[first]!r}"),
            hint="stages must be contiguous runs of ir.nodes order")]
    return []


@verify_rule("produced-once")
def _rule_produced_once(ctx: VerifyContext) -> Iterable[Diagnostic]:
    """Every consumed value is produced exactly once, before its use."""
    out: list[Diagnostic] = []
    produced: dict[str, int] = {v: 1 for v in ctx.ir.graph_inputs}
    for si, _s, nn, node in _plan_nodes(ctx):
        if node is None:
            continue               # coverage rule owns unknown nodes
        for inp in node.inputs:
            if inp not in ctx.ir.values:
                out.append(Diagnostic(
                    rule="produced-once", stage=_stage_label(si), node=nn,
                    message=f"{nn} reads unknown value {inp!r}"))
            elif produced.get(inp, 0) == 0:
                out.append(Diagnostic(
                    rule="produced-once", stage=_stage_label(si), node=nn,
                    message=(f"{nn} consumes {inp!r} before any producer "
                             f"runs"),
                    hint="a producer node was dropped or reordered"))
        for o in node.outputs:
            produced[o] = produced.get(o, 0) + 1
            if produced[o] > 1:
                out.append(Diagnostic(
                    rule="produced-once", stage=_stage_label(si), node=nn,
                    message=f"value {o!r} is produced {produced[o]} times"))
    return out


@verify_rule("output-missing")
def _rule_output_missing(ctx: VerifyContext) -> Iterable[Diagnostic]:
    """Graph outputs must survive planning — fusion/splitting must never
    hide a value the caller is owed."""
    produced = set(ctx.ir.graph_inputs)
    for _si, _s, _nn, node in _plan_nodes(ctx):
        if node is not None:
            produced.update(node.outputs)
    return [Diagnostic(
        rule="output-missing", node=ctx.ir.values.get(o) and
        ctx.ir.values[o].producer or None,
        message=f"graph output {o!r} is never produced by the planned nodes",
        hint="a fusion or edit dropped the producing node's output")
        for o in ctx.ir.graph_outputs if o not in produced]


@verify_rule("dangling-value")
def _rule_dangling_value(ctx: VerifyContext) -> Iterable[Diagnostic]:
    """Every producer-less value that is consumed (or owed to the caller)
    must be a graph input.

    The Frontend registers mid-trace first sightings (closure-captured
    weights) as captured graph inputs; an IR where a consumed value has no
    producer *and* no graph-input registration is the pre-fix tracer bug —
    the executor would have no way to ever feed it."""
    out: list[Diagnostic] = []
    inputs = set(ctx.ir.graph_inputs)
    for vn, v in ctx.ir.values.items():
        if v.producer is not None or vn in inputs:
            continue
        if v.consumers or vn in ctx.ir.graph_outputs:
            out.append(Diagnostic(
                rule="dangling-value", node=vn,
                message=(f"value {vn!r} has no producer and is not a graph "
                         f"input, yet is "
                         + ("consumed by " + ", ".join(v.consumers)
                            if v.consumers else "a graph output")),
                hint="a traced operand was never registered as a (captured) "
                     "graph input — retrace, or add it to ir.graph_inputs"))
    return out


# --------------------------------------------------------------------------- #
# fused-node routing + shape consistency
# --------------------------------------------------------------------------- #
@verify_rule("fused-routing")
def _rule_fused_routing(ctx: VerifyContext) -> Iterable[Diagnostic]:
    """``fused_part_inputs/outputs`` must route every part consistently."""
    out: list[Diagnostic] = []
    for si, _s, nn, node in _plan_nodes(ctx):
        if node is None or not node.fused_from:
            continue
        stage = _stage_label(si)
        n_parts = len(node.fused_from)
        keys = node.fn_key.split("+")
        if len(keys) != n_parts:
            out.append(Diagnostic(
                rule="fused-routing", stage=stage, node=nn,
                message=(f"fn_key {node.fn_key!r} has {len(keys)} parts but "
                         f"fused_from lists {n_parts}")))
        # absent routing metadata is legal (pre-split fused nodes resolve
        # through the composed fallback); TRUNCATED metadata is corruption
        for field_name, lst in (("fused_part_inputs", node.fused_part_inputs),
                                ("fused_part_outputs",
                                 node.fused_part_outputs)):
            if lst and len(lst) != n_parts:
                out.append(Diagnostic(
                    rule="fused-routing", stage=stage, node=nn,
                    message=(f"{field_name} has {len(lst)} entries for "
                             f"{n_parts} fused parts"),
                    hint="routing metadata was truncated; the node cannot "
                         "be split or composed"))
        if (len(node.fused_part_inputs) != n_parts
                or len(node.fused_part_outputs) != n_parts):
            continue               # per-part checks need aligned lists
        internal: set[str] = set()
        for pi, (pins, pouts) in enumerate(zip(node.fused_part_inputs,
                                               node.fused_part_outputs)):
            for v in list(pins) + list(pouts):
                if v not in ctx.ir.values:
                    out.append(Diagnostic(
                        rule="fused-routing", stage=stage, node=nn,
                        message=(f"part {pi} routes unknown value {v!r}")))
            for v in pins:
                if v not in internal and v not in node.inputs:
                    out.append(Diagnostic(
                        rule="fused-routing", stage=stage, node=nn,
                        message=(f"part {pi} input {v!r} is neither an "
                                 f"external input nor produced by an "
                                 f"earlier part")))
            internal.update(pouts)
    return out


@verify_rule("shape-mismatch")
def _rule_shape_mismatch(ctx: VerifyContext) -> Iterable[Diagnostic]:
    """Shapes recorded at fusion time must match the IR's values — a drifted
    shape means the composed fallback would be called with wrong operands."""
    out: list[Diagnostic] = []
    for si, _s, nn, node in _plan_nodes(ctx):
        if node is None or not node.fused_input_shapes:
            continue
        if len(node.fused_input_shapes) != len(node.fused_part_inputs):
            continue               # fused-routing owns misaligned metadata
        for pi, (shapes, pins) in enumerate(zip(node.fused_input_shapes,
                                                node.fused_part_inputs)):
            if len(shapes) != len(pins):
                out.append(Diagnostic(
                    rule="shape-mismatch", stage=_stage_label(si), node=nn,
                    message=(f"part {pi} records {len(shapes)} input shapes "
                             f"for {len(pins)} inputs")))
                continue
            for shape, vn in zip(shapes, pins):
                v = ctx.ir.values.get(vn)
                if v is not None and tuple(shape) != tuple(v.shape):
                    out.append(Diagnostic(
                        rule="shape-mismatch", stage=_stage_label(si),
                        node=nn,
                        message=(f"part {pi} recorded shape {tuple(shape)} "
                                 f"for {vn!r} but the IR says "
                                 f"{tuple(v.shape)}"),
                        hint="the IR was edited after fusion; re-fuse"))
    return out


# --------------------------------------------------------------------------- #
# placement legality
# --------------------------------------------------------------------------- #
def _node_placement(s, idx: int, node: Node) -> Placement:
    if idx < len(s.placements):
        return Placement.parse(s.placements[idx])
    return Placement.parse(node.placement)


@verify_rule("hw-unresolvable")
def _rule_hw_unresolvable(ctx: VerifyContext) -> Iterable[Diagnostic]:
    """hw-placed nodes must resolve in the kernel database for their
    shapes/dtypes (applicability predicates included)."""
    if ctx.db is None:
        return []
    out: list[Diagnostic] = []
    for si, s, nn, node in _plan_nodes(ctx):
        if node is None:
            continue
        p = _node_placement(s, s.node_names.index(nn), node)
        if not p.is_hw:
            continue
        stage = _stage_label(si)
        if node.fused_from:
            # a fused hw node runs either a dedicated fused module or the
            # composed parts; legal when the joined key is accelerated OR
            # every part key is at least registered
            entry = ctx.db.lookup(node.fn_key)
            if entry is not None and entry.accelerated is not None:
                continue
            missing = [k for k in node.fn_key.split("+")
                       if ctx.db.lookup(k) is None]
            if missing:
                out.append(Diagnostic(
                    rule="hw-unresolvable", stage=stage, node=nn,
                    message=(f"fused node {nn} placed hw but parts "
                             f"{missing} are not in database "
                             f"{ctx.db.name!r}"),
                    hint="register the parts or place the node sw"))
            continue
        entry = ctx.db.lookup(node.fn_key)
        if entry is None:
            out.append(Diagnostic(
                rule="hw-unresolvable", stage=stage, node=nn,
                message=(f"{nn} placed hw but fn_key {node.fn_key!r} is not "
                         f"in database {ctx.db.name!r}")))
            continue
        shapes = [tuple(ctx.ir.values[i].shape) for i in node.inputs
                  if i in ctx.ir.values]
        if not entry.has_hw(*shapes):
            out.append(Diagnostic(
                rule="hw-unresolvable", stage=stage, node=nn,
                message=(f"{nn} placed hw but {node.fn_key!r} has no "
                         f"accelerated module applicable to shapes "
                         f"{shapes}"),
                hint="the applicability predicate rejects these shapes; "
                     "place the node sw"))
    return out


@verify_rule("replica-vector")
def _rule_replica_vector(ctx: VerifyContext) -> Iterable[Diagnostic]:
    """``replicas``/``devices``/``device_speeds`` must agree per stage."""
    out: list[Diagnostic] = []
    for si, s in enumerate(ctx.plan.stages):
        stage = _stage_label(si)
        if int(s.replicas) < 1:
            out.append(Diagnostic(
                rule="replica-vector", stage=stage,
                message=f"stage has replicas={s.replicas} (< 1)"))
        if s.devices and len(s.devices) != int(s.replicas):
            out.append(Diagnostic(
                rule="replica-vector", stage=stage,
                message=(f"{len(s.devices)} pinned devices for "
                         f"{s.replicas} replicas"),
                hint="assign_replicas/clear_stage_devices left stale "
                     "pinnings behind"))
        if s.device_speeds:
            if not s.devices:
                out.append(Diagnostic(
                    rule="replica-vector", stage=stage,
                    message="device_speeds set on an unpinned stage",
                    hint="clear_stage_devices must wipe speeds with devices"))
            elif len(s.device_speeds) != int(s.replicas):
                out.append(Diagnostic(
                    rule="replica-vector", stage=stage,
                    message=(f"{len(s.device_speeds)} device speeds for "
                             f"{s.replicas} replicas")))
            if any(not (sp > 0.0) for sp in s.device_speeds):
                out.append(Diagnostic(
                    rule="replica-vector", stage=stage,
                    message=f"non-positive device speed in "
                            f"{s.device_speeds}"))
    return out


@verify_rule("device-ordinal")
def _rule_device_ordinal(ctx: VerifyContext) -> Iterable[Diagnostic]:
    """Pinned ordinals must exist in the deployment's DeviceInventory."""
    if ctx.inventory is None:
        return []
    n = len(ctx.inventory)
    return [Diagnostic(
        rule="device-ordinal", stage=_stage_label(si),
        message=(f"device ordinal {d} out of range for a {n}-device "
                 f"inventory"),
        hint="the plan was placed against a different inventory")
        for si, s in enumerate(ctx.plan.stages)
        for d in s.devices if not (0 <= int(d) < n)]


@verify_rule("serial-only-widened")
def _rule_serial_only_widened(ctx: VerifyContext) -> Iterable[Diagnostic]:
    """A stage holding a ``serial_only`` node must keep exactly one worker."""
    out: list[Diagnostic] = []
    for si, s in enumerate(ctx.plan.stages):
        if int(s.replicas) <= 1:
            continue
        for nn in s.node_names:
            node = ctx.node(nn)
            if node is not None and node.serial_only:
                out.append(Diagnostic(
                    rule="serial-only-widened", stage=_stage_label(si),
                    node=nn,
                    message=(f"stage widened to {s.replicas} workers but "
                             f"{nn} is serial_only"),
                    hint="assign_replicas must pass the IR so markers are "
                         "enforced"))
    return out


@verify_rule("state-slot")
def _rule_state_slot(ctx: VerifyContext) -> Iterable[Diagnostic]:
    """Stateful (slot-bound) nodes mutate host-side state per call, so
    three plan shapes are illegal for them: replicated stages (two workers
    would race on the slot arena), hw placement (the state lives host-side
    by construction), and fusion (the composed replay runs under jit)."""
    out: list[Diagnostic] = []
    for si, s, nn, node in _plan_nodes(ctx):
        if node is None or not getattr(node, "state", None):
            continue
        stage = _stage_label(si)
        if int(s.replicas) > 1:
            out.append(Diagnostic(
                rule="state-slot", stage=stage, node=nn,
                message=(f"stateful node {nn} (state={node.state!r}) sits "
                         f"in a stage widened to {s.replicas} workers"),
                hint="stateful stages are serial_only; re-run "
                     "assign_replicas with the IR"))
        p = _node_placement(s, s.node_names.index(nn), node)
        if p.is_hw:
            out.append(Diagnostic(
                rule="state-slot", stage=stage, node=nn,
                message=(f"stateful node {nn} placed hw but its state "
                         f"{node.state!r} lives host-side"),
                hint="place the node sw; accelerate the stateless parts "
                     "around it instead"))
        if node.fused_from:
            out.append(Diagnostic(
                rule="state-slot", stage=stage, node=nn,
                message=(f"stateful node {nn} was fused "
                         f"({node.fn_key!r}) — the composed replay would "
                         f"jit the slot mutation away"),
                hint="fuse_adjacent_hw must refuse stateful nodes; "
                     "split_fused_node to recover"))
    return out


@verify_rule("phantom-xfer")
def _rule_phantom_xfer(ctx: VerifyContext) -> Iterable[Diagnostic]:
    """Transfer charges are only legal on genuinely multi-device plans —
    an unpinned/degraded plan paying ``xfer_in_ms`` skews every replan
    comparison against it."""
    distinct = {d for s in ctx.plan.stages for d in s.devices}
    if len(distinct) > 1:
        return []
    return [Diagnostic(
        rule="phantom-xfer", stage=_stage_label(si),
        message=(f"stage charges xfer_in_ms={s.xfer_in_ms:.3f} but the plan "
                 f"uses {len(distinct)} distinct device(s)"),
        hint="clear_stage_devices when deploying unpinned")
        for si, s in enumerate(ctx.plan.stages) if s.xfer_in_ms > 0.0]


# --------------------------------------------------------------------------- #
# fusion legality (VMEM) + sanity
# --------------------------------------------------------------------------- #
@verify_rule("vmem-spill")
def _rule_vmem_spill(ctx: VerifyContext) -> Iterable[Diagnostic]:
    """Re-check the VMEM working-set gate on the committed plan: a fused
    hw node whose row-block tile set spills VMEM must not ship, no matter
    what the fusion-time estimate said."""
    out: list[Diagnostic] = []
    for si, s, nn, node in _plan_nodes(ctx):
        if node is None or not node.fused_from:
            continue
        p = _node_placement(s, s.node_names.index(nn), node)
        if not p.is_hw:
            continue
        names = set(node.inputs) | set(node.outputs)
        for pins in node.fused_part_inputs:
            names.update(pins)
        for pouts in node.fused_part_outputs:
            names.update(pouts)
        names &= set(ctx.ir.values)        # missing values flagged elsewhere
        ws = working_set_bytes(ctx.ir, names)
        if ws > ctx.vmem_bytes:
            out.append(Diagnostic(
                rule="vmem-spill", stage=_stage_label(si), node=nn,
                message=(f"fused node working set {ws} B exceeds VMEM "
                         f"({ctx.vmem_bytes} B)"),
                hint="split the fusion (split_fused_node) or place it sw"))
    return out


@verify_rule("stage-time")
def _rule_stage_time(ctx: VerifyContext) -> Iterable[Diagnostic]:
    """Non-positive/non-finite stage times poison every planning decision
    downstream (warning: the executor itself would still run)."""
    return [Diagnostic(
        rule="stage-time", severity=WARNING, stage=_stage_label(si),
        message=f"stage est_time_ms={s.est_time_ms!r} is not a positive "
                f"finite number",
        hint="annotate times (CostModel.annotate / profiler) before "
             "partitioning")
        for si, s in enumerate(ctx.plan.stages)
        if not (isinstance(s.est_time_ms, (int, float))
                and math.isfinite(s.est_time_ms) and s.est_time_ms >= 0.0)]


# --------------------------------------------------------------------------- #
# entry points
# --------------------------------------------------------------------------- #
def verify_plan(ir: CourierIR, plan: PipelinePlan, *,
                db: ModuleDatabase | None = None,
                inventory: DeviceInventory | None = None,
                vmem_bytes: int = VMEM_BYTES) -> list[Diagnostic]:
    """Run every registered verify rule; return all findings (worst first)."""
    ctx = VerifyContext(ir=ir, plan=plan, db=db, inventory=inventory,
                        vmem_bytes=vmem_bytes)
    diags: list[Diagnostic] = []
    for fn in VERIFY_RULES.values():
        diags.extend(fn(ctx))
    diags.sort(key=lambda d: (d.severity != ERROR, d.rule))
    return diags


def check_plan(ir: CourierIR, plan: PipelinePlan, *,
               db: ModuleDatabase | None = None,
               inventory: DeviceInventory | None = None,
               vmem_bytes: int = VMEM_BYTES,
               where: str = "check_plan") -> list[Diagnostic]:
    """The gate: verify and raise on errors (unless ``REPRO_VERIFY=off``).

    Returns the full diagnostic list (warnings included) when the plan
    passes, so callers can surface non-fatal findings.
    """
    if not verify_enabled():
        return []
    diags = verify_plan(ir, plan, db=db, inventory=inventory,
                        vmem_bytes=vmem_bytes)
    errors = [d for d in diags if d.severity == ERROR]
    if errors:
        raise PlanVerificationError(where, errors)
    return diags
