"""Shared diagnostic vocabulary for the verifier and the linter.

Both halves of :mod:`repro.analysis` report findings as frozen
:class:`Diagnostic` records — a rule id, a severity, where it happened
(node/stage for plans, path/line for source), and a fix hint — so the CLI,
the gates, and the tests can all consume one format.
"""
from __future__ import annotations

import os
from dataclasses import dataclass, field
from typing import Optional, Sequence

# Severity levels, mildest first.  Only "error" diagnostics make
# ``check_plan`` raise; "warning" findings are reported but non-fatal.
Severity = str
WARNING: Severity = "warning"
ERROR: Severity = "error"

#: Environment knob: set ``REPRO_VERIFY=off`` (or 0/false/no) to disable the
#: plan-verification gates in generate()/replan/swap_executor.  The linter is
#: not affected — it only runs when invoked explicitly.
VERIFY_ENV = "REPRO_VERIFY"


def verify_enabled() -> bool:
    """True unless the ``REPRO_VERIFY`` escape hatch disables the gate."""
    return os.environ.get(VERIFY_ENV, "").strip().lower() not in (
        "off", "0", "false", "no")


@dataclass(frozen=True)
class Diagnostic:
    """One finding from a verify or lint rule."""

    rule: str                       # registered rule id, e.g. "produced-once"
    message: str                    # human-readable statement of the defect
    severity: Severity = ERROR
    node: Optional[str] = None      # IR node name (verify rules)
    stage: Optional[str] = None     # plan stage name (verify rules)
    path: Optional[str] = None      # source file (lint rules)
    line: Optional[int] = None      # 1-based source line (lint rules)
    hint: Optional[str] = None      # suggested fix

    def format(self) -> str:
        where = []
        if self.path:
            where.append(f"{self.path}:{self.line}" if self.line else self.path)
        if self.stage:
            where.append(f"stage={self.stage}")
        if self.node:
            where.append(f"node={self.node}")
        loc = " ".join(where)
        out = f"{self.severity}[{self.rule}]"
        if loc:
            out += f" {loc}"
        out += f": {self.message}"
        if self.hint:
            out += f"  (hint: {self.hint})"
        return out


class PlanVerificationError(ValueError):
    """A plan failed static verification at a gate.

    Carries the structured diagnostics so callers (the replanner, the
    hot-swap path, tests) can inspect rule ids instead of parsing text.
    """

    def __init__(self, where: str, diagnostics: Sequence[Diagnostic]):
        self.where = where
        self.diagnostics = list(diagnostics)
        lines = "\n  ".join(d.format() for d in self.diagnostics)
        super().__init__(
            f"plan verification failed at {where} "
            f"({len(self.diagnostics)} finding(s)):\n  {lines}")

    @property
    def rules(self) -> list:
        return sorted({d.rule for d in self.diagnostics})
