"""AST-based concurrency/style linter for the repro tree.

A registered-rule framework over Python source files.  Two rule kinds:

* **file rules** (:data:`FILE_RULES`) see one parsed module at a time —
  lock discipline, blocking calls inside critical sections, frozen
  dataclasses, acquire-without-finally, raw placement literals.
* **project rules** (:data:`PROJECT_RULES`) see every module at once —
  cross-module properties like dead exports.

Suppression conventions (all line comments on the flagged line):

* ``# lint: ignore[rule-id]`` — suppress one rule on one line.
* ``# owner: <thread>`` — declares the single thread that owns a field
  mutation, satisfying ``lock-discipline`` without a lock.
* ``# lint: allow-mutable(reason)`` — a plan/placement dataclass that is
  deliberately mutated in place (``frozen-dataclass``).
* ``# lint: allow-dead(reason)`` — a public def kept despite no external
  reference (``dead-export``).

Entry point: :func:`lint_paths`; CLI: ``python -m repro.analysis lint``.
"""
from __future__ import annotations

import ast
import os
from typing import Callable, Iterable, Optional, Sequence

from repro.core.placement import HW, SW

from .diagnostics import Diagnostic

__all__ = ["lint_paths", "lint_file", "FILE_RULES", "PROJECT_RULES",
           "LINT_RULES", "file_rule", "project_rule"]


class LintContext:
    """One parsed source file, with raw lines for pragma checks."""

    def __init__(self, path: str, source: str):
        self.path = path
        self.source = source
        self.lines = source.splitlines()
        self.tree = ast.parse(source, filename=path)

    def line(self, lineno: int) -> str:
        if 1 <= lineno <= len(self.lines):
            return self.lines[lineno - 1]
        return ""


FileRule = Callable[[LintContext], Iterable[Diagnostic]]
ProjectRule = Callable[[Sequence[LintContext], Sequence[LintContext]],
                       Iterable[Diagnostic]]

FILE_RULES: dict[str, FileRule] = {}
PROJECT_RULES: dict[str, ProjectRule] = {}


def file_rule(rule_id: str) -> Callable[[FileRule], FileRule]:
    def deco(fn: FileRule) -> FileRule:
        FILE_RULES[rule_id] = fn
        return fn
    return deco


def project_rule(rule_id: str) -> Callable[[ProjectRule], ProjectRule]:
    def deco(fn: ProjectRule) -> ProjectRule:
        PROJECT_RULES[rule_id] = fn
        return fn
    return deco


def _dotted(node: ast.AST) -> Optional[str]:
    """``self._lock`` / ``g.lock`` → dotted string; None for non-name chains."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _is_self_lockish(expr: ast.AST) -> Optional[str]:
    """``self.<attr>`` where attr smells like a lock/condition → attr."""
    if (isinstance(expr, ast.Attribute)
            and isinstance(expr.value, ast.Name) and expr.value.id == "self"
            and ("lock" in expr.attr.lower() or "cond" in expr.attr.lower())):
        return expr.attr
    return None


def _self_field_of_target(t: ast.AST) -> Optional[str]:
    """Root ``self.<field>`` of an assignment target (attribute chains and
    subscripts included: ``self.x``, ``self.x.y``, ``self.x[i]``)."""
    while isinstance(t, (ast.Subscript, ast.Attribute)):
        if (isinstance(t, ast.Attribute) and isinstance(t.value, ast.Name)
                and t.value.id == "self"):
            return t.attr
        t = t.value
    return None


def _docstring_constants(tree: ast.Module) -> set[int]:
    """Line numbers of docstring Constant nodes (exempt everywhere)."""
    out: set[int] = set()
    for node in ast.walk(tree):
        if isinstance(node, (ast.Module, ast.ClassDef, ast.FunctionDef,
                             ast.AsyncFunctionDef)):
            body = node.body
            if (body and isinstance(body[0], ast.Expr)
                    and isinstance(body[0].value, ast.Constant)
                    and isinstance(body[0].value.value, str)):
                out.add(id(body[0].value))
    return out


# --------------------------------------------------------------------------- #
# placement-literal — migrated from tests/test_placement.py
# --------------------------------------------------------------------------- #
@file_rule("placement-literal")
def _rule_placement_literal(ctx: LintContext) -> Iterable[Diagnostic]:
    """Raw placement-kind string literals outside the parser module.

    Every layer must go through :class:`repro.core.placement.Placement`
    (``.parse`` / ``.is_hw`` / the module constants) instead of comparing
    raw strings — placement.py is the single module allowed to spell them.
    """
    if ctx.path.replace(os.sep, "/").endswith("core/placement.py"):
        return []
    doc_ids = _docstring_constants(ctx.tree)
    out = []
    for node in ast.walk(ctx.tree):
        if (isinstance(node, ast.Constant) and node.value in (HW, SW)
                and id(node) not in doc_ids):
            out.append(Diagnostic(
                rule="placement-literal", path=ctx.path, line=node.lineno,
                message=f"raw placement literal {node.value!r}",
                hint="use repro.core.placement constants / Placement.parse"))
    return out


# --------------------------------------------------------------------------- #
# lock-discipline — guarded fields mutated only under their lock / owner
# --------------------------------------------------------------------------- #
class _ClassLockScan(ast.NodeVisitor):
    """Per-method record of self-field mutations and their lock context."""

    def __init__(self) -> None:
        # (field, method, lineno, lock_depth>0)
        self.mutations: list[tuple[str, str, int, bool]] = []
        self._method = ""
        self._lock_depth = 0

    def scan_method(self, fn: ast.AST, name: str) -> None:
        self._method = name
        self.visit(fn)

    def visit_With(self, node: ast.With) -> None:
        lockish = any(_is_self_lockish(item.context_expr)
                      for item in node.items)
        if lockish:
            self._lock_depth += 1
        self.generic_visit(node)
        if lockish:
            self._lock_depth -= 1

    def _record(self, target: ast.AST, lineno: int) -> None:
        field = _self_field_of_target(target)
        if field is not None:
            self.mutations.append((field, self._method, lineno,
                                   self._lock_depth > 0))

    def visit_Assign(self, node: ast.Assign) -> None:
        for t in node.targets:
            if isinstance(t, ast.Tuple):
                for el in t.elts:
                    self._record(el, node.lineno)
            else:
                self._record(t, node.lineno)
        self.generic_visit(node)

    def visit_AugAssign(self, node: ast.AugAssign) -> None:
        self._record(node.target, node.lineno)
        self.generic_visit(node)

    def visit_AnnAssign(self, node: ast.AnnAssign) -> None:
        if node.value is not None:
            self._record(node.target, node.lineno)
        self.generic_visit(node)


@file_rule("lock-discipline")
def _rule_lock_discipline(ctx: LintContext) -> Iterable[Diagnostic]:
    """A field ever mutated under ``with self.<lock>:`` is *guarded*: every
    other mutation of it must also hold the lock, or carry an ``# owner:``
    comment naming the single thread that owns it.  ``__init__`` (no
    concurrent readers yet) is exempt.
    """
    out = []
    for cls in ast.walk(ctx.tree):
        if not isinstance(cls, ast.ClassDef):
            continue
        scan = _ClassLockScan()
        for item in cls.body:
            if isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef)):
                scan.scan_method(item, item.name)
        guarded = {f for f, m, _ln, locked in scan.mutations
                   if locked and m != "__init__"}
        for field, method, lineno, locked in scan.mutations:
            if locked or method == "__init__" or field not in guarded:
                continue
            if "# owner:" in ctx.line(lineno):
                continue
            out.append(Diagnostic(
                rule="lock-discipline", path=ctx.path, line=lineno,
                message=(f"{cls.name}.{field} is lock-guarded elsewhere but "
                         f"mutated without the lock in {method}()"),
                hint="hold the lock, or annotate the owning thread with "
                     "'# owner: <thread>'"))
    return out


# --------------------------------------------------------------------------- #
# blocking-in-lock — no unbounded blocking inside critical sections
# --------------------------------------------------------------------------- #
_BLOCKING_NAMES = ("device_put", "block_until_ready", "sleep")


class _BlockingScan(ast.NodeVisitor):
    def __init__(self, ctx: LintContext) -> None:
        self.ctx = ctx
        self.out: list[Diagnostic] = []
        self._held: list[str] = []       # dotted lock exprs currently held

    def visit_With(self, node: ast.With) -> None:
        held = [_dotted(item.context_expr) for item in node.items
                if _is_self_lockish(item.context_expr)]
        self._held.extend(h for h in held if h)
        self.generic_visit(node)
        for _ in held:
            if self._held:
                self._held.pop()

    def _flag(self, node: ast.Call, what: str, hint: str) -> None:
        self.out.append(Diagnostic(
            rule="blocking-in-lock", path=self.ctx.path, line=node.lineno,
            message=f"{what} inside a critical section "
                    f"(holding {self._held[-1]})",
            hint=hint))

    def visit_Call(self, node: ast.Call) -> None:
        if self._held:
            fn = node.func
            name = fn.attr if isinstance(fn, ast.Attribute) else (
                fn.id if isinstance(fn, ast.Name) else "")
            has_timeout = any(kw.arg == "timeout" for kw in node.keywords)
            if name == "result" and not node.args and not has_timeout:
                self._flag(node, "unbounded .result()",
                           "resolve futures outside the lock or pass a "
                           "timeout")
            elif name == "get" and not node.args and not has_timeout:
                self._flag(node, "queue.get() with no timeout",
                           "use get(timeout=...) or move it out of the lock")
            elif name in ("wait", "join") and not node.args \
                    and not has_timeout:
                # waiting on the HELD condition releases it — that is the
                # condition-variable idiom, not a deadlock
                recv = _dotted(fn.value) if isinstance(fn, ast.Attribute) \
                    else None
                if recv not in self._held:
                    self._flag(node, f"unbounded .{name}()",
                               "only the held condition may be waited on "
                               "inside its own lock")
            elif name in _BLOCKING_NAMES:
                self._flag(node, f"{name}() (device/host sync)",
                           "stage data and sync outside the lock")
        self.generic_visit(node)


@file_rule("blocking-in-lock")
def _rule_blocking_in_lock(ctx: LintContext) -> Iterable[Diagnostic]:
    """No unbounded blocking calls while holding a ``self.<lock>`` — a
    blocked critical section stalls every thread contending for the lock
    (the executor's rings and counters are all behind one mutex)."""
    scan = _BlockingScan(ctx)
    scan.visit(ctx.tree)
    return scan.out


# --------------------------------------------------------------------------- #
# frozen-dataclass — plan/placement dataclasses must be immutable
# --------------------------------------------------------------------------- #
_FROZEN_SCOPE = ("core/placement.py", "core/partition.py", "analysis/")


def _dataclass_frozen(dec: ast.AST) -> Optional[bool]:
    """None if not a dataclass decorator, else its frozen-ness."""
    if isinstance(dec, ast.Name) and dec.id == "dataclass":
        return False
    if isinstance(dec, ast.Call) and isinstance(dec.func, ast.Name) \
            and dec.func.id == "dataclass":
        for kw in dec.keywords:
            if kw.arg == "frozen" and isinstance(kw.value, ast.Constant):
                return bool(kw.value.value)
        return False
    return None


@file_rule("frozen-dataclass")
def _rule_frozen_dataclass(ctx: LintContext) -> Iterable[Diagnostic]:
    """Plan/placement/diagnostic dataclasses are shared across threads (the
    replanner hands them to the serving thread); they must be frozen unless
    explicitly annotated ``# lint: allow-mutable(reason)``."""
    norm = ctx.path.replace(os.sep, "/")
    if not any(s in norm for s in _FROZEN_SCOPE):
        return []
    out = []
    for cls in ast.walk(ctx.tree):
        if not isinstance(cls, ast.ClassDef):
            continue
        frozen = [f for f in map(_dataclass_frozen, cls.decorator_list)
                  if f is not None]
        if not frozen or frozen[0]:
            continue
        if "# lint: allow-mutable" in ctx.line(cls.lineno):
            continue
        out.append(Diagnostic(
            rule="frozen-dataclass", path=ctx.path, line=cls.lineno,
            message=f"dataclass {cls.name} in a plan/placement module is "
                    f"not frozen",
            hint="use @dataclass(frozen=True) or annotate "
                 "'# lint: allow-mutable(reason)'"))
    return out


# --------------------------------------------------------------------------- #
# acquire-without-finally — manual lock acquire must release in a finally
# --------------------------------------------------------------------------- #
@file_rule("acquire-without-finally")
def _rule_acquire_without_finally(ctx: LintContext) -> Iterable[Diagnostic]:
    """Every manual ``X.acquire()`` needs an ``X.release()`` in a
    ``finally`` of the same function — the pattern whose absence turned
    the executor's close/submit race into a silent hang instead of an
    exception."""
    out = []
    for fn in ast.walk(ctx.tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        released: set[str] = set()
        for t in ast.walk(fn):
            if isinstance(t, ast.Try):
                for stmt in t.finalbody:
                    for call in ast.walk(stmt):
                        if (isinstance(call, ast.Call)
                                and isinstance(call.func, ast.Attribute)
                                and call.func.attr == "release"):
                            recv = _dotted(call.func.value)
                            if recv:
                                released.add(recv)
        for call in ast.walk(fn):
            if (isinstance(call, ast.Call)
                    and isinstance(call.func, ast.Attribute)
                    and call.func.attr == "acquire"):
                recv = _dotted(call.func.value)
                if recv and recv not in released:
                    out.append(Diagnostic(
                        rule="acquire-without-finally", path=ctx.path,
                        line=call.lineno,
                        message=(f"{recv}.acquire() has no matching "
                                 f"{recv}.release() in a finally block of "
                                 f"{fn.name}()"),
                        hint="use 'with' or try/finally so an exception "
                             "cannot leak the lock"))
    return out


# --------------------------------------------------------------------------- #
# swallowed-exception — broad handlers must re-raise or record the error
# --------------------------------------------------------------------------- #
@file_rule("swallowed-exception")
def _rule_swallowed_exception(ctx: LintContext) -> Iterable[Diagnostic]:
    """``except Exception`` / bare ``except`` that neither re-raises nor
    *uses* the caught error silently converts a failure into wrong state —
    the fault-tolerance layer depends on every error landing somewhere (a
    group, a stats counter, a log).  A handler passes when its body
    contains a ``raise``, or when it binds the exception (``as e``) and
    references the name.  Deliberate best-effort probes annotate the
    ``except`` line with ``# lint: allow-swallow(reason)``."""
    broad = ("Exception", "BaseException")
    out = []
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.ExceptHandler):
            continue
        t = node.type
        if t is not None and not (isinstance(t, ast.Name)
                                  and t.id in broad):
            continue
        if "# lint: allow-swallow" in ctx.line(node.lineno):
            continue
        names = set()
        raises = False
        for sub in node.body:
            for n in ast.walk(sub):
                if isinstance(n, ast.Raise):
                    raises = True
                elif isinstance(n, ast.Name):
                    names.add(n.id)
        if raises or (node.name is not None and node.name in names):
            continue
        what = "bare except" if t is None \
            else f"except {t.id}"      # type: ignore[union-attr]
        out.append(Diagnostic(
            rule="swallowed-exception", path=ctx.path, line=node.lineno,
            message=f"{what}: handler neither re-raises nor records the "
                    "error",
            hint="re-raise, bind 'as e' and record it on a group/stats "
                 "object, or annotate '# lint: allow-swallow(reason)'"))
    return out


# --------------------------------------------------------------------------- #
# state-slot-leak — KV slot alloc without a free path in the same function
# --------------------------------------------------------------------------- #
@file_rule("state-slot-leak")
def _rule_state_slot_leak(ctx: LintContext) -> Iterable[Diagnostic]:
    """A ``pool.alloc()`` call in a function with no ``.free`` reference and
    no ``DecodeSession`` guard leaks a KV slot on any early exit — the pool
    is a fixed arena, so a leaked slot is capacity lost until process death.
    Functions that deliberately transfer slot ownership to a caller annotate
    the line with ``# lint: ignore[state-slot-leak]``.  The kvstate module
    itself (which defines the alloc/free pair) is exempt."""
    if ctx.path.replace(os.sep, "/").endswith("runtime/kvstate.py"):
        return []
    out = []
    for fn in ast.walk(ctx.tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        frees = False
        sessions = False
        allocs: list[ast.Call] = []
        for n in ast.walk(fn):
            if isinstance(n, ast.Attribute) and n.attr == "free":
                frees = True
            elif isinstance(n, ast.Name) and n.id == "DecodeSession":
                sessions = True
            elif (isinstance(n, ast.Call)
                    and isinstance(n.func, ast.Attribute)
                    and n.func.attr == "alloc"):
                allocs.append(n)
        if frees or sessions:
            continue
        for call in allocs:
            out.append(Diagnostic(
                rule="state-slot-leak", path=ctx.path, line=call.lineno,
                message=(f".alloc() in {fn.name}() with no .free path or "
                         f"DecodeSession guard in the same function"),
                hint="wrap the slot in DecodeSession, free it in a "
                     "finally, or annotate "
                     "'# lint: ignore[state-slot-leak]' if ownership "
                     "transfers to the caller"))
    return out


# --------------------------------------------------------------------------- #
# dead-export — public module-level defs nobody imports
# --------------------------------------------------------------------------- #
@project_rule("dead-export")
def _rule_dead_export(targets: Sequence[LintContext],
                      refs: Sequence[LintContext]) -> Iterable[Diagnostic]:
    """A public module-level def that nothing *uses* drifts silently (the
    ``spmd_pipeline`` failure mode).  Use = a Name/Attribute reference or an
    ``from x import name`` anywhere across src/tests/benchmarks/examples —
    in the def's own module, only references *outside the def itself* count
    (a def is not kept alive by its own body or recursion alone, but a
    helper its module genuinely calls is).  Re-exports from ``__init__.py``
    files do not count as use — a name whose only mention is the package
    facade is exactly the drift this rule exists to catch.  Annotate
    deliberate keeps with ``# lint: allow-dead(reason)``."""
    def used_names(tree: ast.AST, skip: ast.AST | None = None) -> set[str]:
        skip_ids = {id(n) for n in ast.walk(skip)} if skip is not None \
            else set()
        names: set[str] = set()
        for node in ast.walk(tree):
            if id(node) in skip_ids:
                continue
            if isinstance(node, ast.Name):
                names.add(node.id)
            elif isinstance(node, ast.Attribute):
                names.add(node.attr)
            elif isinstance(node, ast.ImportFrom):
                names.update(a.name for a in node.names)
        return names

    by_file: dict[str, set[str]] = {}
    for ctx in refs:
        if os.path.basename(ctx.path) == "__init__.py":
            continue                       # re-exporting is not using
        by_file[os.path.abspath(ctx.path)] = used_names(ctx.tree)

    out = []
    for ctx in targets:
        base = os.path.basename(ctx.path)
        if base in ("__init__.py", "__main__.py"):
            continue
        me = os.path.abspath(ctx.path)
        other: set[str] = set()
        for path, names in by_file.items():
            if path != me:
                other |= names
        for node in ctx.tree.body:
            if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                     ast.ClassDef)):
                continue
            if node.name.startswith("_"):
                continue
            if "# lint: allow-dead" in ctx.line(node.lineno):
                continue
            own = used_names(ctx.tree, skip=node)
            if node.name in other or node.name in own:
                continue
            out.append(Diagnostic(
                rule="dead-export", path=ctx.path, line=node.lineno,
                message=(f"public def {node.name!r} is never referenced "
                         f"outside its own definition"),
                hint="wire it into a test, make it private, or mark "
                     "'# lint: allow-dead(reason)'"))
    return out


#: merged view for the CLI / docs
LINT_RULES: dict[str, object] = {**FILE_RULES, **PROJECT_RULES}


# --------------------------------------------------------------------------- #
# entry points
# --------------------------------------------------------------------------- #
def _py_files(path: str) -> list[str]:
    if os.path.isfile(path):
        return [path] if path.endswith(".py") else []
    out = []
    for root, _dirs, files in os.walk(path):
        if "__pycache__" in root:
            continue
        out.extend(os.path.join(root, f) for f in sorted(files)
                   if f.endswith(".py"))
    return sorted(out)


def _load(paths: Iterable[str]) -> list[LintContext]:
    ctxs = []
    for f in paths:
        with open(f, encoding="utf-8") as fh:
            ctxs.append(LintContext(f, fh.read()))
    return ctxs


def _suppressed(ctx_by_path: dict, d: Diagnostic) -> bool:
    ctx = ctx_by_path.get(d.path)
    if ctx is None or d.line is None:
        return False
    return f"# lint: ignore[{d.rule}]" in ctx.line(d.line)


def lint_file(ctx: LintContext) -> list[Diagnostic]:
    """Run every file rule over one parsed module."""
    out: list[Diagnostic] = []
    for fn in FILE_RULES.values():
        out.extend(fn(ctx))
    return [d for d in out if not _suppressed({ctx.path: ctx}, d)]


def lint_paths(paths: Sequence[str], *,
               ref_roots: Sequence[str] | None = None) -> list[Diagnostic]:
    """Lint every ``.py`` under ``paths``; returns all findings.

    ``ref_roots`` are the directories scanned for *references* by project
    rules (dead-export).  By default they are derived from the first
    target path: the sibling ``src``/``tests``/``benchmarks``/``examples``
    directories of the enclosing repo, so ``lint_paths(["src/repro"])``
    counts a use in ``tests/`` or ``benchmarks/``.
    """
    files = [f for p in paths for f in _py_files(p)]
    targets = _load(files)
    if ref_roots is None:
        root = os.path.abspath(files[0] if files else ".")
        while root != os.path.dirname(root):
            if os.path.isdir(os.path.join(root, "src")):
                break
            root = os.path.dirname(root)
        ref_roots = [os.path.join(root, d)
                     for d in ("src", "tests", "benchmarks", "examples")
                     if os.path.isdir(os.path.join(root, d))]
    ref_files = {os.path.abspath(f)
                 for r in ref_roots for f in _py_files(r)}
    ref_files.update(os.path.abspath(f) for f in files)
    refs = _load(sorted(ref_files))

    out: list[Diagnostic] = []
    for ctx in targets:
        for fn in FILE_RULES.values():
            out.extend(fn(ctx))
    for fn in PROJECT_RULES.values():
        out.extend(fn(targets, refs))

    by_path = {c.path: c for c in targets}
    by_abs = {os.path.abspath(c.path): c for c in targets}
    out = [d for d in out
           if not _suppressed(by_path, d) and not _suppressed(by_abs, d)]
    out.sort(key=lambda d: (d.path or "", d.line or 0, d.rule))
    return out
