"""Checkpointing — atomic, manifest-verified, async-capable, keep-last-k.

Layout:  <root>/step_<n>/  arrays.npz + manifest.json  (+ .tmp staging dir,
renamed atomically so a crash mid-save never corrupts the latest step).
Restore validates every leaf's shape/dtype against the manifest before any
device_put, and can re-shard onto a target mesh (restore-time resharding =
elastic restart onto a different topology).
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Any

import jax
import numpy as np

Params = Any


class CheckpointStore:
    def __init__(self, root: str, keep: int = 3):
        self.root = root
        self.keep = keep
        os.makedirs(root, exist_ok=True)
        self._async_thread: threading.Thread | None = None

    # -- paths ----------------------------------------------------------------- #
    def _dir(self, step: int) -> str:
        return os.path.join(self.root, f"step_{step:08d}")

    def steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.root):
            if d.startswith("step_") and not d.endswith(".tmp"):
                try:
                    out.append(int(d.split("_")[1]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    # -- save -------------------------------------------------------------------- #
    def save(self, step: int, tree: Params, extra: dict | None = None) -> str:
        leaves, treedef = jax.tree.flatten(tree)
        raw = [np.asarray(x) for x in leaves]
        # npz can't store ml_dtypes (bfloat16, fp8); persist as byte views
        arrays = {f"leaf_{i}": _to_native(a) for i, a in enumerate(raw)}
        manifest = {
            "step": step,
            "treedef": str(treedef),
            "n_leaves": len(leaves),
            "leaves": [{"shape": list(a.shape), "dtype": str(a.dtype),
                        "sum": _digest(a)} for a in raw],
            "extra": extra or {},
        }
        tmp = self._dir(step) + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        final = self._dir(step)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)                      # atomic publish
        self._gc()
        return final

    def save_async(self, step: int, tree: Params,
                   extra: dict | None = None) -> None:
        """Stage host copies now, write in the background (training continues)."""
        host_tree = jax.tree.map(np.asarray, tree)
        self.wait()
        self._async_thread = threading.Thread(
            target=self.save, args=(step, host_tree, extra), daemon=True)
        self._async_thread.start()

    def wait(self) -> None:
        if self._async_thread is not None:
            self._async_thread.join()
            self._async_thread = None

    # -- restore ------------------------------------------------------------------ #
    def restore(self, step: int | None, like: Params,
                shardings: Params | None = None) -> tuple[Params, dict]:
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.root}")
        d = self._dir(step)
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(d, "arrays.npz"))
        leaves_like, treedef = jax.tree.flatten(like)
        if manifest["n_leaves"] != len(leaves_like):
            raise ValueError(
                f"checkpoint has {manifest['n_leaves']} leaves, expected "
                f"{len(leaves_like)} — incompatible tree")
        out = []
        shard_leaves = (jax.tree.flatten(shardings)[0]
                        if shardings is not None else [None] * len(leaves_like))
        for i, (ref, meta) in enumerate(zip(leaves_like, manifest["leaves"])):
            a = _from_native(data[f"leaf_{i}"], meta["dtype"], meta["shape"])
            if list(a.shape) != list(meta["shape"]) or str(a.dtype) != meta["dtype"]:
                raise ValueError(f"leaf {i}: manifest/array mismatch")
            if _digest(a) != meta["sum"]:
                raise ValueError(f"leaf {i}: checksum mismatch (corrupt file)")
            if tuple(a.shape) != tuple(ref.shape):
                raise ValueError(
                    f"leaf {i}: shape {a.shape} != expected {ref.shape}")
            a = a.astype(ref.dtype)
            out.append(jax.device_put(a, shard_leaves[i])
                       if shard_leaves[i] is not None else jax.device_put(a))
        return jax.tree.unflatten(treedef, out), manifest["extra"]

    # -- retention ------------------------------------------------------------------ #
    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(self._dir(s), ignore_errors=True)


def _digest(a: np.ndarray) -> str:
    return hashlib.sha1(np.ascontiguousarray(a).tobytes()).hexdigest()[:16]


def _to_native(a: np.ndarray) -> np.ndarray:
    """ml_dtypes (bf16/fp8) → byte view that npz can store."""
    if a.dtype.kind == "V" or str(a.dtype) not in np.sctypeDict:
        return np.ascontiguousarray(a).view(np.uint8)
    return a


def _from_native(a: np.ndarray, dtype: str, shape: list) -> np.ndarray:
    if str(a.dtype) == dtype:
        return a
    import ml_dtypes  # ships with jax
    dt = np.dtype(getattr(ml_dtypes, dtype, dtype))
    return a.view(dt).reshape(shape)
