"""Module database — the paper's predefined hardware-module database.

Courier-FPGA's Backend "searches corresponding predefined hardware modules
from a database by functions name" (paper Sect. III).  A hit means the
function is off-loaded to the FPGA module; a miss means the original
software function keeps running on the CPU.

TPU mapping: an *accelerated* implementation is a hand-tiled Pallas TPU
kernel (the analog of a predefined HLS module); the *software* fallback is
the pure-jnp implementation compiled by stock XLA.  Entries are keyed by
function name, exactly like the paper (``hls::Sobel`` for ``cv::Sobel``),
with an optional applicability predicate standing in for "the HLS library
supports this data layout".
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable

from .costmodel import NodeCost
from .placement import HW, SW


@dataclass
class ModuleEntry:
    """One database row: a library function and its implementations."""

    name: str
    software: Callable                       # pure-jnp fallback ("runs on CPU")
    accelerated: Callable | None = None      # Pallas-backed ("runs on FPGA")
    applicable: Callable[..., bool] | None = None   # shapes/dtypes predicate
    cost_hw: Callable[..., NodeCost] | None = None  # synthesis-report analog
    cost_sw: Callable[..., NodeCost] | None = None
    tags: tuple[str, ...] = ()
    # name of the mutable per-request state this function touches (e.g. a
    # KV-cache slot pool), or None for pure functions.  Threaded onto the
    # traced Node as ``Node.state``; stateful entries never resolve to hw.
    state: str | None = None

    def has_hw(self, *shape_args: Any) -> bool:
        if self.accelerated is None:
            return False
        if self.applicable is not None and shape_args:
            try:
                return bool(self.applicable(*shape_args))
            except TypeError:
                return True
        return True


class ModuleDatabase:
    """Name → ModuleEntry registry with decorator-based registration."""

    def __init__(self, name: str = "default"):
        self.name = name
        self.entries: dict[str, ModuleEntry] = {}

    # -- registration -------------------------------------------------------- #
    def register(self, name: str, software: Callable,
                 accelerated: Callable | None = None,
                 applicable: Callable[..., bool] | None = None,
                 cost_hw: Callable[..., NodeCost] | None = None,
                 cost_sw: Callable[..., NodeCost] | None = None,
                 tags: tuple[str, ...] = (),
                 state: str | None = None) -> ModuleEntry:
        if state is not None and accelerated is not None:
            raise ValueError(
                f"{name!r}: a stateful module cannot carry an accelerated "
                "impl — the slot state lives host-side")
        e = ModuleEntry(name=name, software=software, accelerated=accelerated,
                        applicable=applicable, cost_hw=cost_hw, cost_sw=cost_sw,
                        tags=tags, state=state)
        self.entries[name] = e
        return e

    def library(self, name: str, **kwargs):
        """Decorator: register the decorated fn as the *software* impl."""
        def deco(fn: Callable) -> Callable:
            self.register(name, software=fn, **kwargs)
            return fn
        return deco

    def add_accelerated(self, name: str, fn: Callable,
                        applicable: Callable[..., bool] | None = None) -> None:
        if name not in self.entries:
            raise KeyError(f"register software impl for {name!r} first")
        self.entries[name].accelerated = fn
        if applicable is not None:
            self.entries[name].applicable = applicable

    @staticmethod
    def fused_key(parts: "tuple[str, ...] | list[str]") -> str:
        """The database key a fused run of ``parts`` resolves under."""
        return "+".join(parts)

    def register_fused(self, parts: "tuple[str, ...] | list[str]",
                       accelerated: Callable,
                       applicable: Callable[..., bool] | None = None,
                       cost_hw: Callable[..., NodeCost] | None = None,
                       tags: tuple[str, ...] = ()) -> ModuleEntry:
        """Register a dedicated fused hw module for a run of functions.

        The entry lives under the joined key (``"a+b+c"``) — the same key
        :func:`repro.core.partition.fuse_adjacent_hw` gives a fused node —
        so the pipeline backend resolves the *single-pass mega-kernel*
        instead of composing the parts' individual kernels.  The software
        fallback composes the parts' registered software impls, keeping the
        Off-load Switcher's "original behavior always available" guarantee.
        Every part must already be registered.
        """
        keys = list(parts)
        if len(keys) < 2:
            raise ValueError("a fused module needs >= 2 parts")
        missing = [k for k in keys if k not in self.entries]
        if missing:
            raise KeyError(f"register software impls first for {missing!r}")
        part_sw = [self.entries[k].software for k in keys]

        def _arity(fn: Callable) -> int:
            """Required positional inputs of a part's software impl."""
            import inspect
            try:
                sig = inspect.signature(fn)
            except (TypeError, ValueError):
                return 1
            n = 0
            for p in sig.parameters.values():
                if (p.kind in (p.POSITIONAL_ONLY, p.POSITIONAL_OR_KEYWORD)
                        and p.default is p.empty):
                    n += 1
            return max(n, 1)

        arities = [_arity(f) for f in part_sw]

        def composed_software(*args: Any, **kwargs: Any):
            # args follow the fused node's calling convention: part 0's
            # inputs first, then each later part's *side operands* in part
            # order (its first input is the carried previous output) — so a
            # fused rmsnorm+matmul fallback routes (x, scale, w) correctly.
            queue = list(args)
            take = arities[0]
            out = part_sw[0](*queue[:take])
            queue = queue[take:]
            for f, ar in zip(part_sw[1:], arities[1:]):
                carry = list(out) if isinstance(out, (tuple, list)) else [out]
                extra = max(ar - len(carry), 0)
                out = f(*carry, *queue[:extra])
                queue = queue[extra:]
            return out

        e = ModuleEntry(name=self.fused_key(keys), software=composed_software,
                        accelerated=accelerated, applicable=applicable,
                        cost_hw=cost_hw, tags=tags + ("fused",))
        self.entries[e.name] = e
        return e

    # -- lookup (paper: "searches ... by functions name") --------------------- #
    def lookup(self, name: str) -> ModuleEntry | None:
        return self.entries.get(name)

    def __contains__(self, name: str) -> bool:
        return name in self.entries

    def resolve(self, name: str, *shape_args: Any,
                prefer_hw: bool = True) -> tuple[Callable, str]:
        """Return (callable, placement) for a function name.

        Placement is "hw" when an applicable accelerated module exists and
        ``prefer_hw`` (the default, as in the paper), else "sw".  Unknown
        names raise — the tracer only records registered library functions,
        mirroring the paper's library-interposition Frontend.
        """
        e = self.lookup(name)
        if e is None:
            raise KeyError(f"{name!r} not in module database {self.name!r}")
        if prefer_hw and e.has_hw(*shape_args):
            return e.accelerated, HW
        return e.software, SW

    def names(self) -> list[str]:
        return sorted(self.entries)


# A process-wide default database, like the toolchain's single module DB.
default_db = ModuleDatabase("courier-default")
