"""Structured placement — backend kind + device + replica, end to end.

Courier-FPGA's core move is putting every pipeline stage on the execution
resource it fits best: predefined hardware modules on the FPGA fabric,
software filters on CPU cores.  The seed reproduction encoded that choice
as a bare ``"hw"/"sw"`` string on each IR node, which was enough to pick an
implementation but said nothing about *where* the chosen implementation
runs — and PR 4's stage replication could therefore only widen a stage
across host threads.  This module replaces the string with a structured
:class:`Placement` (backend kind + device ordinal / mesh coordinate +
replica index) and adds the :class:`DeviceInventory` the planner consumes
to map stage replicas onto *real* devices (N replicas of a stage pinned to
N chips/cores), the way portable accelerator pipelines describe placement
as a first-class object rather than a two-valued tag.

THIS MODULE IS THE ONLY PLACE the literal kind strings may appear — the
back-compat parser (:meth:`Placement.parse`) accepts the legacy strings and
everything else goes through the :data:`HW`/:data:`SW` constants and the
:func:`is_hw`/:func:`is_sw`/:func:`placement_kind` helpers.  A grep-guard
test (AST-based, so docstrings are exempt but code is not) enforces it.
"""
from __future__ import annotations

import os
from dataclasses import dataclass, field, replace
from typing import Any, Iterator, Sequence

# --------------------------------------------------------------------------- #
# Backend kinds — the ONLY allowed spelling of the legacy strings
# --------------------------------------------------------------------------- #
HW = "hw"                    # accelerated module (Pallas kernel / FPGA module)
SW = "sw"                    # software fallback (plain XLA / CPU function)
UNASSIGNED = "unassigned"    # backend not yet chosen (pre-database lookup)

_KINDS = (HW, SW, UNASSIGNED)

# Reserved-core headroom knob for the budget governor (cores the widening
# pass must leave free for the OS / serving threads / the admission loop).
RESERVED_CORES_ENV = "REPRO_RESERVED_CORES"
DEFAULT_RESERVED_CORES = 1


# --------------------------------------------------------------------------- #
# Placement
# --------------------------------------------------------------------------- #
@dataclass(frozen=True)
class Placement:
    """Where one IR node (or one stage replica) executes.

    ``kind``
        Backend kind: :data:`HW` (accelerated module), :data:`SW`
        (software fallback), or :data:`UNASSIGNED`.
    ``device``
        Device ordinal into the active :class:`DeviceInventory`
        (``None`` = unpinned: the process-default device).
    ``mesh_coord``
        Optional mesh coordinate of the device (``launch/mesh.py`` /
        TPU ``coords``) for pod-topology-aware callers.
    ``replica``
        Replica index when the owning stage is widened (0 for serial
        stages) — which of the N parallel workers this placement names.
    """

    kind: str = UNASSIGNED
    device: int | None = None
    mesh_coord: tuple[int, ...] | None = None
    replica: int = 0

    def __post_init__(self) -> None:
        if self.kind not in _KINDS:
            raise ValueError(f"unknown placement kind {self.kind!r}; "
                             f"expected one of {_KINDS}")
        if self.mesh_coord is not None:
            object.__setattr__(self, "mesh_coord",
                               tuple(int(c) for c in self.mesh_coord))

    # -- predicates --------------------------------------------------------- #
    @property
    def is_hw(self) -> bool:
        return self.kind == HW

    @property
    def is_sw(self) -> bool:
        return self.kind == SW

    @property
    def is_assigned(self) -> bool:
        return self.kind != UNASSIGNED

    # -- constructors ------------------------------------------------------- #
    @classmethod
    def hw(cls, device: int | None = None, replica: int = 0,
           mesh_coord: tuple[int, ...] | None = None) -> "Placement":
        return cls(kind=HW, device=device, replica=replica,
                   mesh_coord=mesh_coord)

    @classmethod
    def sw(cls, device: int | None = None, replica: int = 0,
           mesh_coord: tuple[int, ...] | None = None) -> "Placement":
        return cls(kind=SW, device=device, replica=replica,
                   mesh_coord=mesh_coord)

    @classmethod
    def unassigned(cls) -> "Placement":
        return cls()

    @classmethod
    def parse(cls, value: Any) -> "Placement":
        """THE back-compat parser: legacy strings / dicts → Placement.

        Accepts a :class:`Placement` (returned as-is), the legacy
        ``"hw"``/``"sw"``/``"unassigned"`` strings (seed IR, user
        ``edit_ir`` hooks that pin placements by string), a dict (JSON
        deserialization of a structured placement), or ``None``
        (unassigned).  Every other layer calls this instead of comparing
        raw strings.
        """
        if isinstance(value, cls):
            return value
        if value is None:
            return cls()
        if isinstance(value, str):
            return cls(kind=value)          # __post_init__ validates
        if isinstance(value, dict):
            d = dict(value)
            if d.get("mesh_coord") is not None:
                d["mesh_coord"] = tuple(d["mesh_coord"])
            return cls(**d)
        raise TypeError(f"cannot parse a Placement from {type(value).__name__}")

    # -- derivation --------------------------------------------------------- #
    def with_kind(self, kind: str) -> "Placement":
        """Same device/replica pinning, new backend kind (assign_placements
        must not wipe a device assignment when it re-resolves the kind)."""
        return replace(self, kind=kind)

    def on(self, device: int | None, replica: int = 0,
           mesh_coord: tuple[int, ...] | None = None) -> "Placement":
        """Same kind, pinned to ``device`` as replica ``replica``."""
        return replace(self, device=device, replica=replica,
                       mesh_coord=mesh_coord)

    @property
    def key(self) -> tuple:
        """Hashable identity used in StageFn / executor cache keys."""
        return (self.kind, self.device, self.replica)

    # -- rendering ---------------------------------------------------------- #
    def short(self) -> str:
        """Compact label for the IR pretty-printer: ``hw``, ``hw@2``,
        ``hw@2.1`` (device 2, replica 1)."""
        s = self.kind
        if self.device is not None:
            s += f"@{self.device}"
            if self.replica:
                s += f".{self.replica}"
        return s

    def __str__(self) -> str:               # pragma: no cover - trivial
        return self.short()

    def __repr__(self) -> str:
        return f"Placement({self.short()!r})"


# -- helpers that tolerate legacy values ------------------------------------ #
def placement_kind(value: Any) -> str:
    """Backend kind of a placement-like value (string or Placement)."""
    return Placement.parse(value).kind


def is_hw(value: Any) -> bool:
    """True when a placement-like value names the accelerated backend.

    ``None`` (and anything unassigned) is not hw — callers use this as the
    single predicate instead of ``== "hw"`` string comparisons.
    """
    return value is not None and Placement.parse(value).is_hw


def is_sw(value: Any) -> bool:
    return value is not None and Placement.parse(value).is_sw


# --------------------------------------------------------------------------- #
# Device inventory — what the planner places replicas onto
# --------------------------------------------------------------------------- #
@dataclass(frozen=True)
class InventoryDiff:
    """Structured result of :meth:`DeviceInventory.refresh`.

    ``old``/``new`` are the inventories before/after the probe; ``lost``
    and ``gained`` name ordinals in the respective inventory's numbering;
    ``survivors`` maps each surviving OLD ordinal to its NEW ordinal (the
    re-densified numbering after a loss), which is how profiler stats
    keyed by old ordinals follow their device across a re-plan.
    """

    old: "DeviceInventory"
    new: "DeviceInventory"
    lost: tuple[int, ...] = ()         # old ordinals no longer present
    gained: tuple[int, ...] = ()       # new ordinals with no old identity
    survivors: dict = field(default_factory=dict)   # old ordinal -> new

    @property
    def changed(self) -> bool:
        return bool(self.lost or self.gained)

    def describe(self) -> str:
        return (f"InventoryDiff({len(self.old)} -> {len(self.new)} devices; "
                f"lost {list(self.lost)}, gained {list(self.gained)})")


@dataclass(frozen=True)
class DeviceSpec:
    """One placeable device: ordinal + platform + optional topology."""

    ordinal: int                       # index into the inventory
    platform: str = "cpu"              # "tpu" | "gpu" | "cpu"
    device_id: int | None = None       # backend device id (jax.Device.id)
    coord: tuple[int, ...] | None = None   # mesh/pod coordinate when known
    speed: float = 1.0                 # relative throughput vs class baseline

    def __post_init__(self) -> None:
        if self.coord is not None:
            object.__setattr__(self, "coord",
                               tuple(int(c) for c in self.coord))
        if self.speed <= 0.0:
            raise ValueError(f"device speed must be > 0 (got {self.speed})")


class DeviceInventory:
    """The placeable devices the planner maps stage replicas onto.

    Built from ``jax.devices()`` (:meth:`detect`), a production mesh
    (:meth:`from_mesh`), or synthetically (:meth:`host`, for planner unit
    tests that need a 4-device inventory without forcing host devices).
    The inventory is what :func:`repro.core.partition.assign_replicas`
    consumes instead of an abstract worker budget: replica ``w`` of a
    widened stage is pinned to a concrete ordinal here, and the executor
    ``jax.device_put``\\ s that replica's groups onto the mapped
    ``jax.Device``.
    """

    def __init__(self, specs: Sequence[DeviceSpec],
                 jax_devices: Sequence[Any] | None = None):
        if not specs:
            raise ValueError("a DeviceInventory needs at least one device")
        self.specs: tuple[DeviceSpec, ...] = tuple(specs)
        for i, s in enumerate(self.specs):
            if s.ordinal != i:
                raise ValueError(f"spec #{i} carries ordinal {s.ordinal}; "
                                 "ordinals must be dense and ordered")
        if jax_devices is not None and len(jax_devices) != len(self.specs):
            raise ValueError(f"{len(jax_devices)} jax devices for "
                             f"{len(self.specs)} specs")
        self._jax = tuple(jax_devices) if jax_devices is not None else None

    # -- constructors ------------------------------------------------------- #
    @classmethod
    def detect(cls, limit: int | None = None) -> "DeviceInventory":
        """Inventory over ``jax.devices()`` (optionally the first ``limit``)."""
        import jax

        devs = list(jax.devices())
        if limit is not None:
            if limit < 1:
                raise ValueError(f"limit must be >= 1 (got {limit})")
            devs = devs[:limit]
        specs = [DeviceSpec(ordinal=i, platform=str(d.platform),
                            device_id=int(getattr(d, "id", i)),
                            coord=tuple(getattr(d, "coords", None) or ())
                            or None)
                 for i, d in enumerate(devs)]
        return cls(specs, jax_devices=devs)

    @classmethod
    def from_mesh(cls, mesh: Any) -> "DeviceInventory":
        """Inventory over a mesh's devices, coords = mesh coordinates."""
        import numpy as np

        arr = np.asarray(mesh.devices)
        specs, devs = [], []
        for i, idx in enumerate(np.ndindex(arr.shape)):
            d = arr[idx]
            specs.append(DeviceSpec(ordinal=i, platform=str(d.platform),
                                    device_id=int(getattr(d, "id", i)),
                                    coord=tuple(int(c) for c in idx)))
            devs.append(d)
        return cls(specs, jax_devices=devs)

    @classmethod
    def host(cls, n: int, platform: str = "cpu") -> "DeviceInventory":
        """Synthetic n-device inventory (planner tests / dry planning).

        Carries no ``jax.Device`` objects, so executors treat every
        ordinal as the default device (planning-only inventory).  Each
        spec gets a synthetic stable ``device_id`` so :meth:`refresh` can
        match survivors across a :meth:`drop` re-densification.
        """
        return cls([DeviceSpec(ordinal=i, platform=platform, device_id=i)
                    for i in range(n)])

    # -- queries ------------------------------------------------------------ #
    def __len__(self) -> int:
        return len(self.specs)

    def __iter__(self) -> Iterator[DeviceSpec]:
        return iter(self.specs)

    def _check(self, ordinal: int) -> int:
        # explicit range check: Python's negative indexing would silently
        # alias ordinal -1 to the last device while stats/profiles report
        # the bogus ordinal, so reject anything outside [0, len)
        if not 0 <= ordinal < len(self.specs):
            raise IndexError(f"device ordinal {ordinal} out of range for a "
                             f"{len(self.specs)}-device inventory")
        return ordinal

    def spec(self, ordinal: int) -> DeviceSpec:
        return self.specs[self._check(ordinal)]

    def jax_device(self, ordinal: int) -> Any | None:
        """The mapped ``jax.Device`` (None for planning-only inventories)."""
        self._check(ordinal)
        if self._jax is None:
            return None
        return self._jax[ordinal]

    def device_class(self, ordinal: int):
        """Roofline constants for the device's platform class."""
        from .costmodel import device_class
        return device_class(self.spec(ordinal).platform)

    @property
    def homogeneous(self) -> bool:
        return len({(s.platform, s.speed) for s in self.specs}) <= 1

    def worker_budget(self, n_stages: int = 1,
                      reserved_cores: int | None = None) -> int:
        """Budget governor over this inventory (see
        :func:`default_worker_budget`): never below one worker per stage
        or one worker per device — a 4-chip inventory must be widenable
        to 4 replicas even on a small host, because the workers there
        only *drive* devices (they block in ``device_put`` / execute,
        they don't compute).
        """
        return max(default_worker_budget(n_stages, reserved_cores),
                   len(self.specs))

    def describe(self) -> str:
        rows = [f"DeviceInventory({len(self.specs)} devices)"]
        for s in self.specs:
            c = f" coord={s.coord}" if s.coord else ""
            rows.append(f"  #{s.ordinal} {s.platform}"
                        f"(id={s.device_id}){c} x{s.speed:g}")
        return "\n".join(rows)

    # -- elastic inventory --------------------------------------------------- #
    def _identity(self, ordinal: int) -> tuple:
        # device identity across probes: the backend id when one exists
        # (real inventories), the ordinal itself for planning-only
        # inventories (host(n) has no ids — position IS identity there)
        s = self.specs[ordinal]
        return (s.platform, s.device_id if s.device_id is not None
                else ("ordinal", ordinal))

    def refresh(self, probe: Any = None) -> InventoryDiff:
        """Re-detect the device set and diff it against this inventory.

        ``probe`` is a zero-arg callable returning the NEW
        :class:`DeviceInventory` (default: :meth:`detect` — the real
        re-probe; tests and fault benchmarks pass
        ``FaultInjector.surviving``).  Devices are matched by identity
        ``(platform, device_id)``, so a loss that re-densifies the
        ordinals still maps every survivor old→new in the returned
        :class:`InventoryDiff`.
        """
        new = probe() if probe is not None else DeviceInventory.detect()
        old_ids = {self._identity(i): i for i in range(len(self.specs))}
        new_ids = {new._identity(j): j for j in range(len(new.specs))}
        survivors = {old_ids[k]: new_ids[k] for k in old_ids if k in new_ids}
        lost = tuple(sorted(i for k, i in old_ids.items() if k not in new_ids))
        gained = tuple(sorted(j for k, j in new_ids.items()
                              if k not in old_ids))
        return InventoryDiff(old=self, new=new, lost=lost, gained=gained,
                             survivors=survivors)

    def drop(self, ordinals: Any) -> "DeviceInventory":
        """Survivors-only inventory: this one minus ``ordinals``,
        re-densified (survivor k becomes ordinal ``rank(k)``) with
        platform/id/coord/speed and any mapped ``jax.Device`` preserved.
        """
        gone = {self._check(int(o)) for o in ordinals}
        keep = [i for i in range(len(self.specs)) if i not in gone]
        if not keep:
            raise ValueError("cannot drop every device in the inventory")
        specs = [replace(self.specs[i], ordinal=j)
                 for j, i in enumerate(keep)]
        devs = [self._jax[i] for i in keep] if self._jax is not None else None
        return DeviceInventory(specs, jax_devices=devs)

    def reweighted(self, factors: dict) -> "DeviceInventory":
        """Copy with per-ordinal speed multipliers applied (clamped
        positive) — how the replanner de-weights an unhealthy device so
        ``assign_replicas`` widens onto its healthy peers instead."""
        specs = [replace(s, speed=max(s.speed
                                      * float(factors.get(s.ordinal, 1.0)),
                                      1e-6))
                 for s in self.specs]
        return DeviceInventory(specs, jax_devices=self._jax)


# --------------------------------------------------------------------------- #
# Budget governor — widen only when spare cores exist
# --------------------------------------------------------------------------- #
def default_worker_budget(n_stages: int = 1,
                          reserved_cores: int | None = None) -> int:
    """Host-derived default worker budget for the widening pass.

    ``os.cpu_count()`` minus a reserved-core headroom knob
    (``REPRO_RESERVED_CORES`` env var, default 1 — cores kept free for the
    OS, the admission loop, and serving threads), floored at one worker
    per stage (the hard minimum :func:`~repro.core.partition.
    assign_replicas` enforces).  On a saturated host this collapses to the
    floor, so the planner widens nothing — exactly the governor the
    ROADMAP asks for.  An explicit ``worker_budget=`` everywhere remains
    the override.
    """
    if n_stages < 1:
        raise ValueError(f"n_stages must be >= 1 (got {n_stages})")
    if reserved_cores is None:
        reserved_cores = int(os.environ.get(RESERVED_CORES_ENV,
                                            DEFAULT_RESERVED_CORES))
    if reserved_cores < 0:
        raise ValueError(f"reserved_cores must be >= 0 (got {reserved_cores})")
    cores = os.cpu_count() or 1
    return max(n_stages, cores - reserved_cores)


AUTO_BUDGET = "auto"      # sentinel: derive the budget from the governor


def resolve_worker_budget(worker_budget: Any, n_stages: int,
                          inventory: "DeviceInventory | None" = None,
                          ) -> int | None:
    """Normalize a worker-budget argument.

    * an int — the explicit override, returned as-is;
    * :data:`AUTO_BUDGET` — the governor (inventory-aware when one is
      given);
    * ``None`` — the governor when an inventory is present (a caller who
      handed the planner real devices wants them used), else ``None``
      (no widening, the legacy meaning).
    """
    if worker_budget is None:
        if inventory is None:
            return None
        return inventory.worker_budget(n_stages)
    if worker_budget == AUTO_BUDGET:
        if inventory is not None:
            return inventory.worker_budget(n_stages)
        return default_worker_budget(n_stages)
    return int(worker_budget)
