"""Frontend — runtime trace of an unmodified program (paper Sect. II-A).

Courier-FPGA's Frontend needs no source access: it interposes on the shared
library (dlsym/RTLD_NEXT) while the binary runs, gathers runtime information
(Step 2) and recovers the *causal* function-call graph including input/output
data (Step 3) by matching each call's inputs against earlier calls' outputs.

JAX mapping: the "shared library" is the set of functions registered in the
ModuleDatabase, exposed through a :class:`Library` namespace.  The call sites
in user code never change; what a call *binds to* is decided by a dynamically
scoped execution context — exactly the LD_PRELOAD/dlsym trick:

* default        → software implementation (the original binary's behavior)
* ``Frontend.trace`` → software implementation + recording (Steps 1-3)
* ``deploy(plan)``   → the Off-loader's resolved implementation (Step 9)

Causality is discovered with the paper's heuristic: an input array whose
``id()`` matches a previously produced output is an edge; anything else is a
graph input.
"""
from __future__ import annotations

import inspect
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from .database import ModuleDatabase, ModuleEntry, default_db
from .ir import CourierIR, Node

__all__ = ["Library", "Frontend", "deploy", "current_mode",
           "TraceBindingError"]


# --------------------------------------------------------------------------- #
# Dynamically scoped dispatch (the dlsym/RTLD_NEXT analog)
# --------------------------------------------------------------------------- #
class _DispatchState(threading.local):
    def __init__(self):
        self.stack: list[Any] = []


_state = _DispatchState()


def _current() -> "Any | None":
    return _state.stack[-1] if _state.stack else None


def current_mode() -> str:  # lint: allow-dead(introspection API for user edit_ir hooks)
    ctx = _current()
    return getattr(ctx, "mode", "direct")


class Library:
    """Interposable namespace over a ModuleDatabase.

    ``lib.cvtColor(x)`` behaves like the plain software function until a
    trace/deploy context is active — user code is never edited (paper:
    "without user intervention, source code tweaks or re-compilations").
    """

    def __init__(self, db: ModuleDatabase | None = None):
        object.__setattr__(self, "_db", db or default_db)

    @property
    def db(self) -> ModuleDatabase:
        return self._db

    def __getattr__(self, name: str) -> Callable:
        entry = self._db.lookup(name)
        if entry is None:
            raise AttributeError(f"{name!r} is not a registered library function")

        def call(*args: Any, **kwargs: Any):
            ctx = _current()
            if ctx is None:
                return entry.software(*args, **kwargs)
            return ctx.call(entry, *args, **kwargs)

        call.__name__ = name
        return call


def _is_array(x: Any) -> bool:
    return isinstance(x, (jax.Array, np.ndarray))


# --------------------------------------------------------------------------- #
# Trace context (Frontend Steps 1-3)
# --------------------------------------------------------------------------- #
@dataclass
class _TraceRecord:
    fn_key: str
    in_ids: list[int]
    out_ids: list[int]
    in_meta: list[tuple[tuple[int, ...], str]]
    out_meta: list[tuple[tuple[int, ...], str]]
    in_kw: list[str | None]                # keyword per input (None = positional)
    in_arrays: list[Any]                   # the operands themselves (staging)
    params: dict[str, Any]
    time_ms: float
    t_start: float
    t_end: float


def _positional_param_names(fn: Callable) -> list[str | None] | None:
    """Names of fn's positional parameters, in order, for replay rebinding.

    ``None`` entries mark POSITIONAL_ONLY params (cannot be rebound by
    keyword); a ``None`` return means the signature is unavailable (C
    builtins) and nothing can be rebound at all.  The list stops at
    ``*args`` — positions beyond it are unnameable.
    """
    try:
        sig = inspect.signature(fn)
    except (TypeError, ValueError):
        return None
    names: list[str | None] = []
    for p in sig.parameters.values():
        if p.kind == p.POSITIONAL_OR_KEYWORD:
            names.append(p.name)
        elif p.kind == p.POSITIONAL_ONLY:
            names.append(None)
        else:
            break
    return names


class TraceBindingError(TypeError):
    """A call shape the tracer cannot replay through stage functions."""


class _TraceContext:
    mode = "trace"

    def __init__(self, profile: bool = True):
        self.records: list[_TraceRecord] = []
        self.keep_alive: list[Any] = []        # prevent id() reuse during trace
        self.profile = profile
        self.t0 = time.perf_counter()

    def call(self, entry: ModuleEntry, *args: Any, **kwargs: Any):
        # Record every array operand together with HOW it was bound, so the
        # stage fns can replay the exact call.  Positional arrays stay
        # positional (in original relative order); keyword arrays keep their
        # keyword; non-array positionals fold into params by parameter name —
        # and once one does, every later positional must be rebound by name
        # too (the positional prefix seen at replay is shorter than at trace).
        arr_in: list[Any] = []
        in_kw: list[str | None] = []
        params: dict[str, Any] = {}
        pos_names = _positional_param_names(entry.software)

        def name_of(i: int) -> str:
            if pos_names is None or i >= len(pos_names) or pos_names[i] is None:
                raise TraceBindingError(
                    f"{entry.name!r}: positional argument {i} cannot be "
                    f"rebound by keyword for replay (no inspectable name); "
                    f"pass it by keyword or simplify the call")
            return pos_names[i]

        shifted = False
        for i, a in enumerate(args):
            if _is_array(a):
                if shifted:
                    in_kw.append(name_of(i))
                else:
                    in_kw.append(None)
                arr_in.append(a)
            else:
                params[name_of(i)] = a
                shifted = True
        for k, v in kwargs.items():
            if _is_array(v):
                arr_in.append(v)
                in_kw.append(k)
            else:
                params[k] = v
        t_start = time.perf_counter() - self.t0
        t = time.perf_counter()
        out = entry.software(*args, **kwargs)
        if self.profile:
            out = jax.block_until_ready(out)
        dt = (time.perf_counter() - t) * 1e3
        t_end = time.perf_counter() - self.t0
        outs = out if isinstance(out, (tuple, list)) else (out,)
        arr_out = [o for o in outs if _is_array(o)]
        self.keep_alive.extend(arr_in + arr_out)
        self.records.append(_TraceRecord(
            fn_key=entry.name,
            in_ids=[id(a) for a in arr_in],
            out_ids=[id(a) for a in arr_out],
            in_meta=[(tuple(a.shape), str(a.dtype)) for a in arr_in],
            out_meta=[(tuple(a.shape), str(a.dtype)) for a in arr_out],
            in_kw=in_kw, in_arrays=list(arr_in),
            params=params,
            time_ms=dt, t_start=t_start, t_end=t_end))
        return out


class Frontend:
    """Builds a CourierIR from one observed run of an unmodified callable."""

    def __init__(self, db: ModuleDatabase | None = None):
        self.db = db or default_db

    def trace(self, fn: Callable, *args: Any, profile: bool = True,
              name: str | None = None, **kwargs: Any) -> tuple[CourierIR, Any]:
        ctx = _TraceContext(profile=profile)
        _state.stack.append(ctx)
        try:
            out = fn(*args, **kwargs)
        finally:
            _state.stack.pop()
        ir = self._build_ir(ctx, args, kwargs, out,
                            name or getattr(fn, "__name__", "trace"))
        return ir, out

    # -- Step 3: causal graph reconstruction --------------------------------- #
    def _build_ir(self, ctx: _TraceContext, args: Any, kwargs: Any, out: Any,
                  name: str) -> CourierIR:
        ir = CourierIR(name)
        id2val: dict[int, str] = {}
        counter = [0]

        def fresh(meta: tuple, producer: str | None) -> str:
            vname = f"d{counter[0]}"
            counter[0] += 1
            ir.add_value(vname, meta[0], meta[1], producer=producer)
            return vname

        def val_for(aid: int, meta: tuple, producer: str | None) -> str:
            if aid in id2val:
                return id2val[aid]
            vname = fresh(meta, producer)
            id2val[aid] = vname
            return vname

        # graph inputs first (paper: data nodes of the running binary) —
        # every array leaf of the call, positional AND keyword
        flat_args = [a for a in jax.tree.leaves((args, kwargs)) if _is_array(a)]
        for a in flat_args:
            vn = val_for(id(a), (tuple(a.shape), str(a.dtype)), None)
            if vn not in ir.graph_inputs:
                ir.graph_inputs.append(vn)

        per_key: dict[str, int] = {}
        for r in ctx.records:
            idx = per_key.get(r.fn_key, 0)
            per_key[r.fn_key] = idx + 1
            nname = f"{r.fn_key}_{idx}"
            ins: list[str] = []
            for aid, m, arr in zip(r.in_ids, r.in_meta, r.in_arrays):
                first_seen = aid not in id2val
                vn = val_for(aid, m, None)
                if first_seen:
                    # first sighting mid-trace: a closure-captured operand
                    # (model weight/constant), not a top-level argument.  The
                    # executor must still be able to feed it, so it becomes a
                    # graph input whose array is retained for staging.
                    ir.graph_inputs.append(vn)
                    ir.captured[vn] = arr
                ins.append(vn)
            outs: list[str] = []
            for o, m in zip(r.out_ids, r.out_meta):
                if o in id2val:
                    # aliasing: the fn returned an operand unchanged.  Reusing
                    # the value would make this node both consumer and
                    # producer of one id (and stomp the original producer) —
                    # mint a fresh value (an identity edge) and repoint later
                    # consumers of this array at the alias.
                    vn = fresh(m, nname)
                    id2val[o] = vn
                    outs.append(vn)
                else:
                    outs.append(val_for(o, m, nname))
            entry = self.db.lookup(r.fn_key)
            state = entry.state if entry is not None else None
            ir.add_node(Node(name=nname, fn_key=r.fn_key, inputs=ins,
                             outputs=outs, input_kw=list(r.in_kw),
                             params=r.params,
                             time_ms=r.time_ms if ctx.profile else None,
                             t_start=r.t_start, t_end=r.t_end,
                             # stateful calls pin one worker: slot writes
                             # must be observed in token order
                             state=state, serial_only=bool(state)))

        flat_out = [a for a in jax.tree.leaves(out) if _is_array(a)]
        for a in flat_out:
            aid = id(a)
            if aid not in id2val:
                # returned array no library call ever saw (constant, or a
                # passthrough of something outside the traced args): register
                # it as a captured graph input instead of silently emitting a
                # truncated graph_outputs list
                vn = val_for(aid, (tuple(a.shape), str(a.dtype)), None)
                ir.graph_inputs.append(vn)
                ir.captured[vn] = a
            ir.graph_outputs.append(id2val[aid])
        ir.validate()
        return ir


# --------------------------------------------------------------------------- #
# Deploy context (Off-loader Step 9) — see offloader.py for plan construction
# --------------------------------------------------------------------------- #
class _DeployContext:
    mode = "deploy"

    def __init__(self, resolve: Callable[[ModuleEntry], Callable]):
        self._resolve = resolve

    def call(self, entry: ModuleEntry, *args: Any, **kwargs: Any):
        return self._resolve(entry)(*args, **kwargs)


class deploy:
    """``with deploy(plan):`` — run the same user code with calls rebound.

    ``plan`` must provide ``resolve(entry) -> callable`` (see
    :class:`repro.core.offloader.OffloadPlan`).
    """

    def __init__(self, plan: Any):
        self.plan = plan

    def __enter__(self):
        _state.stack.append(_DeployContext(self.plan.resolve))
        return self.plan

    def __exit__(self, *exc: Any):
        _state.stack.pop()
        return False
