"""Frontend — runtime trace of an unmodified program (paper Sect. II-A).

Courier-FPGA's Frontend needs no source access: it interposes on the shared
library (dlsym/RTLD_NEXT) while the binary runs, gathers runtime information
(Step 2) and recovers the *causal* function-call graph including input/output
data (Step 3) by matching each call's inputs against earlier calls' outputs.

JAX mapping: the "shared library" is the set of functions registered in the
ModuleDatabase, exposed through a :class:`Library` namespace.  The call sites
in user code never change; what a call *binds to* is decided by a dynamically
scoped execution context — exactly the LD_PRELOAD/dlsym trick:

* default        → software implementation (the original binary's behavior)
* ``Frontend.trace`` → software implementation + recording (Steps 1-3)
* ``deploy(plan)``   → the Off-loader's resolved implementation (Step 9)

Causality is discovered with the paper's heuristic: an input array whose
``id()`` matches a previously produced output is an edge; anything else is a
graph input.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from .database import ModuleDatabase, ModuleEntry, default_db
from .ir import CourierIR, Node

__all__ = ["Library", "Frontend", "deploy", "current_mode"]


# --------------------------------------------------------------------------- #
# Dynamically scoped dispatch (the dlsym/RTLD_NEXT analog)
# --------------------------------------------------------------------------- #
class _DispatchState(threading.local):
    def __init__(self):
        self.stack: list[Any] = []


_state = _DispatchState()


def _current() -> "Any | None":
    return _state.stack[-1] if _state.stack else None


def current_mode() -> str:  # lint: allow-dead(introspection API for user edit_ir hooks)
    ctx = _current()
    return getattr(ctx, "mode", "direct")


class Library:
    """Interposable namespace over a ModuleDatabase.

    ``lib.cvtColor(x)`` behaves like the plain software function until a
    trace/deploy context is active — user code is never edited (paper:
    "without user intervention, source code tweaks or re-compilations").
    """

    def __init__(self, db: ModuleDatabase | None = None):
        object.__setattr__(self, "_db", db or default_db)

    @property
    def db(self) -> ModuleDatabase:
        return self._db

    def __getattr__(self, name: str) -> Callable:
        entry = self._db.lookup(name)
        if entry is None:
            raise AttributeError(f"{name!r} is not a registered library function")

        def call(*args: Any, **kwargs: Any):
            ctx = _current()
            if ctx is None:
                return entry.software(*args, **kwargs)
            return ctx.call(entry, *args, **kwargs)

        call.__name__ = name
        return call


def _is_array(x: Any) -> bool:
    return isinstance(x, (jax.Array, np.ndarray))


# --------------------------------------------------------------------------- #
# Trace context (Frontend Steps 1-3)
# --------------------------------------------------------------------------- #
@dataclass
class _TraceRecord:
    fn_key: str
    in_ids: list[int]
    out_ids: list[int]
    in_meta: list[tuple[tuple[int, ...], str]]
    out_meta: list[tuple[tuple[int, ...], str]]
    params: dict[str, Any]
    time_ms: float
    t_start: float
    t_end: float


class _TraceContext:
    mode = "trace"

    def __init__(self, profile: bool = True):
        self.records: list[_TraceRecord] = []
        self.keep_alive: list[Any] = []        # prevent id() reuse during trace
        self.profile = profile
        self.t0 = time.perf_counter()

    def call(self, entry: ModuleEntry, *args: Any, **kwargs: Any):
        arr_in = [a for a in args if _is_array(a)]
        params = {k: v for k, v in kwargs.items() if not _is_array(v)}
        arr_in += [v for v in kwargs.values() if _is_array(v)]
        t_start = time.perf_counter() - self.t0
        t = time.perf_counter()
        out = entry.software(*args, **kwargs)
        if self.profile:
            out = jax.block_until_ready(out)
        dt = (time.perf_counter() - t) * 1e3
        t_end = time.perf_counter() - self.t0
        outs = out if isinstance(out, (tuple, list)) else (out,)
        arr_out = [o for o in outs if _is_array(o)]
        self.keep_alive.extend(arr_in + arr_out)
        self.records.append(_TraceRecord(
            fn_key=entry.name,
            in_ids=[id(a) for a in arr_in],
            out_ids=[id(a) for a in arr_out],
            in_meta=[(tuple(a.shape), str(a.dtype)) for a in arr_in],
            out_meta=[(tuple(a.shape), str(a.dtype)) for a in arr_out],
            params=params,
            time_ms=dt, t_start=t_start, t_end=t_end))
        return out


class Frontend:
    """Builds a CourierIR from one observed run of an unmodified callable."""

    def __init__(self, db: ModuleDatabase | None = None):
        self.db = db or default_db

    def trace(self, fn: Callable, *args: Any, profile: bool = True,
              name: str | None = None, **kwargs: Any) -> tuple[CourierIR, Any]:
        ctx = _TraceContext(profile=profile)
        _state.stack.append(ctx)
        try:
            out = fn(*args, **kwargs)
        finally:
            _state.stack.pop()
        ir = self._build_ir(ctx, args, out, name or getattr(fn, "__name__", "trace"))
        return ir, out

    # -- Step 3: causal graph reconstruction --------------------------------- #
    def _build_ir(self, ctx: _TraceContext, args: Any, out: Any,
                  name: str) -> CourierIR:
        ir = CourierIR(name)
        id2val: dict[int, str] = {}
        counter = [0]

        def val_for(aid: int, meta: tuple, producer: str | None) -> str:
            if aid in id2val:
                return id2val[aid]
            vname = f"d{counter[0]}"
            counter[0] += 1
            ir.add_value(vname, meta[0], meta[1], producer=producer)
            id2val[aid] = vname
            return vname

        # graph inputs first (paper: data nodes of the running binary)
        flat_args = [a for a in jax.tree.leaves(args) if _is_array(a)]
        for a in flat_args:
            vn = val_for(id(a), (tuple(a.shape), str(a.dtype)), None)
            if vn not in ir.graph_inputs:
                ir.graph_inputs.append(vn)

        per_key: dict[str, int] = {}
        for r in ctx.records:
            idx = per_key.get(r.fn_key, 0)
            per_key[r.fn_key] = idx + 1
            nname = f"{r.fn_key}_{idx}"
            ins = [val_for(i, m, None) for i, m in zip(r.in_ids, r.in_meta)]
            outs = [val_for(o, m, nname) for o, m in zip(r.out_ids, r.out_meta)]
            ir.add_node(Node(name=nname, fn_key=r.fn_key, inputs=ins,
                             outputs=outs, params=r.params,
                             time_ms=r.time_ms if ctx.profile else None,
                             t_start=r.t_start, t_end=r.t_end))

        flat_out = [a for a in jax.tree.leaves(out) if _is_array(a)]
        for a in flat_out:
            if id(a) in id2val:
                ir.graph_outputs.append(id2val[id(a)])
        ir.validate()
        return ir


# --------------------------------------------------------------------------- #
# Deploy context (Off-loader Step 9) — see offloader.py for plan construction
# --------------------------------------------------------------------------- #
class _DeployContext:
    mode = "deploy"

    def __init__(self, resolve: Callable[[ModuleEntry], Callable]):
        self._resolve = resolve

    def call(self, entry: ModuleEntry, *args: Any, **kwargs: Any):
        return self._resolve(entry)(*args, **kwargs)


class deploy:
    """``with deploy(plan):`` — run the same user code with calls rebound.

    ``plan`` must provide ``resolve(entry) -> callable`` (see
    :class:`repro.core.offloader.OffloadPlan`).
    """

    def __init__(self, plan: Any):
        self.plan = plan

    def __enter__(self):
        _state.stack.append(_DeployContext(self.plan.resolve))
        return self.plan

    def __exit__(self, *exc: Any):
        _state.stack.pop()
        return False
