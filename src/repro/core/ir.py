"""Courier IR — the coarse-grained dataflow representation (paper Sect. II-B).

The IR mirrors what Courier-FPGA's Frontend extracts from a running binary
(paper Steps 1-5): an *ordered* function-call graph whose nodes are
library-level functions ("not a single x86 assembly code ... but a process
with a certain amount of computation") and whose edges carry the observed
input/output data metadata (shape, dtype == the paper's "bit-depth", byte
size) plus a profile log (processing time, absolute start/end times).

Nodes are kept in chronological (traced) order, exactly like the paper's
Fig. 4 graph; the Pipeline Generator partitions this order into contiguous
stages.  Users may inspect and edit the IR (paper Steps 6-7) before the
Backend builds the pipeline.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field, asdict
from typing import Any, Callable, Iterable, Sequence

import numpy as np

from .placement import Placement


# --------------------------------------------------------------------------- #
# Values (edges)
# --------------------------------------------------------------------------- #
@dataclass
class Value:
    """An edge in the call graph: one observed array in/out of a function.

    ``shape``/``dtype`` correspond to the paper's ``height x width x
    bit-depth x channels`` node annotation; ``nbytes`` is what the Pipeline
    Generator uses for port sizing / communication-cost estimates.
    """

    name: str
    shape: tuple[int, ...]
    dtype: str
    producer: str | None = None          # node name that wrote it (None = graph input)
    consumers: list[str] = field(default_factory=list)

    @property
    def nbytes(self) -> int:
        return int(np.prod(self.shape, dtype=np.int64)) * np.dtype(self.dtype).itemsize if self.shape else np.dtype(self.dtype).itemsize

    @property
    def bit_depth(self) -> int:
        """Paper's AXI port-width input: bits per element."""
        return np.dtype(self.dtype).itemsize * 8


# --------------------------------------------------------------------------- #
# Nodes (function calls)
# --------------------------------------------------------------------------- #
@dataclass
class Node:
    """One traced library-function call.

    ``fn_key`` is the database lookup key (paper: the function *name* used to
    search the hardware-module database).  ``time_ms`` is the profiled
    processing time from the Frontend; ``placement`` is filled by the Backend
    after database lookup — a structured :class:`~repro.core.placement.
    Placement` (backend kind + device ordinal + replica index).  Legacy
    string placements ("hw"/"sw") are parsed on construction and on
    attribute assignment-free paths via :meth:`Placement.parse`, so seed
    IRs and user ``edit_ir`` hooks that pin placements by string keep
    working.
    """

    name: str                              # unique instance name, e.g. "cvtColor_0"
    fn_key: str                            # database key, e.g. "cvtColor"
    inputs: list[str] = field(default_factory=list)    # Value names
    outputs: list[str] = field(default_factory=list)   # Value names
    # keyword binding per input: parallel to ``inputs``; None = positional,
    # a string = the keyword the array was passed under at trace time.  Stage
    # replay must honor it — a library fn whose software impl takes arrays by
    # keyword (e.g. ``def f(x, *, w)``) misbinds if w is appended positionally.
    # Empty list (the default, and what pre-existing serialized IRs decode to)
    # means all-positional.
    input_kw: list[str | None] = field(default_factory=list)
    params: dict[str, Any] = field(default_factory=dict)  # static call params
    time_ms: float | None = None           # profiled processing time
    # provenance of time_ms: "estimate" (roofline/synthesis-report analog,
    # may be overwritten by better sources) or "profile" (measured online by
    # StageProfiler — supersedes estimates and is never overwritten by one).
    time_source: str = "estimate"
    t_start: float | None = None           # absolute start (profile log)
    t_end: float | None = None             # absolute end   (profile log)
    flops: float | None = None             # analytical cost-model annotations
    bytes_rw: float | None = None
    placement: Placement = field(default_factory=Placement)
    # TBB filter-kind marker: a serial-only function is not side-effect safe
    # (hidden state, ordered I/O, RNG, in-place buffers), so any stage
    # containing it must keep exactly ONE worker — assign_replicas never
    # widens it.  Pure array functions (everything the tracer records from
    # jnp/Pallas modules) default to replicable.
    serial_only: bool = False
    fused_from: list[str] = field(default_factory=list)  # names of fused originals
    # per-part input shapes recorded at fusion time, one list per fused part;
    # lets the backend re-check shape-gated hw applicability per part when it
    # resolves the fused node's implementations (empty for unfused nodes).
    fused_input_shapes: list[list[tuple[int, ...]]] = field(default_factory=list)
    # per-part static call params recorded at fusion time (one dict per fused
    # part), so the composed fallback impl re-binds each part's own params
    # instead of dropping them; ``params`` on a fused node holds the merged
    # view for a dedicated fused hw module.
    fused_params: list[dict[str, Any]] = field(default_factory=list)
    # per-part dataflow routing recorded at fusion time: each part's input /
    # output value names.  A fused node's own ``inputs`` are the run's
    # *external* inputs (anything not produced inside the run — e.g. the
    # weight operand of a fused rmsnorm+matmul); the routing lists let the
    # backend feed every part exactly the values it consumed pre-fusion.
    fused_part_inputs: list[list[str]] = field(default_factory=list)
    fused_part_outputs: list[list[str]] = field(default_factory=list)
    # keyword binding per part input recorded at fusion time (parallel to
    # ``fused_part_inputs``; one list per part, None = positional) so the
    # composed fallback impl replays each part's kw-bound operands exactly
    # as traced — a fused MoE dispatch whose gate weights arrived by
    # keyword misbinds if replayed positionally.
    fused_part_kw: list[list[str | None]] = field(default_factory=list)
    # stateful-slot binding: the name of the mutable per-request state this
    # call reads/writes (e.g. a KV-cache slot pool), or None for pure
    # functions.  A stateful node implies serial_only (one worker observes
    # the slot writes in token order), must stay on the sw path (the state
    # lives host-side), and must never fuse into a composed hw kernel.
    state: str | None = None

    def __post_init__(self) -> None:
        # back-compat: legacy string placements (and JSON dicts) normalize
        # to the structured Placement on construction
        if not isinstance(self.placement, Placement):
            self.placement = Placement.parse(self.placement)


# --------------------------------------------------------------------------- #
# Graph
# --------------------------------------------------------------------------- #
class CourierIR:
    """Ordered function-call graph with I/O data (paper Fig. 4)."""

    def __init__(self, name: str = "trace"):
        self.name = name
        self.nodes: list[Node] = []                 # chronological order
        self.values: dict[str, Value] = {}
        self.graph_inputs: list[str] = []
        self.graph_outputs: list[str] = []
        # value name -> array for graph inputs the Frontend discovered
        # mid-trace (closure-captured weights/constants) rather than as
        # top-level call arguments.  They live in ``graph_inputs`` — the IR
        # treats them as ordinary inputs — but the backend stages their
        # arrays from here so callers only feed the per-token arguments.
        self.captured: dict[str, Any] = {}

    # -- construction ------------------------------------------------------ #
    def add_value(self, name: str, shape: Sequence[int], dtype: Any,
                  producer: str | None = None) -> Value:
        v = Value(name=name, shape=tuple(int(s) for s in shape),
                  dtype=str(np.dtype(dtype)), producer=producer)
        self.values[name] = v
        return v

    def add_node(self, node: Node) -> Node:
        for i in node.inputs:
            if i not in self.values:
                raise KeyError(f"node {node.name}: unknown input value {i!r}")
            self.values[i].consumers.append(node.name)
        for o in node.outputs:
            if o not in self.values:
                raise KeyError(f"node {node.name}: unknown output value {o!r}")
            self.values[o].producer = node.name
        self.nodes.append(node)
        return node

    # -- queries ------------------------------------------------------------ #
    def node(self, name: str) -> Node:
        for n in self.nodes:
            if n.name == name:
                return n
        raise KeyError(name)

    def total_time_ms(self) -> float:
        return float(sum(n.time_ms or 0.0 for n in self.nodes))

    def is_linear_chain(self) -> bool:
        """True if every node's outputs feed only the next node / graph output.

        The paper's fusion rule ("if the functions have no branch nor loop")
        and the stage partitioner both operate on linear segments.
        """
        for i, n in enumerate(self.nodes):
            for o in n.outputs:
                cons = self.values[o].consumers
                for c in cons:
                    ci = next(j for j, m in enumerate(self.nodes) if m.name == c)
                    if ci != i + 1:
                        return False
        return True

    def consumers_of(self, node: Node) -> list[Node]:
        out: list[Node] = []
        for o in node.outputs:
            for c in self.values[o].consumers:
                out.append(self.node(c))
        return out

    def validate(self) -> None:
        """Topological sanity: every input is produced before use."""
        produced = set(self.graph_inputs)
        for n in self.nodes:
            for i in n.inputs:
                if i not in produced:
                    raise ValueError(
                        f"IR not causally ordered: {n.name} reads {i!r} "
                        f"before it is produced")
            produced.update(n.outputs)
        for o in self.graph_outputs:
            if o not in produced:
                raise ValueError(f"graph output {o!r} never produced")

    # -- paper Fig.4-style rendering ---------------------------------------- #
    def render(self) -> str:
        """ASCII rendering of the chronological call graph incl. I/O data."""
        lines = [f"CourierIR({self.name})  total={self.total_time_ms():.1f} ms"]
        for vn in self.graph_inputs:
            v = self.values[vn]
            tag = " (captured)" if vn in self.captured else ""
            lines.append(f"  (in)  {vn}: {v.shape} {v.dtype}  [{v.nbytes} B]{tag}")
        for n in self.nodes:
            t = f"{n.time_ms:.1f} ms" if n.time_ms is not None else "?"
            p = Placement.parse(n.placement).short()
            lines.append(f"  [{p:^10s}] {n.name} <{n.fn_key}>  {t}")
            for o in n.outputs:
                v = self.values[o]
                lines.append(f"      -> {o}: {v.shape} {v.dtype}  [{v.nbytes} B]")
        for vn in self.graph_outputs:
            lines.append(f"  (out) {vn}")
        return "\n".join(lines)

    # -- (de)serialization --------------------------------------------------- #
    def to_json(self) -> str:
        return json.dumps({
            "name": self.name,
            "nodes": [asdict(n) for n in self.nodes],
            "values": {k: asdict(v) for k, v in self.values.items()},
            "graph_inputs": self.graph_inputs,
            "graph_outputs": self.graph_outputs,
            # names only — the arrays themselves are runtime state, not IR
            "captured": sorted(self.captured),
        }, indent=2)

    @classmethod
    def from_json(cls, s: str) -> "CourierIR":
        d = json.loads(s)
        ir = cls(d["name"])
        for k, v in d["values"].items():
            v = dict(v)
            v["shape"] = tuple(v["shape"])
            ir.values[k] = Value(**v)
        for n in d["nodes"]:
            ir.nodes.append(Node(**{**n, "inputs": list(n["inputs"]),
                                    "outputs": list(n["outputs"])}))
        ir.graph_inputs = list(d["graph_inputs"])
        ir.graph_outputs = list(d["graph_outputs"])
        return ir


def linear_ir(name: str, fn_keys: Sequence[str], times_ms: Sequence[float],
              io_shape: Sequence[int] = (1,), dtype: str = "float32") -> CourierIR:
    """Convenience builder: a linear chain IR from (fn_key, time) pairs.

    Used by tests/benchmarks to replay the *paper's own profile* (Table I)
    through the Pipeline Generator.
    """
    assert len(fn_keys) == len(times_ms)
    ir = CourierIR(name)
    ir.add_value("d0", io_shape, dtype)
    ir.graph_inputs = ["d0"]
    prev = "d0"
    for i, (k, t) in enumerate(zip(fn_keys, times_ms)):
        out = f"d{i+1}"
        ir.add_value(out, io_shape, dtype)
        ir.add_node(Node(name=f"{k}_{i}", fn_key=k, inputs=[prev],
                         outputs=[out], time_ms=float(t)))
        prev = out
    ir.graph_outputs = [prev]
    ir.validate()
    return ir
