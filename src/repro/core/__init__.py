"""Courier-TPU core — the paper's contribution as a composable JAX library.

Paper: "An Automatic Mixed Software Hardware Pipeline Builder for CPU-FPGA
Platforms" (Miyajima, Thomas, Amano, 2014) — re-targeted to TPU pods.

Flow (paper Fig. 1):
  Frontend.trace        Steps 1-5  — runtime trace of an unmodified callable
  (user edit_ir hook)   Steps 6-7  — inspect/modify the Courier IR
  PipelineGenerator     Step 8     — DB lookup, fusion, balanced partition,
                                     mixed sw/hw token pipeline
  courier_offload       Step 9     — deployable wrapper w/ Off-load Switcher
"""
from .costmodel import (CostModel, DeviceClass, DEVICE_CLASSES,
                        FusionEstimate, NodeCost, PEAK_FLOPS_BF16,
                        HBM_BW, HOST_XFER_BW, ICI_BW_PER_LINK, HBM_BYTES,
                        PROFILE_MARGIN, VMEM_BYTES, attention_cost,
                        device_class, elementwise_cost, fused_cost,
                        matmul_cost, measure_ms, measured_contradicts,
                        replicated_bottleneck_ms, stencil_cost, transfer_ms)
from .database import ModuleDatabase, ModuleEntry, default_db
from .executor import (ExecutorClosed, ExecutorStats, PendingToken,
                       PipelineExecutor, StageCounters)
from .ir import CourierIR, Node, Value, linear_ir
from .offloader import OffloadedFunction, OffloadPlan, courier_offload
from .partition import (PipelinePlan, StagePlan, assign_replicas,
                        assign_stage_devices, clear_stage_devices,
                        fuse_adjacent_hw, fused_working_set_bytes,
                        make_model_fused_cost, partition_optimal,
                        partition_paper, split_fused_node,
                        widen_for_deployment)
from .pipeline import (BuiltPipeline, PipelineGenerator, StageFn,
                       assign_placements, make_stage_fns)
from .placement import (AUTO_BUDGET, DeviceInventory, DeviceSpec,
                        InventoryDiff, Placement, default_worker_budget,
                        is_hw, is_sw, placement_kind, resolve_worker_budget)
from .profiler import StageProfiler
from .spmd_pipeline import (pipeline_microbatches, spmd_pipeline_fn,
                            stack_stage_params, stage_apply)
from .tracer import Frontend, Library, deploy

__all__ = [
    "CostModel", "DeviceClass", "DEVICE_CLASSES", "FusionEstimate",
    "NodeCost", "PEAK_FLOPS_BF16", "HBM_BW", "HOST_XFER_BW",
    "ICI_BW_PER_LINK", "HBM_BYTES", "PROFILE_MARGIN", "VMEM_BYTES",
    "attention_cost", "device_class", "elementwise_cost", "fused_cost",
    "matmul_cost", "measure_ms", "measured_contradicts",
    "replicated_bottleneck_ms", "stencil_cost", "transfer_ms",
    "ModuleDatabase", "ModuleEntry", "default_db",
    "ExecutorClosed", "ExecutorStats", "PendingToken", "PipelineExecutor",
    "StageCounters",
    "CourierIR", "Node", "Value", "linear_ir",
    "OffloadedFunction", "OffloadPlan", "courier_offload",
    "PipelinePlan", "StagePlan", "assign_replicas", "assign_stage_devices",
    "clear_stage_devices", "fuse_adjacent_hw", "fused_working_set_bytes",
    "make_model_fused_cost", "partition_optimal", "partition_paper",
    "split_fused_node", "widen_for_deployment",
    "BuiltPipeline", "PipelineGenerator", "StageFn", "assign_placements",
    "make_stage_fns",
    "AUTO_BUDGET", "DeviceInventory", "DeviceSpec", "InventoryDiff",
    "Placement", "default_worker_budget", "is_hw", "is_sw",
    "placement_kind", "resolve_worker_budget",
    "StageProfiler",
    "pipeline_microbatches", "spmd_pipeline_fn", "stack_stage_params",
    "stage_apply",
    "Frontend", "Library", "deploy",
]
