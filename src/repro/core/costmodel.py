"""Cost model — the TPU analog of the paper's processing-time sources.

Courier-FPGA obtains per-function processing times from (a) the Frontend's
runtime profile for software functions and (b) the logic-synthesis tool's
latency report for hardware modules (paper Sect. III-B.4).  On TPU we have
no synthesis report, so the "hardware" estimate is an analytical roofline:

    t = max(flops / PEAK_FLOPS, bytes / HBM_BW)  (+ collective term)

using TPU v5e constants (per task spec): 197 TFLOP/s bf16 per chip,
819 GB/s HBM bandwidth, ~50 GB/s per ICI link.

Both sources feed the same ``NodeCost`` record so the Pipeline Generator's
balanced partitioning (paper Sect. III-B.4) is agnostic to where a time
came from — exactly as in the paper, where measured SW times and estimated
HW times are mixed in one table.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Sequence

import numpy as np

# ---- TPU v5e hardware constants (per chip) -------------------------------- #
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # bytes/s
ICI_BW_PER_LINK = 50e9          # bytes/s per link (per direction)
HBM_BYTES = 16 * 1024**3        # 16 GiB HBM per chip
VMEM_BYTES = 128 * 1024**2      # ~128 MiB VMEM per core (v5e ballpark)
MXU_TILE = (128, 128)           # systolic array tile
LANE = 128                      # vector lane width
SUBLANE = 8

# Host <-> device (and device <-> device via host) staging bandwidth used to
# charge stage boundaries whose producer and consumer sit on different
# devices — the paper's "communication frequency of intermediate data"
# term, now with a real bandwidth attached (PCIe gen4 x16 ballpark).
HOST_XFER_BW = 16e9             # bytes/s


# --------------------------------------------------------------------------- #
# Device classes — per-device-class roofline constants
# --------------------------------------------------------------------------- #
@dataclass(frozen=True)
class DeviceClass:
    """Roofline constants for one class of placeable device.

    The paper costs a hardware module against the synthesis report of the
    *target FPGA part*; here every :class:`~repro.core.placement.
    DeviceSpec` maps to a class so a replica assigned to device ``k`` is
    costed against that device's constants instead of a single global
    TPU-v5e table (a CPU-class replica of the same stage is much slower,
    and the planner should know).
    """

    name: str
    peak_flops: float = PEAK_FLOPS_BF16
    hbm_bw: float = HBM_BW
    ici_bw: float = ICI_BW_PER_LINK
    xfer_bw: float = HOST_XFER_BW       # host<->device staging bandwidth
    vmem_bytes: int = VMEM_BYTES


DEVICE_CLASSES: dict[str, DeviceClass] = {
    "tpu": DeviceClass("tpu"),
    # A100-ish ballpark: ~2x the v5e HBM bw, ~1.6x bf16 flops
    "gpu": DeviceClass("gpu", peak_flops=312e12, hbm_bw=1.6e12,
                       ici_bw=300e9),
    # one beefy host core + DDR: the "software filter on a CPU core" class
    "cpu": DeviceClass("cpu", peak_flops=1e11, hbm_bw=3e10, ici_bw=1e10,
                       xfer_bw=30e9, vmem_bytes=32 * 1024**2),
}


def device_class(platform: str) -> DeviceClass:
    """Roofline constants for a platform name (unknown → TPU defaults)."""
    return DEVICE_CLASSES.get(str(platform).lower(), DEVICE_CLASSES["tpu"])


def transfer_ms(nbytes: float, bw_bytes_per_s: float = HOST_XFER_BW) -> float:
    """Wall ms to move ``nbytes`` across a stage boundary that changes
    device — one staging hop at the slower side's transfer bandwidth."""
    if nbytes <= 0:
        return 0.0
    if bw_bytes_per_s <= 0:
        raise ValueError(f"transfer bandwidth must be > 0 "
                         f"(got {bw_bytes_per_s})")
    return 1e3 * float(nbytes) / float(bw_bytes_per_s)


@dataclass
class NodeCost:
    """Roofline terms for one IR node (or one compiled step)."""

    flops: float = 0.0
    bytes_rw: float = 0.0            # HBM traffic (read+write)
    coll_bytes: float = 0.0          # inter-chip bytes over ICI
    measured_ms: float | None = None  # Frontend profile, wins when present

    def time_ms(self, chips: int = 1, ici_links: int = 1,
                device: DeviceClass | None = None) -> float:
        """Roofline time; ``device`` costs against that device class's
        constants instead of the global TPU-v5e table (measured times
        still win — a profile is of the device that ran it)."""
        if self.measured_ms is not None:
            return self.measured_ms
        peak = device.peak_flops if device is not None else PEAK_FLOPS_BF16
        hbm = device.hbm_bw if device is not None else HBM_BW
        ici = device.ici_bw if device is not None else ICI_BW_PER_LINK
        t_compute = self.flops / (chips * peak)
        t_memory = self.bytes_rw / (chips * hbm)
        t_coll = self.coll_bytes / (chips * ici_links * ici)
        return 1e3 * (max(t_compute, t_memory) + t_coll)

    @property
    def arithmetic_intensity(self) -> float:
        return self.flops / max(self.bytes_rw, 1.0)

    def dominant(self) -> str:
        t_c = self.flops / PEAK_FLOPS_BF16
        t_m = self.bytes_rw / HBM_BW
        t_x = self.coll_bytes / ICI_BW_PER_LINK
        return ("compute", "memory", "collective")[int(np.argmax([t_c, t_m, t_x]))]

    def __add__(self, other: "NodeCost") -> "NodeCost":
        m = None
        if self.measured_ms is not None or other.measured_ms is not None:
            # mixed measured+estimated sum: the operand without a profile
            # contributes its roofline estimate, not 0 — otherwise a stage
            # holding one profiled and one estimated node underreports.
            m = self.time_ms() + other.time_ms()
        return NodeCost(self.flops + other.flops,
                        self.bytes_rw + other.bytes_rw,
                        self.coll_bytes + other.coll_bytes, m)


# --------------------------------------------------------------------------- #
# Fusion model — VMEM-resident intermediates (the TPU dataflow-fusion analog)
# --------------------------------------------------------------------------- #
@dataclass
class FusionEstimate:
    """Predicted economics of fusing a run of adjacent nodes into one kernel.

    On the paper's FPGA the fused cvtColor+cornerHarris module was *slower*
    than its pipelined parts, so Courier rejected it.  On TPU the economics
    usually invert: a fused kernel keeps the intermediates resident in VMEM,
    so their HBM write+readback traffic disappears — but only while the
    fused working set actually fits VMEM.  This record carries both sides of
    that decision so callers (``fuse_adjacent_hw``) can accept wins and
    reject spills.
    """

    cost: NodeCost                  # the fused kernel's roofline record
    hbm_bytes_saved: float          # intermediate write+read traffic removed
    vmem_required: int              # fused working-set bytes (tiles + halos)
    vmem_bytes: int                 # capacity it was checked against
    unfused_ms: float               # sum of the parts' times (seq. latency)

    @property
    def fits_vmem(self) -> bool:
        return self.vmem_required <= self.vmem_bytes

    @property
    def fused_ms(self) -> float:
        """Predicted fused-kernel time; +inf when the working set spills.

        Returning +inf (rather than a degraded estimate) makes a spilling
        fusion lose against *any* acceptance threshold, which is exactly the
        contract ``fuse_adjacent_hw`` needs.
        """
        if not self.fits_vmem:
            return float("inf")
        return self.cost.time_ms()

    @property
    def wins(self) -> bool:
        return self.fits_vmem and self.fused_ms < self.unfused_ms

    def describe(self) -> str:
        return (f"FusionEstimate(fused={self.fused_ms:.4f} ms, "
                f"unfused={self.unfused_ms:.4f} ms, "
                f"hbm_saved={self.hbm_bytes_saved / 1e6:.2f} MB, "
                f"vmem={self.vmem_required / 1e6:.2f}/"
                f"{self.vmem_bytes / 1e6:.0f} MB, "
                f"{'fits' if self.fits_vmem else 'SPILLS'})")


def fused_cost(parts: "list[NodeCost]", intermediate_bytes: float, *,
               vmem_required: int = 0,
               vmem_bytes: int = VMEM_BYTES) -> FusionEstimate:
    """Model a fused kernel over ``parts`` with VMEM-resident intermediates.

    ``intermediate_bytes`` is the total size of the values flowing *between*
    the fused parts.  Unfused, each such value costs one HBM write (by its
    producer) and one HBM read (by its consumer); fused, it never leaves
    VMEM, so ``2 * intermediate_bytes`` of traffic vanishes.  FLOPs are
    conserved — fusion only moves data, it doesn't remove arithmetic.

    ``vmem_required`` is the fused kernel's resident working set (input +
    intermediate + output tiles incl. halos).  When it exceeds
    ``vmem_bytes`` the fusion would spill and the estimate reports
    ``fused_ms = inf`` so callers reject it.

    Parts' ``measured_ms`` are deliberately ignored for the *fused* record:
    the fused kernel is new code, so only the roofline speaks for it; the
    measured times still make up ``unfused_ms`` (the side we compare with).
    """
    if not parts:
        raise ValueError("fused_cost needs at least one part")
    flops = sum(p.flops for p in parts)
    byts = sum(p.bytes_rw for p in parts)
    coll = sum(p.coll_bytes for p in parts)
    saved = min(2.0 * intermediate_bytes, byts)     # can't save more than all
    cost = NodeCost(flops=flops, bytes_rw=byts - saved, coll_bytes=coll)
    unfused_ms = sum(p.time_ms() for p in parts)
    return FusionEstimate(cost=cost, hbm_bytes_saved=saved,
                          vmem_required=int(vmem_required),
                          vmem_bytes=int(vmem_bytes), unfused_ms=unfused_ms)


# --------------------------------------------------------------------------- #
# Analytical costs for common op families
# --------------------------------------------------------------------------- #
def matmul_cost(m: int, n: int, k: int, bytes_per_el: int = 2,
                batch: int = 1) -> NodeCost:
    flops = 2.0 * batch * m * n * k
    byts = bytes_per_el * batch * (m * k + k * n + m * n)
    return NodeCost(flops=flops, bytes_rw=byts)


def elementwise_cost(numel: int, flops_per_el: float = 1.0,
                     bytes_per_el: int = 2, n_operands: int = 2) -> NodeCost:
    return NodeCost(flops=flops_per_el * numel,
                    bytes_rw=bytes_per_el * numel * n_operands)


def stencil_cost(h: int, w: int, c: int, taps: int,
                 bytes_per_el: int = 4) -> NodeCost:
    """k-tap 2-D stencil (Sobel, box filter ...) — the Harris building block."""
    numel = h * w * c
    return NodeCost(flops=2.0 * taps * numel, bytes_rw=2.0 * bytes_per_el * numel)


def attention_cost(batch: int, q_len: int, kv_len: int, heads: int,  # lint: allow-dead(cost-model API for LM workloads; kept for config-driven planners)
                   head_dim: int, kv_heads: int | None = None,
                   window: int | None = None, bytes_per_el: int = 2) -> NodeCost:
    """QK^T + softmax + PV cost; sliding-window caps kv_len at window."""
    kv_heads = kv_heads or heads
    eff_kv = min(kv_len, window) if window else kv_len
    flops = 2.0 * batch * heads * q_len * eff_kv * head_dim * 2  # QK^T and PV
    flops += 5.0 * batch * heads * q_len * eff_kv                # softmax-ish
    byts = bytes_per_el * batch * (
        heads * q_len * head_dim                      # Q
        + 2 * kv_heads * eff_kv * head_dim            # K, V
        + heads * q_len * head_dim)                   # out
    return NodeCost(flops=flops, bytes_rw=byts)


# --------------------------------------------------------------------------- #
# Stage replication (TBB parallel filters — widen instead of re-balance)
# --------------------------------------------------------------------------- #
def replicated_bottleneck_ms(stage_ms: "Sequence[float]",
                             replicas: "Sequence[int]",
                             speeds: "Sequence[Sequence[float]] | None" = None,
                             ) -> float:
    """Predicted steady-state token period of a replicated pipeline plan.

    A stage whose one-worker service time is ``t`` and which runs ``r``
    parallel workers retires a token every ``t / r`` ms once its replicas
    are saturated (the TBB parallel-filter throughput model), so the
    pipeline period is ``max_k t_k / r_k``.  This is the quantity the
    re-planner compares between "move the boundaries" and "widen the
    bottleneck" candidates; with all replicas 1 it reduces to the plain
    bottleneck.  Host-side hand-off overhead is deliberately folded into
    the measured ``stage_ms`` (the profiler times the whole stage
    invocation), not modeled separately.

    ``speeds`` (optional) carries one relative-throughput factor per
    replica per stage (device-aware planning: a replica pinned to a
    faster device class drains more than ``1/r`` of the stream).  Stage
    ``k``'s aggregate rate is ``sum_j speed_kj / t_k``, so its period is
    ``t_k / sum_j speed_kj`` — equal to ``t_k / r_k`` when every replica
    runs at the class baseline.  An empty per-stage entry means
    "homogeneous at speed 1".
    """
    if len(stage_ms) != len(replicas):
        raise ValueError(f"{len(stage_ms)} stage times vs "
                         f"{len(replicas)} replica counts")
    if speeds is not None and len(speeds) != len(stage_ms):
        raise ValueError(f"{len(stage_ms)} stage times vs "
                         f"{len(speeds)} speed vectors")
    if not stage_ms:
        return 0.0
    period = 0.0
    for k, (t, r) in enumerate(zip(stage_ms, replicas)):
        r = max(int(r), 1)
        sp = list(speeds[k]) if speeds is not None and speeds[k] else None
        if sp is not None:
            if len(sp) != r:
                raise ValueError(f"stage {k}: {len(sp)} replica speeds "
                                 f"for {r} replicas")
            if any(s <= 0 for s in sp):
                raise ValueError(f"stage {k}: replica speeds must be > 0")
            rate = sum(sp)
        else:
            rate = float(r)
        period = max(period, float(t) / rate)
    return period


# --------------------------------------------------------------------------- #
# Measured vs modeled (the online-profile write-back contract)
# --------------------------------------------------------------------------- #
PROFILE_MARGIN = 1.5      # default measured-vs-model contradiction factor


def measured_contradicts(model_ms: float | None, measured_ms: float | None,
                         margin: float = PROFILE_MARGIN) -> bool:
    """True when a measurement deviates from the model by ``margin``x.

    The re-planner's trigger condition: a measured stage/node time that is
    ``>= margin`` times the estimate (or ``<= 1/margin`` of it) means the
    cost table the current plan was balanced on is wrong, so fuse/no-fuse
    and stage-boundary decisions deserve a revisit.  ``None`` on either
    side never contradicts (nothing measured, or nothing modeled).
    """
    if model_ms is None or measured_ms is None:
        return False
    if margin < 1.0:
        raise ValueError(f"margin must be >= 1.0 (got {margin})")
    if model_ms <= 0.0:
        return measured_ms > 0.0
    ratio = measured_ms / model_ms
    return ratio >= margin or ratio <= 1.0 / margin


# --------------------------------------------------------------------------- #
# Measured profiles (the Frontend's profile log)
# --------------------------------------------------------------------------- #
def measure_ms(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    """Wall-clock a callable (blocks on JAX async dispatch via block_until_ready)."""
    import jax

    def _run():
        out = fn(*args)
        return jax.block_until_ready(out)

    for _ in range(warmup):
        _run()
    t0 = time.perf_counter()
    for _ in range(iters):
        _run()
    return (time.perf_counter() - t0) / iters * 1e3


@dataclass
class CostModel:
    """Per-fn_key cost providers; mixes measured and analytical sources.

    ``measured`` holds per-function EMA wall times fed by the online
    profiler (:meth:`observe`); they *supersede* the analytical providers
    during :meth:`annotate` — the paper's rule that a runtime profile
    outranks a synthesis-report estimate, kept live while serving.
    """

    chips: int = 1
    ici_links: int = 1
    providers: dict[str, Callable[..., NodeCost]] = field(default_factory=dict)
    measured: dict[str, float] = field(default_factory=dict)
    measure_alpha: float = 0.25

    def register(self, fn_key: str, provider: Callable[..., NodeCost]) -> None:
        self.providers[fn_key] = provider

    def observe(self, fn_key: str, ms: float) -> float:
        """Fold one measured wall time into the per-function EMA."""
        prev = self.measured.get(fn_key)
        a = self.measure_alpha
        self.measured[fn_key] = float(ms) if prev is None \
            else (1.0 - a) * prev + a * float(ms)
        return self.measured[fn_key]

    def cost(self, fn_key: str, *args, **kwargs) -> NodeCost:
        if fn_key not in self.providers:
            raise KeyError(f"no cost provider for {fn_key!r}")
        return self.providers[fn_key](*args, **kwargs)

    def annotate(self, ir) -> None:
        """Fill Node.flops / bytes from providers when a node has no profile.

        Measured times (:meth:`observe`) win over both the provider estimate
        and any pre-existing estimate on the node; nodes they touch are
        marked ``time_source="profile"`` so later estimator passes leave
        them alone.
        """
        for n in ir.nodes:
            if n.fn_key in self.providers:
                shapes = [ir.values[i].shape for i in n.inputs]
                dtypes = [ir.values[i].dtype for i in n.inputs]
                try:
                    c = self.providers[n.fn_key](shapes, dtypes, n.params)
                except TypeError:
                    continue
                n.flops, n.bytes_rw = c.flops, c.bytes_rw
                if n.time_ms is None:
                    n.time_ms = c.time_ms(self.chips, self.ici_links)
            m = self.measured.get(n.fn_key)
            if m is not None:
                n.time_ms = m
                n.time_source = "profile"
