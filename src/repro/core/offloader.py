"""Function Off-loader — paper Sect. III-C (Step 9) + Off-load Switcher.

Courier-FPGA compiles the generated pipeline into a shared object and swaps
it into the *running* binary via DLL injection, keeping the original path
available ("Off-load Switcher").  The JAX analog:

* :class:`OffloadPlan` rebinds the interposable :class:`~repro.core.tracer.
  Library` call sites — ``with deploy(plan):`` makes the *same, unmodified*
  user code call the accelerated implementations (the dlsym/RTLD_NEXT swap).
* :class:`OffloadedFunction` is the generated wrapper: it carries the built
  pipeline, the original function, and a switch with automatic fallback —
  if the accelerated path fails, the call transparently reverts to the
  original ("maintains original processing flow before and after off-load").
* :func:`courier_offload` is the whole toolchain in one call — trace →
  database lookup → (optional) fusion → balanced partition → pipeline →
  deployable wrapper — i.e. paper Steps 1-9 "without user intervention".
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Iterable

from .costmodel import CostModel
from .database import ModuleDatabase, ModuleEntry, default_db
from .ir import CourierIR
from .pipeline import BuiltPipeline, PipelineGenerator
from .placement import Placement, is_hw
from .tracer import Frontend, deploy

__all__ = ["OffloadPlan", "OffloadedFunction", "courier_offload"]


# --------------------------------------------------------------------------- #
# Call-site rebinding plan (used by ``with deploy(plan):``)
# --------------------------------------------------------------------------- #
@dataclass
class OffloadPlan:
    """fn_key → backend-kind decisions, consumed by the deploy context.

    ``decisions`` values are placement kind strings (the
    :data:`~repro.core.placement.HW`/:data:`~repro.core.placement.SW`
    constants) so a serialized plan stays a flat JSON-able dict; all
    comparisons go through the placement helpers.
    """

    decisions: dict[str, str] = field(default_factory=dict)
    fallback_log: list[str] = field(default_factory=list)

    @classmethod
    def from_ir(cls, ir: CourierIR) -> "OffloadPlan":
        kinds = ((n.fn_key, Placement.parse(n.placement)) for n in ir.nodes)
        return cls(decisions={k: p.kind for k, p in kinds if p.is_assigned})

    def resolve(self, entry: ModuleEntry) -> Callable:
        want_hw = (is_hw(self.decisions.get(entry.name))
                   and entry.accelerated)
        if not want_hw:
            return entry.software

        def switched(*args: Any, **kwargs: Any):
            try:
                return entry.accelerated(*args, **kwargs)
            except Exception as e:          # Off-load Switcher fallback
                self.fallback_log.append(f"{entry.name}: {type(e).__name__}: {e}")
                return entry.software(*args, **kwargs)
        return switched


# --------------------------------------------------------------------------- #
# The deployed wrapper
# --------------------------------------------------------------------------- #
class OffloadedFunction:
    """The generated wrapper that replaces the original function.

    ``mode`` selects the path at call time (the Off-load Switcher):
      * "pipeline"  — the built mixed sw/hw pipeline (default)
      * "original"  — the untouched software path
    Any exception on the accelerated path falls back to the original and is
    recorded, so a deployed run never changes observable behavior.
    """

    def __init__(self, original: Callable, pipeline: BuiltPipeline,
                 plan: OffloadPlan, ir: CourierIR):
        self.original = original
        self.pipeline = pipeline
        self.plan = plan
        self.ir = ir
        self.mode = "pipeline"
        self.fallbacks: list[str] = []

    def __call__(self, *args: Any):
        if self.mode == "original":
            return self.original(*args)
        try:
            return self.pipeline(*args)
        except Exception as e:
            self.fallbacks.append(f"pipeline: {type(e).__name__}: {e}")
            return self.original(*args)

    def map(self, tokens: Iterable[Any]) -> list[Any]:
        """Pipelined execution over a token stream (the deployed fast path)."""
        if self.mode == "original":
            return [self.original(*(t if isinstance(t, tuple) else (t,)))
                    for t in tokens]
        return self.pipeline.run(tokens)

    def map_async(self, tokens: Iterable[Any], *,
                  max_in_flight: int | None = None,
                  microbatch: int = 1) -> list[Any]:
        """Token stream through the asynchronous executor (serving path).

        Same results/order as :meth:`map`, but stages are issued eagerly
        with a bounded token pool and optional per-stage micro-batching
        (see :class:`repro.core.executor.PipelineExecutor`).
        """
        # validate before the mode branch so a bad serving config fails
        # deterministically, not only after a switch to "pipeline" mode
        if max_in_flight is not None and max_in_flight < 1:
            raise ValueError(f"max_in_flight must be >= 1, got {max_in_flight}")
        if microbatch < 1:
            raise ValueError(f"microbatch must be >= 1, got {microbatch}")
        if self.mode == "original":
            return self.map(tokens)
        return self.pipeline.run_async(tokens, max_in_flight=max_in_flight,
                                       microbatch=microbatch)

    def switch(self, mode: str) -> None:
        if mode not in ("pipeline", "original"):
            raise ValueError(mode)
        self.mode = mode

    def describe(self) -> str:
        return (f"OffloadedFunction(mode={self.mode})\n"
                + self.pipeline.describe())


# --------------------------------------------------------------------------- #
# Whole-toolchain driver (paper Fig. 1, Steps 1-9)
# --------------------------------------------------------------------------- #
def courier_offload(fn: Callable, *example_args: Any,
                    db: ModuleDatabase | None = None,
                    cost_model: CostModel | None = None,
                    n_threads: int = 2, policy: str = "paper",
                    prefer_hw: bool = True, fuse: bool = False,
                    fused_cost_ms: Callable | None = None,
                    max_stages: int | None = None,
                    profile: bool = True, warmup: bool = True,
                    edit_ir: Callable[[CourierIR], CourierIR] | None = None,
                    ) -> OffloadedFunction:
    """Run the full Courier flow on an unmodified callable.

    ``edit_ir`` is the paper's Steps 6-7 hook: the user may examine and
    modify the traced IR (rerouting dataflow, pinning placements) before
    the Backend builds the pipeline.  ``warmup`` runs the app once before
    the profiled trace so first-call compilation doesn't pollute the
    Frontend's processing times.
    """
    db = db or default_db
    frontend = Frontend(db)
    if warmup and profile:
        import jax
        jax.block_until_ready(fn(*example_args))
    ir, _ = frontend.trace(fn, *example_args, profile=profile)   # Steps 1-5
    if edit_ir is not None:                                      # Steps 6-7
        ir = edit_ir(ir) or ir
    gen = PipelineGenerator(db, cost_model=cost_model)           # Step 8
    pipe = gen.generate(ir, n_threads=n_threads, policy=policy,
                        prefer_hw=prefer_hw, fuse=fuse,
                        fused_cost_ms=fused_cost_ms, max_stages=max_stages)
    plan = OffloadPlan.from_ir(pipe.ir)
    return OffloadedFunction(original=fn, pipeline=pipe, plan=plan,
                             ir=pipe.ir)                          # Step 9
