"""Pipeline Generator — paper Sect. III: build & run the mixed pipeline.

Given a traced CourierIR and the module database, the generator

1. assigns placements by database lookup (hit → "hw" Pallas module, miss →
   "sw" pure-jnp function) and re-estimates hit nodes with the database's
   cost estimator (the synthesis-report analog),
2. optionally fuses adjacent branch-free hw nodes (``#pragma HLS dataflow``),
3. partitions the chronological node list into balanced contiguous stages
   (paper policy or bottleneck-optimal DP),
4. emits one jitted callable per stage operating on the live-value
   environment at the stage boundary (the paper's "intermediate data ...
   stored in the external memory" — here, stage-boundary arrays in HBM),
5. wraps everything in a :class:`BuiltPipeline` whose ``run`` executes a
   TBB-style token pipeline: a wavefront schedule with a bounded number of
   in-flight tokens (TBB's token pool), first/last stages serial-in-order.

JAX's async dispatch provides the overlap TBB gets from its thread pool:
each stage call on a token returns immediately with futures, so stage s can
be issued for token k+1 while token k is still executing downstream — the
paper's "Task #0 can take the second input while Task #1 is processing".

Two token-stream execution paths are exposed:

* ``BuiltPipeline.run``       — the original synchronous wavefront schedule
  (host steps every in-flight token one stage at a time); kept as the
  paper-faithful baseline.
* ``BuiltPipeline.run_async`` / ``BuiltPipeline.executor()`` — the true
  asynchronous executor (:mod:`repro.core.executor`): eager stage issue,
  bounded token pool, optional per-stage micro-batching, throughput and
  occupancy counters.  This is the serving-layer fast path.
"""
from __future__ import annotations

import warnings
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Iterable, Sequence

import jax
import jax.numpy as jnp

from .costmodel import CostModel
from .database import ModuleDatabase
from .ir import CourierIR, Node
from .partition import (PipelinePlan, StagePlan, fuse_adjacent_hw,
                        partition_optimal, partition_paper)
from .placement import HW, SW, Placement, is_hw

__all__ = ["PipelineGenerator", "BuiltPipeline", "StageFn",
           "assign_placements", "make_stage_fns", "loop_batched"]


def loop_batched(fn: Callable) -> Callable:
    """Per-row loop replacement for ``jit(vmap(stage))`` on STATEFUL stages.

    A stage that mutates a host-side slot pool can't be vmapped (vmap
    traces the body once; the per-row pool writes would collapse into
    one) and can't be jitted (the writes would never re-execute).  This
    runs the raw stage body once per leading-axis row and restacks, so
    micro-batched groups still flow through stateful stages — each row's
    slot mutation happens exactly once, in row order.
    """
    def batched(env: dict) -> dict:
        b = jnp.shape(next(iter(env.values())))[0]
        outs = [fn({k: v[i] for k, v in env.items()}) for i in range(b)]
        return {k: jnp.stack([o[k] for o in outs]) for k in outs[0]}
    batched.__name__ = f"loop_batched_{getattr(fn, '__name__', 'stage')}"
    return batched


# --------------------------------------------------------------------------- #
# Step: placement assignment (database lookup)
# --------------------------------------------------------------------------- #
def assign_placements(ir: CourierIR, db: ModuleDatabase,
                      prefer_hw: bool = True) -> None:
    """Paper Fig. 3 'Search corresponding modules from a HW module DB'.

    Marks each node's backend kind (hw = accelerated module, sw = software
    fallback) and, for hw nodes with a cost estimator, replaces the
    measured software time with the estimated accelerated time (the paper
    mixes measured SW times with synthesis-estimated HW times).  Nodes
    whose ``time_ms`` came from the *online* profile (``time_source ==
    "profile"``) keep it — a measurement of the deployed hw module
    outranks the synthesis-report estimate it superseded.  Only the
    placement's *kind* is (re)resolved here: a device/replica pinning set
    by the replica-assignment pass (or a user ``edit_ir`` hook) survives.
    """
    for n in ir.nodes:
        e = db.lookup(n.fn_key)
        shapes = [ir.values[i].shape for i in n.inputs]
        cur = Placement.parse(n.placement)
        if e is not None and prefer_hw and e.has_hw(*shapes):
            n.placement = cur.with_kind(HW)
            if e.cost_hw is not None:
                dtypes = [ir.values[i].dtype for i in n.inputs]
                c = e.cost_hw(shapes, dtypes, n.params)
                n.flops, n.bytes_rw = c.flops, c.bytes_rw
                if n.time_source != "profile":
                    n.time_ms = c.time_ms()
        else:
            n.placement = cur.with_kind(SW)


# --------------------------------------------------------------------------- #
# Stage compilation
# --------------------------------------------------------------------------- #
def _liveness(ir: CourierIR, plan: PipelinePlan) -> list[list[str]]:
    """Live value names at each stage boundary (len = n_stages + 1).

    boundary[0] = graph inputs; boundary[k] = values produced before stage k
    that are still needed by stages >= k or are graph outputs.

    Captured graph inputs (closure-held weights the Frontend registered in
    ``ir.captured``) never cross boundaries — they are per-pipeline
    constants baked into the stage closures, not per-token traffic; shipping
    a weight matrix through every boundary (and stacking it per token under
    micro-batching) would swamp the stream.  The one exception: a captured
    value that *is* a graph output stays live at the final boundary so the
    executor can retire it like any other result.
    """
    name_to_stage: dict[str, int] = {}
    for si, s in enumerate(plan.stages):
        for nn in s.node_names:
            name_to_stage[nn] = si

    cap = set(getattr(ir, "captured", ()))
    boundaries: list[list[str]] = [[v for v in ir.graph_inputs
                                    if v not in cap]]
    produced: set[str] = set(ir.graph_inputs)
    for k in range(1, plan.n_stages + 1):
        for nn in plan.stages[k - 1].node_names:
            produced.update(ir.node(nn).outputs)
        live: list[str] = []
        for v in produced:
            if v in cap and not (k == plan.n_stages
                                 and v in ir.graph_outputs):
                continue
            needed = any(
                name_to_stage.get(c, -1) >= k for c in ir.values[v].consumers
            ) or v in ir.graph_outputs
            if needed:
                live.append(v)
        boundaries.append(sorted(live))
    return boundaries


def _accepts_params(fn: Callable, params: dict) -> bool:
    """True when ``fn(*args, **params)`` cannot fail on a param name.

    A dedicated fused module is only used when it understands *every*
    merged param of the fused run — silently dropping one (or crashing into
    the Off-load Switcher's fallback on every call) would diverge from the
    unfused semantics.  Unknown-signature callables are trusted only for
    empty params.
    """
    if not params:
        return True
    import inspect
    try:
        sig = inspect.signature(fn)
    except (TypeError, ValueError):
        return False
    names = set()
    for p in sig.parameters.values():
        if p.kind == p.VAR_KEYWORD:
            return True
        if p.kind in (p.POSITIONAL_OR_KEYWORD, p.KEYWORD_ONLY):
            names.add(p.name)
    return set(params) <= names


def _resolve_impl(node: Node, ir: CourierIR, db: ModuleDatabase) -> Callable:
    if node.fused_from:
        # fused node "a+b": prefer a *dedicated* fused hw module registered
        # in the database under the joined key (the single-pass mega-kernel
        # — see ModuleDatabase.register_fused); fall back to composing the
        # parts' impls, re-checking each part's shape-gated hw applicability
        # against the input shapes it actually sees (recorded at fusion
        # time) — resolving without shapes would pick hw even for shapes the
        # module's `applicable` rejects.
        shapes = [ir.values[i].shape for i in node.inputs]
        e = db.lookup(node.fn_key)
        if (e is not None and e.has_hw(*shapes)
                and _accepts_params(e.accelerated, node.params)):
            return e.accelerated
        keys = node.fn_key.split("+")
        part_shapes = node.fused_input_shapes or [[] for _ in keys]
        part_params = node.fused_params or [{} for _ in keys]
        impls = [db.resolve(k, *ps, prefer_hw=True)[0]
                 for k, ps in zip(keys, part_shapes)]

        if node.fused_part_inputs:
            # route each part exactly the values it consumed pre-fusion:
            # external operands come from the fused node's args, carried
            # intermediates from earlier parts' outputs.  Each part's
            # keyword bindings (fused_part_kw, recorded at fusion time)
            # replay under their trace-time names — a part whose software
            # impl takes arrays keyword-only misbinds otherwise.
            part_kws = (tuple(map(tuple, node.fused_part_kw))
                        if node.fused_part_kw
                        else tuple(tuple([None] * len(ins))
                                   for ins in node.fused_part_inputs))
            routing = tuple(zip(tuple(map(tuple, node.fused_part_inputs)),
                                tuple(map(tuple, node.fused_part_outputs)),
                                part_kws))
            arg_names = tuple(node.inputs)
            out_names = tuple(node.outputs)

            def fused(*args: Any, _impls=tuple(impls),
                      _params=tuple(part_params), **_merged: Any):
                env = dict(zip(arg_names, args))
                for (ins, outs, kws), f, pp in zip(routing, _impls, _params):
                    pos = [env[v] for v, kw in zip(ins, kws) if kw is None]
                    kw = {kw: env[v] for v, kw in zip(ins, kws)
                          if kw is not None}
                    out = f(*pos, **kw, **pp)
                    out_t = out if isinstance(out, (tuple, list)) else (out,)
                    env.update(zip(outs, out_t))
                res = tuple(env[v] for v in out_names)
                return res[0] if len(res) == 1 else res
            return fused

        def fused(*args: Any, **_merged: Any):
            # legacy linear-chain composition (fused nodes built without
            # routing metadata, e.g. hand-constructed in tests)
            out = args
            for f, pp in zip(impls, part_params):
                out = f(*out, **pp)
                if not isinstance(out, (tuple, list)):
                    out = (out,)
            return out[0] if len(out) == 1 else tuple(out)
        return fused
    shapes = [ir.values[i].shape for i in node.inputs]
    fn, _ = db.resolve(node.fn_key, *shapes, prefer_hw=is_hw(node.placement))
    return fn


class StageFn:
    """One compiled pipeline stage: ``dict(live-in) -> dict(live-out)``.

    Wraps the raw Python stage body in a *hoisted* ``jax.jit`` that lives for
    the pipeline's lifetime, so steady-state serving re-enters the same
    executable instead of re-tracing — and exposes the XLA compile count
    (``jit``'s signature-cache size) so callers can assert **zero recompiles
    after warmup**.  ``raw`` is kept for transform composition (the executor
    vmaps it for micro-batching).

    ``donate`` forwards the env argument's buffers to XLA as donated inputs:
    stage outputs may reuse stage-input memory, killing the per-token
    intermediate copies.  Only safe when the caller hands over ownership of
    the env (true for all boundaries that contain no user-provided graph
    inputs — the generator checks liveness before enabling it).
    """

    __slots__ = ("raw", "jitted", "donated", "stateful", "_fn", "__name__")

    def __init__(self, fn: Callable, *, jit: bool = True,
                 donate: bool = False):
        self.raw = fn
        self.jitted = jit
        self.donated = donate and jit
        # stage contains a stateful (slot-pool-mutating) node: never jit
        # or vmap its body — the executor loop-batches it per row instead
        self.stateful = False
        self._fn = (jax.jit(fn, donate_argnums=(0,) if donate else ())
                    if jit else fn)
        self.__name__ = getattr(fn, "__name__", "stage")

    def __call__(self, env: dict) -> dict:
        if self.donated:
            # donation is a silent no-op on backends without it (CPU), but
            # XLA warns at compile time; suppress only around *this* call so
            # the host application's own donation diagnostics stay intact.
            with warnings.catch_warnings():
                warnings.filterwarnings(
                    "ignore", message="Some donated buffers were not usable")
                return self._fn(env)
        return self._fn(env)

    @property
    def compiles(self) -> int:
        """Number of distinct executables compiled for this stage."""
        if not self.jitted:
            return 0
        try:
            return self._fn._cache_size()
        except AttributeError:          # non-jit fallback / older jax
            return 0


def make_stage_fns(ir: CourierIR, db: ModuleDatabase, plan: PipelinePlan,
                   jit: bool = True, donate: bool = True,
                   cache: dict | None = None) -> list[StageFn]:
    """One callable per stage: dict(live-in) -> dict(live-out).

    ``donate``: donate each stage's env buffers when the live-in boundary
    consists purely of pipeline-owned intermediates (never stage 0, whose
    env aliases caller-owned token arrays, and never a boundary where a
    graph input is still live).

    ``cache``: optional dict carried across re-plans (owned by e.g.
    :class:`~repro.runtime.driver.ElasticPlanner`).  A stage whose identity
    — node names, placements, live-in/out boundaries, jit/donate config —
    is unchanged from a previous plan reuses the *same* :class:`StageFn`
    object, so its compiled executables survive the re-plan: hot-swapping
    a re-balanced executor recompiles only the stages whose boundaries
    actually moved.
    """
    boundaries = _liveness(ir, plan)
    fns: list[StageFn] = []
    for k, s in enumerate(plan.stages):
        nodes = [ir.node(nn) for nn in s.node_names]
        live_out = boundaries[k + 1]
        # a stage containing a stateful node runs the raw Python body:
        # its impl mutates a host-side slot pool, which jit would trace
        # once and never re-execute.  Donation is off with it (the env
        # arrays are read host-side, not handed to XLA).
        has_state = any(getattr(n, "state", None) for n in nodes)
        stage_jit = jit and not has_state
        can_donate = (donate and stage_jit and k > 0
                      and not set(boundaries[k]) & set(ir.graph_inputs))
        # key on the nodes' CURRENT placements (what _resolve_impl reads),
        # not the plan's snapshot — a plan computed before assign_placements
        # would otherwise never hit the cache
        key = (tuple(s.node_names),
               tuple(Placement.parse(n.placement).key for n in nodes),
               tuple(boundaries[k]), tuple(live_out), stage_jit, can_donate)
        if cache is not None and key in cache:
            fns.append(cache[key])
            continue
        impls = [_resolve_impl(n, ir, db) for n in nodes]
        captured = dict(getattr(ir, "captured", {}))

        def stage(env: dict, _nodes=tuple(nodes), _impls=tuple(impls),
                  _live=tuple(live_out), _cap=captured):
            env = dict(env)
            for node, impl in zip(_nodes, _impls):
                # captured operands come from the closure (pipeline-held
                # constants), everything else from the live env; keyword-
                # bound arrays (input_kw) replay under their trace-time name
                kws = node.input_kw or [None] * len(node.inputs)
                pos = [env[v] if v in env else _cap[v]
                       for v, kw in zip(node.inputs, kws) if kw is None]
                kw = {kw: env[v] if v in env else _cap[v]
                      for v, kw in zip(node.inputs, kws) if kw is not None}
                out = impl(*pos, **kw, **node.params)
                outs = out if isinstance(out, (tuple, list)) else (out,)
                for name, o in zip(node.outputs, outs):
                    env[name] = o
            return {k2: env[k2] if k2 in env else _cap[k2] for k2 in _live}

        sf = StageFn(stage, jit=stage_jit, donate=can_donate)
        sf.stateful = has_state
        if cache is not None:
            cache[key] = sf
        fns.append(sf)
    return fns


# --------------------------------------------------------------------------- #
# The built pipeline (deployable artifact)
# --------------------------------------------------------------------------- #
@dataclass
class BuiltPipeline:
    ir: CourierIR
    plan: PipelinePlan
    stage_fns: list[Callable]
    graph_inputs: list[str]                  # per-token inputs callers feed
    graph_outputs: list[str]
    max_in_flight: int | None = None         # TBB token-pool size
    # captured graph inputs (closure-held weights/constants discovered by the
    # Frontend): bound by the stage closures, never passed per token —
    # ``graph_inputs`` above already excludes them.
    captured: dict[str, Any] = field(default_factory=dict)
    # lazily built jit(vmap(stage)) executables, hoisted here (not on each
    # executor) so every executor over this pipeline shares one compiled set
    # — rebuilding an executor must not recompile in steady state.
    _batched_fns: list[Callable] | None = field(default=None, repr=False)

    # -- single token, through all stages (also the reference semantics) --- #
    def __call__(self, *args: Any):
        env = self._env_of(args)
        for fn in self.stage_fns:
            env = fn(env)
        return self._out_of(env)

    # -- token pipeline (paper Fig. 2) -------------------------------------- #
    def run(self, tokens: Iterable[tuple | Any]) -> list[Any]:
        """Wavefront token pipeline with a bounded token pool.

        Issues stage s for token k at wavefront step s+k; with JAX async
        dispatch, issued stages overlap exactly like TBB's thread pool.
        ``max_in_flight`` bounds live tokens (default: n_stages + 1, the
        double-buffering minimum).
        """
        toks = [t if isinstance(t, tuple) else (t,) for t in tokens]
        n = len(toks)
        S = len(self.stage_fns)
        pool = self._validated_pool()
        envs: dict[int, Any] = {}
        done: dict[int, Any] = {}
        next_tok = 0
        # stage index each in-flight token sits at
        at: dict[int, int] = {}
        while len(done) < n:
            # admit new tokens while the pool has room (serial_in_order entry)
            while next_tok < n and len(envs) < pool:
                envs[next_tok] = self._env_of(toks[next_tok])
                at[next_tok] = 0
                next_tok += 1
            # advance the *oldest* tokens first (keeps in-order completion)
            for k in sorted(envs):
                s = at[k]
                envs[k] = self.stage_fns[s](envs[k])
                at[k] = s + 1
                if at[k] == S:
                    done[k] = self._out_of(envs.pop(k))
                    at.pop(k)
        return [done[k] for k in range(n)]

    def run_sequential(self, tokens: Iterable[tuple | Any]) -> list[Any]:
        """No pipelining — the original binary's behavior (baseline)."""
        return [self(*t) if isinstance(t, tuple) else self(t) for t in tokens]

    # -- async executor (TBB parallel_pipeline analog) ----------------------- #
    def executor(self, *, max_in_flight: int | None = None,
                 microbatch: int = 1,
                 pad_microbatches: bool = False,
                 buckets: "Sequence[int] | None" = None,
                 profiler: Any = None, stage_workers: bool = False,
                 replicas: "Sequence[int] | None" = None,
                 devices: "Sequence[Sequence[int]] | None" = None,
                 inventory: Any = None, fault_injector: Any = None,
                 max_group_retries: int = 3, quarantine_after: int = 1,
                 retry_budget_ms: float | None = None,
                 open_groups: bool = False,
                 pad_token: tuple | None = None,
                 ) -> "PipelineExecutor":
        """Build a :class:`~repro.core.executor.PipelineExecutor` over the
        compiled stages (bounded token pool, eager async issue, optional
        per-stage micro-batching with bucketed ragged-group padding).
        ``max_in_flight`` defaults to this pipeline's own setting; the
        executor validates it (>= 1).  Executors built here share this
        pipeline's compiled (and vmapped) stage executables.  ``profiler``
        attaches a :class:`~repro.core.profiler.StageProfiler` for online
        per-stage times; ``stage_workers`` runs stages on dedicated
        threads (host-bound pipelines); ``replicas`` widens stages to the
        given per-stage worker counts (TBB parallel filters — see
        :func:`repro.core.partition.assign_replicas`); ``devices`` pins
        each replica to a device ordinal of ``inventory`` (the plan's
        :attr:`~repro.core.partition.PipelinePlan.stage_devices`);
        ``fault_injector`` / ``max_group_retries`` / ``quarantine_after``
        / ``retry_budget_ms`` configure the executor's fault-tolerance
        layer (see :mod:`repro.runtime.faults`); ``open_groups`` /
        ``pad_token`` enable continuous batching (in-flight seam
        admission — see :meth:`PipelineExecutor.try_join`)."""
        from .executor import PipelineExecutor
        return PipelineExecutor.from_pipeline(
            self, max_in_flight=max_in_flight, microbatch=microbatch,
            pad_microbatches=pad_microbatches, buckets=buckets,
            profiler=profiler, stage_workers=stage_workers,
            replicas=replicas, devices=devices, inventory=inventory,
            fault_injector=fault_injector,
            max_group_retries=max_group_retries,
            quarantine_after=quarantine_after,
            retry_budget_ms=retry_budget_ms,
            open_groups=open_groups, pad_token=pad_token)

    def run_async(self, tokens: Iterable[tuple | Any], *,
                  max_in_flight: int | None = None,
                  microbatch: int = 1) -> list[Any]:
        """Run a token stream through the asynchronous executor.

        Unlike :meth:`run` (the synchronous wavefront), every stage of an
        admitted token is issued immediately and the host blocks only when
        the token pool is full or at final retirement.  Results arrive in
        submission order, identical to :meth:`run`/:meth:`run_sequential`.
        """
        return self.executor(max_in_flight=max_in_flight,
                             microbatch=microbatch).run(tokens)

    def describe(self) -> str:
        return self.plan.describe()

    # -- compile accounting (zero-recompile steady state) ------------------- #
    def batched_stage_fns(self) -> list[Callable]:
        """Shared ``jit(vmap(stage))`` set for micro-batched execution.

        Built once per pipeline and handed to every executor, so executor
        churn (serving re-plans, pool resizes) never pays a recompile.
        """
        if self._batched_fns is None:
            self._batched_fns = [
                loop_batched(getattr(f, "raw", f))
                if getattr(f, "stateful", False)
                else jax.jit(jax.vmap(getattr(f, "raw", f)))
                for f in self.stage_fns]
        return self._batched_fns

    def compile_count(self) -> int:
        """Total executables compiled across all stage fns (incl. vmapped).

        Steady-state serving must hold this constant: after warmup, token
        waves of already-seen shapes re-enter cached executables only.
        """
        total = 0
        for f in self.stage_fns:
            total += getattr(f, "compiles", 0)
        if self._batched_fns is not None:
            for f in self._batched_fns:
                try:
                    total += f._cache_size()
                except AttributeError:
                    pass
        return total

    # -- helpers ------------------------------------------------------------ #
    def _validated_pool(self) -> int:
        """Token-pool size; ``max_in_flight=0`` is an error, not "unset"."""
        if self.max_in_flight is not None and self.max_in_flight < 1:
            raise ValueError(
                f"max_in_flight must be >= 1 (got {self.max_in_flight}); "
                "use None for the default pool of n_stages + 1")
        S = len(self.stage_fns)
        return self.max_in_flight if self.max_in_flight is not None else S + 1

    def _env_of(self, args: Sequence[Any]) -> dict:
        if len(args) != len(self.graph_inputs):
            raise ValueError(f"expected {len(self.graph_inputs)} inputs, "
                             f"got {len(args)}")
        return dict(zip(self.graph_inputs, args))

    def _out_of(self, env: dict):
        outs = tuple(env[o] if o in env else self.captured[o]
                     for o in self.graph_outputs)
        return outs[0] if len(outs) == 1 else outs


# --------------------------------------------------------------------------- #
# The generator itself (paper Step 8)
# --------------------------------------------------------------------------- #
class PipelineGenerator:
    """End-to-end: IR + database → BuiltPipeline."""

    def __init__(self, db: ModuleDatabase, cost_model: CostModel | None = None):
        self.db = db
        self.cost_model = cost_model

    def generate(self, ir: CourierIR, n_threads: int = 2,
                 policy: str = "paper", prefer_hw: bool = True,
                 fuse: bool = False,
                 fused_cost_ms: Callable[[list[Node]], float] | None = None,
                 max_stages: int | None = None,
                 comm_bw_bytes_per_ms: float | None = None,
                 jit: bool = True, donate: bool = True,
                 max_in_flight: int | None = None) -> BuiltPipeline:
        if self.cost_model is not None:
            self.cost_model.annotate(ir)
        assign_placements(ir, self.db, prefer_hw=prefer_hw)
        if fuse:
            # with no explicit estimator the *cost model* decides (fusions
            # that keep intermediates VMEM-resident win; spills rejected) —
            # the paper's fixed reject-policy becomes a modeled choice.
            ir = fuse_adjacent_hw(
                ir, self.db,
                fused_cost_ms=fused_cost_ms if fused_cost_ms is not None
                else "model")
            assign_placements(ir, self.db, prefer_hw=prefer_hw)
        if policy == "paper":
            plan = partition_paper(ir, n_threads=n_threads)
        elif policy == "optimal":
            plan = partition_optimal(ir, max_stages=max_stages,
                                     comm_bw_bytes_per_ms=comm_bw_bytes_per_ms)
        else:
            raise ValueError(f"unknown policy {policy!r}")
        # mandatory legality gate (REPRO_VERIFY=off to bypass): a malformed
        # plan must fail here, not as a wrong answer under traffic.  Lazy
        # import — analysis sits above core in the layering.
        from repro.analysis.verify import check_plan
        check_plan(ir, plan, db=self.db, where="PipelineGenerator.generate")
        fns = make_stage_fns(ir, self.db, plan, jit=jit, donate=donate)
        cap = dict(getattr(ir, "captured", {}))
        token_inputs = [g for g in ir.graph_inputs if g not in cap]
        return BuiltPipeline(ir=ir, plan=plan, stage_fns=fns,
                             graph_inputs=token_inputs,
                             graph_outputs=list(ir.graph_outputs),
                             max_in_flight=max_in_flight, captured=cap)
