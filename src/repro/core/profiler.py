"""Online stage profiler — the Frontend's runtime profile, kept live.

Courier-FPGA "gathers runtime information of library functions from a
running target binary" and feeds those *measured* times to the Pipeline
Generator.  The seed reproduction only did that once, at trace time; this
module keeps the measurement loop running while the pipeline serves
traffic, so the planner can re-balance when reality drifts from the model
(a stage slows down, a fused kernel underperforms its roofline, the host
gets noisy neighbors).

:class:`StageProfiler` is attached to a
:class:`~repro.core.executor.PipelineExecutor` and fed per-stage wall times
from its issue/retire hooks:

* **threaded stage-worker mode** times every stage invocation exactly (each
  stage runs to completion inside its own worker);
* **async-dispatch mode** samples: every ``sample_every``-th token group is
  issued with a blocking barrier after each stage, so steady-state traffic
  pays the measurement cost only at the sampling rate.

Per stage it maintains an **EMA** (fast trend signal) and a bounded
**percentile window** (robust location — the median is what re-planning
uses, so a single straggler sample can't trigger a spurious re-plan).

:meth:`apply_to_ir` closes the loop: measured stage times are written back
into the IR's per-node ``time_ms`` (attributed proportionally to the nodes'
prior estimates), marked ``time_source="profile"`` so they *supersede*
roofline estimates everywhere downstream (``assign_placements`` will not
overwrite a profiled time with a synthesis-report estimate).
"""
from __future__ import annotations

import threading
from collections import deque
from typing import TYPE_CHECKING

import numpy as np

if TYPE_CHECKING:                                    # pragma: no cover
    from .ir import CourierIR
    from .partition import PipelinePlan

__all__ = ["StageProfiler"]


class StageProfiler:
    """Low-overhead per-stage wall-time profile (EMA + percentile window).

    Parameters
    ----------
    n_stages:
        Number of pipeline stages to track.
    alpha:
        EMA smoothing factor (weight of the newest sample).
    window:
        Bounded sample window per stage; percentiles/medians are computed
        over it, so the memory cost is ``n_stages * window`` floats.
    sample_every:
        In async-dispatch mode, profile every ``sample_every``-th token
        group (1 = every group).  A sampled group is issued with a
        blocking barrier per stage — i.e. it loses its async overlap — so
        the default keeps sampling sparse (1 in 8); lower it only for
        pipelines whose stages are host-bound anyway.  Threaded stage
        workers ignore this — their timing is free.
    min_samples:
        Minimum per-stage samples before :meth:`measured_ms` (and hence
        re-planning) trusts the window.
    """

    def __init__(self, n_stages: int, *, alpha: float = 0.25,
                 window: int = 64, sample_every: int = 8,
                 min_samples: int = 4):
        if n_stages < 1:
            raise ValueError(f"n_stages must be >= 1 (got {n_stages})")
        if not 0.0 < alpha <= 1.0:
            raise ValueError(f"alpha must be in (0, 1] (got {alpha})")
        if window < 1:
            raise ValueError(f"window must be >= 1 (got {window})")
        if sample_every < 1:
            raise ValueError(f"sample_every must be >= 1 (got {sample_every})")
        self.n_stages = n_stages
        self.alpha = float(alpha)
        self.window = int(window)
        self.sample_every = int(sample_every)
        self.min_samples = int(min_samples)
        self._ema: list[float | None] = [None] * n_stages
        self._win: list[deque] = [deque(maxlen=window) for _ in range(n_stages)]
        self._count = [0] * n_stages
        self._ticks = 0
        self._lock = threading.Lock()
        # per-(stage, replica) attribution for replicated stages:
        # (stage, replica) -> [count, ema]; populated only when the
        # executor reports a replica index
        self._replica: dict[tuple[int, int], list] = {}
        # per-(stage, device-ordinal) attribution for device-pinned
        # replicas: (stage, device) -> [count, ema]; populated only when
        # the executor reports a device ordinal, so snapshots show which
        # chip served the stage (and which chip is the straggler)
        self._device: dict[tuple[int, int], list] = {}
        # stage-call failures, attributed like the timings: the elastic
        # replanner reads these (with device_ms) to de-weight an unhealthy
        # device instead of re-widening onto it
        self._errors: list[int] = [0] * n_stages
        self._device_errors: dict[int, int] = {}
        # seam occupancy (continuous batching): EMA of the fill fraction
        # each group sealed with (real seats / total rows) plus a seal
        # count — low fill = admission leaves seats on the table, the
        # signal the serving layer's seam-aware predicted wait reads
        self._seam_fill: float | None = None
        self._seam_seals = 0

    def clone_for(self, n_stages: int) -> "StageProfiler":
        """Fresh profiler with the same knobs for a re-planned stage count."""
        return StageProfiler(n_stages, alpha=self.alpha, window=self.window,
                             sample_every=self.sample_every,
                             min_samples=self.min_samples)

    # -- executor-side hooks -------------------------------------------------- #
    def tick(self) -> bool:
        """Admission-side sampling gate: True every ``sample_every``-th call."""
        with self._lock:
            t = self._ticks
            self._ticks += 1
        return t % self.sample_every == 0

    def record(self, stage: int, ms: float, replica: int | None = None,
               device: int | None = None) -> None:
        """Record one measured wall time (ms) for ``stage``.

        ``replica`` (replicated-stage executors) additionally attributes
        the sample to that worker, so a straggling replica — one slow
        thread among N serving a widened stage — is visible in
        :meth:`snapshot` instead of being averaged away; ``device``
        (device-pinned replicas) attributes it to the chip/core that ran
        it, so per-device service times land in the same snapshot.  The
        per-stage aggregate (what re-planning reads) always measures the
        *service* time of one token group, whichever replica ran it.
        """
        if not 0 <= stage < self.n_stages:
            raise IndexError(f"stage {stage} out of range [0, {self.n_stages})")
        ms = float(ms)
        with self._lock:
            prev = self._ema[stage]
            self._ema[stage] = ms if prev is None \
                else (1.0 - self.alpha) * prev + self.alpha * ms
            self._win[stage].append(ms)
            self._count[stage] += 1
            for table, idx in ((self._replica, replica),
                               (self._device, device)):
                if idx is None:
                    continue
                rec = table.setdefault((stage, int(idx)), [0, None])
                rec[0] += 1
                rec[1] = ms if rec[1] is None \
                    else (1.0 - self.alpha) * rec[1] + self.alpha * ms

    def record_seam(self, filled: int, capacity: int) -> None:
        """Record one sealed group's seam occupancy (continuous batching):
        ``filled`` real seats out of ``capacity`` stacked rows."""
        if capacity <= 0:
            return
        frac = min(max(filled / capacity, 0.0), 1.0)
        with self._lock:
            self._seam_fill = frac if self._seam_fill is None \
                else (1.0 - self.alpha) * self._seam_fill + self.alpha * frac
            self._seam_seals += 1

    def seam_fill(self) -> float | None:
        """EMA seam fill fraction (None before any group sealed)."""
        with self._lock:
            return self._seam_fill

    def record_error(self, stage: int, replica: int | None = None,
                     device: int | None = None) -> None:
        """Record one failed stage call (the timing never lands — the call
        raised — so errors are counted separately from the samples)."""
        if not 0 <= stage < self.n_stages:
            raise IndexError(f"stage {stage} out of range [0, {self.n_stages})")
        del replica  # reserved for symmetry with record(); not tabulated yet
        with self._lock:
            self._errors[stage] += 1
            if device is not None:
                d = int(device)
                self._device_errors[d] = self._device_errors.get(d, 0) + 1

    # -- queries --------------------------------------------------------------- #
    def samples(self, stage: int) -> int:
        with self._lock:
            return self._count[stage]

    def ema_ms(self, stage: int) -> float | None:
        with self._lock:
            return self._ema[stage]

    def percentile_ms(self, stage: int, q: float = 50.0) -> float | None:
        with self._lock:
            win = list(self._win[stage])
        if not win:
            return None
        return float(np.percentile(np.asarray(win, dtype=np.float64), q))

    def measured_ms(self, stage: int) -> float | None:
        """Robust per-stage location: the window median, once ``min_samples``
        samples exist.  Medians (not EMAs) drive re-planning so one
        straggler sample cannot flip a plan."""
        if self.samples(stage) < self.min_samples:
            return None
        return self.percentile_ms(stage, 50.0)

    def replica_ms(self, stage: int) -> dict[int, float]:
        """Per-replica EMA wall times for one stage (replicated executors).

        Empty for stages that never reported a replica index.  This is
        *service* time per replica — the planner divides the stage median
        by the replica count for throughput, but a per-replica spread here
        flags a straggling worker thread.
        """
        with self._lock:
            return {w: rec[1] for (s, w), rec in self._replica.items()
                    if s == stage and rec[1] is not None}

    def device_ms(self, stage: int) -> dict[int, float]:
        """Per-device EMA wall times for one stage (device-pinned replicas).

        Empty for stages whose samples never carried a device ordinal.
        Heterogeneous entries here mean the widened stage's chips are not
        pulling equally — the device-level analog of :meth:`replica_ms`.
        """
        with self._lock:
            return {d: rec[1] for (s, d), rec in self._device.items()
                    if s == stage and rec[1] is not None}

    def error_count(self, stage: int) -> int:
        with self._lock:
            return self._errors[stage]

    def device_errors(self) -> dict[int, int]:
        """Failed stage calls per device ordinal (all stages pooled) —
        the error half of the replanner's unhealthy-device signal."""
        with self._lock:
            return dict(self._device_errors)

    @property
    def ready(self) -> bool:
        """True once every stage has ``min_samples`` measurements."""
        return all(self._count[k] >= self.min_samples
                   for k in range(self.n_stages))

    def effective_period_ms(self, replicas: "Sequence[int] | None" = None,
                            ) -> float | None:
        """Measured steady-state token period of the running pipeline.

        The replication-aware bottleneck
        (:func:`~repro.core.costmodel.replicated_bottleneck_ms`) over the
        per-stage window **medians** — the measured analog of
        ``plan.effective_bottleneck_ms``, and the service-period input to
        the serving layer's admission controller (predicted queue wait =
        dispatch groups ahead x this period).  ``None`` until every stage
        has ``min_samples`` measurements, so admission keeps using the
        plan's model until the profile can stand on its own.
        """
        from .costmodel import replicated_bottleneck_ms

        meds = [self.measured_ms(k) for k in range(self.n_stages)]
        if any(m is None for m in meds):
            return None
        reps = list(replicas) if replicas is not None else [1] * self.n_stages
        if len(reps) != self.n_stages:
            return None
        return replicated_bottleneck_ms(meds, reps)

    def snapshot(self) -> dict:
        """Machine-readable per-stage profile (for stats endpoints)."""
        stages = []
        for k in range(self.n_stages):
            entry = {
                "samples": self.samples(k),
                "ema_ms": _round(self.ema_ms(k)),
                "p50_ms": _round(self.percentile_ms(k, 50.0)),
                "p90_ms": _round(self.percentile_ms(k, 90.0)),
            }
            with self._lock:
                reps = {str(w): {"samples": rec[0], "ema_ms": _round(rec[1])}
                        for (s, w), rec in sorted(self._replica.items())
                        if s == k}
                devs = {str(d): {"samples": rec[0], "ema_ms": _round(rec[1])}
                        for (s, d), rec in sorted(self._device.items())
                        if s == k}
            if reps:
                entry["replicas"] = reps
            if devs:
                entry["devices"] = devs
            if self.error_count(k):
                entry["errors"] = self.error_count(k)
            stages.append(entry)
        out = {"n_stages": self.n_stages, "sample_every": self.sample_every,
               "window": self.window, "per_stage": stages}
        with self._lock:
            if self._seam_seals:
                out["seam"] = {"fill_ema": _round(self._seam_fill),
                               "seals": self._seam_seals}
        return out

    def reset(self) -> None:
        with self._lock:
            self._ema = [None] * self.n_stages
            self._win = [deque(maxlen=self.window)
                         for _ in range(self.n_stages)]
            self._count = [0] * self.n_stages
            self._ticks = 0
            self._replica.clear()
            self._device.clear()
            self._errors = [0] * self.n_stages
            self._device_errors.clear()
            self._seam_fill = None
            self._seam_seals = 0

    # -- cost-model write-back -------------------------------------------------- #
    def apply_to_ir(self, ir: "CourierIR", plan: "PipelinePlan", *,
                    min_samples: int | None = None) -> dict[str, float]:
        """Write measured stage times back into the IR as per-node costs.

        For every stage with a trusted measurement, the stage's wall time is
        attributed to its nodes proportionally to their *prior* ``time_ms``
        (uniformly when no priors exist), and each updated node is marked
        ``time_source="profile"`` so downstream estimators never overwrite
        the measurement with a model.  Returns ``{node_name: previous
        time_ms}`` for every node updated (the planner uses it to detect
        measured-vs-model contradictions).
        """
        need = self.min_samples if min_samples is None else min_samples
        replaced: dict[str, float] = {}
        for k, s in enumerate(plan.stages):
            if k >= self.n_stages or self.samples(k) < need:
                continue
            m = self.percentile_ms(k, 50.0)
            if m is None:
                continue
            nodes = [ir.node(nn) for nn in s.node_names]
            priors = [n.time_ms for n in nodes]
            # proportional attribution needs a full, positive prior vector;
            # otherwise fall back to uniform — attributing 0 ms to a
            # None-prior node would pin it as a "measured" free node that
            # no estimator may ever correct
            total = sum(p for p in priors if p is not None)
            proportional = all(p is not None for p in priors) and total > 0
            for n, prior in zip(nodes, priors):
                share = (prior / total) if proportional else 1.0 / len(nodes)
                replaced[n.name] = prior if prior is not None else 0.0
                n.time_ms = m * share
                n.time_source = "profile"
        return replaced


def _round(x: float | None, nd: int = 4) -> float | None:
    return None if x is None else round(float(x), nd)
