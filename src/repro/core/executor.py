"""Asynchronous token-pipeline executor (TBB ``parallel_pipeline`` analog).

:class:`BuiltPipeline.run` emulates TBB's token pipeline with a *synchronous
wavefront*: a Python loop that advances every in-flight token by one stage
per host step.  That keeps tokens ordered but serializes the host around the
wavefront schedule.  This module replaces it with a true asynchronous
executor that leans on JAX's async dispatch the way TBB leans on its thread
pool:

* **Eager issue** — when a token is admitted, *all* of its stage calls are
  issued immediately.  Each jitted stage returns future-backed arrays, so
  stage ``s+1`` is enqueued on the device stream as soon as stage ``s``'s
  output futures exist; the host never blocks between stages.  Work for
  token ``k+1`` is therefore issued while token ``k`` is still executing —
  the paper's "Task #0 can take the second input while Task #1 is
  processing".
* **Bounded token pool** — at most ``max_in_flight`` tokens are
  issued-but-unretired at any moment (TBB's token pool; default
  ``n_stages + 1``, the double-buffering minimum).  Admission blocks on the
  *oldest* token's final outputs when the pool is full, which is also the
  serving layer's backpressure mechanism.  ``max_in_flight`` must be >= 1;
  ``0`` is rejected rather than silently treated as "unset".
* **Per-stage micro-batching** — consecutive tokens whose input
  shapes/dtypes agree can be stacked along a new leading axis and pushed
  through ``jax.vmap``-ed stage functions as one group, amortizing dispatch
  overhead (``microbatch=m``).  Results are unstacked at retirement, so the
  API is token-in/token-out either way.
* **Counters** — per-stage issue counts/host-issue time and pool occupancy
  are tracked continuously; :meth:`PipelineExecutor.stats` exposes
  throughput and occupancy for the serving layer's metrics endpoint.
* **Online profiling** — an attached
  :class:`~repro.core.profiler.StageProfiler` is fed measured per-stage
  wall times: exactly in threaded mode, by sampled blocking barriers in
  async mode (every ``profiler.sample_every``-th group), so the adaptive
  re-planner always has live costs without stalling steady-state traffic.
* **Threaded stage workers** (``stage_workers=True``) — one serial worker
  thread per stage, TBB's actual execution model.  Each admitted group's
  stage ``s`` runs to completion inside worker ``s`` and hands its env to
  worker ``s+1``; host-bound stages (callbacks, eager sw fallbacks) then
  overlap across *threads* instead of relying on device async dispatch,
  which on CPU backends provides no inter-stage overlap at all.
* **Replicated stages** (``replicas=[r0, r1, ...]``) — TBB's *parallel*
  filter kind: stage ``s`` runs ``r_s`` worker threads, so a stage that
  dominates the token period can be *widened* instead of only re-balanced.
  The dataflow is a sequence-numbered ring per replica: admitted groups
  get a monotonically increasing sequence number; replica ``w`` of a stage
  with ``r`` replicas owns the seqs ``w, w+r, w+2r, ...`` and consumes
  them in that order from a preallocated slot ring (each seq has exactly
  one producer — the upstream worker that finished it — so slots are
  single-producer/single-consumer and the hand-off cost is one flag flip,
  not a queue mutation).  Envs ride through the stages unmodified (no
  per-group dict rebuilds on the steady path) and are handed off with no
  retained references, so :class:`~repro.core.pipeline.StageFn` buffer
  donation stays safe.  A reorder buffer at retirement — the in-order
  ``_inflight`` deque plus each group's completion event — guarantees
  tokens retire in submission order even when replicas finish out of
  order; ``ExecutorStats.out_of_order_retired`` asserts it stayed zero.

* **Replica quarantine + bounded retry** — a stage exception on a
  *replicated* stage no longer errors the group.  The failing worker
  retries the group (locally for transients, on a sibling after
  quarantine), bounded by ``max_group_retries`` per group and
  ``retry_budget_ms`` since admission.  A replica whose error count
  reaches ``quarantine_after`` is **quarantined**: its ring is drained,
  its seq-residue ownership is redistributed to healthy siblings (the
  per-stage owner map, rewritten under the stage's route lock so no
  hand-off is lost), and its worker thread exits — in-order retirement is
  preserved throughout because the reorder buffer never changed.  The
  LAST healthy replica of a stage is never quarantined, and unreplicated
  stages keep the error-the-group behavior, so failures are never
  silently swallowed.  ``ExecutorStats.retries``/``quarantined`` count
  the recoveries.  Scripted faults come from a
  :class:`~repro.runtime.faults.FaultInjector` hooked in front of every
  stage body (``fault_injector=``); injection happens BEFORE the stage
  function runs, so a retried injected fault never re-executes a
  half-donated buffer (a real mid-execution failure that already donated
  its buffers will surface on the retry and error the group — degraded,
  not wrong).

Completion is in-order (tokens retire oldest-first), matching the paper's
``serial_in_order`` first/last filters.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from concurrent.futures import Future, ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Any, Callable, Iterable, Sequence

import jax
import jax.numpy as jnp

__all__ = ["PipelineExecutor", "ExecutorStats", "StageCounters",
           "PendingToken", "SubmitError", "ExecutorClosed"]


class ExecutorClosed(RuntimeError):
    """Submission raced (or followed) :meth:`PipelineExecutor.close`.

    Raised instead of hanging: a submitter blocked on token-pool
    backpressure when ``close()`` lands would otherwise be admitted into
    already-closed replica rings, whose completion event never fires.
    ``close()`` publishes ``closed`` under the executor lock *before*
    draining, and the admission loop re-checks it under the same lock, so
    every group that wins admission is visible to close's drain and every
    loser gets this exception — never a silent drop.
    """


class SubmitError(RuntimeError):
    """A submit_many call failed after part of the stream was admitted.

    ``handles`` are PendingTokens for the prefix of the token stream that
    WAS issued (possibly empty); everything from index ``len(handles)``
    onward was not admitted.  ``__cause__`` carries the original error.
    """

    def __init__(self, msg: str, handles: list["PendingToken"]):
        super().__init__(msg)
        self.handles = handles


# --------------------------------------------------------------------------- #
# Counters
# --------------------------------------------------------------------------- #
@dataclass
class StageCounters:
    """Per-stage issue-side counters (host view; device time is async)."""

    issued: int = 0        # stage invocations (one per token group)
    tokens: int = 0        # tokens pushed through this stage
    errors: int = 0        # stage-call failures (pre-retry; see retries)
    issue_ms: float = 0.0  # host time spent dispatching this stage
    # measured stage-body wall time (threaded/sampled only); disjoint from
    # xfer_ms — exec_ms + xfer_ms is the stage's full service time
    exec_ms: float = 0.0
    xfer_ms: float = 0.0   # host time staging groups onto pinned devices
    replicas: int = 1      # worker threads serving this stage
    # CONFIGURED per-replica device ordinals (empty = unpinned).  This
    # echoes the plan; when the executor degraded to a single device the
    # pinning is not in effect (xfer_ms stays 0 and profiler samples carry
    # no device ordinal).
    devices: list = field(default_factory=list)

    def as_dict(self) -> dict:
        return {"issued": self.issued, "tokens": self.tokens,
                "errors": self.errors,
                "issue_ms": round(self.issue_ms, 4),
                "exec_ms": round(self.exec_ms, 4),
                "xfer_ms": round(self.xfer_ms, 4),
                "replicas": self.replicas,
                "devices": list(self.devices)}


@dataclass
class ExecutorStats:
    """Snapshot of executor activity since construction (or ``reset``)."""

    per_stage: list[StageCounters] = field(default_factory=list)
    tokens_admitted: int = 0
    tokens_retired: int = 0
    groups_admitted: int = 0
    max_in_flight_seen: int = 0
    occupancy_samples: int = 0
    occupancy_sum: int = 0
    wall_ms: float = 0.0           # accumulated blocking run() wall time
    out_of_order_retired: int = 0  # groups retired out of submission order
    tokens_failed: int = 0         # tokens retired carrying an error
    retries: int = 0               # failed stage calls re-executed
    quarantined: int = 0           # replicas evicted after repeated errors
    seam_joins: int = 0            # tokens admitted into in-flight groups
    seam_evictions: int = 0        # seats evicted before their group sealed
    # failed stage calls per CONFIGURED device ordinal — the replanner's
    # unhealthy-device signal (populated only for device-placed replicas)
    device_errors: dict = field(default_factory=dict)
    quarantined_replicas: list = field(default_factory=list)  # (stage, w)

    @property
    def mean_occupancy(self) -> float:
        if not self.occupancy_samples:
            return 0.0
        return self.occupancy_sum / self.occupancy_samples

    @property
    def throughput_tps(self) -> float:
        """Retired tokens per second over the accumulated ``run`` wall time."""
        if self.wall_ms <= 0:
            return 0.0
        return self.tokens_retired / (self.wall_ms / 1e3)

    def as_dict(self) -> dict:
        return {
            "tokens_admitted": self.tokens_admitted,
            "tokens_retired": self.tokens_retired,
            "groups_admitted": self.groups_admitted,
            "max_in_flight_seen": self.max_in_flight_seen,
            "out_of_order_retired": self.out_of_order_retired,
            "tokens_failed": self.tokens_failed,
            "retries": self.retries,
            "quarantined": self.quarantined,
            "seam_joins": self.seam_joins,
            "seam_evictions": self.seam_evictions,
            "device_errors": {str(k): v
                              for k, v in sorted(self.device_errors.items())},
            "quarantined_replicas": [list(t)
                                     for t in self.quarantined_replicas],
            "mean_occupancy": round(self.mean_occupancy, 3),
            "wall_ms": round(self.wall_ms, 3),
            "throughput_tps": round(self.throughput_tps, 2),
            "per_stage": [s.as_dict() for s in self.per_stage],
        }


# --------------------------------------------------------------------------- #
# Token signatures (micro-batch grouping)
# --------------------------------------------------------------------------- #
# python scalars have a fixed promoted dtype per type; cache it once instead
# of paying a jnp.result_type dispatch per token arg on the admit path
_SCALAR_SIG: dict[type, tuple] = {}


def _sig_of(args: tuple) -> tuple:
    """Shape/dtype signature of one token, off the jnp dispatch path.

    Arrays (jax/numpy) expose ``shape``/``dtype`` as cached attributes —
    reading them is orders of magnitude cheaper than ``jnp.shape`` +
    ``jnp.result_type``, which the admit loop previously paid per arg per
    token (the dominant per-token overhead of async mode vs the wavefront).
    """
    sig = []
    for a in args:
        try:
            sig.append((a.shape, a.dtype))
        except AttributeError:
            t = type(a)
            s = _SCALAR_SIG.get(t)
            if s is None or not isinstance(a, (bool, int, float, complex)):
                s = (tuple(jnp.shape(a)), jnp.result_type(a))
                if isinstance(a, (bool, int, float, complex)):
                    _SCALAR_SIG[t] = s
            sig.append(s)
    return tuple(sig)


# --------------------------------------------------------------------------- #
# In-flight bookkeeping
# --------------------------------------------------------------------------- #
class _Group:
    """One admitted token group: a (possibly stacked) env fully issued."""

    __slots__ = ("env", "size", "stacked", "results", "done", "error", "lock",
                 "future", "seq", "fns", "evt", "retries", "t_admit",
                 "sealed", "rows", "sig", "evicted")

    def __init__(self, env: dict | None, size: int, stacked: bool):
        self.env = env                # None until all stages are issued
        self.size = size              # real tokens (padding rows excluded)
        self.stacked = stacked
        self.results: list[Any] | None = None
        self.done = False
        self.error: BaseException | None = None   # stage issue failed
        self.lock = threading.Lock()  # serializes issue + finalization
        self.future: Future | None = None  # last-stage future (threaded mode)
        self.seq: int | None = None   # admission sequence (replicated mode)
        self.fns: tuple | None = None  # resolved stage fns (replicated mode)
        self.evt: threading.Event | None = None  # completion (replicated mode)
        self.retries = 0              # failed stage calls re-executed
        self.t_admit = time.perf_counter()  # retry_budget_ms anchor
        # --- continuous-batching seam state (open_groups mode) ---
        # sealed flips True (under the EXECUTOR lock) the instant a stage-0
        # worker claims the group; joins/evictions are only legal before.
        self.sealed = True
        self.rows = size              # stacked rows incl. padding seats
        self.sig: tuple | None = None  # token signature (join compat check)
        # row idx -> error for seats evicted at the seam; the row still
        # flows (as a dead pad row) and result() raises the stored error
        self.evicted: dict[int, BaseException] = {}


class _SeqRing:
    """Sequence-indexed mailbox feeding ONE replica of ONE stage.

    A ring owns a set of seq RESIDUES (mod the stage width ``r``) and
    consumes each residue's seqs strictly in order.  At construction
    replica ``w`` owns exactly residue ``w`` — group sequence numbers
    ``w, w+r, w+2r, ...`` — and every seq has exactly one producer (the
    upstream worker that completed it), so the hand-off is an SPSC dict
    insert + flag flip; the token envs ride on the group object, so the
    steady path moves one reference, never rebuilds a dict.  The mailbox
    is unbounded but in practice holds at most the token pool (admission
    bounds the in-flight seq span).

    Quarantine is why residues are a *set*: when a sibling replica is
    evicted, this ring :meth:`adopt`\\ s the failed replica's residues
    (with their next-expected seqs) and its undelivered groups are
    re-:meth:`put` here, so the adopted residues resume exactly where the
    failed worker stopped — no seq is skipped, none runs twice.
    """

    __slots__ = ("stride", "slots", "cond", "next", "closed")

    def __init__(self, stride: int, first_seq: int):
        self.stride = stride
        # residue -> next owned seq to consume (starts owning one residue)
        self.next: dict[int, int] = {first_seq % max(stride, 1): first_seq}
        self.slots: dict[int, "_Group"] = {}
        self.cond = threading.Condition(threading.Lock())
        self.closed = False

    def put(self, seq: int, group: "_Group") -> bool:
        """False when the ring is closed (the group was NOT enqueued) —
        callers must fail the group rather than wait on an event no
        worker will ever set."""
        with self.cond:
            if self.closed:
                return False
            self.slots[seq] = group
            self.cond.notify_all()
            return True

    def pop(self) -> "tuple[int, _Group] | None":
        """Block for the next owned seq of any owned residue; ``None``
        once closed."""
        with self.cond:
            while True:
                for res, nxt in self.next.items():
                    g = self.slots.pop(nxt, None)
                    if g is not None:
                        self.next[res] = nxt + self.stride
                        return nxt, g
                if self.closed:
                    return None
                self.cond.wait()

    def adopt(self, residue: int, next_seq: int) -> None:
        """Take ownership of a quarantined sibling's residue, resuming at
        ``next_seq`` (the sibling's consumption watermark)."""
        with self.cond:
            self.next[residue] = next_seq
            self.cond.notify_all()

    def retire(self) -> "tuple[dict[int, _Group], dict[int, int]]":
        """Close the ring and hand back its undelivered groups and
        residue watermarks — the quarantine path re-routes both."""
        with self.cond:
            self.closed = True
            slots, nxt = dict(self.slots), dict(self.next)
            self.slots.clear()
            self.cond.notify_all()
            return slots, nxt

    def close(self) -> None:
        with self.cond:
            self.closed = True
            self.cond.notify_all()


class PendingToken:
    """Future-like handle for one submitted token (in-order completion)."""

    __slots__ = ("_executor", "_group", "_idx")

    def __init__(self, executor: "PipelineExecutor", group: _Group, idx: int):
        self._executor = executor
        self._group = group
        self._idx = idx

    def done(self) -> bool:
        return self._group.done

    def result(self) -> Any:
        """Block until this token's final outputs are ready and return them."""
        self._executor._retire_through(self._group)
        if self._idx in self._group.evicted:
            raise self._group.evicted[self._idx]
        if self._group.error is not None:
            raise self._group.error
        return self._group.results[self._idx]


# --------------------------------------------------------------------------- #
# The executor
# --------------------------------------------------------------------------- #
class PipelineExecutor:
    """Async token-pipeline executor over compiled stage functions.

    Parameters
    ----------
    stage_fns:
        One callable per stage, ``dict(live-in) -> dict(live-out)`` (the
        output of :func:`repro.core.pipeline.make_stage_fns`).
    graph_inputs / graph_outputs:
        Value names binding positional token args to the stage-0 env and the
        final env to results.
    max_in_flight:
        Token-pool bound (>= 1).  ``None`` defaults to ``n_stages + 1``.
    microbatch:
        Max tokens stacked into one group when their shapes/dtypes agree
        (1 disables batching).  Groups never exceed the pool size.
    pad_microbatches:
        When True, ragged groups (size < ``microbatch``) are padded by
        repeating the last token, so the vmapped stage executables compile
        for a closed set of leading-axis sizes — serving loops use this to
        keep partial batches off the compile path.  Padding rows are
        dropped at retirement.  Singleton groups are exempt: they take the
        per-token executables (always warmed) directly, skipping the
        stack/unstack round-trip and the padded compute.
    buckets:
        With ``pad_microbatches``, the closed set of group sizes to pad up
        to (e.g. ``(1, 2, 4, 8)``).  A ragged group is padded to the
        smallest bucket that fits instead of all the way to ``microbatch``,
        so steady-state serving compiles one executable per bucket and pads
        far fewer wasted rows.  ``None`` keeps the pad-to-max behavior.
        Bucket sizes above ``microbatch`` are ignored; ``microbatch``
        itself is always an implicit final bucket.
    batched_fns:
        Pre-built ``jit(vmap(stage))`` list to *share* across executors
        (see ``BuiltPipeline.batched_stage_fns``).  When ``None`` the
        executor builds its own lazily.
    profiler:
        Optional :class:`~repro.core.profiler.StageProfiler` fed measured
        per-stage wall times (every stage call in threaded mode; every
        ``profiler.sample_every``-th group via a blocking barrier in async
        mode).  ``warmup`` suspends it so compile time never pollutes the
        profile.
    stage_workers:
        Run each stage in its own serial worker thread (the TBB execution
        model): stage ``s+1`` of a group starts when stage ``s`` finished,
        and different stages overlap across OS threads.  Use for pipelines
        whose stage time is host-bound (eager sw fallbacks, callbacks) —
        JAX async dispatch alone gives those zero overlap on CPU.
    replicas:
        Per-stage worker counts (TBB's *parallel* filters): stage ``s``
        runs on ``replicas[s]`` threads fed by sequence-numbered
        SPSC-per-replica rings, with a reorder buffer guaranteeing
        in-order retirement (see module docstring).  Implies the threaded
        execution model; ``stage_workers`` is ignored when given.  Use
        :func:`repro.core.partition.assign_replicas` to pick the factors
        from measured stage costs.  All-ones is the serial threaded model
        on the ring dataflow.
    devices:
        Per-stage per-replica device ordinals (the planner's
        :meth:`~repro.core.partition.PipelinePlan.stage_devices`): replica
        ``w`` of stage ``s`` ``jax.device_put``\\ s its slot-ring groups
        onto device ``devices[s][w]`` before running the stage, so a
        widened stage's replicas execute on N distinct chips/cores — the
        thread-pool widening becomes real multi-device parallelism (the
        jitted stage compiles one executable per device it runs on, keyed
        by the committed inputs).  Requires ``replicas``; row ``s`` must
        have ``replicas[s]`` entries.  When every ordinal maps to one
        device (single-device hosts, planning-only inventories) the
        staging hop is skipped entirely — today's behavior.
    inventory:
        The :class:`~repro.core.placement.DeviceInventory` that maps
        ordinals to ``jax.Device`` objects; defaults to
        ``DeviceInventory.detect()`` when ``devices`` is given.
    fault_injector:
        Optional :class:`~repro.runtime.faults.FaultInjector` called in
        front of every stage body (all execution modes).  Injected faults
        take the same recovery path as real stage exceptions.
    max_group_retries:
        Retry budget per group across all stages (replicated mode only):
        a group whose stage calls failed this many times errors instead
        of retrying again.
    quarantine_after:
        Errors a single replica may absorb before it is quarantined and
        its seq ownership moves to healthy siblings (default 1: the first
        failure evicts).  The last healthy replica of a stage is never
        quarantined.
    retry_budget_ms:
        Deadline bound on retries: once a group has been in flight this
        long, a failing stage call errors the group instead of retrying —
        late work is degraded, not re-queued forever.  ``None`` (default)
        leaves retries bounded only by ``max_group_retries``.
    open_groups:
        **Continuous batching.**  Admitted groups stay *open* while they
        sit in the stage-0 mailbox: :meth:`try_join` can claim their
        padding seats for newly-arrived tokens, and :meth:`try_evict` can
        turn a seat into a dead row, until the stage-0 worker *seals* the
        group the instant it claims it.  Padding seats are what make this
        free: groups pad to a bucket size anyway (the singleton exemption
        is disabled so EVERY group is stacked to a bucket), so a join
        rewrites a pad row in place — same shapes, same warmed
        executables, zero new compiles.  Requires replicated mode
        (``replicas=``; the seam IS the ring-residency window),
        ``pad_microbatches`` and ``microbatch > 1``.
    pad_token:
        Neutral token substituted into padding rows instead of repeating
        the last real token (one value per graph input).  Required with
        ``open_groups`` when a stage is stateful: a repeated row would
        replay its slot mutation, double-writing a live request's cache,
        and an evicted seat must read as dead.  Use slot id ``-1`` (the
        KV pool's dead row) and zeros for the array operands.
    """

    def __init__(self, stage_fns: Sequence[Callable],
                 graph_inputs: Sequence[str], graph_outputs: Sequence[str],
                 *, max_in_flight: int | None = None, microbatch: int = 1,
                 pad_microbatches: bool = False,
                 buckets: Sequence[int] | None = None,
                 batched_fns: Sequence[Callable] | None = None,
                 profiler: Any = None, stage_workers: bool = False,
                 replicas: Sequence[int] | None = None,
                 devices: Sequence[Sequence[int]] | None = None,
                 inventory: Any = None, fault_injector: Any = None,
                 max_group_retries: int = 3, quarantine_after: int = 1,
                 retry_budget_ms: float | None = None,
                 open_groups: bool = False,
                 pad_token: tuple | None = None):
        if max_in_flight is not None and max_in_flight < 1:
            raise ValueError(
                f"max_in_flight must be >= 1 (got {max_in_flight}); "
                "use None for the default pool of n_stages + 1")
        if microbatch < 1:
            raise ValueError(f"microbatch must be >= 1 (got {microbatch})")
        self.stage_fns = list(stage_fns)
        self.graph_inputs = list(graph_inputs)
        self.graph_outputs = list(graph_outputs)
        self.replicas: list[int] | None = None
        if replicas is not None:
            reps = [int(r) for r in replicas]
            if len(reps) != len(self.stage_fns):
                raise ValueError(
                    f"replicas must name every stage: got {len(reps)} for "
                    f"{len(self.stage_fns)} stages")
            if any(r < 1 for r in reps):
                raise ValueError(f"replica counts must be >= 1 (got {reps})")
            self.replicas = reps
        self.devices: list[list[int]] | None = None
        self._replica_devs: list[list[Any]] | None = None
        if devices is not None:
            if self.replicas is None:
                raise ValueError("devices= requires replicas= (pass all-ones "
                                 "for a serial device-pinned pipeline)")
            devs = [[int(d) for d in row] for row in devices]
            if len(devs) != len(self.replicas) or any(
                    len(row) != r for row, r in zip(devs, self.replicas)):
                raise ValueError(
                    f"devices must carry one ordinal per replica per stage: "
                    f"got {[len(r) for r in devs]} for replicas "
                    f"{self.replicas}")
            self.devices = devs
            if inventory is None:
                from .placement import DeviceInventory
                inventory = DeviceInventory.detect()
            mapped = [[inventory.jax_device(d) for d in row] for row in devs]
            # single-device degrade: when every ordinal maps to one (or no)
            # jax device there is nothing to stage — skip the puts entirely
            distinct = {d for row in mapped for d in row if d is not None}
            self._replica_devs = mapped if len(distinct) > 1 else None
        if max_in_flight is not None:
            self.pool = max_in_flight
        elif self.replicas is not None:
            # widened stages need proportionally more in-flight tokens to
            # keep every replica busy (double-buffered worker count)
            self.pool = sum(self.replicas) + 1
        else:
            self.pool = len(self.stage_fns) + 1
        self.microbatch = min(microbatch, self.pool)
        self.pad_microbatches = pad_microbatches and self.microbatch > 1
        if buckets is not None:
            bs = sorted({int(b) for b in buckets
                         if 1 <= int(b) <= self.microbatch})
            # microbatch is the explicit final bucket, so _pad_for always
            # lands on a warmed size — never a silent new executable
            self.buckets: tuple[int, ...] | None = tuple(
                bs + ([self.microbatch] if (not bs or bs[-1] != self.microbatch)
                      else []))
        else:
            self.buckets = None
        self._batched_fns: list[Callable] | None = (
            list(batched_fns) if batched_fns is not None else None)
        self.profiler = profiler
        self.stage_workers = bool(stage_workers) and self.replicas is None
        self._pools: list[ThreadPoolExecutor] | None = None
        if self.stage_workers:
            # one SERIAL worker per stage: per-stage ordering is preserved
            # (TBB's serial filters) while distinct stages run concurrently
            self._pools = [
                ThreadPoolExecutor(max_workers=1,
                                   thread_name_prefix=f"stage-{i}")
                for i in range(len(self.stage_fns))]
        if max_group_retries < 0:
            raise ValueError(
                f"max_group_retries must be >= 0 (got {max_group_retries})")
        if quarantine_after < 1:
            raise ValueError(
                f"quarantine_after must be >= 1 (got {quarantine_after})")
        self._injector = fault_injector
        self.max_group_retries = int(max_group_retries)
        self.quarantine_after = int(quarantine_after)
        self.retry_budget_ms = (None if retry_budget_ms is None
                                else float(retry_budget_ms))
        self.open_groups = bool(open_groups)
        if self.open_groups:
            if replicas is None:
                raise ValueError(
                    "open_groups requires replicated mode (replicas=): the "
                    "join seam is the stage-0 ring-residency window")
            if not self.pad_microbatches:
                raise ValueError(
                    "open_groups requires pad_microbatches with "
                    "microbatch > 1 — padding seats are what joins claim")
        self.pad_token: tuple | None = None
        if pad_token is not None:
            pt = pad_token if isinstance(pad_token, tuple) else (pad_token,)
            if len(pt) != len(self.graph_inputs):
                raise ValueError(
                    f"pad_token must carry one value per graph input "
                    f"({len(self.graph_inputs)}), got {len(pt)}")
            self.pad_token = pt
        # open (unsealed) groups, oldest first — joins scan this under
        # self._lock; stage-0 workers remove a group here when they seal it
        self._open: deque[_Group] = deque()
        self._inflight: deque[_Group] = deque()
        self._occupancy = 0               # live (non-retired) tokens
        self._lock = threading.RLock()
        self.closed = False
        self._seq = 0                     # admission sequence (replicated)
        self._next_retire_seq = 0         # in-order retirement watermark
        self._rings: list[list[_SeqRing]] | None = None
        self._replica_threads: list[threading.Thread] = []
        self._owner: list[list[int]] | None = None
        self._route_locks: list[threading.Lock] | None = None
        self._healthy: list[list[bool]] | None = None
        self._err_counts: list[list[int]] | None = None
        if self.replicas is not None:
            self._rings = [[_SeqRing(r, w) for w in range(r)]
                           for r in self.replicas]
            # residue -> serving replica; rewritten by _quarantine under
            # the per-stage route lock (serializes against _route)
            self._owner = [list(range(r)) for r in self.replicas]
            self._route_locks = [threading.Lock() for _ in self.replicas]
            self._healthy = [[True] * r for r in self.replicas]
            self._err_counts = [[0] * r for r in self.replicas]
            for si, r in enumerate(self.replicas):
                for w in range(r):
                    t = threading.Thread(
                        target=self._replica_loop, args=(si, w),
                        name=f"stage-{si}-replica-{w}", daemon=True)
                    t.start()
                    self._replica_threads.append(t)
        self._stats = ExecutorStats(per_stage=self._fresh_counters())

    def _fresh_counters(self) -> list[StageCounters]:
        reps = self.replicas or [1] * len(self.stage_fns)
        devs = self.devices or [[] for _ in reps]
        return [StageCounters(replicas=r, devices=list(d))
                for r, d in zip(reps, devs)]

    # -- construction helpers ------------------------------------------------ #
    @classmethod
    def from_pipeline(cls, pipe, *, max_in_flight: int | None = None,
                      microbatch: int = 1,
                      pad_microbatches: bool = False,
                      buckets: Sequence[int] | None = None,
                      profiler: Any = None, stage_workers: bool = False,
                      replicas: Sequence[int] | None = None,
                      devices: Sequence[Sequence[int]] | None = None,
                      inventory: Any = None, fault_injector: Any = None,
                      max_group_retries: int = 3, quarantine_after: int = 1,
                      retry_budget_ms: float | None = None,
                      open_groups: bool = False,
                      pad_token: tuple | None = None,
                      ) -> "PipelineExecutor":
        """Build from a :class:`repro.core.pipeline.BuiltPipeline`.

        The vmapped stage executables are hoisted onto (and shared via) the
        pipeline, so building a new executor over the same pipeline — pool
        resizes, serving re-plans — never recompiles a stage.
        """
        mif = max_in_flight if max_in_flight is not None else pipe.max_in_flight
        batched = pipe.batched_stage_fns() if microbatch > 1 else None
        return cls(pipe.stage_fns, pipe.graph_inputs, pipe.graph_outputs,
                   max_in_flight=mif, microbatch=microbatch,
                   pad_microbatches=pad_microbatches, buckets=buckets,
                   batched_fns=batched, profiler=profiler,
                   stage_workers=stage_workers, replicas=replicas,
                   devices=devices, inventory=inventory,
                   fault_injector=fault_injector,
                   max_group_retries=max_group_retries,
                   quarantine_after=quarantine_after,
                   retry_budget_ms=retry_budget_ms,
                   open_groups=open_groups, pad_token=pad_token)

    # -- public API ---------------------------------------------------------- #
    def submit(self, *args: Any) -> PendingToken:
        """Admit one token (backpressure: blocks while the pool is full)."""
        return self.submit_many([args])[0]

    def submit_many(self, tokens: Iterable[tuple | Any]) -> list[PendingToken]:
        """Admit a token stream, micro-batching compatible neighbors.

        All stages of each admitted group are issued immediately (JAX async
        dispatch); the call blocks only when the token pool is full, and
        then only on the oldest group's final outputs.  Malformed tokens
        (wrong arity) are rejected up front, before ANY token is admitted,
        so a plain ValueError implies nothing was issued.  A later failure
        (e.g. a shape that breaks jit tracing at stage-issue time) raises
        :class:`SubmitError` carrying the handles of the prefix that WAS
        admitted, so callers never lose — or double-issue — work that is
        already on the device.
        """
        if self.closed:
            raise ExecutorClosed("executor is closed; build a fresh one")
        toks = [t if isinstance(t, tuple) else (t,) for t in tokens]
        for i, t in enumerate(toks):
            if len(t) != len(self.graph_inputs):
                raise ValueError(
                    f"token {i}: expected {len(self.graph_inputs)} inputs, "
                    f"got {len(t)}")
        handles: list[PendingToken] = []
        for group_toks in self._group_tokens(toks):
            try:
                handles.extend(self._admit(group_toks))
            except ExecutorClosed:
                if not handles:
                    raise           # nothing issued: the clean "closed" case
                raise SubmitError(
                    f"executor closed after token {len(handles)}",
                    handles) from None
            except BaseException as e:
                raise SubmitError(
                    f"submit failed at token {len(handles)}: {e}",
                    handles) from e
        return handles

    # -- continuous batching (open_groups mode) ------------------------------ #
    def try_join(self, args: tuple | Any) -> PendingToken | None:
        """Admit one token into an already in-flight group's padding seat.

        Scans the open (unsealed) groups oldest-first for one whose token
        signature matches, that has a free padding seat, no error, and
        pool headroom; claims the next seat (rows ``[0, size)`` stay
        contiguous real tokens), rewrites that env row in place, and
        returns a handle that retires WITH the group — the token skips the
        queue-to-group-formation wait entirely.  Returns ``None`` when no
        seam is open (caller falls back to :meth:`submit` /
        :meth:`submit_many`).  Env writes happen under the executor lock,
        strictly before the stage-0 worker's seal flip under the same
        lock, so a joined row is either fully visible to the stage or the
        join never happened.  No new executables: the group's stacked
        shape — and therefore its warmed bucket executable — is unchanged.
        """
        if not self.open_groups:
            return None
        toks = args if isinstance(args, tuple) else (args,)
        if len(toks) != len(self.graph_inputs):
            raise ValueError(
                f"expected {len(self.graph_inputs)} inputs, got {len(toks)}")
        sig = _sig_of(toks)
        with self._lock:
            if self.closed:
                raise ExecutorClosed("executor is closed; build a fresh one")
            if self._occupancy + 1 > self.pool:
                return None
            for g in self._open:
                if (g.sealed or g.error is not None or g.size >= g.rows
                        or g.sig != sig):
                    continue
                row = g.size
                # functional row update — async dispatch, completes (as a
                # program order write) before the worker's sealed read
                g.env = {k: v.at[row].set(a) if hasattr(v, "at") else v
                         for (k, v), a in zip(g.env.items(), toks)}
                g.size += 1
                self._occupancy += 1
                self._stats.tokens_admitted += 1
                self._stats.seam_joins += 1
                self._stats.max_in_flight_seen = max(
                    self._stats.max_in_flight_seen, self._occupancy)
                self._stats.occupancy_samples += 1
                self._stats.occupancy_sum += self._occupancy
                for c in self._stats.per_stage:
                    c.tokens += 1
                return PendingToken(self, g, row)
        return None

    def try_evict(self, handle: PendingToken,
                  error: BaseException | None = None) -> bool:
        """Turn an unsealed seat into a dead row (seam-side cancellation).

        Only legal before the seat's group seals; the row is overwritten
        with ``pad_token`` (when configured) so a stateful stage treats it
        as dead, and ``handle.result()`` raises ``error``.  Group
        accounting is unchanged — the seat still retires with its group,
        it just carries no live request.  Returns False once the group
        sealed (too late: the token runs; cancel at the serving layer
        instead).
        """
        g = handle._group
        with self._lock:
            if not self.open_groups or g.sealed or g.done \
                    or g.error is not None:
                return False
            idx = handle._idx
            if idx in g.evicted:
                return True
            if self.pad_token is not None:
                g.env = {k: (v.at[idx].set(p) if hasattr(v, "at") else v)
                         for (k, v), p in zip(g.env.items(), self.pad_token)}
            g.evicted[idx] = error if error is not None else RuntimeError(
                "token evicted at the batch seam")
            self._stats.seam_evictions += 1
            return True

    def seam_capacity(self) -> int:
        """Free padding seats across open unsealed groups, capped by pool
        headroom — the serving layer's 'how many arrivals can jump the
        queue right now' signal (predicted-wait input)."""
        if not self.open_groups:
            return 0
        with self._lock:
            free = sum(g.rows - g.size for g in self._open
                       if not g.sealed and g.error is None)
            return max(0, min(free, self.pool - self._occupancy))

    def run(self, tokens: Iterable[tuple | Any]) -> list[Any]:
        """Blocking map over a token stream; results in submission order."""
        t0 = time.perf_counter()
        handles = self.submit_many(tokens)
        out = [h.result() for h in handles]
        with self._lock:
            self._stats.wall_ms += (time.perf_counter() - t0) * 1e3
        return out

    def drain(self) -> None:
        """Block until every in-flight token has retired."""
        with self._lock:
            last = self._inflight[-1] if self._inflight else None
        if last is not None:
            self._retire_through(last)

    def warmup(self, *args: Any) -> None:
        """Compile the per-token and (if batching) vmapped stage
        executables for one example token, blocking until ready.  With
        bucketed padding every bucket size is warmed, so steady-state
        serving never compiles for a ragged group again.  A device-pinned
        executor warms every replica: groups route to replica ``seq %
        r``, and each pinned replica's device builds its own jit
        executable, so one warm group per replica (``max(replicas)``
        consecutive seqs cover every stage's replicas) keeps first-touch
        compiles off the serving path for devices 1..N-1 too.  The
        attached profiler (if any) is suspended so compile time never
        lands in the profile and poisons the first re-plan decision."""
        prof, self.profiler = self.profiler, None
        # one group per distinct replica ring when pinning is in effect:
        # consecutive seqs 0..max_r-1 hit residue w of every stage whose
        # width r_s <= max_r (all of them), i.e. every pinned device
        rounds = max(self.replicas) if (self.replicas is not None
                                        and self._replica_devs is not None) \
            else 1
        try:
            for _ in range(rounds):
                self.submit(*args).result()
            if self.microbatch > 1:
                sizes = set(self.buckets or ()) | {self.microbatch}
                for n in sorted(sizes):
                    if n <= 1:
                        continue
                    for _ in range(rounds):
                        for h in self.submit_many([args] * n):
                            h.result()
        finally:
            self.profiler = prof
        self.reset_stats()

    def close(self) -> None:
        """Drain in-flight work and shut down stage-worker threads.

        Sets ``closed`` so caches (e.g. ElasticPlanner's) never hand a
        shut-down executor back out.  ``closed`` is published under the
        executor lock BEFORE draining: a submitter racing this call either
        wins its pool reservation first (its group is then in ``_inflight``
        and the drain below retires it) or observes ``closed`` inside the
        admission loop and raises :class:`ExecutorClosed` — it can never
        be admitted into the rings this method is about to close.
        """
        with self._lock:
            self.closed = True
        self.drain()
        if self._pools is not None:
            for p in self._pools:
                p.shutdown(wait=True)
        if self._rings is not None:
            for stage_rings in self._rings:
                for ring in stage_rings:
                    ring.close()
            for t in self._replica_threads:
                t.join(timeout=30.0)

    def compile_count(self) -> int:
        """Executables compiled across per-token and vmapped stage fns.

        Constant across identical-shape token waves after :meth:`warmup` —
        the zero-recompile steady-state invariant the serving layer asserts.
        """
        total = sum(getattr(f, "compiles", 0) for f in self.stage_fns)
        if self._batched_fns is not None:
            for f in self._batched_fns:
                try:
                    total += f._cache_size()
                except AttributeError:
                    pass
        return total

    def stats(self) -> ExecutorStats:
        return self._stats

    def reset_stats(self) -> None:
        with self._lock:
            self._stats = ExecutorStats(per_stage=self._fresh_counters())

    @property
    def in_flight(self) -> int:
        with self._lock:
            return self._occupancy

    # -- internals ----------------------------------------------------------- #
    def _group_tokens(self, toks: list[tuple]) -> Iterable[list[tuple]]:
        """Split the stream into runs of shape-compatible tokens (<= mb)."""
        if self.microbatch <= 1:
            for t in toks:
                yield [t]
            return
        cur: list[tuple] = []
        cur_sig: tuple | None = None
        for t in toks:
            sig = _sig_of(t)
            if cur and (sig != cur_sig or len(cur) >= self.microbatch):
                yield cur
                cur = []
            cur.append(t)
            cur_sig = sig
        if cur:
            yield cur

    def _env_of(self, args: Sequence[Any]) -> dict:
        if len(args) != len(self.graph_inputs):
            raise ValueError(f"expected {len(self.graph_inputs)} inputs, "
                             f"got {len(args)}")
        return dict(zip(self.graph_inputs, args))

    def _out_of(self, env: dict):
        outs = tuple(env[o] for o in self.graph_outputs)
        return outs[0] if len(outs) == 1 else outs

    def _stage_fns_for(self, size: int) -> list[Callable]:
        if size == 1:
            return self.stage_fns
        if self._batched_fns is None:
            # vmap over the env dict (a pytree of per-token arrays) — over
            # the *raw* stage body when the stage is a StageFn, so one
            # jit(vmap(...)) owns the executable cache; jit so repeated
            # group sizes reuse the compiled executable.
            self._batched_fns = [jax.jit(jax.vmap(getattr(f, "raw", f)))
                                 for f in self.stage_fns]
        return self._batched_fns

    def _pad_for(self, size: int) -> int:
        """Padding rows for a ragged group: to the smallest bucket that
        fits (bucketed mode) or all the way to ``microbatch``.

        ``microbatch`` itself is always the explicit final bucket (the
        constructor appends it), so every padded size lands on an
        executable ``warmup`` compiled; a size no bucket fits — only
        reachable by bypassing ``_group_tokens``'s microbatch cap — is an
        error, never a silent compile of a new group size.

        Singleton groups are never padded: the per-token executables are
        always compiled (``warmup`` runs a single token first), so padding
        one real row up to a bucket would only buy a stack/unstack
        round-trip plus wasted padded compute.  EXCEPT in ``open_groups``
        mode — there a singleton pads to a bucket like any other ragged
        group, because its padding seats are exactly what later arrivals
        join into.
        """
        if not self.pad_microbatches or size >= self.microbatch \
                or (size == 1 and not self.open_groups):
            return 0
        if self.buckets:
            for b in self.buckets:
                if b >= size:
                    return b - size
            raise RuntimeError(
                f"group size {size} exceeds every pad bucket "
                f"{self.buckets}; grouping should cap at microbatch="
                f"{self.microbatch}")
        return self.microbatch - size

    def _admit(self, group_toks: list[tuple]) -> list[PendingToken]:
        size = len(group_toks)
        pad = self._pad_for(size)
        stacked = size > 1 or pad > 0
        if stacked:
            # padding rows: a neutral pad_token when one is configured
            # (dead rows a stateful stage must not mutate — and the seats
            # open-group joins rewrite), else repeat the last token; either
            # way every group compiles (and reuses) the same
            # [bucket, ...] executable
            filler = (self.pad_token if self.pad_token is not None
                      else group_toks[-1])
            rows = group_toks + [filler] * pad
            args = tuple(jnp.stack(c) for c in zip(*rows))
        else:
            args = group_toks[0]
        env = self._env_of(args)

        # 1) reserve a pool slot.  The group is published with env=None and
        #    its per-group lock held, so finalizers queue on g.lock until
        #    issue completes — the executor lock itself is only held for
        #    O(us) bookkeeping, never across a jit trace/compile.
        g = _Group(None, size, stacked)
        g.rows = size + pad if stacked else size
        if self.open_groups:
            g.sig = _sig_of(group_toks[0])
        g.lock.acquire()
        while True:
            with self._lock:
                if self.closed:
                    # close() won the race: refuse admission instead of
                    # parking tokens in rings whose workers are exiting
                    g.lock.release()
                    raise ExecutorClosed(
                        "executor closed while waiting for pool capacity")
                if not self._inflight or self._occupancy + size <= self.pool:
                    self._inflight.append(g)
                    if self._rings is not None:
                        # seq assigned under the SAME lock as the in-order
                        # deque append: retirement order == seq order
                        g.seq = self._seq
                        self._seq += 1
                    self._occupancy += size
                    self._stats.tokens_admitted += size
                    self._stats.groups_admitted += 1
                    self._stats.max_in_flight_seen = max(
                        self._stats.max_in_flight_seen, self._occupancy)
                    self._stats.occupancy_samples += 1
                    self._stats.occupancy_sum += self._occupancy
                    break
                oldest = self._inflight[0]
            # backpressure: pool full — retire the oldest group.  The device
            # wait happens OUTSIDE self._lock so concurrent retirers
            # (serving threads) never stall admission behind it.
            self._finalize(oldest)

        # 2) issue every stage outside the executor lock (the first call of
        #    a new group size pays the vmap+jit trace here)
        try:
            fns = self._stage_fns_for(size + pad if stacked else 1)
            counters = []
            if self._rings is not None:
                t0 = time.perf_counter()
                g.env = env
                g.fns = tuple(fns)
                g.evt = threading.Event()
                if self.open_groups and g.rows > g.size:
                    # publish the group as OPEN before routing: joins may
                    # claim its padding seats until the stage-0 worker
                    # seals it (both transitions under self._lock)
                    with self._lock:
                        g.sealed = False
                        self._open.append(g)
                self._route(0, g.seq, g)
                enq = (time.perf_counter() - t0) * 1e3 / max(len(fns), 1)
                counters = [(si, enq) for si in range(len(fns))]
            elif self._pools is not None:
                t0 = time.perf_counter()
                self._issue_threaded(g, env, fns)
                enq = (time.perf_counter() - t0) * 1e3 / max(len(fns), 1)
                counters = [(si, enq) for si in range(len(fns))]
            else:
                # async-dispatch issue; sampled groups pay a blocking
                # barrier per stage so the profiler sees real wall times
                sample = self.profiler is not None and self.profiler.tick()
                for si, fn in enumerate(fns):
                    if self._injector is not None:
                        # unreplicated path: injected faults error the
                        # group at issue time (no replica to retry on)
                        self._injector.on_stage_call(si)
                    t0 = time.perf_counter()
                    env = fn(env)   # returns immediately (async dispatch)
                    # issue_ms stays a pure dispatch metric: capture it
                    # before any profiling barrier
                    counters.append((si, (time.perf_counter() - t0) * 1e3))
                    if sample:
                        env = jax.block_until_ready(env)
                        ms = (time.perf_counter() - t0) * 1e3
                        self.profiler.record(si, ms)
                        with self._lock:
                            self._stats.per_stage[si].exec_ms += ms
                g.env = env
        except BaseException as e:
            # unwind the reservation so the failed group neither blocks the
            # pool nor surfaces bogus results
            g.error = e
            g.done = True
            with self._lock:
                g.sealed = True          # no joins into a poisoned group
                # g.size, not size: any seat joined between registration
                # and the failure is unwound with its group
                self._occupancy -= g.size
                self._stats.tokens_admitted -= g.size
                self._stats.groups_admitted -= 1
                try:
                    self._inflight.remove(g)
                except ValueError:
                    pass
                try:
                    self._open.remove(g)
                except ValueError:
                    pass
            if self._rings is not None and g.seq is not None \
                    and g.evt is None:
                # the seq was reserved but never routed: push the poisoned
                # group through anyway so replica rings (which consume owned
                # seqs strictly in order) never stall on a gap
                g.evt = threading.Event()
                self._route(0, g.seq, g)
            raise
        finally:
            g.lock.release()
        with self._lock:
            for si, ms in counters:
                c = self._stats.per_stage[si]
                c.issued += 1
                c.tokens += size
                c.issue_ms += ms
        return [PendingToken(self, g, i) for i in range(size)]

    # -- replicated-stage dataflow (sequence-numbered rings) ----------------- #
    def _route(self, si: int, seq: int, g: _Group) -> None:
        """Hand a group to stage ``si``'s owning replica ring.

        Ownership is looked up through ``self._owner`` (residue ``seq mod
        r`` -> replica index) under the stage's route lock, so a
        concurrent quarantine either sees this put in the old ring (and
        re-routes it during its drain) or this put sees the new owner.
        A refused hand-off (ring already closed — only reachable if a
        caller bypasses the admission-side closed check) poisons the group
        and signals its completion event, so finalizers raise instead of
        waiting forever on a worker that already exited.
        """
        r = self.replicas[si]
        with self._route_locks[si]:
            ok = self._rings[si][self._owner[si][seq % r]].put(seq, g)
        if not ok:
            if g.error is None:
                g.error = ExecutorClosed(
                    f"stage {si} ring closed before seq {seq} arrived")
            g.evt.set()

    def _replica_loop(self, si: int, w: int) -> None:
        """Worker loop for replica ``w`` of stage ``si``.

        Pops this replica's owned seqs in order, stages the group onto
        this replica's pinned device (when one is assigned), runs the
        stage to completion (blocking on device work), and routes the
        group to the next stage's owning replica — or signals completion
        after the last stage.  An errored group is forwarded without
        executing further stages, so downstream replicas never stall on a
        skipped seq.
        """
        ring = self._rings[si][w]
        last = si == len(self.stage_fns) - 1
        dev = (self._replica_devs[si][w]
               if self._replica_devs is not None else None)
        # profiler attribution must describe placements actually in effect:
        # in degraded mode (single/planning-only inventory) nothing is
        # staged, so samples carry no device ordinal
        ordinal = (self.devices[si][w]
                   if self._replica_devs is not None else None)
        # fault injection keys on the CONFIGURED placement even in degraded
        # mode: a planning-only inventory still scripts "lose ordinal 2",
        # and the replica the plan pinned there must observe the loss
        inj_ord = (self.devices[si][w]
                   if self.devices is not None else None)
        while True:
            item = ring.pop()
            if item is None:
                return
            seq, g = item
            if si == 0 and not g.sealed:
                # SEAL: membership freezes the instant the stage-0 worker
                # claims the group.  Under the executor lock, so a
                # concurrent try_join either completed its env write
                # before this flip (its row runs with the group) or
                # observes sealed and moves on — never a torn env.
                with self._lock:
                    g.sealed = True
                    try:
                        self._open.remove(g)
                    except ValueError:
                        pass
                if self.profiler is not None and g.rows > 0:
                    rec = getattr(self.profiler, "record_seam", None)
                    if rec is not None:
                        rec(g.size, g.rows)
            forward = True
            if g.error is None:
                forward = self._exec_replicated(si, w, seq, g, dev,
                                                ordinal, inj_ord)
            if forward:
                if last:
                    g.evt.set()
                else:
                    self._route(si + 1, seq, g)
            else:
                return      # this replica quarantined itself; seq re-runs

    def _exec_replicated(self, si: int, w: int, seq: int, g: _Group,
                         dev: Any, ordinal: int | None,
                         inj_ord: int | None) -> bool:
        """Run stage ``si`` on group ``g`` with bounded retry.

        Injection fires BEFORE the stage body, so a retried injected fault
        never re-executes a half-donated buffer.  Returns True when the
        group should be forwarded (success, or a non-retryable error
        recorded on the group); False when this replica quarantined itself
        — the group then re-runs on a sibling replica via the ownership
        transfer in :meth:`_quarantine`.
        """
        while True:
            t0 = time.perf_counter()
            try:
                if self._injector is not None:
                    self._injector.on_stage_call(si, replica=w,
                                                 device=inj_ord)
                if dev is not None:
                    # commit the group onto this replica's device; the
                    # jitted stage then compiles/executes there (one
                    # executable per device, cached by jit) and its
                    # outputs stay committed for the .devices() audit
                    g.env = jax.device_put(g.env, dev)
                    xfer = (time.perf_counter() - t0) * 1e3
                else:
                    xfer = 0.0
                g.env = jax.block_until_ready(g.fns[si](g.env))
                ms = (time.perf_counter() - t0) * 1e3
                if self.profiler is not None:
                    # the profiler measures SERVICE time — staging
                    # included, matching the replicated_bottleneck_ms
                    # contract that hand-off overhead lives in the
                    # measured stage time
                    self.profiler.record(si, ms, replica=w,
                                         device=ordinal)
                with self._lock:
                    # counters are DISJOINT: exec_ms is the stage body
                    # alone, xfer_ms the staging hop (sum = service)
                    self._stats.per_stage[si].exec_ms += ms - xfer
                    self._stats.per_stage[si].xfer_ms += xfer
                return True
            except BaseException as e:
                action = self._on_stage_error(si, w, g, e, inj_ord)
                if action == "retry":
                    continue
                if action == "quarantine":
                    self._quarantine(si, w, seq, g)
                    return False
                g.error = e
                return True

    def _on_stage_error(self, si: int, w: int, g: _Group, e: BaseException,
                        inj_ord: int | None) -> str:
        """Decide what a failed stage call on a replicated stage means.

        ``"fail"`` — record the error on the group (unreplicated stage,
        retry budget exhausted, or no healthy sibling would remain);
        ``"retry"`` — re-run locally (transient, replica still healthy);
        ``"quarantine"`` — evict this replica and re-run on a sibling.
        """
        now = time.perf_counter()
        with self._lock:
            self._stats.per_stage[si].errors += 1
            if inj_ord is not None:
                self._stats.device_errors[inj_ord] = \
                    self._stats.device_errors.get(inj_ord, 0) + 1
            self._err_counts[si][w] += 1
            errs = self._err_counts[si][w]
            healthy_others = sum(self._healthy[si]) \
                - (1 if self._healthy[si][w] else 0)
            budget_ok = self.retry_budget_ms is None \
                or (now - g.t_admit) * 1e3 < self.retry_budget_ms
            can_retry = (self.replicas[si] > 1
                         and g.retries < self.max_group_retries
                         and budget_ok)
            if can_retry:
                g.retries += 1
                self._stats.retries += 1
        if self.profiler is not None:
            # profiler has its own lock — record outside self._lock
            self.profiler.record_error(si, replica=w, device=inj_ord)
        if not can_retry:
            return "fail"
        if errs >= self.quarantine_after and healthy_others >= 1:
            return "quarantine"
        return "retry"

    def _quarantine(self, si: int, w: int, seq: int, g: _Group) -> None:
        """Evict replica ``w`` of stage ``si`` and redistribute its work.

        The failing replica drains its own ring (``retire``), rolls the
        failed seq's residue watermark back so the group re-runs, then
        hands every owned residue — and every parked group — to the
        surviving healthy replicas round-robin.  The stage's route lock
        serializes this against concurrent :meth:`_route` puts: a put
        either landed in the old ring before ``retire`` (captured and
        re-put below) or resolves the new owner afterwards.  Callers
        guarantee at least one healthy sibling remains
        (:meth:`_on_stage_error` checks ``healthy_others >= 1``).
        """
        r = self.replicas[si]
        with self._route_locks[si]:
            with self._lock:
                self._healthy[si][w] = False
                self._stats.quarantined += 1
                self._stats.quarantined_replicas.append((si, w))
                targets = [i for i in range(r) if self._healthy[si][i]]
            slots, nxt = self._rings[si][w].retire()
            # roll back the failed seq's watermark: the group whose call
            # failed must re-run on its new owner
            nxt[seq % r] = seq
            slots[seq] = g
            for j, res in enumerate(sorted(nxt)):
                t = targets[j % len(targets)]
                self._owner[si][res] = t
                self._rings[si][t].adopt(res, nxt[res])
            for s in sorted(slots):
                self._rings[si][self._owner[si][s % r]].put(s, slots[s])

    def healthy_replicas(self) -> list[int] | None:
        """Healthy worker count per stage (None for a non-replicated
        executor) — the serving layer's view of quarantine attrition."""
        if self._healthy is None:
            return None
        with self._lock:
            return [sum(h) for h in self._healthy]

    def _issue_threaded(self, g: _Group, env: dict,
                        fns: Sequence[Callable]) -> None:
        """Chain the group's stages across the serial per-stage workers.

        Stage ``s``'s task waits on stage ``s-1``'s future, runs the stage
        to completion (blocking on its device work), and returns the next
        env.  Submission order per pool preserves per-stage token order.
        """
        prev: Future | None = None
        for si, (fn, pool) in enumerate(zip(fns, self._pools)):
            prev = pool.submit(self._run_stage, fn, si,
                               env if prev is None else None, prev)
        g.future = prev

    def _run_stage(self, fn: Callable, si: int, env0: dict | None,
                   prev: Future | None) -> dict:
        env = env0 if prev is None else prev.result()
        if self._injector is not None:
            # non-replicated stage: an injected fault errors the group
            # (no sibling to retry on), same as a real stage exception
            self._injector.on_stage_call(si)
        t0 = time.perf_counter()
        out = jax.block_until_ready(fn(env))
        ms = (time.perf_counter() - t0) * 1e3
        if self.profiler is not None:
            self.profiler.record(si, ms)
        with self._lock:
            self._stats.per_stage[si].exec_ms += ms
        return out

    def _retire_through(self, group: _Group) -> None:
        """Finalize ``group`` and everything older (in-order retirement)."""
        while not group.done:
            with self._lock:
                if group.done or not self._inflight:
                    break
                oldest = self._inflight[0]
            self._finalize(oldest)

    def _finalize(self, g: _Group) -> None:
        """Block on a group's final outputs and unstack them.

        Idempotent; callable from any thread.  The executor lock is NOT
        held across the device wait — only the per-group lock serializes
        double-finalization, so admission can proceed while a serving
        thread blocks here.
        """
        finalized_here = False
        with g.lock:
            if not g.done:
                try:
                    if g.evt is not None:         # replicated stage workers
                        g.evt.wait()
                        if g.error is not None:
                            raise g.error
                    elif g.future is not None:    # threaded stage workers
                        g.env = g.future.result()
                    out = self._out_of(g.env)
                    jax.block_until_ready(out)
                    if g.stacked:
                        if isinstance(out, tuple):
                            g.results = [tuple(o[i] for o in out)
                                         for i in range(g.size)]
                        else:
                            g.results = [out[i] for i in range(g.size)]
                    else:
                        g.results = [out]
                except BaseException as e:
                    # an execute-time failure (threaded stage, or a runtime
                    # error surfacing at the blocking wait): the group still
                    # leaves the pipeline — it counts as retired so
                    # issued == retired holds and the pool slot is freed —
                    # and every PendingToken.result() re-raises the error.
                    g.error = e
                g.done = True
                finalized_here = True
        with self._lock:
            if finalized_here:           # exactly-once accounting per group
                self._stats.tokens_retired += g.size
                if g.error is not None:
                    self._stats.tokens_failed += g.size
                self._occupancy -= g.size
                if g.seq is not None:
                    # reorder-buffer audit: retirement must consume seqs
                    # monotonically even when replicas complete out of order
                    if g.seq < self._next_retire_seq:
                        self._stats.out_of_order_retired += 1
                    self._next_retire_seq = max(self._next_retire_seq,
                                                g.seq + 1)
            # drop retired groups from the head (in-order by design)
            while self._inflight and self._inflight[0].done:
                self._inflight.popleft()
