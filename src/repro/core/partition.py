"""Stage partitioning — paper Sect. III-B.4, plus a beyond-paper optimum.

The paper's policy, quoted: *"Pipeline Generator divides total processing
time by the number of thread plus one and searches the closest sub-total of
processing time of functions."*  Stages are contiguous runs of the traced
chronological order; the first and last stage run ``serial_in_order`` and the
middle stages ``parallel`` (TBB filter kinds).

Two partitioners:

* :func:`partition_paper` — the policy verbatim (paper-faithful baseline):
  greedy cuts at the cumulative sum closest to ``total/(n_threads+1)``.
* :func:`partition_optimal` — beyond-paper: the classic contiguous-partition
  DP that *minimizes the bottleneck stage* (steady-state token period),
  optionally charging each stage boundary its intermediate-data transfer
  cost ("the communication frequency of intermediate data should be
  reduced", paper Sect. III-B.4).  Recorded separately in EXPERIMENTS.md.

Plus :func:`fuse_adjacent_hw` — the ``#pragma HLS dataflow`` analog: merge
maximal runs of adjacent database-hit functions with no branch (single
consumer = next node), keeping the paper's observed behavior that a fusion
estimated slower than its pipelined parts is rejected (their fused
cvtColor+cornerHarris "was too slow to use").
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Iterable, Sequence

from .costmodel import (VMEM_BYTES, FusionEstimate, NodeCost, fused_cost,
                        replicated_bottleneck_ms, transfer_ms)
from .database import ModuleDatabase
from .ir import CourierIR, Node
from .placement import (AUTO_BUDGET, DeviceInventory, Placement,
                        resolve_worker_budget)

__all__ = [
    "StagePlan", "PipelinePlan",
    "partition_paper", "partition_optimal", "fuse_adjacent_hw",
    "fused_working_set_bytes", "working_set_bytes", "make_model_fused_cost",
    "split_fused_node",
    "assign_replicas", "assign_stage_devices", "clear_stage_devices",
    "widen_for_deployment",
]


@dataclass
class StagePlan:  # lint: allow-mutable(mutated in place by assign_replicas / assign_stage_devices / clear_stage_devices)
    node_names: list[str]
    est_time_ms: float
    kind: str = "parallel"            # "serial_in_order" | "parallel" (TBB)
    placements: list[Placement] = field(default_factory=list)  # per node
    comm_in_bytes: int = 0            # intermediate data entering this stage
    replicas: int = 1                 # worker threads (TBB parallel filter)
    # per-replica device assignment (ordinals into the planner's
    # DeviceInventory; empty = unpinned, every replica on the default
    # device — the single-host degenerate case)
    devices: list[int] = field(default_factory=list)
    # per-replica relative throughput (parallel to ``devices``; empty =
    # homogeneous at the class baseline)
    device_speeds: list[float] = field(default_factory=list)
    # transfer cost charged when this stage's device set differs from its
    # predecessor's (host<->device staging of comm_in_bytes per token)
    xfer_in_ms: float = 0.0


@dataclass
class PipelinePlan:  # lint: allow-mutable(stages re-widened/re-pinned in place across replans)
    stages: list[StagePlan]
    policy: str = "paper"

    @property
    def n_stages(self) -> int:
        return len(self.stages)

    @property
    def bottleneck_ms(self) -> float:
        """Slowest stage's one-worker service time (replication ignored)."""
        return max(s.est_time_ms for s in self.stages)

    @property
    def replicas(self) -> list[int]:
        return [s.replicas for s in self.stages]

    @property
    def total_workers(self) -> int:
        return sum(s.replicas for s in self.stages)

    @property
    def stage_devices(self) -> list[list[int]] | None:
        """Per-stage per-replica device ordinals; None when unpinned."""
        if not any(s.devices for s in self.stages):
            return None
        return [list(s.devices) for s in self.stages]

    @property
    def effective_bottleneck_ms(self) -> float:
        """Predicted token period with stage replication applied.

        A stage ``r`` workers wide retires a token every ``t / r`` ms in
        steady state, so the period is ``max_k t_k / r_k`` — equal to
        :attr:`bottleneck_ms` for an all-serial plan.  Device-pinned plans
        additionally charge each stage its cross-device boundary transfer
        (``xfer_in_ms``) and weight replicas by their device speed.
        """
        speeds = None
        if any(s.device_speeds for s in self.stages):
            speeds = [list(s.device_speeds) for s in self.stages]
        return replicated_bottleneck_ms(
            [s.est_time_ms + s.xfer_in_ms for s in self.stages],
            self.replicas, speeds)

    def predicted_speedup(self, n_tokens: int = 1000) -> float:
        """Sequential time vs pipelined time for a long token stream.

        Pipeline time for T tokens = fill (sum of stages for token 0) +
        (T-1) * bottleneck; sequential = T * sum.  Replicated stages use
        their effective (widened) period.
        """
        total = sum(s.est_time_ms for s in self.stages)
        pipe = total + (n_tokens - 1) * self.effective_bottleneck_ms
        return (n_tokens * total) / pipe

    def describe(self) -> str:
        rows = [f"PipelinePlan[{self.policy}] {self.n_stages} stages, "
                f"bottleneck={self.effective_bottleneck_ms:.2f} ms, "
                f"steady-state speedup={self.predicted_speedup():.2f}x"]
        for i, s in enumerate(self.stages):
            width = f" x{s.replicas}" if s.replicas > 1 else ""
            devs = f" on devices {s.devices}" if s.devices else ""
            xfer = f" (+{s.xfer_in_ms:.2f} ms xfer)" if s.xfer_in_ms else ""
            rows.append(f"  Stage #{i} [{s.kind:>15s}]{width}{devs} "
                        f"{s.est_time_ms:8.2f} ms{xfer}  "
                        f"{list(zip(s.node_names, s.placements))}")
        return "\n".join(rows)

    # -- (de)serialization — verifier CLI / plan artifacts ------------------ #
    def to_json(self) -> str:
        import json
        from dataclasses import asdict
        return json.dumps({
            "policy": self.policy,
            "stages": [asdict(s) for s in self.stages],
        }, indent=2)

    @classmethod
    def from_json(cls, s: str) -> "PipelinePlan":
        import json
        d = json.loads(s)
        stages = []
        for sd in d["stages"]:
            sd = dict(sd)
            sd["placements"] = [Placement.parse(p)
                                for p in sd.get("placements", [])]
            stages.append(StagePlan(**sd))
        return cls(stages=stages, policy=d.get("policy", "paper"))


# --------------------------------------------------------------------------- #
# helpers
# --------------------------------------------------------------------------- #
def _times(ir: CourierIR) -> list[float]:
    ts = []
    for n in ir.nodes:
        if n.time_ms is None:
            raise ValueError(f"node {n.name} has no processing time; run the "
                             "Frontend profile or CostModel.annotate first")
        ts.append(float(n.time_ms))
    return ts


def _mk_plan(ir: CourierIR, cuts: Sequence[int], policy: str) -> PipelinePlan:
    """``cuts`` are indices where a new stage begins (excluding 0)."""
    bounds = [0, *cuts, len(ir.nodes)]
    stages: list[StagePlan] = []
    for a, b in zip(bounds[:-1], bounds[1:]):
        nodes = ir.nodes[a:b]
        comm = 0
        for inp in nodes[0].inputs:
            v = ir.values[inp]
            if v.producer is not None:      # intermediate data via ext. memory
                comm += v.nbytes
        stages.append(StagePlan(
            node_names=[n.name for n in nodes],
            est_time_ms=sum(n.time_ms for n in nodes),
            placements=[n.placement for n in nodes],
            comm_in_bytes=comm))
    if stages:
        stages[0].kind = "serial_in_order"       # paper: first ...
        stages[-1].kind = "serial_in_order"      # ... and last are serial
        for s in stages[1:-1]:
            s.kind = "parallel"
    return PipelinePlan(stages=stages, policy=policy)


# --------------------------------------------------------------------------- #
# Paper-faithful policy
# --------------------------------------------------------------------------- #
def partition_paper(ir: CourierIR, n_threads: int = 2) -> PipelinePlan:
    """The paper's closest-subtotal policy, verbatim.

    target = total / (n_threads + 1).  Walk the chronological function list
    accumulating time; place a cut at the prefix whose subtotal is closest
    to the target (choosing between stopping before/after the element that
    crosses it), then restart the accumulation.
    """
    times = _times(ir)
    n = len(times)
    target = sum(times) / (n_threads + 1)
    cuts: list[int] = []
    acc = 0.0
    for i, t in enumerate(times[:-1]):          # never cut after the last node
        take = acc + t
        # closest sub-total: cut *after* i if take is closer to target than
        # continuing to take+next would be.
        nxt = take + times[i + 1]
        if abs(take - target) <= abs(nxt - target):
            cuts.append(i + 1)
            acc = 0.0
        else:
            acc = take
    return _mk_plan(ir, cuts, policy="paper")


# --------------------------------------------------------------------------- #
# Beyond-paper: bottleneck-optimal contiguous partition (DP)
# --------------------------------------------------------------------------- #
def _boundary_cost(ir: CourierIR, i: int, comm_bw_bytes_per_ms: float | None) -> float:
    """Transfer cost charged when a stage starts at node index i (>0)."""
    if not comm_bw_bytes_per_ms or i == 0:
        return 0.0
    n = ir.nodes[i]
    byts = 0
    for inp in n.inputs:
        v = ir.values[inp]
        if v.producer is not None:
            byts += v.nbytes
    return byts / comm_bw_bytes_per_ms


def partition_optimal(ir: CourierIR, max_stages: int | None = None,
                      comm_bw_bytes_per_ms: float | None = None,
                      stage_overhead_ms: float = 0.0) -> PipelinePlan:
    """Minimize the bottleneck stage over all contiguous partitions.

    DP over (prefix, #stages); objective for a stage [a, b) is
    ``sum(times[a:b]) + boundary_cost(a) + stage_overhead_ms``.  Sweeps the
    stage count 1..max_stages and keeps the best bottleneck (ties → fewer
    stages, which also reduces "the communication frequency of intermediate
    data").
    """
    times = _times(ir)
    n = len(times)
    max_stages = min(max_stages or n, n)
    prefix = [0.0]
    for t in times:
        prefix.append(prefix[-1] + t)

    def seg(a: int, b: int) -> float:           # cost of stage [a, b)
        return (prefix[b] - prefix[a]
                + _boundary_cost(ir, a, comm_bw_bytes_per_ms)
                + stage_overhead_ms)

    INF = float("inf")
    best_plan: tuple[float, list[int]] | None = None
    # dp[k][i] = min over partitions of first i nodes into k stages of the
    # max stage cost; parent pointers reconstruct cuts.
    dp_prev = [seg(0, i) for i in range(n + 1)]          # k = 1
    parents: list[list[int]] = [[0] * (n + 1)]
    if best_plan is None:
        best_plan = (dp_prev[n] + 0.0, [])
    for k in range(2, max_stages + 1):
        dp_cur = [INF] * (n + 1)
        par = [0] * (n + 1)
        for i in range(k, n + 1):
            for j in range(k - 1, i):
                c = max(dp_prev[j], seg(j, i))
                if c < dp_cur[i]:
                    dp_cur[i], par[i] = c, j
        parents.append(par)
        if dp_cur[n] < best_plan[0] - 1e-12:
            cuts: list[int] = []
            i, kk = n, k
            pars = parents
            while kk > 1:
                j = pars[kk - 1][i]
                cuts.append(j)
                i, kk = j, kk - 1
            best_plan = (dp_cur[n], sorted(cuts))
        dp_prev = dp_cur
    return _mk_plan(ir, best_plan[1], policy="optimal-dp")


# --------------------------------------------------------------------------- #
# Stage replication — widen the bottleneck stage (TBB parallel filters)
# --------------------------------------------------------------------------- #
def assign_replicas(plan: PipelinePlan, ir: CourierIR | None = None, *,
                    worker_budget: "int | str | None" = None,
                    inventory: DeviceInventory | None = None,
                    target_ms: float | None = None,
                    max_replicas: int | None = None) -> PipelinePlan:
    """Pick per-stage replication factors under a total worker budget.

    The widening rule (documented in EXPERIMENTS.md): every replicable
    stage gets ``ceil(stage_ms / target_ms)`` workers, clamped to
    ``[1, max_replicas]`` and to the budget.  ``target_ms`` — the token
    period the plan is widened toward — defaults to the *smallest
    achievable* period: the least candidate ``T`` (searched over
    ``{stage_ms / j}`` and the serial floor) whose total worker demand
    fits ``worker_budget``, floored by the slowest non-replicable stage
    (no budget can widen past it).

    ``worker_budget`` may be an explicit int (the override),
    :data:`~repro.core.placement.AUTO_BUDGET` (the ``os.cpu_count()``
    governor), or ``None`` — which derives the budget from ``inventory``
    when one is given and raises otherwise.  ``inventory``
    (a :class:`~repro.core.placement.DeviceInventory`) additionally maps
    each replica onto a concrete device via
    :func:`assign_stage_devices`: the N replicas of a widened stage are
    pinned to N distinct chips/cores and cross-device stage boundaries
    are charged their transfer cost.

    A stage is replicable only when every node in it is side-effect safe
    (``Node.serial_only`` unset); pass ``ir`` to enforce the markers —
    without it every stage is assumed pure (true for traced jnp/Pallas
    pipelines).  If the explicit ``target_ms`` demands more workers than
    the budget allows, replicas are taken back from the stages whose
    effective time suffers least, so the result always satisfies
    ``plan.total_workers <= worker_budget``.

    Mutates (and returns) ``plan``: only the stages' ``replicas`` (and
    device-assignment) fields change; boundaries, times, and kinds are
    untouched, which is what lets the executor reuse every compiled
    StageFn when the re-planner chooses widening over re-balancing.
    """
    import math

    times = [float(s.est_time_ms) for s in plan.stages]
    n = len(times)
    if n == 0:
        return plan
    worker_budget = resolve_worker_budget(worker_budget, n, inventory)
    if worker_budget is None:
        raise ValueError("assign_replicas needs a worker_budget (or an "
                         "inventory to derive one from)")
    if worker_budget < n:
        raise ValueError(f"worker_budget {worker_budget} below the one-"
                         f"worker-per-stage floor ({n} stages)")
    replicable = []
    for s in plan.stages:
        ok = True
        if ir is not None:
            # stateful nodes are serial even if a hand-built IR forgot the
            # flag: concurrent workers would race the slot-pool writes
            ok = not any(ir.node(nn).serial_only
                         or getattr(ir.node(nn), "state", None)
                         for nn in s.node_names)
        replicable.append(ok)
    cap = max(1, min(max_replicas if max_replicas is not None
                     else worker_budget, worker_budget - (n - 1)))

    def demand(t: float) -> list[int]:
        """Workers per stage to hit a token period of ``t``."""
        out = []
        for ms, ok in zip(times, replicable):
            if not ok or ms <= 0.0 or t <= 0.0:
                out.append(1)
            else:
                out.append(min(cap, max(1, math.ceil(ms / t - 1e-9))))
        return out

    if target_ms is None:
        # the serial floor: no widening beats the slowest serial-only stage
        floor = max((t for t, ok in zip(times, replicable) if not ok),
                    default=0.0)
        cands = sorted({max(t / j, floor)
                        for t, ok in zip(times, replicable) if t > 0
                        for j in range(1, (cap if ok else 1) + 1)} | {floor})
        target_ms = max(times)
        for t in cands:
            if t > 0 and sum(demand(t)) <= worker_budget:
                target_ms = t
                break
    reps = demand(target_ms)
    # an explicit target can over-subscribe the budget: shed replicas where
    # the effective stage time grows least
    while sum(reps) > worker_budget:
        k = min((i for i in range(n) if reps[i] > 1),
                key=lambda i: times[i] / (reps[i] - 1))
        reps[k] -= 1
    for s, r in zip(plan.stages, reps):
        s.replicas = int(r)
    if inventory is not None:
        assign_stage_devices(plan, inventory, ir=ir)
    else:
        # mutate-and-rerun API: a previous device-assigned run must not
        # leave stale per-replica pinnings behind (their lengths would no
        # longer match the new replica counts)
        clear_stage_devices(plan)
    return plan


def clear_stage_devices(plan: PipelinePlan) -> PipelinePlan:
    """Drop per-replica device pinnings (and their transfer charges).

    Callers use this when a device-assigned plan ends up deployed
    *unpinned* (no stage widened, so the executor runs on the default
    device): keeping the pinnings would charge ``effective_bottleneck_ms``
    transfer costs the executor never pays, skewing replan comparisons.
    """
    for s in plan.stages:
        s.devices = []
        s.device_speeds = []
        s.xfer_in_ms = 0.0
    return plan


def widen_for_deployment(plan: PipelinePlan, ir: CourierIR | None = None, *,
                         worker_budget: "int | str | None" = None,
                         inventory: DeviceInventory | None = None,
                         ) -> "tuple[list[int] | None, list[list[int]] | None]":
    """The widening pass as every deployment site must apply it.

    Resolves the budget (:func:`~repro.core.placement.
    resolve_worker_budget`), runs :func:`assign_replicas` (device-pinned
    when an ``inventory`` is given), and returns the ``(replicas,
    devices)`` pair to hand the executor.  When no budget resolves or no
    stage widens it returns ``(None, None)`` **and clears any pinnings
    off the plan** — the executor then runs unpinned, and a plan that
    kept device speeds / transfer charges would feed wrong effective
    periods to replan comparisons and the serving batcher.  One helper so
    the deploy-or-degrade rule cannot diverge between call sites
    (``ElasticPlanner`` and ``serve_pipeline_demo`` both go through it).
    """
    wb = resolve_worker_budget(worker_budget, len(plan.stages), inventory)
    if wb is None:
        clear_stage_devices(plan)     # the docstring's promise holds here too
        return None, None
    assign_replicas(plan, ir, worker_budget=wb, inventory=inventory)
    if not any(s.replicas > 1 for s in plan.stages):
        clear_stage_devices(plan)
        return None, None
    return plan.replicas, plan.stage_devices


def assign_stage_devices(plan: PipelinePlan, inventory: DeviceInventory,
                         ir: CourierIR | None = None) -> PipelinePlan:
    """Map every stage replica onto a concrete device of ``inventory``.

    Placement rule (greedy, heaviest stage first): each stage's ``r``
    replicas are pinned to the ``r`` devices that would complete the
    stage's per-replica share earliest — *distinct* devices whenever the
    inventory holds at least ``r`` (the whole point of widening onto
    hardware: N replicas on N chips), with wrap-around only when replicas
    outnumber devices.  Load is the per-device sum of assigned
    speed-normalized ``est_time_ms / replicas`` shares, so two widened
    stages spread over different chips instead of stacking onto device 0.
    Per-replica ``device_speeds`` come from the specs; a stage whose
    device set differs from its predecessor's is charged the transfer of
    its ``comm_in_bytes`` at the slower side's staging bandwidth
    (``xfer_in_ms``).  Stage 0 is charged the *graph inputs'* host-side
    staging when ``ir`` is given (the executor ``device_put``\\ s every
    admitted group, and the first stage's inputs are often the pipeline's
    biggest tensors); without an ``ir`` the input bytes are unknown and
    stage 0 stays uncharged.

    On a single-device inventory every replica lands on ordinal 0 with
    no transfer charge anywhere — the executor detects that and degrades
    to the host-thread behavior, paying no staging.  Mutates and returns
    ``plan``.
    """
    n_dev = len(inventory)
    load = [0.0] * n_dev
    order = sorted(range(len(plan.stages)),
                   key=lambda i: -float(plan.stages[i].est_time_ms))
    for i in order:
        s = plan.stages[i]
        r = max(int(s.replicas), 1)
        chosen: list[int] = []
        for j in range(r):
            pool = [d for d in range(n_dev) if d not in chosen] or \
                list(range(n_dev))
            share = float(s.est_time_ms) / r
            # load[d] is already the device's busy TIME (speed-normalized
            # at accumulation); pick the device that would finish this
            # replica's share earliest
            d = min(pool, key=lambda d: (
                load[d] + share / inventory.spec(d).speed, d))
            chosen.append(d)
            load[d] += share / inventory.spec(d).speed
        s.devices = chosen
        s.device_speeds = [float(inventory.spec(d).speed) for d in chosen]
    # boundary transfer: charged where the device set changes hands.  A
    # single-distinct-device plan degrades in the executor (no puts at
    # all), so nothing is charged anywhere.
    multi = len({d for s in plan.stages for d in s.devices}) > 1
    if plan.stages:
        s0 = plan.stages[0]
        s0.xfer_in_ms = 0.0
        if multi and ir is not None:
            # captured inputs (closure weights) are staged once at deploy,
            # not shipped per token — only true token inputs cost transfer
            cap = getattr(ir, "captured", {})
            in_bytes = sum(ir.values[v].nbytes for v in ir.graph_inputs
                           if v not in cap)
            if in_bytes > 0:
                bw = min(inventory.device_class(d).xfer_bw
                         for d in s0.devices)
                s0.xfer_in_ms = transfer_ms(in_bytes, bw)
    for a, b in zip(plan.stages[:-1], plan.stages[1:]):
        cur = set(b.devices)
        if multi and cur != set(a.devices) and b.comm_in_bytes > 0:
            bw = min(inventory.device_class(d).xfer_bw
                     for d in (cur | set(a.devices)))
            b.xfer_in_ms = transfer_ms(b.comm_in_bytes, bw)
        else:
            b.xfer_in_ms = 0.0
    return plan


# --------------------------------------------------------------------------- #
# Fusion pass — #pragma HLS dataflow analog, now cost-model driven
# --------------------------------------------------------------------------- #
def _clone_ir_shell(ir: CourierIR, name: str) -> CourierIR:
    """Copy an IR's values (links cleared) and graph I/O, but no nodes.

    The rebuild idiom shared by :func:`fuse_adjacent_hw` and
    :func:`split_fused_node`: producer/consumer links are re-derived by
    ``add_node`` as the caller adds its new node list.
    """
    out = CourierIR(name)
    out.values = {k: type(v)(**{**v.__dict__, "consumers": [],
                                "producer": None})
                  for k, v in ir.values.items()}
    out.graph_inputs = list(ir.graph_inputs)
    out.graph_outputs = list(ir.graph_outputs)
    out.captured = dict(getattr(ir, "captured", {}))
    return out



def working_set_bytes(ir: CourierIR, value_names: "Iterable[str]", *,
                      row_block: int = 8, halo_rows: int = 4,
                      itemsize: int = 4) -> int:
    """Resident VMEM bytes for one row-block tile of each named value.

    For a value shaped ``(rows, ...)`` the tile is ``min(rows, row_block +
    halo_rows)`` rows of ``prod(shape[1:])`` elements; rank-0/1 values count
    whole (they are broadcast operands like norm scales).  ``halo_rows``
    over-approximates stencil halos so the check errs toward rejecting.
    Shared by the fusion-time gate (:func:`fused_working_set_bytes`) and the
    static verifier's ``vmem-spill`` re-check on committed plans.
    """
    import numpy as np

    total = 0
    for vn in set(value_names):
        v = ir.values[vn]
        if len(v.shape) >= 2:
            rows = min(v.shape[0], row_block + halo_rows)
            row_el = int(np.prod(v.shape[1:], dtype=np.int64))
            total += rows * row_el * itemsize
        else:
            total += max(v.nbytes, itemsize)
    return total


def fused_working_set_bytes(ir: CourierIR, run: Sequence[Node], *,
                            row_block: int = 8, halo_rows: int = 4,
                            itemsize: int = 4) -> int:
    """Resident VMEM bytes a row-block fused kernel needs for ``run``.

    A fused stencil/elementwise kernel keeps one row-block tile of every
    value the run touches (inputs, intermediates, outputs) resident at once;
    see :func:`working_set_bytes` for the per-value tile model.
    """
    seen: set[str] = set()
    for n in run:
        seen.update(n.inputs)
        seen.update(n.outputs)
    return working_set_bytes(ir, seen, row_block=row_block,
                             halo_rows=halo_rows, itemsize=itemsize)


def make_model_fused_cost(ir: CourierIR, *, vmem_bytes: int = VMEM_BYTES,
                          row_block: int = 8,
                          ) -> Callable[[list[Node]], FusionEstimate]:
    """Build the cost-model fusion estimator for ``fuse_adjacent_hw``.

    Returns a ``run -> FusionEstimate`` callable: the fused kernel's roofline
    with the intermediates' HBM write+read traffic removed, gated by the
    VMEM working-set check (a spilling fusion reports ``fused_ms = inf`` and
    is therefore always rejected).  Nodes must carry ``flops``/``bytes_rw``
    annotations (from ``CostModel.annotate`` or the database's ``cost_hw``
    providers); a run containing an unannotated node is conservatively
    unfusable — exactly the paper's stance when the synthesis report is
    missing.
    """
    def estimate(run: list[Node]) -> FusionEstimate | float:
        parts = []
        for n in run:
            if n.flops is None or n.bytes_rw is None:
                return float("inf")        # no model → don't gamble on fusion
            parts.append(NodeCost(flops=n.flops, bytes_rw=n.bytes_rw,
                                  measured_ms=n.time_ms))
        inter = sum(ir.values[o].nbytes
                    for n in run[:-1] for o in n.outputs)
        ws = fused_working_set_bytes(ir, run, row_block=row_block)
        return fused_cost(parts, inter, vmem_required=ws,
                          vmem_bytes=vmem_bytes)
    return estimate


def split_fused_node(ir: CourierIR, name: str,
                     part_times_ms: Sequence[float] | None = None) -> CourierIR:
    """Undo one fusion: replace a fused node with its original parts.

    The inverse of :func:`fuse_adjacent_hw` for a single node, used by the
    profile-guided re-planner when the *measured* time of a fused kernel
    contradicts the model that justified fusing (the estimate said the
    mega-kernel wins; the profile says it became the bottleneck — the exact
    situation the paper hit with its fused cvtColor+cornerHarris HLS
    module, discovered online here instead of at synthesis time).

    Part nodes are reconstructed from the routing metadata recorded at
    fusion time (``fused_part_inputs/outputs``, ``fused_params``).
    ``part_times_ms`` sets the parts' processing times; by default the
    fused node's time is split evenly (callers with a cost model can
    re-annotate afterwards).  Returns a new IR; the input is not mutated.
    """
    node = ir.node(name)
    if not node.fused_from:
        raise ValueError(f"{name!r} is not a fused node")
    if not node.fused_part_inputs or not node.fused_part_outputs:
        raise ValueError(f"{name!r} carries no per-part routing metadata; "
                         "only nodes built by fuse_adjacent_hw can be split")
    keys = node.fn_key.split("+")
    n_parts = len(node.fused_from)
    if part_times_ms is None:
        t = (node.time_ms or 0.0) / n_parts
        part_times_ms = [t] * n_parts
    if len(part_times_ms) != n_parts:
        raise ValueError(f"need {n_parts} part times, got {len(part_times_ms)}")
    parts = []
    for i, pname in enumerate(node.fused_from):
        params = dict(node.fused_params[i]) if node.fused_params else {}
        kw = (list(node.fused_part_kw[i]) if node.fused_part_kw else [])
        parts.append(Node(
            name=pname, fn_key=keys[i],
            inputs=list(node.fused_part_inputs[i]),
            outputs=list(node.fused_part_outputs[i]),
            input_kw=kw,
            params=params, time_ms=float(part_times_ms[i]),
            time_source=node.time_source,
            serial_only=node.serial_only))

    out = _clone_ir_shell(ir, ir.name + "+defused")
    for n in ir.nodes:
        if n.name == name:
            for p in parts:
                out.add_node(p)
        else:
            out.add_node(n)
    out.validate()
    return out


def fuse_adjacent_hw(ir: CourierIR, db: ModuleDatabase,
                     fused_cost_ms: Callable[[list[Node]], float]
                     | str | None = None,
                     accept_threshold: float = 1.0,
                     vmem_bytes: int = VMEM_BYTES) -> CourierIR:
    """Merge maximal runs of adjacent DB-hit nodes with no branch.

    A run is fusable when every node has an accelerated module and the run
    is *closed*: every non-final node's outputs are consumed only by nodes
    inside the run and are not graph outputs (paper: "if the functions
    have no branch nor loop" — branches that stay inside the run are fine:
    a MoE gate feeding both dispatch and combine fuses as one run, and
    keyword-bound operands replay through the recorded ``fused_part_kw``
    routing).  Stateful nodes (``Node.state``) never fuse — their host-side
    slot mutations can't live inside a composed hw kernel.  A fusion is
    accepted only when its estimated time ``<= accept_threshold *
    max(individual times)`` — i.e. the fused module must not become the new
    bottleneck, encoding the paper's rejection of their slow fused
    cvtColor+cornerHarris module.

    ``fused_cost_ms`` may be:

    * ``None`` — conservative: the pass fuses nothing (seed behavior);
    * ``"model"`` — use :func:`make_model_fused_cost`: accept fusions the
      roofline says win (VMEM-resident intermediates), reject ones whose
      working set spills VMEM;
    * a callable ``run -> float | FusionEstimate`` — custom estimator.  A
      returned :class:`~repro.core.costmodel.FusionEstimate` additionally
      annotates the fused node with the modeled flops / HBM bytes so the
      partitioners see the reduced traffic.
    """
    if fused_cost_ms is None:
        return ir
    if fused_cost_ms == "model":
        fused_cost_ms = make_model_fused_cost(ir, vmem_bytes=vmem_bytes)
    out = _clone_ir_shell(ir, ir.name + "+fused")

    def hw(n: Node) -> bool:
        if getattr(n, "state", None):
            # a stateful node's host-side slot mutation cannot live inside
            # a composed hw kernel — never a fusion candidate
            return False
        e = db.lookup(n.fn_key)
        return e is not None and e.has_hw(*[ir.values[i].shape for i in n.inputs])

    def closed_prefix(cand: list[Node]) -> bool:
        """Every non-final node's outputs stay inside ``cand`` and are not
        graph outputs.  Multi-consumer intermediates are accepted when ALL
        consumers sit in the prefix (the MoE gate → dispatch+combine
        diamond); an output escaping the prefix — or with no consumer at
        all — keeps the run unfused at this length."""
        names = {n.name for n in cand}
        return all(
            o not in ir.graph_outputs           # fusing would hide it
            and ir.values[o].consumers
            and all(c in names for c in ir.values[o].consumers)
            for n in cand[:-1] for o in n.outputs)

    i = 0
    new_nodes: list[Node] = []
    while i < len(ir.nodes):
        # grow the maximal adjacent hw span, then take the longest closed
        # prefix (>= 2) as the fusion candidate
        j = i
        while j < len(ir.nodes) and hw(ir.nodes[j]):
            j += 1
        run = [ir.nodes[i]]
        for L in range(j - i, 1, -1):
            cand = ir.nodes[i:i + L]
            if closed_prefix(cand):
                run = cand
                break
        if len(run) >= 2:
            est = fused_cost_ms(run)
            fe = est if isinstance(est, FusionEstimate) else None
            est_ms = fe.fused_ms if fe is not None else float(est)
            worst = max(n.time_ms or 0.0 for n in run)
            if est_ms <= accept_threshold * worst:
                merged_params: dict = {}
                for n in run:
                    merged_params.update(n.params)
                # external inputs: everything the run consumes that it does
                # not itself produce (first-part inputs AND side operands of
                # later parts, e.g. a fused matmul's weight), in first-use
                # order — this is the fused node's calling convention.
                produced = {o for n in run for o in n.outputs}
                ext_inputs: list[str] = []
                for n in run:
                    for inp in n.inputs:
                        if inp not in produced and inp not in ext_inputs:
                            ext_inputs.append(inp)
                fused = Node(
                    name="+".join(n.name for n in run),
                    fn_key="+".join(n.fn_key for n in run),
                    inputs=ext_inputs,
                    outputs=list(run[-1].outputs),
                    params=merged_params, time_ms=est_ms,
                    placement=Placement.hw(),
                    fused_from=[n.name for n in run],
                    fused_input_shapes=[
                        [ir.values[i].shape for i in n.inputs] for n in run],
                    fused_params=[dict(n.params) for n in run],
                    fused_part_inputs=[list(n.inputs) for n in run],
                    fused_part_outputs=[list(n.outputs) for n in run],
                    fused_part_kw=[list(n.input_kw or [None] * len(n.inputs))
                                   for n in run],
                    serial_only=any(n.serial_only for n in run))
                if fe is not None:        # thread the modeled roofline through
                    fused.flops = fe.cost.flops
                    fused.bytes_rw = fe.cost.bytes_rw
                new_nodes.append(fused)
                i += len(run)
                continue
        new_nodes.append(run[0])
        i += 1

    # value producer/consumer links re-derive from the new node list
    for n in new_nodes:
        out.add_node(n)
    out.validate()
    return out
