"""SPMD token pipeline — the paper's TBB pipeline at pod scale.

Courier-FPGA's deployed artifact is a *token-based software pipeline*: each
stage (a group of functions, some on CPU, some as FPGA modules) processes
token k while the upstream stage already works on token k+1, intermediate
data moving through external memory.  On a TPU pod the native equivalent is
microbatch pipeline parallelism executed inside ``shard_map``:

    token            = microbatch
    pipeline stage   = contiguous group of model layers (Courier partition)
    TBB thread pool  = mesh devices along the ``stage`` axis
    DDR3 hand-off    = ``jax.lax.ppermute`` over the ICI
    token pool       = microbatches in flight (fill/drain schedule)

The stage boundaries come from the same Pipeline Generator partitioners
(paper policy / optimal DP) used for the host pipeline, so the paper's
balanced-partition idea drives pod-scale layer placement.  Stages may hold
*unequal* layer counts (balanced by cost, not cardinality): per-stage layer
stacks are padded to the maximum and masked with ``lax.cond``.

The whole executor is differentiable — ``jax.grad`` through ``scan`` +
``ppermute`` yields the reverse-permuted backward pipeline automatically,
so the same artifact trains (fwd+bwd) and serves (fwd).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

__all__ = ["stack_stage_params", "stage_apply", "spmd_pipeline_fn",
           "pipeline_microbatches"]


def _shard_map(fn, mesh, in_specs, out_specs):
    """shard_map across jax versions: top-level (>=0.5, check_vma) vs
    jax.experimental.shard_map (0.4.x, check_rep)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map
    return shard_map(fn, mesh=mesh, in_specs=in_specs,
                     out_specs=out_specs, check_rep=False)


# --------------------------------------------------------------------------- #
# Parameter staging
# --------------------------------------------------------------------------- #
def stack_stage_params(layer_params: Any, boundaries: Sequence[int]) -> tuple[Any, jax.Array]:
    """[L, ...] layer-stacked params → ([S, Lmax, ...] padded, lengths[S]).

    ``boundaries`` are stage start indices, e.g. [0, 3, 8] for L=10 gives
    stages of 3, 5 and 2 layers.  Padding layers are zeros and are skipped
    at run time via the lengths mask.
    """
    bounds = list(boundaries)
    L = jax.tree.leaves(layer_params)[0].shape[0]
    if bounds[0] != 0:
        raise ValueError("boundaries must start at 0")
    ends = bounds[1:] + [L]
    lengths = np.array([e - b for b, e in zip(bounds, ends)], dtype=np.int32)
    if (lengths <= 0).any():
        raise ValueError(f"empty stage in boundaries {bounds} for L={L}")
    lmax = int(lengths.max())

    def stack(x):
        segs = []
        for b, e in zip(bounds, ends):
            seg = x[b:e]
            pad = [(0, lmax - (e - b))] + [(0, 0)] * (x.ndim - 1)
            segs.append(jnp.pad(seg, pad))
        return jnp.stack(segs)          # [S, Lmax, ...]

    return jax.tree.map(stack, layer_params), jnp.asarray(lengths)


# --------------------------------------------------------------------------- #
# One stage = masked scan over its (padded) layers
# --------------------------------------------------------------------------- #
def stage_apply(block_fn: Callable[[Any, jax.Array], jax.Array],
                stage_params: Any, length: jax.Array, x: jax.Array) -> jax.Array:
    """Apply ``length`` layers of the padded [Lmax, ...] stack to x."""
    lmax = jax.tree.leaves(stage_params)[0].shape[0]

    def body(h, inp):
        lp, i = inp
        h2 = jax.lax.cond(i < length, lambda: block_fn(lp, h), lambda: h)
        return h2, None

    h, _ = jax.lax.scan(body, x, (stage_params, jnp.arange(lmax)))
    return h


# --------------------------------------------------------------------------- #
# The pipeline step loop (runs INSIDE shard_map over ``axis_name``)
# --------------------------------------------------------------------------- #
def spmd_pipeline_fn(block_fn: Callable[[Any, jax.Array], jax.Array],
                     n_stages: int, axis_name: str = "stage") -> Callable:
    """Build fn(stage_params, lengths, xs) for use inside shard_map.

    Per-device inputs:
      stage_params — this device's stage stack, leaves [1, Lmax, ...]
      lengths      — [S] per-stage layer counts (replicated)
      xs           — [M, mb, ...] all microbatch tokens (replicated)

    Returns out_buf [M, mb, ...]; only the *last* stage's buffer holds the
    pipeline outputs (use out_specs P(axis) and slice [-1] outside, or wrap
    with :func:`pipeline_microbatches`).
    """

    def fn(stage_params, lengths, xs):
        stage = jax.lax.axis_index(axis_name)
        params = jax.tree.map(lambda a: a[0], stage_params)   # drop stage dim
        my_len = lengths[stage]
        M = xs.shape[0]
        T = M + n_stages - 1
        fwd = [(i, i + 1) for i in range(n_stages - 1)]

        def step(carry, t):
            recv, out_buf = carry
            # stage 0 admits token t (serial_in_order entry)
            tok = jax.lax.dynamic_index_in_dim(
                xs, jnp.minimum(t, M - 1), axis=0, keepdims=False)
            x = jnp.where(stage == 0, tok, recv)
            y = stage_apply(block_fn, params, my_len, x)
            # last stage retires token t-(S-1) (serial_in_order exit)
            oidx = jnp.clip(t - (n_stages - 1), 0, M - 1)
            upd = jax.lax.dynamic_update_slice_in_dim(
                out_buf, y[None], oidx, axis=0)
            emit = (stage == n_stages - 1) & (t >= n_stages - 1)
            out_buf = jnp.where(emit, upd, out_buf)
            # hand token to the next stage over the ICI (the DDR3 analog)
            recv = jax.lax.ppermute(y, axis_name, fwd) if n_stages > 1 else y
            return (recv, out_buf), None

        zero = jnp.zeros_like(xs[0])
        out0 = jnp.zeros_like(xs)
        (_, out_buf), _ = jax.lax.scan(step, (zero, out0), jnp.arange(T))
        return out_buf

    return fn


# --------------------------------------------------------------------------- #
# Mesh-level convenience wrapper
# --------------------------------------------------------------------------- #
def pipeline_microbatches(mesh, block_fn: Callable, layer_params: Any,
                          boundaries: Sequence[int], xs: jax.Array,
                          axis_name: str = "stage",
                          batch_axis: str | None = None) -> jax.Array:
    """Run [M, mb, ...] microbatches through the staged pipeline on ``mesh``.

    ``layer_params`` leaves are [L, ...]; ``boundaries`` come from a
    PipelinePlan (stage start layer indices).  Returns [M, mb, ...] outputs.
    When ``batch_axis`` is given, the microbatch dim of ``xs`` is sharded
    over it (data parallel × pipeline parallel).
    """
    n_stages = mesh.shape[axis_name]
    if len(boundaries) != n_stages:
        raise ValueError(f"{len(boundaries)} stage boundaries for "
                         f"{n_stages}-way '{axis_name}' mesh axis")
    staged, lengths = stack_stage_params(layer_params, boundaries)
    fn = spmd_pipeline_fn(block_fn, n_stages, axis_name)

    mb_spec = P(None, batch_axis) if batch_axis else P()
    shmap = _shard_map(
        fn, mesh=mesh,
        in_specs=(jax.tree.map(lambda _: P(axis_name), staged),
                  P(), mb_spec),
        out_specs=P(axis_name))
    out = shmap(staged, lengths, xs)           # [S*M, mb, ...] stacked by stage
    # every stage contributed an [M, ...] buffer; only the last stage's holds
    # the retired tokens (serial_in_order exit)
    return out.reshape((n_stages, xs.shape[0]) + out.shape[1:])[-1]
