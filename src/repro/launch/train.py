"""Training launcher — end-to-end driver (deliverable b).

Runs a real training loop for any ``--arch`` (reduced or full config) with
the complete substrate stack: synthetic data pipeline with prefetch,
AdamW, per-layer remat, checkpointing, fault-tolerant restart, straggler
monitoring.  On this CPU container use ``--reduced`` (the full configs are
exercised via the dry-run).

    python -m repro.launch.train --arch gemma3-12b --reduced --steps 200
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointStore
from repro.configs import ARCH_IDS, get_config
from repro.data import SyntheticLMData
from repro.models import LM
from repro.optim import adamw_init
from repro.runtime import FaultTolerantDriver, StragglerMonitor

from .steps import make_train_step


def build(cfg, steps: int, lr: float, seq_len: int, global_batch: int):
    model = LM(cfg)
    data = SyntheticLMData(vocab=cfg.vocab, seq_len=seq_len,
                           global_batch=global_batch, seed=0)
    _, step_fn = make_train_step(cfg, mesh=None, seq_parallel=False,
                                 lr=lr, warmup=max(steps // 20, 5),
                                 total_steps=steps, loss_chunk=min(512, seq_len))
    jstep = jax.jit(step_fn, donate_argnums=(0,))

    def step(state, batch):
        b = {"ids": jnp.asarray(batch.ids), "labels": jnp.asarray(batch.labels),
             "mask": jnp.asarray(batch.mask)}
        if cfg.embeds_in:
            # stub modality frontend: embed tokens via the tied table
            b["embeds"] = jnp.take(state["params"]["embed"]["table"],
                                   b.pop("ids"), axis=0)
            b["labels"] = batch.labels
        if cfg.cross_attn_every:
            b["img_embeds"] = jnp.zeros(
                (batch.ids.shape[0], cfg.n_img_tokens, cfg.d_model),
                jnp.dtype(cfg.dtype))
        return jstep(state, b)

    params = model.init(jax.random.PRNGKey(0))
    state = {"params": params, "opt": adamw_init(params)}
    return state, step, data


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="gemma3-12b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="artifacts/ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    print(f"[train] arch={cfg.arch_id} N={cfg.n_params/1e6:.1f}M params "
          f"(reduced={args.reduced})")
    state, step, data = build(cfg, args.steps, args.lr, args.seq_len,
                              args.batch)
    store = CheckpointStore(f"{args.ckpt_dir}/{cfg.arch_id}", keep=2)
    driver = FaultTolerantDriver(step, store, data,
                                 ckpt_every=args.ckpt_every,
                                 straggler=StragglerMonitor())
    t0 = time.time()
    state, res = driver.run(state, args.steps)
    dt = time.time() - t0
    n_tok = args.steps * args.batch * args.seq_len
    first = np.mean(res.losses[:5]) if len(res.losses) >= 5 else res.losses[0]
    last = np.mean(res.losses[-5:])
    print(f"[train] {res.steps_done} steps in {dt:.1f}s "
          f"({n_tok / dt:.0f} tok/s), loss {first:.3f} -> {last:.3f}, "
          f"restarts={res.restarts}, stragglers={len(driver.straggler.flagged)}")
    assert last < first, "loss did not decrease"


if __name__ == "__main__":
    main()
