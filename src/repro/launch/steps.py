"""Step builders + abstract input specs for every (arch × shape) cell.

``input_specs`` returns ShapeDtypeStruct stand-ins (weak-type-correct,
shardable, no device allocation) for every model input, per task spec:
train lowers ``train_step``; prefill lowers the full forward;
decode_* / long_* lower ``serve_step`` (one token against a seq_len cache).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.models import LM
from repro.models.config import ArchConfig, ShapeConfig
from repro.optim import adamw_init, adamw_update, cosine_schedule

from .sharding import (act_spec, batch_spec, cache_shardings, guard_spec,
                       opt_shardings, param_shardings, param_spec)

Params = Any


def _layer_param_constraint(mesh):
    """Constraint for a *sliced* layer's weights inside the scan body.

    Same rules as storage sharding but with the "data" (FSDP) axis dropped —
    i.e. "this layer is gathered on data, still TP-sharded on model".
    Anchoring the slice keeps GSPMD's FSDP all-gather per-iteration instead
    of hoisting a whole-stack gather out of the loop.
    """
    from .sharding import drop_data

    def con(lp):
        return jax.tree.map_with_path(
            lambda path, a: jax.lax.with_sharding_constraint(
                a, NamedSharding(mesh, drop_data(param_spec(mesh, path, a)))),
            lp)

    return con


# --------------------------------------------------------------------------- #
# batch specs
# --------------------------------------------------------------------------- #
def _sds(shape, dtype, sharding=None):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def batch_structs(cfg: ArchConfig, shape: ShapeConfig, mesh=None) -> dict:
    B, S = shape.global_batch, shape.seq_len
    bs = (NamedSharding(mesh, guard_spec(mesh, batch_spec(mesh), (B, S)))
          if mesh is not None else None)
    dt = jnp.dtype(cfg.dtype)

    def tok3(s):  # [B, s, d] embeds sharding
        if mesh is None:
            return None
        b = batch_spec(mesh)[0]
        return NamedSharding(
            mesh, guard_spec(mesh, P(b, None, None), (B, s, cfg.d_model)))

    if shape.kind == "train":
        out = {
            "labels": _sds((B, S), jnp.int32, bs),
            "mask": _sds((B, S), jnp.float32, bs),
        }
        if cfg.embeds_in:
            out["embeds"] = _sds((B, S, cfg.d_model), dt, tok3(S))
        else:
            out["ids"] = _sds((B, S), jnp.int32, bs)
        if cfg.cross_attn_every:
            out["img_embeds"] = _sds((B, cfg.n_img_tokens, cfg.d_model), dt,
                                     tok3(cfg.n_img_tokens))
        return out
    if shape.kind == "prefill":
        out = {}
        if cfg.embeds_in:
            out["embeds"] = _sds((B, S, cfg.d_model), dt, tok3(S))
        else:
            out["ids"] = _sds((B, S), jnp.int32, bs)
        if cfg.cross_attn_every:
            out["img_embeds"] = _sds((B, cfg.n_img_tokens, cfg.d_model), dt,
                                     tok3(cfg.n_img_tokens))
        return out
    # decode: one new token against a seq_len cache
    out = {"pos": _sds((), jnp.int32,
                       NamedSharding(mesh, P()) if mesh is not None else None)}
    if cfg.embeds_in:
        out["embeds"] = _sds((B, 1, cfg.d_model), dt, tok3(1))
    else:
        out["ids"] = _sds((B, 1), jnp.int32, bs)
    return out


def abstract_params(cfg: ArchConfig) -> Params:
    model = LM(cfg)
    key = jax.random.PRNGKey(0)
    return jax.eval_shape(model.init, key)


def abstract_cache(cfg: ArchConfig, batch: int, cache_len: int) -> Params:
    model = LM(cfg)
    return jax.eval_shape(lambda: model.init_cache(batch, cache_len))


def with_shardings(mesh, tree: Params, shardings: Params) -> Params:
    """Attach shardings to a ShapeDtypeStruct pytree."""
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        tree, shardings)


# --------------------------------------------------------------------------- #
# train step
# --------------------------------------------------------------------------- #
def make_train_step(cfg: ArchConfig, mesh=None, *, scan_chunks: int = 0,
                    seq_parallel: bool = True, lr: float = 3e-4,
                    warmup: int = 200, total_steps: int = 20000,
                    remat: bool = True, unroll: bool = False,
                    loss_chunk: int = 512):
    model = LM(cfg)
    sched = cosine_schedule(lr, warmup, total_steps)
    con = None
    pcon = _layer_param_constraint(mesh) if mesh is not None else None
    if mesh is not None:
        from repro.models.layers import set_attention_mesh
        set_attention_mesh(mesh)
    if mesh is not None and seq_parallel:
        sp = NamedSharding(mesh, act_spec(mesh))
        con = lambda h: jax.lax.with_sharding_constraint(h, sp)

    def train_step(state, batch):
        def loss_fn(p):
            kw = {}
            ids = batch.get("ids")
            if cfg.embeds_in:
                kw["embeds"] = batch["embeds"]
            if cfg.cross_attn_every:
                kw["img_embeds"] = batch["img_embeds"]
            h, aux = model.apply(p, ids, remat=remat, act_constraint=con,
                                 param_constraint=pcon,
                                 scan_chunks=scan_chunks, unroll=unroll, **kw)
            ce = model.loss(p, h, batch["labels"], batch["mask"],
                            chunk=loss_chunk)
            total = ce
            if cfg.n_experts:
                total = (total + 1e-2 * aux["load_balance_loss"]
                         + 1e-3 * aux["router_z_loss"])
            return total, (ce, aux)

        (_, (ce, aux)), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            state["params"])
        params, opt, om = adamw_update(grads, state["opt"], state["params"],
                                       lr=sched)
        metrics = {"loss": ce, **om}
        if cfg.n_experts:
            metrics["dropped_frac"] = aux["dropped_frac"]
        return {"params": params, "opt": opt}, metrics

    return model, train_step


def train_state_structs(cfg: ArchConfig, mesh):
    params = abstract_params(cfg)
    opt = jax.eval_shape(adamw_init, params)
    ps = param_shardings(mesh, params)
    os_ = opt_shardings(mesh, opt, params)
    state = {"params": with_shardings(mesh, params, ps),
             "opt": type(opt)(step=with_shardings(mesh, (opt.step), os_.step),
                              m=with_shardings(mesh, opt.m, os_.m),
                              v=with_shardings(mesh, opt.v, os_.v))}
    shardings = {"params": ps, "opt": os_}
    return state, shardings


# --------------------------------------------------------------------------- #
# serve steps
# --------------------------------------------------------------------------- #
def make_prefill_step(cfg: ArchConfig, mesh=None, *, unroll: bool = False):
    model = LM(cfg)
    if mesh is not None:
        from repro.models.layers import set_attention_mesh
        set_attention_mesh(mesh)

    def prefill_step(params, batch):
        kw = {}
        ids = batch.get("ids")
        if cfg.embeds_in:
            kw["embeds"] = batch["embeds"]
        if cfg.cross_attn_every:
            kw["img_embeds"] = batch["img_embeds"]
        h, _ = model.apply(params, ids, remat=False, unroll=unroll, **kw)
        return model.logits(params, h[:, -1:])

    return model, prefill_step


def make_decode_step(cfg: ArchConfig, mesh=None, *, unroll: bool = False):
    model = LM(cfg)
    pcon = _layer_param_constraint(mesh) if mesh is not None else None
    if mesh is not None:
        from repro.models.layers import set_attention_mesh
        set_attention_mesh(mesh)

    def serve_step(params, cache, batch):
        kw = {}
        ids = batch.get("ids")
        if cfg.embeds_in:
            kw["embeds"] = batch["embeds"]
        logits, cache = model.decode_step(params, ids, cache, batch["pos"],
                                          unroll=unroll,
                                          param_constraint=pcon, **kw)
        return logits, cache

    return model, serve_step


def serve_structs(cfg: ArchConfig, shape: ShapeConfig, mesh,
                  serving_layout: bool = False):
    from .sharding import param_shardings_serving
    params = abstract_params(cfg)
    ps = (param_shardings_serving(mesh, params) if serving_layout
          else param_shardings(mesh, params))
    out = {"params": with_shardings(mesh, params, ps), "param_shardings": ps}
    if shape.kind == "decode":
        cache = abstract_cache(cfg, shape.global_batch, shape.seq_len)
        cs = cache_shardings(mesh, cfg, cache)
        out["cache"] = with_shardings(mesh, cache, cs)
        out["cache_shardings"] = cs
    return out
