import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run — prove the distribution config is coherent (task §e).

For every (architecture × input shape) cell, on the single-pod 16×16 mesh
and the 2×16×16 multi-pod mesh:

    lowered  = jax.jit(step, ...).lower(**input_specs(arch))
    compiled = lowered.compile()
    print(compiled.memory_analysis())    # proves it fits
    print(compiled.cost_analysis())      # FLOPs/bytes for §Roofline

plus a collective-bytes pass over the post-SPMD HLO (cost_analysis doesn't
report collectives).  Results land in artifacts/dryrun/*.json for
benchmarks/roofline.py and EXPERIMENTS.md §Dry-run.

Usage:
    python -m repro.launch.dryrun --arch gemma3-12b --shape train_4k
    python -m repro.launch.dryrun --all [--multi-pod|--both]
"""
import argparse
import json
import math
import re
import time
import traceback

import jax

from repro.configs import ARCH_IDS, SHAPES, get_config, supports_shape
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import (batch_structs, make_decode_step,
                                make_prefill_step, make_train_step,
                                serve_structs, train_state_structs)

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
}

_COLL_RE = re.compile(
    r"=\s+(?P<types>[^=]*?)\s+"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?P<suffix>-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def collective_bytes(hlo: str) -> dict[str, float]:
    """Sum result bytes of every collective op in post-SPMD HLO text."""
    out: dict[str, float] = {}
    for line in hlo.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        if f"{m.group('op')}-done(" in line:
            continue
        byts = 0.0
        for dt, dims in _SHAPE_RE.findall(m.group("types")):
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            byts += n * _DTYPE_BYTES[dt]
        key = m.group("op")
        out[key] = out.get(key, 0.0) + byts
        out[f"{key}_count"] = out.get(f"{key}_count", 0.0) + 1
    return out


def _mem_dict(mem) -> dict:
    keys = ["argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "alias_size_in_bytes",
            "generated_code_size_in_bytes"]
    d = {}
    for k in keys:
        try:
            d[k] = int(getattr(mem, k))
        except Exception:  # lint: allow-swallow(best-effort memory_analysis probe; absent fields are expected per backend)
            pass
    return d


_COST_KEYS = ("flops", "bytes accessed", "transcendentals", "optimal_seconds")


def _cost_dict(cost) -> dict:
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    return {k: float(cost[k]) for k in _COST_KEYS if k in cost}


def default_scan_chunks(n_layers: int) -> int:
    """Largest divisor of L not exceeding ~sqrt(L) (nested-remat chunk)."""
    best = 1
    for c in range(1, int(math.isqrt(n_layers)) + 2):
        if n_layers % c == 0:
            best = c
    return best


# --------------------------------------------------------------------------- #
# Probes: XLA's cost model counts a while-loop body ONCE (trip count is
# ignored), so the big scanned model under-reports FLOPs/bytes/collectives.
# We therefore compile two tiny *unrolled* variants (k1, k2 layers) on the
# same mesh/shardings and extrapolate linearly in L:
#     total(L) = C(k1) + (C(k2) - C(k1)) / (k2 - k1) * (L - k1)
# Time-recurrence inner scans (rwkv/ssm) remain under-counted and get an
# analytic correction in benchmarks/roofline.py (documented there).
# --------------------------------------------------------------------------- #
def probe_layer_counts(cfg) -> tuple[int, int]:
    if cfg.cross_attn_every:
        return cfg.cross_attn_every, 2 * cfg.cross_attn_every
    if cfg.global_every:
        return cfg.global_every, 2 * cfg.global_every
    return 1, 2


def _probe_one(cfg, shape, mesh, k: int, seq_parallel: bool) -> dict:
    from dataclasses import replace
    ck = replace(cfg, n_layers=k)
    if shape.kind == "train":
        _, step = make_train_step(ck, mesh, scan_chunks=0,
                                  seq_parallel=seq_parallel, unroll=True,
                                  loss_chunk=shape.seq_len)
        state, shardings = train_state_structs(ck, mesh)
        batch = batch_structs(ck, shape, mesh)
        jitted = jax.jit(step, out_shardings=(shardings, None),
                         donate_argnums=(0,))
        with mesh:
            compiled = jitted.lower(state, batch).compile()
    elif shape.kind == "prefill":
        _, step = make_prefill_step(ck, mesh, unroll=True)
        sv = serve_structs(ck, shape, mesh)
        batch = batch_structs(ck, shape, mesh)
        with mesh:
            compiled = jax.jit(step).lower(sv["params"], batch).compile()
    else:
        _, step = make_decode_step(ck, mesh, unroll=True)
        sv = serve_structs(ck, shape, mesh)
        batch = batch_structs(ck, shape, mesh)
        jitted = jax.jit(step, out_shardings=(None, sv["cache_shardings"]),
                         donate_argnums=(1,))
        with mesh:
            compiled = jitted.lower(sv["params"], sv["cache"], batch).compile()
    return {"k": k, "cost": _cost_dict(compiled.cost_analysis()),
            "collectives": collective_bytes(compiled.as_text())}


def probe_extrapolate(p1: dict, p2: dict, n_layers: int) -> dict:
    k1, k2 = p1["k"], p2["k"]
    out = {"flops": 0.0, "bytes": 0.0, "collectives": {}}

    def lerp(a, b):
        return a + (b - a) / (k2 - k1) * (n_layers - k1)

    out["flops"] = lerp(p1["cost"].get("flops", 0.0), p2["cost"].get("flops", 0.0))
    out["bytes"] = lerp(p1["cost"].get("bytes accessed", 0.0),
                        p2["cost"].get("bytes accessed", 0.0))
    keys = set(p1["collectives"]) | set(p2["collectives"])
    for key in keys:
        out["collectives"][key] = lerp(p1["collectives"].get(key, 0.0),
                                       p2["collectives"].get(key, 0.0))
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: str | None = "artifacts/dryrun",
             seq_parallel: bool = True, scan_chunks: int | None = None,
             probe: bool = True, serving_layout: bool = False,
             verbose: bool = True) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rec: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                 "kind": shape.kind,
                 "n_params": cfg.n_params, "n_params_active": cfg.n_params_active,
                 "seq_len": shape.seq_len, "global_batch": shape.global_batch}

    ok, why = supports_shape(cfg, shape)
    if not ok:
        rec.update(status="skip", reason=why)
        _write(rec, out_dir)
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    rec["chips"] = int(n_chips)
    t0 = time.perf_counter()
    try:
        if shape.kind == "train":
            chunks = (default_scan_chunks(cfg.n_layers)
                      if scan_chunks is None else scan_chunks)
            rec["scan_chunks"] = chunks
            _, step = make_train_step(cfg, mesh, scan_chunks=chunks,
                                      seq_parallel=seq_parallel)
            state, shardings = train_state_structs(cfg, mesh)
            batch = batch_structs(cfg, shape, mesh)
            jitted = jax.jit(step, out_shardings=(shardings, None),
                             donate_argnums=(0,))
            with mesh:
                lowered = jitted.lower(state, batch)
        elif shape.kind == "prefill":
            _, step = make_prefill_step(cfg, mesh)
            sv = serve_structs(cfg, shape, mesh, serving_layout=serving_layout)
            batch = batch_structs(cfg, shape, mesh)
            jitted = jax.jit(step)
            with mesh:
                lowered = jitted.lower(sv["params"], batch)
        else:  # decode
            _, step = make_decode_step(cfg, mesh)
            sv = serve_structs(cfg, shape, mesh, serving_layout=serving_layout)
            batch = batch_structs(cfg, shape, mesh)
            jitted = jax.jit(step, out_shardings=(None, sv["cache_shardings"]),
                             donate_argnums=(1,))
            with mesh:
                lowered = jitted.lower(sv["params"], sv["cache"], batch)
        t_lower = time.perf_counter() - t0
        t0 = time.perf_counter()
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        coll = collective_bytes(hlo)
        rec.update(
            status="ok", lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
            memory=_mem_dict(mem), cost=_cost_dict(cost), collectives=coll,
            hlo_bytes=len(hlo))
        if probe:
            try:
                k1, k2 = probe_layer_counts(cfg)
                p1 = _probe_one(cfg, shape, mesh, k1, seq_parallel)
                p2 = _probe_one(cfg, shape, mesh, k2, seq_parallel)
                rec["probe"] = {"p1": p1, "p2": p2,
                                "extrapolated": probe_extrapolate(
                                    p1, p2, cfg.n_layers)}
            except Exception as e:
                rec["probe"] = {"error": f"{type(e).__name__}: {e}"}
        if verbose:
            print(f"[{arch} × {shape_name} × {mesh_name}] OK "
                  f"lower={t_lower:.1f}s compile={t_compile:.1f}s")
            print("  memory_analysis:", rec["memory"])
            c = rec["cost"]
            print(f"  cost: flops={c.get('flops', 0):.3e} "
                  f"bytes={c.get('bytes accessed', 0):.3e}")
            print("  collectives:", {k: f"{v:.3e}" for k, v in coll.items()
                                     if not k.endswith("_count")})
    except Exception as e:
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
        if verbose:
            print(f"[{arch} × {shape_name} × {mesh_name}] FAIL: {rec['error']}")
    _write(rec, out_dir)
    return rec


def _write(rec: dict, out_dir: str | None) -> None:
    if out_dir is None:
        return
    os.makedirs(out_dir, exist_ok=True)
    name = f"{rec['arch']}__{rec['shape']}__{rec['mesh']}.json"
    with open(os.path.join(out_dir, name), "w") as f:
        json.dump(rec, f, indent=1)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both", action="store_true",
                    help="run single-pod and multi-pod meshes")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--no-seq-parallel", action="store_true")
    ap.add_argument("--scan-chunks", type=int, default=None)
    ap.add_argument("--no-probe", action="store_true")
    ap.add_argument("--serving-layout", action="store_true",
                    help="TP-only weights for prefill/decode (no FSDP "
                         "re-gather; see EXPERIMENTS.md §Perf B1')")
    args = ap.parse_args()

    meshes = [False, True] if args.both else [args.multi_pod]
    cells = ([(a, s) for a in ARCH_IDS for s in SHAPES]
             if args.all else [(args.arch, args.shape)])
    n_fail = 0
    for arch, shape in cells:
        for mp in meshes:
            rec = run_cell(arch, shape, mp, out_dir=args.out,
                           seq_parallel=not args.no_seq_parallel,
                           scan_chunks=args.scan_chunks,
                           probe=not args.no_probe,
                           serving_layout=args.serving_layout)
            n_fail += rec["status"] == "error"
    if n_fail:
        raise SystemExit(f"{n_fail} cells failed")


if __name__ == "__main__":
    main()
