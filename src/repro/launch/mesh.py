"""Production meshes.

Functions, not module-level constants, so importing this module never
touches jax device state (the dry-run must set XLA_FLAGS first).
"""
from __future__ import annotations

import jax

try:                                    # AxisType only exists on jax>=0.5
    from jax.sharding import AxisType
except ImportError:
    AxisType = None


def _mesh(shape, axes):
    if AxisType is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; 2 pods = 512 chips when multi_pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_pipeline_mesh(*, n_stages: int = 4, multi_pod: bool = False):  # lint: allow-dead(pod mesh recipe for hillclimb/SPMD runs)
    """Courier pipeline mode: split the model axis into (stage, model).

    Same 256/512 chips, reshaped so the Pipeline Generator's stage
    boundaries map onto the ``stage`` axis (used by the hillclimb and the
    SPMD token-pipeline examples; the baseline dry-run uses
    :func:`make_production_mesh`).
    """
    tp = 16 // n_stages
    if n_stages * tp != 16:
        raise ValueError("n_stages must divide 16")
    if multi_pod:
        return _mesh((2, 16, n_stages, tp), ("pod", "data", "stage", "model"))
    return _mesh((16, n_stages, tp), ("data", "stage", "model"))


def batch_axes(mesh) -> tuple[str, ...]:
    """Mesh axes the global batch shards over."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
