"""Sharding rules — parameter/optimizer/cache layouts for the production mesh.

Scheme (baseline cells):
  * tensor-parallel dim → "model"  (attention heads / FFN hidden / experts)
  * a second, storage-only dim → "data" (FSDP-style; GSPMD all-gathers
    per layer inside the scan, so peak live weights stay ~one layer)
  * optimizer moments follow their param (ZeRO-1 falls out of FSDP here)
  * KV caches: batch → ("pod","data"); kv-heads → "model" when divisible,
    else head_dim → "model" (mistral-style kv=8 < 16)
  * activations (train): sequence-parallel constraint P(batch, "model", —)

Every rule is divisibility-guarded: a dim that doesn't divide its mesh axis
is left unsharded rather than failing (hymba's 25 heads, 32001 vocab...).
"""
from __future__ import annotations

import re
from typing import Any

import jax
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from .mesh import batch_axes


def _div(n: int, mesh, axis: str) -> bool:
    return axis in mesh.axis_names and n % mesh.shape[axis] == 0


def guard_spec(mesh, spec: P, shape: tuple[int, ...]) -> P:
    """Drop spec axes whose mesh size doesn't divide the dim (public guard)."""
    dims = list(spec) + [None] * (len(shape) - len(spec))
    return _nd(mesh, dims, shape)


def _nd(mesh, spec_dims: list, shape: tuple[int, ...]) -> P:
    """Build a PartitionSpec, dropping axes that don't divide."""
    out = []
    for dim, want in zip(shape, spec_dims):
        if want is None:
            out.append(None)
            continue
        axes = want if isinstance(want, tuple) else (want,)
        good: list[str] = []
        rem = dim
        for a in axes:
            if a in mesh.axis_names and rem % mesh.shape[a] == 0:
                good.append(a)
                rem //= mesh.shape[a]
        out.append(tuple(good) if len(good) > 1 else (good[0] if good else None))
    return P(*out)


# --------------------------------------------------------------------------- #
# parameter rules (path-pattern → dim spec)
# --------------------------------------------------------------------------- #
_PARAM_RULES: list[tuple[str, list]] = [
    # embedding: vocab → model (TP) + d → data (FSDP)
    (r"embed/table$",        ["model", "data"]),
    # attention
    (r"attn/wq$",            ["data", "model", None]),
    (r"attn/wk$",            ["data", "model", None]),
    (r"attn/wv$",            ["data", "model", None]),
    (r"attn/wo$",            ["model", "data"]),
    # dense mlp
    (r"mlp/wi$",             ["data", None, "model"]),
    (r"mlp/wo$",             ["model", "data"]),
    # moe (experts → model = EP; within-expert ff → data for storage)
    (r"moe/router$",         [None, None]),
    (r"moe/wi$",             ["model", "data", None, None]),
    (r"moe/wo$",             ["model", "data", None]),
    # ssm (hymba)
    (r"ssm/in_proj$",        ["data", None, "model"]),
    (r"ssm/out_proj$",       ["model", "data"]),
    (r"ssm/(conv|w_dt|w_bc|A_log|dt_bias|D)$", None),   # small → replicate
    # rwkv
    (r"rwkv/(wr|wk|wv|wg|cr)$", ["data", "model"]),
    (r"rwkv/wo$",            ["model", "data"]),
    (r"rwkv/ck$",            ["data", "model"]),
    (r"rwkv/cv$",            ["model", "data"]),
    (r"rwkv/.*",             None),
    # norms & everything small
    (r".*",                  None),
]


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_spec(mesh, path, leaf) -> P:
    """PartitionSpec for one parameter leaf (skips stacked layer dims)."""
    s = _path_str(path)
    shape = leaf.shape
    # leading stacked-layer dims (scan stacks / vlm groups) stay unsharded
    n_stack = 0
    for pat, dims in _PARAM_RULES:
        if re.search(pat, s):
            if dims is None:
                return P()
            n_stack = len(shape) - len(dims)
            if n_stack < 0:
                return P()
            return _nd(mesh, [None] * n_stack + dims, shape)
    return P()


def param_shardings(mesh, params: Any) -> Any:
    return jax.tree.map_with_path(
        lambda path, leaf: NamedSharding(mesh, param_spec(mesh, path, leaf)),
        params)


def drop_data(spec: P) -> P:
    """Remove the FSDP ("data") axis from a spec (serving layout)."""
    out = []
    for s in spec:
        if s is None:
            out.append(None)
        elif isinstance(s, tuple):
            kept = tuple(a for a in s if a != "data")
            out.append(kept if kept else None)
        else:
            out.append(None if s == "data" else s)
    return P(*out)


def param_shardings_serving(mesh, params: Any) -> Any:
    """TP-only weights (no FSDP): serving re-gathers nothing per step.

    Correct when params/model-shards fit HBM next to the KV cache —
    inference has no optimizer state, so the FSDP storage trick that
    training needs just adds an all-gather to every decode step.
    """
    return jax.tree.map_with_path(
        lambda path, leaf: NamedSharding(
            mesh, drop_data(param_spec(mesh, path, leaf))),
        params)


def opt_shardings(mesh, opt_state: Any, params: Any) -> Any:
    """Moments mirror their parameter's sharding; step is replicated."""
    pshard = param_shardings(mesh, params)
    return type(opt_state)(
        step=NamedSharding(mesh, P()),
        m=pshard, v=pshard)


# --------------------------------------------------------------------------- #
# batch / cache / activation specs
# --------------------------------------------------------------------------- #
def batch_spec(mesh) -> P:
    ba = batch_axes(mesh)
    return P(ba if len(ba) > 1 else (ba[0] if ba else None), None)


def act_spec(mesh) -> P:
    """Sequence-parallel activation constraint [B, S, d]."""
    ba = batch_axes(mesh)
    b = ba if len(ba) > 1 else (ba[0] if ba else None)
    return P(b, "model", None)


def cache_spec(mesh, cfg, path, leaf) -> P:
    """KV cache / recurrent state sharding (leaf has leading layer dim)."""
    s = _path_str(path)
    shape = leaf.shape
    ba = batch_axes(mesh)
    b = ba if len(ba) > 1 else (ba[0] if ba else None)
    bdim = shape[1] if len(shape) > 1 else 1

    def bspec():
        # batch must divide; else replicate (long_500k batch=1)
        if b is None:
            return None
        n = int(np.prod([mesh.shape[a] for a in (b if isinstance(b, tuple) else (b,))]))
        return b if bdim % n == 0 else None

    if re.search(r"(^|/)(k|v)$", s) and len(shape) == 5:
        # [L, B, M, KV, hd]
        L, B, M, KV, hd = shape
        kv_ax = "model" if _div(KV, mesh, "model") else None
        hd_ax = "model" if kv_ax is None and _div(hd, mesh, "model") else None
        return P(None, bspec(), None, kv_ax, hd_ax)
    if re.search(r"ssm/h$", s) or re.search(r"/S$", s):
        dims = [None, bspec()] + [None] * (len(shape) - 2)
        # shard the largest trailing dim over model if divisible
        for i in range(2, len(shape)):
            if _div(shape[i], mesh, "model"):
                dims[i] = "model"
                break
        return P(*dims)
    if len(shape) >= 2:
        dims = [None, bspec()] + [None] * (len(shape) - 2)
        for i in range(len(shape) - 1, 1, -1):
            if _div(shape[i], mesh, "model"):
                dims[i] = "model"
                break
        return P(*dims)
    return P()


def cache_shardings(mesh, cfg, cache: Any) -> Any:
    return jax.tree.map_with_path(
        lambda path, leaf: NamedSharding(mesh, cache_spec(mesh, cfg, path, leaf)),
        cache)
