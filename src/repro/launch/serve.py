"""Serving launcher — LM decode driver + pipeline request-queue server.

Two serving modes:

* ``lm`` (default) — batched prefill + KV-cache decode on a reduced LM
  config (deliverable b)::

      python -m repro.launch.serve --arch rwkv6-1.6b --reduced --tokens 32

* ``pipeline`` — a request-queue serving loop over a Courier-built token
  pipeline (the ROADMAP's "serve heavy traffic" front-end)::

      python -m repro.launch.serve --mode pipeline --requests 64

  :class:`RequestQueueServer` accepts requests into per-priority-class
  queues (interactive / batch / best-effort), forms dynamic batches (up to
  ``max_batch``, waiting at most ``max_wait_ms`` after the first request of
  a batch), and feeds them to a
  :class:`~repro.core.executor.PipelineExecutor`.  Backpressure comes from
  the executor's bounded token pool: the batcher blocks inside
  ``submit_many`` while the pool is full, which in turn fills the bounded
  request queue and blocks producers — unless an
  :class:`AdmissionController` is attached, in which case load the queue
  cannot absorb is *shed* (fast-failed with :class:`Overloaded`) instead
  of blocking submitters, and a degradation ladder sheds best-effort
  traffic first.  Per-request latency (queue + execute) is recorded and
  summarized per class by :meth:`RequestQueueServer.stats`.

Overload-protection model (see EXPERIMENTS.md "Overload protection"):

* **Priority classes** — ``submit(..., priority=)`` with strict priority
  across classes (interactive preempts batch preempts best-effort) and
  earliest-deadline-first order within a class; a starvation-avoidance
  credit guarantees a lower class the next batch after it has been passed
  over ``starvation_credit`` times, so batch work still drains under
  sustained interactive load.
* **Admission control** — the controller predicts the queue wait a new
  request would see (dispatch-group period x groups ahead of it) and
  sheds, at submit time, requests that cannot meet their deadline; the
  period starts from the plan's effective (replication-aware) bottleneck
  and is continuously refreshed from the executor's online profile.
* **Graceful degradation** — a pressure ladder derived from the predicted
  backlog: level 1 sheds best-effort, level 2 additionally shrinks the
  batcher's max-wait (partial batches dispatch sooner, trading batching
  efficiency for latency when it matters).
* **End-to-end deadlines** — a request past its deadline is failed with
  :class:`DeadlineExceeded` wherever it is caught: at submit (predicted),
  at dispatch (still queued), or at retirement (in-flight too long) — it
  is never returned late.
"""
from __future__ import annotations

import argparse
import heapq
import math
import threading
import time
from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.core.executor import ExecutorClosed, PipelineExecutor
from repro.models import LM

# priority classes: strict priority in ascending order (0 preempts 1
# preempts 2); PRIORITY_CLASSES names them for stats/benchmark reporting
INTERACTIVE, BATCH, BEST_EFFORT = 0, 1, 2
PRIORITY_CLASSES = ("interactive", "batch", "best_effort")
N_CLASSES = len(PRIORITY_CLASSES)


def priority_of(p: "int | str") -> int:
    """Normalize a priority argument (class index or class name)."""
    if isinstance(p, str):
        try:
            return PRIORITY_CLASSES.index(p.replace("-", "_"))
        except ValueError:
            raise ValueError(f"unknown priority class {p!r}; expected one "
                             f"of {PRIORITY_CLASSES}") from None
    i = int(p)
    if not 0 <= i < N_CLASSES:
        raise ValueError(f"priority must be in [0, {N_CLASSES}) (got {i})")
    return i


class DeadlineExceeded(TimeoutError):
    """A request's ``deadline_ms`` expired before its result could be
    delivered — late work is degraded (failed fast) instead of returned
    late, whether it was still queued or already in flight."""


class WaitTimeout(TimeoutError):
    """:meth:`Request.wait`'s own ``timeout=`` expired before the request
    resolved.  Distinct from :class:`DeadlineExceeded` (the *request's*
    deadline, raised from ``Request.error``) so callers can tell "my wait
    gave up" from "the server failed the request"."""


class Overloaded(RuntimeError):
    """Request shed at submit time by the :class:`AdmissionController`:
    the predicted queue wait exceeds its deadline, the degradation ladder
    is shedding its class, or the bounded queue is full.  Fast-fail —
    the request never consumed queue or executor capacity."""


# --------------------------------------------------------------------------- #
# Request-queue serving loop over a token-pipeline executor
# --------------------------------------------------------------------------- #
@dataclass
class Request:
    """One in-flight serving request with its latency timeline."""

    args: tuple
    t_submit: float
    t_batch: float | None = None      # when the batcher picked it up
    t_done: float | None = None       # when its outputs were ready
    result: Any = None
    error: BaseException | None = None
    deadline_ms: float | None = None  # end-to-end deadline (degrade if past)
    priority: int = INTERACTIVE      # class index into PRIORITY_CLASSES
    # release hook: called exactly once with the request AFTER it resolved
    # (every terminal outcome — served/shed/expired/failed), outside the
    # server lock.  This is where per-request resources pinned at submit
    # time (a KV-cache slot) are returned: a shed or expired request must
    # free its slot exactly like a served one, or the arena leaks.
    on_finish: Any = None
    _event: threading.Event = field(default_factory=threading.Event)
    _finished: bool = False           # owner: RequestQueueServer._lock

    def wait(self, timeout: float | None = None) -> Any:
        """Block for the result.  Raises :class:`WaitTimeout` when
        ``timeout`` expires first (the request may still resolve later —
        a later ``wait`` observes it), and re-raises the request's own
        error (:class:`DeadlineExceeded`, :class:`Overloaded`, executor
        failures) once it resolved unsuccessfully."""
        if not self._event.wait(timeout):
            raise WaitTimeout(
                f"request not served within wait timeout ({timeout} s)")
        if self.error is not None:
            raise self.error
        return self.result

    @property
    def deadline_at(self) -> float:
        """Absolute deadline on the ``perf_counter`` clock (inf if none) —
        the EDF ordering key within a priority class."""
        if self.deadline_ms is None:
            return math.inf
        return self.t_submit + self.deadline_ms / 1e3

    @property
    def latency_ms(self) -> float | None:
        if self.t_done is None:
            return None
        return (self.t_done - self.t_submit) * 1e3

    @property
    def queue_ms(self) -> float | None:
        if self.t_batch is None:
            return None
        return (self.t_batch - self.t_submit) * 1e3


def replication_aware_batching(plan: Any, *, max_batch: int,
                               max_wait_ms: float,
                               max_growth: float = 4.0,
                               min_wait_ms: float = 0.25,
                               ) -> tuple[int, float]:
    """Derive dynamic-batching knobs from the plan's *effective* period.

    A widened stage drains token groups ``r``-wide, so the pipeline's
    steady-state token period is the plan's effective (replication-aware)
    bottleneck, not the serial one.  Holding the batcher at knobs tuned
    for the serial period would starve the replicas: the max-wait deadline
    admits one batch per serial period while the executor could retire
    ``ratio = serial / effective`` of them.  This helper scales the knobs
    by that ratio — ``max_batch`` grows (more tokens per admission keeps
    every replica fed) and ``max_wait_ms`` shrinks (partial batches
    dispatch sooner because the pipeline drains faster) — clamped to
    ``max_growth`` so a massively widened plan doesn't balloon the
    compiled batch shape, and to ``min_wait_ms`` so the batcher never
    busy-spins.  A serial plan (ratio 1) returns the knobs unchanged.
    """
    if max_batch < 1:
        raise ValueError(f"max_batch must be >= 1, got {max_batch}")
    serial = float(plan.bottleneck_ms)
    eff = float(plan.effective_bottleneck_ms)
    if serial <= 0.0 or eff <= 0.0:
        return max_batch, max_wait_ms
    ratio = min(max(serial / eff, 1.0), float(max_growth))
    return (max(1, int(round(max_batch * ratio))),
            max(max_wait_ms / ratio, min_wait_ms))


def _percentile(xs: list, q: float) -> float:
    """Exact linear-interpolation percentile over finite samples only;
    0.0 for empty windows.

    Latency windows can be tiny (a 1-request batch right after startup) or
    carry non-finite entries (a timed-out clock pair); filtering here keeps
    the stats endpoint NaN-free instead of poisoning dashboards.  Linear
    interpolation (the numpy default, implemented explicitly here) makes
    tail quantiles — p99/p999 over modest windows — exact instead of
    snapping to the nearest sample rank.
    """
    vals = sorted(float(x) for x in xs
                  if x is not None and math.isfinite(float(x)))
    if not vals:
        return 0.0
    q = min(max(float(q), 0.0), 100.0)
    rank = (q / 100.0) * (len(vals) - 1)
    lo = int(rank)
    hi = min(lo + 1, len(vals) - 1)
    frac = rank - lo
    return vals[lo] * (1.0 - frac) + vals[hi] * frac


def _latency_summary(lat: list) -> dict:
    return {
        "mean": float(np.mean(lat)) if lat else 0.0,
        "p50": _percentile(lat, 50),
        "p95": _percentile(lat, 95),
        "p99": _percentile(lat, 99),
        "p999": _percentile(lat, 99.9),
        "max": max(lat) if lat else 0.0,
    }


# --------------------------------------------------------------------------- #
# Admission control + degradation ladder
# --------------------------------------------------------------------------- #
class AdmissionController:
    """Submit-time admission control with a degradation ladder.

    The controller predicts the queueing delay a new request would see —
    ``ceil(depth_ahead / batch_hint) * period_ms``, where ``period_ms`` is
    the service period of one dispatch group (the pipeline's effective,
    replication-aware bottleneck) and ``depth_ahead`` counts the queued
    requests at its priority or higher plus the executor's in-flight
    tokens — and **sheds** (fast-fails with :class:`Overloaded`) requests
    that cannot meet their deadline *at submit time*, before they consume
    queue or token-pool capacity.

    A **degradation ladder** derived from the total predicted backlog
    (all classes) relative to ``slo_ref_ms`` degrades service under
    sustained pressure instead of collapsing:

    * level 0 — backlog <= ``shed_at`` x ref: admit everything;
    * level 1 — backlog > ``shed_at`` x ref: shed best-effort;
    * level 2 — backlog > ``degrade_at`` x ref: shed best-effort AND
      report ``max_wait_scale() < 1`` so the batcher dispatches partial
      batches sooner (latency over batching efficiency).

    ``period_ms`` starts from the plan's model (or a calibration run) and
    is refreshed from the online profile via :meth:`update_period`, so the
    admission rule tracks the pipeline the executor actually runs, not the
    one the planner predicted.
    """

    def __init__(self, period_ms: float, *, batch_hint: int = 1,
                 slo_ref_ms: float | None = None, shed_at: float = 0.5,
                 degrade_at: float = 1.0, degraded_wait_scale: float = 0.5,
                 deadline_slack: float = 1.0, ref_periods: float = 20.0):
        if period_ms <= 0.0:
            raise ValueError(f"period_ms must be > 0 (got {period_ms})")
        if batch_hint < 1:
            raise ValueError(f"batch_hint must be >= 1 (got {batch_hint})")
        if not 0.0 < shed_at <= degrade_at:
            raise ValueError(f"need 0 < shed_at <= degrade_at "
                             f"(got {shed_at}, {degrade_at})")
        if not 0.0 < degraded_wait_scale <= 1.0:
            raise ValueError(f"degraded_wait_scale must be in (0, 1] "
                             f"(got {degraded_wait_scale})")
        self.period_ms = float(period_ms)    # owner: updater (single writer)
        self.batch_hint = int(batch_hint)
        self.slo_ref_ms = None if slo_ref_ms is None else float(slo_ref_ms)
        self.shed_at = float(shed_at)
        self.degrade_at = float(degrade_at)
        self.degraded_wait_scale = float(degraded_wait_scale)
        self.deadline_slack = float(deadline_slack)
        self.ref_periods = float(ref_periods)
        self._lock = threading.Lock()
        self._level = 0
        self._window_max_level = 0       # worst level seen this window
        self._streak = 0                 # consecutive level-2 windows
        self.admitted = [0] * N_CLASSES
        self.shed = [0] * N_CLASSES
        self.shed_reasons = {"deadline": 0, "ladder": 0, "queue_full": 0}

    @classmethod
    def from_plan(cls, plan: Any, *, max_batch: int = 1,
                  **kwargs: Any) -> "AdmissionController":
        """Seed the period from the plan's effective (replication-aware)
        bottleneck; the online profile refines it once traffic flows."""
        return cls(max(float(plan.effective_bottleneck_ms), 1e-3),
                   batch_hint=max_batch, **kwargs)

    # -- model ---------------------------------------------------------------- #
    def update_period(self, period_ms: float) -> None:
        """Refresh the dispatch-group period from the online profile."""
        if period_ms and period_ms > 0.0:
            self.period_ms = float(period_ms)

    def predicted_wait_ms(self, depth_ahead: int) -> float:
        """Queue-wait prediction for a request with ``depth_ahead``
        requests (queued at >= its priority, plus in-flight) before it:
        full dispatch groups x the per-group service period."""
        groups = math.ceil(max(int(depth_ahead), 0) / self.batch_hint)
        return groups * self.period_ms

    def _ref_ms(self) -> float:
        return self.slo_ref_ms if self.slo_ref_ms is not None \
            else self.ref_periods * self.period_ms

    def level(self, depth_total: int) -> int:
        """Degradation-ladder level for the current total backlog."""
        backlog = self.predicted_wait_ms(depth_total)
        ref = self._ref_ms()
        if backlog > self.degrade_at * ref:
            return 2
        if backlog > self.shed_at * ref:
            return 1
        return 0

    def max_wait_scale(self) -> float:
        """Batcher max-wait multiplier for the last observed level."""
        return self.degraded_wait_scale if self._level >= 2 else 1.0

    def end_window(self) -> None:
        """Close one observation window of the sustained-pressure signal.

        A window whose *worst* admission-time ladder level reached 2
        extends the level-2 streak; anything milder resets it.  The
        batcher closes a window alongside every admission-period refresh,
        so the streak counts consecutive dispatch windows spent at the top
        of the ladder — the trigger
        :meth:`~repro.runtime.driver.ElasticPlanner.autoscale_from_ladder`
        watches to widen the plan instead of shedding forever.
        """
        with self._lock:
            if self._window_max_level >= 2:
                self._streak += 1
            else:
                self._streak = 0
            self._window_max_level = 0

    @property
    def level2_streak(self) -> int:
        """Consecutive closed windows whose worst level reached 2."""
        with self._lock:
            return self._streak

    def reset_streak(self) -> None:
        """Restart the sustained-pressure observation window — called by
        the autoscaler after it acted on a streak, so one burst triggers
        one widen attempt rather than one per subsequent window."""
        with self._lock:
            self._streak = 0
            self._window_max_level = 0

    # -- the admission rule ---------------------------------------------------- #
    def admit(self, *, priority: int, deadline_ms: float | None,
              depth_ahead: int, depth_total: int) -> str | None:
        """``None`` to admit, else the shed reason.

        Ladder first (pressure sheds whole classes regardless of their
        deadlines), then the per-request deadline feasibility check.
        """
        level = self.level(depth_total)
        with self._lock:
            self._level = level
            if level > self._window_max_level:
                self._window_max_level = level
            if level >= 1 and priority >= BEST_EFFORT:
                self.shed[priority] += 1
                self.shed_reasons["ladder"] += 1
                return (f"degradation ladder level {level}: shedding "
                        f"{PRIORITY_CLASSES[priority]} traffic")
            if deadline_ms is not None:
                wait = self.predicted_wait_ms(depth_ahead)
                if wait > float(deadline_ms) * self.deadline_slack:
                    self.shed[priority] += 1
                    self.shed_reasons["deadline"] += 1
                    return (f"predicted queue wait {wait:.1f} ms exceeds "
                            f"the {deadline_ms:g} ms deadline "
                            f"({depth_ahead} ahead, period "
                            f"{self.period_ms:.2f} ms)")
            self.admitted[priority] += 1
            return None

    def note_queue_full(self, priority: int) -> None:
        """Account a shed caused by the bounded queue refusing the put."""
        with self._lock:
            # the request was counted admitted by admit(); it ended up
            # shed after all, so move it across
            self.admitted[priority] = max(self.admitted[priority] - 1, 0)
            self.shed[priority] += 1
            self.shed_reasons["queue_full"] += 1

    def snapshot(self) -> dict:
        with self._lock:
            return {
                "period_ms": round(self.period_ms, 4),
                "batch_hint": self.batch_hint,
                "slo_ref_ms": round(self._ref_ms(), 4),
                "level": self._level,
                "level2_streak": self._streak,
                "admitted": {PRIORITY_CLASSES[c]: self.admitted[c]
                             for c in range(N_CLASSES)},
                "shed": {PRIORITY_CLASSES[c]: self.shed[c]
                         for c in range(N_CLASSES)},
                "shed_reasons": dict(self.shed_reasons),
            }


# --------------------------------------------------------------------------- #
# Per-class EDF queues (one condition: put/get/stop/swap all share it)
# --------------------------------------------------------------------------- #
class _ClassedQueue:
    """Bounded per-priority-class request queues under one condition.

    Within a class, requests pop earliest-deadline-first (deadline-less
    requests order FIFO after every deadlined one); across classes the
    batcher takes the highest-priority non-empty class, except that a
    class passed over ``credit`` times in a row gets the next batch — the
    starvation-avoidance credit that keeps batch/best-effort draining
    under sustained interactive load.

    One :class:`threading.Condition` serializes everything and doubles as
    the batcher's wakeup: ``put`` notifies on enqueue, :meth:`wake` is the
    stop/swap signal — the batcher never polls (the old 0.02 s
    ``Queue.get`` timeout loop) and an idle server stops promptly.
    """

    def __init__(self, maxsize: int, *, credit: int = 4):
        if maxsize < 1:
            raise ValueError(f"maxsize must be >= 1 (got {maxsize})")
        if credit < 1:
            raise ValueError(f"credit must be >= 1 (got {credit})")
        self.maxsize = int(maxsize)
        self.credit = int(credit)
        self._cond = threading.Condition(threading.Lock())
        self._heaps: list[list] = [[] for _ in range(N_CLASSES)]
        self._skipped = [0] * N_CLASSES
        self._size = 0
        self._seq = 0
        self._closed = False

    # -- producer side --------------------------------------------------------- #
    def put(self, r: Request, *, block: bool = True) -> str:
        """Enqueue; returns ``"ok"``, ``"full"`` (non-blocking refusal),
        or ``"closed"`` (the server stopped — callers must fail the
        request, never leave it parked)."""
        with self._cond:
            while True:
                if self._closed:
                    return "closed"
                if self._size < self.maxsize:
                    heapq.heappush(self._heaps[r.priority],
                                   (r.deadline_at, self._seq, r))
                    self._seq += 1
                    self._size += 1
                    self._cond.notify_all()
                    return "ok"
                if not block:
                    return "full"
                self._cond.wait()

    # -- consumer side (batcher thread only) ----------------------------------- #
    def _select_class(self) -> tuple[int, bool] | None:
        """(class, credit_override) for the next batch, or ``None``.

        ``credit_override`` is True when the starvation credit forced a
        lower class *past* a non-empty higher one — the batcher then
        dispatches a single-request trickle batch, so the credit costs
        the higher class one service period per ``credit`` batches
        instead of a full ``max_batch`` flush (which would invert the
        priority under sustained load).  Must hold the condition."""
        nonempty = [c for c in range(N_CLASSES) if self._heaps[c]]
        if not nonempty:
            return None
        starved = [c for c in nonempty if self._skipped[c] >= self.credit]
        pick = min(starved) if starved else min(nonempty)
        for c in nonempty:
            if c > pick:
                self._skipped[c] += 1
        self._skipped[pick] = 0
        return pick, pick != min(nonempty)

    def get_first(self, abort: Any) -> tuple[Request | None, bool]:
        """Block for the first request of the next batch.

        Returns ``(request, credit_override)``; request is ``None`` when
        ``abort()`` is true and the queue is empty (server stopping, or a
        pending executor swap needs the batcher at a batch boundary).  A
        non-empty queue always yields a request — stop drains before
        exiting."""
        with self._cond:
            while True:
                sel = self._select_class()
                if sel is not None:
                    cls, override = sel
                    return self._pop(cls), override
                if abort() or self._closed:
                    return None, False
                self._cond.wait()

    def get_from(self, cls: int, timeout: float) -> Request | None:
        """Next EDF request from ``cls`` within ``timeout`` seconds (batch
        continuation: batches never mix priority classes)."""
        deadline = time.perf_counter() + max(timeout, 0.0)
        with self._cond:
            while True:
                if self._heaps[cls]:
                    return self._pop(cls)
                remaining = deadline - time.perf_counter()
                if remaining <= 0 or self._closed:
                    return None
                self._cond.wait(remaining)

    def _pop(self, cls: int) -> Request:
        _, _, r = heapq.heappop(self._heaps[cls])
        self._size -= 1     # owner: callers hold self._cond (get_first/get_from)
        self._cond.notify_all()          # wake blocked producers
        return r

    # -- lifecycle / introspection ---------------------------------------------- #
    def drain(self) -> list[Request]:
        """Remove and return everything still queued (stop's reject pass)."""
        with self._cond:
            out = [r for h in self._heaps for (_, _, r) in h]
            for h in self._heaps:
                h.clear()
            self._size = 0
            self._cond.notify_all()
            return out

    def wake(self) -> None:
        """Nudge the batcher (stop / pending swap) without enqueuing."""
        with self._cond:
            self._cond.notify_all()

    def close(self) -> None:
        """Refuse future puts and unblock producers parked on a full
        queue — nobody is ever left blocked on a stopped server."""
        with self._cond:
            self._closed = True
            self._cond.notify_all()

    def qsize(self) -> int:
        with self._cond:
            return self._size

    def empty(self) -> bool:
        return self.qsize() == 0

    def depth_upto(self, cls: int) -> int:
        """Queued requests at priority ``cls`` or higher — the work ahead
        of a new ``cls`` submission under strict priority."""
        with self._cond:
            return sum(len(self._heaps[c]) for c in range(cls + 1))

    def depths(self) -> list[int]:
        with self._cond:
            return [len(h) for h in self._heaps]


class RequestQueueServer:
    """Dynamic-batching serving loop over a :class:`PipelineExecutor`.

    A batcher thread collects requests into batches of at most
    ``max_batch`` from the per-class EDF queues (strict priority across
    classes, starvation credit, see :class:`_ClassedQueue`), waiting up to
    ``max_wait_ms`` after a batch's first request before dispatching a
    partial batch.  Batches are issued asynchronously via
    ``executor.submit_many`` (micro-batched when shapes agree) and retired
    by a separate completion thread, so batch ``k+1`` is collected and
    issued while batch ``k`` is still executing — throughput is bounded by
    the executor's token pool, which is also the backpressure signal:
    ``submit`` blocks once ``queue_depth`` (default: pool size) requests
    are waiting, or — with an :class:`AdmissionController` attached —
    sheds instead of blocking (open-loop safety: an overloaded server
    fast-fails rather than stalling its producers).

    Every submitted request resolves **exactly once** into one of four
    terminal outcomes, counted per class: ``served`` (result delivered
    within its deadline), ``shed`` (admission/ladder/queue-full/stop
    fast-fail, never dispatched), ``expired`` (its ``deadline_ms`` passed
    while queued or in flight — :class:`DeadlineExceeded`, the SLO
    violation signal), ``failed`` (executor error).

    **Continuous batching** (``continuous=True``, executor built with
    ``open_groups=True``): the batcher never waits out ``max_wait_ms`` to
    fill a batch.  Each collected request is first *offered to the seam*
    (``executor.try_join``) — a free pad seat in a group still inside its
    stage-0 ring-residency window serves it with zero batching delay —
    and only seam misses are dispatched as a fresh (padded, open) group
    that later arrivals can join in flight.  Admission predictions
    subtract ``executor.seam_capacity()`` from the queue depth, since
    open seats serve queued work without a new dispatch group.
    """

    def __init__(self, executor: PipelineExecutor, *, max_batch: int = 8,
                 max_wait_ms: float = 5.0, queue_depth: int | None = None,
                 plan: Any = None, admission: AdmissionController | None = None,
                 starvation_credit: int = 4, continuous: bool = False):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if continuous and not getattr(executor, "open_groups", False):
            raise ValueError(
                "continuous batching needs an executor built with "
                "open_groups=True (the join seam is the stage-0 "
                "ring-residency window)")
        self.executor = executor
        self.max_batch = max_batch
        self.max_wait_ms = max_wait_ms
        # continuous batching: requests join in-flight groups at the
        # executor's seam (try_join) instead of waiting for the batcher's
        # max-wait window; the batcher never holds a request back to fill
        # a batch — a miss seeds a new (padded, open) group immediately
        self.continuous = bool(continuous)
        if plan is not None:
            # replication-aware sizing: the plan's effective (widened)
            # bottleneck period drives the batching knobs, not the serial one
            self.max_batch, self.max_wait_ms = replication_aware_batching(
                plan, max_batch=max_batch, max_wait_ms=max_wait_ms)
        self._admission = admission
        self._queues = _ClassedQueue(
            queue_depth if queue_depth is not None else executor.pool,
            credit=starvation_credit)
        self._issued: "list | Any" = __import__("queue").Queue()
        self._running = False
        self._batcher: threading.Thread | None = None
        self._retirer: threading.Thread | None = None
        self._done: list[Request] = []
        self._batch_sizes: list[int] = []
        self._seam_joined = 0            # requests admitted via try_join
        self._release_errors: list[BaseException] = []
        self._class_counts = [
            {"submitted": 0, "served": 0, "shed": 0, "expired": 0, "failed": 0}
            for _ in range(N_CLASSES)]
        self._rejected = 0               # failed without serving (stop/shed)
        self._stopped = False
        self._lock = threading.Lock()
        # zero-downtime executor hot-swap (see swap_executor)
        self._swap_lock = threading.Lock()
        self._pending_swap: tuple[PipelineExecutor, threading.Event] | None = None
        self.swaps = 0

    # -- lifecycle ----------------------------------------------------------- #
    def start(self) -> "RequestQueueServer":
        self._running = True
        self._batcher = threading.Thread(target=self._batch_loop, daemon=True)
        self._retirer = threading.Thread(target=self._retire_loop, daemon=True)
        self._batcher.start()
        self._retirer.start()
        return self

    def stop(self) -> None:
        """Drain the queue, serve everything submitted, then stop.

        Requests that could not be served (racing submitters that enqueue
        after the batcher's final drain pass, producers blocked on a full
        queue) are failed with
        :class:`~repro.core.executor.ExecutorClosed` rather than left
        blocking in ``Request.wait`` until their own timeout.
        """
        self._running = False
        self._queues.wake()             # batcher may be idle-blocked
        if self._batcher is not None:
            self._batcher.join()
        self._issued.put(None)          # retirer sentinel
        if self._retirer is not None:
            self._retirer.join()
        self._stopped = True
        self._queues.close()            # unblock producers; refuse new puts
        self._reject_pending()

    def _reject_pending(self) -> None:
        for r in self._queues.drain():
            self._finish(r, "shed", ExecutorClosed(
                "server stopped before this request was served"))

    def _finish(self, r: Request, outcome: str,
                err: BaseException | None = None,
                dispatched: bool = False) -> None:
        """The single terminal funnel: every request resolves exactly once
        (guarded by ``_finished`` under the server lock), its class
        counter bumps exactly once, and its waiters wake exactly once."""
        with self._lock:
            if r._finished:
                return
            r._finished = True
            if err is not None:
                r.error = err
            if r.t_done is None:
                r.t_done = time.perf_counter()
            self._class_counts[r.priority][outcome] += 1
            if outcome in ("shed", "expired"):
                self._rejected += 1
            if dispatched:
                self._done.append(r)
        r._event.set()
        cb = r.on_finish
        if cb is not None:
            # outside the lock: the hook may free a KV slot / touch the
            # executor; exactly-once is inherited from the _finished guard
            try:
                cb(r)
            except BaseException as e:
                with self._lock:
                    self._release_errors.append(e)

    def _fail_request(self, r: Request, err: BaseException) -> None:
        outcome = "shed"
        if isinstance(err, DeadlineExceeded):
            outcome = "expired"
        elif not isinstance(err, (Overloaded, ExecutorClosed)):
            outcome = "failed"
        self._finish(r, outcome, err)

    def __enter__(self) -> "RequestQueueServer":
        return self.start()

    def __exit__(self, *exc: Any) -> None:
        self.stop()

    # -- client API ---------------------------------------------------------- #
    def submit(self, *args: Any, deadline_ms: float | None = None,
               priority: "int | str" = INTERACTIVE,
               on_finish: Any = None) -> Request:
        """Enqueue one request into its priority class.

        Without an admission controller the put blocks when the bounded
        queue is full (closed-loop backpressure).  With one, overload is
        *shed*: the controller fast-fails requests whose deadline the
        predicted queue wait already breaks (and whole classes under the
        degradation ladder), and a full queue refuses the put with
        :class:`Overloaded` instead of blocking the producer.

        ``deadline_ms`` is end-to-end: a request past its deadline is
        failed with :class:`DeadlineExceeded` at whichever point catches
        it first (submit-time prediction, dispatch, or retirement) — never
        returned late.

        ``on_finish`` (called exactly once with the request, on every
        terminal outcome) is where per-request resources — a KV-cache
        slot — are released; it is installed *before* any shed path can
        fire, so a fast-failed request still returns its slot.
        """
        pri = priority_of(priority)
        r = Request(args=args, t_submit=time.perf_counter(),
                    deadline_ms=deadline_ms, priority=pri,
                    on_finish=on_finish)
        with self._lock:
            self._class_counts[pri]["submitted"] += 1
        if self._stopped:
            self._finish(r, "shed", ExecutorClosed(
                "server is stopped; requests are no longer accepted"))
            return r
        adm = self._admission
        if adm is not None:
            in_flight = self.executor.in_flight
            # seam-aware admission: seats already open at the batch seam
            # serve queued work without a fresh dispatch group, so they
            # come off the predicted depth (floored at 0)
            seam = 0
            if self.continuous:
                cap = getattr(self.executor, "seam_capacity", None)
                if cap is not None:
                    seam = int(cap())
            reason = adm.admit(
                priority=pri, deadline_ms=deadline_ms,
                depth_ahead=max(
                    self._queues.depth_upto(pri) + in_flight - seam, 0),
                depth_total=max(
                    self._queues.qsize() + in_flight - seam, 0))
            if reason is not None:
                self._finish(r, "shed", Overloaded(reason))
                return r
        status = self._queues.put(r, block=adm is None)
        if status == "full":
            adm.note_queue_full(pri)
            self._finish(r, "shed", Overloaded(
                f"request queue full ({self._queues.maxsize} deep)"))
            return r
        if status == "closed":
            self._finish(r, "shed", ExecutorClosed(
                "server stopped while this request waited for queue space"))
            return r
        if self._stopped:
            # close the submit/stop race: the drain pass may already have
            # finished when this put landed
            self._reject_pending()
        return r

    def swap_executor(self, new_executor: PipelineExecutor, *,
                      warm_args: tuple | None = None,
                      timeout: float = 120.0,
                      plan: Any = None, ir: Any = None,
                      db: Any = None, inventory: Any = None,
                      ) -> PipelineExecutor:
        """Zero-downtime executor hot-swap (the adaptive re-plan deploy).

        Sequence (documented in EXPERIMENTS.md):

        0. **Verify off-path** — when the caller hands over the candidate's
           ``plan`` + ``ir`` (and optionally its ``db``/``inventory``),
           the static verifier re-checks the plan *before* warmup or
           publication; a failing candidate raises
           :class:`~repro.analysis.diagnostics.PlanVerificationError` and
           the server keeps serving on the old executor — zero requests
           dropped (``REPRO_VERIFY=off`` skips the gate).
        1. **Warm off-path** — when ``warm_args`` is given, the new
           executor's ``warmup`` compiles every bucket shape *before* it
           sees traffic, so the swap never pays a compile on the serving
           path (and pays **zero** when the rebuilt executor reuses the
           old plan's StageFn/vmapped executables).
        2. **Swap at a batch boundary** — the batcher thread installs the
           new executor between batches, so no batch is ever split across
           executors.
        3. **Drain in flight** — batches already issued keep their
           ``PendingToken`` handles into the *old* executor; the retire
           thread resolves them as usual.  Nothing is cancelled, no
           request is dropped, and completion order per request is
           unchanged.

        Blocks until the batcher performed the swap (immediately when the
        server is not running) and returns the old executor — the caller
        may ``drain()``/``close()`` it once its stats are harvested.
        """
        if plan is not None and ir is not None:
            from repro.analysis.verify import check_plan
            check_plan(ir, plan, db=db, inventory=inventory,
                       where="RequestQueueServer.swap_executor")
        if warm_args is not None:
            new_executor.warmup(*warm_args)
        done = threading.Event()
        with self._swap_lock:
            if self._pending_swap is not None:
                raise RuntimeError("another executor swap is in progress")
            # capture BEFORE publishing: once the pending swap is visible a
            # fast batcher may install new_executor at any moment, and
            # self.executor would then be the new one
            old = self.executor
            self._pending_swap = (new_executor, done)
        if not self._running:             # no batcher: swap synchronously
            self._maybe_swap()
        else:
            self._queues.wake()           # idle batcher blocks on the queue
            if not done.wait(timeout):
                # withdraw the offer so a stalled batcher can't install a
                # swap the caller already gave up on (and so future swaps
                # aren't blocked forever); if the batcher took it in this
                # instant, the swap DID happen and the timeout is moot
                with self._swap_lock:
                    if self._pending_swap is not None \
                            and self._pending_swap[1] is done:
                        self._pending_swap = None
                        raise TimeoutError(
                            "executor swap not performed within timeout")
        return old

    def _maybe_swap(self) -> None:
        """Install a pending executor; called between batches (batcher)."""
        with self._swap_lock:
            pend, self._pending_swap = self._pending_swap, None
        if pend is None:
            return
        new_ex, done = pend
        self.executor = new_ex
        self.swaps += 1
        done.set()

    def slo_violation_rate(self, priority: int | None = None) -> float:
        """Fraction of *completed* requests (served or expired) that
        missed their deadline — the re-planner's SLO signal
        (:meth:`~repro.runtime.driver.ElasticPlanner.replan_from_profile`
        takes it alongside the stage medians)."""
        with self._lock:
            classes = range(N_CLASSES) if priority is None else [priority]
            served = sum(self._class_counts[c]["served"] for c in classes)
            expired = sum(self._class_counts[c]["expired"] for c in classes)
        total = served + expired
        return (expired / total) if total else 0.0

    def stats(self) -> dict:
        """Per-request latency summary (overall + per class) + executor
        throughput counters + admission-controller state."""
        with self._lock:         # one snapshot: latencies, sizes, span agree
            ok = [r for r in self._done if r.error is None]
            lat = [r.latency_ms for r in ok if r.latency_ms is not None]
            queue_ms = [r.queue_ms for r in self._done
                        if r.queue_ms is not None]
            sizes = list(self._batch_sizes)
            done = list(self._done)
            counts = [dict(c) for c in self._class_counts]
        span_s = 0.0
        if done:
            span_s = (max(r.t_done for r in done)
                      - min(r.t_submit for r in done))
        classes = {}
        for c, name in enumerate(PRIORITY_CLASSES):
            class_lat = [r.latency_ms for r in done
                         if r.priority == c and r.error is None
                         and r.latency_ms is not None]
            entry = dict(counts[c])
            entry["latency_ms"] = _latency_summary(class_lat)
            classes[name] = entry
        return {
            "requests_served": sum(c["served"] for c in counts),
            "batches": len(sizes),
            "mean_batch_size": float(np.mean(sizes)) if sizes else 0.0,
            "throughput_rps": (len(lat) / span_s) if span_s > 0 else 0.0,
            "latency_ms": _latency_summary(lat),
            "queue_ms_mean": float(np.mean(queue_ms)) if queue_ms else 0.0,
            "queue_depth": self._queues.qsize(),
            "class_queue_depths": self._queues.depths(),
            "rejected": self._rejected,
            "shed": sum(c["shed"] for c in counts),
            "expired": sum(c["expired"] for c in counts),
            "failed": sum(c["failed"] for c in counts),
            "submitted": sum(c["submitted"] for c in counts),
            "classes": classes,
            "seam_joins": self._seam_joined,
            "release_errors": len(self._release_errors),
            "slo_violation_rate": self.slo_violation_rate(),
            "admission": (self._admission.snapshot()
                          if self._admission is not None else None),
            "swaps": self.swaps,
            "executor": self.executor.stats().as_dict(),
            "profile": (self.executor.profiler.snapshot()
                        if getattr(self.executor, "profiler", None) is not None
                        else None),
        }

    # -- server threads ------------------------------------------------------ #
    def _abort_collect(self) -> bool:
        # read without _swap_lock: a stale None only delays the swap by one
        # wake (swap_executor wakes the queue after publishing)
        return not self._running or self._pending_swap is not None

    def _collect_batch(self) -> list[Request]:
        first, credit_override = self._queues.get_first(self._abort_collect)
        if first is None:
            return []
        batch = [first]
        if credit_override:
            # starvation-credit grant: a single-request trickle batch, so
            # the still-backlogged higher class resumes immediately after
            return batch
        wait_ms = self.max_wait_ms
        if self._admission is not None:
            wait_ms *= self._admission.max_wait_scale()
        deadline = time.perf_counter() + wait_ms / 1e3
        while len(batch) < self.max_batch:
            if self.continuous:
                # continuous batching never holds a request back to fill
                # a batch: take what is queued right now, dispatch, and
                # let late arrivals join the group at the executor seam
                nxt = self._queues.get_from(first.priority, 0.0)
                if nxt is None:
                    break
                batch.append(nxt)
                continue
            remaining = deadline - time.perf_counter()
            if remaining <= 0:
                break
            # batches never mix classes: EDF continuation from the first
            # request's class only
            nxt = self._queues.get_from(first.priority, remaining)
            if nxt is None:
                break
            batch.append(nxt)
        return batch

    def _refresh_admission_period(self) -> None:
        """Feed the admission rule the measured dispatch-group period and
        close one pressure-observation window (the level-2 streak tick)."""
        adm = self._admission
        if adm is None:
            return
        adm.end_window()
        prof = getattr(self.executor, "profiler", None)
        if prof is None or not hasattr(prof, "effective_period_ms"):
            return
        period = prof.effective_period_ms(
            getattr(self.executor, "replicas", None))
        if period is not None:
            adm.update_period(period)

    def _batch_loop(self) -> None:
        while self._running or not self._queues.empty():
            self._maybe_swap()            # executor swaps at batch boundaries
            batch = self._collect_batch()
            if not batch:
                continue
            self._refresh_admission_period()
            t_batch = time.perf_counter()
            # degrade past-deadline requests instead of dispatching late:
            # they failed their SLO while queued, executing them anyway
            # would only delay the requests still inside theirs
            live: list[Request] = []
            for r in batch:
                if t_batch > r.deadline_at:
                    self._finish(r, "expired", DeadlineExceeded(
                        f"request missed its {r.deadline_ms:g} ms deadline "
                        "while queued"))
                else:
                    live.append(r)
            batch = live
            if not batch:
                continue
            for r in batch:
                r.t_batch = t_batch
            if self.continuous:
                # offer every request to the seam first: a free seat in an
                # in-flight group serves it without waiting for a fresh
                # dispatch group (in-order retirement is preserved — the
                # joined token retires with its adoptive group's seq)
                rest: list[Request] = []
                joined = 0
                for r in batch:
                    try:
                        h = self.executor.try_join(r.args)
                    except ExecutorClosed:
                        h = None         # submit_many below reports it
                    except BaseException as e:
                        self._finish(r, "failed", e)
                        continue
                    if h is not None:
                        joined += 1
                        self._issued.put((r, h))
                    else:
                        rest.append(r)
                if joined:
                    # seam joins are not batches: they rode along inside
                    # groups already dispatched, so _batch_sizes (the
                    # dispatch-group log) deliberately excludes them
                    with self._lock:
                        self._seam_joined += joined
                batch = rest
                if not batch:
                    continue
            try:
                # eager async issue; blocks only on token-pool backpressure
                handles = self.executor.submit_many([r.args for r in batch])
            except BaseException as first_err:
                # SubmitError carries handles for the prefix that WAS
                # admitted — keep those (never double-issue device work)
                # and retry only the remainder one-by-one so just the
                # malformed request(s) fail
                handles = list(getattr(first_err, "handles", []) or [])
                good: list[Request] = batch[:len(handles)]
                for r in batch[len(handles):]:
                    try:
                        handles.extend(self.executor.submit_many([r.args]))
                        good.append(r)
                    except BaseException as e:
                        self._finish(r, "failed",
                                     getattr(e, "__cause__", None) or e)
                batch = good
                if not batch:
                    continue
            with self._lock:
                self._batch_sizes.append(len(batch))
            for r, h in zip(batch, handles):
                self._issued.put((r, h))
        self._maybe_swap()                # never leave a swap waiter hanging

    def _retire_loop(self) -> None:
        while True:
            item = self._issued.get()
            if item is None:
                return
            r, handle = item
            try:
                result = handle.result()
            except BaseException as e:
                r.t_done = time.perf_counter()
                self._finish(r, "failed", e, dispatched=True)
                continue
            r.t_done = time.perf_counter()
            if r.t_done > r.deadline_at:
                # end-to-end deadline: a request that went past its SLO
                # while in flight is failed at retirement, not returned
                # late — the result is discarded, the violation counted
                self._finish(r, "expired", DeadlineExceeded(
                    f"request completed {((r.t_done - r.t_submit) * 1e3):.1f}"
                    f" ms after submit, past its {r.deadline_ms:g} ms "
                    "deadline"), dispatched=True)
                continue
            r.result = result
            self._finish(r, "served", dispatched=True)


def serve_pipeline_demo(n_requests: int = 64, max_batch: int = 8,
                        max_wait_ms: float = 4.0,
                        size: tuple[int, int] = (64, 96),
                        worker_budget: "int | str | None" = None,
                        devices: int | None = None) -> dict:
    """Smoke-servable demo: Harris pipeline behind the request queue.

    ``worker_budget`` serves the pipeline with replicated stages: the
    planner's widening pass (:func:`repro.core.partition.assign_replicas`)
    distributes the budget over the planned stage times and the executor
    runs the widened stages on parallel worker threads, retiring requests
    strictly in submission order.  Pass the int budget,
    :data:`~repro.core.placement.AUTO_BUDGET` for the cpu-count governor,
    or set ``devices=N`` to place replicas on the first N devices of the
    detected :class:`~repro.core.placement.DeviceInventory` (each replica
    of a widened stage pinned to its own chip/core).  A widened plan also
    re-derives the batching knobs from its effective bottleneck period
    (:func:`replication_aware_batching`).
    """
    from repro.core import DeviceInventory, courier_offload
    from repro.core.partition import widen_for_deployment
    from repro.core.tracer import Library
    from repro.models.harris import corner_harris_demo, make_harris_db

    if max_batch < 1:
        raise ValueError(f"max_batch must be >= 1, got {max_batch}")
    db = make_harris_db(with_hw=False)
    lib = Library(db)
    app = corner_harris_demo(lib)
    H, W = size
    frames = [jax.random.uniform(jax.random.PRNGKey(i), (H, W, 3)) * 255
              for i in range(n_requests)]
    off = courier_offload(app, frames[0], db=db, prefer_hw=False)
    inventory = DeviceInventory.detect(limit=devices) if devices else None
    plan = off.pipeline.plan
    # the shared deploy-or-degrade rule: a plan that ends up unpinned
    # carries no pinnings, so the batching knobs below are sized from the
    # period the executor will actually run at
    replicas, stage_devices = widen_for_deployment(
        plan, off.pipeline.ir, worker_budget=worker_budget,
        inventory=inventory)
    if replicas is not None:
        # a widened plan drains r-wide: grow the batch / shrink the wait
        max_batch, max_wait_ms = replication_aware_batching(
            plan, max_batch=max_batch, max_wait_ms=max_wait_ms)
    # pad_microbatches: ragged partial batches reuse the one compiled
    # [max_batch, ...] executable instead of compiling per batch size
    ex = off.pipeline.executor(microbatch=max_batch, pad_microbatches=True,
                               replicas=replicas, devices=stage_devices,
                               inventory=inventory)
    ex.warmup(frames[0])      # compile before latencies are measured

    with RequestQueueServer(ex, max_batch=max_batch,
                            max_wait_ms=max_wait_ms) as srv:
        reqs = [srv.submit(f) for f in frames]
        for r in reqs:
            r.wait(timeout=120.0)
    return srv.stats()


def serve_traced_transformer_demo(n_requests: int = 24, max_batch: int = 4,
                                  max_wait_ms: float = 4.0,
                                  seq_len: int = 32, d: int = 64,
                                  n_layers: int = 2, ff: int = 128,
                                  n_heads: int = 4, vocab: int = 128,
                                  worker_budget: "int | str | None" = None,
                                  devices: int | None = None) -> dict:
    """The general trace→serve path: a transformer forward pass traced by
    the Frontend (weights closed over, no model-code edits), lowered
    through partition→fusion→replication→verify, served behind the
    request queue.

    Each request is one ``[seq_len, d]`` embedding sequence.  Returns the
    server stats plus trace-path facts: the fused nodes (the registered
    rmsnorm+matmul mega-kernel must fire on the traced graph), the number
    of captured weight inputs, and ``results_match`` — served results
    compared bit-exactly against ``jax.jit`` of the untraced model.
    """
    from repro.core import DeviceInventory, PipelineGenerator
    from repro.core.partition import widen_for_deployment
    from repro.core.tracer import Frontend, Library
    from repro.models.zoo import (init_transformer_params, make_zoo_db,
                                  transformer_demo)

    if max_batch < 1:
        raise ValueError(f"max_batch must be >= 1, got {max_batch}")
    db = make_zoo_db()
    lib = Library(db)
    params = init_transformer_params(jax.random.PRNGKey(0), n_layers=n_layers,
                                     d=d, ff=ff, n_heads=n_heads, vocab=vocab)
    app = transformer_demo(lib, params)
    seqs = [jax.random.normal(jax.random.PRNGKey(100 + i), (seq_len, d),
                              jnp.float32) for i in range(n_requests)]

    ir, _ = Frontend(db).trace(app, seqs[0])
    pipe = PipelineGenerator(db).generate(ir, policy="optimal", fuse=True,
                                          max_stages=4)
    inventory = DeviceInventory.detect(limit=devices) if devices else None
    plan = pipe.plan
    replicas, stage_devices = widen_for_deployment(
        plan, pipe.ir, worker_budget=worker_budget, inventory=inventory)
    if replicas is not None:
        max_batch, max_wait_ms = replication_aware_batching(
            plan, max_batch=max_batch, max_wait_ms=max_wait_ms)
    ex = pipe.executor(microbatch=max_batch, pad_microbatches=True,
                       replicas=replicas, devices=stage_devices,
                       inventory=inventory)
    ex.warmup(seqs[0])

    with RequestQueueServer(ex, max_batch=max_batch,
                            max_wait_ms=max_wait_ms) as srv:
        reqs = [srv.submit(s) for s in seqs]
        results = [r.wait(timeout=120.0) for r in reqs]

    # bit-exact parity with the untraced model (jax.jit of the very same
    # user function, weights still in its closure)
    ref = jax.jit(app)
    match = all(bool(jnp.array_equal(y, ref(s)))
                for y, s in zip(results, seqs))
    stats = srv.stats()
    stats.update({
        "results_match": match,
        "n_nodes": len(pipe.ir.nodes),
        "n_stages": plan.n_stages,
        "fused_nodes": [n.name for n in pipe.ir.nodes if n.fused_from],
        "captured_inputs": len(pipe.captured),
        "token_inputs": len(pipe.graph_inputs),
        "replicas": list(replicas) if replicas is not None else None,
    })
    return stats


def _budget_arg(v: str):
    """argparse type for --worker-budget: an int or the 'auto' sentinel,
    rejected with a clean argparse error instead of an int() traceback."""
    from repro.core.placement import AUTO_BUDGET

    if v == AUTO_BUDGET:
        return v
    try:
        return int(v)
    except ValueError:
        raise argparse.ArgumentTypeError(
            f"expected an integer or 'auto', got {v!r}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=["lm", "pipeline", "trace"],
                    default="lm")
    ap.add_argument("--arch", choices=ARCH_IDS, default="gemma3-12b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--max-wait-ms", type=float, default=4.0)
    ap.add_argument("--worker-budget", type=_budget_arg, default=None,
                    help="total stage workers; > n_stages widens "
                         "(replicates) the bottleneck stages; 'auto' "
                         "derives the budget from os.cpu_count() minus "
                         "the REPRO_RESERVED_CORES headroom")
    ap.add_argument("--devices", type=int, default=None,
                    help="place stage replicas on the first N detected "
                         "devices (jax.devices()); each replica of a "
                         "widened stage is pinned to its own device")
    args = ap.parse_args()

    if args.mode == "trace":
        stats = serve_traced_transformer_demo(
            n_requests=args.requests, max_batch=args.max_batch,
            max_wait_ms=args.max_wait_ms, worker_budget=args.worker_budget,
            devices=args.devices)
        lat = stats["latency_ms"]
        print(f"[serve] traced transformer: {stats['requests_served']} "
              f"requests over {stats['n_stages']} stages "
              f"(fused: {stats['fused_nodes']}, "
              f"{stats['captured_inputs']} captured weights)")
        print(f"[serve] results match untraced model: "
              f"{stats['results_match']}")
        print(f"[serve] latency ms: mean={lat['mean']:.2f} "
              f"p50={lat['p50']:.2f} p95={lat['p95']:.2f} max={lat['max']:.2f}")
        return

    if args.mode == "pipeline":
        stats = serve_pipeline_demo(n_requests=args.requests,
                                    max_batch=args.max_batch,
                                    max_wait_ms=args.max_wait_ms,
                                    worker_budget=args.worker_budget,
                                    devices=args.devices)
        lat = stats["latency_ms"]
        print(f"[serve] pipeline mode: {stats['requests_served']} requests, "
              f"{stats['batches']} batches "
              f"(mean size {stats['mean_batch_size']:.1f})")
        print(f"[serve] latency ms: mean={lat['mean']:.2f} "
              f"p50={lat['p50']:.2f} p95={lat['p95']:.2f} max={lat['max']:.2f}")
        print(f"[serve] executor: {stats['executor']}")
        return

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, P, T = args.batch, args.prompt_len, args.tokens
    key = jax.random.PRNGKey(1)
    prompt = jax.random.randint(key, (B, P), 0, cfg.vocab)
    kw = {}
    if cfg.cross_attn_every:
        kw["img_embeds"] = jax.random.normal(
            key, (B, cfg.n_img_tokens, cfg.d_model), jnp.float32)

    cache = model.init_cache(B, P + T)
    table = params["embed"]["table"]

    def emb(ids):
        return jnp.take(table, ids, axis=0)

    t0 = time.time()
    if cfg.embeds_in:
        hp, cache = model.prefill(params, None, cache, embeds=emb(prompt), **kw)
    else:
        hp, cache = model.prefill(params, prompt, cache, **kw)
    logits = model.logits(params, hp[:, -1:])
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    decode = jax.jit(
        lambda p, c, ids, pos: model.decode_step(
            p, None if cfg.embeds_in else ids, c, pos,
            embeds=emb(ids) if cfg.embeds_in else None),
        donate_argnums=(1,))
    out_tokens = []
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
    t0 = time.time()
    for t in range(T):
        out_tokens.append(tok)
        logits, cache = decode(params, cache, tok, P + t)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
    jax.block_until_ready(tok)
    t_decode = time.time() - t0

    seqs = jnp.concatenate(out_tokens, axis=1)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    print(f"[serve] arch={cfg.arch_id} batch={B} prompt={P}")
    print(f"[serve] prefill: {1e3 * t_prefill:.1f} ms "
          f"({B * P / t_prefill:.0f} tok/s)")
    print(f"[serve] decode: {1e3 * t_decode / T:.2f} ms/token "
          f"({B * T / t_decode:.0f} tok/s), generated {seqs.shape}")


if __name__ == "__main__":
    main()
