"""Serving launcher — batched prefill + decode driver (deliverable b).

    python -m repro.launch.serve --arch rwkv6-1.6b --reduced --tokens 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.models import LM


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="gemma3-12b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=32)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, P, T = args.batch, args.prompt_len, args.tokens
    key = jax.random.PRNGKey(1)
    prompt = jax.random.randint(key, (B, P), 0, cfg.vocab)
    kw = {}
    if cfg.cross_attn_every:
        kw["img_embeds"] = jax.random.normal(
            key, (B, cfg.n_img_tokens, cfg.d_model), jnp.float32)

    cache = model.init_cache(B, P + T)
    table = params["embed"]["table"]

    def emb(ids):
        return jnp.take(table, ids, axis=0)

    t0 = time.time()
    if cfg.embeds_in:
        hp, cache = model.prefill(params, None, cache, embeds=emb(prompt), **kw)
    else:
        hp, cache = model.prefill(params, prompt, cache, **kw)
    logits = model.logits(params, hp[:, -1:])
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    decode = jax.jit(
        lambda p, c, ids, pos: model.decode_step(
            p, None if cfg.embeds_in else ids, c, pos,
            embeds=emb(ids) if cfg.embeds_in else None),
        donate_argnums=(1,))
    out_tokens = []
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
    t0 = time.time()
    for t in range(T):
        out_tokens.append(tok)
        logits, cache = decode(params, cache, tok, P + t)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
    jax.block_until_ready(tok)
    t_decode = time.time() - t0

    seqs = jnp.concatenate(out_tokens, axis=1)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    print(f"[serve] arch={cfg.arch_id} batch={B} prompt={P}")
    print(f"[serve] prefill: {1e3 * t_prefill:.1f} ms "
          f"({B * P / t_prefill:.0f} tok/s)")
    print(f"[serve] decode: {1e3 * t_decode / T:.2f} ms/token "
          f"({B * T / t_decode:.0f} tok/s), generated {seqs.shape}")


if __name__ == "__main__":
    main()
