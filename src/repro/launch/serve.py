"""Serving launcher — LM decode driver + pipeline request-queue server.

Two serving modes:

* ``lm`` (default) — batched prefill + KV-cache decode on a reduced LM
  config (deliverable b)::

      python -m repro.launch.serve --arch rwkv6-1.6b --reduced --tokens 32

* ``pipeline`` — a request-queue serving loop over a Courier-built token
  pipeline (the ROADMAP's "serve heavy traffic" front-end)::

      python -m repro.launch.serve --mode pipeline --requests 64

  :class:`RequestQueueServer` accepts single-token requests, forms dynamic
  batches (up to ``max_batch``, waiting at most ``max_wait_ms`` after the
  first request of a batch), and feeds them to a
  :class:`~repro.core.executor.PipelineExecutor`.  Backpressure comes from
  the executor's bounded token pool: the batcher blocks inside ``submit_many``
  while the pool is full, which in turn fills the bounded request queue and
  blocks producers.  Per-request latency (queue + execute) is recorded and
  summarized by :meth:`RequestQueueServer.stats`.
"""
from __future__ import annotations

import argparse
import threading
import time
from dataclasses import dataclass, field
from queue import Empty, Queue
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.core.executor import ExecutorClosed, PipelineExecutor
from repro.models import LM


class DeadlineExceeded(TimeoutError):
    """A request's ``deadline_ms`` expired before it was dispatched —
    late work is degraded (failed fast) instead of re-queued forever."""


# --------------------------------------------------------------------------- #
# Request-queue serving loop over a token-pipeline executor
# --------------------------------------------------------------------------- #
@dataclass
class Request:
    """One in-flight serving request with its latency timeline."""

    args: tuple
    t_submit: float
    t_batch: float | None = None      # when the batcher picked it up
    t_done: float | None = None       # when its outputs were ready
    result: Any = None
    error: BaseException | None = None
    deadline_ms: float | None = None  # dispatch deadline (degrade when past)
    _event: threading.Event = field(default_factory=threading.Event)

    def wait(self, timeout: float | None = None) -> Any:
        if not self._event.wait(timeout):
            raise TimeoutError("request not served within timeout")
        if self.error is not None:
            raise self.error
        return self.result

    @property
    def latency_ms(self) -> float | None:
        if self.t_done is None:
            return None
        return (self.t_done - self.t_submit) * 1e3

    @property
    def queue_ms(self) -> float | None:
        if self.t_batch is None:
            return None
        return (self.t_batch - self.t_submit) * 1e3


def replication_aware_batching(plan: Any, *, max_batch: int,
                               max_wait_ms: float,
                               max_growth: float = 4.0,
                               min_wait_ms: float = 0.25,
                               ) -> tuple[int, float]:
    """Derive dynamic-batching knobs from the plan's *effective* period.

    A widened stage drains token groups ``r``-wide, so the pipeline's
    steady-state token period is the plan's effective (replication-aware)
    bottleneck, not the serial one.  Holding the batcher at knobs tuned
    for the serial period would starve the replicas: the max-wait deadline
    admits one batch per serial period while the executor could retire
    ``ratio = serial / effective`` of them.  This helper scales the knobs
    by that ratio — ``max_batch`` grows (more tokens per admission keeps
    every replica fed) and ``max_wait_ms`` shrinks (partial batches
    dispatch sooner because the pipeline drains faster) — clamped to
    ``max_growth`` so a massively widened plan doesn't balloon the
    compiled batch shape, and to ``min_wait_ms`` so the batcher never
    busy-spins.  A serial plan (ratio 1) returns the knobs unchanged.
    """
    if max_batch < 1:
        raise ValueError(f"max_batch must be >= 1, got {max_batch}")
    serial = float(plan.bottleneck_ms)
    eff = float(plan.effective_bottleneck_ms)
    if serial <= 0.0 or eff <= 0.0:
        return max_batch, max_wait_ms
    ratio = min(max(serial / eff, 1.0), float(max_growth))
    return (max(1, int(round(max_batch * ratio))),
            max(max_wait_ms / ratio, min_wait_ms))


def _percentile(xs: list[float], q: float) -> float:
    """Percentile over finite samples only; 0.0 for empty/tiny windows.

    Latency windows can be tiny (a 1-request batch right after startup) or
    carry non-finite entries (a timed-out clock pair); filtering here keeps
    the stats endpoint NaN-free instead of poisoning dashboards.
    """
    arr = np.asarray([x for x in xs if x is not None], dtype=np.float64)
    arr = arr[np.isfinite(arr)]
    return float(np.percentile(arr, q)) if arr.size else 0.0


class RequestQueueServer:
    """Dynamic-batching serving loop over a :class:`PipelineExecutor`.

    A batcher thread collects requests into batches of at most ``max_batch``,
    waiting up to ``max_wait_ms`` after a batch's first request before
    dispatching a partial batch (the max-wait deadline trades latency for
    batching efficiency).  Batches are issued asynchronously via
    ``executor.submit_many`` (micro-batched when shapes agree) and retired
    by a separate completion thread, so batch ``k+1`` is collected and
    issued while batch ``k`` is still executing — throughput is bounded by
    the executor's token pool, which is also the backpressure signal:
    ``submit`` blocks once ``queue_depth`` (default: pool size) requests
    are waiting.
    """

    def __init__(self, executor: PipelineExecutor, *, max_batch: int = 8,
                 max_wait_ms: float = 5.0, queue_depth: int | None = None,
                 plan: Any = None):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        self.executor = executor
        self.max_batch = max_batch
        self.max_wait_ms = max_wait_ms
        if plan is not None:
            # replication-aware sizing: the plan's effective (widened)
            # bottleneck period drives the batching knobs, not the serial one
            self.max_batch, self.max_wait_ms = replication_aware_batching(
                plan, max_batch=max_batch, max_wait_ms=max_wait_ms)
        self.queue: Queue[Request] = Queue(
            maxsize=queue_depth if queue_depth is not None else executor.pool)
        self._issued: Queue[tuple[Request, Any]] = Queue()
        self._running = False
        self._batcher: threading.Thread | None = None
        self._retirer: threading.Thread | None = None
        self._done: list[Request] = []
        self._batch_sizes: list[int] = []
        self._rejected = 0               # failed without serving (stop/deadline)
        self._stopped = False
        self._lock = threading.Lock()
        # zero-downtime executor hot-swap (see swap_executor)
        self._swap_lock = threading.Lock()
        self._pending_swap: tuple[PipelineExecutor, threading.Event] | None = None
        self.swaps = 0

    # -- lifecycle ----------------------------------------------------------- #
    def start(self) -> "RequestQueueServer":
        self._running = True
        self._batcher = threading.Thread(target=self._batch_loop, daemon=True)
        self._retirer = threading.Thread(target=self._retire_loop, daemon=True)
        self._batcher.start()
        self._retirer.start()
        return self

    def stop(self) -> None:
        """Drain the queue, serve everything submitted, then stop.

        Requests that could not be served (racing submitters that enqueue
        after the batcher's final drain pass) are failed with
        :class:`~repro.core.executor.ExecutorClosed` rather than left
        blocking in ``Request.wait`` until their own timeout.
        """
        self._running = False
        if self._batcher is not None:
            self._batcher.join()
        self._issued.put(None)          # retirer sentinel
        if self._retirer is not None:
            self._retirer.join()
        self._stopped = True
        self._reject_pending()

    def _reject_pending(self) -> None:
        while True:
            try:
                r = self.queue.get_nowait()
            except Empty:
                return
            self._fail_request(r, ExecutorClosed(
                "server stopped before this request was served"))

    def _fail_request(self, r: Request, err: BaseException) -> None:
        r.error = err
        r.t_done = time.perf_counter()
        with self._lock:
            self._rejected += 1
        r._event.set()

    def __enter__(self) -> "RequestQueueServer":
        return self.start()

    def __exit__(self, *exc: Any) -> None:
        self.stop()

    # -- client API ---------------------------------------------------------- #
    def submit(self, *args: Any, deadline_ms: float | None = None) -> Request:
        """Enqueue one request; blocks when the queue is full (backpressure).

        ``deadline_ms`` bounds the time-to-dispatch: a request still queued
        that long after submission is failed with :class:`DeadlineExceeded`
        instead of dispatched late (and its executor-side retries are
        bounded by the same budget via ``retry_budget_ms``).
        """
        r = Request(args=args, t_submit=time.perf_counter(),
                    deadline_ms=deadline_ms)
        if self._stopped:
            self._fail_request(r, ExecutorClosed(
                "server is stopped; requests are no longer accepted"))
            return r
        self.queue.put(r)
        if self._stopped:
            # close the submit/stop race: the drain pass may already have
            # finished when this put landed
            self._reject_pending()
        return r

    def swap_executor(self, new_executor: PipelineExecutor, *,
                      warm_args: tuple | None = None,
                      timeout: float = 120.0,
                      plan: Any = None, ir: Any = None,
                      db: Any = None, inventory: Any = None,
                      ) -> PipelineExecutor:
        """Zero-downtime executor hot-swap (the adaptive re-plan deploy).

        Sequence (documented in EXPERIMENTS.md):

        0. **Verify off-path** — when the caller hands over the candidate's
           ``plan`` + ``ir`` (and optionally its ``db``/``inventory``),
           the static verifier re-checks the plan *before* warmup or
           publication; a failing candidate raises
           :class:`~repro.analysis.diagnostics.PlanVerificationError` and
           the server keeps serving on the old executor — zero requests
           dropped (``REPRO_VERIFY=off`` skips the gate).
        1. **Warm off-path** — when ``warm_args`` is given, the new
           executor's ``warmup`` compiles every bucket shape *before* it
           sees traffic, so the swap never pays a compile on the serving
           path (and pays **zero** when the rebuilt executor reuses the
           old plan's StageFn/vmapped executables).
        2. **Swap at a batch boundary** — the batcher thread installs the
           new executor between batches, so no batch is ever split across
           executors.
        3. **Drain in flight** — batches already issued keep their
           ``PendingToken`` handles into the *old* executor; the retire
           thread resolves them as usual.  Nothing is cancelled, no
           request is dropped, and completion order per request is
           unchanged.

        Blocks until the batcher performed the swap (immediately when the
        server is not running) and returns the old executor — the caller
        may ``drain()``/``close()`` it once its stats are harvested.
        """
        if plan is not None and ir is not None:
            from repro.analysis.verify import check_plan
            check_plan(ir, plan, db=db, inventory=inventory,
                       where="RequestQueueServer.swap_executor")
        if warm_args is not None:
            new_executor.warmup(*warm_args)
        done = threading.Event()
        with self._swap_lock:
            if self._pending_swap is not None:
                raise RuntimeError("another executor swap is in progress")
            # capture BEFORE publishing: once the pending swap is visible a
            # fast batcher may install new_executor at any moment, and
            # self.executor would then be the new one
            old = self.executor
            self._pending_swap = (new_executor, done)
        if not self._running:             # no batcher: swap synchronously
            self._maybe_swap()
        elif not done.wait(timeout):
            # withdraw the offer so a stalled batcher can't install a
            # swap the caller already gave up on (and so future swaps
            # aren't blocked forever); if the batcher took it in this
            # instant, the swap DID happen and the timeout is moot
            with self._swap_lock:
                if self._pending_swap is not None \
                        and self._pending_swap[1] is done:
                    self._pending_swap = None
                    raise TimeoutError(
                        "executor swap not performed within timeout")
        return old

    def _maybe_swap(self) -> None:
        """Install a pending executor; called between batches (batcher)."""
        with self._swap_lock:
            pend, self._pending_swap = self._pending_swap, None
        if pend is None:
            return
        new_ex, done = pend
        self.executor = new_ex
        self.swaps += 1
        done.set()

    def stats(self) -> dict:
        """Per-request latency summary + executor throughput counters."""
        with self._lock:         # one snapshot: latencies, sizes, span agree
            lat = [r.latency_ms for r in self._done if r.latency_ms is not None]
            queue_ms = [r.queue_ms for r in self._done
                        if r.queue_ms is not None]
            sizes = list(self._batch_sizes)
            done = list(self._done)
        span_s = 0.0
        if done:
            span_s = (max(r.t_done for r in done)
                      - min(r.t_submit for r in done))
        return {
            "requests_served": len(lat),
            "batches": len(sizes),
            "mean_batch_size": float(np.mean(sizes)) if sizes else 0.0,
            "throughput_rps": (len(lat) / span_s) if span_s > 0 else 0.0,
            "latency_ms": {
                "mean": float(np.mean(lat)) if lat else 0.0,
                "p50": _percentile(lat, 50),
                "p95": _percentile(lat, 95),
                "max": max(lat) if lat else 0.0,
            },
            "queue_ms_mean": float(np.mean(queue_ms)) if queue_ms else 0.0,
            "queue_depth": self.queue.qsize(),
            "rejected": self._rejected,
            "swaps": self.swaps,
            "executor": self.executor.stats().as_dict(),
            "profile": (self.executor.profiler.snapshot()
                        if getattr(self.executor, "profiler", None) is not None
                        else None),
        }

    # -- server threads ------------------------------------------------------ #
    def _collect_batch(self) -> list[Request]:
        try:
            first = self.queue.get(timeout=0.02)
        except Empty:
            return []
        batch = [first]
        deadline = time.perf_counter() + self.max_wait_ms / 1e3
        while len(batch) < self.max_batch:
            remaining = deadline - time.perf_counter()
            if remaining <= 0:
                break
            try:
                batch.append(self.queue.get(timeout=remaining))
            except Empty:
                break
        return batch

    def _batch_loop(self) -> None:
        while self._running or not self.queue.empty():
            self._maybe_swap()            # executor swaps at batch boundaries
            batch = self._collect_batch()
            if not batch:
                continue
            t_batch = time.perf_counter()
            # degrade past-deadline requests instead of dispatching late:
            # they failed their SLO while queued, executing them anyway
            # would only delay the requests still inside theirs
            live: list[Request] = []
            for r in batch:
                if r.deadline_ms is not None \
                        and (t_batch - r.t_submit) * 1e3 > r.deadline_ms:
                    self._fail_request(r, DeadlineExceeded(
                        f"request missed its {r.deadline_ms:g} ms dispatch "
                        "deadline"))
                else:
                    live.append(r)
            batch = live
            if not batch:
                continue
            for r in batch:
                r.t_batch = t_batch
            try:
                # eager async issue; blocks only on token-pool backpressure
                handles = self.executor.submit_many([r.args for r in batch])
            except BaseException as first_err:
                # SubmitError carries handles for the prefix that WAS
                # admitted — keep those (never double-issue device work)
                # and retry only the remainder one-by-one so just the
                # malformed request(s) fail
                handles = list(getattr(first_err, "handles", []) or [])
                good: list[Request] = batch[:len(handles)]
                for r in batch[len(handles):]:
                    try:
                        handles.extend(self.executor.submit_many([r.args]))
                        good.append(r)
                    except BaseException as e:
                        r.error = getattr(e, "__cause__", None) or e
                        r.t_done = time.perf_counter()
                        r._event.set()
                batch = good
                if not batch:
                    continue
            with self._lock:
                self._batch_sizes.append(len(batch))
            for r, h in zip(batch, handles):
                self._issued.put((r, h))
        self._maybe_swap()                # never leave a swap waiter hanging

    def _retire_loop(self) -> None:
        while True:
            item = self._issued.get()
            if item is None:
                return
            r, handle = item
            try:
                r.result = handle.result()
            except BaseException as e:
                r.error = e
            r.t_done = time.perf_counter()
            with self._lock:
                self._done.append(r)
            r._event.set()


def serve_pipeline_demo(n_requests: int = 64, max_batch: int = 8,
                        max_wait_ms: float = 4.0,
                        size: tuple[int, int] = (64, 96),
                        worker_budget: "int | str | None" = None,
                        devices: int | None = None) -> dict:
    """Smoke-servable demo: Harris pipeline behind the request queue.

    ``worker_budget`` serves the pipeline with replicated stages: the
    planner's widening pass (:func:`repro.core.partition.assign_replicas`)
    distributes the budget over the planned stage times and the executor
    runs the widened stages on parallel worker threads, retiring requests
    strictly in submission order.  Pass the int budget,
    :data:`~repro.core.placement.AUTO_BUDGET` for the cpu-count governor,
    or set ``devices=N`` to place replicas on the first N devices of the
    detected :class:`~repro.core.placement.DeviceInventory` (each replica
    of a widened stage pinned to its own chip/core).  A widened plan also
    re-derives the batching knobs from its effective bottleneck period
    (:func:`replication_aware_batching`).
    """
    from repro.core import DeviceInventory, courier_offload
    from repro.core.partition import widen_for_deployment
    from repro.core.tracer import Library
    from repro.models.harris import corner_harris_demo, make_harris_db

    if max_batch < 1:
        raise ValueError(f"max_batch must be >= 1, got {max_batch}")
    db = make_harris_db(with_hw=False)
    lib = Library(db)
    app = corner_harris_demo(lib)
    H, W = size
    frames = [jax.random.uniform(jax.random.PRNGKey(i), (H, W, 3)) * 255
              for i in range(n_requests)]
    off = courier_offload(app, frames[0], db=db, prefer_hw=False)
    inventory = DeviceInventory.detect(limit=devices) if devices else None
    plan = off.pipeline.plan
    # the shared deploy-or-degrade rule: a plan that ends up unpinned
    # carries no pinnings, so the batching knobs below are sized from the
    # period the executor will actually run at
    replicas, stage_devices = widen_for_deployment(
        plan, off.pipeline.ir, worker_budget=worker_budget,
        inventory=inventory)
    if replicas is not None:
        # a widened plan drains r-wide: grow the batch / shrink the wait
        max_batch, max_wait_ms = replication_aware_batching(
            plan, max_batch=max_batch, max_wait_ms=max_wait_ms)
    # pad_microbatches: ragged partial batches reuse the one compiled
    # [max_batch, ...] executable instead of compiling per batch size
    ex = off.pipeline.executor(microbatch=max_batch, pad_microbatches=True,
                               replicas=replicas, devices=stage_devices,
                               inventory=inventory)
    ex.warmup(frames[0])      # compile before latencies are measured

    with RequestQueueServer(ex, max_batch=max_batch,
                            max_wait_ms=max_wait_ms) as srv:
        reqs = [srv.submit(f) for f in frames]
        for r in reqs:
            r.wait(timeout=120.0)
    return srv.stats()


def serve_traced_transformer_demo(n_requests: int = 24, max_batch: int = 4,
                                  max_wait_ms: float = 4.0,
                                  seq_len: int = 32, d: int = 64,
                                  n_layers: int = 2, ff: int = 128,
                                  n_heads: int = 4, vocab: int = 128,
                                  worker_budget: "int | str | None" = None,
                                  devices: int | None = None) -> dict:
    """The general trace→serve path: a transformer forward pass traced by
    the Frontend (weights closed over, no model-code edits), lowered
    through partition→fusion→replication→verify, served behind the
    request queue.

    Each request is one ``[seq_len, d]`` embedding sequence.  Returns the
    server stats plus trace-path facts: the fused nodes (the registered
    rmsnorm+matmul mega-kernel must fire on the traced graph), the number
    of captured weight inputs, and ``results_match`` — served results
    compared bit-exactly against ``jax.jit`` of the untraced model.
    """
    from repro.core import DeviceInventory, PipelineGenerator
    from repro.core.partition import widen_for_deployment
    from repro.core.tracer import Frontend, Library
    from repro.models.zoo import (init_transformer_params, make_zoo_db,
                                  transformer_demo)

    if max_batch < 1:
        raise ValueError(f"max_batch must be >= 1, got {max_batch}")
    db = make_zoo_db()
    lib = Library(db)
    params = init_transformer_params(jax.random.PRNGKey(0), n_layers=n_layers,
                                     d=d, ff=ff, n_heads=n_heads, vocab=vocab)
    app = transformer_demo(lib, params)
    seqs = [jax.random.normal(jax.random.PRNGKey(100 + i), (seq_len, d),
                              jnp.float32) for i in range(n_requests)]

    ir, _ = Frontend(db).trace(app, seqs[0])
    pipe = PipelineGenerator(db).generate(ir, policy="optimal", fuse=True,
                                          max_stages=4)
    inventory = DeviceInventory.detect(limit=devices) if devices else None
    plan = pipe.plan
    replicas, stage_devices = widen_for_deployment(
        plan, pipe.ir, worker_budget=worker_budget, inventory=inventory)
    if replicas is not None:
        max_batch, max_wait_ms = replication_aware_batching(
            plan, max_batch=max_batch, max_wait_ms=max_wait_ms)
    ex = pipe.executor(microbatch=max_batch, pad_microbatches=True,
                       replicas=replicas, devices=stage_devices,
                       inventory=inventory)
    ex.warmup(seqs[0])

    with RequestQueueServer(ex, max_batch=max_batch,
                            max_wait_ms=max_wait_ms) as srv:
        reqs = [srv.submit(s) for s in seqs]
        results = [r.wait(timeout=120.0) for r in reqs]

    # bit-exact parity with the untraced model (jax.jit of the very same
    # user function, weights still in its closure)
    ref = jax.jit(app)
    match = all(bool(jnp.array_equal(y, ref(s)))
                for y, s in zip(results, seqs))
    stats = srv.stats()
    stats.update({
        "results_match": match,
        "n_nodes": len(pipe.ir.nodes),
        "n_stages": plan.n_stages,
        "fused_nodes": [n.name for n in pipe.ir.nodes if n.fused_from],
        "captured_inputs": len(pipe.captured),
        "token_inputs": len(pipe.graph_inputs),
        "replicas": list(replicas) if replicas is not None else None,
    })
    return stats


def _budget_arg(v: str):
    """argparse type for --worker-budget: an int or the 'auto' sentinel,
    rejected with a clean argparse error instead of an int() traceback."""
    from repro.core.placement import AUTO_BUDGET

    if v == AUTO_BUDGET:
        return v
    try:
        return int(v)
    except ValueError:
        raise argparse.ArgumentTypeError(
            f"expected an integer or 'auto', got {v!r}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=["lm", "pipeline", "trace"],
                    default="lm")
    ap.add_argument("--arch", choices=ARCH_IDS, default="gemma3-12b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--max-wait-ms", type=float, default=4.0)
    ap.add_argument("--worker-budget", type=_budget_arg, default=None,
                    help="total stage workers; > n_stages widens "
                         "(replicates) the bottleneck stages; 'auto' "
                         "derives the budget from os.cpu_count() minus "
                         "the REPRO_RESERVED_CORES headroom")
    ap.add_argument("--devices", type=int, default=None,
                    help="place stage replicas on the first N detected "
                         "devices (jax.devices()); each replica of a "
                         "widened stage is pinned to its own device")
    args = ap.parse_args()

    if args.mode == "trace":
        stats = serve_traced_transformer_demo(
            n_requests=args.requests, max_batch=args.max_batch,
            max_wait_ms=args.max_wait_ms, worker_budget=args.worker_budget,
            devices=args.devices)
        lat = stats["latency_ms"]
        print(f"[serve] traced transformer: {stats['requests_served']} "
              f"requests over {stats['n_stages']} stages "
              f"(fused: {stats['fused_nodes']}, "
              f"{stats['captured_inputs']} captured weights)")
        print(f"[serve] results match untraced model: "
              f"{stats['results_match']}")
        print(f"[serve] latency ms: mean={lat['mean']:.2f} "
              f"p50={lat['p50']:.2f} p95={lat['p95']:.2f} max={lat['max']:.2f}")
        return

    if args.mode == "pipeline":
        stats = serve_pipeline_demo(n_requests=args.requests,
                                    max_batch=args.max_batch,
                                    max_wait_ms=args.max_wait_ms,
                                    worker_budget=args.worker_budget,
                                    devices=args.devices)
        lat = stats["latency_ms"]
        print(f"[serve] pipeline mode: {stats['requests_served']} requests, "
              f"{stats['batches']} batches "
              f"(mean size {stats['mean_batch_size']:.1f})")
        print(f"[serve] latency ms: mean={lat['mean']:.2f} "
              f"p50={lat['p50']:.2f} p95={lat['p95']:.2f} max={lat['max']:.2f}")
        print(f"[serve] executor: {stats['executor']}")
        return

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, P, T = args.batch, args.prompt_len, args.tokens
    key = jax.random.PRNGKey(1)
    prompt = jax.random.randint(key, (B, P), 0, cfg.vocab)
    kw = {}
    if cfg.cross_attn_every:
        kw["img_embeds"] = jax.random.normal(
            key, (B, cfg.n_img_tokens, cfg.d_model), jnp.float32)

    cache = model.init_cache(B, P + T)
    table = params["embed"]["table"]

    def emb(ids):
        return jnp.take(table, ids, axis=0)

    t0 = time.time()
    if cfg.embeds_in:
        hp, cache = model.prefill(params, None, cache, embeds=emb(prompt), **kw)
    else:
        hp, cache = model.prefill(params, prompt, cache, **kw)
    logits = model.logits(params, hp[:, -1:])
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    decode = jax.jit(
        lambda p, c, ids, pos: model.decode_step(
            p, None if cfg.embeds_in else ids, c, pos,
            embeds=emb(ids) if cfg.embeds_in else None),
        donate_argnums=(1,))
    out_tokens = []
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
    t0 = time.time()
    for t in range(T):
        out_tokens.append(tok)
        logits, cache = decode(params, cache, tok, P + t)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
    jax.block_until_ready(tok)
    t_decode = time.time() - t0

    seqs = jnp.concatenate(out_tokens, axis=1)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    print(f"[serve] arch={cfg.arch_id} batch={B} prompt={P}")
    print(f"[serve] prefill: {1e3 * t_prefill:.1f} ms "
          f"({B * P / t_prefill:.0f} tok/s)")
    print(f"[serve] decode: {1e3 * t_decode / T:.2f} ms/token "
          f"({B * T / t_decode:.0f} tok/s), generated {seqs.shape}")


if __name__ == "__main__":
    main()
