"""The paper's own workload: cornerHarris_Demo (OpenCV) on a 1920×1080 frame.

Not an LM arch — this config drives the case-study benchmarks
(benchmarks/table1..3) and the quickstart example, reproducing the paper's
processing flow: cvtColor → cornerHarris → normalize → convertScaleAbs.
"""
from dataclasses import dataclass


@dataclass(frozen=True)
class HarrisConfig:
    arch_id: str = "harris-demo"
    height: int = 1080
    width: int = 1920
    block_size: int = 2          # cv::cornerHarris blockSize
    ksize: int = 3               # Sobel aperture
    k: float = 0.04              # Harris k
    # paper Table I reference timings [ms] on Zynq (original / offloaded)
    paper_times_orig = {"cvtColor": 46.3, "cornerHarris": 999.0,
                        "normalize": 108.0, "convertScaleAbs": 217.8}
    paper_times_offl = {"cvtColor": 39.8, "cornerHarris": 13.6,
                        "normalize": 80.2, "convertScaleAbs": 13.2}
    paper_total_orig_ms: float = 1371.1
    paper_total_offl_ms: float = 83.8
    paper_speedup: float = 15.36


config = HarrisConfig()
