"""llama-3.2-vision-11b [vlm] — cross-attn image layers every 5th layer.

Backbone only per task spec: the ViT frontend is a stub; ``input_specs``
provides precomputed image patch embeddings [B, n_img_tokens, d_model].
40 layers = 8 groups of (4 self + 1 cross).
"""
from repro.models.config import ArchConfig

config = ArchConfig(
    arch_id="llama-3.2-vision-11b", family="vlm",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab=128256,
    cross_attn_every=5, n_img_tokens=1601, rope_theta=5e5,
)
