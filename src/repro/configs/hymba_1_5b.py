"""hymba-1.5b [hybrid] — parallel attn + mamba heads (arXiv:2411.13676).

Hymba mixes sliding-window attention with a parallel SSM branch per block;
the SSM branch supplies the global context, so SWA everywhere keeps the
arch sub-quadratic (long_500k eligible). See DESIGN.md §5.
"""
from repro.models.config import ArchConfig

config = ArchConfig(
    arch_id="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5, head_dim=64,
    d_ff=5504, vocab=32001,
    window=1024, ssm_state=16, hybrid=True,
)
