"""moonshot-v1-16b-a3b [moe] — kimi/moonlight, 64 experts top-6."""
from repro.models.config import ArchConfig

config = ArchConfig(
    arch_id="moonshot-v1-16b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=1408, vocab=163840,
    n_experts=64, top_k=6, rope_theta=5e4,
)
