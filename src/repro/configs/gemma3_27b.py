"""gemma3-27b [dense] — 5:1 local:global sliding window, 128k context."""
from repro.models.config import ArchConfig

config = ArchConfig(
    arch_id="gemma3-27b", family="dense",
    n_layers=62, d_model=5376, n_heads=32, n_kv_heads=16, head_dim=128,
    d_ff=21504, vocab=262144,
    window=1024, global_every=6,
    rope_theta=1e4, rope_theta_global=1e6,
)
