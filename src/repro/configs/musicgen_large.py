"""musicgen-large [audio] — decoder-only over EnCodec tokens (arXiv:2306.05284).

Backbone only per task spec: the EnCodec frontend is a stub; ``input_specs``
provides precomputed frame embeddings [B, S, d_model].
"""
from repro.models.config import ArchConfig

config = ArchConfig(
    arch_id="musicgen-large", family="audio",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
    d_ff=8192, vocab=2048,
    embeds_in=True,
)
