"""rwkv6-1.6b [ssm] — Finch, data-dependent decay (arXiv:2404.05892)."""
from repro.models.config import ArchConfig

config = ArchConfig(
    arch_id="rwkv6-1.6b", family="ssm",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
    d_ff=7168, vocab=65536,
    rwkv=True,
)
