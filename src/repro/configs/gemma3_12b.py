"""gemma3-12b [dense] — 5:1 local:global sliding window, 128k context."""
from repro.models.config import ArchConfig

config = ArchConfig(
    arch_id="gemma3-12b", family="dense",
    n_layers=48, d_model=3840, n_heads=16, n_kv_heads=8, head_dim=256,
    d_ff=15360, vocab=262144,
    window=1024, global_every=6,            # 5 local : 1 global
    rope_theta=1e4, rope_theta_global=1e6,
)
