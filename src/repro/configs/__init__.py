"""Architecture config registry — ``--arch <id>`` resolution."""
from __future__ import annotations

import importlib

from repro.models.config import SHAPES, ArchConfig, ShapeConfig, supports_shape

_MODULES = {
    "mistral-large-123b": "mistral_large_123b",
    "gemma3-12b": "gemma3_12b",
    "gemma3-27b": "gemma3_27b",
    "deepseek-67b": "deepseek_67b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "hymba-1.5b": "hymba_1_5b",
    "musicgen-large": "musicgen_large",
    "llama-3.2-vision-11b": "llama_3_2_vision_11b",
    "rwkv6-1.6b": "rwkv6_1_6b",
}

ARCH_IDS = list(_MODULES)


def get_config(arch_id: str) -> ArchConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.config


def all_configs() -> dict[str, ArchConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


__all__ = ["ARCH_IDS", "SHAPES", "ArchConfig", "ShapeConfig", "get_config",
           "all_configs", "supports_shape"]
