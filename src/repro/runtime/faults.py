"""Deterministic fault injection — one harness for serving AND training.

Courier-FPGA's dynamic function replacement only pays off if the pipeline
it attached to a running binary *survives* that runtime: a hardware module
dropping out mid-stream must degrade the pipeline, not kill it.  Testing
that without a chip to unplug needs scripted faults, and the repo grew two
ad-hoc idioms for them — ``FaultTolerantDriver``'s ``fail_hook(step)``
callback and per-test monkeypatched stage functions.  This module replaces
both with one scriptable harness:

* :class:`FaultPlan` — a builder that scripts *what* fails and *when*, in
  terms of deterministic invocation counts (never wall clock):
  ``transient(stage, at_calls=...)`` raises :class:`InjectedFault` on the
  N-th invocation of a stage; ``slowdown(stage, extra_ms, ...)`` stretches
  a call window; ``lose_device(ordinal, after_calls=...)`` makes every
  stage call placed on that device ordinal raise
  :class:`DeviceLostError` permanently — the scripted analog of a chip
  dropping out; ``fail_step(at_steps=...)`` scripts training-step faults
  (each fires once, so a checkpoint-restart replay of the same step
  succeeds); ``random_transients(rate, seed, ...)`` draws per-invocation
  faults from a seeded hash, reproducible regardless of thread
  interleaving (the chaos-soak schedule).

* :class:`FaultInjector` — the built plan, hooked into the executor's
  stage call-sites (``PipelineExecutor(fault_injector=...)`` calls
  :meth:`FaultInjector.on_stage_call` before every stage body) and into
  the training loop (``FaultTolerantDriver(faults=...)`` calls
  :meth:`FaultInjector.on_step`).  Injection happens BEFORE the stage
  function runs, so a retried call never re-executes a half-donated
  buffer.  :meth:`surviving` closes the elastic loop: it derives the
  post-loss :class:`~repro.core.placement.DeviceInventory` for
  ``DeviceInventory.refresh(probe=...)``.

The injector is also scriptable *after* construction (``lose_device`` on a
live injector), which is how benchmarks pull a device out from under a
serving loop mid-run.
"""
from __future__ import annotations

import hashlib
import threading
import time
from typing import Any, Callable, Iterable

__all__ = ["FaultPlan", "FaultInjector", "InjectedFault", "DeviceLostError",
           "as_injector"]


class InjectedFault(RuntimeError):
    """A scripted transient failure (see :meth:`FaultPlan.transient`)."""


class DeviceLostError(InjectedFault):
    """A scripted permanent device loss: every stage call placed on the
    lost ordinal raises this, from the scripted trigger point on."""

    def __init__(self, msg: str, ordinal: int):
        super().__init__(msg)
        self.ordinal = ordinal


class FaultPlan:
    """Deterministic fault script, built fluently and compiled by
    :meth:`build` into a :class:`FaultInjector`.

    All triggers are INVOCATION COUNTS (0-based, per stage or per device
    ordinal), never wall-clock times — the same plan replays identically
    under any scheduler.  A retried stage call is a *new* invocation, so a
    single scripted transient is survived by one retry unless the plan
    scripts the retry's count too.
    """

    def __init__(self) -> None:
        self.transients: dict[int, set[int]] = {}     # stage -> call counts
        self.slowdowns: list[tuple[int, float, int, int | None]] = []
        self.device_losses: dict[int, int] = {}       # ordinal -> after_calls
        self.step_faults: set[int] = set()
        # (seed, rate, stages, from_call)
        self.random_spec: tuple[int, float, tuple[int, ...] | None, int] | None = None

    def transient(self, stage: int, at_calls: Iterable[int]) -> "FaultPlan":
        """Raise :class:`InjectedFault` on the given invocation counts of
        ``stage`` (counted across all replicas of the stage)."""
        self.transients.setdefault(int(stage), set()).update(
            int(c) for c in at_calls)
        return self

    def slowdown(self, stage: int, extra_ms: float, *, from_call: int = 0,
                 to_call: int | None = None) -> "FaultPlan":
        """Sleep ``extra_ms`` before each invocation of ``stage`` in the
        call window ``[from_call, to_call)`` (``None`` = forever)."""
        if extra_ms < 0:
            raise ValueError(f"extra_ms must be >= 0 (got {extra_ms})")
        self.slowdowns.append((int(stage), float(extra_ms), int(from_call),
                               None if to_call is None else int(to_call)))
        return self

    def lose_device(self, ordinal: int, *, after_calls: int = 0) -> "FaultPlan":
        """Permanently lose device ``ordinal`` once ``after_calls`` stage
        calls have been placed on it: that call and every later one on the
        ordinal raise :class:`DeviceLostError`."""
        self.device_losses[int(ordinal)] = int(after_calls)
        return self

    def fail_step(self, at_steps: Iterable[int]) -> "FaultPlan":
        """Raise :class:`InjectedFault` at the given training steps — each
        fires ONCE, so a checkpoint-restart replay of the step succeeds."""
        self.step_faults.update(int(s) for s in at_steps)
        return self

    def random_transients(self, rate: float, seed: int, *,
                          stages: Iterable[int] | None = None,
                          from_call: int = 0) -> "FaultPlan":
        """Seeded random transients: invocation ``n`` of stage ``s`` faults
        when ``hash(seed, s, n) < rate`` — a pure function of the counts,
        so the schedule reproduces bit-exactly under any thread
        interleaving (the chaos-soak test's schedule).  ``from_call``
        exempts the first invocations of each stage (calls ``n <
        from_call`` never draw), so a warmup/calibration phase stays
        fault-free while the measured phase gets the full rate."""
        if not 0.0 <= rate < 1.0:
            raise ValueError(f"rate must be in [0, 1) (got {rate})")
        if from_call < 0:
            raise ValueError(f"from_call must be >= 0 (got {from_call})")
        self.random_spec = (int(seed), float(rate),
                            tuple(int(s) for s in stages)
                            if stages is not None else None,
                            int(from_call))
        return self

    def build(self) -> "FaultInjector":
        return FaultInjector(self)


def _hash_draw(seed: int, stage: int, call: int) -> float:
    """Deterministic uniform draw in [0, 1) from (seed, stage, call)."""
    h = hashlib.sha256(f"{seed}:{stage}:{call}".encode()).digest()
    return int.from_bytes(h[:8], "big") / float(1 << 64)


class FaultInjector:
    """A compiled :class:`FaultPlan`, hooked into executors and drivers.

    Thread-safe: the invocation counters are the only shared state and
    live behind one lock; the fault decision for an invocation depends
    only on its count, so concurrent replicas see a deterministic
    schedule.  Counters (``injected``/``slowed``/``device_faults``) make
    the injected load auditable from benchmarks.
    """

    def __init__(self, plan: FaultPlan | None = None):
        self.plan = plan or FaultPlan()
        self._lock = threading.Lock()
        self._stage_calls: dict[int, int] = {}
        self._device_calls: dict[int, int] = {}
        self._lost: set[int] = set()          # ordinals whose loss triggered
        self._steps_fired: set[int] = set()
        self._hook: Callable[[int], None] | None = None
        self.injected = 0                     # transient faults raised
        self.device_faults = 0                # device-loss faults raised
        self.slowed = 0                       # slowdown sleeps applied

    @classmethod
    def from_hook(cls, hook: Callable[[int], None]) -> "FaultInjector":
        """Wrap a legacy ``fail_hook(step)`` callback (the pre-harness
        idiom) so training code has one injection API."""
        inj = cls()
        inj._hook = hook
        return inj

    # -- live scripting (benchmarks pull devices mid-run) -------------------- #
    def lose_device(self, ordinal: int, *, after_calls: int = 0) -> None:
        """Script a device loss on a LIVE injector (counted from the calls
        already placed on the ordinal)."""
        with self._lock:
            base = self._device_calls.get(int(ordinal), 0)
            self.plan.device_losses[int(ordinal)] = base + int(after_calls)

    def remap_devices(self, mapping: Any) -> None:
        """Renumber device-keyed state after an inventory re-densification
        (old ordinal -> new ordinal, i.e. ``InventoryDiff.survivors``).
        Entries for ordinals absent from the mapping — the lost devices —
        are dropped: their loss is now encoded in the inventory itself,
        so the re-planned executor must not re-trigger it on whichever
        survivor inherited the ordinal."""
        with self._lock:
            m = {int(k): int(v) for k, v in dict(mapping).items()}
            self.plan.device_losses = {
                m[o]: c for o, c in self.plan.device_losses.items() if o in m}
            self._device_calls = {
                m[o]: c for o, c in self._device_calls.items() if o in m}
            self._lost = {m[o] for o in self._lost if o in m}

    # -- executor hook -------------------------------------------------------- #
    def on_stage_call(self, stage: int, *, replica: int | None = None,
                      device: int | None = None) -> None:
        """Called by the executor before every stage body.  Raises the
        scripted fault for this invocation (or sleeps for a scripted
        slowdown); returns normally otherwise."""
        plan = self.plan
        sleep_ms = 0.0
        with self._lock:
            n = self._stage_calls.get(stage, 0)
            self._stage_calls[stage] = n + 1
            if device is not None:
                dn = self._device_calls.get(device, 0)
                self._device_calls[device] = dn + 1
                cut = plan.device_losses.get(device)
                if cut is not None and dn >= cut:
                    self._lost.add(device)
                    self.device_faults += 1
                    raise DeviceLostError(
                        f"injected device loss: ordinal {device} "
                        f"(stage {stage} replica {replica}, device call "
                        f"{dn})", device)
            if n in plan.transients.get(stage, ()):
                self.injected += 1
                raise InjectedFault(
                    f"injected transient: stage {stage} call {n}"
                    + (f" (replica {replica})" if replica is not None else ""))
            if plan.random_spec is not None:
                seed, rate, stages, from_call = plan.random_spec
                if (stages is None or stage in stages) and n >= from_call \
                        and _hash_draw(seed, stage, n) < rate:
                    self.injected += 1
                    raise InjectedFault(
                        f"injected random transient: stage {stage} call {n}")
            for s, extra_ms, lo, hi in plan.slowdowns:
                if s == stage and lo <= n and (hi is None or n < hi):
                    sleep_ms += extra_ms
            if sleep_ms:
                self.slowed += 1
        if sleep_ms:                          # sleep OUTSIDE the lock
            time.sleep(sleep_ms / 1e3)

    # -- training hook -------------------------------------------------------- #
    def on_step(self, step: int) -> None:
        """Called by the training driver before each step; raises the
        scripted step fault (once per scripted step)."""
        if self._hook is not None:
            self._hook(step)
            return
        with self._lock:
            if step in self.plan.step_faults and step not in self._steps_fired:
                self._steps_fired.add(step)
                self.injected += 1
                raise InjectedFault(f"injected step fault at step {step}")

    # -- elastic-inventory hook ------------------------------------------------ #
    def lost_ordinals(self) -> frozenset[int]:
        """Ordinals whose scripted loss has TRIGGERED (a loss scripted but
        never hit by a stage call is not yet observable, exactly like a
        real chip that failed while idle and unprobed)."""
        with self._lock:
            return frozenset(self._lost)

    def surviving(self, inventory: Any) -> Any:
        """Post-loss inventory: ``inventory`` minus the triggered losses —
        the ``probe`` argument for ``DeviceInventory.refresh``."""
        lost = self.lost_ordinals()
        return inventory.drop(lost) if lost else inventory

    def stage_calls(self, stage: int) -> int:
        with self._lock:
            return self._stage_calls.get(stage, 0)

    def stats(self) -> dict:
        with self._lock:
            return {"injected": self.injected,
                    "device_faults": self.device_faults,
                    "slowed": self.slowed,
                    "lost_ordinals": sorted(self._lost)}


def as_injector(faults: Any) -> FaultInjector | None:
    """Normalize a ``faults=`` argument: a plan is built, an injector
    passes through, ``None`` stays ``None``."""
    if faults is None or isinstance(faults, FaultInjector):
        return faults
    if isinstance(faults, FaultPlan):
        return faults.build()
    raise TypeError(f"faults must be a FaultPlan or FaultInjector, "
                    f"got {type(faults).__name__}")
