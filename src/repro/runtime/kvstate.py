"""Per-request KV-cache slot pool — stateful decode serving.

Courier's pipeline treats every token as a pure function of its inputs;
decode-style traffic is not: step ``t`` of a request attends over the
keys/values written by steps ``0..t-1``.  Re-running the full prefix per
step (what the traced zoo attention does today) turns an O(1) decode step
into O(t) — the workload continuous batching exists to serve becomes the
workload that can't use it.

:class:`KVSlotPool` is the missing state layer: a fixed arena of
per-request cache slots, host-resident (numpy), keyed by an integer
``slot_id`` that rides through the pipeline env as an ordinary stage
input.  The serving layer allocates a slot at admission, threads the id
through every decode step of the request, and frees it at retirement —
on EVERY terminal path (served/shed/expired/failed), which the
``state-slot-leak`` lint rule and the serve-layer release hook enforce.

Slot ``-1`` is the *dead-row* id: padding rows and evicted seats in a
continuously-batched group carry it, and every pool mutation on it is a
no-op — a padded group can run the stateful stage without double-writing
any live request's cache.

The pool is intentionally host-side and lock-guarded rather than a jnp
carry: stateful nodes are ``serial_only`` (one worker observes writes in
token order), never jitted, never fused, never hw-placed — the
``state-slot`` verify rule rejects plans that violate any of those.
"""
from __future__ import annotations

import threading
from typing import Any, Iterable, Mapping

import numpy as np

__all__ = ["KVSlotPool", "DecodeSession", "SlotError"]


class SlotError(RuntimeError):
    """Illegal slot-pool transition (double free, use-after-free,
    exhaustion, alias).  Loud by design: every one of these is a serving
    bug that would otherwise corrupt another request's cache."""


class KVSlotPool:
    """Fixed arena of per-request cache slots.

    Parameters
    ----------
    n_slots:
        Concurrent live requests the arena supports.  ``alloc`` raises
        :class:`SlotError` when exhausted — admission control, not the
        pool, decides what to do about that.
    max_seq:
        Rows per slot (the longest prefix a request may accumulate).
    specs:
        Named per-row buffer shapes, e.g. ``{"k": (n_heads, head_dim),
        "v": (n_heads, head_dim)}``.  Each named buffer is one
        ``[n_slots, max_seq, *spec]`` arena.
    dtype:
        Element dtype of every arena (default float32).
    """

    def __init__(self, n_slots: int, max_seq: int,
                 specs: Mapping[str, tuple[int, ...]],
                 dtype: Any = np.float32):
        if n_slots < 1:
            raise ValueError(f"n_slots must be >= 1 (got {n_slots})")
        if max_seq < 1:
            raise ValueError(f"max_seq must be >= 1 (got {max_seq})")
        if not specs:
            raise ValueError("specs must name at least one buffer")
        self.n_slots = int(n_slots)
        self.max_seq = int(max_seq)
        self.specs = {str(k): tuple(int(d) for d in v)
                      for k, v in specs.items()}
        self.dtype = np.dtype(dtype)
        self._buf = {k: np.zeros((self.n_slots, self.max_seq) + shp,
                                 dtype=self.dtype)
                     for k, shp in self.specs.items()}
        self._len = np.zeros(self.n_slots, dtype=np.int64)
        self._live = [False] * self.n_slots
        self._free: list[int] = list(range(self.n_slots - 1, -1, -1))
        self._lock = threading.Lock()
        self.allocs = 0
        self.frees = 0
        self.high_water = 0

    # -- lifecycle ----------------------------------------------------------- #
    def alloc(self) -> int:
        """Claim a free slot (length reset to 0).  Never returns a slot
        that is already live — aliasing a live request's cache is the one
        unrecoverable serving bug, so exhaustion raises instead."""
        with self._lock:
            if not self._free:
                raise SlotError(
                    f"slot pool exhausted ({self.n_slots} live); free a "
                    "retired request's slot before admitting another")
            s = self._free.pop()
            if self._live[s]:  # free-list corruption — fail loudly
                raise SlotError(f"free list returned live slot {s}")
            self._live[s] = True
            self._len[s] = 0
            self.allocs += 1
            self.high_water = max(self.high_water, self.live_count())
            return s

    def free(self, slot: int) -> None:
        """Release a live slot.  Slot ``-1`` (dead row) is a no-op;
        freeing a non-live slot raises (double-free guard)."""
        if slot < 0:
            return
        with self._lock:
            if not (0 <= slot < self.n_slots) or not self._live[slot]:
                raise SlotError(f"free of non-live slot {slot}")
            self._live[slot] = False
            self._len[slot] = 0
            self._free.append(slot)
            self.frees += 1

    # -- per-step access ------------------------------------------------------ #
    def append(self, slot: int, **rows: Any) -> int:
        """Write one row per named buffer at the slot's current length and
        advance it; returns the row index written.  Slot ``-1`` discards
        (returns -1); appending to a freed slot raises (use-after-free)."""
        if slot < 0:
            return -1
        extra = set(rows) - set(self._buf)
        if extra or set(self._buf) - set(rows):
            raise SlotError(
                f"append must write every buffer {sorted(self._buf)} "
                f"(got {sorted(rows)})")
        with self._lock:
            if not (0 <= slot < self.n_slots) or not self._live[slot]:
                raise SlotError(f"append to non-live slot {slot} "
                                "(use-after-free?)")
            pos = int(self._len[slot])
            if pos >= self.max_seq:
                raise SlotError(
                    f"slot {slot} full ({self.max_seq} rows)")
            for k, v in rows.items():
                self._buf[k][slot, pos] = np.asarray(v, dtype=self.dtype)
            self._len[slot] = pos + 1
            return pos

    def read(self, slot: int) -> dict[str, np.ndarray]:
        """Copies of the slot's filled rows per buffer ([len, *spec]).
        Slot ``-1`` reads as empty ([0, *spec]) so dead rows attend over
        nothing without a special case in the caller."""
        with self._lock:
            if slot < 0:
                return {k: np.zeros((0,) + shp, dtype=self.dtype)
                        for k, shp in self.specs.items()}
            if not (0 <= slot < self.n_slots) or not self._live[slot]:
                raise SlotError(f"read of non-live slot {slot}")
            n = int(self._len[slot])
            return {k: b[slot, :n].copy() for k, b in self._buf.items()}

    def length(self, slot: int) -> int:
        """Filled rows of a slot (0 for the dead row) — the decode step's
        absolute position, e.g. the RoPE offset."""
        if slot < 0:
            return 0
        with self._lock:
            if not (0 <= slot < self.n_slots) or not self._live[slot]:
                raise SlotError(f"length of non-live slot {slot}")
            return int(self._len[slot])

    # -- audits --------------------------------------------------------------- #
    def live_count(self) -> int:
        return sum(self._live)

    def live_slots(self) -> list[int]:
        with self._lock:
            return [i for i, v in enumerate(self._live) if v]

    def check_no_leaks(self, expected_live: Iterable[int] = ()) -> None:
        """Raise unless exactly ``expected_live`` slots are live — the
        benchmark/test end-of-run leak audit."""
        with self._lock:
            live = {i for i, v in enumerate(self._live) if v}
        exp = set(expected_live)
        if live != exp:
            raise SlotError(
                f"slot leak audit failed: live={sorted(live)} "
                f"expected={sorted(exp)} (allocs={self.allocs} "
                f"frees={self.frees})")

    def stats(self) -> dict:
        with self._lock:
            return {"n_slots": self.n_slots, "live": sum(self._live),
                    "allocs": self.allocs, "frees": self.frees,
                    "high_water": self.high_water}


class DecodeSession:
    """Context-managed slot lifetime: alloc on enter, free on exit.

    The free runs on ALL exits (normal and exception), so driver loops
    that die mid-request still return the slot — the runtime counterpart
    of the ``state-slot-leak`` lint rule.
    """

    def __init__(self, pool: KVSlotPool):
        self.pool = pool
        self.slot: int | None = None

    def __enter__(self) -> "DecodeSession":
        self.slot = self.pool.alloc()
        return self

    def __exit__(self, *exc: Any) -> bool:
        if self.slot is not None:
            self.pool.free(self.slot)
            self.slot = None
        return False
