from .driver import (ElasticPlanner, FaultTolerantDriver, ReplanDecision,
                     StragglerMonitor, TrainResult)
from .faults import (DeviceLostError, FaultInjector, FaultPlan,
                     InjectedFault, as_injector)
from .kvstate import DecodeSession, KVSlotPool, SlotError

__all__ = ["ElasticPlanner", "FaultTolerantDriver", "ReplanDecision",
           "StragglerMonitor", "TrainResult",
           "DeviceLostError", "FaultInjector", "FaultPlan",
           "InjectedFault", "as_injector",
           "DecodeSession", "KVSlotPool", "SlotError"]
