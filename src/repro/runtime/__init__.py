from .driver import (ElasticPlanner, FaultTolerantDriver, ReplanDecision,
                     StragglerMonitor, TrainResult)
from .faults import (DeviceLostError, FaultInjector, FaultPlan,
                     InjectedFault, as_injector)

__all__ = ["ElasticPlanner", "FaultTolerantDriver", "ReplanDecision",
           "StragglerMonitor", "TrainResult",
           "DeviceLostError", "FaultInjector", "FaultPlan",
           "InjectedFault", "as_injector"]
