from .driver import (ElasticPlanner, FaultTolerantDriver, StragglerMonitor,
                     TrainResult)

__all__ = ["ElasticPlanner", "FaultTolerantDriver", "StragglerMonitor",
           "TrainResult"]
