from .driver import (ElasticPlanner, FaultTolerantDriver, ReplanDecision,
                     StragglerMonitor, TrainResult)

__all__ = ["ElasticPlanner", "FaultTolerantDriver", "ReplanDecision",
           "StragglerMonitor", "TrainResult"]
