"""Distributed runtime — fault tolerance, stragglers, elastic re-planning.

* :class:`FaultTolerantDriver` — checkpoint/restart training loop: periodic
  (async) checkpoints, automatic reload-and-continue on step failure with
  bounded retries.  Deterministic data (``batch(step)``) makes the restart
  bit-exact: a resumed run re-executes the same token stream.
* :class:`StragglerMonitor` — per-step deadline tracking against a running
  median; flags and (optionally) re-dispatches slow steps.  On a real pod
  the re-dispatch hook would reschedule the step on a spare slice; here it
  re-issues the computation, which also covers transient host stalls.
* :class:`ElasticPlanner` — the Courier angle on elasticity: when the
  device count changes, *re-run the Pipeline Generator* to re-balance stage
  boundaries for the surviving resources (paper's balanced partition, new
  resource count), instead of aborting the job.  With a module database it
  also owns the serving-side executor: :meth:`ElasticPlanner.executor_for`
  recompiles the stage functions and rebuilds the
  :class:`~repro.core.executor.PipelineExecutor` *only* when the re-planned
  stage boundaries actually change, so an elastic resize is a cheap no-op
  when the balanced partition is unaffected.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable

import numpy as np

from repro.core.ir import CourierIR
from repro.core.partition import (PipelinePlan, StagePlan, assign_replicas,
                                  partition_optimal)
from repro.core.placement import (DeviceInventory, InventoryDiff,
                                  resolve_worker_budget)
from repro.runtime.faults import as_injector


# --------------------------------------------------------------------------- #
# Straggler mitigation
# --------------------------------------------------------------------------- #
class StragglerMonitor:
    def __init__(self, threshold: float = 3.0, window: int = 32):
        self.threshold = threshold
        self.times: list[float] = []
        self.window = window
        self.flagged: list[tuple[int, float]] = []

    def record(self, step: int, dt: float) -> bool:
        """Returns True if this step is a straggler (→ caller may re-dispatch)."""
        hist = self.times[-self.window:]
        self.times.append(dt)
        if len(hist) < 8:
            return False
        med = float(np.median(hist))
        if dt > self.threshold * med:
            self.flagged.append((step, dt))
            return True
        return False


# --------------------------------------------------------------------------- #
# Elastic re-planning (Courier re-balance on resource change OR profile drift)
# --------------------------------------------------------------------------- #
@dataclass
class ReplanDecision:
    """Outcome of one :meth:`ElasticPlanner.replan_from_profile` check."""

    replanned: bool
    reason: str
    old_bottleneck_ms: float          # measured bottleneck of the old plan
    new_bottleneck_ms: float          # predicted bottleneck of the new plan
    gain: float                       # old / new (1.0 when not replanned)
    defused: list[str] = field(default_factory=list)   # fused nodes split
    plan: Any = None                  # new PipelinePlan (None if unchanged)
    executor: Any = None              # new executor (None if unchanged)
    widened: bool = False             # won by replication, not re-balancing
    replicas: list[int] | None = None  # chosen per-stage worker counts

    def describe(self) -> str:
        verdict = "REPLAN" if self.replanned else "keep"
        return (f"[{verdict}] {self.reason}: measured bottleneck "
                f"{self.old_bottleneck_ms:.3f} ms -> predicted "
                f"{self.new_bottleneck_ms:.3f} ms ({self.gain:.2f}x)"
                + (f", replicas {self.replicas}" if self.widened else "")
                + (f", defused {self.defused}" if self.defused else ""))


class ElasticPlanner:
    """Re-balance pipeline stage boundaries when the stage count changes —
    or when the *online profile* contradicts the cost table the current
    plan was balanced on.

    ``db`` (optional) enables the executor path: the planner can then turn
    a re-balanced plan into compiled stage functions and a running
    :class:`~repro.core.executor.PipelineExecutor`, caching the current
    executor keyed by its stage boundaries.  A persistent ``StageFn`` cache
    (shared across every plan this planner builds) keeps the compiled
    executables of stages whose boundaries didn't move, so a profile-driven
    re-plan recompiles only the stages that actually changed.

    Re-plan policy knobs (hysteresis — no flapping under noisy timings):

    * ``min_gain`` — a new plan must beat the measured bottleneck by this
      factor before the executor is rebuilt (default 1.15);
    * ``margin`` — measured-vs-model contradiction factor that triggers a
      fuse/no-fuse revisit (default
      :data:`repro.core.costmodel.PROFILE_MARGIN`);
    * ``min_samples`` — per-stage sample floor before the profile is
      trusted at all (also enforced by the profiler's window median, which
      is itself robust to stragglers).
    """

    def __init__(self, layer_ir: CourierIR, db: Any = None, *,
                 min_gain: float = 1.15, margin: float | None = None,
                 min_samples: int = 4,
                 inventory: DeviceInventory | None = None,
                 fault_injector: Any = None, max_group_retries: int = 3,
                 quarantine_after: int = 1,
                 retry_budget_ms: float | None = None):
        from repro.core.costmodel import PROFILE_MARGIN

        self.layer_ir = layer_ir
        self.db = db
        # the devices the planner places stage replicas onto; None keeps
        # the host-thread widening (devices unpinned, today's behavior)
        self.inventory = inventory
        self.min_gain = float(min_gain)
        self.margin = PROFILE_MARGIN if margin is None else float(margin)
        self.min_samples = int(min_samples)
        # fault-tolerance knobs forwarded to every executor this planner
        # builds (constructor state, NOT cache-key material: swapping the
        # injector mid-run would otherwise force a spurious rebuild)
        self.fault_injector = as_injector(fault_injector)
        self.max_group_retries = int(max_group_retries)
        self.quarantine_after = int(quarantine_after)
        self.retry_budget_ms = (None if retry_budget_ms is None
                                else float(retry_budget_ms))
        self._cached: tuple[tuple, Any] | None = None
        self._current_plan: PipelinePlan | None = None
        self._stagefn_cache: dict = {}    # stage identity -> StageFn (reuse)
        # first-seen MODEL times per node, captured before any profile
        # write-back: the fusion-revisit contradiction check compares
        # measurements against the model, not against older measurements
        # (which would let gradual drift creep under the margin forever)
        self._model_ms: dict[str, float] = {}
        self.rebuilds = 0                 # executor recompiles (observability)
        self.replans = 0                  # profile-driven plan changes
        self.replan_checks = 0            # replan_from_profile invocations
        self.last_decision: ReplanDecision | None = None

    def plan(self, n_stages: int) -> PipelinePlan:
        return partition_optimal(self.layer_ir, max_stages=n_stages)

    def boundaries(self, n_stages: int) -> list[int]:
        plan = self.plan(n_stages)
        bounds, i = [], 0
        for s in plan.stages:
            bounds.append(i)
            i += len(s.node_names)
        return bounds

    @property
    def current_plan(self) -> PipelinePlan | None:
        return self._current_plan

    def stagefns_cached(self) -> int:
        """Size of the cross-plan StageFn cache (observability)."""
        return len(self._stagefn_cache)

    @staticmethod
    def _cache_key(plan: PipelinePlan, replicas, max_in_flight, microbatch,
                   jit, stage_workers, profiler, devices=None) -> tuple:
        """Executor-cache identity: plan shape + replicas + device pinning
        + executor config.

        Single source of truth for both :meth:`executor_for` and
        :meth:`replan_from_profile` — a key-shape change that touched only
        one site would silently serve stale (or needlessly rebuilt)
        executors.
        """
        return (tuple(len(s.node_names) for s in plan.stages),
                tuple(replicas) if replicas else None,
                tuple(tuple(row) for row in devices) if devices else None,
                max_in_flight, microbatch, jit, stage_workers, id(profiler))

    def _build_executor(self, plan: PipelinePlan, *, max_in_flight, microbatch,
                        jit, profiler=None, stage_workers=False,
                        replicas=None, devices=None) -> Any:
        from repro.core.executor import PipelineExecutor
        from repro.core.pipeline import assign_placements, make_stage_fns

        assign_placements(self.layer_ir, self.db)
        fns = make_stage_fns(self.layer_ir, self.db, plan, jit=jit,
                             cache=self._stagefn_cache)
        # captured graph inputs (traced closure weights) are baked into the
        # stage fns — the executor only sees the per-token inputs
        cap = getattr(self.layer_ir, "captured", {})
        token_inputs = [g for g in self.layer_ir.graph_inputs if g not in cap]
        return PipelineExecutor(fns, token_inputs,
                                self.layer_ir.graph_outputs,
                                max_in_flight=max_in_flight,
                                microbatch=microbatch, profiler=profiler,
                                stage_workers=stage_workers,
                                replicas=replicas, devices=devices,
                                inventory=self.inventory,
                                fault_injector=self.fault_injector,
                                max_group_retries=self.max_group_retries,
                                quarantine_after=self.quarantine_after,
                                retry_budget_ms=self.retry_budget_ms)

    def _widen(self, plan: PipelinePlan, worker_budget) -> tuple:
        """Run the widening pass on ``plan``; returns (replicas, devices)
        for the executor — (None, None) when no stage widened (or no
        budget resolved), so serial plans keep the async-dispatch path
        with no stale pinnings (see
        :func:`~repro.core.partition.widen_for_deployment`)."""
        from repro.core.partition import widen_for_deployment

        return widen_for_deployment(plan, self.layer_ir,
                                    worker_budget=worker_budget,
                                    inventory=self.inventory)

    def executor_for(self, n_stages: int, *, max_in_flight: int | None = None,
                     microbatch: int = 1, jit: bool = True,
                     profiler: Any = None,
                     stage_workers: bool = False,
                     worker_budget: "int | str | None" = None,
                     ) -> tuple[Any, bool]:
        """(executor, rebuilt) for a resource count of ``n_stages``.

        Re-partitions the IR for the new stage count; when the resulting
        stage boundaries (or the requested executor config) differ from the
        cached executor's, stage functions are recompiled and a fresh
        executor is returned (``rebuilt=True``).  An unchanged partition
        with the same config reuses the cached executor (``rebuilt=False``)
        — in-flight work and warm compilations survive the resize.

        ``worker_budget`` widens stages beyond one worker each
        (:func:`~repro.core.partition.assign_replicas` over the planned
        stage times) and runs the executor in replicated mode: an int is
        the explicit budget, :data:`~repro.core.placement.AUTO_BUDGET`
        derives it from the cpu-count governor, and ``None`` widens only
        when the planner holds a :class:`~repro.core.placement.
        DeviceInventory` (whose devices then pin the replicas).
        """
        if self.db is None:
            raise ValueError("ElasticPlanner needs a ModuleDatabase to build "
                             "executors; pass db= at construction")
        plan = self.plan(n_stages)
        replicas, devices = self._widen(plan, worker_budget)
        key = self._cache_key(plan, replicas, max_in_flight, microbatch,
                              jit, stage_workers, profiler, devices)
        if self._cached is not None and self._cached[0] == key \
                and not getattr(self._cached[1], "closed", False):
            return self._cached[1], False
        ex = self._build_executor(plan, max_in_flight=max_in_flight,
                                  microbatch=microbatch, jit=jit,
                                  profiler=profiler,
                                  stage_workers=stage_workers,
                                  replicas=replicas, devices=devices)
        self._cached = (key, ex)
        self._current_plan = plan
        self.rebuilds += 1
        return ex, True

    def replan_from_profile(self, profiler: Any, *,
                            max_stages: int | None = None,
                            max_in_flight: int | None = None,
                            microbatch: int = 1, jit: bool = True,
                            stage_workers: bool = False,
                            min_gain: float | None = None,
                            margin: float | None = None,
                            min_samples: int | None = None,
                            revisit_fusion: bool = True,
                            worker_budget: "int | str | None" = None,
                            new_profiler: Any = None,
                            slo_violation_rate: float | None = None,
                            slo_replan_threshold: float = 0.05,
                            ) -> ReplanDecision:
        """Profile-guided re-plan check: measured costs -> maybe new executor.

        The decision rule (documented in EXPERIMENTS.md):

        1. **Trust gate** — every current stage needs ``min_samples``
           measurements; otherwise keep the plan ("insufficient profile").
        2. **Write-back** — measured stage medians are attributed to nodes
           (:meth:`StageProfiler.apply_to_ir`), superseding roofline
           estimates (``time_source="profile"``).
        3. **Fusion revisit** — a fused node whose measured time
           contradicts its model by ``margin`` is split back into its
           parts (:func:`~repro.core.partition.split_fused_node`), letting
           the partitioner place them in separate stages.
        4. **Re-balance** — ``partition_optimal`` over the measured costs
           (``max_stages`` defaults to the current stage count).  With a
           ``worker_budget``, a second candidate **widens** the current
           boundaries instead (:func:`~repro.core.partition.
           assign_replicas` over the measured stage times — the TBB
           parallel-filter move: multiply workers on the bottleneck stage
           rather than move work off it), the re-balanced candidate is
           widened too, and the plan whose *effective* (replication-aware)
           bottleneck the cost model predicts smallest wins.  Ties go to
           widening: unchanged boundaries mean every compiled StageFn is
           reused, so the hot-swap costs zero recompiles.
        5. **Hysteresis** — rebuild only when the predicted effective
           bottleneck beats the *measured* effective bottleneck by
           ``min_gain`` AND the plan (boundaries or replicas) actually
           changed; otherwise keep serving the current executor.  Window
           medians + this threshold are what prevent plan flapping under
           noisy timings.

        **SLO pressure** — when the serving layer reports
        ``slo_violation_rate`` (fraction of completed requests that
        missed their deadline, see
        :meth:`~repro.launch.serve.RequestQueueServer.slo_violation_rate`)
        at or above ``slo_replan_threshold``, the hysteresis gate is
        waived (``min_gain`` treated as 1.0): requests are already
        failing their deadlines, so *any* predicted improvement is worth
        a zero-drop hot-swap — stage medians alone can look healthy
        while queueing delay destroys the SLO.  The plan-identity check
        still applies (an unchanged plan is never rebuilt).

        The new executor shares the planner's StageFn cache, so stages with
        unchanged boundaries keep their compiled executables (bounded
        recompiles during the serving layer's hot-swap).
        """
        from repro.core.costmodel import (measured_contradicts,
                                          replicated_bottleneck_ms)
        from repro.core.partition import split_fused_node

        if self.db is None:
            raise ValueError("ElasticPlanner needs a ModuleDatabase to build "
                             "executors; pass db= at construction")
        if self._current_plan is None:
            raise ValueError("no current plan: call executor_for() before "
                             "replan_from_profile()")
        min_gain = self.min_gain if min_gain is None else float(min_gain)
        slo_pressure = (slo_violation_rate is not None
                        and slo_violation_rate >= slo_replan_threshold)
        if slo_pressure:
            # deadlines are already being missed: any predicted gain
            # justifies a (zero-drop) swap, so hysteresis is waived
            min_gain = min(min_gain, 1.0)
        margin = self.margin if margin is None else float(margin)
        min_samples = self.min_samples if min_samples is None \
            else int(min_samples)
        self.replan_checks += 1
        plan = self._current_plan

        def keep(reason: str, old_b: float, new_b: float | None = None,
                 defused: list[str] | None = None) -> ReplanDecision:
            d = ReplanDecision(False, reason, old_b, new_b or old_b,
                               1.0 if not new_b else old_b / max(new_b, 1e-12),
                               defused or [])
            self.last_decision = d
            return d

        # 1) trust gate: the caller's (possibly lower) min_samples decides,
        #    so query the window directly rather than measured_ms (which
        #    enforces the profiler's own floor)
        if plan.n_stages > profiler.n_stages or \
                min(profiler.samples(k) for k in range(plan.n_stages)) \
                < min_samples:
            return keep("insufficient profile", 0.0)
        measured = [profiler.percentile_ms(k, 50.0)
                    for k in range(plan.n_stages)]
        if any(m is None for m in measured):
            return keep("insufficient profile", 0.0)
        # the profiler measures per-invocation SERVICE time; a stage already
        # replicated r-wide retires tokens at service/r, so the baseline the
        # candidates must beat is the effective period
        old_bottleneck = replicated_bottleneck_ms(measured, plan.replicas)

        # 2) measured costs supersede the model (in-place: time_ms only,
        #    so the current plan's node names stay valid either way).
        #    Snapshot each node's model time FIRST — and only while it is
        #    still a model ("profile" write-backs from earlier checks must
        #    not become the baseline)
        for n in self.layer_ir.nodes:
            if n.time_source != "profile" and n.time_ms is not None:
                self._model_ms.setdefault(n.name, n.time_ms)
        model_ms = {n.name: self._model_ms.get(n.name, n.time_ms)
                    for n in self.layer_ir.nodes}
        profiler.apply_to_ir(self.layer_ir, plan, min_samples=min_samples)

        # 3) fuse/no-fuse revisit under measured costs — STAGED on a local
        #    IR and committed only if the re-plan is accepted; a defuse on
        #    the keep path would orphan the current plan's fused stages
        ir = self.layer_ir
        defused: list[str] = []
        if revisit_fusion:
            for n in list(ir.nodes):
                if n.fused_from and measured_contradicts(
                        model_ms.get(n.name), n.time_ms, margin):
                    ir = split_fused_node(ir, n.name)
                    defused.append(n.name)

        # 4) re-balance on measured costs — and, under a worker budget, the
        #    competing widen-in-place candidate (same boundaries, replicated
        #    bottleneck stage).  The cost model's effective bottleneck picks
        #    the winner.
        new_plan = partition_optimal(
            ir,
            max_stages=max_stages if max_stages is not None else plan.n_stages)
        chosen, widened = new_plan, False
        wb_new = resolve_worker_budget(worker_budget, new_plan.n_stages,
                                       self.inventory)
        if wb_new is not None:
            assign_replicas(new_plan, ir, worker_budget=wb_new,
                            inventory=self.inventory)
            widen = PipelinePlan(
                stages=[StagePlan(node_names=list(s.node_names),
                                  est_time_ms=float(m), kind=s.kind,
                                  placements=list(s.placements),
                                  comm_in_bytes=s.comm_in_bytes)
                        for s, m in zip(plan.stages, measured)],
                policy="widen")
            # widening never moves boundaries, so serial_only markers are
            # checked against the CURRENT (possibly still-fused) IR
            wb_widen = resolve_worker_budget(worker_budget, widen.n_stages,
                                             self.inventory)
            assign_replicas(widen, self.layer_ir, worker_budget=wb_widen,
                            inventory=self.inventory)
            if plan.stage_devices is not None:
                # the current deployment is device-pinned, so the measured
                # stage times the candidates are built on ALREADY reflect
                # the devices that ran them — staging hop included (the
                # replica loop records service time, put included) and
                # device speed included.  Re-adding the modeled transfer
                # or dividing by device_speeds again would double-charge
                # / double-credit them and bias the comparison; the
                # pinnings themselves stay (the executor needs them).
                # The delta of a changed topology stays unmodeled here;
                # the next profile window measures it.
                for cand in (new_plan, widen):
                    for s in cand.stages:
                        s.xfer_in_ms = 0.0
                        s.device_speeds = []
            if widen.effective_bottleneck_ms \
                    <= new_plan.effective_bottleneck_ms * (1.0 + 1e-9):
                chosen, widened = widen, True

        # 5) hysteresis (plan identity = boundaries AND replicas)
        same_plan = (
            (widened or not defused)
            and [s.node_names for s in chosen.stages]
            == [s.node_names for s in plan.stages]
            and chosen.replicas == plan.replicas)
        if same_plan:
            return keep("plan unchanged", old_bottleneck)
        new_bottleneck = chosen.effective_bottleneck_ms
        gain = old_bottleneck / max(new_bottleneck, 1e-12)
        if gain < min_gain:
            return keep(f"gain {gain:.2f}x below hysteresis threshold "
                        f"{min_gain:.2f}x", old_bottleneck,
                        new_bottleneck, defused if not widened else [])

        # static legality gate: a candidate that fails verification is
        # DISCARDED — the current executor keeps serving, nothing is
        # committed (no IR, no plan, no cache entry), and the decision
        # records why.  Widening verifies against the current (possibly
        # fused) IR; a re-balance against the staged (possibly defused) one.
        from repro.analysis.verify import PlanVerificationError, check_plan
        try:
            check_plan(self.layer_ir if widened else ir, chosen,
                       db=self.db, inventory=self.inventory,
                       where="ElasticPlanner.replan_from_profile")
        except PlanVerificationError as e:
            return keep(f"candidate failed verification ({', '.join(e.rules)})",
                        old_bottleneck, new_bottleneck)

        prof = new_profiler
        if prof is None and hasattr(profiler, "clone_for"):
            prof = profiler.clone_for(chosen.n_stages)
        if not widened:
            self.layer_ir = ir            # commit the (possibly defused) IR
        else:
            defused = []                  # widening kept the fused stages
        replicas = chosen.replicas if any(r > 1 for r in chosen.replicas) \
            else None
        if replicas is None:
            # deployed unpinned: the plan must not keep charging device
            # transfer costs the executor never pays
            from repro.core.partition import clear_stage_devices
            clear_stage_devices(chosen)
        devices = chosen.stage_devices if replicas is not None else None
        ex = self._build_executor(plan=chosen, max_in_flight=max_in_flight,
                                  microbatch=microbatch, jit=jit,
                                  profiler=prof, stage_workers=stage_workers,
                                  replicas=replicas, devices=devices)
        key = self._cache_key(chosen, replicas, max_in_flight, microbatch,
                              jit, stage_workers, prof, devices)
        self._cached = (key, ex)
        self._current_plan = chosen
        self.rebuilds += 1
        self.replans += 1
        reason = ("measured costs widened the bottleneck stage" if widened
                  else "measured costs re-balanced the plan")
        if slo_pressure:
            reason += (f" (SLO pressure: {slo_violation_rate:.1%} violation "
                       "rate waived hysteresis)")
        d = ReplanDecision(
            True, reason,
            old_bottleneck, new_bottleneck, gain,
            defused, chosen, ex, widened=widened,
            replicas=list(chosen.replicas))
        self.last_decision = d
        return d

    def autoscale_from_ladder(self, admission: Any, profiler: Any, *,
                              worker_budget: "int | str",
                              streak: int = 3,
                              **replan_kw: Any) -> ReplanDecision | None:
        """Capacity response to sustained overload: widen instead of shed.

        The admission controller's degradation ladder sheds load when the
        predicted backlog breaches its reference — the right *transient*
        response, and the wrong *steady-state* one: a server pinned at
        ladder level 2 is simply under-provisioned, and shedding forever
        converts a capacity problem into a permanent availability loss.
        This method watches the controller's ``level2_streak`` (consecutive
        observation windows whose worst admission-time level reached 2,
        one window per dispatched batch) and, once the streak reaches
        ``streak``, runs :meth:`replan_from_profile` with the given
        ``worker_budget`` — the widening candidate multiplies workers on
        the measured bottleneck stage, which raises the very period the
        ladder's backlog prediction is built on.

        Returns ``None`` while the streak is below the trigger; otherwise
        the :class:`ReplanDecision` (which the caller deploys through
        ``RequestQueueServer.swap_executor`` when ``replanned``).  The
        streak is reset either way — one sustained burst triggers one
        widen attempt, and the ladder keeps protecting the server while
        the next profile window accumulates.
        """
        if int(streak) < 1:
            raise ValueError(f"streak must be >= 1 (got {streak})")
        if int(admission.level2_streak) < int(streak):
            return None
        decision = self.replan_from_profile(profiler,
                                            worker_budget=worker_budget,
                                            **replan_kw)
        admission.reset_streak()
        return decision

    def replan_on_inventory_change(self, diff: InventoryDiff, *,
                                   profiler: Any = None, stats: Any = None,
                                   max_in_flight: int | None = None,
                                   microbatch: int = 1, jit: bool = True,
                                   stage_workers: bool = False,
                                   worker_budget: "int | str | None" = None,
                                   new_profiler: Any = None) -> ReplanDecision:
        """Survivors-only re-plan after a device loss/gain.

        Takes the structured :class:`~repro.core.placement.InventoryDiff`
        from ``DeviceInventory.refresh()`` and, when it reports a change:

        1. adopts ``diff.new`` as the planner's inventory (and renumbers
           the fault injector's device-keyed state along
           ``diff.survivors``);
        2. builds a **survivors candidate**: the current stage boundaries
           (no recompiles — every StageFn is reused) re-widened by
           :func:`~repro.core.partition.assign_replicas` onto the
           surviving devices, using measured stage medians when the
           profiler has them;
        3. **de-weights unhealthy survivors**: a surviving device whose
           error count (executor stats + profiler) or per-stage
           ``device_ms`` marks it slow has its inventory speed scaled
           down, so the widening pass prefers its healthy peers;
        4. runs the candidate through the static verify gate — an illegal
           candidate keeps the current executor serving;
        5. rebuilds the executor (shared StageFn cache) for the serving
           layer to deploy via ``swap_executor`` — the zero-drop hot-swap.

        Unlike :meth:`replan_from_profile` there is no hysteresis: a lost
        device is a hard fact, not a noisy timing.
        """
        from repro.core.costmodel import replicated_bottleneck_ms
        from repro.core.partition import clear_stage_devices

        if self.db is None:
            raise ValueError("ElasticPlanner needs a ModuleDatabase to build "
                             "executors; pass db= at construction")
        if self._current_plan is None:
            raise ValueError("no current plan: call executor_for() before "
                             "replan_on_inventory_change()")
        self.replan_checks += 1
        plan = self._current_plan
        if not diff.changed:
            d = ReplanDecision(False, "inventory unchanged", 0.0, 0.0, 1.0)
            self.last_decision = d
            return d

        self.inventory = diff.new
        if self.fault_injector is not None:
            # scripted losses/counters are keyed by ordinal; follow the
            # survivors into the re-densified numbering
            self.fault_injector.remap_devices(diff.survivors)

        # stage times for the candidate: measured medians when the profile
        # has them (the loss usually happens mid-serve), model otherwise
        times = []
        for k, s in enumerate(plan.stages):
            m = None
            if profiler is not None and k < profiler.n_stages:
                m = profiler.percentile_ms(k, 50.0)
            times.append(float(m) if m is not None
                         else float(s.est_time_ms or 0.0))
        old_bottleneck = replicated_bottleneck_ms(times, plan.replicas)

        # unhealthy-survivor de-weighting: error counts and straggling
        # device_ms medians scale the surviving specs' speeds down
        errs: dict[int, int] = {}
        if stats is not None:
            for d_, c in (getattr(stats, "device_errors", None) or {}).items():
                errs[int(d_)] = errs.get(int(d_), 0) + int(c)
        slow: dict[int, float] = {}
        if profiler is not None:
            if hasattr(profiler, "device_errors"):
                for d_, c in profiler.device_errors().items():
                    errs[int(d_)] = errs.get(int(d_), 0) + int(c)
            for k in range(min(plan.n_stages, profiler.n_stages)):
                per_dev = profiler.device_ms(k)
                if len(per_dev) < 2:
                    continue
                med = float(np.median(list(per_dev.values())))
                for d_, ms in per_dev.items():
                    r = med / ms if ms > 0 else 1.0
                    slow[d_] = min(slow.get(d_, 1.0), min(r, 1.0))
        factors: dict[int, float] = {}
        for old, new in diff.survivors.items():
            f = slow.get(old, 1.0) / (1.0 + errs.get(old, 0))
            if f < 1.0:
                factors[new] = f
        inv = diff.new.reweighted(factors) if factors else diff.new

        cand = PipelinePlan(
            stages=[StagePlan(node_names=list(s.node_names),
                              est_time_ms=float(t), kind=s.kind,
                              placements=list(s.placements),
                              comm_in_bytes=s.comm_in_bytes)
                    for s, t in zip(plan.stages, times)],
            policy="survivors")
        wb = resolve_worker_budget(worker_budget, cand.n_stages, inv)
        assign_replicas(cand, self.layer_ir, worker_budget=wb, inventory=inv)

        from repro.analysis.verify import PlanVerificationError, check_plan
        try:
            check_plan(self.layer_ir, cand, db=self.db, inventory=inv,
                       where="ElasticPlanner.replan_on_inventory_change")
        except PlanVerificationError as e:
            d = ReplanDecision(
                False, "survivors candidate failed verification "
                f"({', '.join(e.rules)})", old_bottleneck, old_bottleneck,
                1.0)
            self.last_decision = d
            return d

        replicas = cand.replicas if any(r > 1 for r in cand.replicas) \
            else None
        if replicas is None:
            clear_stage_devices(cand)
        devices = cand.stage_devices if replicas is not None else None
        prof = new_profiler
        if prof is None and profiler is not None \
                and hasattr(profiler, "clone_for"):
            prof = profiler.clone_for(cand.n_stages)
        ex = self._build_executor(plan=cand, max_in_flight=max_in_flight,
                                  microbatch=microbatch, jit=jit,
                                  profiler=prof, stage_workers=stage_workers,
                                  replicas=replicas, devices=devices)
        key = self._cache_key(cand, replicas, max_in_flight, microbatch,
                              jit, stage_workers, prof, devices)
        self._cached = (key, ex)
        self._current_plan = cand
        self.rebuilds += 1
        self.replans += 1
        d = ReplanDecision(
            True,
            f"inventory changed: lost {list(diff.lost)}, "
            f"gained {list(diff.gained)} -> re-widened onto "
            f"{len(diff.new)} survivors",
            old_bottleneck, cand.effective_bottleneck_ms,
            old_bottleneck / max(cand.effective_bottleneck_ms, 1e-12),
            plan=cand, executor=ex, widened=True,
            replicas=list(cand.replicas))
        self.last_decision = d
        return d


# --------------------------------------------------------------------------- #
# Fault-tolerant training driver
# --------------------------------------------------------------------------- #
@dataclass
class TrainResult:
    steps_done: int
    final_loss: float
    restarts: int
    straggler_redispatches: int
    losses: list[float] = field(default_factory=list)


class FaultTolerantDriver:
    """Checkpoint/restart loop around a pure ``step_fn(state, batch)``.

    ``step_fn`` returns (new_state, metrics-dict with "loss").
    ``faults`` is the fault-injection point: a
    :class:`~repro.runtime.faults.FaultPlan` or built injector whose
    :meth:`~repro.runtime.faults.FaultInjector.on_step` is called before
    each step — the same harness the serving executors hook, so training
    and serving share one injection API.  ``fail_hook(step)`` (the legacy
    callback) is still accepted and wrapped via
    :meth:`~repro.runtime.faults.FaultInjector.from_hook`.  Production
    leaves both None; real exceptions (device loss, preemption) take the
    same recovery path.
    """

    def __init__(self, step_fn: Callable, store, data, *,
                 ckpt_every: int = 50, max_restarts: int = 3,
                 async_ckpt: bool = True,
                 straggler: StragglerMonitor | None = None,
                 redispatch_stragglers: bool = False,
                 faults: Any = None,
                 fail_hook: Callable[[int], None] | None = None):
        from repro.runtime.faults import FaultInjector

        self.step_fn = step_fn
        self.store = store
        self.data = data
        self.ckpt_every = ckpt_every
        self.max_restarts = max_restarts
        self.async_ckpt = async_ckpt
        self.straggler = straggler or StragglerMonitor()
        self.redispatch = redispatch_stragglers
        if faults is not None and fail_hook is not None:
            raise ValueError("pass faults= OR the legacy fail_hook=, not both")
        self._injector = (FaultInjector.from_hook(fail_hook)
                          if fail_hook is not None else as_injector(faults))

    def run(self, state: Any, n_steps: int) -> tuple[Any, TrainResult]:
        import jax

        restarts = 0
        redispatches = 0
        # keyed by step so a restart that REPLAYS steps overwrites their
        # entries instead of appending duplicates (the pre-crash entries
        # for steps after the checkpoint used to double-count)
        losses: dict[int, float] = {}
        start = 0
        # resume from latest checkpoint if one exists
        latest = self.store.latest_step()
        if latest is not None:
            state, extra = self.store.restore(latest, like=state)
            start = int(extra.get("next_step", latest))

        step = start
        while step < n_steps:
            try:
                if self._injector is not None:
                    self._injector.on_step(step)
                batch = self.data.batch(step)
                t0 = time.perf_counter()
                state, metrics = self.step_fn(state, batch)
                jax.block_until_ready(metrics["loss"])
                dt = time.perf_counter() - t0
                if self.straggler.record(step, dt) and self.redispatch:
                    # re-dispatch the same step (pure fn + same batch = safe)
                    state, metrics = self.step_fn(state, batch)
                    jax.block_until_ready(metrics["loss"])
                    redispatches += 1
                losses[step] = float(metrics["loss"])
                step += 1
                if step % self.ckpt_every == 0 or step == n_steps:
                    saver = (self.store.save_async if self.async_ckpt
                             else self.store.save)
                    saver(step, state, {"next_step": step})
            except Exception:
                restarts += 1
                if restarts > self.max_restarts:
                    raise
                latest = self.store.latest_step()
                if latest is None:
                    step = 0      # restart from scratch
                    continue
                self.store.wait()
                state, extra = self.store.restore(latest, like=state)
                step = int(extra.get("next_step", latest))
        self.store.wait()
        loss_seq = [losses[k] for k in sorted(losses)]
        return state, TrainResult(steps_done=step,
                                  final_loss=loss_seq[-1] if loss_seq
                                  else float("nan"),
                                  restarts=restarts,
                                  straggler_redispatches=redispatches,
                                  losses=loss_seq)
