"""Distributed runtime — fault tolerance, stragglers, elastic re-planning.

* :class:`FaultTolerantDriver` — checkpoint/restart training loop: periodic
  (async) checkpoints, automatic reload-and-continue on step failure with
  bounded retries.  Deterministic data (``batch(step)``) makes the restart
  bit-exact: a resumed run re-executes the same token stream.
* :class:`StragglerMonitor` — per-step deadline tracking against a running
  median; flags and (optionally) re-dispatches slow steps.  On a real pod
  the re-dispatch hook would reschedule the step on a spare slice; here it
  re-issues the computation, which also covers transient host stalls.
* :class:`ElasticPlanner` — the Courier angle on elasticity: when the
  device count changes, *re-run the Pipeline Generator* to re-balance stage
  boundaries for the surviving resources (paper's balanced partition, new
  resource count), instead of aborting the job.  With a module database it
  also owns the serving-side executor: :meth:`ElasticPlanner.executor_for`
  recompiles the stage functions and rebuilds the
  :class:`~repro.core.executor.PipelineExecutor` *only* when the re-planned
  stage boundaries actually change, so an elastic resize is a cheap no-op
  when the balanced partition is unaffected.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable

import numpy as np

from repro.core.ir import CourierIR
from repro.core.partition import PipelinePlan, partition_optimal


# --------------------------------------------------------------------------- #
# Straggler mitigation
# --------------------------------------------------------------------------- #
class StragglerMonitor:
    def __init__(self, threshold: float = 3.0, window: int = 32):
        self.threshold = threshold
        self.times: list[float] = []
        self.window = window
        self.flagged: list[tuple[int, float]] = []

    def record(self, step: int, dt: float) -> bool:
        """Returns True if this step is a straggler (→ caller may re-dispatch)."""
        hist = self.times[-self.window:]
        self.times.append(dt)
        if len(hist) < 8:
            return False
        med = float(np.median(hist))
        if dt > self.threshold * med:
            self.flagged.append((step, dt))
            return True
        return False


# --------------------------------------------------------------------------- #
# Elastic re-planning (Courier re-balance on resource change)
# --------------------------------------------------------------------------- #
class ElasticPlanner:
    """Re-balance pipeline stage boundaries when the stage count changes.

    ``db`` (optional) enables the executor path: the planner can then turn
    a re-balanced plan into compiled stage functions and a running
    :class:`~repro.core.executor.PipelineExecutor`, caching the current
    executor keyed by its stage boundaries.
    """

    def __init__(self, layer_ir: CourierIR, db: Any = None):
        self.layer_ir = layer_ir
        self.db = db
        self._cached: tuple[tuple[int, ...], Any] | None = None
        self.rebuilds = 0                 # executor recompiles (observability)

    def plan(self, n_stages: int) -> PipelinePlan:
        return partition_optimal(self.layer_ir, max_stages=n_stages)

    def boundaries(self, n_stages: int) -> list[int]:
        plan = self.plan(n_stages)
        bounds, i = [], 0
        for s in plan.stages:
            bounds.append(i)
            i += len(s.node_names)
        return bounds

    def executor_for(self, n_stages: int, *, max_in_flight: int | None = None,
                     microbatch: int = 1, jit: bool = True) -> tuple[Any, bool]:
        """(executor, rebuilt) for a resource count of ``n_stages``.

        Re-partitions the IR for the new stage count; when the resulting
        stage boundaries (or the requested executor config) differ from the
        cached executor's, stage functions are recompiled and a fresh
        executor is returned (``rebuilt=True``).  An unchanged partition
        with the same config reuses the cached executor (``rebuilt=False``)
        — in-flight work and warm compilations survive the resize.
        """
        if self.db is None:
            raise ValueError("ElasticPlanner needs a ModuleDatabase to build "
                             "executors; pass db= at construction")
        from repro.core.executor import PipelineExecutor
        from repro.core.pipeline import assign_placements, make_stage_fns

        plan = self.plan(n_stages)
        key = (tuple(len(s.node_names) for s in plan.stages),
               max_in_flight, microbatch, jit)
        if self._cached is not None and self._cached[0] == key:
            return self._cached[1], False
        assign_placements(self.layer_ir, self.db)
        fns = make_stage_fns(self.layer_ir, self.db, plan, jit=jit)
        ex = PipelineExecutor(fns, self.layer_ir.graph_inputs,
                              self.layer_ir.graph_outputs,
                              max_in_flight=max_in_flight,
                              microbatch=microbatch)
        self._cached = (key, ex)
        self.rebuilds += 1
        return ex, True


# --------------------------------------------------------------------------- #
# Fault-tolerant training driver
# --------------------------------------------------------------------------- #
@dataclass
class TrainResult:
    steps_done: int
    final_loss: float
    restarts: int
    straggler_redispatches: int
    losses: list[float] = field(default_factory=list)


class FaultTolerantDriver:
    """Checkpoint/restart loop around a pure ``step_fn(state, batch)``.

    ``step_fn`` returns (new_state, metrics-dict with "loss").
    ``fail_hook(step)`` is the fault-injection point used by tests (raises
    to simulate a node failure); production leaves it None and real
    exceptions (device loss, preemption) take the same path.
    """

    def __init__(self, step_fn: Callable, store, data, *,
                 ckpt_every: int = 50, max_restarts: int = 3,
                 async_ckpt: bool = True,
                 straggler: StragglerMonitor | None = None,
                 redispatch_stragglers: bool = False,
                 fail_hook: Callable[[int], None] | None = None):
        self.step_fn = step_fn
        self.store = store
        self.data = data
        self.ckpt_every = ckpt_every
        self.max_restarts = max_restarts
        self.async_ckpt = async_ckpt
        self.straggler = straggler or StragglerMonitor()
        self.redispatch = redispatch_stragglers
        self.fail_hook = fail_hook

    def run(self, state: Any, n_steps: int) -> tuple[Any, TrainResult]:
        import jax

        restarts = 0
        redispatches = 0
        losses: list[float] = []
        start = 0
        # resume from latest checkpoint if one exists
        latest = self.store.latest_step()
        if latest is not None:
            state, extra = self.store.restore(latest, like=state)
            start = int(extra.get("next_step", latest))

        step = start
        while step < n_steps:
            try:
                if self.fail_hook is not None:
                    self.fail_hook(step)
                batch = self.data.batch(step)
                t0 = time.perf_counter()
                state, metrics = self.step_fn(state, batch)
                jax.block_until_ready(metrics["loss"])
                dt = time.perf_counter() - t0
                if self.straggler.record(step, dt) and self.redispatch:
                    # re-dispatch the same step (pure fn + same batch = safe)
                    state, metrics = self.step_fn(state, batch)
                    jax.block_until_ready(metrics["loss"])
                    redispatches += 1
                losses.append(float(metrics["loss"]))
                step += 1
                if step % self.ckpt_every == 0 or step == n_steps:
                    saver = (self.store.save_async if self.async_ckpt
                             else self.store.save)
                    saver(step, state, {"next_step": step})
            except Exception:
                restarts += 1
                if restarts > self.max_restarts:
                    raise
                latest = self.store.latest_step()
                if latest is None:
                    step = 0      # restart from scratch
                    continue
                self.store.wait()
                state, extra = self.store.restore(latest, like=state)
                step = int(extra.get("next_step", latest))
        self.store.wait()
        return state, TrainResult(steps_done=step,
                                  final_loss=losses[-1] if losses else float("nan"),
                                  restarts=restarts,
                                  straggler_redispatches=redispatches,
                                  losses=losses)
